#!/usr/bin/env bash
# CI entry point: build, test, docs, lint, and emit the benchmarks.
#
#   ./ci.sh            # build + test + doc + fmt/clippy + quick benchmarks
#   CI_SKIP_BENCH=1 ./ci.sh     # skip the serving + repro benchmarks
#   CI_STRICT=1 ./ci.sh         # fmt/clippy failures fail the run too
#
# Build and test failures always fail the run. fmt/clippy are advisory
# by default (CI_STRICT=1 promotes them) because the rustfmt/clippy
# components may be absent from minimal toolchains.
set -uo pipefail

cd "$(dirname "$0")"
ROOT="$(pwd)"
FAILURES=0
ADVISORY=0

note() { printf '\n== %s ==\n' "$*"; }

run_required() {
    note "$*"
    if ! "$@"; then
        echo "FAILED (required): $*"
        FAILURES=$((FAILURES + 1))
    fi
}

run_advisory() {
    note "$*"
    if ! "$@"; then
        echo "FAILED (advisory): $*"
        ADVISORY=$((ADVISORY + 1))
    fi
}

cd rust

run_required cargo build --release
run_required cargo test -q

# Repo-invariant static analysis (ISSUE 10): the lexical rules guarding
# the concurrency core (unsafe-safety, raw-spawn, panic-path,
# atomic-ordering, ablation-reach) plus the drift rules that keep THIS
# script's metrics gate and docs/ARCHITECTURE.md's tables in sync with
# what the code actually emits (metrics-drift, chaos-drift). Required:
# a violation is either a real hole in an invariant or a vocabulary
# drift, and both rot fast once tolerated.
run_required cargo run --release --quiet -- lint --json

# Docs are part of the deliverable (ISSUE 2): the crate carries
# #![deny(missing_docs)] and the doc build must be warning-free
# (broken intra-doc links etc. fail here, doc-tests fail `cargo test`).
note "cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
if ! RUSTDOCFLAGS="-D warnings" cargo doc --no-deps; then
    echo "FAILED (required): cargo doc --no-deps"
    FAILURES=$((FAILURES + 1))
fi

if cargo fmt --version >/dev/null 2>&1; then
    run_advisory cargo fmt --check
else
    echo "cargo fmt unavailable — skipping"
fi

if cargo clippy --version >/dev/null 2>&1; then
    # No blanket -D warnings: the deny-list is pinned in Cargo.toml
    # [lints.clippy], so this run and a developer's local `cargo clippy`
    # enforce the same set regardless of toolchain drift.
    run_advisory cargo clippy --all-targets
else
    echo "cargo clippy unavailable — skipping"
fi

# Concurrency sanitizers (advisory): dynamic checking that complements
# `boba lint`'s static rules. ThreadSanitizer races the pool, the
# coalescer, the trace ring, and the WAL/live-mutation path under real
# threads; Miri interprets the pointer-heavy single-thread kernels
# (parallel::, the trace ring's slot recycling, the .bcoo mmap-style
# decoder) with full provenance checking. Both need nightly — TSan
# additionally rust-src for -Zbuild-std — so stable-only containers
# skip them without failing the run.
if cargo +nightly --version >/dev/null 2>&1; then
    SYSROOT="$(rustc +nightly --print sysroot 2>/dev/null || true)"
    if [ -n "$SYSROOT" ] && [ -d "$SYSROOT/lib/rustlib/src/rust/library" ]; then
        note "ThreadSanitizer suites (nightly, advisory)"
        TSAN_TARGET="$(rustc +nightly -vV | sed -n 's/^host: //p')"
        tsan_test() {
            RUSTFLAGS="-Zsanitizer=thread" TSAN_OPTIONS="halt_on_error=1" \
                cargo +nightly test -q -Zbuild-std --target "$TSAN_TARGET" "$@"
        }
        if ! { tsan_test --test pool_stress \
            && tsan_test --test integration_mutate \
            && tsan_test --lib -- parallel:: server::coalesce obs::ring; }; then
            echo "FAILED (advisory): ThreadSanitizer suites"
            ADVISORY=$((ADVISORY + 1))
        fi
    else
        echo "nightly rust-src unavailable — skipping TSan suites"
    fi
    if cargo +nightly miri --version >/dev/null 2>&1; then
        note "Miri suites (nightly, advisory)"
        if ! MIRIFLAGS="-Zmiri-disable-isolation" cargo +nightly miri test -q --lib -- \
            parallel::par_concat graph::io::bcoo obs::ring; then
            echo "FAILED (advisory): Miri suites"
            ADVISORY=$((ADVISORY + 1))
        fi
    else
        echo "miri unavailable — skipping Miri suites"
    fi
else
    echo "nightly toolchain unavailable — skipping TSan/Miri suites"
fi

# Quick serving benchmark for the perf trajectory: BOBA-prepared vs
# random-labeled artifacts under a mixed SpMV/PageRank load, plus a
# single-vs-coalesced pricing row (--coalesce routes 4-query batches
# through POST /query/batch), written to BENCH_serve.json at the repo
# root. --spawn self-hosts an ephemeral server so the step is one
# self-contained command. --overload appends the resilience sweep:
# open-loop traffic at ~2x measured capacity against an admission-
# enabled server and an unprotected twin, pricing goodput and accepted-
# request p99 under overload (retries honor Retry-After). --churn
# appends the frozen-vs-mutating sweep: the same query mix with and
# without a fraction of durable POST /mutate batches against a
# WAL-enabled server, pricing what churn costs co-resident queries.
if [ "${CI_SKIP_BENCH:-0}" != "1" ] && [ "$FAILURES" -eq 0 ]; then
    note "serving benchmark (BENCH_serve.json)"
    if ! cargo run --release -- loadgen --spawn --compare --coalesce \
        --dataset rmat:14:8 --conns 4 --requests 600 \
        --mix spmv:7,pagerank:3 --pr-iters 5 --batch-queries 4 \
        --overload --retries 2 --churn --mutate-frac 0.3 \
        --scrape-metrics --json "$ROOT/BENCH_serve.json"; then
        echo "FAILED (required): serving benchmark"
        FAILURES=$((FAILURES + 1))
    elif ! grep -q '"mode":"single"' "$ROOT/BENCH_serve.json" \
        || ! grep -q '"mode":"coalesced"' "$ROOT/BENCH_serve.json" \
        || ! grep -q '"speedup_coalesced_qps"' "$ROOT/BENCH_serve.json"; then
        # The committed serving trajectory must price both axes:
        # reordering (reordered/baseline) AND batching (the coalesced
        # row with its speedup vs the single-query run).
        echo "FAILED (required): BENCH_serve.json lacks the coalesced-vs-single rows"
        FAILURES=$((FAILURES + 1))
    elif ! grep -q '"server"' "$ROOT/BENCH_serve.json" \
        || ! grep -q '"prepare.transpose"' "$ROOT/BENCH_serve.json"; then
        # --scrape-metrics must embed the server-side evidence: per-
        # endpoint p50/p99 from the /metrics delta plus the prepare
        # stage breakdown (ingest/reorder/convert/transpose).
        echo "FAILED (required): BENCH_serve.json lacks the scraped server-side evidence"
        FAILURES=$((FAILURES + 1))
    else
        # The overload sweep must land with its resilience accounting:
        # the serve-overload section (admission vs no_admission rows)
        # and the new per-run counters.
        for key in '"serve-overload"' '"overload"' '"no_admission"' \
                   '"rejected"' '"deadline_exceeded"' '"retries"'; do
            if ! grep -q "$key" "$ROOT/BENCH_serve.json"; then
                echo "FAILED (required): BENCH_serve.json lacks $key"
                FAILURES=$((FAILURES + 1))
            fi
        done
        # The churn sweep must land with its mutation accounting: the
        # serve-churn section (frozen vs mutating rows), the pricing
        # ratios, and the scraped server-side mutation counters.
        for key in '"churn"' '"serve-churn"' '"mutating"' \
                   '"goodput_ratio_mutating_vs_frozen"' \
                   '"p99_ratio_mutating_vs_frozen"' \
                   '"server_mutations_total"' '"server_compactions_total"'; do
            if ! grep -q "$key" "$ROOT/BENCH_serve.json"; then
                echo "FAILED (required): BENCH_serve.json lacks $key"
                FAILURES=$((FAILURES + 1))
            fi
        done
    fi

    # Observability gate: serve on a fixed port, drive real traffic,
    # then scrape /metrics and /debug/traces raw (bash /dev/tcp — no
    # curl dependency) and require every metric family the dashboards
    # and the loadgen scraper key on.
    note "metrics exposition gate"
    OBS_PORT="${CI_OBS_PORT:-7199}"
    http_get() {  # port path
        exec 3<>"/dev/tcp/127.0.0.1/$1" || return 1
        printf 'GET %s HTTP/1.1\r\nhost: ci\r\nconnection: close\r\n\r\n' "$2" >&3
        cat <&3
        exec 3>&- 2>/dev/null
    }
    http_post() {  # port path body
        exec 3<>"/dev/tcp/127.0.0.1/$1" || return 1
        printf 'POST %s HTTP/1.1\r\nhost: ci\r\nconnection: close\r\ncontent-length: %s\r\n\r\n%s' \
            "$2" "${#3}" "$3" >&3
        cat <&3
        exec 3>&- 2>/dev/null
    }
    ./target/release/boba serve --addr "127.0.0.1:$OBS_PORT" --workers 4 \
        --max-inflight 8 --default-deadline-ms 5000 \
        --slow-trace-ms 5000 --format delta &
    SERVE_PID=$!
    sleep 1
    # Liveness vs readiness split: /healthz answers from the first
    # accept; /readyz reports ready on an idle, prepared-or-empty
    # server.
    if ! http_get "$OBS_PORT" /healthz | grep -q '"status":"ok"'; then
        echo "FAILED (required): /healthz is not answering ok"
        FAILURES=$((FAILURES + 1))
    fi
    if ! http_get "$OBS_PORT" /readyz | grep -q '"status":"ready"'; then
        echo "FAILED (required): /readyz is not ready on an idle server"
        FAILURES=$((FAILURES + 1))
    fi
    if ! cargo run --release -- loadgen --addr "127.0.0.1:$OBS_PORT" \
        --dataset rmat:12:8 --conns 2 --requests 120 --mix spmv:3,pagerank:1; then
        echo "FAILED (required): loadgen against the fixed-port server"
        FAILURES=$((FAILURES + 1))
    fi
    METRICS="$ROOT/ci_metrics.txt"
    http_get "$OBS_PORT" /metrics > "$METRICS" || true
    for fam in boba_uptime_seconds boba_requests_total boba_request_errors_total \
               boba_request_duration_seconds boba_registry_graphs boba_registry_hits_total \
               boba_registry_misses_total boba_registry_evictions_total \
               boba_registry_capacity boba_registry_prepares_total \
               boba_pool_dispatches_total boba_pool_threads boba_pool_threads_spawned \
               boba_coalesce_batches_total boba_coalesce_batch_width \
               boba_coalesce_queries_total boba_coalesce_groups \
               boba_stage_duration_seconds boba_process_resident_memory_bytes \
               boba_process_resident_memory_peak_bytes \
               boba_traces_total boba_format_bytes_per_edge \
               boba_inflight boba_admission_rejected_total boba_deadline_exceeded_total \
               boba_mutations_total boba_compactions_total boba_delta_entries \
               boba_recovering boba_io_corruption_total; do
        if ! grep -q "^# TYPE $fam " "$METRICS"; then
            echo "FAILED (required): /metrics lacks family $fam"
            FAILURES=$((FAILURES + 1))
        fi
    done
    if ! http_get "$OBS_PORT" '/debug/traces?n=8' | grep -q '"endpoint":"ingest"'; then
        echo "FAILED (required): /debug/traces has no ingest trace"
        FAILURES=$((FAILURES + 1))
    fi
    kill "$SERVE_PID" 2>/dev/null
    wait "$SERVE_PID" 2>/dev/null
    rm -f "$METRICS"

    # Crash-recovery smoke: a WAL-enabled fixed-port server is killed
    # by the `crash-after-append` fault mid-churn (the process aborts
    # *after* the record is fsync-durable — the SIGKILL window the WAL
    # exists for), restarted over the same --wal-dir, and its replayed
    # digest must equal a never-crashed twin that applied the same
    # batches. The digest is the label-invariant edge-multiset hash, so
    # equality holds even though the restart re-runs BOBA from scratch.
    note "crash-recovery smoke"
    WAL_DIR="$ROOT/ci_wal"
    TWIN_DIR="$ROOT/ci_wal_twin"
    rm -rf "$WAL_DIR" "$TWIN_DIR"
    CRASH_PORT=$((OBS_PORT + 1))
    TWIN_PORT=$((OBS_PORT + 2))
    CRASH_DATASET='{"dataset": "pa:2000:4"}'
    mutate_body() {
        printf '{"ops": [{"op": "upsert", "u": %s, "v": %s, "w": 1.5}, {"op": "delete", "u": %s, "v": %s}]}' \
            "$1" "$(((($1 + 7)) % 2000))" "$((($1 * 3) % 2000))" "$((($1 * 5) % 2000))"
    }
    wait_ready() {  # port
        for _ in $(seq 1 150); do
            if http_get "$1" /readyz 2>/dev/null | grep -q '"status":"ready"'; then
                return 0
            fi
            sleep 0.2
        done
        return 1
    }
    # The 4th append aborts the server (skip 3, then fire once): three
    # acked batches plus one durable-but-maybe-unacked record on disk.
    BOBA_FAULTS='crash-after-append:1:3' ./target/release/boba serve \
        --addr "127.0.0.1:$CRASH_PORT" --workers 2 --wal-dir "$WAL_DIR" &
    CRASH_PID=$!
    wait_ready "$CRASH_PORT"
    GID=$(http_post "$CRASH_PORT" /graphs "$CRASH_DATASET" \
        | grep -o '"id":"[^"]*"' | head -1 | cut -d'"' -f4)
    for i in 1 2 3 4; do
        http_post "$CRASH_PORT" "/graphs/$GID/mutate" "$(mutate_body "$i")" >/dev/null 2>&1 || true
    done
    wait "$CRASH_PID" 2>/dev/null
    if kill -0 "$CRASH_PID" 2>/dev/null; then
        echo "FAILED (required): crash-after-append did not kill the server"
        FAILURES=$((FAILURES + 1))
        kill -9 "$CRASH_PID" 2>/dev/null
    fi
    # The never-crashed twin applies the identical four batches (the
    # 4th record was durable on the crash server, so replay includes it).
    ./target/release/boba serve --addr "127.0.0.1:$TWIN_PORT" --workers 2 \
        --wal-dir "$TWIN_DIR" &
    TWIN_PID=$!
    wait_ready "$TWIN_PORT"
    TID=$(http_post "$TWIN_PORT" /graphs "$CRASH_DATASET" \
        | grep -o '"id":"[^"]*"' | head -1 | cut -d'"' -f4)
    for i in 1 2 3 4; do
        http_post "$TWIN_PORT" "/graphs/$TID/mutate" "$(mutate_body "$i")" >/dev/null
    done
    TWIN_DIGEST=$(http_get "$TWIN_PORT" "/graphs/$TID/digest" | grep -o '"digest":"[0-9a-f]*"')
    kill "$TWIN_PID" 2>/dev/null
    wait "$TWIN_PID" 2>/dev/null
    # Restart over the crash-state directory (no faults armed) and let
    # WAL replay finish (/readyz drops its `recovering` reason).
    ./target/release/boba serve --addr "127.0.0.1:$CRASH_PORT" --workers 2 \
        --wal-dir "$WAL_DIR" &
    CRASH_PID=$!
    if ! wait_ready "$CRASH_PORT"; then
        echo "FAILED (required): restarted server never finished WAL replay"
        FAILURES=$((FAILURES + 1))
    fi
    CRASH_DIGEST=$(http_get "$CRASH_PORT" "/graphs/$GID/digest" | grep -o '"digest":"[0-9a-f]*"')
    if [ -z "$TWIN_DIGEST" ] || [ "$CRASH_DIGEST" != "$TWIN_DIGEST" ]; then
        echo "FAILED (required): replayed digest $CRASH_DIGEST != twin $TWIN_DIGEST"
        FAILURES=$((FAILURES + 1))
    fi
    if ! http_get "$CRASH_PORT" /metrics | grep -q '^boba_mutations_total'; then
        echo "FAILED (required): recovered server does not export boba_mutations_total"
        FAILURES=$((FAILURES + 1))
    fi
    kill "$CRASH_PID" 2>/dev/null
    wait "$CRASH_PID" 2>/dev/null
    rm -rf "$WAL_DIR" "$TWIN_DIR"

    # Paper-reproduction smoke run: T1–T5 on the generated quick trio,
    # writing the trajectory JSON and regenerating docs/RESULTS.md from
    # the same records (uploaded as a CI artifact). The run itself is the
    # first determinism gate: T2 errors out if the deterministic parallel
    # converter's output digest diverges from the sequential digest.
    note "repro smoke (BENCH_repro.json + docs/RESULTS.md)"
    if ! cargo run --release -- repro --quick \
        --json "$ROOT/BENCH_repro.json" --md "$ROOT/docs/RESULTS.md"; then
        echo "FAILED (required): repro smoke"
        FAILURES=$((FAILURES + 1))
    elif ! grep -q 'convert_par_det_ms' "$ROOT/BENCH_repro.json"; then
        # Belt-and-braces: the committed trajectory must carry the
        # par-det conversion rows (digest-gated in t2_conversion).
        echo "FAILED (required): BENCH_repro.json has no convert_par_det_ms rows"
        FAILURES=$((FAILURES + 1))
    elif ! grep -q 'ingest_ms' "$ROOT/BENCH_repro.json"; then
        # Schema boba-repro/2: T3 prices the ingest stage per dataset.
        echo "FAILED (required): BENCH_repro.json has no T3 ingest_ms rows"
        FAILURES=$((FAILURES + 1))
    elif ! grep -q 'bytes_per_edge' "$ROOT/BENCH_repro.json"; then
        # Schema boba-repro/3: T5 prices the compressed kernel formats.
        echo "FAILED (required): BENCH_repro.json has no T5 bytes_per_edge rows"
        FAILURES=$((FAILURES + 1))
    fi

    # Pool-dispatch microbench smoke: one iteration, just to prove the
    # pool-vs-spawn harness builds and runs (full numbers are a manual
    # `cargo bench --bench micro_pool`, recorded in docs/EXPERIMENTS.md).
    note "micro_pool smoke"
    if ! cargo bench --bench micro_pool -- --smoke; then
        echo "FAILED (required): micro_pool smoke"
        FAILURES=$((FAILURES + 1))
    fi

    # Ingest microbench smoke: one iteration of seq-text vs parallel-
    # text vs .bcoo, just to prove the harness builds and every path
    # loads the same graph (full numbers: `cargo bench --bench
    # micro_ingest`, recorded in docs/EXPERIMENTS.md §Ingest).
    note "micro_ingest smoke"
    if ! cargo bench --bench micro_ingest -- --smoke; then
        echo "FAILED (required): micro_ingest smoke"
        FAILURES=$((FAILURES + 1))
    fi

    # Batched-SpMV microbench smoke: one iteration of the k-sweep (k
    # independent spmv calls vs one spmm pass, boba vs random ordering).
    # The bench asserts spmm is bit-identical to the k spmv calls before
    # timing, so this doubles as a determinism gate (full numbers:
    # `cargo bench --bench micro_batch`, docs/EXPERIMENTS.md §Batching).
    note "micro_batch smoke"
    if ! cargo bench --bench micro_batch -- --smoke; then
        echo "FAILED (required): micro_batch smoke"
        FAILURES=$((FAILURES + 1))
    fi

    # Kernel-format microbench smoke: one iteration of encode + SpMV
    # per format on both orderings. The bench gates every format
    # bit-identical to spmv_pull before timing, so this doubles as a
    # determinism gate (full numbers: `cargo bench --bench
    # micro_format`, docs/EXPERIMENTS.md §Formats).
    note "micro_format smoke"
    if ! cargo bench --bench micro_format -- --smoke; then
        echo "FAILED (required): micro_format smoke"
        FAILURES=$((FAILURES + 1))
    fi

    # Tracing-overhead smoke: the bench itself asserts < 5 µs per span
    # with tracing on (the serve path wraps every kernel in a span, so
    # regressions here tax every query).
    note "micro_obs smoke"
    if ! cargo bench --bench micro_obs -- --smoke; then
        echo "FAILED (required): micro_obs smoke"
        FAILURES=$((FAILURES + 1))
    fi
fi

cd "$ROOT"
printf '\n== summary ==\n'
echo "required failures: $FAILURES, advisory failures: $ADVISORY"
if [ "${CI_STRICT:-0}" = "1" ]; then
    FAILURES=$((FAILURES + ADVISORY))
fi
exit "$([ "$FAILURES" -eq 0 ] && echo 0 || echo 1)"
