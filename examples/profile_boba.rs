//! §Perf instrumentation harness: times each phase of parallel BOBA
//! (records pass, rank compaction, relabel) separately, across thread
//! counts. Used to drive the docs/EXPERIMENTS.md §Perf iteration log.
//!
//! Run: `cargo run --release --example profile_boba`

use boba::graph::gen::{self, GenParams};
use boba::parallel::{self, atomic::AtomicU32Array, ThreadGuard};
use boba::reorder::{boba::Boba, Reorderer};
use std::time::Instant;

fn time_ms(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warm
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() * 1e3 / iters as f64
}

fn main() {
    let g = gen::rmat(&GenParams::rmat(18, 16), 1).randomized(2);
    let (n, m) = (g.n(), g.m());
    println!("rmat18: n={n} m={m}");

    // Phase 1: records pass (racy min over I++J).
    let records_pass = || {
        let records = AtomicU32Array::new(n, u32::MAX);
        let chunk = parallel::default_chunk(2 * m);
        let src = &g.src;
        let dst = &g.dst;
        parallel::par_for_chunks(2 * m, chunk, |lo, hi| {
            let (i_lo, i_hi) = (lo.min(m), hi.min(m));
            for i in i_lo..i_hi {
                records.racy_min(src[i] as usize, i as u32);
            }
            for i in lo.max(m)..hi.max(m) {
                records.racy_min(dst[i - m] as usize, i as u32);
            }
        });
        records
    };
    println!(
        "records pass:   {:.2} ms",
        time_ms(10, || {
            std::hint::black_box(records_pass());
        })
    );

    // Phase 2: rank compaction (sort of (record, v) keys).
    let records = records_pass().into_vec();
    println!(
        "rank compact:   {:.2} ms",
        time_ms(10, || {
            let mut keyed: Vec<u64> =
                (0..n).map(|v| ((records[v] as u64) << 32) | v as u64).collect();
            keyed.sort_unstable();
            std::hint::black_box(keyed);
        })
    );

    // Phase 3: relabel (2m gathers through the permutation).
    let p = Boba::parallel().reorder(&g);
    let perm = p.new_of_old().to_vec();
    println!(
        "relabel:        {:.2} ms",
        time_ms(10, || {
            std::hint::black_box(g.relabeled(&perm));
        })
    );

    // Whole algorithm across threads.
    for t in [1usize, 2, 4, 8, 16] {
        let _guard = ThreadGuard::pin(t);
        let ms = time_ms(10, || {
            std::hint::black_box(Boba::parallel().reorder(&g));
        });
        println!("BOBA total (t={t:>2}): {ms:.2} ms");
    }
}
