//! END-TO-END DRIVER — the full system on a real workload.
//!
//! This is the repository's E2E validation (docs/EXPERIMENTS.md §E2E): it
//! exercises every layer together on the paper's Problem-3 scenario:
//!
//!   1. a producer thread streams edge batches (the RAPIDS-style online
//!      setting) through the backpressured ingest channel;
//!   2. the coordinator assembles the COO, reorders with parallel BOBA
//!      (Algorithm 3), converts to CSR — all stages timed;
//!   3. all four paper workloads (SpMV, PageRank, TC, SSSP) run on both
//!      the random-labeled and BOBA-reordered graphs (native kernels);
//!   4. PageRank additionally runs through the AOT PJRT artifacts (L2
//!      jnp graph — the L1 Pallas variant is validated in pjrt_spmv),
//!      proving the three-layer stack composes: Rust → PJRT → XLA-compiled
//!      JAX/Pallas compute, Python absent at runtime;
//!   5. prints the headline metric: end-to-end speedup including
//!      reordering cost (paper: up to 3.45×, median ~2.35× for SpMV).
//!
//! Run: `make artifacts && cargo run --release --example e2e_pipeline`
//! (BOBA_SCALE=full for the paper-scale version.)

use boba::convert;
use boba::coordinator::datasets;
use boba::coordinator::pipeline::{App, Pipeline, ReorderStage, StreamingIngest};
use boba::reorder::{boba::Boba, Reorderer};
use boba::runtime::{ell::EllPlan, Engine};
use boba::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    // ── workload: a PA-web-like graph, randomized labels ─────────────
    // Sized so the dense working set exceeds LLC (the regime the paper
    // targets; cache-resident graphs have nothing to gain from
    // reordering). BOBA_SCALE=full doubles it again.
    let dataset = datasets::by_name("pa_c8").unwrap();
    let n = match datasets::Scale::from_env() {
        datasets::Scale::Quick => 500_000,
        datasets::Scale::Full => 2_000_000,
    };
    let raw = boba::graph::gen::preferential_attachment(n, 8, 42);
    let graph = raw.randomized(7);
    println!(
        "workload: pa n={} m={} (stands in for {})",
        graph.n(),
        graph.m(),
        dataset.stands_in_for,
    );

    // ── stage 0: streaming ingestion with backpressure ───────────────
    let sw = Stopwatch::start();
    let (producer, stream) = StreamingIngest::from_coo(graph.clone(), 1 << 15, 4);
    let (assembled, batches) = stream.collect();
    producer.join().ok();
    println!("ingest: {batches} batches in {:.2} ms", sw.ms());
    assert_eq!(assembled.m(), graph.m());

    // ── stages 1–3 for each app, Random vs BOBA ──────────────────────
    let mut speedups: Vec<(String, f64)> = Vec::new();
    println!("\n{:<6} {:>12} {:>12} {:>9}  breakdown (BOBA)", "app", "rand ms", "boba ms", "speedup");
    for app in App::all() {
        let pipe = Pipeline::new(app);
        let rand = pipe.run(&assembled, &ReorderStage::None);
        let boba_run = pipe.run(&assembled, &ReorderStage::Scheme(Box::new(Boba::parallel())));
        // Cross-scheme correctness: digests must agree (f32 reduction
        // order differs under relabeling, hence the loose tolerance).
        let tol = 1e-3 * rand.digest.abs().max(1.0);
        assert!(
            (rand.digest - boba_run.digest).abs() <= tol,
            "{}: digest {} vs {}",
            app.name(),
            rand.digest,
            boba_run.digest
        );
        let speedup = rand.total_ms() / boba_run.total_ms();
        println!(
            "{:<6} {:>12.2} {:>12.2} {:>8.2}x  [{}]",
            app.name(),
            rand.total_ms(),
            boba_run.total_ms(),
            speedup,
            boba_run.stages.summary()
        );
        speedups.push((app.name().to_string(), speedup));
    }

    // ── the PJRT path: PageRank through the AOT artifacts ────────────
    // Validation-sized (the tile-pass launch overhead of the CPU-PJRT
    // engine at 500k vertices would dominate the example; pjrt perf is
    // profiled separately in docs/EXPERIMENTS.md §Perf).
    println!("\nPJRT (AOT jax→HLO→xla) PageRank:");
    let engine = Engine::load_default()?;
    let small = boba::graph::gen::preferential_attachment(40_000, 6, 43).randomized(5);
    let (_, reordered) = Boba::parallel().reorder_relabel(&small);
    let csr = convert::coo_to_csr(&reordered);
    let plan = EllPlan::pack_pagerank(&csr, engine.meta)?;
    let pr_iters = 15;
    let sw = Stopwatch::start();
    let (ranks, iters) = engine.pagerank(&plan, csr.n(), 0.85, pr_iters, 0.0)?;
    let pjrt_ms = sw.ms();
    // Validate against the native kernel.
    let native = boba::algos::pagerank::pagerank(
        &csr,
        boba::algos::pagerank::PrParams { max_iters: pr_iters, tol: 0.0, ..Default::default() },
    );
    let max_diff = ranks
        .iter()
        .zip(&native.ranks)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!(
        "  {} tile passes/iter, {iters} iters in {pjrt_ms:.1} ms on {}, max |Δrank| vs native = {max_diff:.2e}",
        plan.passes(),
        engine.platform()
    );
    anyhow::ensure!(max_diff < 1e-4, "PJRT PageRank diverged from native");

    // ── headline ─────────────────────────────────────────────────────
    let best = speedups
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "\nheadline: best end-to-end speedup (incl. reorder cost) = {:.2}x on {} \
         (paper: up to 3.45x)",
        best.1, best.0
    );
    println!("E2E OK — all layers composed, all digests matched.");
    Ok(())
}
