//! Figure 2 reproduction: spy-plot visualizations of the adjacency
//! matrix under five orderings — original, randomized, BOBA, RCM, Gorder.
//!
//! Writes one PGM image per (dataset, ordering) into `spy_plots/` plus a
//! coarse ASCII rendering to stdout. As in the paper's Figure 2, BOBA's
//! plot visibly restores the original structure on PA-generated graphs
//! and keeps band structure on meshes, while the randomized plot is
//! uniform noise.
//!
//! Run: `cargo run --release --example spy_plot`

use boba::graph::{gen, Coo};
use boba::metrics;
use boba::reorder::{boba::Boba, gorder::Gorder, rcm::Rcm, Reorderer};
use std::io::Write;
use std::path::Path;

const RES: usize = 256; // spy-plot resolution (RES × RES density bins)

fn density(coo: &Coo) -> Vec<u32> {
    let n = coo.n().max(1);
    let mut bins = vec![0u32; RES * RES];
    for (u, v) in coo.edges() {
        let bu = (u as usize * RES) / n;
        let bv = (v as usize * RES) / n;
        bins[bu * RES + bv] += 1;
    }
    bins
}

fn write_pgm(bins: &[u32], path: &Path) -> std::io::Result<()> {
    let max = *bins.iter().max().unwrap_or(&1) as f64;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "P2\n{RES} {RES}\n255")?;
    for r in 0..RES {
        let row: Vec<String> = (0..RES)
            .map(|c| {
                // log-scale density -> darkness (255 = empty, 0 = dense)
                let v = bins[r * RES + c] as f64;
                let shade = if v == 0.0 {
                    255
                } else {
                    (255.0 * (1.0 - (1.0 + v).ln() / (1.0 + max).ln())) as u32
                };
                shade.to_string()
            })
            .collect();
        writeln!(f, "{}", row.join(" "))?;
    }
    Ok(())
}

fn ascii(bins: &[u32]) -> String {
    const W: usize = 48;
    let max = *bins.iter().max().unwrap_or(&1) as f64;
    let mut out = String::new();
    for r in 0..W {
        for c in 0..W {
            // Downsample RES -> W.
            let mut acc = 0u64;
            for rr in r * RES / W..(r + 1) * RES / W {
                for cc in c * RES / W..(c + 1) * RES / W {
                    acc += bins[rr * RES + cc] as u64;
                }
            }
            let shades = [' ', '.', ':', '+', '#', '@'];
            let idx = if acc == 0 {
                0
            } else {
                (((acc as f64).ln() / (max * 4.0 + 1.0).ln()) * 5.0).ceil().min(5.0) as usize
            };
            out.push(shades[idx]);
        }
        out.push('\n');
    }
    out
}

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("spy_plots")?;
    let cases: Vec<(&str, Coo)> = vec![
        // Fig 2a: simulated power-law graph.
        ("pa", gen::preferential_attachment(8_000, 6, 3)),
        // Fig 2c: regular uniform graph (delaunay-like mesh).
        ("delaunay", gen::delaunay_mesh(90, 90, 3).symmetrized()),
    ];
    for (name, original) in cases {
        let randomized = original.randomized(11);
        let schemes: Vec<(&str, Coo)> = vec![
            ("original", original.clone()),
            ("random", randomized.clone()),
            ("boba", {
                let p = Boba::parallel().reorder(&randomized);
                randomized.relabeled(p.new_of_old())
            }),
            ("rcm", {
                let p = Rcm::new().reorder(&randomized);
                randomized.relabeled(p.new_of_old())
            }),
            ("gorder", {
                let p = Gorder::new(5).reorder(&randomized);
                randomized.relabeled(p.new_of_old())
            }),
        ];
        println!("=== {name} (n={} m={}) ===", original.n(), original.m());
        for (scheme, graph) in &schemes {
            let bins = density(graph);
            let path = format!("spy_plots/{name}_{scheme}.pgm");
            write_pgm(&bins, Path::new(&path))?;
            println!(
                "{scheme:>9}: NBR {:.3}, avg |p(u)-p(v)| {:>10.1}  -> {path}",
                metrics::nbr_coo(graph),
                metrics::avg_edge_distance(graph),
            );
        }
        // ASCII for the most instructive pair, like the paper's side-by-side.
        println!("\n{name}/random:");
        println!("{}", ascii(&density(&schemes[1].1)));
        println!("{name}/boba:");
        println!("{}", ascii(&density(&schemes[2].1)));
    }
    println!("wrote spy_plots/*.pgm (viewable with any image tool)");
    Ok(())
}
