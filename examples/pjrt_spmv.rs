//! PJRT round-trip: run SpMV through both AOT artifacts (plain-jnp L2
//! graph and the Pallas L1 kernel's lowering) and validate against the
//! native Rust kernel — proving the three layers compute the same thing
//! and that BOBA's reordering also reduces the tile-pass count the
//! runtime must launch.
//!
//! Requires `make artifacts` first.
//! Run: `cargo run --release --example pjrt_spmv`

use boba::convert;
use boba::graph::gen;
use boba::reorder::{boba::Boba, Reorderer};
use boba::runtime::{ell::EllPlan, Engine, SpmvKind};
use boba::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load_default()?;
    println!(
        "engine: platform={} tile={}x{}",
        engine.platform(),
        engine.meta.n_tile,
        engine.meta.k
    );

    let g = gen::preferential_attachment(30_000, 6, 5).randomized(3);
    let csr_rand = convert::coo_to_csr(&g);
    let perm = Boba::parallel().reorder(&g);
    let reordered = g.relabeled(perm.new_of_old());
    let csr_boba = convert::coo_to_csr(&reordered);

    let x = vec![1.0f32; g.n()];
    let native = boba::algos::spmv::spmv_pull(&csr_rand, &x);

    for (label, csr) in [("random", &csr_rand), ("BOBA", &csr_boba)] {
        let plan = EllPlan::pack(csr, engine.meta)?;
        for kind in [SpmvKind::Jnp, SpmvKind::Pallas] {
            let sw = Stopwatch::start();
            let y = plan.execute(&engine, kind, &x)?;
            let ms = sw.ms();
            // Digest comparison (labels differ, sums agree).
            let sum: f64 = y.iter().map(|&v| v as f64).sum();
            let native_sum: f64 = native.iter().map(|&v| v as f64).sum();
            assert!(
                (sum - native_sum).abs() < 1e-5 * native_sum.abs().max(1.0),
                "digest mismatch: {sum} vs {native_sum}"
            );
            println!(
                "{label:>7} / {kind:?}: {:>4} tile passes, {ms:>8.2} ms, Σy = {sum:.1} ✓",
                plan.passes()
            );
        }
    }
    println!("\nAll artifact outputs match the native kernel. Python was not involved.");
    Ok(())
}
