//! Figure 7 companion: trace one kernel through the cache simulator and
//! dump a per-scheme breakdown, including the per-region (x-gather vs
//! index-stream) hit behaviour that explains WHY BOBA helps — the paper's
//! §5.5 analysis at finer grain than the figure.
//!
//! Run: `cargo run --release --example cache_analysis`

use boba::algos::spmv;
use boba::algos::trace::{Region, Tracer};
use boba::cachesim::Hierarchy;
use boba::convert;
use boba::graph::gen::{self, GenParams};
use boba::reorder::{boba::Boba, degree::DegreeSort, hub::HubSort, Reorderer};

/// A tracer that routes accesses to a hierarchy AND tallies per-region
/// miss rates (the x-gather region is the interesting one).
struct RegionStats {
    hier: Hierarchy,
    x_reads: u64,
    x_l1_hits: u64,
    other_reads: u64,
    other_l1_hits: u64,
}

impl RegionStats {
    fn new() -> Self {
        Self {
            hier: Hierarchy::v100_scaled(),
            x_reads: 0,
            x_l1_hits: 0,
            other_reads: 0,
            other_l1_hits: 0,
        }
    }
}

impl Tracer for RegionStats {
    fn read(&mut self, addr: u64) {
        let is_x = (addr >> 30) == (Region::VectorX as u64);
        let hit = self.hier.l1.access(addr);
        if !hit {
            self.hier.l2.access(addr);
        }
        if is_x {
            self.x_reads += 1;
            self.x_l1_hits += hit as u64;
        } else {
            self.other_reads += 1;
            self.other_l1_hits += hit as u64;
        }
    }
}

fn main() {
    let g = gen::rmat(&GenParams::rmat(17, 8), 42).randomized(9);
    println!("SpMV cache analysis on rmat17 (n={} m={})\n", g.n(), g.m());
    let schemes: Vec<(String, boba::graph::Coo)> = {
        let mut v = vec![("Random".to_string(), g.clone())];
        let list: Vec<Box<dyn Reorderer>> = vec![
            Box::new(Boba::parallel()),
            Box::new(HubSort::new()),
            Box::new(DegreeSort::new()),
        ];
        for s in list {
            let p = s.reorder(&g);
            v.push((s.name().to_string(), g.relabeled(p.new_of_old())));
        }
        v
    };
    println!(
        "{:>8}  {:>9} {:>9} {:>9} | {:>12} {:>14}",
        "scheme", "L1 %", "L2 %", "DRAM %", "x-gather L1%", "stream L1%"
    );
    for (name, graph) in schemes {
        let csr = convert::coo_to_csr(&graph);
        let x = vec![1.0f32; csr.n()];
        let mut t = RegionStats::new();
        let _y = spmv::spmv_pull_traced(&csr, &x, &mut t);
        let r = t.hier.rates();
        println!(
            "{:>8}  {:>8.1}% {:>8.1}% {:>8.1}% | {:>11.1}% {:>13.1}%",
            name,
            r.l1 * 100.0,
            r.l2 * 100.0,
            r.dram_fraction * 100.0,
            100.0 * t.x_l1_hits as f64 / t.x_reads.max(1) as f64,
            100.0 * t.other_l1_hits as f64 / t.other_reads.max(1) as f64,
        );
    }
    println!(
        "\nThe index/offset streams hit regardless of ordering; the x-gather\n\
         column is where reordering acts — the paper's Algorithm 1 line 4."
    );
}
