//! §Perf probe: two-stage (relabel → convert) vs fused relabel-convert.
use boba::convert;
use boba::graph::gen;
use boba::reorder::{boba::Boba, Reorderer};
use std::time::Instant;
fn main() {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8_000_000);
    let g = gen::preferential_attachment(n, 8, 42).randomized(7);
    let t = Instant::now();
    let csr0 = convert::coo_to_csr(&g);
    println!("rand convert:      {:.0} ms", t.elapsed().as_secs_f64()*1e3);
    let t = Instant::now();
    let p = Boba::parallel().reorder(&g);
    println!("reorder (perm):    {:.0} ms", t.elapsed().as_secs_f64()*1e3);
    let t = Instant::now();
    let relab = g.relabeled(p.new_of_old());
    println!("relabel:           {:.0} ms", t.elapsed().as_secs_f64()*1e3);
    let t = Instant::now();
    let csr1 = convert::coo_to_csr(&relab);
    println!("convert (boba):    {:.0} ms", t.elapsed().as_secs_f64()*1e3);
    let t = Instant::now();
    let csr2 = convert::coo_to_csr_relabeled(&g, p.new_of_old());
    println!("fused:             {:.0} ms", t.elapsed().as_secs_f64()*1e3);
    assert_eq!(csr1, csr2);
    std::hint::black_box((csr0, csr1, csr2));
}
