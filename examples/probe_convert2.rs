//! §Perf probe: COO→CSR with prefetched histogram + scatter.
use boba::graph::gen;
use boba::graph::Csr;
use std::time::Instant;

fn convert_pf(coo: &boba::graph::Coo, dist: usize) -> Csr {
    let n = coo.n();
    let m = coo.m();
    let src = &coo.src;
    let mut row_ptr = vec![0u64; n + 1];
    for e in 0..m {
        if e + dist < m {
            unsafe { core::arch::x86_64::_mm_prefetch(
                row_ptr.as_ptr().add(src[e + dist] as usize + 1) as *const i8,
                core::arch::x86_64::_MM_HINT_T0) };
        }
        row_ptr[src[e] as usize + 1] += 1;
    }
    for i in 0..n { row_ptr[i + 1] += row_ptr[i]; }
    let mut cursor = row_ptr.clone();
    let mut col_idx = vec![0u32; m];
    for e in 0..m {
        if e + dist < m {
            unsafe { core::arch::x86_64::_mm_prefetch(
                cursor.as_ptr().add(src[e + dist] as usize) as *const i8,
                core::arch::x86_64::_MM_HINT_T0) };
        }
        let s = src[e] as usize;
        let pos = cursor[s] as usize;
        cursor[s] += 1;
        col_idx[pos] = coo.dst[e];
    }
    Csr { row_ptr, col_idx, vals: None }
}

fn main() {
    let g = gen::preferential_attachment(8_000_000, 8, 42).randomized(7);
    let base = boba::convert::coo_to_csr(&g);
    for dist in [0usize, 16, 32, 64] {
        let t = Instant::now();
        let c = if dist == 0 { boba::convert::coo_to_csr(&g) } else { convert_pf(&g, dist) };
        println!("dist={dist:>3}: {:.0} ms", t.elapsed().as_secs_f64() * 1e3);
        assert_eq!(c, base);
    }
}
