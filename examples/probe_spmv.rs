//! §Perf probe: SpMV gather with/without software prefetch.
use boba::convert::coo_to_csr;
use boba::graph::gen;
use std::time::Instant;

fn spmv_prefetch(csr: &boba::graph::Csr, x: &[f32], dist: usize) -> Vec<f32> {
    let mut y = vec![0f32; csr.n()];
    let cols = &csr.col_idx;
    for v in 0..csr.n() {
        let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
        let mut acc = 0f32;
        for e in lo..hi {
            let pf = e + dist;
            if pf < cols.len() {
                unsafe {
                    #[cfg(target_arch = "x86_64")]
                    core::arch::x86_64::_mm_prefetch(
                        x.as_ptr().add(cols[pf] as usize) as *const i8,
                        core::arch::x86_64::_MM_HINT_T0,
                    );
                }
            }
            acc += x[cols[e] as usize];
        }
        y[v] = acc;
    }
    y
}

fn main() {
    let g = gen::preferential_attachment(8_000_000, 8, 42).randomized(7);
    let csr = coo_to_csr(&g);
    let x = vec![1.0f32; csr.n()];
    let base = boba::algos::spmv::spmv_pull(&csr, &x);
    for dist in [0usize, 8, 16, 32, 64] {
        let t = Instant::now();
        let y = if dist == 0 { boba::algos::spmv::spmv_pull(&csr, &x) } else { spmv_prefetch(&csr, &x, dist) };
        println!("dist={dist:>3}: {:.0} ms", t.elapsed().as_secs_f64() * 1e3);
        assert_eq!(y, base);
    }
}
