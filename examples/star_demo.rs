//! Figure 1 demo: the double-star probability experiment.
//!
//! The paper's Figure 1 argues that uniformly sampling cells of the
//! flattened edge list (the preferential-attachment picture behind BOBA)
//! brings the two adjacent star centers `a`, `b` together early: the
//! probability both land in the first k positions is p2≈24%, p3≈50%,
//! p4≈70% for the 10-leaf instance. This example Monte-Carlo-verifies
//! those numbers against the sampling process, then shows deterministic
//! BOBA placing both centers in positions 1–2.
//!
//! Run: `cargo run --release --example star_demo`

use boba::graph::gen;
use boba::reorder::{boba::Boba, Reorderer};
use boba::util::prng::Xoshiro256;

fn main() {
    // Figure 1's instance: centers a=0, b=1 joined by an edge, five
    // leaves each — 11 edges, 22 flattened cells, degrees 6/6/1…
    let g = gen::double_star(5);
    let m = g.m();
    let flat: Vec<u32> = g.src.iter().chain(g.dst.iter()).copied().collect();
    assert_eq!(flat.len(), 2 * m);

    // Monte-Carlo the sampling process of Figure 1: repeatedly draw a
    // uniform remaining cell, emit its vertex, delete all its cells.
    let trials = 200_000;
    let mut rng = Xoshiro256::new(1);
    let mut both_within = [0usize; 8]; // both centers in first k, k=0..7
    for _ in 0..trials {
        let mut cells: Vec<u32> = flat.clone();
        let mut pos_a = usize::MAX;
        let mut pos_b = usize::MAX;
        let mut emitted = 0;
        while pos_a == usize::MAX || pos_b == usize::MAX {
            let at = rng.below_usize(cells.len());
            let v = cells[at];
            if v == 0 && pos_a == usize::MAX {
                pos_a = emitted;
            }
            if v == 1 && pos_b == usize::MAX {
                pos_b = emitted;
            }
            cells.retain(|&c| c != v);
            emitted += 1;
        }
        let last = pos_a.max(pos_b);
        for (k, slot) in both_within.iter_mut().enumerate() {
            if last < k {
                *slot += 1;
            }
        }
    }
    println!("P(both centers within first k emissions), {trials} trials:");
    for k in 2..=6 {
        println!("  p_{k} = {:.1}%", 100.0 * both_within[k] as f64 / trials as f64);
    }
    println!("(paper Figure 1: p_2 ≈ 24%, p_3 ≈ 50%, p_4 ≈ 70%)");

    // Deterministic BOBA on the same edge list.
    let p = Boba::sequential().reorder(&g);
    let order = p.order();
    println!(
        "\nBOBA order (first 4): {:?}  — centers 0 and 1 first, as Figure 1 predicts",
        &order[..4]
    );
    assert_eq!(&order[..2], &[0, 1]);
}
