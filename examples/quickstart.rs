//! Quickstart: the BOBA pipeline in ~30 lines.
//!
//! Generates a scale-free graph with randomized labels (the paper's input
//! model), reorders it with parallel BOBA (Algorithm 3), converts to CSR,
//! and runs SpMV — reporting how each stage's time changes vs. the
//! unreordered baseline.
//!
//! Run: `cargo run --release --example quickstart`

use boba::algos::spmv;
use boba::convert;
use boba::graph::gen::{self, GenParams};
use boba::metrics;
use boba::reorder::{boba::Boba, Reorderer};
use boba::util::timer::Stopwatch;

fn main() {
    // 1. A randomly-labeled COO edge list: what a real pipeline holds
    //    right after parsing an .mtx/.el file.
    let graph = gen::rmat(&GenParams::rmat(17, 16), 42).randomized(7);
    println!("graph: n={} m={}", graph.n(), graph.m());

    // 2. Baseline: convert + SpMV on the randomized labels.
    let sw = Stopwatch::start();
    let csr_rand = convert::coo_to_csr(&graph);
    let conv_rand = sw.ms();
    let x = vec![1.0f32; graph.n()];
    let sw = Stopwatch::start();
    let y_rand = spmv::spmv_pull(&csr_rand, &x);
    let spmv_rand = sw.ms();

    // 3. BOBA: reorder (the lightweight step), then the same pipeline.
    let sw = Stopwatch::start();
    let perm = Boba::parallel().reorder(&graph);
    let reorder_ms = sw.ms();
    let reordered = graph.relabeled(perm.new_of_old());
    let sw = Stopwatch::start();
    let csr_boba = convert::coo_to_csr(&reordered);
    let conv_boba = sw.ms();
    let sw = Stopwatch::start();
    let y_boba = spmv::spmv_pull(&csr_boba, &x);
    let spmv_boba = sw.ms();

    // 4. Correctness: SpMV results agree up to the label permutation.
    let total: f64 = y_rand.iter().map(|&v| v as f64).sum();
    let total_b: f64 = y_boba.iter().map(|&v| v as f64).sum();
    assert!((total - total_b).abs() < 1e-6 * total.abs().max(1.0));

    println!(
        "NBR locality: random {:.3} -> BOBA {:.3} (lower = better)",
        metrics::nbr(&csr_rand),
        metrics::nbr(&csr_boba)
    );
    println!("reorder:              {reorder_ms:>9.2} ms   (BOBA only)");
    println!("COO→CSR:   rand {conv_rand:>9.2} ms | BOBA {conv_boba:>9.2} ms");
    println!("SpMV:      rand {spmv_rand:>9.2} ms | BOBA {spmv_boba:>9.2} ms");
    let e2e_rand = conv_rand + spmv_rand;
    let e2e_boba = reorder_ms + conv_boba + spmv_boba;
    println!(
        "end-to-end {e2e_rand:>9.2} ms | {e2e_boba:>9.2} ms  =>  {:.2}x",
        e2e_rand / e2e_boba
    );
}
