//! Bench target regenerating the paper's **Table 1** (NBR spatial-
//! locality metric over CSR for every dataset × reordering scheme).
//!
//! Run: `cargo bench --bench table1_nbr`
//! Env: BOBA_SCALE=quick|full, BOBA_HEAVY=0 to skip Gorder/RCM,
//!      BOBA_SEED to change the seed.

use boba::coordinator::experiments;

fn main() {
    let seed = std::env::var("BOBA_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t = experiments::table1(seed);
    println!("{}", t.render());
    println!(
        "paper shape check: Gorder best, BOBA ≈ RCM and ≪ random on uniform graphs,\n\
         Hub/Degree ≈ random on road-like datasets (cf. paper Table 1)."
    );
}
