//! Micro-benchmarks of the COO→CSR conversion stage — the pipeline cost
//! the paper's Problem 3 centres on — under each labeling, plus the
//! sequential/parallel converter ablation and the PJRT ELL pack/pass
//! counts.
//!
//! Run: `cargo bench --bench micro_convert`

use boba::bench::{Bench, Report};
use boba::convert;
use boba::graph::gen::{self, GenParams};
use boba::reorder::{boba::Boba, Reorderer};

fn main() {
    let mut report = Report::new("micro: COO→CSR conversion");
    let b = Bench::default();

    let g = gen::rmat(&GenParams::rmat(18, 16), 42).randomized(7);
    let m = g.m() as u64;
    let perm = Boba::parallel().reorder(&g);
    let boba_g = g.relabeled(perm.new_of_old());

    report.push(b.run_with_items("rmat18/random/seq", m, || convert::coo_to_csr(&g)));
    report.push(b.run_with_items("rmat18/BOBA/seq", m, || convert::coo_to_csr(&boba_g)));
    // Deterministic (private-histogram) vs atomic-scatter parallel
    // conversion — the det-vs-atomic ablation docs/EXPERIMENTS.md
    // §Conversion records.
    report.push(b.run_with_items("rmat18/random/par-det", m, || convert::coo_to_csr_parallel(&g)));
    report.push(b.run_with_items("rmat18/BOBA/par-det", m, || {
        convert::coo_to_csr_parallel(&boba_g)
    }));
    report.push(b.run_with_items("rmat18/random/par-atomic", m, || {
        convert::coo_to_csr_parallel_atomic(&g)
    }));
    report.push(b.run_with_items("rmat18/BOBA/par-atomic", m, || {
        convert::coo_to_csr_parallel_atomic(&boba_g)
    }));
    // Fused relabel+convert, sequential vs parallel.
    report.push(b.run_with_items("rmat18/BOBA/fused-seq", m, || {
        convert::coo_to_csr_relabeled(&g, perm.new_of_old())
    }));
    report.push(b.run_with_items("rmat18/BOBA/fused-par", m, || {
        convert::coo_to_csr_relabeled_parallel(&g, perm.new_of_old())
    }));

    // The sort stage TC charges (paper: ~10x the conversion cost).
    report.push(b.run_with_items("rmat18/random/sort", m, || convert::sort_coo_by_src(&g)));
    report.push(b.run_with_items("rmat18/BOBA/sort", m, || convert::sort_coo_by_src(&boba_g)));

    report.print();

    // ELL pack pass counts (runtime launch cost proxy; no PJRT needed).
    let meta = boba::runtime::Meta { n_tile: 8192, k: 16 };
    let plan_r = boba::runtime::ell::EllPlan::pack(&convert::coo_to_csr(&g), meta).unwrap();
    let plan_b = boba::runtime::ell::EllPlan::pack(&convert::coo_to_csr(&boba_g), meta).unwrap();
    println!(
        "ELL tile passes (8192x16): random={} BOBA={} ({}% fewer launches)",
        plan_r.passes(),
        plan_b.passes(),
        (100.0 * (1.0 - plan_b.passes() as f64 / plan_r.passes() as f64)) as i32
    );
}
