//! Bench target regenerating the paper's **Figure 6** (application
//! runtime normalized to Random, plus reorder time, on the uniform/road
//! suite — where degree-based schemes fail and BOBA ≈ heavyweight).
//!
//! Run: `cargo bench --bench fig6_uniform`

use boba::coordinator::experiments;

fn main() {
    let seed = std::env::var("BOBA_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t = experiments::fig6(seed);
    println!("{}", t.render());
    println!(
        "paper shape check: Degree/Hub ≈ random (or worse) on road-like graphs;\n\
         BOBA tracks the heavyweight band at a fraction of the reorder cost."
    );
}
