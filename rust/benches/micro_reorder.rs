//! Micro-benchmarks of the reordering algorithms themselves (the §5.4
//! "Reordering time" comparison, plus BOBA-variant ablations: sequential
//! vs racy-parallel vs atomic-parallel, and thread scaling).
//!
//! Run: `cargo bench --bench micro_reorder`

use boba::bench::{Bench, Report};
use boba::coordinator::datasets;
use boba::graph::gen::{self, GenParams};
use boba::parallel::ThreadGuard;
use boba::reorder::{
    boba::Boba, degree::DegreeSort, gorder::Gorder, hub::HubSort, rcm::Rcm, Reorderer,
};

fn main() {
    let seed = 42;
    let mut report = Report::new("micro: reordering algorithms");
    let b = Bench::default();

    // §5.4-style lineup on one scale-free and one uniform dataset.
    for name in ["pa_c8", "delaunay_s"] {
        let g = datasets::by_name(name).unwrap().build(seed).randomized(seed + 1);
        let m = g.m() as u64;
        let light: Vec<Box<dyn Reorderer>> = vec![
            Box::new(Boba::sequential()),
            Box::new(Boba::parallel()),
            Box::new(Boba::parallel_atomic()),
            Box::new(HubSort::new()),
            Box::new(DegreeSort::new()),
        ];
        for s in light {
            report.push(b.run_with_items(&format!("{name}/{}", s.name()), m, || s.reorder(&g)));
        }
        if boba::coordinator::experiments::include_heavy() {
            let heavy: Vec<Box<dyn Reorderer>> =
                vec![Box::new(Rcm::new()), Box::new(Gorder::new(5))];
            let once = Bench::once();
            for s in heavy {
                report.push(once.run_with_items(&format!("{name}/{}", s.name()), m, || {
                    s.reorder(&g)
                }));
            }
        }
    }

    // Thread scaling of parallel BOBA (the paper's "highly parallelizable"
    // claim, measured).
    let g = gen::rmat(&GenParams::rmat(18, 16), seed).randomized(1);
    let m = g.m() as u64;
    for t in [1usize, 2, 4, 8, 16] {
        let _guard = ThreadGuard::pin(t);
        let s = Boba::parallel();
        report.push(b.run_with_items(&format!("rmat18/BOBA/threads={t}"), m, || s.reorder(&g)));
    }

    report.print();
}
