//! Micro-benchmark of batched query execution: k independent
//! `spmv_pull` calls vs one multi-RHS `spmm_pull` call, on a
//! BOBA-ordered and a randomized-label CSR.
//!
//! The k-sweep isolates the two effects the batched serve path stacks:
//! the spmm kernel streams `row_ptr`/`col_idx` once for k right-hand
//! sides (per-query edge-stream cost falls as ~1/k — visible on both
//! orderings), and BOBA's clustered labels keep the k gathers
//! cache-resident (the boba rows beat the rand rows at every k).
//! Expected shape: `spmm k` total time grows far slower than k× the
//! `spmv x1` time, so ms/query decreases with k until the k register
//! accumulators and the x-block working set outgrow the cache.
//!
//! Run: `cargo bench --bench micro_batch` (`-- --smoke` for the 1-shot
//! CI gate). docs/EXPERIMENTS.md §Batching records the trajectory.

use boba::algos::{spmm, spmv};
use boba::bench::{black_box, Bench, Report};
use boba::convert;
use boba::graph::gen::{self, GenParams};
use boba::reorder::{boba::Boba, Reorderer};
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (bench, scale, edge_factor) = if smoke {
        (Bench { warmup: 0, iters: 1, max_total: Duration::from_secs(60) }, 13u32, 8u32)
    } else {
        (Bench::quick(), 17, 16)
    };
    // The paper's input model: randomized labels are the baseline BOBA
    // recovers locality from.
    let g = gen::rmat(&GenParams::rmat(scale, edge_factor), 42).randomized(43);
    let rand_csr = convert::coo_to_csr_parallel(&g);
    let boba_csr = {
        let (_perm, h) = Boba::parallel().reorder_relabel(&g);
        convert::coo_to_csr_parallel(&h)
    };
    let n = rand_csr.n();
    let m = rand_csr.m() as u64;
    println!(
        "micro_batch: rmat{scale} n={n} m={m} (k-sweep, spmv x{{k}} vs spmm k={{k}})\n"
    );

    let mut report = Report::new("micro: batched SpMV (one spmm pass vs k spmv passes)");
    for (order, csr) in [("rand", &rand_csr), ("boba", &boba_csr)] {
        for k in [1usize, 2, 4, 8, 16] {
            let x: Vec<f32> = (0..k * n)
                .map(|i| ((i as u32).wrapping_mul(2654435761) % 1000) as f32 * 0.001)
                .collect();
            // Equivalence gate first: the bench is only meaningful if
            // the two sides compute the same bits.
            {
                let mut want: Vec<f32> = Vec::with_capacity(k * n);
                for j in 0..k {
                    want.extend(spmv::spmv_pull(csr, &x[j * n..(j + 1) * n]));
                }
                assert_eq!(
                    spmm::spmm_pull(csr, &x, k),
                    want,
                    "{order}/k={k}: spmm must be bit-identical to k spmv calls"
                );
            }
            report.push(bench.run_with_items(&format!("{order}/spmv x{k}"), m * k as u64, || {
                for j in 0..k {
                    black_box(spmv::spmv_pull(csr, &x[j * n..(j + 1) * n]));
                }
            }));
            report.push(bench.run_with_items(&format!("{order}/spmm k={k}"), m * k as u64, || {
                black_box(spmm::spmm_pull(csr, &x, k))
            }));
        }
    }
    report.print();
    println!(
        "\nper-query edge-stream amortization: compare (spmm k)/k against spmv x1 —\n\
         the index streams are read once per spmm pass instead of once per query;\n\
         edges/s (the items column) rising with k is the same signal."
    );
}
