//! Micro-benchmark of the ingest subsystem: the old sequential
//! `BufReader::lines()` + `str::parse` reader (replicated below
//! verbatim as the baseline), the parallel byte-level text parser
//! (`graph::io`), and the `.bcoo` binary load — on a ≥1M-edge graph in
//! the full run, so the acceptance ordering
//! `.bcoo > parallel text > sequential text` (load throughput) is
//! measured where it matters. docs/EXPERIMENTS.md §Ingest records the
//! trajectory, including the text→`.bcoo` ratio.
//!
//! Run: `cargo bench --bench micro_ingest` (`-- --smoke` for the
//! 1-shot CI gate on a smaller graph).

use boba::bench::{black_box, Bench, Report};
use boba::graph::io::{self, bcoo};
use boba::graph::{gen, Coo};
use std::io::BufRead;
use std::path::Path;
use std::time::Duration;

/// The pre-parallel Matrix Market reader, kept bit-for-bit as the
/// baseline: one `String` + UTF-8 validation + `str::parse` per line.
fn seq_read_matrix_market(path: &Path) -> anyhow::Result<Coo> {
    let f = std::fs::File::open(path)?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty file"))??;
    let h: Vec<&str> = header.split_whitespace().collect();
    anyhow::ensure!(h.len() >= 5 && h[0].starts_with("%%MatrixMarket"), "bad header");
    let pattern = h[3] == "pattern";
    let symmetric = h[4] == "symmetric";
    let mut dims: Option<(usize, usize, usize)> = None;
    let mut src = Vec::new();
    let mut dst = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        if dims.is_none() {
            let r: usize = it.next().unwrap().parse()?;
            let c: usize = it.next().unwrap().parse()?;
            let nnz: usize = it.next().unwrap().parse()?;
            dims = Some((r, c, nnz));
            src.reserve(nnz);
            dst.reserve(nnz);
            continue;
        }
        let i: u64 = it.next().ok_or_else(|| anyhow::anyhow!("short line"))?.parse()?;
        let j: u64 = it.next().ok_or_else(|| anyhow::anyhow!("short line"))?.parse()?;
        src.push((i - 1) as u32);
        dst.push((j - 1) as u32);
        if !pattern {
            let v: f32 = it.next().map(|s| s.parse()).transpose()?.unwrap_or(1.0);
            vals.push(v);
        }
        if symmetric && i != j {
            src.push((j - 1) as u32);
            dst.push((i - 1) as u32);
            if !pattern {
                vals.push(*vals.last().unwrap());
            }
        }
    }
    let (r, c, _) = dims.ok_or_else(|| anyhow::anyhow!("missing size line"))?;
    let mut coo = Coo::new(r.max(c), src, dst);
    if !pattern {
        coo.vals = Some(vals);
    }
    Ok(coo)
}

fn main() {
    // Note: the raw read_* functions never consult the sidecar cache
    // (only io::load_graph_file does), so every iteration below is a
    // real parse — no cache-busting needed.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (bench, scale) = if smoke {
        (Bench { warmup: 0, iters: 1, max_total: Duration::from_secs(60) }, 13)
    } else {
        (Bench { warmup: 1, iters: 5, max_total: Duration::from_secs(300) }, 17)
    };
    // rmat(17, 8) is 8 · 2^17 = 1,048,576 edges — the ≥1M-edge bar the
    // acceptance ordering is measured on; --smoke drops to 64k edges.
    let g = gen::rmat(&gen::GenParams::rmat(scale, 8), 42).randomized(43);
    let edges = g.m() as u64;

    let dir = std::env::temp_dir();
    let mtx = dir.join(format!("boba_micro_ingest_{}.mtx", std::process::id()));
    let el = dir.join(format!("boba_micro_ingest_{}.el", std::process::id()));
    let bin = dir.join(format!("boba_micro_ingest_{}.bcoo", std::process::id()));
    io::write_matrix_market(&g, &mtx).unwrap();
    io::write_edge_list(&g, &el).unwrap();
    bcoo::write_bcoo(&g, &bin).unwrap();

    let mut report = Report::new("micro: graph ingest — seq text vs parallel text vs .bcoo");
    let m_seq = bench.run_with_items("mtx/seq-text", edges, || {
        black_box(seq_read_matrix_market(&mtx).unwrap())
    });
    let m_par = bench.run_with_items("mtx/par-text", edges, || {
        black_box(io::read_matrix_market(&mtx).unwrap())
    });
    let m_el = bench.run_with_items("el/par-text", edges, || {
        black_box(io::read_edge_list(&el, true).unwrap())
    });
    let m_bin = bench.run_with_items("bcoo", edges, || {
        black_box(bcoo::read_bcoo(&bin).unwrap())
    });

    // Sanity: every path loads the same graph.
    assert_eq!(seq_read_matrix_market(&mtx).unwrap(), g);
    assert_eq!(io::read_matrix_market(&mtx).unwrap(), g);
    assert_eq!(bcoo::read_bcoo(&bin).unwrap(), g);

    let (seq_ms, par_ms, bin_ms) =
        (m_seq.median_ms(), m_par.median_ms(), m_bin.median_ms());
    report.push(m_seq);
    report.push(m_par);
    report.push(m_el);
    report.push(m_bin);
    report.print();
    println!(
        "sizes: mtx {} B, bcoo {} B; speedups: par-text {:.2}x over seq-text, \
         bcoo {:.2}x over par-text, {:.2}x over seq-text (text→bcoo ratio)",
        std::fs::metadata(&mtx).map(|m| m.len()).unwrap_or(0),
        std::fs::metadata(&bin).map(|m| m.len()).unwrap_or(0),
        seq_ms / par_ms.max(1e-9),
        par_ms / bin_ms.max(1e-9),
        seq_ms / bin_ms.max(1e-9),
    );

    std::fs::remove_file(&mtx).ok();
    std::fs::remove_file(&el).ok();
    std::fs::remove_file(&bin).ok();
}
