//! Micro-benchmark of stage-span tracing overhead: `obs::span` with
//! tracing enabled (stage-histogram path), inside an open request trace
//! (histogram + span-tree path), and with the kill switch thrown. The
//! serve path wraps every kernel call in a span, so the per-span cost
//! must stay far below kernel time — the CI smoke gate asserts < 5 µs
//! per span with tracing on.
//!
//! Also prices the resilience fast paths: a disarmed fault point
//! (`chaos::should` with no `BOBA_FAULTS` spec) and an unscoped
//! deadline checkpoint (`deadline::expired` with no deadline
//! installed). Both guard hot loops — kernel iterations, registry
//! stages — so the smoke gate holds them under 1 µs each.
//!
//! Run: `cargo bench --bench micro_obs` (`-- --smoke` for the 1-shot CI
//! gate).

use boba::bench::{black_box, Bench, Measurement, Report};
use boba::obs;
use std::time::Duration;

const SPANS: u64 = 100_000;
const PER_TRACE: u64 = 256;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let bench = if smoke {
        Bench { warmup: 1, iters: 3, max_total: Duration::from_secs(30) }
    } else {
        Bench::quick()
    };
    let mut report = Report::new("micro: stage-span tracing overhead");
    let per_span_us = |m: &Measurement| m.median_ms() * 1e3 / SPANS as f64;

    // Tracing on, no open trace: the steady-state query path for
    // requests that only feed the stage histograms.
    obs::set_enabled(true);
    let on = bench.run_with_items("span/stage-histogram", SPANS, || {
        let mut acc = 0u64;
        for i in 0..SPANS {
            acc = acc.wrapping_add(obs::span("bench.obs", || black_box(i)));
        }
        acc
    });
    let on_us = per_span_us(&on);

    // Inside an open trace every span also lands in the request tree
    // (the traced-request path; PER_TRACE spans per begin/finish pair).
    let in_trace = bench.run_with_items("span/in-trace", SPANS, || {
        let mut acc = 0u64;
        for _ in 0..SPANS / PER_TRACE {
            let g = obs::begin();
            for i in 0..PER_TRACE {
                acc = acc.wrapping_add(obs::span("bench.obs", || black_box(i)));
            }
            black_box(g.finish("spmv", 200));
        }
        acc
    });
    let in_trace_us = per_span_us(&in_trace);

    // Kill switch thrown: the span must degrade to one relaxed atomic
    // load around the closure.
    obs::set_enabled(false);
    let off = bench.run_with_items("span/disabled", SPANS, || {
        let mut acc = 0u64;
        for i in 0..SPANS {
            acc = acc.wrapping_add(obs::span("bench.obs", || black_box(i)));
        }
        acc
    });
    let off_us = per_span_us(&off);
    obs::set_enabled(true);

    // Disarmed fault point: with no spec armed `chaos::should` is one
    // relaxed atomic load and an early return.
    obs::chaos::clear();
    let faults = bench.run_with_items("chaos/disarmed", SPANS, || {
        let mut acc = 0u64;
        for i in 0..SPANS {
            acc = acc
                .wrapping_add(obs::chaos::should("prepare-fail") as u64)
                .wrapping_add(black_box(i));
        }
        acc
    });
    let faults_us = per_span_us(&faults);

    // Unscoped deadline checkpoint: with no deadline installed,
    // `deadline::expired` is one thread-local read.
    let ddl = bench.run_with_items("deadline/unscoped", SPANS, || {
        let mut acc = 0u64;
        for i in 0..SPANS {
            acc = acc
                .wrapping_add(boba::util::deadline::expired() as u64)
                .wrapping_add(black_box(i));
        }
        acc
    });
    let ddl_us = per_span_us(&ddl);

    report.push(on);
    report.push(in_trace);
    report.push(off);
    report.push(faults);
    report.push(ddl);
    report.print();
    println!(
        "per-span: stage-histogram {on_us:.4} µs, in-trace {in_trace_us:.4} µs, \
         disabled {off_us:.4} µs; per-check: disarmed fault {faults_us:.4} µs, \
         unscoped deadline {ddl_us:.4} µs"
    );

    if smoke {
        assert!(
            on_us < 5.0,
            "span overhead with tracing on must stay under 5 µs, measured {on_us:.4} µs"
        );
        assert!(
            in_trace_us < 5.0,
            "in-trace span overhead must stay under 5 µs, measured {in_trace_us:.4} µs"
        );
        assert!(
            faults_us < 1.0,
            "disarmed fault-point check must stay under 1 µs, measured {faults_us:.4} µs"
        );
        assert!(
            ddl_us < 1.0,
            "unscoped deadline check must stay under 1 µs, measured {ddl_us:.4} µs"
        );
        println!(
            "smoke ok: span overhead within the 5 µs budget, \
             resilience checks within the 1 µs budget"
        );
    }
}
