//! Micro-benchmark of parallel-dispatch overhead: the persistent worker
//! pool (`parallel::pool`) vs the old spawn-per-call baseline
//! (`std::thread::scope`, replicated below verbatim). Short hot regions
//! — BOBA's record scan, conversion passes, per-request SpMV rows — are
//! dominated by dispatch cost, which is exactly what the pool amortizes;
//! docs/EXPERIMENTS.md §Pool records the trajectory.
//!
//! Run: `cargo bench --bench micro_pool` (`-- --smoke` for the 1-shot CI
//! gate).

use boba::bench::{black_box, Bench, Report};
use boba::parallel::{self, pool};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// The pre-pool dispatcher, kept bit-for-bit as the baseline: fresh
/// scoped OS threads spawned and joined on every call.
fn spawn_for_chunks<F>(len: usize, chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if len == 0 {
        return;
    }
    let t = parallel::threads().min(len.div_ceil(chunk)).max(1);
    if t == 1 {
        body(0, len);
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..t {
            s.spawn(|| loop {
                let lo = cursor.fetch_add(chunk, Ordering::Relaxed);
                if lo >= len {
                    break;
                }
                let hi = (lo + chunk).min(len);
                body(lo, hi);
            });
        }
    });
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (bench, dispatches) = if smoke {
        (Bench { warmup: 0, iters: 1, max_total: Duration::from_secs(30) }, 10u64)
    } else {
        (Bench::quick(), 200u64)
    };
    let mut report = Report::new("micro: pool dispatch vs spawn-per-call");

    // Tiny bodies at three region sizes: the smaller the region, the
    // larger the dispatch share — 4k items is BOBA-scan-per-batch
    // territory, 1M items approximates a full conversion pass.
    for (label, len) in [("4k", 4_096usize), ("64k", 65_536), ("1M", 1 << 20)] {
        let chunk = (len / 64).max(256);
        report.push(bench.run_with_items(&format!("{label}/pool"), dispatches, || {
            for _ in 0..dispatches {
                parallel::par_for_chunks(len, chunk, |lo, hi| {
                    black_box(hi - lo);
                });
            }
        }));
        report.push(bench.run_with_items(&format!("{label}/spawn"), dispatches, || {
            for _ in 0..dispatches {
                spawn_for_chunks(len, chunk, |lo, hi| {
                    black_box(hi - lo);
                });
            }
        }));
    }

    // par_jobs scheduling: one straggler among short jobs. The pool's
    // work-conserving claim loop starts every fast job immediately; the
    // old wave scheduler serialized a full wave behind the straggler.
    let jobs_round: u64 = if smoke { 1 } else { 5 };
    report.push(bench.run_with_items("jobs/straggler", jobs_round, || {
        for _ in 0..jobs_round {
            let jobs: Vec<Box<dyn FnOnce() -> u64 + Send>> = (0..16u64)
                .map(|j| {
                    Box::new(move || {
                        if j == 0 {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        j
                    }) as _
                })
                .collect();
            black_box(parallel::par_jobs(jobs));
        }
    }));

    report.print();
    let (workers, generations) = pool::stats();
    println!("pool: {workers} persistent workers over {generations} dispatch generations");
}
