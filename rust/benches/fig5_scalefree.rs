//! Bench target regenerating the paper's **Figure 5** (application
//! runtime normalized to Random, plus reorder time, on the scale-free
//! suite for all five schemes).
//!
//! Run: `cargo bench --bench fig5_scalefree`

use boba::coordinator::experiments;

fn main() {
    let seed = std::env::var("BOBA_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t = experiments::fig5(seed);
    println!("{}", t.render());
    println!(
        "paper shape check: BOBA's reorder time is ~10x below Hub/Degree and\n\
         orders below Gorder/RCM; its app runtimes sit between the degree-based\n\
         and heavyweight bands on scale-free graphs."
    );
}
