//! Bench target regenerating the paper's **Figure 7** (L1/L2 hit rates
//! and DRAM-served fraction per application × scheme, via the
//! trace-driven cache simulator standing in for nvprof).
//!
//! Run: `cargo bench --bench fig7_cache`

use boba::coordinator::experiments;

fn main() {
    let seed = std::env::var("BOBA_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t = experiments::fig7(seed);
    println!("{}", t.render());
    println!(
        "paper shape check: BOBA's hit rates track the heavyweight schemes\n\
         (not the lightweight ones) on every application; TC shows the highest\n\
         L1 rates (high data reuse), SSSP the least improvement."
    );
}
