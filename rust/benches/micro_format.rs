//! Micro-benchmark of the compressed kernel-format family
//! ([`boba::runtime::format`]): encode cost and parallel-SpMV time for
//! every registered format, on a BOBA-ordered and a randomized-label
//! CSR.
//!
//! What to look for: `bytes/edge` is the story — delta narrows to
//! ~2 B/edge when a labeling clusters each 64-row block's columns
//! (BOBA's whole point), and the SpMV rows show whether the thinner
//! index stream buys wall-clock on a memory-bound kernel. sell/ell pad
//! (bytes/edge above 4 on skewed rows) and buy regularity instead;
//! tiled trades a second pass over y for x reuse inside an L2-sized
//! column window. Every format is gated bit-identical to `spmv_pull`
//! before any timing runs — a divergence aborts the bench.
//!
//! Run: `cargo bench --bench micro_format` (`-- --smoke` for the
//! 1-shot CI gate). docs/EXPERIMENTS.md §Formats records the
//! trajectory; `boba repro` T5 commits the same measurement shape.

use boba::algos::spmv;
use boba::bench::{black_box, Bench, Report};
use boba::convert;
use boba::graph::gen::{self, GenParams};
use boba::reorder::{boba::Boba, Reorderer};
use boba::runtime::format::{self, SpmvFormat, FORMAT_NAMES};
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (bench, scale, edge_factor) = if smoke {
        (Bench { warmup: 0, iters: 1, max_total: Duration::from_secs(60) }, 13u32, 8u32)
    } else {
        (Bench::quick(), 17, 16)
    };
    // The paper's input model: randomized labels are the baseline BOBA
    // recovers locality from.
    let g = gen::rmat(&GenParams::rmat(scale, edge_factor), 42).randomized(43);
    let mut rand_csr = convert::coo_to_csr_parallel(&g);
    let mut boba_csr = {
        let (_perm, h) = Boba::parallel().reorder_relabel(&g);
        convert::coo_to_csr_parallel(&h)
    };
    // Sorted rows so the tiled format can take its u16 column tiles
    // (unsorted rows fall back to the raw irregular stream).
    rand_csr.sort_rows();
    boba_csr.sort_rows();
    let n = rand_csr.n();
    let m = rand_csr.m() as u64;
    println!("micro_format: rmat{scale} n={n} m={m} (encode + parallel SpMV per format)\n");

    let x: Vec<f32> = (0..n)
        .map(|i| ((i as u32).wrapping_mul(2654435761) % 1000) as f32 * 0.001)
        .collect();
    let mut report = Report::new("micro: kernel formats (encode cost, SpMV time)");
    for (order, csr) in [("rand", &rand_csr), ("boba", &boba_csr)] {
        let want = spmv::spmv_pull(csr, &x);
        for name in FORMAT_NAMES {
            let enc = format::encode(name, csr).expect("registered format encodes");
            // Equivalence gate first: the bench is only meaningful if
            // the format computes the same bits as the reference.
            for (kernel, got) in
                [("seq", enc.spmv(&x)), ("par", enc.spmv_parallel(&x))]
            {
                assert!(
                    want.len() == got.len()
                        && want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{order}/{name}: {kernel} SpMV must be bit-identical to spmv_pull"
                );
            }
            println!(
                "{order}/{name}: {:.2} bytes/edge ({} B index + {} B overhead)",
                enc.bytes_per_edge(),
                enc.index_bytes(),
                enc.overhead_bytes()
            );
            report.push(bench.run_with_items(&format!("{order}/{name}/encode"), m, || {
                black_box(format::encode(name, csr).expect("encoded a moment ago"))
            }));
            report.push(bench.run_with_items(&format!("{order}/{name}/spmv"), m, || {
                black_box(enc.spmv_parallel(&x))
            }));
        }
    }
    report.print();
    println!(
        "\nread bytes/edge against the SpMV rows: a thinner index stream only pays\n\
         off if the kernel is memory-bound on it — boba/delta vs rand/csr is the\n\
         headline contrast; repro T5 prices the same against a stream roofline."
    );
}
