//! Bench target regenerating the paper's **Figure 4** (end-to-end
//! stacked stage times — reorder + [sort] + convert + app — BOBA vs
//! Random for all four applications × all datasets).
//!
//! Run: `cargo bench --bench fig4_end_to_end`

use boba::coordinator::experiments;

fn main() {
    let seed = std::env::var("BOBA_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t = experiments::fig4(seed);
    println!("{}", t.render());
    println!(
        "paper shape check: conversion dominates most pipelines; BOBA speeds it up\n\
          1.3–5x; TC is sort-dominated and can lose end-to-end on kron-like graphs."
    );
}
