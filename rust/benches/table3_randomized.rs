//! Bench target regenerating the paper's **Table 3** (SpMV and COO→CSR
//! runtimes on pre-randomized datasets, Random vs BOBA — including the
//! designed negative result on the uniform delaunay mesh).
//!
//! Run: `cargo bench --bench table3_randomized`

use boba::coordinator::experiments;

fn main() {
    let seed = std::env::var("BOBA_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t = experiments::table3(seed);
    println!("{}", t.render());
    println!(
        "paper shape check: BOBA helps conversion+SpMV on the scale-free rows,\n\
         and is ~neutral on delaunay (its Table 3 shows the same null result)."
    );
}
