//! An offline, std-only drop-in subset of the `anyhow` error crate.
//!
//! The build environment cannot resolve crates.io (the same constraint
//! that led this repo to hand-roll its arg parser, bench harness, and
//! property-testing framework instead of clap/criterion/proptest), so
//! this path dependency provides the slice of anyhow's API the codebase
//! actually uses:
//!
//! * [`Error`] — a boxed-free error carrying a context chain;
//! * [`Result<T>`] with the defaulted error type;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros (format-string forms);
//! * the [`Context`] extension trait for `Result` and `Option`
//!   (`.context(..)` / `.with_context(|| ..)`);
//! * blanket `From<E: std::error::Error>` so `?` converts io/parse
//!   errors, preserving their `source()` chain;
//! * `{e}` prints the outermost message, `{e:#}` the full chain —
//!   matching anyhow's Display contract, which `main.rs` and the
//!   property harness rely on.
//!
//! Unsupported anyhow features (downcasting, backtraces, `Error::new`
//! with live source objects) are deliberately omitted; nothing in this
//! repo uses them. If the real crate ever becomes resolvable, deleting
//! this directory and pointing Cargo.toml at the registry is a drop-in
//! swap.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error as a chain of messages, outermost context first.
///
/// Unlike `std` errors this type intentionally does **not** implement
/// `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
/// impl coherent (the same design decision the real anyhow makes).
pub struct Error {
    /// `chain[0]` is the outermost message (latest context added);
    /// subsequent entries are the causes, in order.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost (most recently added) message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-separated (anyhow's format).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

/// `?` conversion from any std error, flattening its `source()` chain.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_number(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?;
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_number("42").unwrap(), 42);
        let err = parse_number("nope").unwrap_err();
        assert!(err.to_string().contains("invalid digit"));
    }

    #[test]
    fn context_chains_and_formats() {
        let base: Result<()> = Err(anyhow!("inner failure"));
        let err = base.context("outer context").unwrap_err();
        assert_eq!(format!("{err}"), "outer context");
        assert_eq!(format!("{err:#}"), "outer context: inner failure");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("inner failure"));
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let err = none.context("was empty").unwrap_err();
        assert_eq!(err.to_string(), "was empty");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: Result<u32, std::num::ParseIntError> = "3".parse();
        let v = ok
            .with_context(|| {
                called = true;
                "ctx"
            })
            .unwrap();
        assert_eq!(v, 3);
        assert!(!called, "with_context must not evaluate on Ok");
    }

    fn ensure_even(v: u32) -> Result<()> {
        ensure!(v % 2 == 0, "{v} is odd");
        ensure!(v < 100);
        Ok(())
    }

    #[test]
    fn ensure_and_bail() {
        assert!(ensure_even(4).is_ok());
        assert_eq!(ensure_even(3).unwrap_err().to_string(), "3 is odd");
        assert!(ensure_even(102)
            .unwrap_err()
            .to_string()
            .contains("condition failed"));
        fn bails() -> Result<()> {
            bail!("stop: {}", 9)
        }
        assert_eq!(bails().unwrap_err().to_string(), "stop: 9");
    }

    #[test]
    fn inline_capture_in_format() {
        let key = "scale";
        let err = anyhow!("missing required option --{key}");
        assert_eq!(err.to_string(), "missing required option --scale");
    }
}
