//! Property tests over the reordering algorithms — the core L3
//! invariants: every scheme yields a bijection, relabeling preserves
//! graph structure, BOBA's variants relate as specified, and the
//! locality metrics respond the way the paper claims.

use boba::graph::{gen, Coo};
use boba::metrics;
use boba::parallel::ThreadGuard;
use boba::reorder::{
    self, boba::Boba, degree::DegreeSort, gorder::Gorder, hub::HubSort, random::RandomOrder,
    rcm::Rcm, Reorderer,
};
use boba::testing::{check, Config, Gen};

/// Random COO with every vertex in ≥1 edge not guaranteed — exercising
/// the isolated-vertex path too.
fn arb_coo(g: &mut Gen) -> Coo {
    let n = g.usize(2..800);
    let m = g.usize(1..4000);
    let kind = g.usize(0..4);
    let seed = g.seed();
    match kind {
        0 => gen::uniform_random(n, m, seed),
        1 => gen::preferential_attachment(n.max(4), (m / n.max(1)).clamp(1, 8), seed),
        2 => {
            let w = (n as f64).sqrt() as usize + 2;
            gen::grid_road(w, w, seed)
        }
        _ => gen::rmat(&gen::GenParams::rmat(10, 4), seed),
    }
}

#[test]
fn all_schemes_produce_bijections() {
    check(Config::default().cases(40), "bijection", |g| {
        let coo = arb_coo(g);
        let schemes: Vec<Box<dyn Reorderer>> = vec![
            Box::new(Boba::sequential()),
            Box::new(Boba::parallel()),
            Box::new(Boba::parallel_atomic()),
            Box::new(DegreeSort::new()),
            Box::new(HubSort::new()),
            Box::new(RandomOrder::new(7)),
            Box::new(Rcm::new()),
            Box::new(Gorder::new(3)),
        ];
        for s in schemes {
            let p = s.reorder(&coo);
            p.validate(coo.n())
                .map_err(|e| anyhow::anyhow!("{}: {e}", s.name()))?;
        }
        Ok(())
    });
}

#[test]
fn relabeling_preserves_structure() {
    check(Config::default().cases(30), "structure invariants", |g| {
        let coo = arb_coo(g);
        let p = Boba::parallel().reorder(&coo);
        let h = coo.relabeled(p.new_of_old());
        anyhow::ensure!(h.m() == coo.m());
        anyhow::ensure!(h.n() == coo.n());
        // Degree multiset invariant.
        let mut d0 = coo.total_degrees();
        let mut d1 = h.total_degrees();
        d0.sort_unstable();
        d1.sort_unstable();
        anyhow::ensure!(d0 == d1, "degree multiset changed");
        // NScore upper bound (Lemma 8) holds for any labeling.
        anyhow::ensure!(metrics::nscore(&h) <= metrics::nscore_upper_bound(&h));
        Ok(())
    });
}

#[test]
fn boba_atomic_equals_sequential_always() {
    check(Config::default().cases(40), "atomic == sequential", |g| {
        let coo = arb_coo(g);
        let a = Boba::sequential().reorder(&coo);
        let b = Boba::parallel_atomic().reorder(&coo);
        anyhow::ensure!(a == b, "atomic-min parallel must equal Algorithm 2");
        Ok(())
    });
}

#[test]
fn boba_racy_single_thread_equals_sequential() {
    check(Config::default().cases(20), "racy@1thread == sequential", |g| {
        let coo = arb_coo(g);
        let _t = ThreadGuard::pin(1);
        let a = Boba::sequential().reorder(&coo);
        let b = Boba::parallel().reorder(&coo);
        anyhow::ensure!(a == b);
        Ok(())
    });
}

#[test]
fn boba_first_appearance_is_minimal() {
    // For the sequential algorithm: if u's first appearance in I++J
    // precedes v's, then new(u) < new(v) (among non-isolated vertices).
    check(Config::default().cases(30), "first-appearance order", |g| {
        let coo = arb_coo(g);
        let p = Boba::sequential().reorder(&coo);
        let map = p.new_of_old();
        let mut first = vec![usize::MAX; coo.n()];
        for (i, &v) in coo.src.iter().chain(coo.dst.iter()).enumerate() {
            if first[v as usize] == usize::MAX {
                first[v as usize] = i;
            }
        }
        let mut seen: Vec<(usize, u32)> = (0..coo.n())
            .filter(|&v| first[v] != usize::MAX)
            .map(|v| (first[v], map[v]))
            .collect();
        seen.sort_unstable();
        for w in seen.windows(2) {
            anyhow::ensure!(w[0].1 < w[1].1, "appearance order violated");
        }
        Ok(())
    });
}

#[test]
fn boba_improves_or_matches_nbr_on_structured_inputs() {
    // On generator-natural edge orders with randomized labels, BOBA's NBR
    // must not be (much) worse than random's — the paper's "safe to apply
    // indiscriminately" claim. Allow 5% slack for tiny graphs.
    check(Config::default().cases(15), "nbr safety", |g| {
        let coo = arb_coo(g);
        if coo.m() < 50 {
            return Ok(());
        }
        let rand = coo.randomized(g.seed());
        let p = Boba::parallel().reorder(&rand);
        let reord = rand.relabeled(p.new_of_old());
        let nbr_rand = metrics::nbr_coo(&rand);
        let nbr_boba = metrics::nbr_coo(&reord);
        anyhow::ensure!(
            nbr_boba <= nbr_rand * 1.05 + 0.05,
            "BOBA made NBR worse: {nbr_boba} vs {nbr_rand}"
        );
        Ok(())
    });
}

#[test]
fn hub_sort_places_max_degree_first() {
    check(Config::default().cases(30), "hub first", |g| {
        let coo = arb_coo(g);
        if coo.m() == 0 {
            return Ok(());
        }
        let deg = coo.total_degrees();
        let maxdeg = *deg.iter().max().unwrap();
        let avg = (2 * coo.m()) as f64 / coo.n() as f64;
        if (maxdeg as f64) <= avg {
            return Ok(()); // perfectly regular: no hubs
        }
        let p = HubSort::new().reorder(&coo);
        let order = p.order();
        anyhow::ensure!(
            deg[order[0] as usize] == maxdeg,
            "hub sort must place a max-degree vertex first"
        );
        Ok(())
    });
}

#[test]
fn rcm_never_increases_bandwidth_on_paths() {
    check(Config::default().cases(15), "rcm path bandwidth", |g| {
        let n = g.usize(4..400);
        let src: Vec<u32> = (0..n as u32 - 1).collect();
        let dst: Vec<u32> = (1..n as u32).collect();
        let path = Coo::new(n, src, dst).randomized(g.seed());
        let p = Rcm::new().reorder(&path);
        let h = path.relabeled(p.new_of_old());
        anyhow::ensure!(
            metrics::bandwidth(&h) == 1,
            "RCM must recover optimal bandwidth on paths, got {}",
            metrics::bandwidth(&h)
        );
        Ok(())
    });
}

#[test]
fn degenerate_inputs_yield_valid_permutations() {
    // Every scheme reachable through the shared CLI vocabulary
    // (`reorder::by_name`) must return a bijection on the degenerate
    // COOs real edge-list files produce: empty graphs, a single vertex,
    // self-loops, duplicate edges, and fully isolated vertex sets.
    let cases: Vec<(&str, Coo)> = vec![
        ("empty", Coo::new(0, vec![], vec![])),
        ("one-vertex", Coo::new(1, vec![], vec![])),
        ("self-loop", Coo::new(1, vec![0], vec![0])),
        ("loops-and-dups", Coo::new(3, vec![0, 0, 0, 2, 2], vec![0, 1, 1, 2, 1])),
        ("all-isolated", Coo::new(5, vec![], vec![])),
    ];
    let names =
        ["boba", "boba-seq", "boba-atomic", "degree", "hub", "rcm", "gorder", "random"];
    for (label, coo) in &cases {
        for name in names {
            let s = reorder::by_name(name, 3).unwrap();
            let p = s.reorder(coo);
            p.validate(coo.n())
                .unwrap_or_else(|e| panic!("{name} on {label}: invalid permutation: {e}"));
            // Applying the permutation must preserve the edge multiset
            // size (relabeling never drops or invents edges).
            let h = coo.relabeled(p.new_of_old());
            assert_eq!(h.m(), coo.m(), "{name} on {label}");
            h.validate().unwrap_or_else(|e| panic!("{name} on {label}: {e}"));
        }
    }
}

#[test]
fn degenerate_inputs_random_cases() {
    // Randomized variant: sprinkle self-loops and duplicates into small
    // COOs and require bijectivity from every scheme.
    check(Config::default().cases(25), "degenerate bijection", |g| {
        let n = g.usize(1..40);
        let m = g.usize(0..120);
        let src: Vec<u32> = g.vec(m, |g| g.usize(0..n) as u32);
        let mut dst: Vec<u32> = g.vec(m, |g| g.usize(0..n) as u32);
        // Force some self-loops and duplicate edges.
        for i in 0..m {
            if g.bool(0.2) {
                dst[i] = src[i]; // self-loop
            }
            if i > 0 && g.bool(0.2) {
                let j = g.usize(0..i);
                dst[i] = dst[j];
                // duplicate of an earlier edge
                let s = src[j];
                src[i] = s;
            }
        }
        let coo = Coo::new(n, src, dst);
        for name in ["boba", "boba-seq", "boba-atomic", "degree", "hub", "rcm", "gorder", "random"]
        {
            let p = reorder::by_name(name, g.seed()).unwrap().reorder(&coo);
            p.validate(coo.n())
                .map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn permutation_composition_roundtrip() {
    check(Config::default().cases(40), "perm algebra", |g| {
        let coo = arb_coo(g);
        let p = Boba::parallel().reorder(&coo);
        let h = coo.relabeled(p.new_of_old());
        let back = h.relabeled(p.inverse().new_of_old());
        anyhow::ensure!(back == coo, "inverse relabel must round-trip");
        Ok(())
    });
}
