//! Server smoke test (the service-layer acceptance path): bind an
//! ephemeral port, ingest a small R-MAT graph, run one SpMV and one
//! PageRank query over raw `std::net::TcpStream`, assert the served
//! digests match direct `algos::` calls on the same pipeline output,
//! then shut down cleanly.

use boba::algos::{pagerank, spmv};
use boba::convert;
use boba::coordinator::datasets;
use boba::server::http::HttpClient;
use boba::server::json::Json;
use boba::server::{self, ServerConfig};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

const SEED: u64 = 42;
const DATASET: &str = "rmat:10:8";

fn spawn_server() -> server::Server {
    server::spawn(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        capacity: 4,
        batch: 1 << 12,
        in_flight: 2,
        seed: SEED,
        read_timeout: Duration::from_secs(10),
        ..Default::default()
    })
    .expect("server must bind an ephemeral port")
}

/// One raw HTTP exchange over a bare TcpStream (no client helper):
/// `connection: close` delimits the response body.
fn raw_post(addr: &std::net::SocketAddr, path: &str, body: &str) -> (u16, Json) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nhost: smoke\r\nconnection: close\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    s.flush().unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8_lossy(&raw);
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("numeric status");
    let json_body = text
        .split("\r\n\r\n")
        .nth(1)
        .expect("header/body separator");
    (status, Json::parse(json_body).expect("JSON body"))
}

/// Like [`raw_post`] but returns the raw header block too (for
/// asserting response headers like `x-request-id`).
fn raw_exchange(
    addr: &std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, String, Json) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nhost: smoke\r\nconnection: close\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    s.flush().unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8_lossy(&raw);
    let (head, json_body) = text.split_once("\r\n\r\n").expect("header/body separator");
    let status: u16 =
        head.split_whitespace().nth(1).expect("status line").parse().expect("numeric status");
    (status, head.to_string(), Json::parse(json_body).expect("JSON body"))
}

#[test]
fn smoke_ingest_query_validate_shutdown() {
    let server = spawn_server();
    let addr = server.addr();

    // ── ingest + prepare (BOBA scheme) ────────────────────────────
    let (status, ingest) = raw_post(
        &addr,
        "/graphs",
        &format!("{{\"dataset\": \"{DATASET}\", \"scheme\": \"boba\"}}"),
    );
    assert_eq!(status, 201, "fresh prepare must 201: {}", ingest.render());
    let id = ingest.get("id").unwrap().as_str().unwrap().to_string();
    assert_eq!(id, format!("{DATASET}@boba"));

    // ── local reference: the same pipeline input, computed directly ──
    // The registry builds resolve(DATASET, seed).randomized(seed+1);
    // digests below are label-invariant, so the reference runs on the
    // un-reordered labels.
    let coo = datasets::resolve(DATASET, SEED).unwrap().randomized(SEED + 1);
    assert_eq!(ingest.get("n").unwrap().as_u64(), Some(coo.n() as u64));
    assert_eq!(ingest.get("m").unwrap().as_u64(), Some(coo.m() as u64));
    let csr = convert::coo_to_csr(&coo);
    let ones = vec![1.0f32; csr.n()];

    // ── SpMV over a raw TcpStream ─────────────────────────────────
    let spmv_ref: f64 = spmv::spmv_pull(&csr, &ones).iter().map(|&v| v as f64).sum();
    let (status, resp) = raw_post(&addr, &format!("/graphs/{id}/spmv"), "");
    assert_eq!(status, 200, "{}", resp.render());
    let served = resp.get("digest").unwrap().as_f64().unwrap();
    assert!(
        (served - spmv_ref).abs() <= 1e-6 * spmv_ref.abs().max(1.0),
        "served SpMV digest {served} != direct algos::spmv digest {spmv_ref}"
    );

    // ── PageRank over a raw TcpStream ─────────────────────────────
    let pr_ref: f64 = {
        let p = pagerank::PrParams { max_iters: 40, ..Default::default() };
        pagerank::pagerank(&csr, p).ranks.iter().map(|&v| v as f64).sum()
    };
    let (status, resp) = raw_post(&addr, &format!("/graphs/{id}/pagerank"), "{\"iters\": 40}");
    assert_eq!(status, 200, "{}", resp.render());
    let served = resp.get("digest").unwrap().as_f64().unwrap();
    assert!(
        (served - pr_ref).abs() < 1e-3,
        "served PageRank digest {served} != direct algos::pagerank digest {pr_ref} \
         (tolerance covers f32 summation-order drift across labelings)"
    );

    // ── health, stats, listing over the persistent client ─────────
    let mut client = HttpClient::connect(&addr.to_string()).unwrap();
    let (status, health) = client.request_json("GET", "/healthz", "").unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(health.get("graphs").unwrap().as_u64(), Some(1));

    let (status, stats) = client.request_json("GET", "/stats", "").unwrap();
    assert_eq!(status, 200);
    let eps = stats.get("endpoints").unwrap();
    assert_eq!(eps.get("spmv").unwrap().get("count").unwrap().as_u64(), Some(1));
    assert_eq!(eps.get("spmv").unwrap().get("errors").unwrap().as_u64(), Some(0));
    assert_eq!(eps.get("pagerank").unwrap().get("errors").unwrap().as_u64(), Some(0));

    let (status, listing) = client.request_json("GET", "/graphs", "").unwrap();
    assert_eq!(status, 200);
    match listing {
        Json::Arr(items) => {
            assert_eq!(items.len(), 1);
            assert_eq!(items[0].get("id").unwrap().as_str(), Some(id.as_str()));
            assert_eq!(items[0].get("queries").unwrap().as_u64(), Some(2));
        }
        other => panic!("expected listing array, got {other:?}"),
    }
    drop(client);

    // ── clean shutdown: workers join; the port stops answering ────
    server.shutdown();
    assert!(
        HttpClient::connect(&addr.to_string())
            .and_then(|mut c| c.request("GET", "/healthz", b""))
            .is_err(),
        "server must stop accepting after shutdown"
    );
}

#[test]
fn batch_endpoint_matches_direct_queries_over_http() {
    let server = spawn_server();
    let addr = server.addr();
    let (status, resp) = raw_post(
        &addr,
        "/graphs",
        &format!("{{\"dataset\": \"{DATASET}\", \"scheme\": \"boba\"}}"),
    );
    assert_eq!(status, 201);
    let id = resp.get("id").unwrap().as_str().unwrap().to_string();

    let (status, direct_spmv) = raw_post(&addr, &format!("/graphs/{id}/spmv"), "");
    assert_eq!(status, 200);
    let (status, direct_sssp) = raw_post(&addr, &format!("/graphs/{id}/sssp"), "");
    assert_eq!(status, 200);

    let body = format!(
        "{{\"id\": \"{id}\", \"queries\": [\
         {{\"query\": \"spmv\"}}, {{\"query\": \"spmv\", \"seed\": 9}}, \
         {{\"query\": \"sssp\"}}, {{\"query\": \"tc\"}}]}}"
    );
    let (status, batch) = raw_post(&addr, "/query/batch", &body);
    assert_eq!(status, 200, "{}", batch.render());
    assert_eq!(batch.get("count").unwrap().as_u64(), Some(4));
    let rows = match batch.get("results").unwrap() {
        Json::Arr(items) => items.clone(),
        other => panic!("results not an array: {other:?}"),
    };
    // Batched answers must equal the direct ones exactly — the batched
    // kernels are bit-identical, and both digests fold in vertex order.
    assert_eq!(
        rows[0].get("digest").unwrap().as_f64(),
        direct_spmv.get("digest").unwrap().as_f64(),
        "batched spmv == direct spmv"
    );
    assert_eq!(
        rows[2].get("digest").unwrap().as_f64(),
        direct_sssp.get("digest").unwrap().as_f64(),
        "batched sssp == direct sssp"
    );
    // The two spmv entries shared one kernel pass.
    assert_eq!(rows[0].get("batch_width").unwrap().as_u64(), Some(2));

    // /stats exposes the batch endpoint slot and the width histograms.
    let mut client = HttpClient::connect(&addr.to_string()).unwrap();
    let (_, stats) = client.request_json("GET", "/stats", "").unwrap();
    assert_eq!(
        stats.get("endpoints").unwrap().get("batch").unwrap().get("count").unwrap().as_u64(),
        Some(1)
    );
    let co = stats.get("coalescer").unwrap();
    assert!(co.get("spmv").unwrap().get("batches").unwrap().as_u64().unwrap() >= 1);
    drop(client);
    server.shutdown();
}

/// The observability acceptance path: a cold prepare's trace must
/// attribute (essentially) the whole request to its named stages, the
/// request id must come back as a response header, and `/metrics` must
/// expose the full family set in parseable exposition format.
#[test]
fn traces_account_for_the_cold_prepare_and_metrics_expose_families() {
    let server = spawn_server();
    let addr = server.addr();

    // Cold prepare of a non-trivial graph (2^14 vertices, 2^17 edges):
    // big enough that routing/JSON overhead is noise next to the
    // ingest/reorder/convert/transpose stages.
    let (status, head, ingest) = raw_exchange(
        &addr,
        "POST",
        "/graphs",
        "{\"dataset\": \"rmat:14:8\", \"scheme\": \"boba\"}",
    );
    assert_eq!(status, 201, "{}", ingest.render());
    assert!(head.contains("x-request-id: r-"), "response headers: {head}");
    let prep = ingest.get("prep").expect("cold prepare report");
    assert!(prep.get("transpose_ms").is_some(), "prep breakdown: {}", prep.render());

    // The trace ring has the request, newest first.
    let (status, _head, traces) = raw_exchange(&addr, "GET", "/debug/traces?n=8", "");
    assert_eq!(status, 200);
    let rows = match traces.get("traces").unwrap() {
        Json::Arr(items) => items.clone(),
        other => panic!("traces not an array: {other:?}"),
    };
    let t = rows
        .iter()
        .find(|t| t.get("endpoint").and_then(Json::as_str) == Some("ingest"))
        .expect("the ingest trace is in the ring");
    let total_us = t.get("total_us").unwrap().as_f64().unwrap();
    let spans_us = t.get("spans_us").unwrap().as_f64().unwrap();
    assert!(total_us > 0.0);
    assert!(spans_us <= total_us, "spans cannot exceed the request ({spans_us} > {total_us})");
    assert!(
        spans_us >= 0.9 * total_us,
        "prepare stages must account for ≥90% of the cold request \
         (spans {spans_us} µs of {total_us} µs)"
    );
    let spans = match t.get("spans").unwrap() {
        Json::Arr(items) => items.clone(),
        other => panic!("spans not an array: {other:?}"),
    };
    for stage in ["prepare.ingest", "prepare.reorder", "prepare.convert", "prepare.transpose"] {
        assert!(
            spans.iter().any(|s| s.get("name").and_then(Json::as_str) == Some(stage)),
            "missing stage {stage} in {spans:?}"
        );
    }

    // Queries land kernel spans too.
    let (status, _, _) = raw_exchange(&addr, "POST", "/graphs/rmat:14:8@boba/pagerank", "");
    assert_eq!(status, 200);
    let (_, _, traces) = raw_exchange(&addr, "GET", "/debug/traces?n=4", "");
    let rows = match traces.get("traces").unwrap() {
        Json::Arr(items) => items.clone(),
        other => panic!("traces not an array: {other:?}"),
    };
    let pr = rows
        .iter()
        .find(|t| t.get("endpoint").and_then(Json::as_str) == Some("pagerank"))
        .expect("the pagerank trace is in the ring");
    let names: Vec<&str> = match pr.get("spans").unwrap() {
        Json::Arr(items) => {
            items.iter().filter_map(|s| s.get("name").and_then(Json::as_str)).collect()
        }
        _ => Vec::new(),
    };
    assert!(names.contains(&"kernel.pagerank"), "pagerank spans: {names:?}");

    // /metrics: parseable, complete, and correctly typed (the loadgen
    // scrape parser is strict about HELP/TYPE and bucket shape).
    let mut client = HttpClient::connect(&addr.to_string()).unwrap();
    let (status, raw) = client.request("GET", "/metrics", b"").unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(raw).unwrap();
    let scrape = boba::obs::text::Scrape::parse(&text).expect("conformant exposition");
    assert!(scrape.families.len() >= 10, "only {} families", scrape.families.len());
    assert!(
        scrape.value("boba_registry_prepares_total", &[]).unwrap() >= 1.0,
        "the cold prepare must be counted"
    );
    let stages = scrape.histogram("boba_stage_duration_seconds", &[("stage", "prepare.reorder")]);
    assert!(stages.last().unwrap().1 >= 1.0, "reorder stage histogram populated");
    server.shutdown();
}

#[test]
fn boba_and_none_schemes_serve_identical_answers() {
    // The BOBA-vs-random serving comparison must differ only in speed,
    // never in results: prepare the same dataset both ways and compare
    // every query digest.
    let server = spawn_server();
    let addr = server.addr();
    let mut ids = Vec::new();
    for scheme in ["boba", "none"] {
        let (status, resp) = raw_post(
            &addr,
            "/graphs",
            &format!("{{\"dataset\": \"{DATASET}\", \"scheme\": \"{scheme}\"}}"),
        );
        assert_eq!(status, 201);
        ids.push(resp.get("id").unwrap().as_str().unwrap().to_string());
    }
    for (query, body, tol) in [
        ("spmv", "", 1e-6),
        ("pagerank", "{\"iters\": 30}", 1e-3),
        ("sssp", "", 1e-6),
        ("tc", "", 0.0),
    ] {
        let digests: Vec<f64> = ids
            .iter()
            .map(|id| {
                let (status, resp) = raw_post(&addr, &format!("/graphs/{id}/{query}"), body);
                assert_eq!(status, 200, "{query}: {}", resp.render());
                resp.get("digest").unwrap().as_f64().unwrap()
            })
            .collect();
        assert!(
            (digests[0] - digests[1]).abs() <= tol * digests[0].abs().max(1.0),
            "{query} digests diverge across schemes: {digests:?}"
        );
    }
    server.shutdown();
}
