//! Resilience integration tests: chaos-driven failing prepares with
//! concurrent single-flight waiters, admission-gate shutdown release,
//! end-to-end deadline 504s, and `Retry-After` parseability on
//! rejected requests.
//!
//! The chaos fault table is process-global state, so every test here
//! serializes on the file-local `LOCK`. The library's own unit tests
//! run in a separate binary (and arm only test-only points), so the
//! production points exercised here cannot race them.

use boba::server::admission::{Admission, AdmissionConfig};
use boba::server::http::HttpClient;
use boba::server::json::Json;
use boba::server::{self, ServerConfig};
use boba::util::deadline;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn spawn_server(tweak: impl FnOnce(&mut ServerConfig)) -> server::Server {
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        capacity: 4,
        seed: 42,
        read_timeout: Duration::from_secs(10),
        ..Default::default()
    };
    tweak(&mut cfg);
    server::spawn(cfg).expect("server must bind an ephemeral port")
}

fn client(srv: &server::Server) -> HttpClient {
    HttpClient::connect(&srv.addr().to_string()).expect("connect")
}

/// One raw HTTP exchange with caller-supplied extra headers (the
/// `HttpClient` helper deliberately has no header surface).
fn raw_post(
    addr: &std::net::SocketAddr,
    path: &str,
    body: &str,
    headers: &[(&str, &str)],
) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut req = format!("POST {path} HTTP/1.1\r\nhost: resilience\r\nconnection: close\r\n");
    for (name, value) in headers {
        req.push_str(&format!("{name}: {value}\r\n"));
    }
    req.push_str(&format!("content-length: {}\r\n\r\n{body}", body.len()));
    s.write_all(req.as_bytes()).unwrap();
    s.flush().unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8_lossy(&raw);
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("numeric status");
    let body = text.split("\r\n\r\n").nth(1).unwrap_or_default().to_string();
    (status, body)
}

/// The single-flight failure contract: when the leader's prepare hits
/// an armed `prepare-fail`, every joined waiter gets a clean error
/// naming the fault (nobody hangs, nobody panics), the pending slot is
/// fully torn down, and a retry after disarming succeeds.
#[test]
fn concurrent_waiters_on_a_failing_prepare_all_get_clean_errors_then_retry_succeeds() {
    let _g = lock();
    let srv = spawn_server(|_| {});
    let mut c = client(&srv);
    let (st, _) = c
        .request("POST", "/debug/faults", b"{\"spec\": \"prepare-fail:1\"}")
        .expect("arm fault table");
    assert_eq!(st, 200);

    // N concurrent ingests of the same artifact. The first leader's
    // prepare consumes the fault budget and fails; everyone parked on
    // that flight inherits the error. Stragglers that arrive after the
    // teardown become fresh leaders and succeed (budget spent) — both
    // outcomes are legal, hangs and opaque 5xxs are not.
    const N: usize = 6;
    let addr = srv.addr().to_string();
    let results: Vec<(u16, String)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|_| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = HttpClient::connect(&addr).expect("connect");
                    let (st, body) = c
                        .request("POST", "/graphs", b"{\"dataset\": \"rmat:10:8\"}")
                        .expect("exchange completes");
                    (st, String::from_utf8_lossy(&body).into_owned())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("no waiter panics")).collect()
    });

    let failed = results.iter().filter(|(st, _)| *st == 422).count();
    assert!(failed >= 1, "the first leader must hit the armed fault: {results:?}");
    for (st, body) in &results {
        match st {
            200 | 201 => {}
            422 => assert!(
                body.contains("injected fault"),
                "failure must name the injected fault: {body}"
            ),
            other => panic!("unexpected status {other}: {body}"),
        }
    }

    // Disarm explicitly and retry: the failed artifact must not be
    // poisoned — a clean prepare publishes it.
    let (st, _) = c.request("POST", "/debug/faults", b"{\"spec\": \"\"}").unwrap();
    assert_eq!(st, 200);
    let (st, body) = c.request("POST", "/graphs", b"{\"dataset\": \"rmat:10:8\"}").unwrap();
    assert!(
        st == 200 || st == 201,
        "retry after disarm must succeed: {st} {}",
        String::from_utf8_lossy(&body)
    );
    srv.shutdown();
}

/// Shutdown must release every waiter parked behind the in-flight
/// gate — both the patient kind (no deadline) and the kind parked
/// under a generous deadline — with the `shutdown` rejection, while a
/// waiter whose own deadline runs out first leaves with `deadline`.
#[test]
fn shutdown_releases_admission_parked_and_deadline_parked_waiters() {
    let _g = lock();
    let adm = Arc::new(Admission::new(AdmissionConfig {
        rate: 0.0,
        burst: 0.0,
        max_inflight: 1,
    }));
    let hold = adm.admit("t", false).expect("first admit fills the only slot");

    // Waiter with a short deadline: must self-release as `deadline`
    // without any help from shutdown.
    let a = adm.clone();
    let short = std::thread::spawn(move || {
        let _scope = deadline::scope(Some(Instant::now() + Duration::from_millis(300)));
        a.admit("t", false).map(|_| ()).map_err(|r| r.reason())
    });
    let reason = short.join().expect("short-deadline waiter returns");
    assert_eq!(reason, Err("deadline"));

    // Two parked waiters — one patient, one under a 60 s deadline —
    // that only shutdown can release while `hold` pins the slot.
    let b = adm.clone();
    let patient = std::thread::spawn(move || b.admit("t", false).map(|_| ()).map_err(|r| r.reason()));
    let c = adm.clone();
    let deadlined = std::thread::spawn(move || {
        let _scope = deadline::scope(Some(Instant::now() + Duration::from_secs(60)));
        c.admit("t", false).map(|_| ()).map_err(|r| r.reason())
    });
    // Let both reach the parked state (the gate polls at 250 ms, so a
    // generous settle beats any scheduling jitter).
    std::thread::sleep(Duration::from_millis(400));
    assert!(adm.pressured(), "gate must be saturated with parked waiters");

    let released = Instant::now();
    adm.shutdown();
    assert_eq!(patient.join().expect("patient waiter returns"), Err("shutdown"));
    assert_eq!(deadlined.join().expect("deadlined waiter returns"), Err("shutdown"));
    assert!(
        released.elapsed() < Duration::from_secs(5),
        "shutdown release must be prompt, took {:?}",
        released.elapsed()
    );
    drop(hold);
}

/// Deadline propagation end-to-end over HTTP: a request whose
/// `x-deadline-ms` budget is already spent gets a 504 from the
/// dequeue-time check, never a kernel run.
#[test]
fn spent_deadline_budget_yields_504_over_http() {
    let _g = lock();
    let srv = spawn_server(|_| {});
    let mut c = client(&srv);
    let (st, body) = c.request("POST", "/graphs", b"{\"dataset\": \"pa:800:4\"}").unwrap();
    assert_eq!(st, 201, "{}", String::from_utf8_lossy(&body));
    let ingest = Json::parse(&String::from_utf8_lossy(&body)).expect("JSON ingest reply");
    let id = ingest.get("id").unwrap().as_str().unwrap().to_string();

    let (status, body) = raw_post(
        &srv.addr(),
        &format!("/graphs/{id}/spmv"),
        "",
        &[("x-deadline-ms", "0")],
    );
    assert_eq!(status, 504, "{body}");
    let err = Json::parse(&body).expect("JSON error body");
    assert_eq!(err.get("reason").and_then(Json::as_str), Some("deadline"));

    // The same query without a deadline header still serves normally.
    let (st, _) = c.request("POST", &format!("/graphs/{id}/spmv"), b"").unwrap();
    assert_eq!(st, 200);
    srv.shutdown();
}

/// Rate-limited requests must carry a `Retry-After` a client can
/// actually parse (the loadgen backoff floors on it) plus the JSON
/// reason body.
#[test]
fn rate_limited_requests_carry_a_parseable_retry_after() {
    let _g = lock();
    let srv = spawn_server(|c| {
        c.rate = 0.1; // one token every 10 s...
        c.burst = 1.0; // ...and exactly one to start with
    });
    let mut c = client(&srv);
    let (st, body) = c.request("POST", "/graphs", b"{\"dataset\": \"pa:600:4\"}").unwrap();
    assert_eq!(st, 201, "{}", String::from_utf8_lossy(&body));

    let (st, body) = c.request("POST", "/graphs", b"{\"dataset\": \"pa:600:4\"}").unwrap();
    assert_eq!(st, 429, "{}", String::from_utf8_lossy(&body));
    let ra = c.retry_after().expect("429 must carry Retry-After");
    assert!(ra >= 1, "Retry-After rounds up to whole seconds, got {ra}");
    let err = Json::parse(&String::from_utf8_lossy(&body)).expect("JSON error body");
    assert_eq!(err.get("reason").and_then(Json::as_str), Some("rate"));
    assert!(err.get("retry_after_s").and_then(Json::as_u64).unwrap_or(0) >= 1);

    // The rejection shows up in /stats and /metrics under the default
    // tenant with the `rate` reason.
    let (st, stats) = c.request("GET", "/stats", b"").unwrap();
    assert_eq!(st, 200);
    let stats = String::from_utf8_lossy(&stats);
    assert!(stats.contains("\"admission\""), "stats must expose admission: {stats}");
    let (st, metrics) = c.request("GET", "/metrics", b"").unwrap();
    assert_eq!(st, 200);
    let metrics = String::from_utf8_lossy(&metrics);
    assert!(
        metrics.contains("boba_admission_rejected_total"),
        "metrics must expose the rejection family: {metrics}"
    );
    srv.shutdown();
}
