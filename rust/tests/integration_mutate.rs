//! Durable-mutation integration tests: crash-equivalence of WAL replay
//! against a never-crashed twin, torn-tail repair with corruption
//! accounting, injected WAL I/O errors, staged compaction crashes, and
//! `/readyz` degradation while replay is in flight.
//!
//! A "crash" here is a server torn down without any checkpoint or WAL
//! retirement (`Server::shutdown` writes nothing — every acked record
//! is already fsynced), followed by a fresh `spawn` over the same
//! `--wal-dir`. That is byte-for-byte the state a SIGKILL at a record
//! boundary leaves behind; mid-record crashes are modelled by the
//! `wal-torn-write` fault point, which leaves half a record on disk.
//! The `crash-after-append` point calls `abort()` and is exercised by
//! the ci.sh subprocess smoke, not in-process here.
//!
//! The chaos fault table is process-global, so every test serializes
//! on the file-local `LOCK` (the library's unit tests run in a
//! separate binary and cannot race these).

use boba::obs::chaos;
use boba::server::http::HttpClient;
use boba::server::json::Json;
use boba::server::{self, ServerConfig};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// A scratch WAL directory, wiped at the start of every test run.
fn wal_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("boba-imut-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create wal dir");
    dir
}

/// Spawn a WAL-enabled server on an ephemeral port. The seed is fixed
/// so a restarted server regenerates the identical base dataset.
fn spawn_wal(dir: &Path, compact_threshold: usize) -> server::Server {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        capacity: 4,
        seed: 42,
        read_timeout: Duration::from_secs(10),
        wal_dir: Some(dir.to_path_buf()),
        compact_threshold,
        ..Default::default()
    };
    server::spawn(cfg).expect("server must bind an ephemeral port")
}

fn client(srv: &server::Server) -> HttpClient {
    HttpClient::connect(&srv.addr().to_string()).expect("connect")
}

const DATASET: &str = "pa:1500:4";
const N: u32 = 1500;

fn ingest(c: &mut HttpClient) -> String {
    let body = format!("{{\"dataset\": \"{DATASET}\"}}");
    let (st, resp) = c.request("POST", "/graphs", body.as_bytes()).expect("ingest");
    assert!(st == 200 || st == 201, "ingest -> {st}: {}", String::from_utf8_lossy(&resp));
    Json::parse(&String::from_utf8_lossy(&resp))
        .expect("ingest json")
        .get("id")
        .and_then(Json::as_str)
        .expect("ingest id")
        .to_string()
}

/// A deterministic mutation batch: two upserts and a delete derived
/// from `i`, identical across the crash server and its twin.
fn batch_body(i: u32) -> String {
    let base = (i * 97) % (N - 100);
    format!(
        "{{\"ops\": [\
         {{\"op\": \"upsert\", \"u\": {}, \"v\": {}, \"w\": {}.5}},\
         {{\"op\": \"upsert\", \"u\": {}, \"v\": {}}},\
         {{\"op\": \"delete\", \"u\": {}, \"v\": {}}}]}}",
        base,
        (base + 3) % N,
        i % 7,
        (base + 11) % N,
        (base + 29) % N,
        (i * 13) % N,
        (i * 17) % N,
    )
}

fn mutate(c: &mut HttpClient, id: &str, body: &str) -> (u16, String) {
    let (st, resp) = c
        .request("POST", &format!("/graphs/{id}/mutate"), body.as_bytes())
        .expect("mutate exchange");
    (st, String::from_utf8_lossy(&resp).into_owned())
}

/// `GET /graphs/{id}/digest` → (digest hex, delta_entries, epoch).
fn digest(c: &mut HttpClient, id: &str) -> (String, u64, u64) {
    let (st, resp) =
        c.request("GET", &format!("/graphs/{id}/digest"), b"").expect("digest exchange");
    assert_eq!(st, 200, "digest -> {st}: {}", String::from_utf8_lossy(&resp));
    let j = Json::parse(&String::from_utf8_lossy(&resp)).expect("digest json");
    (
        j.get("digest").and_then(Json::as_str).expect("digest field").to_string(),
        j.get("delta_entries").and_then(Json::as_u64).unwrap_or(0),
        j.get("epoch").and_then(Json::as_u64).unwrap_or(0),
    )
}

fn arm(c: &mut HttpClient, spec: &str) {
    let body = format!("{{\"spec\": \"{spec}\"}}");
    let (st, resp) = c.request("POST", "/debug/faults", body.as_bytes()).expect("arm");
    assert_eq!(st, 200, "arming {spec:?}: {}", String::from_utf8_lossy(&resp));
}

/// Poll until WAL replay has finished: `/readyz` back to 200 and the
/// recovered graph answering its digest page.
fn wait_recovered(srv: &server::Server, id: &str) -> (String, u64, u64) {
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut last = String::new();
    while Instant::now() < deadline {
        let mut c = client(srv);
        let (st, body) = c.request("GET", "/readyz", b"").expect("readyz");
        last = String::from_utf8_lossy(&body).into_owned();
        if st == 200 {
            let (st, _) = c.request("GET", &format!("/graphs/{id}/digest"), b"").expect("digest");
            if st == 200 {
                return digest(&mut c, id);
            }
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    panic!("recovery did not finish within 60s; last /readyz: {last}");
}

/// Sizes of every `.wal` segment under `dir`, sorted by name.
fn wal_sizes(dir: &Path) -> Vec<(String, u64)> {
    let mut out: Vec<(String, u64)> = std::fs::read_dir(dir)
        .expect("read wal dir")
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "wal"))
        .map(|e| {
            (e.file_name().to_string_lossy().into_owned(), e.metadata().expect("meta").len())
        })
        .collect();
    out.sort();
    out
}

/// The tentpole contract: kill a WAL server at a record boundary (every
/// acked record fsynced, nothing else on disk), restart it over the
/// same directory, and the replayed digest equals both the pre-crash
/// digest and a never-crashed twin that applied the same batches.
#[test]
fn restart_replay_matches_never_crashed_twin() {
    let _g = lock();
    chaos::clear();
    let dir = wal_dir("replay");

    let (id, want) = {
        let srv = spawn_wal(&dir, 0);
        let mut c = client(&srv);
        let id = ingest(&mut c);
        for i in 0..6 {
            let (st, body) = mutate(&mut c, &id, &batch_body(i));
            assert_eq!(st, 200, "batch {i}: {body}");
            assert!(body.contains("\"durable\":true"), "ack must confirm fsync: {body}");
        }
        let (want, entries, _) = digest(&mut c, &id);
        assert!(entries >= 1, "overlay must be populated before the crash");
        srv.shutdown();
        (id, want)
    };

    // The twin: a fresh WAL dir, identical ingest + batches, no crash.
    let tdir = wal_dir("replay-twin");
    {
        let srv = spawn_wal(&tdir, 0);
        let mut c = client(&srv);
        let tid = ingest(&mut c);
        for i in 0..6 {
            assert_eq!(mutate(&mut c, &tid, &batch_body(i)).0, 200);
        }
        let (twin, _, _) = digest(&mut c, &tid);
        assert_eq!(twin, want, "twin and crash server diverged before the crash");
        srv.shutdown();
    }

    // Restart over the crash-state directory: replay must reconstruct
    // the acked state exactly.
    {
        let srv = spawn_wal(&dir, 0);
        let (got, entries, _) = wait_recovered(&srv, &id);
        assert_eq!(got, want, "replayed digest must match the never-crashed twin");
        assert!(entries >= 1, "replay must repopulate the overlay");
        srv.shutdown();
    }

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&tdir);
}

/// Mid-record crash: `wal-torn-write` leaves half a record on disk and
/// poisons the appender. The un-acked batch is lost by design; restart
/// truncates the torn tail (counting it in `boba_io_corruption_total`)
/// and recovers exactly the acked prefix.
#[test]
fn torn_write_recovers_acked_prefix_and_counts_corruption() {
    let _g = lock();
    chaos::clear();
    let dir = wal_dir("torn");
    let torn_before = boba::obs::corrupt::get("wal-torn-tail");

    let (id, want) = {
        let srv = spawn_wal(&dir, 0);
        let mut c = client(&srv);
        let id = ingest(&mut c);
        for i in 0..3 {
            assert_eq!(mutate(&mut c, &id, &batch_body(i)).0, 200);
        }
        let (want, _, _) = digest(&mut c, &id);

        arm(&mut c, "wal-torn-write:1");
        let (st, body) = mutate(&mut c, &id, &batch_body(99));
        assert_eq!(st, 503, "a torn append must not ack: {body}");
        assert!(body.contains("torn"), "failure must name the torn write: {body}");
        // Nothing un-acked may leak into query state…
        let (d, _, _) = digest(&mut c, &id);
        assert_eq!(d, want);
        // …and the appender stays poisoned until restart.
        let (st, body) = mutate(&mut c, &id, &batch_body(100));
        assert_eq!(st, 503);
        assert!(body.contains("poisoned"), "{body}");
        arm(&mut c, "");
        srv.shutdown();
        (id, want)
    };

    {
        let srv = spawn_wal(&dir, 0);
        let (got, _, _) = wait_recovered(&srv, &id);
        assert_eq!(got, want, "replay must recover exactly the acked prefix");
        assert!(
            boba::obs::corrupt::get("wal-torn-tail") > torn_before,
            "the truncated tail must be counted"
        );
        let mut c = client(&srv);
        let (st, body) = c.request("GET", "/metrics", b"").expect("metrics");
        assert_eq!(st, 200);
        let text = String::from_utf8_lossy(&body).into_owned();
        assert!(
            text.contains("boba_io_corruption_total{kind=\"wal-torn-tail\"}"),
            "corruption family missing from /metrics"
        );
        srv.shutdown();
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// An injected WAL I/O error is a clean 503 that writes nothing and
/// changes nothing; the very next append (budget spent) succeeds.
#[test]
fn wal_io_error_is_a_clean_503_that_changes_nothing() {
    let _g = lock();
    chaos::clear();
    let dir = wal_dir("ioerr");
    let srv = spawn_wal(&dir, 0);
    let mut c = client(&srv);
    let id = ingest(&mut c);
    assert_eq!(mutate(&mut c, &id, &batch_body(0)).0, 200);
    let (want, _, _) = digest(&mut c, &id);

    arm(&mut c, "wal-io-error:1");
    let (st, body) = mutate(&mut c, &id, &batch_body(1));
    assert_eq!(st, 503, "{body}");
    assert!(body.contains("wal-io-error"), "failure must name the fault: {body}");
    let (d, _, _) = digest(&mut c, &id);
    assert_eq!(d, want, "a failed append must not mutate query state");

    // Budget spent: durability resumes without a restart.
    let (st, body) = mutate(&mut c, &id, &batch_body(1));
    assert_eq!(st, 200, "{body}");
    let (d, _, _) = digest(&mut c, &id);
    assert_ne!(d, want, "the retried batch must now be applied");
    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Mid-compaction crashes at both staged windows (pre-checkpoint and
/// post-checkpoint) leave the served digest untouched, a retry
/// compacts cleanly, and a restart over the compacted directory agrees.
#[test]
fn failed_compaction_preserves_digest_over_http_and_restart() {
    let _g = lock();
    chaos::clear();
    let dir = wal_dir("compact");

    let (id, want) = {
        let srv = spawn_wal(&dir, 0);
        let mut c = client(&srv);
        let id = ingest(&mut c);
        for i in 0..5 {
            assert_eq!(mutate(&mut c, &id, &batch_body(i)).0, 200);
        }
        let (want, entries, _) = digest(&mut c, &id);
        assert!(entries >= 1);

        for stage in [0, 1] {
            arm(&mut c, &format!("compact-fail:{stage}:1"));
            let (st, body) = c
                .request("POST", &format!("/graphs/{id}/compact"), b"")
                .expect("compact exchange");
            let body = String::from_utf8_lossy(&body).into_owned();
            assert_eq!(st, 503, "stage {stage}: {body}");
            assert!(body.contains("compact-fail"), "stage {stage}: {body}");
            let (d, _, _) = digest(&mut c, &id);
            assert_eq!(d, want, "a failed compaction must not change the digest");
        }
        arm(&mut c, "");

        let (st, body) =
            c.request("POST", &format!("/graphs/{id}/compact"), b"").expect("compact");
        let body = String::from_utf8_lossy(&body).into_owned();
        assert_eq!(st, 200, "{body}");
        assert!(body.contains("\"compacted\":true"), "{body}");
        let (d, entries, epoch) = digest(&mut c, &id);
        assert_eq!(d, want, "compaction must preserve the logical graph");
        assert_eq!(entries, 0, "compaction must drain the overlay");
        assert!(epoch >= 1, "compaction must advance the epoch");
        srv.shutdown();
        (id, want)
    };

    // Restart over the compacted directory: recovery now boots from
    // the checkpoint instead of the dataset recipe.
    {
        let srv = spawn_wal(&dir, 0);
        let (got, _, _) = wait_recovered(&srv, &id);
        assert_eq!(got, want);
        srv.shutdown();
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// While replay is in flight `/readyz` degrades with the `recovering`
/// reason, and a shutdown mid-replay exits without modifying a single
/// byte of the undamaged segments. The stall is injected by arming
/// `slow-stage` before the restart, which delays the recovery thread's
/// own prepare spans.
#[test]
fn readyz_reports_recovering_and_shutdown_mid_replay_leaves_wal_bytes() {
    let _g = lock();
    chaos::clear();
    let dir = wal_dir("recovering");

    let (id, want) = {
        let srv = spawn_wal(&dir, 0);
        let mut c = client(&srv);
        let id = ingest(&mut c);
        for i in 0..8 {
            assert_eq!(mutate(&mut c, &id, &batch_body(i)).0, 200);
        }
        let (want, _, _) = digest(&mut c, &id);
        srv.shutdown();
        (id, want)
    };
    let sizes = wal_sizes(&dir);
    assert!(!sizes.is_empty(), "mutations must have produced WAL segments");

    // Restart with the recovery thread stalled in its first prepare
    // spans: the first /readyz lands inside the replay window.
    chaos::set_spec("slow-stage:500:3").expect("arm slow-stage");
    {
        let srv = spawn_wal(&dir, 0);
        let mut c = client(&srv);
        let (st, body) = c.request("GET", "/readyz", b"").expect("readyz");
        let body = String::from_utf8_lossy(&body).into_owned();
        assert_eq!(st, 503, "readyz must degrade during replay: {body}");
        assert!(body.contains("recovering"), "readyz must name the reason: {body}");
        srv.shutdown();
    }
    chaos::clear();
    // Let the detached recovery thread observe the flag and drain.
    std::thread::sleep(Duration::from_millis(2200));
    assert_eq!(wal_sizes(&dir), sizes, "an interrupted replay must not touch clean segments");

    // A clean restart finishes replay and reports ready.
    {
        let srv = spawn_wal(&dir, 0);
        let (got, _, _) = wait_recovered(&srv, &id);
        assert_eq!(got, want);
        let mut c = client(&srv);
        let (st, body) = c.request("GET", "/readyz", b"").expect("readyz");
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
        srv.shutdown();
    }

    let _ = std::fs::remove_dir_all(&dir);
}
