//! Stress tests of the persistent worker pool: nested dispatch from
//! foreign OS threads (the server's worker threads enter the parallel
//! substrate exactly like this), `par_jobs` jobs that fan out into
//! `par_for_chunks` internally, and concurrent `set_threads` flips —
//! asserting no deadlock and full, exactly-once index coverage
//! throughout.

use boba::parallel::{self, pool, ThreadGuard};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// `set_threads` is process-global and libtest runs `#[test]`s
/// concurrently, so the tests that pin or flip the worker count take
/// this lock to avoid perturbing each other's scheduling assumptions.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn nested_par_jobs_into_par_for_chunks_from_server_like_threads() {
    // 4 "server worker" OS threads, each dispatching a wave of par_jobs
    // whose jobs themselves run par_for_chunks — two levels of nesting
    // on top of foreign threads. The pool's caller-participates design
    // must complete all of it without deadlock.
    const OS_THREADS: usize = 4;
    const JOBS: usize = 6;
    const LEN: usize = 20_000;
    let _serial = serial();
    let hits = Arc::new(
        (0..OS_THREADS * JOBS * LEN)
            .map(|_| AtomicUsize::new(0))
            .collect::<Vec<_>>(),
    );
    let handles: Vec<_> = (0..OS_THREADS)
        .map(|t| {
            let hits = Arc::clone(&hits);
            std::thread::spawn(move || {
                let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..JOBS)
                    .map(|j| {
                        let hits = Arc::clone(&hits);
                        Box::new(move || {
                            let off = (t * JOBS + j) * LEN;
                            parallel::par_for_chunks(LEN, 512, |lo, hi| {
                                for i in lo..hi {
                                    hits[off + i].fetch_add(1, Ordering::Relaxed);
                                }
                            });
                            j
                        }) as _
                    })
                    .collect();
                let out = parallel::par_jobs(jobs);
                assert_eq!(out, (0..JOBS).collect::<Vec<_>>(), "job results in order");
            })
        })
        .collect();
    for h in handles {
        h.join().expect("server-like thread completed");
    }
    for (i, h) in hits.iter().enumerate() {
        assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} covered exactly once");
    }
}

#[test]
fn set_threads_flips_during_dispatch_storm() {
    // Repeatedly flip the worker pin while another thread hammers the
    // pool with short dispatches. Each dispatch reads the mask once at
    // entry; flips must never deadlock it or lose coverage.
    let _serial = serial();
    let stop = Arc::new(AtomicUsize::new(0));
    let flipper = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut pin = 1usize;
            while stop.load(Ordering::Relaxed) == 0 {
                let _g = ThreadGuard::pin(pin);
                pin = pin % 8 + 1;
                std::thread::yield_now();
            }
        })
    };
    for round in 0..200 {
        let len = 1_000 + round * 7;
        let total = AtomicUsize::new(0);
        parallel::par_for_chunks(len, 64, |lo, hi| {
            total.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), len, "round {round}");
    }
    stop.store(1, Ordering::Relaxed);
    flipper.join().unwrap();
}

#[test]
fn pool_is_reused_not_respawned() {
    let _serial = serial();
    let _g = ThreadGuard::pin(4);
    parallel::par_for_chunks(1 << 16, 1 << 10, |_, _| {}); // warm
    let (_, gen_before) = pool::stats();
    for _ in 0..32 {
        parallel::par_reduce(
            1 << 14,
            256,
            0u64,
            |acc, lo, hi| acc + (hi - lo) as u64,
            |a, b| a + b,
        );
    }
    let (workers, gen_after) = pool::stats();
    assert!(gen_after > gen_before, "dispatch generations advance");
    // Workers are bounded by machine parallelism / the biggest pin, not
    // by the number of dispatches (the spawn-per-call failure mode).
    let ceiling = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).max(8);
    assert!(workers <= ceiling, "pool spawned {workers} workers (ceiling {ceiling})");
}

#[test]
fn par_jobs_is_work_conserving_under_one_slow_job() {
    // With the old wave scheduler, a slow job in wave 1 gated every job
    // of wave 2. Now all fast jobs must finish while the slow one is
    // still sleeping. (Generous timing margins keep this robust on slow
    // CI machines; the ordering claim—fast jobs don't wait for the slow
    // one—is what matters.)
    let _serial = serial();
    let _g = ThreadGuard::pin(4);
    let started = std::time::Instant::now();
    let fast_done = Arc::new(AtomicUsize::new(0));
    let jobs: Vec<Box<dyn FnOnce() -> u128 + Send>> = (0..8)
        .map(|j| {
            let fast_done = Arc::clone(&fast_done);
            Box::new(move || {
                if j == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(400));
                } else {
                    fast_done.fetch_add(1, Ordering::Relaxed);
                }
                started.elapsed().as_millis()
            }) as _
        })
        .collect();
    let finish_ms = parallel::par_jobs(jobs);
    assert_eq!(fast_done.load(Ordering::Relaxed), 7);
    // Every fast job must have finished well before the slow job did —
    // they never queue behind it in a wave.
    let slow_finish = finish_ms[0];
    for (j, &t) in finish_ms.iter().enumerate().skip(1) {
        assert!(
            t < slow_finish,
            "job {j} finished at {t}ms, after the slow job at {slow_finish}ms"
        );
    }
}
