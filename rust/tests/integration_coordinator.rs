//! Coordinator-level integration: dataset suite coherence, CLI-style
//! dispatch paths (via the library surface the binary uses), concurrent
//! pipeline jobs through the parallel substrate, and failure injection on
//! the I/O boundary.

use boba::coordinator::datasets::{self, Family, Scale};
use boba::coordinator::pipeline::{App, Pipeline, ReorderStage};
use boba::graph::io;
use boba::parallel;
use boba::reorder::boba::Boba;

#[test]
fn dataset_suite_families_partition() {
    let all = datasets::full_suite();
    assert!(all.iter().any(|d| d.family == Family::ScaleFree));
    assert!(all.iter().any(|d| d.family == Family::Uniform));
    for d in &all {
        assert!(datasets::by_name(d.name).is_some());
    }
    assert!(datasets::by_name("nope").is_none());
}

#[test]
fn scale_knob_changes_size() {
    let d = datasets::by_name("kron_s").unwrap();
    let q = d.build_at(Scale::Quick, 1);
    let f = d.build_at(Scale::Full, 1);
    assert!(f.m() > 4 * q.m(), "full {} vs quick {}", f.m(), q.m());
}

#[test]
fn concurrent_pipelines_share_nothing() {
    // The coordinator dispatches independent requests via par_jobs; the
    // pipelines must not interfere (no global state).
    let g = datasets::by_name("pa_c8").unwrap().build_at(Scale::Quick, 2).randomized(3);
    let jobs: Vec<Box<dyn FnOnce() -> f64 + Send>> = App::all()
        .into_iter()
        .map(|app| {
            let g = g.clone();
            Box::new(move || {
                Pipeline::new(app)
                    .run(&g, &ReorderStage::Scheme(Box::new(Boba::parallel())))
                    .digest
            }) as _
        })
        .collect();
    let digests = parallel::par_jobs(jobs);
    // Same digests as running serially.
    for (app, d) in App::all().into_iter().zip(&digests) {
        let serial = Pipeline::new(app)
            .run(&g, &ReorderStage::Scheme(Box::new(Boba::parallel())))
            .digest;
        let tol = 1e-6 * serial.abs().max(1.0);
        assert!((d - serial).abs() <= tol, "{}: {d} vs {serial}", app.name());
    }
}

#[test]
fn io_failure_paths_are_errors_not_panics() {
    let missing = std::path::Path::new("/nonexistent/boba/file.mtx");
    assert!(io::read_matrix_market(missing).is_err());
    assert!(io::read_edge_list(missing, false).is_err());

    // Malformed content.
    let mut p = std::env::temp_dir();
    p.push(format!("boba_bad_{}.mtx", std::process::id()));
    std::fs::write(&p, "%%MatrixMarket matrix coordinate real general\n2 2 1\nnot numbers\n")
        .unwrap();
    assert!(io::read_matrix_market(&p).is_err());
    std::fs::remove_file(&p).ok();
}

#[test]
fn runtime_meta_load_failure_is_graceful() {
    // Pointing at an empty dir must error with a make-artifacts hint.
    // (Engine::load hits this same path first; Engine itself only
    // exists under the `pjrt` feature.)
    let dir = std::env::temp_dir().join(format!("boba_empty_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let Err(err) = boba::runtime::Meta::load(&dir) else {
        panic!("load from empty dir must fail");
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
#[cfg(feature = "pjrt")]
fn runtime_engine_load_failure_is_graceful() {
    let dir = std::env::temp_dir().join(format!("boba_empty_eng_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let Err(err) = boba::runtime::Engine::load(&dir) else {
        panic!("load from empty dir must fail");
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn inventory_lists_every_dataset() {
    let inv = datasets::inventory(1);
    for d in datasets::full_suite() {
        assert!(inv.contains(d.name), "inventory missing {}", d.name);
    }
}

#[test]
fn reorderers_are_send_sync_boxable() {
    // The coordinator moves schemes across worker threads; this must
    // compile and run.
    fn takes_send_sync<T: Send + Sync>(_: &T) {}
    let schemes = boba::reorder::all_schemes(1);
    for s in &schemes {
        takes_send_sync(s);
    }
    assert_eq!(schemes.len(), 6);
}
