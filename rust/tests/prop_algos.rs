//! Property tests over the graph kernels: label-invariance (the keystone
//! of the whole reordering story — f(G) must not change when labels do),
//! oracle agreement, and parallel/sequential equivalence.

use boba::algos::{pagerank, spmv, sssp, tc};
use boba::convert::{coo_to_csr, sort_coo_by_src};
use boba::graph::{gen, Coo};
use boba::testing::{check, Config, Gen};
use boba::util::prng::Xoshiro256;

fn arb_graph(g: &mut Gen) -> Coo {
    let n = g.usize(4..500);
    let m = g.usize(4..3000);
    gen::uniform_random(n, m, g.seed())
}

fn arb_perm(g: &mut Gen, n: usize) -> Vec<u32> {
    Xoshiro256::new(g.seed()).permutation(n)
}

#[test]
fn spmv_commutes_with_relabeling() {
    check(Config::default().cases(40), "spmv label-invariance", |g| {
        let coo = arb_graph(g);
        let perm = arb_perm(g, coo.n());
        let x: Vec<f32> = (0..coo.n()).map(|_| g.f32()).collect();
        // y on original labels.
        let y0 = spmv::spmv_pull(&coo_to_csr(&coo), &x);
        // relabel graph AND x, run, un-relabel y.
        let h = coo.relabeled(&perm);
        let mut xp = vec![0f32; coo.n()];
        for v in 0..coo.n() {
            xp[perm[v] as usize] = x[v];
        }
        let yp = spmv::spmv_pull(&coo_to_csr(&h), &xp);
        for v in 0..coo.n() {
            let a = y0[v];
            let b = yp[perm[v] as usize];
            anyhow::ensure!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "{a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn spmv_parallel_equals_sequential() {
    check(Config::default().cases(15), "spmv par == seq", |g| {
        let n = g.usize(100..3000);
        let m = g.usize(20_000..60_000);
        let coo = gen::uniform_random(n, m, g.seed());
        let csr = coo_to_csr(&coo);
        let x: Vec<f32> = (0..n).map(|_| g.f32()).collect();
        let a = spmv::spmv_pull(&csr, &x);
        let b = spmv::spmv_pull_parallel(&csr, &x);
        anyhow::ensure!(a == b, "parallel SpMV must be bitwise identical");
        Ok(())
    });
}

#[test]
fn pagerank_mass_conserved_any_graph() {
    check(Config::default().cases(25), "pagerank mass", |g| {
        let coo = arb_graph(g);
        let csr = coo_to_csr(&coo);
        let r = pagerank::pagerank(&csr, pagerank::PrParams::default());
        let s: f64 = r.ranks.iter().map(|&v| v as f64).sum();
        anyhow::ensure!((s - 1.0).abs() < 1e-2, "mass {s}");
        anyhow::ensure!(r.ranks.iter().all(|&v| v >= 0.0));
        Ok(())
    });
}

#[test]
fn pagerank_invariant_under_relabeling() {
    check(Config::default().cases(20), "pagerank label-invariance", |g| {
        let coo = arb_graph(g);
        let perm = arb_perm(g, coo.n());
        let p = pagerank::PrParams { max_iters: 20, tol: 0.0, ..Default::default() };
        let r0 = pagerank::pagerank(&coo_to_csr(&coo), p);
        let r1 = pagerank::pagerank(&coo_to_csr(&coo.relabeled(&perm)), p);
        for v in 0..coo.n() {
            let a = r0.ranks[v];
            let b = r1.ranks[perm[v] as usize];
            anyhow::ensure!((a - b).abs() < 1e-4, "rank({v}): {a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn tc_invariant_under_relabeling_and_orientation() {
    check(Config::default().cases(25), "tc invariance", |g| {
        let coo = arb_graph(g);
        let count = |c: &Coo| {
            let und = c.symmetrized().deduped();
            let csr = coo_to_csr(&sort_coo_by_src(&und));
            let rank = tc::degree_rank(&csr);
            tc::triangle_count_ranked(&tc::orient_by_rank(&csr, &rank), &rank)
        };
        let id_count = {
            let und = coo.symmetrized().deduped();
            let csr = coo_to_csr(&sort_coo_by_src(&und));
            tc::triangle_count(&tc::orient_for_tc(&csr))
        };
        let perm = arb_perm(g, coo.n());
        anyhow::ensure!(count(&coo) == id_count, "rank vs id orientation");
        anyhow::ensure!(count(&coo.relabeled(&perm)) == id_count, "relabeling changed count");
        Ok(())
    });
}

#[test]
fn sssp_frontier_equals_dijkstra() {
    check(Config::default().cases(25), "sssp oracle", |g| {
        let n = g.usize(4..400);
        let m = g.usize(4..2500);
        let mut coo = gen::uniform_random(n, m, g.seed());
        coo.vals = Some((0..m).map(|_| g.f32() + 0.001).collect());
        let csr = coo_to_csr(&coo);
        let src = g.usize(0..n) as u32;
        let a = sssp::dijkstra(&csr, src);
        let b = sssp::sssp_frontier(&csr, src);
        for v in 0..n {
            if a[v].is_finite() {
                anyhow::ensure!((a[v] - b[v]).abs() < 1e-3, "v={v}: {} vs {}", a[v], b[v]);
            } else {
                anyhow::ensure!(b[v].is_infinite());
            }
        }
        Ok(())
    });
}

#[test]
fn traced_kernels_equal_untraced() {
    check(Config::default().cases(20), "traced == plain", |g| {
        let coo = arb_graph(g);
        let csr = coo_to_csr(&coo);
        let x: Vec<f32> = (0..coo.n()).map(|_| g.f32()).collect();
        let mut t = boba::algos::trace::VecTrace::default();
        anyhow::ensure!(
            spmv::spmv_pull_traced(&csr, &x, &mut t) == spmv::spmv_pull(&csr, &x)
        );
        let mut t2 = boba::algos::trace::VecTrace::default();
        anyhow::ensure!(
            sssp::sssp_frontier_traced(&csr, 0, &mut t2) == sssp::sssp_frontier(&csr, 0)
        );
        Ok(())
    });
}

#[test]
fn cache_sim_counts_match_trace_length() {
    check(Config::default().cases(15), "sim read accounting", |g| {
        let coo = arb_graph(g);
        let csr = coo_to_csr(&coo);
        let x = vec![1.0f32; coo.n()];
        let mut vt = boba::algos::trace::VecTrace::default();
        spmv::spmv_pull_traced(&csr, &x, &mut vt);
        let mut hier = boba::cachesim::Hierarchy::v100_like();
        for &a in &vt.addrs {
            hier.access(a);
        }
        anyhow::ensure!(hier.rates().reads == vt.addrs.len() as u64);
        Ok(())
    });
}
