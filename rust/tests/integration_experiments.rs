//! End-to-end runs of the experiment drivers at reduced scale, asserting
//! the *paper-shape* properties each table/figure claims (not absolute
//! numbers — DESIGN.md §6 defines what must hold).
//!
//! These run with BOBA_HEAVY honored; they use the lightweight-only
//! lineup plus targeted heavyweight spot-checks to stay CI-sized.

use boba::convert;
use boba::coordinator::experiments;
use boba::graph::gen;
use boba::metrics;
use boba::reorder::{boba::Boba, gorder::Gorder, hub::HubSort, rcm::Rcm, Reorderer};

fn light_only() {
    std::env::set_var("BOBA_HEAVY", "0");
    std::env::set_var("BOBA_SCALE", "quick");
}

/// Timing-based shape assertions are noisy when the test harness runs
/// suites concurrently: retry up to 3 times and fail only if every
/// attempt violates the shape.
fn retry_timing(name: &str, attempts: usize, f: impl Fn() -> Result<(), String>) {
    let mut last = String::new();
    for _ in 0..attempts {
        match f() {
            Ok(()) => return,
            Err(e) => last = e,
        }
    }
    panic!("{name}: failed {attempts} attempts; last: {last}");
}

#[test]
fn table1_boba_beats_random_on_uniform_suite() {
    light_only();
    let t = experiments::table1(11);
    for ds in ["delaunay_s", "rgg_s"] {
        let rand = t.get(ds, "Rand").unwrap();
        let boba = t.get(ds, "BOBA").unwrap();
        let hub = t.get(ds, "Hub").unwrap();
        assert!(boba < 0.85 * rand, "{ds}: BOBA {boba} vs rand {rand}");
        // Degree-based methods ≈ random on uniform graphs (paper Fig 3/6).
        assert!(hub > 0.95 * rand, "{ds}: Hub {hub} should ≈ rand {rand}");
    }
}

#[test]
fn table1_heavyweight_spot_check() {
    // Gorder best, BOBA between heavyweight and random (paper Table 1) on
    // one uniform dataset, computed directly (not via the full driver).
    let g = gen::delaunay_mesh(120, 120, 3).symmetrized().randomized(7);
    let rand_nbr = metrics::nbr_coo(&g);
    let nbr_of = |s: &dyn Reorderer| {
        let p = s.reorder(&g);
        metrics::nbr_coo(&g.relabeled(p.new_of_old()))
    };
    let gorder = nbr_of(&Gorder::new(5));
    let rcm = nbr_of(&Rcm::new());
    let boba = nbr_of(&Boba::parallel());
    let hub = nbr_of(&HubSort::new());
    assert!(gorder < boba, "Gorder {gorder} must beat BOBA {boba}");
    assert!(boba < 0.9 * rand_nbr, "BOBA {boba} vs rand {rand_nbr}");
    assert!(boba < hub, "BOBA {boba} must beat Hub {hub} on uniform");
    assert!(rcm < rand_nbr, "RCM {rcm} vs rand {rand_nbr}");
}

#[test]
fn table3_shapes() {
    light_only();
    retry_timing("table3", 2, || {
        let t = experiments::table3(5);
        // Scale-free rows: BOBA conversion ≤ random conversion (the
        // paper's central conversion-speedup claim).
        for ds in ["arabic_like", "copapers_like"] {
            let rc = t.get(ds, "rand_conv").unwrap();
            let bc = t.get(ds, "boba_conv").unwrap();
            if bc > rc * 1.15 {
                return Err(format!("{ds}: conv {bc} vs {rc}"));
            }
        }
        // delaunay: bounded either way (the paper's null-result row; our
        // generator's natural edge order lets BOBA recover more — see
        // docs/EXPERIMENTS.md Table 3 note).
        let rc = t.get("delaunay_like", "rand_conv").unwrap();
        let bc = t.get("delaunay_like", "boba_conv").unwrap();
        if !(bc < rc * 1.5 && bc > rc * 0.2) {
            return Err(format!("delaunay conv {bc} vs {rc}"));
        }
        Ok(())
    });
}

#[test]
fn fig7_boba_tracks_heavyweight_not_random() {
    light_only();
    let t = experiments::fig7(3);
    // On the scale-free dataset, BOBA's SpMV L1 rate must beat Random's.
    let rand_l1 = t.get("kron18/SpMV/Random", "l1").unwrap();
    let boba_l1 = t.get("kron18/SpMV/BOBA", "l1").unwrap();
    assert!(boba_l1 > rand_l1, "BOBA {boba_l1} vs random {rand_l1}");
    // DRAM-served fraction must shrink.
    let rand_dram = t.get("kron18/SpMV/Random", "dram").unwrap();
    let boba_dram = t.get("kron18/SpMV/BOBA", "dram").unwrap();
    assert!(boba_dram < rand_dram, "{boba_dram} vs {rand_dram}");
    // TC has the highest L1 rates of all apps (high data reuse — §5.5).
    let tc_l1 = t.get("kron18/TC/Random", "l1").unwrap();
    for app in ["SpMV", "PR", "SSSP"] {
        let other = t.get(&format!("kron18/{app}/Random"), "l1").unwrap();
        assert!(tc_l1 > other, "TC {tc_l1} vs {app} {other}");
    }
}

#[test]
fn reorder_cost_ordering_boba_fastest() {
    // §5.4's cost hierarchy on one dataset: BOBA < degree-based
    // lightweight < heavyweight (RCM here; Gorder is covered by the bench
    // where its long runtime is the point).
    use boba::util::timer::Stopwatch;
    let g = gen::preferential_attachment(100_000, 6, 2).randomized(3);
    let time = |s: &dyn Reorderer| {
        let mut samples: Vec<f64> = (0..3)
            .map(|_| {
                let sw = Stopwatch::start();
                std::hint::black_box(s.reorder(&g));
                sw.ms()
            })
            .collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        samples[1]
    };
    retry_timing("reorder cost hierarchy", 3, || {
        let boba = time(&Boba::parallel());
        let hub = time(&HubSort::new());
        let rcm = time(&Rcm::new());
        if boba >= hub * 2.0 {
            return Err(format!("BOBA {boba} vs Hub {hub}"));
        }
        if boba * 2.0 >= rcm {
            return Err(format!("BOBA {boba} vs RCM {rcm}"));
        }
        Ok(())
    });
}

#[test]
fn conversion_speedup_on_big_scale_free_graph() {
    // The Problem-3 headline on a graph whose counter array breaks cache:
    // BOBA-relabeled conversion must be faster than random-labeled.
    use boba::util::timer::Stopwatch;
    let g = gen::preferential_attachment(400_000, 6, 4).randomized(9);
    let p = Boba::parallel().reorder(&g);
    let b = g.relabeled(p.new_of_old());
    retry_timing("conversion speedup", 3, || {
        let t_rand = {
            let sw = Stopwatch::start();
            std::hint::black_box(convert::coo_to_csr(&g));
            sw.ms()
        };
        let t_boba = {
            let sw = Stopwatch::start();
            std::hint::black_box(convert::coo_to_csr(&b));
            sw.ms()
        };
        if t_boba >= t_rand {
            return Err(format!("BOBA conv {t_boba:.1}ms vs random {t_rand:.1}ms"));
        }
        Ok(())
    });
}
