//! Property tests over the conversion substrate: COO→CSR (sequential and
//! parallel), radix sort, and transposition — the pipeline stages whose
//! cache behaviour the paper's Problem 3 measures, so their *correctness*
//! must be beyond doubt under every labeling.

use boba::convert::{
    coo_to_csr, coo_to_csr_parallel, coo_to_csr_parallel_atomic, csr_to_coo, sort_coo_by_src,
};
use boba::graph::{gen, Coo};
use boba::testing::{check, Config, Gen};

fn arb_coo(g: &mut Gen) -> Coo {
    let n = g.usize(1..1000);
    let m = g.usize(0..6000);
    gen::uniform_random(n, m, g.seed())
}

#[test]
fn csr_structure_matches_coo() {
    check(Config::default().cases(50), "csr == coo", |g| {
        let coo = arb_coo(g);
        let csr = coo_to_csr(&coo);
        csr.validate()?;
        anyhow::ensure!(csr.m() == coo.m());
        anyhow::ensure!(csr.n() == coo.n());
        // Every COO edge appears exactly once in the CSR.
        let mut count_coo = std::collections::HashMap::new();
        for e in coo.edges() {
            *count_coo.entry(e).or_insert(0u32) += 1;
        }
        let mut count_csr = std::collections::HashMap::new();
        for v in 0..csr.n() {
            for &u in csr.neighbors(v) {
                *count_csr.entry((v as u32, u)).or_insert(0u32) += 1;
            }
        }
        anyhow::ensure!(count_coo == count_csr, "edge multisets differ");
        Ok(())
    });
}

#[test]
fn parallel_converter_is_bit_identical_to_sequential() {
    check(Config::default().cases(25), "par == seq (bit-identical)", |g| {
        // Force sizes across the parallel threshold.
        let n = g.usize(10..2000);
        let m = g.usize(30_000..80_000);
        let coo = gen::uniform_random(n, m, g.seed());
        let a = coo_to_csr(&coo);
        let b = coo_to_csr_parallel(&coo);
        // The deterministic kernel needs no sort_rows compensation:
        // every array must match exactly.
        anyhow::ensure!(a == b, "deterministic parallel converter diverged");
        Ok(())
    });
}

#[test]
fn atomic_baseline_matches_sequential_up_to_row_order() {
    check(Config::default().cases(10), "par-atomic == seq (multisets)", |g| {
        let n = g.usize(10..2000);
        let m = g.usize(30_000..80_000);
        let coo = gen::uniform_random(n, m, g.seed());
        let a = coo_to_csr(&coo);
        let mut b = coo_to_csr_parallel_atomic(&coo);
        anyhow::ensure!(a.row_ptr == b.row_ptr, "row_ptr differs");
        let mut a2 = a.clone();
        a2.sort_rows();
        b.sort_rows();
        anyhow::ensure!(a2.col_idx == b.col_idx, "col multisets differ");
        Ok(())
    });
}

#[test]
fn roundtrip_csr_coo_csr() {
    check(Config::default().cases(40), "csr->coo->csr fixpoint", |g| {
        let coo = arb_coo(g);
        let csr = coo_to_csr(&coo);
        let back = csr_to_coo(&csr);
        let csr2 = coo_to_csr(&back);
        anyhow::ensure!(csr == csr2);
        Ok(())
    });
}

#[test]
fn radix_sort_is_sorted_and_permutation() {
    check(Config::default().cases(40), "radix sort", |g| {
        let coo = arb_coo(g);
        let s = sort_coo_by_src(&coo);
        for i in 1..s.m() {
            let prev = ((s.src[i - 1] as u64) << 32) | s.dst[i - 1] as u64;
            let cur = ((s.src[i] as u64) << 32) | s.dst[i] as u64;
            anyhow::ensure!(prev <= cur, "not sorted at {i}");
        }
        let mut a: Vec<_> = coo.edges().collect();
        let mut b: Vec<_> = s.edges().collect();
        a.sort_unstable();
        b.sort_unstable();
        anyhow::ensure!(a == b, "edge multiset changed");
        Ok(())
    });
}

#[test]
fn radix_sort_stable_on_dst() {
    // sort_coo_by_src sorts by (src, dst): within a src, dst ascending.
    check(Config::default().cases(30), "within-row sorted", |g| {
        let coo = arb_coo(g);
        let csr = coo_to_csr(&sort_coo_by_src(&coo));
        anyhow::ensure!(csr.rows_sorted());
        Ok(())
    });
}

#[test]
fn transpose_preserves_edge_count_and_reverses() {
    check(Config::default().cases(40), "transpose", |g| {
        let coo = arb_coo(g);
        let csr = coo_to_csr(&coo);
        let t = csr.transposed();
        anyhow::ensure!(t.m() == csr.m());
        // (u,v) in csr <=> (v,u) in t (as multisets).
        let mut fwd = std::collections::HashMap::new();
        for v in 0..csr.n() {
            for &u in csr.neighbors(v) {
                *fwd.entry((v as u32, u)).or_insert(0u32) += 1;
            }
        }
        let mut rev = std::collections::HashMap::new();
        for v in 0..t.n() {
            for &u in t.neighbors(v) {
                *rev.entry((u, v as u32)).or_insert(0u32) += 1;
            }
        }
        anyhow::ensure!(fwd == rev);
        Ok(())
    });
}

#[test]
fn weighted_conversion_keeps_value_sum() {
    check(Config::default().cases(30), "weighted sum", |g| {
        let mut coo = arb_coo(g);
        let vals: Vec<f32> = (0..coo.m()).map(|_| g.f32()).collect();
        let total: f64 = vals.iter().map(|&v| v as f64).sum();
        coo.vals = Some(vals);
        let csr = coo_to_csr(&coo);
        let total2: f64 = csr.vals.as_ref().unwrap().iter().map(|&v| v as f64).sum();
        anyhow::ensure!((total - total2).abs() < 1e-3 * total.abs().max(1.0));
        Ok(())
    });
}
