//! Corollary 9 (of Bollobás–Riordan Theorem 16): on LCD
//! preferential-attachment graphs, expected NScore is (near-)maximized by
//! the identity ordering — i.e. ordering by attachment time. This is the
//! theoretical heart of BOBA: appearance order ≈ attachment order.
//!
//! Statistical test: for G_c^n built by the LCD process with natural
//! (attachment-time) labels,
//!   (a) NScore(identity) beats random labelings by a wide margin;
//!   (b) BOBA applied to a *randomized* copy recovers most of that score;
//!   (c) the recovered ordering correlates with attachment time.

use boba::graph::gen;
use boba::metrics::nscore;
use boba::reorder::{boba::Boba, Reorderer};

#[test]
fn identity_beats_random_orderings() {
    // With NScore's w=1 window the absolute scores are small, so the test
    // uses a denser G_c^n (c=8) and a clear-but-achievable margin.
    for seed in 0..3 {
        let g = gen::preferential_attachment(3000, 8, seed);
        let id_score = nscore(&g);
        for rs in 0..3 {
            let rand_score = nscore(&g.randomized(100 + rs));
            assert!(
                id_score as f64 > 1.25 * rand_score as f64,
                "seed {seed}: identity {id_score} vs random {rand_score}"
            );
        }
    }
}

#[test]
fn boba_recovers_attachment_order_score() {
    for seed in 0..3 {
        let g = gen::preferential_attachment(3000, 4, seed);
        let id_score = nscore(&g) as f64;
        let rand = g.randomized(7 + seed);
        let rand_score = nscore(&rand) as f64;
        let p = Boba::sequential().reorder(&rand);
        let rec_score = nscore(&rand.relabeled(p.new_of_old())) as f64;
        // BOBA must close most of the gap between random and identity.
        let recovered_fraction = (rec_score - rand_score) / (id_score - rand_score);
        assert!(
            recovered_fraction > 0.5,
            "seed {seed}: recovered only {recovered_fraction:.2} \
             (random {rand_score}, boba {rec_score}, identity {id_score})"
        );
    }
}

#[test]
fn boba_rank_correlates_with_attachment_time() {
    // Spearman-style check: average |BOBA rank − attachment time| must be
    // far below the ~n/3 expected for an unrelated permutation.
    let n = 4000usize;
    let g = gen::preferential_attachment(n, 4, 5);
    let rand = g.randomized(11);
    // rand = relabel(g, sigma). BOBA on rand gives p. The composed map
    // old-attachment-id -> boba-new-id is p(sigma(v)).
    let sigma = {
        // Recover sigma by comparing edge lists: rand.src[i] = sigma(g.src[i]).
        let mut s = vec![0u32; n];
        for (a, b) in g.src.iter().zip(rand.src.iter()) {
            s[*a as usize] = *b;
        }
        for (a, b) in g.dst.iter().zip(rand.dst.iter()) {
            s[*a as usize] = *b;
        }
        s
    };
    let p = Boba::sequential().reorder(&rand);
    let map = p.new_of_old();
    let mean_dev: f64 = (0..n)
        .map(|v| (map[sigma[v] as usize] as f64 - v as f64).abs())
        .sum::<f64>()
        / n as f64;
    let random_expectation = n as f64 / 3.0;
    assert!(
        mean_dev < 0.4 * random_expectation,
        "mean |rank - attachment time| = {mean_dev:.1}, random would be ~{random_expectation:.1}"
    );
}

#[test]
fn pa_degree_distribution_is_powerlaw_ish() {
    // Sanity for the generator Corollary 9 assumes: heavy tail — the top
    // 1% of vertices own a disproportionate share of degree.
    let g = gen::preferential_attachment(10_000, 4, 2);
    let mut deg = g.total_degrees();
    deg.sort_unstable_by(|a, b| b.cmp(a));
    let top1: u64 = deg[..100].iter().map(|&d| d as u64).sum();
    let total: u64 = deg.iter().map(|&d| d as u64).sum();
    let share = top1 as f64 / total as f64;
    assert!(share > 0.08, "top-1% degree share {share:.3} too small for PA");
}
