//! The conversion determinism contract: `coo_to_csr_parallel` and
//! `coo_to_csr_relabeled_parallel` must equal their sequential
//! counterparts **bit-for-bit** (`row_ptr`, `col_idx`, `vals`) at every
//! pinned worker count, on every input shape — skewed (R-MAT), regular
//! (road grid), weighted, and degenerate. This is the contract that lets
//! the serving registry, the pipeline, and the TC paths use the parallel
//! kernels with no `sort_rows` compensation, and lets `repro` digests
//! compare across `--threads` settings.

use boba::convert::{
    coo_to_csr, coo_to_csr_parallel, coo_to_csr_relabeled, coo_to_csr_relabeled_parallel,
};
use boba::graph::{gen, Coo};
use boba::parallel::ThreadGuard;
use boba::reorder::{boba::Boba, Reorderer};

/// Worker pins the contract is checked under. Pins are process-global,
/// so a concurrently running test may mask the effective count — which
/// is fine: the contract is *thread-count independence*, so the asserts
/// must hold whatever count actually schedules.
const PINS: [usize; 4] = [1, 2, 4, 8];

/// The input lineup: large enough to cross the parallel threshold where
/// it matters, plus the degenerate shapes that exercise the edges of the
/// partitioning (empty edge list, single vertex, all self-loops).
fn lineup() -> Vec<(&'static str, Coo)> {
    let weighted = {
        let mut g = gen::uniform_random(3_000, 40_000, 11);
        g.vals = Some((0..g.m()).map(|i| (i % 17) as f32 * 0.5 - 3.0).collect());
        g
    };
    vec![
        ("rmat", gen::rmat(&gen::GenParams::rmat(12, 16), 7).randomized(3)),
        ("road-grid", gen::grid_road(160, 120, 5).symmetrized().randomized(9)),
        ("weighted", weighted),
        ("empty", Coo::new(5, vec![], vec![])),
        ("single-vertex", Coo::new(1, vec![0, 0], vec![0, 0])),
        ("all-self-loops", Coo::new(64, (0..64).collect(), (0..64).collect())),
    ]
}

#[test]
fn parallel_convert_bit_identical_at_every_pin() {
    for (name, g) in lineup() {
        let reference = coo_to_csr(&g);
        for pin in PINS {
            let guard = ThreadGuard::pin(pin);
            let par = coo_to_csr_parallel(&g);
            drop(guard);
            assert_eq!(
                reference, par,
                "{name}: coo_to_csr_parallel diverged from coo_to_csr at pin {pin}"
            );
        }
    }
}

#[test]
fn parallel_fused_relabel_bit_identical_at_every_pin() {
    for (name, g) in lineup() {
        // A non-trivial relabeling (BOBA's first-appearance order); falls
        // back to the identity-ish order on degenerate inputs, which is
        // exactly the edge case worth pinning.
        let perm = Boba::sequential().reorder(&g);
        let reference = coo_to_csr_relabeled(&g, perm.new_of_old());
        assert_eq!(
            reference,
            coo_to_csr(&g.relabeled(perm.new_of_old())),
            "{name}: fused sequential reference must match relabel-then-convert"
        );
        for pin in PINS {
            let guard = ThreadGuard::pin(pin);
            let par = coo_to_csr_relabeled_parallel(&g, perm.new_of_old());
            drop(guard);
            assert_eq!(
                reference, par,
                "{name}: coo_to_csr_relabeled_parallel diverged at pin {pin}"
            );
        }
    }
}

#[test]
fn weighted_values_follow_columns_exactly() {
    // Beyond multiset equality: the weighted parallel conversion must
    // keep every (col, val) pair in the sequential position.
    let mut g = gen::rmat(&gen::GenParams::rmat(12, 16), 21).randomized(2);
    g.vals = Some((0..g.m()).map(|i| i as f32 * 0.25).collect());
    let seq = coo_to_csr(&g);
    for pin in PINS {
        let _guard = ThreadGuard::pin(pin);
        let par = coo_to_csr_parallel(&g);
        assert_eq!(seq.vals, par.vals, "vals diverged at pin {pin}");
        assert_eq!(seq.col_idx, par.col_idx, "col_idx diverged at pin {pin}");
    }
}

#[test]
fn sorted_input_stays_sorted_through_parallel_convert() {
    // The property the TC/serve paths now rely on instead of sort_rows:
    // stable deterministic scatter of a (src, dst)-sorted COO yields
    // sorted adjacency lists.
    let g = gen::rmat(&gen::GenParams::rmat(12, 16), 31).randomized(17);
    let sorted = boba::convert::sort_coo_by_src(&g.symmetrized().deduped());
    for pin in PINS {
        let _guard = ThreadGuard::pin(pin);
        let csr = coo_to_csr_parallel(&sorted);
        assert!(csr.rows_sorted(), "rows unsorted at pin {pin}");
    }
}
