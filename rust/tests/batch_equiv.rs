//! Batched-query equivalence and single-flight tests — the determinism
//! gate of the batched query engine:
//!
//! * `spmm` output bit-identical to k independent `spmv_pull` calls at
//!   every pinned thread count and batch width;
//! * multi-source frontier SSSP identical to per-source
//!   `sssp_frontier`;
//! * the rebuilt `pagerank_parallel` bit-identical to sequential
//!   `pagerank` at every pinned thread count (the tier-1 pagerank
//!   determinism gate);
//! * `GraphRegistry::get_or_prepare` single-flight: 8 concurrent cold
//!   requesters run the Problem-3 pipeline exactly once;
//! * coalescer shutdown releases parked waiters.

use boba::algos::{pagerank, spmm, spmv, sssp};
use boba::convert::coo_to_csr;
use boba::graph::{gen, Coo};
use boba::parallel::ThreadGuard;
use boba::server::coalesce::{BatchQuery, CoalesceConfig, Coalescer};
use boba::server::registry::{GraphRegistry, RegistryConfig};
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// The equivalence fixtures: scale-free, road-like, degenerate.
fn fixtures() -> Vec<(&'static str, Coo)> {
    let mut weighted = gen::uniform_random(500, 4000, 11);
    weighted.vals = Some((0..4000).map(|i| ((i * 7) % 97) as f32 * 0.125 + 0.25).collect());
    vec![
        ("rmat", gen::rmat(&gen::GenParams::rmat(12, 8), 3).randomized(7)),
        ("road-grid", gen::grid_road(40, 30, 2).symmetrized()),
        ("weighted", weighted),
        ("empty", Coo::new(5, vec![], vec![])),
        ("single-vertex", Coo::new(1, vec![0], vec![0])),
    ]
}

/// Deterministic column-major RHS block.
fn rhs(n: usize, k: usize) -> Vec<f32> {
    (0..k * n)
        .map(|i| ((i as u32).wrapping_mul(2654435761) % 1009) as f32 * 0.01 - 3.0)
        .collect()
}

#[test]
fn spmm_bit_identical_to_k_spmv_calls_at_every_pin() {
    for (name, coo) in fixtures() {
        let csr = coo_to_csr(&coo);
        let n = csr.n();
        for k in [1usize, 2, 7, 16] {
            let x = rhs(n, k);
            let mut want: Vec<f32> = Vec::with_capacity(k * n);
            for j in 0..k {
                want.extend(spmv::spmv_pull(&csr, &x[j * n..(j + 1) * n]));
            }
            for t in [1usize, 2, 4, 8] {
                let _g = ThreadGuard::pin(t);
                assert_eq!(spmm::spmm_pull(&csr, &x, k), want, "{name}: seq k={k} t={t}");
                assert_eq!(
                    spmm::spmm_pull_parallel(&csr, &x, k),
                    want,
                    "{name}: par k={k} t={t}"
                );
            }
        }
    }
}

#[test]
fn multi_source_sssp_identical_to_per_source_at_every_pin() {
    for (name, coo) in fixtures() {
        let csr = coo_to_csr(&coo);
        let n = csr.n();
        for s in [1usize, 2, 7, 16] {
            let sources: Vec<u32> = (0..s).map(|i| ((i * 37 + 1) % n) as u32).collect();
            for t in [1usize, 2, 4, 8] {
                let _g = ThreadGuard::pin(t);
                let d = sssp::sssp_frontier_multi(&csr, &sources);
                for (i, &src) in sources.iter().enumerate() {
                    let want = sssp::sssp_frontier(&csr, src);
                    assert_eq!(
                        &d[i * n..(i + 1) * n],
                        want.as_slice(),
                        "{name}: s={s} source#{i}={src} t={t}"
                    );
                }
            }
        }
    }
}

#[test]
fn pagerank_parallel_bit_identical_to_sequential_at_every_pin() {
    // n = 2^15 clears the 2^14 fallback threshold, so the parallel
    // kernel genuinely runs at pins > 1.
    let g = gen::rmat(&gen::GenParams::rmat(15, 8), 5).randomized(6);
    let csr = coo_to_csr(&g);
    let p = pagerank::PrParams { max_iters: 20, ..Default::default() };
    let want = pagerank::pagerank(&csr, p);
    for t in [1usize, 2, 4, 8] {
        let _g = ThreadGuard::pin(t);
        let got = pagerank::pagerank_parallel(&csr, p);
        assert_eq!(got.iters, want.iters, "iteration count must match at t={t}");
        assert_eq!(
            got.ranks, want.ranks,
            "pagerank_parallel must be bit-identical to pagerank at t={t}"
        );
    }
}

fn registry() -> GraphRegistry {
    GraphRegistry::new(RegistryConfig { capacity: 4, batch: 1000, in_flight: 2, seed: 17 })
}

#[test]
fn registry_hammer_eight_cold_requesters_one_prepare() {
    let r = Arc::new(registry());
    let barrier = Arc::new(Barrier::new(8));
    let mut handles = Vec::new();
    for _ in 0..8 {
        let r = r.clone();
        let b = barrier.clone();
        handles.push(std::thread::spawn(move || {
            b.wait();
            r.get_or_prepare("pa:4000:4", "boba").unwrap()
        }));
    }
    let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(r.prepares(), 1, "8 concurrent cold requesters must run ONE pipeline");
    assert_eq!(
        outs.iter().filter(|(_, cached)| !cached).count(),
        1,
        "exactly one leader reports a fresh prepare"
    );
    for (g, _) in &outs {
        assert!(Arc::ptr_eq(g, &outs[0].0), "every requester shares the one artifact");
    }
    // Miss-counter discipline: the leader is the only miss; the seven
    // waiters landed on the shared result and count as hits.
    let stats = r.stats_json();
    assert_eq!(stats.get("misses").unwrap().as_u64(), Some(1), "waiters must not count as misses");
    assert_eq!(stats.get("hits").unwrap().as_u64(), Some(7));
}

#[test]
fn registry_failed_prepare_releases_waiters_and_stays_retryable() {
    let r = Arc::new(registry());
    let barrier = Arc::new(Barrier::new(4));
    let mut handles = Vec::new();
    for _ in 0..4 {
        let r = r.clone();
        let b = barrier.clone();
        handles.push(std::thread::spawn(move || {
            b.wait();
            r.get_or_prepare("pa:1000:4", "definitely-not-a-scheme")
        }));
    }
    for h in handles {
        assert!(h.join().unwrap().is_err(), "every requester sees the prepare failure");
    }
    assert_eq!(r.prepares(), 1, "the failing pipeline also runs once");
    assert_eq!(r.len(), 0, "failures cache nothing");
    // The key is immediately retryable with a valid scheme.
    assert!(r.get_or_prepare("pa:1000:4", "boba").is_ok());
}

#[test]
fn coalescer_shutdown_releases_parked_waiters() {
    let r = registry();
    let (graph, _) = r.get_or_prepare("pa:2000:4", "none").unwrap();
    // A 60 s window parks the leader (and followers) until shutdown.
    let co = Arc::new(Coalescer::new(CoalesceConfig {
        window: Duration::from_secs(60),
        max_batch: 16,
    }));
    let mut handles = Vec::new();
    for i in 0..4u64 {
        let co = co.clone();
        let graph = graph.clone();
        handles.push(std::thread::spawn(move || {
            co.submit(&graph, BatchQuery::Spmv { seed: Some(i) })
        }));
    }
    std::thread::sleep(Duration::from_millis(150));
    co.shutdown();
    for h in handles {
        assert!(
            h.join().unwrap().is_err(),
            "shutdown must release every parked waiter with an error"
        );
    }
    assert!(
        co.submit(&graph, BatchQuery::Spmv { seed: None }).is_err(),
        "post-shutdown submissions are refused"
    );
}

#[test]
fn coalesced_batches_answer_exactly_like_single_queries() {
    let r = registry();
    let (graph, _) = r.get_or_prepare("pa:2500:4", "boba").unwrap();
    let co = Arc::new(Coalescer::new(CoalesceConfig {
        window: Duration::from_millis(40),
        max_batch: 16,
    }));
    let mut handles = Vec::new();
    for i in 0..8u64 {
        let co = co.clone();
        let graph = graph.clone();
        handles.push(std::thread::spawn(move || {
            let source = (i * 311) as u32 % graph.csr.n() as u32;
            (source, co.submit(&graph, BatchQuery::Sssp { source }).unwrap())
        }));
    }
    for h in handles {
        let (source, (out, width)) = h.join().unwrap();
        let boba::server::coalesce::BatchOut::Sssp { digest, reached } = out else {
            panic!("wrong answer kind");
        };
        let d = sssp::sssp_frontier(&graph.csr, source);
        let want: f64 = d.iter().filter(|v| v.is_finite()).map(|&v| v as f64).sum();
        assert_eq!(digest, want, "coalescing must not change the sssp digest (src {source})");
        assert_eq!(reached, d.iter().filter(|v| v.is_finite()).count());
        assert!((1..=16).contains(&width));
    }
    assert_eq!(co.sssp_widths().queries(), 8);
}
