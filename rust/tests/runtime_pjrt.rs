#![cfg(feature = "pjrt")]

//! PJRT runtime integration: load the AOT artifacts, execute both SpMV
//! variants and PageRank, validate against native kernels. Requires
//! `make artifacts` (tests are skipped with a notice when artifacts are
//! absent, e.g. in a fresh checkout).

use boba::algos::{pagerank, spmv};
use boba::convert::coo_to_csr;
use boba::graph::gen;
use boba::runtime::{ell::EllPlan, Engine, SpmvKind};

/// Fresh engine per test — `Engine` is deliberately not Send/Sync (the
/// xla crate's PJRT handles are Rc-based), and each test runs on its own
/// thread.
fn engine() -> Option<Engine> {
    match Engine::load_default() {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping PJRT test (run `make artifacts`): {err:#}");
            None
        }
    }
}

#[test]
fn spmv_jnp_matches_native() {
    let Some(engine) = engine() else { return };
    let engine = &engine;
    let g = gen::preferential_attachment(5000, 4, 1).randomized(2);
    let csr = coo_to_csr(&g);
    let x: Vec<f32> = (0..csr.n()).map(|i| (i % 13) as f32 * 0.5).collect();
    let y_pjrt = engine.spmv_csr(SpmvKind::Jnp, &csr, &x).unwrap();
    let y_native = spmv::spmv_pull(&csr, &x);
    for (a, b) in y_pjrt.iter().zip(&y_native) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
    }
}

#[test]
fn spmv_pallas_matches_jnp() {
    let Some(engine) = engine() else { return };
    let engine = &engine;
    let g = gen::uniform_random(3000, 20_000, 3);
    let csr = coo_to_csr(&g);
    let x: Vec<f32> = (0..csr.n()).map(|i| 1.0 + (i % 7) as f32).collect();
    let plan = EllPlan::pack(&csr, engine.meta).unwrap();
    let a = plan.execute(engine, SpmvKind::Jnp, &x).unwrap();
    let b = plan.execute(engine, SpmvKind::Pallas, &x).unwrap();
    assert_eq!(a.len(), b.len());
    for (x0, x1) in a.iter().zip(&b) {
        assert!((x0 - x1).abs() <= 1e-4 * x0.abs().max(1.0), "{x0} vs {x1}");
    }
}

#[test]
fn spmv_weighted_matches_native() {
    let Some(engine) = engine() else { return };
    let engine = &engine;
    let mut g = gen::uniform_random(2000, 12_000, 5);
    g.vals = Some((0..g.m()).map(|i| (i % 5) as f32 - 2.0).collect());
    let csr = coo_to_csr(&g);
    let x = vec![1.5f32; csr.n()];
    let y_pjrt = engine.spmv_csr(SpmvKind::Jnp, &csr, &x).unwrap();
    let y_native = spmv::spmv_pull(&csr, &x);
    for (a, b) in y_pjrt.iter().zip(&y_native) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0) + 1e-4, "{a} vs {b}");
    }
}

#[test]
fn spmv_handles_n_larger_than_tile() {
    let Some(engine) = engine() else { return };
    let engine = &engine;
    // n spans multiple tiles AND multiple column segments.
    let n = engine.meta.n_tile * 2 + 123;
    let g = gen::uniform_random(n, n * 4, 7);
    let csr = coo_to_csr(&g);
    let x = vec![1.0f32; n];
    let y = engine.spmv_csr(SpmvKind::Jnp, &csr, &x).unwrap();
    let y_native = spmv::spmv_pull(&csr, &x);
    assert_eq!(y.len(), n);
    for (a, b) in y.iter().zip(&y_native) {
        assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
    }
}

#[test]
fn spmv_high_degree_rows_span_passes() {
    let Some(engine) = engine() else { return };
    let engine = &engine;
    // One row with degree 5*k forces multiple ELL passes.
    let k = engine.meta.k;
    let deg = 5 * k + 3;
    let mut src = vec![0u32; deg];
    let dst: Vec<u32> = (1..=deg as u32).collect();
    src.push(1);
    let mut dst = dst;
    dst.push(0);
    let n = deg + 2;
    let g = boba::graph::Coo::new(n, src, dst);
    let csr = coo_to_csr(&g);
    let plan = EllPlan::pack(&csr, engine.meta).unwrap();
    assert!(plan.passes() >= 6, "expected ≥6 passes, got {}", plan.passes());
    let x = vec![1.0f32; n];
    let y = plan.execute(engine, SpmvKind::Jnp, &x).unwrap();
    assert_eq!(y[0], deg as f32);
}

#[test]
fn pagerank_pjrt_matches_native() {
    let Some(engine) = engine() else { return };
    let engine = &engine;
    let g = gen::preferential_attachment(4000, 4, 9).randomized(1);
    let csr = coo_to_csr(&g);
    let plan = EllPlan::pack_pagerank(&csr, engine.meta).unwrap();
    let (ranks, iters) = engine.pagerank(&plan, csr.n(), 0.85, 25, 0.0).unwrap();
    let native = pagerank::pagerank(
        &csr,
        pagerank::PrParams { max_iters: 25, tol: 0.0, damping: 0.85 },
    );
    assert_eq!(iters, 25);
    let max_diff = ranks
        .iter()
        .zip(&native.ranks)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-5, "max diff {max_diff}");
    let mass: f32 = ranks.iter().sum();
    assert!((mass - 1.0).abs() < 1e-3, "mass {mass}");
}

#[test]
fn pagerank_pjrt_handles_dangling() {
    let Some(engine) = engine() else { return };
    let engine = &engine;
    // Chain with a dangling tail.
    let g = boba::graph::Coo::new(4, vec![0, 1, 2], vec![1, 2, 3]);
    let csr = coo_to_csr(&g);
    let plan = EllPlan::pack_pagerank(&csr, engine.meta).unwrap();
    let (ranks, _) = engine.pagerank(&plan, 4, 0.85, 40, 1e-7).unwrap();
    let native = pagerank::pagerank(
        &csr,
        pagerank::PrParams { max_iters: 40, tol: 1e-7 / 4.0, damping: 0.85 },
    );
    for (a, b) in ranks.iter().zip(&native.ranks) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn engine_reports_platform() {
    let Some(engine) = engine() else { return };
    let engine = &engine;
    assert_eq!(engine.platform(), "cpu");
    assert!(engine.meta.n_tile >= 512);
    assert!(engine.meta.k >= 1);
}
