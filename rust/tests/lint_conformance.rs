//! Conformance suite for `boba lint`: one fixture pair per rule (the
//! violation fires; the documented remedy silences it), the escape
//! hatch grammar, masking soundness (strings/comments never
//! false-positive), and the capstone — the real tree is clean.

use boba::analysis::{self, LintInput, SourceFile};
use std::path::Path;

fn src(path: &str, text: &str) -> SourceFile {
    SourceFile { path: path.to_string(), text: text.to_string() }
}

fn input(sources: Vec<SourceFile>) -> LintInput {
    LintInput { sources, ci_sh: None, architecture_md: None }
}

fn rules_fired(input: &LintInput) -> Vec<String> {
    analysis::lint(input).into_iter().map(|v| v.rule).collect()
}

// ---- unsafe-safety ----

#[test]
fn unsafe_outside_whitelist_and_without_safety_comment_fires() {
    let v = analysis::lint(&input(vec![src(
        "graph/mod.rs",
        "pub fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n",
    )]));
    // both facets fire: wrong module AND no SAFETY comment
    assert_eq!(v.len(), 2, "{}", analysis::render_table(&v));
    assert!(v.iter().all(|x| x.rule == "unsafe-safety" && x.line == 2));
}

#[test]
fn unsafe_with_safety_comment_in_whitelisted_module_passes() {
    let v = analysis::lint(&input(vec![src(
        "obs/ring.rs",
        "pub fn f(p: *const u32) -> u32 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn rustdoc_safety_section_counts_for_unsafe_fns() {
    // `# Safety` in the doc comment above an `unsafe fn` is the idiom
    // rustdoc itself expects; the rule accepts it as the annotation.
    let v = analysis::lint(&input(vec![src(
        "parallel/mod.rs",
        "/// Reads through the pointer.\n///\n/// # Safety\n/// `p` must be valid for reads.\npub unsafe fn f(p: *const u32) -> u32 {\n    *p\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn safety_comment_reaches_over_statement_continuation_lines() {
    // The annotation sits above the statement; the `unsafe` token is on
    // a continuation line (the statement opened with `=` above it).
    let v = analysis::lint(&input(vec![src(
        "obs/ring.rs",
        "pub fn f(p: *const u32) -> u32 {\n    // SAFETY: caller guarantees p is valid.\n    let x =\n        unsafe { *p };\n    x\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

// ---- raw-spawn ----

#[test]
fn raw_spawn_outside_pool_fires() {
    let v = analysis::lint(&input(vec![src(
        "coordinator/mod.rs",
        "use std::thread;\npub fn go() {\n    thread::spawn(|| {});\n}\n",
    )]));
    assert_eq!(v.len(), 1, "{}", analysis::render_table(&v));
    assert_eq!(v[0].rule, "raw-spawn");
    assert_eq!(v[0].line, 3);
}

#[test]
fn raw_spawn_in_whitelisted_file_or_test_passes() {
    let v = analysis::lint(&input(vec![
        src("parallel/pool.rs", "use std::thread;\npub fn go() {\n    thread::spawn(|| {});\n}\n"),
        src(
            "coordinator/mod.rs",
            "#[cfg(test)]\nmod tests {\n    use std::thread;\n    #[test]\n    fn t() {\n        thread::spawn(|| {}).join().ok();\n    }\n}\n",
        ),
    ]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

// ---- panic-path ----

#[test]
fn unwrap_on_request_path_fires() {
    let fired = rules_fired(&input(vec![src(
        "server/router.rs",
        "pub fn handle(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
    )]));
    assert_eq!(fired, vec!["panic-path"]);
}

#[test]
fn lock_poisoning_unwrap_is_exempt() {
    // Unwrapping a Mutex/Condvar result propagates a *prior* panic —
    // the carve-out the rule documents.
    let v = analysis::lint(&input(vec![src(
        "server/router.rs",
        "use std::sync::Mutex;\npub fn peek(m: &Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn panic_in_test_block_of_request_path_file_passes() {
    let v = analysis::lint(&input(vec![src(
        "server/wal.rs",
        "pub fn ok() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1u32).unwrap();\n        panic!(\"only in tests\");\n    }\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn non_request_path_files_may_unwrap() {
    let v = analysis::lint(&input(vec![src(
        "coordinator/experiments.rs",
        "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

// ---- atomic-ordering ----

#[test]
fn acquire_without_ordering_comment_fires() {
    let fired = rules_fired(&input(vec![src(
        "graph/mod.rs",
        "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    a.load(Ordering::Acquire)\n}\n",
    )]));
    assert_eq!(fired, vec!["atomic-ordering"]);
}

#[test]
fn ordering_comment_silences_the_rule() {
    let v = analysis::lint(&input(vec![src(
        "graph/mod.rs",
        "use std::sync::atomic::{AtomicUsize, Ordering};\npub fn f(a: &AtomicUsize) -> usize {\n    // ordering: pairs with the Release store in publish().\n    a.load(Ordering::Acquire)\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn relaxed_counter_whitelist_needs_no_annotation() {
    let v = analysis::lint(&input(vec![src(
        "obs/hist.rs",
        "use std::sync::atomic::{AtomicU64, Ordering};\npub fn bump(c: &AtomicU64) {\n    c.fetch_add(1, Ordering::Relaxed);\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn relaxed_outside_counter_whitelist_still_needs_annotation() {
    let fired = rules_fired(&input(vec![src(
        "graph/mod.rs",
        "use std::sync::atomic::{AtomicU64, Ordering};\npub fn bump(c: &AtomicU64) {\n    c.fetch_add(1, Ordering::Relaxed);\n}\n",
    )]));
    assert_eq!(fired, vec!["atomic-ordering"]);
}

#[test]
fn std_cmp_ordering_never_matches() {
    // `Ordering::Less` is std::cmp, not atomics — must not fire.
    let v = analysis::lint(&input(vec![src(
        "graph/mod.rs",
        "use std::cmp::Ordering;\npub fn f(a: u32, b: u32) -> bool {\n    a.cmp(&b) == Ordering::Less\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

// ---- the allow escape hatch ----

#[test]
fn allow_with_reason_suppresses_named_rule_on_next_code_line() {
    let v = analysis::lint(&input(vec![src(
        "coordinator/mod.rs",
        "use std::thread;\npub fn go() {\n    // lint: allow(raw-spawn): long-running I/O thread, not kernel work.\n    thread::spawn(|| {});\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn allow_suppression_spans_its_comment_block() {
    // A multi-line justification: the allow is on the first comment
    // line, the violation two comment lines further down.
    let v = analysis::lint(&input(vec![src(
        "coordinator/mod.rs",
        "use std::thread;\npub fn go() {\n    // lint: allow(raw-spawn): this producer blocks on a bounded\n    // channel for its whole life; parking it on the pool would\n    // deadlock the helper-barrier dispatch model.\n    thread::spawn(|| {});\n}\n",
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn allow_without_reason_is_rejected_and_does_not_suppress() {
    let fired = rules_fired(&input(vec![src(
        "coordinator/mod.rs",
        "use std::thread;\npub fn go() {\n    // lint: allow(raw-spawn)\n    thread::spawn(|| {});\n}\n",
    )]));
    // the bare allow is itself a violation AND the spawn still fires
    assert!(fired.contains(&"allow-syntax".to_string()), "{fired:?}");
    assert!(fired.contains(&"raw-spawn".to_string()), "{fired:?}");
}

#[test]
fn allow_naming_unknown_rule_is_rejected() {
    let fired = rules_fired(&input(vec![src(
        "coordinator/mod.rs",
        "// lint: allow(no-such-rule): whatever\npub fn f() {}\n",
    )]));
    assert_eq!(fired, vec!["allow-syntax"]);
}

#[test]
fn allow_only_suppresses_the_named_rule() {
    // An allow(panic-path) does nothing for a raw-spawn finding.
    let fired = rules_fired(&input(vec![src(
        "coordinator/mod.rs",
        "use std::thread;\npub fn go() {\n    // lint: allow(panic-path): wrong rule named here.\n    thread::spawn(|| {});\n}\n",
    )]));
    assert_eq!(fired, vec!["raw-spawn"]);
}

// ---- masking soundness ----

#[test]
fn tokens_inside_strings_and_comments_never_fire() {
    let v = analysis::lint(&input(vec![src(
        "graph/mod.rs",
        concat!(
            "// unsafe thread::spawn .unwrap() Ordering::Acquire — all in a comment\n",
            "pub fn f() -> String {\n",
            "    let a = \"unsafe { thread::spawn }\";\n",
            "    let b = r#\"x.unwrap() panic! Ordering::SeqCst\"#;\n",
            "    /* unreachable! in a block comment */\n",
            "    format!(\"{a}{b}\")\n",
            "}\n",
        ),
    )]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

// ---- ablation-reach ----

#[test]
fn atomic_kernel_referenced_outside_repro_fires() {
    let fired = rules_fired(&input(vec![
        src("algos/pagerank.rs", "pub fn pagerank_atomic() {}\n"),
        src("coordinator/pipeline.rs", "pub fn run() {\n    crate::algos::pagerank::pagerank_atomic();\n}\n"),
    ]));
    assert_eq!(fired, vec!["ablation-reach"]);
}

#[test]
fn atomic_kernel_reachable_from_repro_and_tests_passes() {
    let v = analysis::lint(&input(vec![
        src("algos/pagerank.rs", "pub fn pagerank_atomic() {}\n"),
        src("coordinator/repro.rs", "pub fn t4() {\n    crate::algos::pagerank::pagerank_atomic();\n}\n"),
        src(
            "metrics/mod.rs",
            "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        crate::algos::pagerank::pagerank_atomic();\n    }\n}\n",
        ),
    ]));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

// ---- metrics-drift ----

fn metrics_fixture(ci_gate: &str, doc_row: &str) -> LintInput {
    LintInput {
        sources: vec![src(
            "server/router.rs",
            "pub fn expose(p: &mut crate::obs::Page) {\n    p.family(\"boba_x_total\", \"counter\");\n}\n",
        )],
        ci_sh: Some(format!("#!/bin/sh\nfor fam in {ci_gate}; do\n  grep -q \"^$fam\" m.txt\ndone\n")),
        architecture_md: Some(format!(
            "# Arch\n\n<!-- lint:metrics-families:begin -->\n| family | type |\n|---|---|\n{doc_row}\n<!-- lint:metrics-families:end -->\n",
        )),
    }
}

#[test]
fn matching_code_ci_and_docs_pass() {
    // the fixture's Page type doesn't exist, but the linter is lexical
    let v = analysis::lint(&metrics_fixture("boba_x_total", "| `boba_x_total` | counter |"));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn family_missing_from_ci_gate_fires() {
    let v = analysis::lint(&metrics_fixture("", "| `boba_x_total` | counter |"));
    assert_eq!(v.len(), 1, "{}", analysis::render_table(&v));
    assert_eq!(v[0].rule, "metrics-drift");
    assert_eq!(v[0].file, "ci.sh");
}

#[test]
fn docs_row_for_unemitted_family_fires() {
    let v = analysis::lint(&metrics_fixture(
        "boba_x_total",
        "| `boba_x_total` | counter |\n| `boba_ghost_total` | counter |",
    ));
    assert_eq!(v.len(), 1, "{}", analysis::render_table(&v));
    assert_eq!(v[0].rule, "metrics-drift");
    assert_eq!(v[0].file, "docs/ARCHITECTURE.md");
}

#[test]
fn doc_label_and_param_suffixes_are_stripped() {
    let v = analysis::lint(&metrics_fixture("boba_x_total", "| `boba_x_total{kind}` | counter |"));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

// ---- chaos-drift ----

fn chaos_fixture(points: &str, doc_rows: &str) -> LintInput {
    LintInput {
        sources: vec![src(
            "obs/chaos.rs",
            format!("const KNOWN_POINTS: &[&str] = &[{points}];\n").as_str(),
        )],
        ci_sh: None,
        // the metrics table is present-but-empty: no fixture source
        // emits a family, so it stays consistent and out of the way
        architecture_md: Some(format!(
            "# Arch\n\n<!-- lint:metrics-families:begin -->\n<!-- lint:metrics-families:end -->\n\n<!-- lint:chaos-points:begin -->\n| point | effect |\n|---|---|\n{doc_rows}\n<!-- lint:chaos-points:end -->\n",
        )),
    }
}

#[test]
fn chaos_points_matching_fault_table_pass() {
    let v = analysis::lint(&chaos_fixture(
        "\"conn-drop\", \"wal-io-error\", \"test-point\"",
        "| `conn-drop` | closes the socket |\n| `wal-io-error` | fails the append |",
    ));
    assert!(v.is_empty(), "{}", analysis::render_table(&v));
}

#[test]
fn undocumented_chaos_point_fires() {
    let v = analysis::lint(&chaos_fixture(
        "\"conn-drop\", \"wal-io-error\"",
        "| `conn-drop` | closes the socket |",
    ));
    assert_eq!(v.len(), 1, "{}", analysis::render_table(&v));
    assert_eq!(v[0].rule, "chaos-drift");
}

#[test]
fn fault_table_row_without_a_point_fires() {
    let v = analysis::lint(&chaos_fixture(
        "\"conn-drop\"",
        "| `conn-drop` | closes the socket |\n| `ghost-fault` | nothing |",
    ));
    assert_eq!(v.len(), 1, "{}", analysis::render_table(&v));
    assert_eq!(v[0].rule, "chaos-drift");
}

// ---- output formats ----

#[test]
fn json_document_shape() {
    let v = analysis::lint(&input(vec![src(
        "server/router.rs",
        "pub fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
    )]));
    let doc = boba::util::Json::parse(&analysis::render_json(&v)).expect("valid JSON");
    assert_eq!(doc.get("version").and_then(|j| j.as_str()), Some("boba-lint/1"));
    assert_eq!(doc.get("count").and_then(|j| j.as_u64()), Some(1));
}

// ---- the capstone: the real tree is clean ----

#[test]
fn real_tree_has_zero_violations() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf();
    let input = analysis::load_tree(&root).expect("tree loads");
    assert!(input.sources.len() > 40, "tree walk found only {} files", input.sources.len());
    assert!(input.ci_sh.is_some(), "ci.sh missing");
    assert!(input.architecture_md.is_some(), "docs/ARCHITECTURE.md missing");
    let v = analysis::lint(&input);
    assert!(v.is_empty(), "the tree must lint clean:\n{}", analysis::render_table(&v));
}
