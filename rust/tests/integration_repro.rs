//! End-to-end tests of the `boba repro` harness on tiny generated
//! datasets: schema validity of the emitted JSON, coverage of all five
//! repro tables, markdown rendering, and the determinism claim — pinned
//! worker-thread count must not change the permutation a deterministic
//! scheme produces.
//!
//! Determinism carve-outs: the **only** remaining exemption is the
//! `boba` parallel *reordering* variant, whose racy min records are the
//! paper's published Algorithm 3 (the GPU kernel deliberately skips
//! AtomicMin; `boba-atomic` restores exactness and is asserted equal to
//! `boba-seq`). Every *kernel* in the serve/repro path — the parallel
//! converter, the parallel ingest, `spmm`, multi-source SSSP, and since
//! the batched query engine also `pagerank_parallel` — is bit-identical
//! to its sequential form at every thread count (`determinism_convert`,
//! `golden_io`, and `batch_equiv` are the tier-1 gates).

use boba::bench::results::ResultsDoc;
use boba::coordinator::repro::{self, ReproOptions};

/// Tiny inputs so the full T1–T5 sweep stays CI-sized.
fn tiny_opts(seed: u64) -> ReproOptions {
    let mut opts = ReproOptions::quick(seed);
    opts.dataset_specs = vec!["rmat:10:4".into(), "grid:40:30".into()];
    opts.reps = 2;
    opts.warmup = 0;
    opts.pr_iters = 5;
    opts
}

#[test]
fn repro_covers_all_tables_with_valid_schema() {
    let run = repro::run(&tiny_opts(42)).unwrap();
    let doc = &run.doc;

    // All five tables, ≥ 3 reorder schemes (the acceptance bar).
    assert_eq!(doc.tables(), vec!["T1", "T2", "T3", "T4", "T5"]);
    let schemes = doc.schemes();
    assert!(schemes.len() >= 3, "schemes: {schemes:?}");
    for s in ["boba", "boba-seq", "boba-atomic", "degree", "hub", "random"] {
        assert!(schemes.iter().any(|x| x == s), "missing scheme {s}: {schemes:?}");
    }

    // T1 rows carry digests and positive medians.
    let t1 = doc.get("T1", "rmat:10:4", "boba", "reorder_ms").unwrap();
    assert!(t1.digest.is_some());
    assert!(t1.summary.median_ms >= 0.0);
    assert!(t1.summary.min_ms <= t1.summary.median_ms);
    assert!(t1.summary.median_ms <= t1.summary.max_ms);
    assert_eq!(t1.summary.n, 2, "reps honoured");

    // T2 has the pre/post contrast across the sequential, deterministic
    // parallel (par-det), and atomic-baseline kernels, plus the fused
    // paths and a speedup.
    for metric in ["convert_seq_ms", "convert_par_det_ms", "convert_par_atomic_ms"] {
        assert!(doc.get("T2", "rmat:10:4", "random", metric).is_some(), "{metric}");
        assert!(doc.get("T2", "rmat:10:4", "boba", metric).is_some(), "{metric}");
    }
    assert!(doc.get("T2", "rmat:10:4", "boba", "convert_fused_ms").is_some());
    assert!(doc.get("T2", "rmat:10:4", "boba", "convert_fused_par_ms").is_some());
    assert!(doc.get("T2", "rmat:10:4", "boba", "convert_speedup_x").is_some());
    // The determinism gate: par-det rows carry the same output digest as
    // the sequential rows (the harness itself errors on a mismatch; this
    // pins the contract in the committed JSON too).
    for dataset in ["rmat:10:4", "grid:40:30"] {
        for scheme in ["random", "boba"] {
            let seq = doc.get("T2", dataset, scheme, "convert_seq_ms").unwrap();
            let det = doc.get("T2", dataset, scheme, "convert_par_det_ms").unwrap();
            assert!(seq.digest.is_some(), "{dataset}/{scheme} seq digest missing");
            assert_eq!(
                seq.digest, det.digest,
                "{dataset}/{scheme}: par-det digest must equal the sequential digest"
            );
        }
    }

    // T3 prices the ingest stage once per dataset (schema
    // boba-repro/2): generated specs through the batched
    // StreamingIngest assembly, file specs through a disk re-load.
    for dataset in ["rmat:10:4", "grid:40:30"] {
        let ing = doc
            .get("T3", dataset, "", "ingest_ms")
            .unwrap_or_else(|| panic!("no T3 ingest_ms row for {dataset}"));
        assert!(ing.summary.median_ms >= 0.0);
        assert!(ing.items_per_sec.unwrap_or(0.0) > 0.0, "ingest throughput recorded");
    }

    // T3 prices the batched SpMV the serving coalescer runs: spmm rows
    // at k ∈ {1, 4, 8} for the random baseline and the BOBA ordering.
    for dataset in ["rmat:10:4", "grid:40:30"] {
        for scheme in ["random", "boba"] {
            for k in [1u32, 4, 8] {
                let rec = doc
                    .get("T3", dataset, scheme, &format!("spmm_k{k}_ms"))
                    .unwrap_or_else(|| panic!("no T3 spmm_k{k}_ms row for {dataset}/{scheme}"));
                assert!(rec.summary.median_ms >= 0.0);
                assert!(rec.items_per_sec.unwrap_or(0.0) > 0.0, "spmm throughput recorded");
                assert_eq!(rec.app, "SpMV");
            }
        }
    }

    // T3 covers all four apps with totals and a speedup per scheme.
    for app in ["SpMV", "PR", "TC", "SSSP"] {
        let total = doc
            .records
            .iter()
            .find(|r| r.table == "T3" && r.app == app && r.scheme == "boba"
                && r.metric == "total_ms")
            .unwrap_or_else(|| panic!("no T3 total for {app}"));
        assert!(total.summary.median_ms > 0.0);
        assert!(doc
            .records
            .iter()
            .any(|r| r.table == "T3" && r.app == app && r.metric == "speedup_x"));
    }

    // T4 hit rates are percentages.
    let t4: Vec<_> = doc.records.iter().filter(|r| r.table == "T4").collect();
    assert!(!t4.is_empty());
    for r in &t4 {
        assert!(
            (0.0..=100.0).contains(&r.summary.median_ms),
            "{}/{}/{} = {}",
            r.dataset,
            r.scheme,
            r.metric,
            r.summary.median_ms
        );
    }

    // T5 reports every kernel format per scheme with the full metric
    // set, plus one machine roofline row.
    let stream = doc.get("T5", "", "", "stream_gbs").expect("stream roofline row");
    assert!(stream.summary.median_ms > 0.0, "stream GB/s must be positive");
    for dataset in ["rmat:10:4", "grid:40:30"] {
        for scheme in ["random", "boba"] {
            for fmt in ["csr", "delta", "sell", "tiled", "ell"] {
                for metric in ["bytes_per_edge", "encode_ms", "spmv_ms", "effective_gbs"] {
                    let rec = doc
                        .records
                        .iter()
                        .find(|r| r.table == "T5" && r.dataset == dataset
                            && r.scheme == scheme && r.app == fmt && r.metric == metric)
                        .unwrap_or_else(|| {
                            panic!("no T5 {metric} row for {dataset}/{scheme}/{fmt}")
                        });
                    assert!(
                        rec.summary.median_ms >= 0.0,
                        "{dataset}/{scheme}/{fmt}/{metric} negative"
                    );
                }
            }
        }
        // Plain CSR streams exactly 4 column bytes per edge; delta never
        // exceeds it (the narrow rule is span ≤ 65535 *and* ≥ 2 edges).
        let bpe = |scheme: &str, fmt: &str| {
            doc.records
                .iter()
                .find(|r| r.table == "T5" && r.dataset == dataset && r.scheme == scheme
                    && r.app == fmt && r.metric == "bytes_per_edge")
                .unwrap()
                .summary
                .median_ms
        };
        assert!((bpe("random", "csr") - 4.0).abs() < 1e-9, "{dataset}: csr != 4 B/edge");
        for scheme in ["random", "boba"] {
            assert!(
                bpe(scheme, "delta") <= 4.0 + 1e-9,
                "{dataset}/{scheme}: delta exceeds plain CSR"
            );
        }
        // The acceptance bar: BOBA's locality never loses to the random
        // baseline on the delta encoding (equality is allowed — at quick
        // scale n < 65536 makes every block narrow under any labeling).
        assert!(
            bpe("boba", "delta") <= bpe("random", "delta") + 1e-9,
            "{dataset}: boba delta bytes/edge worse than random"
        );
    }

    // The emitted JSON round-trips through the strict parser.
    let text = doc.to_json().render();
    let back = ResultsDoc::parse(&text).expect("BENCH_repro.json must be schema-valid");
    assert_eq!(back.records.len(), doc.records.len());
    assert_eq!(back.seed, 42);

    // The markdown page renders every table from the same records.
    let md = doc.render_markdown();
    for t in ["## T1", "## T2", "## T3", "## T4", "## T5"] {
        assert!(md.contains(t), "markdown missing {t}");
    }
    assert!(md.contains("boba repro"), "regeneration hint present");

    // The console rendering names every table too.
    for t in ["T1 —", "T2 —", "T3 —", "T4 —", "T5 —"] {
        assert!(run.console.contains(t), "console missing {t}");
    }
}

#[test]
fn thread_count_does_not_change_deterministic_digests() {
    // `repro --threads 1` and `--threads N` must agree on every
    // deterministic scheme's permutation digest. `boba` (the racy
    // Algorithm-3 variant) is exempt by design: the paper's GPU kernel
    // deliberately skips AtomicMin, and `boba-atomic` is the variant
    // that restores exact first-appearance order.
    let mut opts = tiny_opts(7);
    opts.tables = vec!["T1".into()];

    let digests = |threads: usize| {
        let mut o = opts.clone();
        o.threads = Some(threads);
        let run = repro::run(&o).unwrap();
        assert_eq!(run.doc.threads, threads, "pinned thread count recorded");
        run.doc
            .records
            .iter()
            .map(|r| ((r.dataset.clone(), r.scheme.clone()), r.digest.clone().unwrap()))
            .collect::<std::collections::BTreeMap<_, _>>()
    };
    let one = digests(1);
    let four = digests(4);
    assert_eq!(one.len(), four.len());
    for ((dataset, scheme), d1) in &one {
        if scheme == "boba" {
            continue; // racy by design; not a determinism claim
        }
        let d4 = &four[&(dataset.clone(), scheme.clone())];
        assert_eq!(
            d1, d4,
            "{scheme} on {dataset}: digest differs between 1 and 4 threads"
        );
    }
    // The atomic-min parallel variant recovers the sequential order
    // exactly (paper §4.3) — same digest as Algorithm 2, at any width.
    for dataset in ["rmat:10:4", "grid:40:30"] {
        assert_eq!(
            one[&(dataset.to_string(), "boba-seq".to_string())],
            four[&(dataset.to_string(), "boba-atomic".to_string())],
            "{dataset}: boba-atomic must equal boba-seq"
        );
    }
}

#[test]
fn t2_determinism_gate_exercises_the_parallel_kernel() {
    // The tiny datasets above sit below the 1<<15-edge threshold where
    // coo_to_csr_parallel falls back to the sequential kernel — there
    // the digest gate compares sequential against itself. This run uses
    // a 65_536-edge graph with a pinned multi-worker count, so the
    // deterministic parallel kernel really executes and t2_conversion's
    // internal bail (par-det digest != sequential digest) is live.
    let mut opts = ReproOptions::quick(11);
    opts.dataset_specs = vec!["rmat:13:8".into()];
    opts.tables = vec!["T2".into()];
    opts.threads = Some(4);
    opts.reps = 1;
    opts.warmup = 0;
    let run = repro::run(&opts).expect("par-det digest must match sequential");
    for scheme in ["random", "boba"] {
        let seq = run.doc.get("T2", "rmat:13:8", scheme, "convert_seq_ms").unwrap();
        let det = run.doc.get("T2", "rmat:13:8", scheme, "convert_par_det_ms").unwrap();
        assert!(seq.digest.is_some(), "{scheme}: seq digest missing");
        assert_eq!(seq.digest, det.digest, "{scheme}: par-det digest diverged");
    }
}

#[test]
fn t3_file_spec_ingest_prices_the_bcoo_sidecar() {
    // A file-spec dataset: build_datasets' first text parse writes the
    // `.bcoo` sidecar, so the T3 ingest stage prices the binary-cache
    // hit — and the row must land in the document like any other.
    use boba::graph::io::{self, bcoo};
    let g = boba::graph::gen::preferential_attachment(300, 4, 5);
    let path = std::env::temp_dir()
        .join(format!("boba_repro_ingest_{}.mtx", std::process::id()));
    io::write_matrix_market(&g, &path).unwrap();
    let sidecar = bcoo::sidecar_path(&path);
    std::fs::remove_file(&sidecar).ok();

    let spec = path.to_str().unwrap().to_string();
    let mut opts = ReproOptions::quick(5);
    opts.dataset_specs = vec![spec.clone()];
    opts.tables = vec!["T3".into()];
    opts.reps = 1;
    opts.warmup = 0;
    opts.pr_iters = 3;
    let run = repro::run(&opts).unwrap();

    let ing = run.doc.get("T3", &spec, "", "ingest_ms").expect("ingest row for file spec");
    assert!(ing.summary.median_ms >= 0.0);
    assert!(sidecar.exists(), "text parse wrote the sidecar the ingest stage then hits");
    // Round-trips through the strict v2 parser.
    let back = ResultsDoc::parse(&run.doc.to_json().render()).unwrap();
    assert!(back.get("T3", &spec, "", "ingest_ms").is_some());

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&sidecar).ok();
}

#[test]
fn repro_honours_table_subset() {
    let mut opts = tiny_opts(3);
    opts.dataset_specs = vec!["rmat:10:4".into()];
    opts.tables = vec!["T2".into()];
    let run = repro::run(&opts).unwrap();
    assert_eq!(run.doc.tables(), vec!["T2"]);
    assert!(run.doc.records.iter().all(|r| r.table == "T2"));
    assert!(!run.console.contains("T1 —"));
}
