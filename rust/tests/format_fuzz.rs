//! Seeded fuzz harness for the delta/narrow CSR encoder
//! ([`boba::runtime::delta`]).
//!
//! The encoder's block structure has sharp corners worth hammering:
//! column spans of exactly 65535 (the widest narrow block) and 65536
//! (one past it), single-edge blocks (excluded from narrowing by the
//! `edges ≥ 2` rule so the descriptor can never outweigh the stream),
//! empty rows in the middle of occupied blocks, and hub rows crossing
//! task boundaries. Every trial is driven by [`Xoshiro256`] from a
//! fixed seed list and every assertion message embeds that seed, so a
//! failure is replayable by pasting one number into a unit test.
//!
//! Invariants per trial: decode roundtrips to the exact input CSR,
//! `bytes_per_edge` never exceeds plain CSR's 4 B/edge, and both SpMV
//! kernels are bit-identical to [`spmv_pull`].

use boba::algos::spmv::spmv_pull;
use boba::convert;
use boba::graph::{Coo, Csr};
use boba::runtime::delta::{DeltaCsr, DELTA_BLOCK_ROWS};
use boba::runtime::format::SpmvFormat;
use boba::util::prng::Xoshiro256;

/// One random graph: per 64-row block, pick a column window whose span
/// is drawn from a menu that straddles the narrow/wide boundary, leave
/// ~a third of the rows empty, and occasionally grow a hub row.
fn random_graph(seed: u64) -> Coo {
    let mut rng = Xoshiro256::stream(seed, 1);
    // A quarter of the trials use a vertex range wide enough that spans
    // of 65536+ are actually constructible.
    let boundary = rng.below(4) == 0;
    let n = if boundary {
        66_000 + rng.below_usize(8_000)
    } else {
        DELTA_BLOCK_ROWS + rng.below_usize(4_000)
    };
    let span_menu = [1usize, 100, 65_535, 65_536, usize::MAX];
    let mut src = Vec::new();
    let mut dst = Vec::new();
    for b in 0..n.div_ceil(DELTA_BLOCK_ROWS) {
        let span = span_menu[rng.below_usize(span_menu.len())].min(n);
        let lo = rng.below_usize(n - span + 1);
        for r in 0..DELTA_BLOCK_ROWS {
            let v = b * DELTA_BLOCK_ROWS + r;
            if v >= n {
                break;
            }
            if rng.below(3) == 0 {
                continue; // empty row inside the block
            }
            let mut deg = 1 + rng.below_usize(8);
            if rng.below(64) == 0 {
                deg += rng.below_usize(512); // hub row
            }
            for _ in 0..deg {
                src.push(v as u32);
                dst.push((lo + rng.below_usize(span)) as u32);
            }
        }
    }
    if seed % 2 == 0 {
        // Weighted half the time; exact zeros included deliberately.
        let vals = (0..src.len())
            .map(|_| if rng.below(10) == 0 { 0.0 } else { rng.next_f32() * 2.0 - 1.0 })
            .collect();
        Coo::with_vals(n, src, dst, vals)
    } else {
        Coo::new(n, src, dst)
    }
}

fn check_delta(seed: u64, csr: &Csr) {
    let enc = DeltaCsr::encode(csr);
    assert_eq!(
        &enc.decode(),
        csr,
        "seed {seed}: delta decode must roundtrip the input CSR exactly"
    );
    assert!(
        enc.bytes_per_edge() <= 4.0 + 1e-9,
        "seed {seed}: delta spends {} B/edge, more than plain CSR's 4.0 \
         (narrow {} / wide {} blocks)",
        enc.bytes_per_edge(),
        enc.narrow_blocks(),
        enc.wide_blocks()
    );
    let x: Vec<f32> = (0..csr.n()).map(|i| ((i % 13) as f32) * 0.5 - 3.0).collect();
    let want = spmv_pull(csr, &x);
    for (kernel, got) in [("sequential", enc.spmv(&x)), ("parallel", enc.spmv_parallel(&x))] {
        assert_eq!(want.len(), got.len(), "seed {seed}: {kernel} output length");
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "seed {seed}: {kernel} y[{i}] = {b}, spmv_pull says {a}"
            );
        }
    }
}

#[test]
fn fuzz_delta_encoder_roundtrip_and_bits() {
    for trial in 0..16u64 {
        let seed = 0xB0BA_0000 + trial;
        let g = random_graph(seed);
        check_delta(seed, &convert::coo_to_csr(&g));
    }
}

#[test]
fn span_65535_is_the_widest_narrow_block() {
    // Row 0 holds columns {0, 65535}: span exactly u16::MAX with ≥ 2
    // edges — the last configuration the narrow rule admits.
    let g = Coo::new(70_000, vec![0, 0], vec![0, 65_535]);
    let csr = convert::coo_to_csr(&g);
    let enc = DeltaCsr::encode(&csr);
    assert_eq!(enc.narrow_blocks(), 1, "span 65535 must encode narrow");
    assert_eq!(enc.wide_blocks(), 0);
    assert!((enc.bytes_per_edge() - 4.0).abs() < 1e-9, "2×u16 deltas + one u32 base over 2 edges");
    check_delta(65_535, &csr);
}

#[test]
fn span_65536_falls_back_to_wide() {
    let g = Coo::new(70_000, vec![0, 0], vec![0, 65_536]);
    let csr = convert::coo_to_csr(&g);
    let enc = DeltaCsr::encode(&csr);
    assert_eq!(enc.wide_blocks(), 1, "span 65536 no longer fits a u16 delta");
    assert_eq!(enc.narrow_blocks(), 0);
    assert!((enc.bytes_per_edge() - 4.0).abs() < 1e-9, "wide blocks stream raw u32 columns");
    check_delta(65_536, &csr);
}

#[test]
fn single_edge_blocks_stay_wide() {
    // One edge in the block: narrowing would spend a 4-byte base to
    // save 2 bytes of column — the `edges ≥ 2` rule forbids it, which
    // is what makes `bytes_per_edge ≤ 4.0` an invariant, not a hope.
    let g = Coo::new(128, vec![5], vec![90]);
    let csr = convert::coo_to_csr(&g);
    let enc = DeltaCsr::encode(&csr);
    assert_eq!(enc.wide_blocks(), 1);
    assert_eq!(enc.narrow_blocks(), 0);
    check_delta(1, &csr);
}

#[test]
fn empty_rows_inside_a_block_are_preserved() {
    // Only rows 0 and 63 of the first block carry edges; the 62 empty
    // rows between them must decode back as empty, and the block still
    // narrows (span 40 across the two occupied rows).
    let g = Coo::new(64, vec![0, 0, 63], vec![10, 50, 30]);
    let csr = convert::coo_to_csr(&g);
    for v in 1..63 {
        assert_eq!(csr.degree(v), 0);
    }
    let enc = DeltaCsr::encode(&csr);
    assert_eq!(enc.narrow_blocks(), 1);
    check_delta(63, &csr);
}
