//! Differential format-equivalence harness — the gate the compressed
//! kernel-format family ([`boba::runtime::format`]) ships behind.
//!
//! The contract under test: every registered format (plain CSR, delta,
//! SELL-C-σ, tiled, ELL) produces **bit-identical** SpMV output to the
//! reference [`boba::algos::spmv::spmv_pull`] — same f32 accumulation
//! order per destination row — from both its sequential and its
//! pool-parallel kernel, at every pinned thread count, across reordering
//! schemes (boba / random / degree), across graph shapes (power-law,
//! road-like, weighted with zero and negative weights, and the
//! degenerate family: empty, single-vertex, all-self-loops, hub row),
//! and with sorted as well as unsorted adjacency lists (the tiled
//! format takes a different code path for each). Each format must also
//! decode back to the exact CSR it was built from.
//!
//! Everything is compared via `f32::to_bits` — approximate equality
//! would hide reassociated additions, and reassociation is precisely
//! the bug class this suite exists to catch.

use boba::algos::spmv::spmv_pull;
use boba::convert;
use boba::graph::{gen, Coo, Csr};
use boba::parallel::ThreadGuard;
use boba::reorder::{self, Reorderer};
use boba::runtime::format::{self, SpmvFormat, FORMAT_NAMES};

/// A deterministic dense probe vector with negative, zero, and positive
/// entries (i % 23 hits 0 ⇒ x contains exact -4.0 and a zero crossing).
fn probe_x(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 23) as f32) * 0.375 - 4.0).collect()
}

fn assert_bits_equal(tag: &str, want: &[f32], got: &[f32]) {
    assert_eq!(want.len(), got.len(), "{tag}: output length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{tag}: y[{i}] = {b} (bits {:#010x}), expected {a} (bits {:#010x})",
            b.to_bits(),
            a.to_bits()
        );
    }
}

/// Run the full differential battery against one CSR: for every
/// registered format, decode roundtrip + sequential bits + parallel
/// bits at 1/2/4/8 pinned worker threads.
fn check_csr(tag: &str, csr: &Csr) {
    let x = probe_x(csr.n());
    let want = spmv_pull(csr, &x);
    for name in FORMAT_NAMES {
        let enc = format::encode(name, csr)
            .unwrap_or_else(|e| panic!("{tag}/{name}: encode failed: {e:#}"));
        assert_eq!(enc.n(), csr.n(), "{tag}/{name}: n");
        assert_eq!(enc.m(), csr.m(), "{tag}/{name}: m");
        assert_eq!(&enc.decode(), csr, "{tag}/{name}: decode must roundtrip exactly");
        assert_bits_equal(&format!("{tag}/{name}/seq"), &want, &enc.spmv(&x));
        for threads in [1usize, 2, 4, 8] {
            let _guard = ThreadGuard::pin(threads);
            assert_bits_equal(
                &format!("{tag}/{name}/par@{threads}"),
                &want,
                &enc.spmv_parallel(&x),
            );
        }
    }
}

/// Relabel a graph under each scheme and check both the raw CSR (tiled
/// takes its irregular fallback) and the row-sorted CSR (tiled engages
/// its u16 column tiles; delta blocks get their best span).
fn check_graph(tag: &str, g: &Coo) {
    for scheme in ["boba", "random", "degree"] {
        let r = reorder::by_name(scheme, 99).unwrap();
        let (_perm, h) = r.reorder_relabel(g);
        let csr = convert::coo_to_csr(&h);
        check_csr(&format!("{tag}@{scheme}"), &csr);
        let mut sorted = csr.clone();
        sorted.sort_rows();
        check_csr(&format!("{tag}@{scheme}+sorted"), &sorted);
    }
}

#[test]
fn formats_match_on_power_law_graph() {
    // Above PAR_MIN_EDGES (1<<14) so the parallel kernels really fan
    // out instead of taking their sequential fallback.
    let g = gen::rmat(&gen::GenParams::rmat(12, 8), 77).randomized(78);
    assert!(g.m() >= 1 << 14, "must exercise the parallel path, m = {}", g.m());
    check_graph("rmat", &g);
}

#[test]
fn formats_match_on_road_like_graph() {
    let g = gen::grid_road(140, 120, 5).symmetrized();
    check_graph("road", &g);
}

#[test]
fn formats_match_on_weighted_graph() {
    // Weights include exact zeros and negatives: a format that drops,
    // reorders, or pads the value stream shows up immediately.
    let g = gen::rmat(&gen::GenParams::rmat(12, 8), 31).randomized(32);
    let vals: Vec<f32> = (0..g.m()).map(|i| ((i % 7) as f32) - 3.0).collect();
    let w = Coo::with_vals(g.n(), g.src.clone(), g.dst.clone(), vals);
    assert!(w.m() >= 1 << 14);
    check_graph("weighted", &w);
}

#[test]
fn formats_match_on_degenerate_graphs() {
    // Empty graph: no edges, 16 isolated vertices.
    check_graph("empty", &Coo::new(16, vec![], vec![]));
    // Single vertex with a self-loop (one edge, one block, span 0).
    check_graph("single", &Coo::new(1, vec![0], vec![0]));
    // All self-loops: every row has exactly one edge, diagonal matrix.
    let n = 64u32;
    let ids: Vec<u32> = (0..n).collect();
    check_graph("selfloops", &Coo::new(n as usize, ids.clone(), ids));
}

#[test]
fn formats_match_on_hub_row_graph() {
    // One row holding half the edges (row 0 → everyone) plus a ring:
    // stresses SELL slice padding, the ELL multi-pass row tiles, and
    // edge-balanced task splitting that lands mid-hub.
    let n: u32 = 20_000;
    let mut src = Vec::with_capacity(2 * n as usize);
    let mut dst = Vec::with_capacity(2 * n as usize);
    for v in 1..n {
        src.push(0);
        dst.push(v);
    }
    for v in 0..n {
        src.push(v);
        dst.push((v + 1) % n);
    }
    let g = Coo::new(n as usize, src, dst);
    assert!(g.m() >= 1 << 14);
    check_graph("hub", &g);
}

#[test]
fn padding_never_reaches_the_accumulator() {
    // The sharp probe for padded formats (sell, ell): x[0] = +∞. A
    // guard-by-length implementation never touches a padded slot; a
    // guard-by-annihilation implementation (col = 0, val = 0.0) would
    // compute 0.0 × ∞ = NaN — or for the unweighted add-only kernels,
    // ∞ + finite where the reference has finite — and diverge bitwise.
    let g = gen::rmat(&gen::GenParams::rmat(12, 8), 51).randomized(52);
    let csr = convert::coo_to_csr(&g);
    let mut x = probe_x(csr.n());
    x[0] = f32::INFINITY;
    let want = spmv_pull(&csr, &x);
    for name in FORMAT_NAMES {
        let enc = format::encode(name, &csr).unwrap();
        assert_bits_equal(&format!("inf/{name}/seq"), &want, &enc.spmv(&x));
        let _guard = ThreadGuard::pin(4);
        assert_bits_equal(&format!("inf/{name}/par"), &want, &enc.spmv_parallel(&x));
    }
}
