//! Prometheus exposition conformance under live traffic: scrape
//! `GET /metrics` twice with concurrent load between the scrapes and
//! assert the properties a real scraper relies on — every sample lives
//! under a `# HELP`/`# TYPE` header, histogram buckets are cumulative
//! and end in `+Inf` with consistent `_sum`/`_count`, and counters
//! never move backwards between scrapes.

use boba::obs::text::{Family, Scrape};
use boba::server::http::HttpClient;
use boba::server::{self, ServerConfig};
use std::time::Duration;

fn spawn_server() -> server::Server {
    server::spawn(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        capacity: 4,
        batch: 1 << 12,
        in_flight: 2,
        seed: 7,
        read_timeout: Duration::from_secs(10),
        ..Default::default()
    })
    .expect("server must bind an ephemeral port")
}

fn scrape(addr: &str) -> Scrape {
    let mut c = HttpClient::connect(addr).expect("connect for scrape");
    let (status, body) = c.request("GET", "/metrics", b"").expect("scrape");
    assert_eq!(status, 200);
    // Strict parse: headerless samples, orphan TYPE lines, and
    // duplicate families are all parse errors.
    Scrape::parse(&String::from_utf8_lossy(&body)).expect("conformant exposition")
}

/// Every histogram family: per label-set, buckets are cumulative,
/// finish with `+Inf`, and `_count` equals the `+Inf` bucket.
fn check_histograms(s: &Scrape) {
    for fam in s.families.iter().filter(|f| f.typ == "histogram") {
        let mut label_sets: Vec<Vec<(String, String)>> = Vec::new();
        for sample in &fam.samples {
            if !sample.name.ends_with("_bucket") {
                continue;
            }
            let mut ls: Vec<(String, String)> = sample
                .labels
                .iter()
                .filter(|(k, _)| k != "le")
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
            ls.sort();
            if !label_sets.contains(&ls) {
                label_sets.push(ls);
            }
        }
        for ls in label_sets {
            let want: Vec<(&str, &str)> =
                ls.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            let buckets = s.histogram(&fam.name, &want);
            assert!(!buckets.is_empty(), "{}: no buckets for {want:?}", fam.name);
            assert_eq!(
                buckets.last().unwrap().0,
                f64::INFINITY,
                "{}: bucket ladder must end in +Inf",
                fam.name
            );
            for pair in buckets.windows(2) {
                assert!(
                    pair[1].1 >= pair[0].1,
                    "{}: buckets must be cumulative ({pair:?})",
                    fam.name
                );
            }
            let count_name = format!("{}_count", fam.name);
            let count = s.value(&count_name, &want).expect("histogram _count sample");
            assert_eq!(
                buckets.last().unwrap().1,
                count,
                "{}: +Inf bucket must equal _count",
                fam.name
            );
            let sum_name = format!("{}_sum", fam.name);
            assert!(s.value(&sum_name, &want).is_some(), "{}: missing _sum", fam.name);
        }
    }
}

/// Counter samples from `pre` must not exceed their `post` values.
fn check_monotone(pre: &Scrape, post: &Scrape) {
    for fam in pre.families.iter().filter(|f| f.typ == "counter") {
        let after: Option<&Family> = post.families.iter().find(|f| f.name == fam.name);
        let after = after.unwrap_or_else(|| panic!("family {} vanished", fam.name));
        for sample in &fam.samples {
            let want: Vec<(&str, &str)> =
                sample.labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            let newer = after
                .samples
                .iter()
                .find(|s| s.name == sample.name && s.matches(&want))
                .unwrap_or_else(|| panic!("sample {}{:?} vanished", sample.name, want));
            assert!(
                newer.value >= sample.value,
                "counter {}{:?} moved backwards: {} -> {}",
                sample.name,
                want,
                sample.value,
                newer.value
            );
        }
    }
}

#[test]
fn metrics_are_conformant_and_counters_monotone_under_load() {
    let server = spawn_server();
    let addr = server.addr().to_string();

    // Warm the cache so the load phase is pure queries.
    let mut c = HttpClient::connect(&addr).unwrap();
    let (status, _) = c
        .request_json("POST", "/graphs", "{\"dataset\": \"pa:4000:4\", \"scheme\": \"boba\"}")
        .unwrap();
    assert_eq!(status, 201);
    drop(c);

    let pre = scrape(&addr);
    assert!(pre.families.len() >= 10, "only {} families", pre.families.len());
    for fam in &pre.families {
        assert!(!fam.help.is_empty(), "{} has no HELP text", fam.name);
        assert!(
            matches!(fam.typ.as_str(), "counter" | "gauge" | "histogram"),
            "{}: unexpected type {}",
            fam.name,
            fam.typ
        );
    }
    check_histograms(&pre);

    // Concurrent load between the scrapes: mixed queries + one batch.
    let mut handles = Vec::new();
    for w in 0..3 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = HttpClient::connect(&addr).unwrap();
            for i in 0..10 {
                let path = if (i + w) % 2 == 0 {
                    "/graphs/pa:4000:4@boba/spmv"
                } else {
                    "/graphs/pa:4000:4@boba/sssp"
                };
                let (status, _) = c.request("POST", path, b"").unwrap();
                assert_eq!(status, 200);
            }
            let batch = "{\"id\": \"pa:4000:4@boba\", \"queries\": [\
                         {\"query\": \"spmv\"}, {\"query\": \"spmv\", \"seed\": 3}, \
                         {\"query\": \"sssp\"}]}";
            let (status, _) = c.request("POST", "/query/batch", batch.as_bytes()).unwrap();
            assert_eq!(status, 200);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let post = scrape(&addr);
    check_histograms(&post);
    check_monotone(&pre, &post);

    // The load is visible in the delta: 30 direct queries + 3 batches.
    let count = |s: &Scrape, ep: &str| {
        s.value("boba_requests_total", &[("endpoint", ep)]).unwrap_or(0.0)
    };
    let delta: f64 = ["spmv", "sssp", "batch"]
        .iter()
        .map(|ep| count(&post, ep) - count(&pre, ep))
        .sum();
    assert!(delta >= 33.0, "expected ≥33 requests between scrapes, saw {delta}");
    server.shutdown();
}
