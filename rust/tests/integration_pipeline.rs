//! Integration tests of the full Problem-3 pipeline: streaming ingest →
//! reorder → convert → app, across datasets, schemes and apps; plus
//! file-I/O round-trips through the pipeline.

use boba::coordinator::datasets::{by_name, Scale};
use boba::coordinator::pipeline::{App, Pipeline, ReorderStage, StreamingIngest};
use boba::graph::io;
use boba::reorder::{boba::Boba, degree::DegreeSort, hub::HubSort, Reorderer};

fn quick(name: &str, seed: u64) -> boba::graph::Coo {
    by_name(name).unwrap().build_at(Scale::Quick, seed).randomized(seed + 1)
}

#[test]
fn every_app_runs_on_every_dataset_random_vs_boba() {
    for name in ["pa_c8", "road_grid"] {
        let g = quick(name, 3);
        for app in App::all() {
            let pipe = Pipeline::new(app);
            let a = pipe.run(&g, &ReorderStage::None);
            let b = pipe.run(&g, &ReorderStage::Scheme(Box::new(Boba::parallel())));
            // SSSP's digest is source-dependent; the max-degree source is
            // only label-invariant when the maximum is unique (true on
            // skew graphs, tied everywhere on regular grids) — so SSSP
            // digests are compared on pa_c8 only.
            if app == App::Sssp && name == "road_grid" {
                assert!(a.digest > 0.0 && b.digest > 0.0);
                continue;
            }
            let tol = 1e-3 * a.digest.abs().max(1.0);
            assert!(
                (a.digest - b.digest).abs() <= tol,
                "{name}/{}: {} vs {}",
                app.name(),
                a.digest,
                b.digest
            );
        }
    }
}

#[test]
fn lightweight_schemes_agree_on_digests() {
    let g = quick("soc_s", 9);
    let pipe = Pipeline::new(App::Spmv);
    let base = pipe.run(&g, &ReorderStage::None).digest;
    let schemes: Vec<Box<dyn Reorderer + Send + Sync>> = vec![
        Box::new(Boba::sequential()),
        Box::new(Boba::parallel_atomic()),
        Box::new(DegreeSort::new()),
        Box::new(HubSort::new()),
    ];
    for s in schemes {
        let name = s.name();
        let r = pipe.run(&g, &ReorderStage::Scheme(s));
        let tol = 1e-3 * base.abs().max(1.0);
        assert!((r.digest - base).abs() <= tol, "{name}: {} vs {base}", r.digest);
    }
}

#[test]
fn streaming_ingest_then_pipeline_matches_direct() {
    let g = quick("kron_s", 5);
    let (producer, stream) = StreamingIngest::from_coo(g.clone(), 10_000, 3);
    let (assembled, _batches) = stream.collect();
    producer.join().unwrap();
    let pipe = Pipeline::new(App::Spmv);
    let direct = pipe.run(&g, &ReorderStage::None);
    let streamed = pipe.run(&assembled, &ReorderStage::None);
    assert_eq!(direct.digest, streamed.digest);
}

#[test]
fn pipeline_through_mtx_file_roundtrip() {
    let g = quick("pa_c8", 7);
    let mut path = std::env::temp_dir();
    path.push(format!("boba_pipe_{}.mtx", std::process::id()));
    io::write_matrix_market(&g, &path).unwrap();
    let re_read = io::read_matrix_market(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(g, re_read);
    let pipe = Pipeline::new(App::PageRank);
    let a = pipe.run(&g, &ReorderStage::None);
    let b = pipe.run(&re_read, &ReorderStage::None);
    assert_eq!(a.digest, b.digest);
}

#[test]
fn stage_records_complete_per_app() {
    let g = quick("delaunay_s", 2);
    for app in App::all() {
        let r = Pipeline::new(app).run(&g, &ReorderStage::Scheme(Box::new(Boba::parallel())));
        assert!(r.stages.ms("reorder").is_some(), "{}", app.name());
        assert!(r.stages.ms("convert").is_some(), "{}", app.name());
        assert!(r.stages.ms("app").is_some(), "{}", app.name());
        assert_eq!(r.stages.ms("sort").is_some(), app == App::Tc, "{}", app.name());
    }
}

#[test]
fn edge_shuffled_input_still_correct() {
    // §5.6: adversarial edge order hurts BOBA's *locality*, never its
    // correctness.
    let g = quick("road_grid", 8).edge_shuffled(99);
    let pipe = Pipeline::new(App::Spmv);
    let a = pipe.run(&g, &ReorderStage::None);
    let b = pipe.run(&g, &ReorderStage::Scheme(Box::new(Boba::parallel())));
    let tol = 1e-3 * a.digest.abs().max(1.0);
    assert!((a.digest - b.digest).abs() <= tol);
}
