//! Golden-equivalence suite for the parallel byte-level ingest
//! (`graph::io`): the new readers must produce a **bit-identical**
//! `Coo` (n, src, dst, vals) to the old sequential
//! `BufReader::lines()` + `str::parse` readers — replicated verbatim
//! below as the reference — on every fixture shape, at every pinned
//! thread count. Malformed inputs must error, never panic. The `.bcoo`
//! sidecar cache must hit when fresh, miss when stale, and ignore
//! corrupt sidecars.

use boba::graph::io::{self, bcoo};
use boba::graph::{gen, Coo};
use boba::parallel::ThreadGuard;
use std::io::BufRead;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("boba_golden_{}_{name}", std::process::id()));
    p
}

/// Write a fixture, removing any sidecars a previous run left behind.
fn fixture(name: &str, content: &[u8]) -> PathBuf {
    let p = tmp(name);
    std::fs::write(&p, content).unwrap();
    std::fs::remove_file(bcoo::sidecar_path_for(&p, false)).ok();
    std::fs::remove_file(bcoo::sidecar_path_for(&p, true)).ok();
    p
}

fn cleanup(p: &Path) {
    std::fs::remove_file(p).ok();
    std::fs::remove_file(bcoo::sidecar_path_for(p, false)).ok();
    std::fs::remove_file(bcoo::sidecar_path_for(p, true)).ok();
}

// ── the pre-parallel readers, kept verbatim as the reference ─────────

fn ref_read_matrix_market(path: &Path) -> anyhow::Result<Coo> {
    let f = std::fs::File::open(path)?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty file"))??;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 5 || !h[0].starts_with("%%MatrixMarket") {
        anyhow::bail!("not a MatrixMarket file: {header:?}");
    }
    if h[1] != "matrix" || h[2] != "coordinate" {
        anyhow::bail!("only 'matrix coordinate' supported, got {header:?}");
    }
    let field = h[3].to_string();
    let symmetry = h[4].to_string();
    let mut dims: Option<(usize, usize, usize)> = None;
    let mut src = Vec::new();
    let mut dst = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        if dims.is_none() {
            let r: usize = it.next().unwrap().parse()?;
            let c: usize = it.next().unwrap().parse()?;
            let nnz: usize = it.next().unwrap().parse()?;
            dims = Some((r, c, nnz));
            continue;
        }
        let i: u64 = it.next().ok_or_else(|| anyhow::anyhow!("short line"))?.parse()?;
        let j: u64 = it.next().ok_or_else(|| anyhow::anyhow!("short line"))?.parse()?;
        if i == 0 || j == 0 {
            anyhow::bail!("MatrixMarket indices are 1-based; found 0");
        }
        src.push((i - 1) as u32);
        dst.push((j - 1) as u32);
        if field != "pattern" {
            let v: f32 = it.next().map(|s| s.parse()).transpose()?.unwrap_or(1.0);
            vals.push(v);
        }
        if symmetry == "symmetric" && i != j {
            src.push((j - 1) as u32);
            dst.push((i - 1) as u32);
            if field != "pattern" {
                vals.push(*vals.last().unwrap());
            }
        }
    }
    let (r, c, _) = dims.ok_or_else(|| anyhow::anyhow!("missing size line"))?;
    let n = r.max(c);
    let mut coo = Coo { n, src, dst, vals: None };
    if field != "pattern" {
        coo.vals = Some(vals);
    }
    coo.validate()?;
    Ok(coo)
}

fn ref_read_edge_list(path: &Path, preserve_ids: bool) -> anyhow::Result<Coo> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut raw: Vec<(u64, u64)> = Vec::new();
    let mut header_n: Option<usize> = None;
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            if header_n.is_none() {
                for (at, _) in t.match_indices("n=") {
                    let at_boundary = at == 0
                        || matches!(t.as_bytes()[at - 1], b' ' | b'\t' | b'#' | b':');
                    if !at_boundary {
                        continue;
                    }
                    let digits: String = t[at + 2..]
                        .chars()
                        .take_while(|c| c.is_ascii_digit())
                        .collect();
                    if let Ok(v) = digits.parse() {
                        header_n = Some(v);
                        break;
                    }
                }
            }
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u64 = it.next().unwrap().parse()?;
        let v: u64 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("edge line with one endpoint: {t:?}"))?
            .parse()?;
        raw.push((u, v));
    }
    if preserve_ids {
        let n_ids = raw.iter().map(|&(u, v)| u.max(v)).max().map_or(0, |x| x + 1) as usize;
        let n = n_ids.max(header_n.unwrap_or(0));
        let src = raw.iter().map(|&(u, _)| u as u32).collect();
        let dst = raw.iter().map(|&(_, v)| v as u32).collect();
        return Ok(Coo { n, src, dst, vals: None });
    }
    let mut map = std::collections::HashMap::new();
    let mut next = 0u32;
    let mut id = |x: u64, map: &mut std::collections::HashMap<u64, u32>| {
        *map.entry(x).or_insert_with(|| {
            let v = next;
            next += 1;
            v
        })
    };
    let mut src = Vec::with_capacity(raw.len());
    let mut dst = Vec::with_capacity(raw.len());
    for &(u, _) in &raw {
        src.push(id(u, &mut map));
    }
    for &(_, v) in &raw {
        dst.push(id(v, &mut map));
    }
    Ok(Coo { n: next as usize, src, dst, vals: None })
}

/// Bit-exact Coo comparison (vals compared by f32 bits, so -0.0 and
/// NaN payloads count too).
fn assert_bit_identical(a: &Coo, b: &Coo, what: &str) {
    assert_eq!(a.n(), b.n(), "{what}: n");
    assert_eq!(a.src, b.src, "{what}: src");
    assert_eq!(a.dst, b.dst, "{what}: dst");
    match (&a.vals, &b.vals) {
        (None, None) => {}
        (Some(x), Some(y)) => {
            assert_eq!(x.len(), y.len(), "{what}: vals len");
            for (i, (va, vb)) in x.iter().zip(y).enumerate() {
                assert_eq!(va.to_bits(), vb.to_bits(), "{what}: vals[{i}]");
            }
        }
        _ => panic!("{what}: vals presence differs"),
    }
}

const PINS: [usize; 4] = [1, 2, 4, 8];

fn golden_mtx(name: &str, content: &[u8]) {
    let p = fixture(name, content);
    let want = ref_read_matrix_market(&p).unwrap();
    for t in PINS {
        let _g = ThreadGuard::pin(t);
        let got = io::read_matrix_market(&p).unwrap();
        assert_bit_identical(&got, &want, &format!("{name} @ {t} threads"));
    }
    cleanup(&p);
}

fn golden_el(name: &str, content: &[u8], preserve: bool) {
    let p = fixture(name, content);
    let want = ref_read_edge_list(&p, preserve).unwrap();
    for t in PINS {
        let _g = ThreadGuard::pin(t);
        let got = io::read_edge_list(&p, preserve).unwrap();
        assert_bit_identical(&got, &want, &format!("{name} @ {t} threads"));
    }
    cleanup(&p);
}

// ── hand-written fixtures ────────────────────────────────────────────

#[test]
fn mtx_general_real_golden() {
    golden_mtx(
        "gen_real.mtx",
        b"%%MatrixMarket matrix coordinate real general\n\
          % comment\n\
          4 4 5\n\
          1 2 1.5\n\
          2 3 -2.25\n\
          % inline comment\n\
          3 1 1e-3\n\
          4 4 0.30000001\n\
          1 4\n",
    );
}

#[test]
fn mtx_symmetric_pattern_golden() {
    golden_mtx(
        "sym_pat.mtx",
        b"%%MatrixMarket matrix coordinate pattern symmetric\n\
          5 5 4\n\
          2 1\n\
          3 3\n\
          5 2\n\
          4 1\n",
    );
}

#[test]
fn mtx_symmetric_integer_golden() {
    golden_mtx(
        "sym_int.mtx",
        b"%%MatrixMarket matrix coordinate integer symmetric\n\
          3 3 3\n\
          2 1 7\n\
          3 3 -4\n\
          3 2 12\n",
    );
}

#[test]
fn mtx_crlf_and_no_trailing_newline_golden() {
    golden_mtx(
        "crlf.mtx",
        b"%%MatrixMarket matrix coordinate pattern general\r\n\
          3 3 3\r\n\
          1 2\r\n\
          2 3\r\n\
          3 1",
    );
}

#[test]
fn plus_prefixed_integers_golden() {
    // Rust's integer FromStr accepts a leading '+', so the old readers
    // did too — the byte-level parsers must keep accepting it.
    golden_mtx(
        "plus.mtx",
        b"%%MatrixMarket matrix coordinate real general\n+3 +3 +2\n+1 +2 +1.5\n3 1 2\n",
    );
    golden_el("plus.el", b"+1 2\n3 +4\n", true);
    golden_el("plus_dense.el", b"+1 2\n3 +4\n", false);
}

#[test]
fn mtx_rectangular_dims_golden() {
    golden_mtx(
        "rect.mtx",
        b"%%MatrixMarket matrix coordinate pattern general\n2 6 2\n1 6\n2 1\n",
    );
}

#[test]
fn el_commented_headered_sparse_golden() {
    let content = b"# boba edge list: n=12 m=4\n\
                    % another comment style\n\
                    100 7\n\
                    \n\
                    7 100\n\
                    # mid-file comment\n\
                    500 100\n\
                    0 500\n";
    golden_el("sparse.el", content, true);
    golden_el("sparse_dense.el", content, false);
}

#[test]
fn el_crlf_no_trailing_newline_golden() {
    let content = b"# n=9\r\n3 1\r\n1 2\r\n2 3";
    golden_el("crlf.el", content, true);
    golden_el("crlf_dense.el", content, false);
}

// ── generated fixtures large enough to exercise the range splitter ───

#[test]
fn mtx_generated_pattern_golden_across_pins() {
    let g = gen::rmat(&gen::GenParams::rmat(12, 8), 7).randomized(8);
    assert!(g.m() >= 30_000);
    let p = tmp("big_pat.mtx");
    io::write_matrix_market(&g, &p).unwrap();
    std::fs::remove_file(bcoo::sidecar_path(&p)).ok();
    let want = ref_read_matrix_market(&p).unwrap();
    assert_bit_identical(&want, &g, "writer round-trip sanity");
    for t in PINS {
        let _g = ThreadGuard::pin(t);
        let got = io::read_matrix_market(&p).unwrap();
        assert_bit_identical(&got, &want, &format!("big_pat.mtx @ {t} threads"));
    }
    cleanup(&p);
}

#[test]
fn mtx_generated_weighted_golden_across_pins() {
    // Weights whose shortest Display forms exercise both the fast f32
    // path (short fractions) and the str::parse fallback (9-digit
    // mantissas, exponents).
    let g0 = gen::preferential_attachment(6_000, 6, 3);
    let vals: Vec<f32> = (0..g0.m())
        .map(|i| ((i as f32) * 0.37 - 1000.0) * 10f32.powi((i % 13) as i32 - 6))
        .collect();
    let g = Coo::with_vals(g0.n(), g0.src.clone(), g0.dst.clone(), vals);
    let p = tmp("big_w.mtx");
    io::write_matrix_market(&g, &p).unwrap();
    std::fs::remove_file(bcoo::sidecar_path(&p)).ok();
    let want = ref_read_matrix_market(&p).unwrap();
    assert_bit_identical(&want, &g, "writer round-trip sanity");
    for t in PINS {
        let _g = ThreadGuard::pin(t);
        let got = io::read_matrix_market(&p).unwrap();
        assert_bit_identical(&got, &want, &format!("big_w.mtx @ {t} threads"));
    }
    cleanup(&p);
}

#[test]
fn el_generated_golden_across_pins_both_modes() {
    let g = gen::rmat(&gen::GenParams::rmat(12, 6), 5).randomized(6);
    let p = tmp("big.el");
    io::write_edge_list(&g, &p).unwrap();
    std::fs::remove_file(bcoo::sidecar_path(&p)).ok();
    for preserve in [true, false] {
        let want = ref_read_edge_list(&p, preserve).unwrap();
        for t in PINS {
            let _g = ThreadGuard::pin(t);
            let got = io::read_edge_list(&p, preserve).unwrap();
            assert_bit_identical(
                &got,
                &want,
                &format!("big.el preserve={preserve} @ {t} threads"),
            );
        }
    }
    cleanup(&p);
}

// ── malformed inputs: errors, never panics ───────────────────────────

#[test]
fn malformed_inputs_error_not_panic() {
    let cases: [(&str, &[u8]); 8] = [
        ("trunc_size.mtx", b"%%MatrixMarket matrix coordinate pattern general\n3 3\n"),
        ("no_size.mtx", b"%%MatrixMarket matrix coordinate pattern general\n% only comments\n"),
        ("junk_tok.mtx", b"%%MatrixMarket matrix coordinate pattern general\n3 3 1\n1 x\n"),
        ("zero_based.mtx", b"%%MatrixMarket matrix coordinate pattern general\n3 3 1\n0 1\n"),
        ("short_line.mtx", b"%%MatrixMarket matrix coordinate pattern general\n3 3 1\n2\n"),
        ("oob.mtx", b"%%MatrixMarket matrix coordinate pattern general\n3 3 1\n9 1\n"),
        ("bad_val.mtx", b"%%MatrixMarket matrix coordinate real general\n3 3 1\n1 2 zzz\n"),
        ("bad_field.mtx", b"%%MatrixMarket matrix coordinate complex general\n3 3 1\n1 2 0 0\n"),
    ];
    for (name, content) in cases {
        let p = fixture(name, content);
        for t in [1, 4] {
            let _g = ThreadGuard::pin(t);
            assert!(io::read_matrix_market(&p).is_err(), "{name} must error");
        }
        cleanup(&p);
    }
    let el_cases: [(&str, &[u8]); 3] = [
        ("one_endpoint.el", b"1 2\n3\n"),
        ("junk.el", b"1 2\nx y\n"),
        ("glued.el", b"1 2\n3x 4\n"),
    ];
    for (name, content) in el_cases {
        let p = fixture(name, content);
        for t in [1, 4] {
            let _g = ThreadGuard::pin(t);
            assert!(io::read_edge_list(&p, true).is_err(), "{name} must error");
            assert!(io::read_edge_list(&p, false).is_err(), "{name} must error (dense)");
        }
        cleanup(&p);
    }
}

#[test]
fn error_reports_the_right_line_at_every_pin() {
    // The bad line sits deep in the file; a racing parallel parse must
    // still report the earliest failing line, like a sequential scan.
    let mut content = b"%%MatrixMarket matrix coordinate pattern general\n20000 20000 20000\n".to_vec();
    for i in 0..9_000u32 {
        content.extend_from_slice(format!("{} {}\n", i + 1, (i % 777) + 1).as_bytes());
    }
    content.extend_from_slice(b"1 bogus\n"); // line 9003
    for i in 0..9_000u32 {
        content.extend_from_slice(format!("{} {}\n", (i % 555) + 1, i + 1).as_bytes());
    }
    let p = fixture("deep_err.mtx", &content);
    for t in PINS {
        let _g = ThreadGuard::pin(t);
        let err = format!("{:#}", io::read_matrix_market(&p).unwrap_err());
        assert!(err.contains("line 9003"), "@{t} threads: {err}");
    }
    cleanup(&p);
}

// ── the sidecar cache ────────────────────────────────────────────────

/// `BOBA_NO_BCOO_CACHE` is process-global and tests share a process:
/// every test that loads through the cache (or toggles the var) holds
/// this lock so the disable test cannot race the hit tests.
static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn sidecar_cache_hits_and_serves_identical_graph() {
    let _env = env_guard();
    let g = gen::preferential_attachment(2_000, 4, 9).randomized(2);
    let p = tmp("cache.mtx");
    io::write_matrix_market(&g, &p).unwrap();
    let sc = bcoo::sidecar_path(&p);
    std::fs::remove_file(&sc).ok();
    let first = io::load_graph_file(&p, true).unwrap();
    assert!(sc.exists(), "first text load writes the sidecar");
    let second = io::load_graph_file(&p, true).unwrap();
    assert_bit_identical(&second, &first, "cache hit");
    assert_bit_identical(&first, &g, "parse correctness");
    cleanup(&p);
}

#[test]
fn stale_sidecar_is_ignored_after_source_rewrite() {
    let _env = env_guard();
    let a = Coo::new(3, vec![0, 1], vec![1, 2]);
    let b = Coo::new(4, vec![0, 1, 2], vec![1, 2, 3]);
    let p = tmp("stale.mtx");
    io::write_matrix_market(&a, &p).unwrap();
    let sc = bcoo::sidecar_path(&p);
    std::fs::remove_file(&sc).ok();
    assert_eq!(io::load_graph_file(&p, true).unwrap(), a);
    assert!(sc.exists());
    // Rewrite the source; the old sidecar (graph `a`) is now stale.
    // The sleep outlasts even 1-second filesystem mtime granularity so
    // the rewrite is strictly newer on any platform.
    std::thread::sleep(std::time::Duration::from_millis(1100));
    io::write_matrix_market(&b, &p).unwrap();
    assert_eq!(io::load_graph_file(&p, true).unwrap(), b, "stale sidecar must not serve");
    // And the sidecar was refreshed to `b`.
    assert_eq!(bcoo::read_bcoo(&sc).unwrap(), b);
    cleanup(&p);
}

#[test]
fn corrupt_or_wrong_mode_sidecar_falls_back_to_text() {
    let _env = env_guard();
    let p = tmp("corrupt.el");
    std::fs::write(&p, "5 9\n9 5\n").unwrap();
    let sc = bcoo::sidecar_path_for(&p, false);
    let sc_dense = bcoo::sidecar_path_for(&p, true);
    // Corrupt sidecar newer than the source: ignored, text re-parsed.
    std::fs::write(&sc, b"BCOOgarbage-that-is-not-valid").unwrap();
    let g = io::load_graph_file(&p, true).unwrap();
    assert_eq!(g.n(), 10);
    assert_eq!(g.src, vec![5, 9]);
    // The two relabeling modes produce different graphs from the same
    // file and cache under different sidecar names, so alternating
    // loads never thrash each other's cache.
    let dense = io::load_graph_file(&p, false).unwrap();
    assert_eq!(dense.n(), 2, "dense relabel: 5→0, 9→1");
    assert!(sc_dense.exists(), "dense mode caches under its own name");
    let preserved = io::load_graph_file(&p, true).unwrap();
    assert_eq!(preserved.n(), 10, "preserve-ids load must not see the dense cache");
    // Belt-and-braces: a dense sidecar renamed onto the preserve name
    // is rejected by the flag bit, not served.
    std::fs::copy(&sc_dense, &sc).unwrap();
    let preserved2 = io::load_graph_file(&p, true).unwrap();
    assert_eq!(preserved2.n(), 10, "flag bit rejects a renamed wrong-mode sidecar");
    cleanup(&p);
}

#[test]
fn corrupt_sidecars_are_quarantined_and_text_reparsed() {
    let _env = env_guard();
    let p = tmp("quarantine.el");
    std::fs::write(&p, "0 1\n1 2\n").unwrap();
    let sc = bcoo::sidecar_path_for(&p, false);
    let bad = {
        let mut n = sc.as_os_str().to_os_string();
        n.push(".bad");
        std::path::PathBuf::from(n)
    };
    std::fs::remove_file(&sc).ok();
    std::fs::remove_file(&bad).ok();
    // Seed a valid sidecar strictly newer than the source (the sleep
    // outlasts 1-second filesystem mtime granularity), so every
    // corrupted rewrite below is mtime-fresh and genuinely parsed.
    std::thread::sleep(std::time::Duration::from_millis(1100));
    let want = io::load_graph_file(&p, true).unwrap();
    assert!(sc.exists());
    let pristine = std::fs::read(&sc).unwrap();

    // Bit flip in the payload: the checksum catches it, the file moves
    // to `.bad` with its bytes intact, and the text re-parse serves the
    // right graph and rewrites a fresh cache.
    let mut flipped = pristine.clone();
    let flip_at = pristine.len() / 2;
    flipped[flip_at] ^= 0x40;
    std::fs::write(&sc, &flipped).unwrap();
    assert_eq!(io::load_graph_file(&p, true).unwrap(), want);
    assert!(bad.exists(), "bit-flipped sidecar is quarantined to .bad");
    assert_eq!(std::fs::read(&bad).unwrap(), flipped, "quarantine preserves the evidence");
    assert!(sc.exists(), "fallback re-parse rewrote a fresh sidecar");
    assert_eq!(bcoo::read_bcoo(&sc).unwrap(), want);
    std::fs::remove_file(&bad).unwrap();

    // Truncation (also caught without the checksum, by the length check).
    std::fs::write(&sc, &pristine[..pristine.len() - 5]).unwrap();
    assert_eq!(io::load_graph_file(&p, true).unwrap(), want);
    assert!(bad.exists(), "truncated sidecar is quarantined");
    std::fs::remove_file(&bad).unwrap();

    // Zero length — shorter than the header, still quarantined cleanly.
    std::fs::write(&sc, b"").unwrap();
    assert_eq!(io::load_graph_file(&p, true).unwrap(), want);
    assert!(bad.exists(), "zero-length sidecar is quarantined");
    std::fs::remove_file(&bad).unwrap();
    cleanup(&p);
}

#[test]
fn cache_disable_env_is_respected() {
    let _env = env_guard();
    // Serialized against other env-reading tests by using a unique
    // fixture; the var is restored before the test ends.
    let p = tmp("nocache.el");
    std::fs::write(&p, "0 1\n1 0\n").unwrap();
    let sc = bcoo::sidecar_path(&p);
    std::fs::remove_file(&sc).ok();
    std::env::set_var("BOBA_NO_BCOO_CACHE", "1");
    let g = io::load_graph_file(&p, true).unwrap();
    std::env::remove_var("BOBA_NO_BCOO_CACHE");
    assert_eq!(g.m(), 2);
    assert!(!sc.exists(), "disabled cache writes no sidecar");
    cleanup(&p);
}

#[test]
fn bcoo_roundtrip_weighted_and_direct_load() {
    let g = Coo::with_vals(
        6,
        vec![0, 2, 4, 5],
        vec![1, 3, 5, 0],
        vec![0.5, -0.0, f32::MIN_POSITIVE, 3.25e7],
    );
    let p = tmp("direct.bcoo");
    bcoo::write_bcoo(&g, &p).unwrap();
    let back = io::load_graph_file(&p, true).unwrap();
    assert_bit_identical(&back, &g, ".bcoo direct load");
    std::fs::remove_file(&p).ok();
}
