//! Proposition 10: on a d-regular COO graph sorted by destination, BOBA's
//! ordering is a (d+1)-factor approximation of the optimal NScore:
//! `(d+1) · NScore(G, p_B) ≥ NScore(G, p*)`.
//!
//! NScore(G, p*) is NP-hard to compute, so the property is checked
//! against Lemma 8's upper bound `NScore(G, p*) ≤ m` — a *stronger*
//! requirement than the proposition itself (it implies it), exactly the
//! chain the paper's proof uses.

use boba::graph::Coo;
use boba::metrics::{nscore, nscore_upper_bound};
use boba::reorder::{boba::Boba, Reorderer};
use boba::testing::{check, Config, Gen};
use boba::util::prng::Xoshiro256;

/// Build a random d-regular directed graph: every vertex has out-degree
/// exactly d (a union of d random permutations — the standard
/// construction; in-degrees are also d).
fn d_regular(n: usize, d: usize, seed: u64) -> Coo {
    let mut rng = Xoshiro256::new(seed);
    let mut src = Vec::with_capacity(n * d);
    let mut dst = Vec::with_capacity(n * d);
    for _ in 0..d {
        let perm = rng.permutation(n);
        for (u, &v) in perm.iter().enumerate() {
            src.push(u as u32);
            dst.push(v);
        }
    }
    Coo::new(n, src, dst)
}

#[test]
fn proposition10_end_to_end_statement() {
    // `(d+1)·NScore(G, p_B) ≥ NScore(G, p*)` — checked against the best
    // ordering we can actually construct: max over {BOBA, identity,
    // several randoms, degree order}. Since NScore(p*) ≥ any of these,
    // passing against the max is a necessary check of the proposition.
    check(Config::default().cases(20), "Prop 10: (d+1)-approximation", |g: &mut Gen| {
        let n = g.usize(8..300);
        let d = g.usize(2..5);
        let graph = d_regular(n, d, g.seed());
        let sorted = graph.sorted_by_dst();
        let p = Boba::sequential().reorder(&sorted);
        let boba_score = nscore(&sorted.relabeled(p.new_of_old()));
        let mut best = nscore(&sorted); // identity
        for _ in 0..4 {
            best = best.max(nscore(&sorted.randomized(g.seed())));
        }
        anyhow::ensure!(
            (d as u64 + 1) * boba_score >= best,
            "(d+1)*{boba_score} < best-found {best} (n={n}, d={d})"
        );
        // And the trivially sound Lemma-8 form of the claim's ceiling:
        anyhow::ensure!(best <= nscore_upper_bound(&sorted));
        Ok(())
    });
}

/// The quantitative core of the proof: the paper's recurrence gives
/// `NScore(G, p_B) ≥ (d-1)m/d²`, and Lemma 8 bounds the optimum by m, so
/// the end-to-end claim is `(d+1)·NScore ≥ m·(d-1)(d+1)/d² … ≥` — we
/// check the two proof ingredients directly:
///   (a) NScore(BOBA order) ≥ (d-1)·m/d² − d  (slack d for boundary rows)
///   (b) NScore(any order) ≤ m                (Lemma 8)
#[test]
fn proposition10_quantitative_ingredients() {
    check(Config::default().cases(30), "Prop 10 ingredients", |g: &mut Gen| {
        let n = g.usize(16..400);
        let d = g.usize(2..5);
        let graph = d_regular(n, d, g.seed());
        let sorted = graph.sorted_by_dst();
        let m = sorted.m() as f64;

        // (b) Lemma 8 for several orderings.
        anyhow::ensure!(nscore(&sorted) as f64 <= m);
        let rand = sorted.randomized(g.seed());
        anyhow::ensure!(nscore(&rand) as f64 <= m);

        // (a) BOBA's guaranteed fraction. The proof's bound is
        // (d-1)m/d²; random d-regular unions can have duplicate edges
        // (reducing effective regularity), so allow a 0.5 safety factor
        // plus an additive d for the last block.
        let p = Boba::sequential().reorder(&sorted);
        let relabeled = sorted.relabeled(p.new_of_old());
        let score = nscore(&relabeled) as f64;
        let bound = 0.5 * (d as f64 - 1.0) * m / (d as f64 * d as f64) - d as f64;
        anyhow::ensure!(
            score >= bound,
            "NScore(BOBA)={score} below proof bound {bound} (n={n}, d={d}, m={m})"
        );
        Ok(())
    });
}

#[test]
fn boba_on_sorted_dregular_beats_random_ordering() {
    // The observable consequence of Prop 10 the paper cares about: on
    // sorted d-regular inputs BOBA's NScore beats a random labeling's.
    check(Config::default().cases(20), "Prop 10 consequence", |g: &mut Gen| {
        let n = g.usize(64..600);
        let d = g.usize(2..5);
        let graph = d_regular(n, d, g.seed()).sorted_by_dst();
        let p = Boba::sequential().reorder(&graph);
        let boba_score = nscore(&graph.relabeled(p.new_of_old()));
        let rand_score = nscore(&graph.randomized(g.seed()));
        anyhow::ensure!(
            boba_score >= rand_score,
            "BOBA {boba_score} < random {rand_score} on sorted d-regular input"
        );
        Ok(())
    });
}
