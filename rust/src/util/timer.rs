//! Wall-clock timing utilities for pipeline stages and experiment drivers.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start a new stopwatch.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed milliseconds as f64.
    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Restart and return the elapsed time of the lap that ended.
    pub fn lap(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates named stage timings for pipeline reports (the Fig. 4
/// stacked-bar data is produced from these records).
#[derive(Debug, Default, Clone)]
pub struct StageTimer {
    stages: Vec<(String, Duration)>,
}

impl StageTimer {
    /// New empty timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name`. Returns the closure's
    /// value.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let out = f();
        self.stages.push((name.to_string(), sw.elapsed()));
        out
    }

    /// Record an externally measured duration.
    pub fn record(&mut self, name: &str, d: Duration) {
        self.stages.push((name.to_string(), d));
    }

    /// Stage records in insertion order.
    pub fn stages(&self) -> &[(String, Duration)] {
        &self.stages
    }

    /// Milliseconds for a named stage (sums duplicates), if present.
    pub fn ms(&self, name: &str) -> Option<f64> {
        let mut total = 0.0;
        let mut found = false;
        for (n, d) in &self.stages {
            if n == name {
                total += d.as_secs_f64() * 1e3;
                found = true;
            }
        }
        found.then_some(total)
    }

    /// Total of all stages in milliseconds.
    pub fn total_ms(&self) -> f64 {
        self.stages.iter().map(|(_, d)| d.as_secs_f64() * 1e3).sum()
    }

    /// Render a one-line summary: `reorder=1.2ms convert=88.0ms ...`.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        for (n, d) in &self.stages {
            parts.push(format!("{}={:.2}ms", n, d.as_secs_f64() * 1e3));
        }
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_something() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.ms() >= 4.0);
    }

    #[test]
    fn stage_timer_records_and_sums() {
        let mut t = StageTimer::new();
        let v = t.time("a", || 21 * 2);
        assert_eq!(v, 42);
        t.record("b", Duration::from_millis(10));
        t.record("a", Duration::from_millis(5));
        assert!(t.ms("a").unwrap() >= 5.0);
        assert_eq!(t.stages().len(), 3);
        assert!(t.ms("missing").is_none());
        assert!(t.total_ms() >= 15.0);
        assert!(t.summary().contains("b="));
    }
}
