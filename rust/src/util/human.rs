//! Human-readable formatting helpers for reports and tables.

/// Format a byte count: `1.5 GiB`, `340.4 MB`-style (paper's Table 2 uses
/// decimal MB for dataset sizes, so both are provided).
pub fn bytes_binary(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Decimal megabytes with one decimal, as in the paper's Table 2.
pub fn mb_decimal(b: u64) -> String {
    format!("{:.1}", b as f64 / 1e6)
}

/// Format a count: `1.1M`, `89M`, `57.7M` (Table 2 style).
pub fn count_compact(n: u64) -> String {
    if n >= 1_000_000_000 {
        format!("{:.1}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

/// Milliseconds with adaptive precision.
pub fn ms(v: f64) -> String {
    if v < 0.1 {
        format!("{:.4} ms", v)
    } else if v < 10.0 {
        format!("{:.2} ms", v)
    } else {
        format!("{:.1} ms", v)
    }
}

/// Left-pad to a fixed width (simple table layout helper).
pub fn pad(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{}{}", " ".repeat(w - s.len()), s)
    }
}

/// Right-pad to a fixed width.
pub fn pad_right(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{}{}", s, " ".repeat(w - s.len()))
    }
}

/// Render an aligned text table: header row + data rows.
pub fn table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, widths: &[usize]| -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push_str("  ");
            }
            if i == 0 {
                line.push_str(&pad_right(c, widths[i]));
            } else {
                line.push_str(&pad(c, widths[i]));
            }
        }
        line
    };
    out.push_str(&fmt_row(header.iter().map(|s| s.to_string()).collect(), &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row.clone(), &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes_binary(512), "512 B");
        assert_eq!(bytes_binary(2048), "2.0 KiB");
        assert_eq!(bytes_binary(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn counts() {
        assert_eq!(count_compact(999), "999");
        assert_eq!(count_compact(4_200_000), "4.2M");
        assert_eq!(count_compact(1_500), "1.5K");
        assert_eq!(count_compact(2_000_000_000), "2.0B");
    }

    #[test]
    fn ms_precision() {
        assert_eq!(ms(0.01234), "0.0123 ms");
        assert_eq!(ms(5.678), "5.68 ms");
        assert_eq!(ms(123.4), "123.4 ms");
    }

    #[test]
    fn table_alignment() {
        let t = table(
            &["name", "v"],
            &[vec!["a".into(), "1".into()], vec!["longer".into(), "22".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].contains("22"));
    }

    #[test]
    fn mb_matches_paper_style() {
        assert_eq!(mb_decimal(340_400_000), "340.4");
    }
}
