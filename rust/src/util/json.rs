//! A small JSON value type with a recursive-descent parser and a
//! renderer. (No JSON crate resolves offline; the grammar needed here is
//! tiny and fully under test.)
//!
//! Shared by every machine-readable emitter in the crate: the service
//! layer's request/response bodies ([`crate::server`] re-exports this
//! module as `server::json`), the loadgen's `BENCH_serve.json`, and the
//! repro harness's `BENCH_repro.json` ([`crate::bench::results`]).

use anyhow::{bail, Context, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (integers round-trip exactly up to 2^53).
    Num(f64),
    /// String
    Str(String),
    /// Array
    Arr(Vec<Json>),
    /// Object — insertion-ordered pairs (duplicate keys keep the first).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Consume into an object's pairs. Total: a non-object value comes
    /// back as a single `("value", v)` pair, so callers that extend a
    /// known-object JSON with extra fields never need a panicking match
    /// arm (the serve path's panic-path lint rule).
    pub fn into_obj_pairs(self) -> Vec<(String, Json)> {
        match self {
            Json::Obj(pairs) => pairs,
            other => vec![("value".to_string(), other)],
        }
    }

    /// Member lookup on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String payload.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Numeric payload truncated to u64 (None for negatives/non-numbers).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 => Some(*v as u64),
            _ => None,
        }
    }

    /// Bool payload.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), at: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.at != p.bytes.len() {
            bail!("trailing garbage at byte {} of JSON document", p.at);
        }
        Ok(v)
    }

    /// Render compactly (no extra whitespace; keys in stored order).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    // Integers without the trailing ".0" (ids, counts).
                    if v.fract() == 0.0 && v.abs() < 9.0e15 {
                        out.push_str(&format!("{}", *v as i64));
                    } else {
                        out.push_str(&format!("{v}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.at < self.bytes.len()
            && matches!(self.bytes[self.at], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.at += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.at).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.at += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.at,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn eat_literal(&mut self, lit: &str) -> Result<()> {
        if self.bytes[self.at..].starts_with(lit.as_bytes()) {
            self.at += lit.len();
            Ok(())
        } else {
            bail!("expected {lit:?} at byte {}", self.at)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().context("unexpected end of JSON")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.eat_literal("true").map(|_| Json::Bool(true)),
            b'f' => self.eat_literal("false").map(|_| Json::Bool(false)),
            b'n' => self.eat_literal("null").map(|_| Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.at),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut pairs: Vec<(String, Json)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.at += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string().context("object key must be a string")?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            if !pairs.iter().any(|(k, _)| *k == key) {
                pairs.push((key, val));
            }
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.at += 1,
                Some(b'}') => {
                    self.at += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.at),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.at += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.at += 1,
                Some(b']') => {
                    self.at += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.at),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self
                .bytes
                .get(self.at)
                .context("unterminated string")?;
            self.at += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = *self.bytes.get(self.at).context("dangling escape")?;
                    self.at += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.at..self.at + 4)
                                .context("short \\u escape")?;
                            self.at += 4;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).context("non-ascii \\u escape")?,
                                16,
                            )
                            .context("bad \\u escape")?;
                            // BMP only; surrogates map to the replacement
                            // char (service bodies are ASCII in practice).
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        e => bail!("unknown escape \\{}", e as char),
                    }
                }
                c if c < 0x20 => bail!("raw control byte {c:#x} in string"),
                _ => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    let start = self.at - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    let slice = self
                        .bytes
                        .get(start..end)
                        .context("truncated UTF-8 sequence")?;
                    out.push_str(std::str::from_utf8(slice).context("invalid UTF-8")?);
                    self.at = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.at;
        if self.peek() == Some(b'-') {
            self.at += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
        ) {
            self.at += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.at]).unwrap_or("");
        let v: f64 = text
            .parse()
            .with_context(|| format!("bad number {text:?} at byte {start}"))?;
        Ok(Json::Num(v))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"dataset": "rmat:16:16", "opts": {"iters": 20}, "xs": [1, 2, 3]}"#)
            .unwrap();
        assert_eq!(v.get("dataset").unwrap().as_str(), Some("rmat:16:16"));
        assert_eq!(v.get("opts").unwrap().get("iters").unwrap().as_u64(), Some(20));
        match v.get("xs").unwrap() {
            Json::Arr(xs) => assert_eq!(xs.len(), 3),
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_round_trip() {
        let original = Json::Str("a \"quote\"\nnew\tline \\ slash".into());
        let rendered = original.render();
        assert_eq!(Json::parse(&rendered).unwrap(), original);
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn render_parse_round_trip_document() {
        let doc = Json::obj(vec![
            ("id", Json::Str("pa_c8@boba".into())),
            ("n", Json::Num(65536.0)),
            ("p50_ms", Json::Num(0.125)),
            ("ok", Json::Bool(true)),
            ("tags", Json::Arr(vec![Json::Str("a".into()), Json::Null])),
        ]);
        let text = doc.render();
        assert_eq!(Json::parse(&text).unwrap(), doc);
        assert!(text.contains("\"n\":65536"));
        assert!(!text.contains("65536.0"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("{\"name\": \"héllo→世界\"}").unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("héllo→世界"));
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn duplicate_keys_keep_first() {
        let v = Json::parse(r#"{"a": 1, "a": 2}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
    }
}
