//! Small substrates the rest of the crate builds on: deterministic PRNGs
//! (no `rand` crate resolves offline), a CLI argument parser (no `clap`),
//! wall-clock stage timers, a JSON codec (no `serde`), thread-local
//! request deadlines, and human-readable formatting.

pub mod prng;
pub mod args;
pub mod timer;
pub mod human;
pub mod json;
pub mod deadline;

pub use json::Json;
pub use prng::{SplitMix64, Xoshiro256};
pub use timer::{StageTimer, Stopwatch};
