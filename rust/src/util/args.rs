//! A small CLI argument parser (`clap` does not resolve offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with typed getters and auto-generated usage.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The first non-flag token, if any (the subcommand).
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of tokens.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.opts.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.opts.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option (any FromStr) with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            Some(v) => v.parse().unwrap_or(default),
            None => default,
        }
    }

    /// Typed option, erroring with a message naming the key on failure.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<T> {
        let v = self
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{key}"))?;
        v.parse()
            .map_err(|_| anyhow::anyhow!("option --{key}={v} failed to parse"))
    }

    /// Boolean flag presence (`--verbose`).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.get(key).map_or(false, |v| v == "true")
    }

    /// Positional arguments (after the subcommand).
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("reorder --algo boba --scale 18 input.mtx");
        assert_eq!(a.command.as_deref(), Some("reorder"));
        assert_eq!(a.get("algo"), Some("boba"));
        assert_eq!(a.get_parse::<u32>("scale", 0), 18);
        assert_eq!(a.positional(), &["input.mtx".to_string()]);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("run --algo=spmv --verbose --iters=3");
        assert_eq!(a.get("algo"), Some("spmv"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_parse::<usize>("iters", 1), 3);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("x --check");
        assert!(a.flag("check"));
    }

    #[test]
    fn require_errors() {
        let a = parse("x");
        assert!(a.require::<u32>("scale").is_err());
        let b = parse("x --scale nope");
        assert!(b.require::<u32>("scale").is_err());
        let c = parse("x --scale 7");
        assert_eq!(c.require::<u32>("scale").unwrap(), 7);
    }

    #[test]
    fn default_when_missing() {
        let a = parse("x");
        assert_eq!(a.get_or("name", "dflt"), "dflt");
        assert_eq!(a.get_parse::<f64>("eps", 0.5), 0.5);
    }
}
