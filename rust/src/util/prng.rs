//! Deterministic pseudo-random number generators.
//!
//! The `rand` crate does not resolve offline, so the crate carries its own
//! generators: [`SplitMix64`] (seed expansion / cheap streams) and
//! [`Xoshiro256`] (xoshiro256**, the workhorse). Both are tiny,
//! well-studied, and — crucially for the experiment drivers — fully
//! deterministic across runs and threads, so every table and figure in
//! docs/EXPERIMENTS.md is exactly reproducible from its seed.

/// SplitMix64 — Steele, Lea & Flood's 64-bit mixer.
///
/// Primarily used to expand a user seed into the state of a larger
/// generator and to derive independent per-thread streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** by Blackman & Vigna — fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion (the construction the authors
    /// recommend; guarantees a non-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive the i-th independent stream from this seed. Used by the
    /// parallel runtime to hand each worker its own generator.
    pub fn stream(seed: u64, i: u64) -> Self {
        // Mix the stream index through SplitMix64 so adjacent indices
        // yield uncorrelated states.
        let mut sm = SplitMix64::new(seed ^ (i.wrapping_mul(0xA076_1D64_78BD_642F)));
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            v.swap(i, j);
        }
    }

    /// A uniformly random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the public-domain C implementation,
        // seed = 1234567.
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_deterministic_and_stream_independent() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut s0 = Xoshiro256::stream(42, 0);
        let mut s1 = Xoshiro256::stream(42, 1);
        // Streams must differ immediately.
        assert_ne!(s0.next_u64(), s1.next_u64());
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Xoshiro256::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = rng.below(10) as usize;
            counts[v] += 1;
        }
        for &c in &counts {
            // Each bucket expects 10_000; allow 10%.
            assert!((9_000..=11_000).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Xoshiro256::new(3);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn permutation_is_valid() {
        let mut rng = Xoshiro256::new(11);
        let p = rng.permutation(1000);
        let mut seen = vec![false; 1000];
        for &v in &p {
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut rng = Xoshiro256::new(5);
        let mut v: Vec<u32> = (0..100).map(|i| i % 7).collect();
        let mut sorted_before = v.clone();
        sorted_before.sort_unstable();
        rng.shuffle(&mut v);
        let mut sorted_after = v.clone();
        sorted_after.sort_unstable();
        assert_eq!(sorted_before, sorted_after);
    }
}
