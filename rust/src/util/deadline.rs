//! Request-deadline propagation: a thread-local budget that long
//! kernels and prepare stages poll cooperatively.
//!
//! The serve path derives a deadline from the client's `x-deadline-ms`
//! header (or the server's `--default-deadline-ms`) and installs it on
//! the request thread with [`scope`] before dispatching. Anything
//! running on that thread — registry prepare stages, PageRank
//! iterations, SSSP rounds, batch tiles — calls [`expired`] at its
//! natural checkpoint and returns early instead of burning a core on
//! an answer nobody is waiting for; the router maps the early return
//! to `504 Gateway Timeout`.
//!
//! The thread-local lives here in `util` (not `server`) so the
//! algorithm kernels can poll it without a layering violation: `algos`
//! may depend on `util`, never on `server`. With no deadline installed
//! — every offline path: CLI runs, benches, repro — [`expired`] is one
//! thread-local read of a `None`, no clock call, no branch misses.
//! Worker-pool threads never see the request thread's deadline (the
//! cell is thread-local and the pool predates the request); only the
//! *orchestrating* loops poll, which is exactly the granularity the
//! checkpoints want.

use std::cell::Cell;
use std::time::{Duration, Instant};

thread_local! {
    static DEADLINE: Cell<Option<Instant>> = const { Cell::new(None) };
}

/// RAII guard restoring the previous thread-local deadline on drop —
/// scopes nest (a batch member may tighten, never loosen, the request
/// deadline).
pub struct Scope {
    prev: Option<Instant>,
}

impl Drop for Scope {
    fn drop(&mut self) {
        DEADLINE.with(|d| d.set(self.prev));
    }
}

/// Install `deadline` as the current thread's deadline for the guard's
/// lifetime. `None` clears it (useful to shield sub-work that must run
/// to completion).
pub fn scope(deadline: Option<Instant>) -> Scope {
    let prev = DEADLINE.with(|d| d.replace(deadline));
    Scope { prev }
}

/// The current thread's deadline, if one is installed.
pub fn current() -> Option<Instant> {
    DEADLINE.with(|d| d.get())
}

/// True when a deadline is installed and has passed. The no-deadline
/// path is a thread-local read — cheap enough for per-iteration
/// checkpoints in kernels.
pub fn expired() -> bool {
    match current() {
        Some(t) => Instant::now() >= t,
        None => false,
    }
}

/// Time left until the installed deadline: `None` when no deadline is
/// set, `Some(ZERO)` when already past it.
pub fn remaining() -> Option<Duration> {
    current().map(|t| t.saturating_duration_since(Instant::now()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_deadline_never_expires() {
        assert!(current().is_none());
        assert!(!expired());
        assert!(remaining().is_none());
    }

    #[test]
    fn scope_installs_and_restores() {
        let far = Instant::now() + Duration::from_secs(60);
        {
            let _g = scope(Some(far));
            assert_eq!(current(), Some(far));
            assert!(!expired());
            assert!(remaining().unwrap() > Duration::from_secs(30));
            {
                let near = Instant::now() - Duration::from_millis(1);
                let _inner = scope(Some(near));
                assert!(expired());
                assert_eq!(remaining(), Some(Duration::ZERO));
            }
            assert_eq!(current(), Some(far), "inner scope restored on drop");
        }
        assert!(current().is_none(), "outer scope restored on drop");
    }

    #[test]
    fn scope_none_shields_sub_work() {
        let _g = scope(Some(Instant::now() - Duration::from_millis(1)));
        assert!(expired());
        let _shield = scope(None);
        assert!(!expired());
    }

    #[test]
    fn deadline_is_thread_local() {
        let _g = scope(Some(Instant::now() - Duration::from_millis(1)));
        assert!(expired());
        std::thread::spawn(|| {
            assert!(!expired(), "other threads must not inherit the deadline");
        })
        .join()
        .unwrap();
    }
}
