//! A small property-based testing framework (proptest does not resolve
//! offline). Deterministic generation from seeds, configurable case
//! counts, and greedy input shrinking on failure.
//!
//! Properties are closures receiving a [`Gen`]; on failure the harness
//! retries the failing seed at smaller size scales to report a smaller
//! counterexample, then panics with the seed so the case can be replayed
//! exactly.

use crate::util::prng::Xoshiro256;

/// Property-run configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (cases derive from it).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 100, seed: 0xB0BA }
    }
}

impl Config {
    /// Set the case count.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Set the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Value source handed to properties.
pub struct Gen {
    rng: Xoshiro256,
    /// Scale factor in (0, 1] applied to requested ranges while
    /// shrinking; 1.0 during normal generation.
    scale: f64,
}

impl Gen {
    fn new(seed: u64, scale: f64) -> Self {
        Self { rng: Xoshiro256::new(seed), scale }
    }

    /// u64 in `range` (half-open). Shrinking narrows toward the lower
    /// bound.
    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        let span = range.end - range.start;
        if span == 0 {
            return range.start;
        }
        let scaled = ((span as f64 * self.scale).ceil() as u64).max(1);
        range.start + self.rng.below(scaled.min(span))
    }

    /// usize in `range`.
    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    /// f64 in [0,1).
    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// f32 in [0,1).
    pub fn f32(&mut self) -> f32 {
        self.rng.next_f32()
    }

    /// bool with probability `p` of true.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Vector of `len` values drawn by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below_usize(xs.len())]
    }

    /// Draw a fresh seed (for crate generators that take seeds).
    pub fn seed(&mut self) -> u64 {
        self.rng.next_u64()
    }
}

/// Run `prop` over `cfg.cases` random cases.
pub fn check<F>(cfg: Config, name: &str, prop: F)
where
    F: Fn(&mut Gen) -> anyhow::Result<()> + std::panic::RefUnwindSafe,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ ((case as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        if let Err(msg) = run_case(&prop, case_seed, 1.0) {
            // Greedy shrink: smaller scales, same seed.
            let mut best: (f64, String) = (1.0, msg);
            for &scale in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                if let Err(m) = run_case(&prop, case_seed, scale) {
                    best = (scale, m);
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 minimal scale {}):\n{}",
                best.0, best.1
            );
        }
    }
}

fn run_case<F>(prop: &F, seed: u64, scale: f64) -> Result<(), String>
where
    F: Fn(&mut Gen) -> anyhow::Result<()> + std::panic::RefUnwindSafe,
{
    let outcome = std::panic::catch_unwind(|| {
        let mut g = Gen::new(seed, scale);
        prop(&mut g)
    });
    match outcome {
        Ok(Ok(())) => Ok(()),
        Ok(Err(e)) => Err(format!("returned error: {e:#}")),
        Err(p) => {
            let msg = p
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic (non-string payload)".into());
            Err(format!("panicked: {msg}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default().cases(50), "sort idempotent", |g| {
            let len = g.usize(0..50);
            let mut v = g.vec(len, |g| g.u64(0..100));
            v.sort_unstable();
            let w = {
                let mut w = v.clone();
                w.sort_unstable();
                w
            };
            anyhow::ensure!(v == w);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check(Config::default().cases(3), "always fails", |g| {
            let v = g.u64(0..10);
            anyhow::ensure!(v > 100, "v was {v}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn panicking_property_reported() {
        check(Config::default().cases(2), "panics", |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn shrink_scales_reduce_sizes() {
        let mut g_small = Gen::new(1, 0.01);
        let b = g_small.usize(0..10_000);
        assert!(b <= 100);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Gen::new(9, 1.0);
        let mut b = Gen::new(9, 1.0);
        for _ in 0..10 {
            assert_eq!(a.u64(0..1000), b.u64(0..1000));
        }
    }
}
