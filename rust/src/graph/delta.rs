//! Sorted delta COO overlay for live graph mutations.
//!
//! A [`DeltaOverlay`] is the in-memory write side of the mutation
//! subsystem (ROADMAP item 1): `POST /mutate` batches land here after
//! they are durable in the WAL ([`crate::server::wal`]), and every
//! query kernel merges the overlay with the frozen base CSR at read
//! time until the background compactor re-runs BOBA + convert and
//! folds the delta into a fresh epoch.
//!
//! Representation: two sorted, pair-unique COO fragments over the
//! base's vertex space —
//!
//! * **upserts** `(src, dst, w)`: the pair `(src, dst)` exists in the
//!   live graph with weight `w`, regardless of what the base stores
//!   (an upsert *replaces* every parallel base copy of the pair);
//! * **tombstones** `(src, dst)`: the pair is deleted — every base
//!   copy is masked out.
//!
//! Both fragments are kept sorted by `(src, dst)` *and* mirrored
//! sorted by `(dst, src)` so pull kernels (PageRank over `Aᵀ`) can
//! merge in-neighbor rows as cheaply as out-neighbor rows. The two
//! sets are disjoint: applying an upsert clears the pair's tombstone
//! and vice versa, so membership checks are two binary searches per
//! touched row.
//!
//! ## Merge order and determinism
//!
//! Every merged kernel iterates one row as: **base edges in storage
//! order, skipping masked pairs, then overlay upserts in ascending
//! destination order**. That canonical order is shared by the
//! sequential and parallel merge paths (rows never split across
//! tasks), so the merged kernels are **bit-identical at every thread
//! count** — the same determinism bar the converter, the formats, and
//! deterministic PageRank already meet. SSSP's frontier relaxation is
//! order-independent at its fixpoint (distances are mins over the same
//! set of f32 path folds), which the unit tests assert bitwise.

use crate::algos::pagerank::{PrParams, PrResult};
use crate::graph::{Coo, Csr};
use crate::parallel::{self, SendPtr};
use crate::util::deadline;

/// One logical mutation against a prepared artifact, in the artifact's
/// (relabeled) vertex space.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DeltaOp {
    /// Insert-or-replace the edge `(src, dst)` with weight `w` (pass
    /// `1.0` for unweighted graphs — the registry normalizes).
    Upsert {
        /// Source vertex.
        src: u32,
        /// Destination vertex.
        dst: u32,
        /// Edge weight (`1.0` on unweighted artifacts).
        w: f32,
    },
    /// Delete every copy of the edge `(src, dst)`.
    Delete {
        /// Source vertex.
        src: u32,
        /// Destination vertex.
        dst: u32,
    },
}

/// Immutable sorted overlay snapshot (copy-on-write: [`DeltaOverlay::apply`]
/// builds the next snapshot, readers keep the old `Arc`).
#[derive(Clone, Debug, Default)]
pub struct DeltaOverlay {
    n: usize,
    // Upserts sorted by (src, dst), pair-unique.
    up_src: Vec<u32>,
    up_dst: Vec<u32>,
    up_val: Vec<f32>,
    // Tombstones sorted by (src, dst), pair-unique, disjoint from upserts.
    del_src: Vec<u32>,
    del_dst: Vec<u32>,
    // The same two sets sorted by (dst, src) — the pull-kernel mirror.
    tup_dst: Vec<u32>,
    tup_src: Vec<u32>,
    tdel_dst: Vec<u32>,
    tdel_src: Vec<u32>,
}

/// Binary-search the contiguous row `[lo, hi)` of `key` in a sorted
/// key column.
fn row_range(keys: &[u32], key: u32) -> (usize, usize) {
    let lo = keys.partition_point(|&k| k < key);
    let hi = lo + keys[lo..].partition_point(|&k| k == key);
    (lo, hi)
}

impl DeltaOverlay {
    /// Empty overlay over `n` vertices.
    pub fn empty(n: usize) -> DeltaOverlay {
        DeltaOverlay { n, ..Default::default() }
    }

    /// Overlay built from an op sequence (later ops win per pair).
    pub fn from_ops(n: usize, ops: &[DeltaOp]) -> DeltaOverlay {
        DeltaOverlay::empty(n).apply(ops)
    }

    /// Vertex-space size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Upsert count.
    pub fn upserts(&self) -> usize {
        self.up_src.len()
    }

    /// Tombstone count.
    pub fn tombstones(&self) -> usize {
        self.del_src.len()
    }

    /// Total overlay entries (upserts + tombstones) — the compaction
    /// threshold is checked against this.
    pub fn len(&self) -> usize {
        self.upserts() + self.tombstones()
    }

    /// True when the overlay holds no entries (queries take the pure
    /// base path).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Next snapshot with `ops` applied in order (last write per pair
    /// wins). Panics if an op names a vertex `>= n` — callers validate
    /// against the artifact before appending to the WAL.
    pub fn apply(&self, ops: &[DeltaOp]) -> DeltaOverlay {
        use std::collections::BTreeMap;
        // Some(w) = upsert, None = tombstone.
        let mut state: BTreeMap<(u32, u32), Option<f32>> = BTreeMap::new();
        for i in 0..self.up_src.len() {
            state.insert((self.up_src[i], self.up_dst[i]), Some(self.up_val[i]));
        }
        for i in 0..self.del_src.len() {
            state.insert((self.del_src[i], self.del_dst[i]), None);
        }
        for op in ops {
            match *op {
                DeltaOp::Upsert { src, dst, w } => {
                    assert!(
                        (src as usize) < self.n && (dst as usize) < self.n,
                        "delta op vertex out of range (n={})",
                        self.n
                    );
                    state.insert((src, dst), Some(w));
                }
                DeltaOp::Delete { src, dst } => {
                    assert!(
                        (src as usize) < self.n && (dst as usize) < self.n,
                        "delta op vertex out of range (n={})",
                        self.n
                    );
                    state.insert((src, dst), None);
                }
            }
        }
        let mut next = DeltaOverlay::empty(self.n);
        // BTreeMap iterates (src, dst)-sorted — the forward arrays come
        // out sorted for free; the transposed mirror re-sorts.
        let mut tup: Vec<(u32, u32, f32)> = Vec::new();
        let mut tdel: Vec<(u32, u32)> = Vec::new();
        for (&(s, d), &entry) in &state {
            match entry {
                Some(w) => {
                    next.up_src.push(s);
                    next.up_dst.push(d);
                    next.up_val.push(w);
                    tup.push((d, s, w));
                }
                None => {
                    next.del_src.push(s);
                    next.del_dst.push(d);
                    tdel.push((d, s));
                }
            }
        }
        tup.sort_unstable_by_key(|&(d, s, _)| (d, s));
        tdel.sort_unstable();
        for (d, s, _) in &tup {
            next.tup_dst.push(*d);
            next.tup_src.push(*s);
        }
        for (d, s) in &tdel {
            next.tdel_dst.push(*d);
            next.tdel_src.push(*s);
        }
        next
    }

    /// True when the base pair `(src, dst)` is masked (tombstoned or
    /// replaced by an upsert). Callers on hot paths should use the
    /// per-row ranges instead; this is the spot-check form.
    pub fn masked(&self, src: u32, dst: u32) -> bool {
        let (dlo, dhi) = row_range(&self.del_src, src);
        let (ulo, uhi) = row_range(&self.up_src, src);
        self.del_dst[dlo..dhi].binary_search(&dst).is_ok()
            || self.up_dst[ulo..uhi].binary_search(&dst).is_ok()
    }

    /// Out-row upsert slice for `src`: `(dsts, weights)` ascending.
    pub fn row_upserts(&self, src: u32) -> (&[u32], &[f32]) {
        let (lo, hi) = row_range(&self.up_src, src);
        (&self.up_dst[lo..hi], &self.up_val[lo..hi])
    }

    /// Merged out-degree array: base degree minus masked base copies
    /// plus one per upsert. Integer arithmetic — deterministic by
    /// construction.
    pub fn merged_out_degrees(&self, base: &Csr) -> Vec<u32> {
        let mut deg: Vec<u32> = (0..base.n()).map(|v| base.degree(v) as u32).collect();
        for v in self.touched_rows() {
            let (dlo, dhi) = row_range(&self.del_src, v);
            let (ulo, uhi) = row_range(&self.up_src, v);
            let mut masked = 0u32;
            for &c in base.neighbors(v as usize) {
                if self.del_dst[dlo..dhi].binary_search(&c).is_ok()
                    || self.up_dst[ulo..uhi].binary_search(&c).is_ok()
                {
                    masked += 1;
                }
            }
            deg[v as usize] -= masked;
        }
        for &s in &self.up_src {
            deg[s as usize] += 1;
        }
        deg
    }

    /// Merged edge count.
    pub fn merged_m(&self, base: &Csr) -> usize {
        self.merged_out_degrees(base).iter().map(|&d| d as usize).sum()
    }

    /// Distinct source rows carrying any overlay entry, ascending.
    fn touched_rows(&self) -> Vec<u32> {
        let mut rows: Vec<u32> = self.del_src.iter().chain(self.up_src.iter()).copied().collect();
        rows.sort_unstable();
        rows.dedup();
        rows
    }
}

/// One merged out-row accumulation: base edges in storage order (masked
/// pairs skipped), then upserts ascending — the canonical order both
/// the sequential and parallel SpMV share.
#[inline]
fn merged_row_acc(base: &Csr, d: &DeltaOverlay, x: &[f32], v: usize) -> f32 {
    let (lo, hi) = (base.row_ptr[v] as usize, base.row_ptr[v + 1] as usize);
    let (dlo, dhi) = row_range(&d.del_src, v as u32);
    let (ulo, uhi) = row_range(&d.up_src, v as u32);
    let mut acc = 0f32;
    if dlo == dhi && ulo == uhi {
        // Untouched row: the exact base loop (same adds, same order).
        match &base.vals {
            Some(vals) => {
                for e in lo..hi {
                    acc += vals[e] * x[base.col_idx[e] as usize];
                }
            }
            None => {
                for e in lo..hi {
                    acc += x[base.col_idx[e] as usize];
                }
            }
        }
        return acc;
    }
    let dels = &d.del_dst[dlo..dhi];
    let ups = &d.up_dst[ulo..uhi];
    let masked = |c: u32| dels.binary_search(&c).is_ok() || ups.binary_search(&c).is_ok();
    match &base.vals {
        Some(vals) => {
            for e in lo..hi {
                let c = base.col_idx[e];
                if !masked(c) {
                    acc += vals[e] * x[c as usize];
                }
            }
        }
        None => {
            for e in lo..hi {
                let c = base.col_idx[e];
                if !masked(c) {
                    acc += x[c as usize];
                }
            }
        }
    }
    for i in ulo..uhi {
        acc += d.up_val[i] * x[d.up_dst[i] as usize];
    }
    acc
}

/// Sequential merged SpMV: `y = (base ⊕ delta)·x`.
pub fn spmv_merged(base: &Csr, d: &DeltaOverlay, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), base.n());
    (0..base.n()).map(|v| merged_row_acc(base, d, x, v)).collect()
}

/// Edge-balanced parallel merged SpMV — **bit-identical to
/// [`spmv_merged`] at every thread count** (rows never split across
/// tasks and the per-row body is shared).
pub fn spmv_merged_parallel(base: &Csr, d: &DeltaOverlay, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), base.n());
    let n = base.n();
    if base.m() < 1 << 14 {
        return spmv_merged(base, d, x);
    }
    let tasks = (parallel::threads() * 8).max(1);
    let bounds = crate::algos::spmv::edge_balanced_row_bounds(base, tasks);
    let mut y = vec![0f32; n];
    let y_ptr = SendPtr(y.as_mut_ptr());
    let bounds_ref = &bounds;
    parallel::par_for_chunks(tasks, 1, |t_lo, t_hi| {
        for t in t_lo..t_hi {
            for v in bounds_ref[t]..bounds_ref[t + 1] {
                // SAFETY: row ranges are disjoint across tasks.
                unsafe { *y_ptr.get().add(v) = merged_row_acc(base, d, x, v) };
            }
        }
    });
    y
}

/// One merged in-row accumulation for pull PageRank: base in-neighbors
/// ascending (masked pairs skipped), then upsert in-neighbors
/// ascending. `tr` must be the stable transpose of the base.
#[inline]
fn merged_in_row_acc(tr: &Csr, d: &DeltaOverlay, share: &[f32], u: usize) -> f32 {
    let (lo, hi) = (tr.row_ptr[u] as usize, tr.row_ptr[u + 1] as usize);
    let (dlo, dhi) = row_range(&d.tdel_dst, u as u32);
    let (ulo, uhi) = row_range(&d.tup_dst, u as u32);
    let mut acc = 0f32;
    if dlo == dhi && ulo == uhi {
        for e in lo..hi {
            acc += share[tr.col_idx[e] as usize];
        }
        return acc;
    }
    let dels = &d.tdel_src[dlo..dhi];
    let ups = &d.tup_src[ulo..uhi];
    let masked = |s: u32| dels.binary_search(&s).is_ok() || ups.binary_search(&s).is_ok();
    for e in lo..hi {
        let s = tr.col_idx[e];
        if !masked(s) {
            acc += share[s as usize];
        }
    }
    for i in ulo..uhi {
        acc += share[d.tup_src[i] as usize];
    }
    acc
}

/// Shared iteration core of the two merged PageRank entry points: the
/// only difference between them is whether `share` and the pull rows
/// are filled serially or by the pool — both orders of f32 addition
/// are identical per element/row, so the results agree bitwise.
fn pagerank_merged_impl(
    base: &Csr,
    tr: &Csr,
    d: &DeltaOverlay,
    p: PrParams,
    par: bool,
) -> PrResult {
    let n = base.n();
    debug_assert_eq!(tr.n(), n);
    let deg = d.merged_out_degrees(base);
    let mut rank = vec![1.0f32 / n as f32; n];
    let mut share = vec![0f32; n];
    let mut next = vec![0f32; n];
    let chunk = parallel::default_chunk(n);
    let mut iters = 0;
    for _ in 0..p.max_iters {
        if deadline::expired() {
            break;
        }
        iters += 1;
        // share[v] = rank[v]/deg(v) — element-wise.
        if par {
            let rank_ref = &rank;
            let deg_ref = &deg;
            let share_ptr = SendPtr(share.as_mut_ptr());
            parallel::par_for_chunks(n, chunk, |lo, hi| {
                for v in lo..hi {
                    let dg = deg_ref[v];
                    let s = if dg == 0 { 0.0 } else { rank_ref[v] / dg as f32 };
                    // SAFETY: disjoint chunks.
                    unsafe { *share_ptr.get().add(v) = s };
                }
            });
        } else {
            for v in 0..n {
                share[v] = if deg[v] == 0 { 0.0 } else { rank[v] / deg[v] as f32 };
            }
        }
        // Dangling mass: sequential fold in vertex order in both paths.
        let mut dangling = 0f32;
        for v in 0..n {
            if deg[v] == 0 {
                dangling += rank[v];
            }
        }
        // next[u] = Σ share over merged in-neighbors, canonical order.
        if par {
            let tasks = (parallel::threads() * 8).max(1);
            let bounds = crate::algos::spmv::edge_balanced_row_bounds(tr, tasks);
            let next_ptr = SendPtr(next.as_mut_ptr());
            let share_ref = &share;
            let bounds_ref = &bounds;
            parallel::par_for_chunks(tasks, 1, |t_lo, t_hi| {
                for t in t_lo..t_hi {
                    for u in bounds_ref[t]..bounds_ref[t + 1] {
                        // SAFETY: row ranges are disjoint across tasks.
                        unsafe {
                            *next_ptr.get().add(u) = merged_in_row_acc(tr, d, share_ref, u)
                        };
                    }
                }
            });
        } else {
            for u in 0..n {
                next[u] = merged_in_row_acc(tr, d, &share, u);
            }
        }
        let base_rank = (1.0 - p.damping) / n as f32 + p.damping * dangling / n as f32;
        let mut delta = 0f32;
        for v in 0..n {
            let nv = base_rank + p.damping * next[v];
            delta += (nv - rank[v]).abs();
            rank[v] = nv;
        }
        if delta < p.tol {
            break;
        }
    }
    PrResult { ranks: rank, iters }
}

/// Sequential merged PageRank (pull form over the cached base
/// transpose plus the overlay's transposed mirror).
pub fn pagerank_merged(base: &Csr, tr: &Csr, d: &DeltaOverlay, p: PrParams) -> PrResult {
    pagerank_merged_impl(base, tr, d, p, false)
}

/// Parallel merged PageRank — bit-identical to [`pagerank_merged`] at
/// every thread count (same share/dangling/update folds, same per-row
/// pull order).
pub fn pagerank_merged_parallel(base: &Csr, tr: &Csr, d: &DeltaOverlay, p: PrParams) -> PrResult {
    if base.n() < 1 << 14 {
        return pagerank_merged(base, tr, d, p);
    }
    pagerank_merged_impl(base, tr, d, p, true)
}

/// Frontier SSSP over the merged adjacency (weights from `base.vals`
/// and the upsert weights; all-ones when the base is unweighted).
/// Checks the ambient request deadline between rounds like
/// [`crate::algos::sssp::sssp_frontier`].
pub fn sssp_merged(base: &Csr, d: &DeltaOverlay, source: u32) -> Vec<f32> {
    let n = base.n();
    let mut dist = vec![f32::INFINITY; n];
    dist[source as usize] = 0.0;
    let mut frontier = vec![source];
    let mut in_next = vec![false; n];
    while !frontier.is_empty() {
        if deadline::expired() {
            break;
        }
        let mut next = Vec::new();
        for &v in &frontier {
            let dv = dist[v as usize];
            relax_merged_row(base, d, v, dv, &mut |u, nd| {
                if nd < dist[u as usize] {
                    dist[u as usize] = nd;
                    if !in_next[u as usize] {
                        in_next[u as usize] = true;
                        next.push(u);
                    }
                }
            });
        }
        for &u in &next {
            in_next[u as usize] = false;
        }
        frontier = next;
    }
    dist
}

/// Parallel merged SSSP: each round computes relaxation proposals from
/// a snapshot of `dist` in parallel, then applies them sequentially.
/// Rounds differ from the sequential kernel's (which relaxes through
/// in-round updates), but the **fixpoint is bitwise identical**: every
/// distance is the minimum over the same set of left-folded f32 path
/// sums, and both kernels iterate until no relaxation applies.
pub fn sssp_merged_parallel(base: &Csr, d: &DeltaOverlay, source: u32) -> Vec<f32> {
    let n = base.n();
    if base.m() < 1 << 14 {
        return sssp_merged(base, d, source);
    }
    let mut dist = vec![f32::INFINITY; n];
    dist[source as usize] = 0.0;
    let mut frontier = vec![source];
    let mut in_next = vec![false; n];
    while !frontier.is_empty() {
        if deadline::expired() {
            break;
        }
        let chunk = parallel::default_chunk(frontier.len());
        let dist_ref = &dist;
        let frontier_ref = &frontier;
        let proposals: Vec<Vec<(u32, f32)>> = {
            let m = frontier.len().div_ceil(chunk);
            let mut jobs: Vec<Box<dyn FnOnce() -> Vec<(u32, f32)> + Send>> =
                Vec::with_capacity(m);
            for c in 0..m {
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(frontier.len());
                jobs.push(Box::new(move || {
                    let mut out = Vec::new();
                    for &v in &frontier_ref[lo..hi] {
                        let dv = dist_ref[v as usize];
                        relax_merged_row(base, d, v, dv, &mut |u, nd| {
                            if nd < dist_ref[u as usize] {
                                out.push((u, nd));
                            }
                        });
                    }
                    out
                }));
            }
            parallel::par_jobs(jobs)
        };
        let mut next = Vec::new();
        for chunk in proposals {
            for (u, nd) in chunk {
                if nd < dist[u as usize] {
                    dist[u as usize] = nd;
                    if !in_next[u as usize] {
                        in_next[u as usize] = true;
                        next.push(u);
                    }
                }
            }
        }
        for &u in &next {
            in_next[u as usize] = false;
        }
        frontier = next;
    }
    dist
}

/// Relax every merged out-edge of `v` (base order minus masks, then
/// upserts), calling `visit(dst, dv + w)` per live edge.
#[inline]
fn relax_merged_row(
    base: &Csr,
    d: &DeltaOverlay,
    v: u32,
    dv: f32,
    visit: &mut impl FnMut(u32, f32),
) {
    let (lo, hi) = (base.row_ptr[v as usize] as usize, base.row_ptr[v as usize + 1] as usize);
    let (dlo, dhi) = row_range(&d.del_src, v);
    let (ulo, uhi) = row_range(&d.up_src, v);
    let dels = &d.del_dst[dlo..dhi];
    let ups = &d.up_dst[ulo..uhi];
    let untouched = dels.is_empty() && ups.is_empty();
    for e in lo..hi {
        let c = base.col_idx[e];
        if !untouched && (dels.binary_search(&c).is_ok() || ups.binary_search(&c).is_ok()) {
            continue;
        }
        let w = base.vals.as_ref().map_or(1.0, |vv| vv[e]);
        visit(c, dv + w);
    }
    for i in ulo..uhi {
        visit(d.up_dst[i], dv + d.up_val[i]);
    }
}

/// Materialize the merged graph as a COO in the canonical row-major
/// order (per row: unmasked base edges in storage order, then upserts
/// ascending). Weighted iff the base is weighted — upsert weights ride
/// along there and are dropped on unweighted bases. This is what the
/// compactor reorders and converts into the next epoch, and what the
/// TC pipeline rebuilds its oriented view from.
pub fn merged_coo(base: &Csr, d: &DeltaOverlay) -> Coo {
    let n = base.n();
    let weighted = base.vals.is_some();
    let cap = base.m() + d.upserts();
    let mut src = Vec::with_capacity(cap);
    let mut dst = Vec::with_capacity(cap);
    let mut vals = weighted.then(|| Vec::with_capacity(cap));
    for v in 0..n {
        let (lo, hi) = (base.row_ptr[v] as usize, base.row_ptr[v + 1] as usize);
        let (dlo, dhi) = row_range(&d.del_src, v as u32);
        let (ulo, uhi) = row_range(&d.up_src, v as u32);
        let dels = &d.del_dst[dlo..dhi];
        let ups = &d.up_dst[ulo..uhi];
        let untouched = dels.is_empty() && ups.is_empty();
        for e in lo..hi {
            let c = base.col_idx[e];
            if !untouched && (dels.binary_search(&c).is_ok() || ups.binary_search(&c).is_ok()) {
                continue;
            }
            src.push(v as u32);
            dst.push(c);
            if let Some(vv) = vals.as_mut() {
                vv.push(base.vals.as_ref().unwrap()[e]);
            }
        }
        for i in ulo..uhi {
            src.push(v as u32);
            dst.push(d.up_dst[i]);
            if let Some(vv) = vals.as_mut() {
                vv.push(d.up_val[i]);
            }
        }
    }
    match vals {
        Some(v) => Coo::with_vals(n, src, dst, v),
        None => Coo::new(n, src, dst),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::spmv;
    use crate::convert;
    use crate::util::prng::Xoshiro256;

    fn base_graph(seed: u64, n: usize, m: usize) -> Csr {
        let mut rng = Xoshiro256::new(seed);
        let src: Vec<u32> = (0..m).map(|_| (rng.next_u64() % n as u64) as u32).collect();
        let dst: Vec<u32> = (0..m).map(|_| (rng.next_u64() % n as u64) as u32).collect();
        convert::coo_to_csr(&Coo::new(n, src, dst))
    }

    fn random_ops(seed: u64, n: usize, count: usize) -> Vec<DeltaOp> {
        let mut rng = Xoshiro256::new(seed);
        (0..count)
            .map(|_| {
                let src = (rng.next_u64() % n as u64) as u32;
                let dst = (rng.next_u64() % n as u64) as u32;
                if rng.next_u64() % 3 == 0 {
                    DeltaOp::Delete { src, dst }
                } else {
                    DeltaOp::Upsert { src, dst, w: 1.0 }
                }
            })
            .collect()
    }

    #[test]
    fn apply_is_last_write_wins_and_sets_stay_disjoint() {
        let d = DeltaOverlay::from_ops(
            10,
            &[
                DeltaOp::Upsert { src: 1, dst: 2, w: 3.0 },
                DeltaOp::Delete { src: 1, dst: 2 },
                DeltaOp::Upsert { src: 1, dst: 2, w: 5.0 },
                DeltaOp::Delete { src: 4, dst: 5 },
            ],
        );
        assert_eq!(d.upserts(), 1);
        assert_eq!(d.tombstones(), 1);
        assert!(d.masked(1, 2), "an upsert masks the base pair");
        assert!(d.masked(4, 5));
        assert!(!d.masked(2, 1));
        let (dsts, ws) = d.row_upserts(1);
        assert_eq!((dsts, ws), (&[2u32][..], &[5.0f32][..]));
    }

    #[test]
    fn merged_coo_matches_naive_edge_set() {
        let base = base_graph(7, 50, 300);
        let ops = random_ops(8, 50, 60);
        let d = DeltaOverlay::from_ops(50, &ops);
        let merged = merged_coo(&base, &d);
        assert_eq!(merged.m(), d.merged_m(&base));
        // Every surviving base edge is unmasked; every upsert appears
        // exactly once.
        for i in 0..merged.m() {
            let (s, t) = (merged.src[i], merged.dst[i]);
            let up = d.row_upserts(s).0.binary_search(&t).is_ok();
            assert!(up || !d.masked(s, t), "edge ({s},{t}) must be live");
        }
        for i in 0..d.up_src.len() {
            let (s, t) = (d.up_src[i], d.up_dst[i]);
            let copies = (0..merged.m())
                .filter(|&e| merged.src[e] == s && merged.dst[e] == t)
                .count();
            assert_eq!(copies, 1, "upsert ({s},{t}) appears exactly once");
        }
    }

    #[test]
    fn spmv_merged_matches_materialized_and_parallel_is_bit_identical() {
        let base = base_graph(11, 2000, 40_000);
        let ops = random_ops(12, 2000, 500);
        let d = DeltaOverlay::from_ops(2000, &ops);
        let x: Vec<f32> = (0..2000).map(|i| ((i % 97) as f32) * 0.125 - 6.0).collect();
        let seq = spmv_merged(&base, &d, &x);
        // The materialized merged CSR preserves the canonical row order,
        // so the plain kernel over it reproduces the merge bitwise.
        let mat = convert::coo_to_csr(&merged_coo(&base, &d));
        let want = spmv::spmv_pull(&mat, &x);
        assert_eq!(seq.len(), want.len());
        for v in 0..seq.len() {
            assert_eq!(seq[v].to_bits(), want[v].to_bits(), "row {v} diverges");
        }
        for threads in [1, 2, 4, 7] {
            let _t = parallel::ThreadGuard::pin(threads);
            let par = spmv_merged_parallel(&base, &d, &x);
            for v in 0..seq.len() {
                assert_eq!(
                    seq[v].to_bits(),
                    par[v].to_bits(),
                    "thread count {threads}, row {v}"
                );
            }
        }
    }

    #[test]
    fn empty_overlay_is_the_identity_for_spmv() {
        let base = base_graph(13, 300, 2000);
        let d = DeltaOverlay::empty(300);
        let x: Vec<f32> = (0..300).map(|i| i as f32 * 0.5).collect();
        let merged = spmv_merged(&base, &d, &x);
        let plain = spmv::spmv_pull(&base, &x);
        for v in 0..300 {
            assert_eq!(merged[v].to_bits(), plain[v].to_bits());
        }
    }

    #[test]
    fn pagerank_merged_seq_par_bit_identical_and_close_to_materialized() {
        let base = base_graph(17, 20_000, 120_000);
        let tr = base.transposed_structure();
        let ops = random_ops(18, 20_000, 2_000);
        let d = DeltaOverlay::from_ops(20_000, &ops);
        let p = PrParams { max_iters: 10, ..Default::default() };
        let seq = pagerank_merged(&base, &tr, &d, p);
        for threads in [1, 3, 6] {
            let _t = parallel::ThreadGuard::pin(threads);
            let par = pagerank_merged_parallel(&base, &tr, &d, p);
            assert_eq!(seq.iters, par.iters);
            for v in 0..base.n() {
                assert_eq!(
                    seq.ranks[v].to_bits(),
                    par.ranks[v].to_bits(),
                    "thread count {threads}, vertex {v}"
                );
            }
        }
        // Semantics check (not bitwise — summation orders differ): the
        // merged kernel agrees with plain PageRank on the materialized
        // merged graph to f32 tolerance.
        let mat = convert::coo_to_csr(&merged_coo(&base, &d));
        let want = crate::algos::pagerank::pagerank(&mat, p);
        let err: f64 = seq
            .ranks
            .iter()
            .zip(&want.ranks)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .sum();
        assert!(err < 1e-4, "L1 divergence {err} from materialized PageRank");
    }

    #[test]
    fn sssp_merged_respects_inserts_deletes_and_parallel_fixpoint() {
        // Path 0→1→2→3 with a shortcut delete and an inserted bridge.
        let base = convert::coo_to_csr(&Coo::new(
            5,
            vec![0, 1, 2, 0],
            vec![1, 2, 3, 3],
        ));
        let d = DeltaOverlay::from_ops(
            5,
            &[
                DeltaOp::Delete { src: 0, dst: 3 }, // remove the shortcut
                DeltaOp::Upsert { src: 3, dst: 4, w: 1.0 },
            ],
        );
        let dist = sssp_merged(&base, &d, 0);
        assert_eq!(dist[3], 3.0, "shortcut deleted — path goes the long way");
        assert_eq!(dist[4], 4.0, "inserted bridge reaches vertex 4");
        // Random graph: parallel fixpoint is bitwise equal.
        let big = base_graph(23, 3000, 30_000);
        let ops = random_ops(24, 3000, 400);
        let dd = DeltaOverlay::from_ops(3000, &ops);
        let seq = sssp_merged(&big, &dd, 0);
        for threads in [2, 5] {
            let _t = parallel::ThreadGuard::pin(threads);
            let par = sssp_merged_parallel(&big, &dd, 0);
            for v in 0..3000 {
                assert_eq!(seq[v].to_bits(), par[v].to_bits(), "vertex {v}");
            }
        }
    }

    #[test]
    fn merged_out_degrees_track_masks_and_upserts() {
        let base = convert::coo_to_csr(&Coo::new(4, vec![0, 0, 0, 1], vec![1, 1, 2, 3]));
        // Row 0 has a duplicate (0,1): an upsert collapses both copies
        // into one edge; a delete of (0,2) masks one more.
        let d = DeltaOverlay::from_ops(
            4,
            &[
                DeltaOp::Upsert { src: 0, dst: 1, w: 1.0 },
                DeltaOp::Delete { src: 0, dst: 2 },
                DeltaOp::Upsert { src: 2, dst: 0, w: 1.0 },
            ],
        );
        let deg = d.merged_out_degrees(&base);
        assert_eq!(deg, vec![1, 1, 1, 0]);
        assert_eq!(d.merged_m(&base), 3);
    }
}
