//! Graph representations and data sources.
//!
//! The paper's pipeline starts from a **COO edge list** ([`Coo`]) — the
//! dominant on-disk format (Matrix Market, SNAP `.el`) — and converts to
//! **CSR** ([`Csr`]) for computation. [`gen`] provides the synthetic
//! dataset families standing in for the paper's SuiteSparse/SNAP corpus
//! (see DESIGN.md §2), and [`io`] reads/writes the interchange formats.

pub mod coo;
pub mod csr;
pub mod delta;
pub mod gen;
pub mod io;

pub use coo::Coo;
pub use csr::Csr;
