//! CSR (compressed sparse row) representation — "the most popular format
//! for computation" [Filippone et al. 2017], target of the paper's
//! Problem-3 conversion stage and input of every graph kernel here.

/// Compressed sparse row graph/matrix.
///
/// Row `v`'s neighbors (out-neighbors of vertex `v`, non-zero columns of
/// row `v`) are `col_idx[row_ptr[v] .. row_ptr[v+1]]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// `n + 1` row offsets.
    pub row_ptr: Vec<u64>,
    /// `m` column indices.
    pub col_idx: Vec<u32>,
    /// Optional `m` values (`None` ⇒ unweighted / all-ones).
    pub vals: Option<Vec<f32>>,
}

impl Csr {
    /// Number of vertices/rows.
    #[inline]
    pub fn n(&self) -> usize {
        self.row_ptr.len() - 1
    }

    /// Number of edges/non-zeros.
    #[inline]
    pub fn m(&self) -> usize {
        self.col_idx.len()
    }

    /// Neighbor slice of `v` (`N^out(v)` in the paper's notation).
    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[v] as usize..self.row_ptr[v + 1] as usize]
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        (self.row_ptr[v + 1] - self.row_ptr[v]) as usize
    }

    /// Values slice of `v`'s row, if weighted.
    #[inline]
    pub fn row_vals(&self, v: usize) -> Option<&[f32]> {
        self.vals
            .as_ref()
            .map(|vv| &vv[self.row_ptr[v] as usize..self.row_ptr[v + 1] as usize])
    }

    /// Maximum out-degree.
    pub fn max_degree(&self) -> usize {
        (0..self.n()).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Structural validation: monotone `row_ptr`, terminal offset == m,
    /// all columns `< n`.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.row_ptr.is_empty() {
            anyhow::bail!("row_ptr must have n+1 entries");
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() as usize != self.m() {
            anyhow::bail!("row_ptr endpoints wrong");
        }
        for w in self.row_ptr.windows(2) {
            if w[0] > w[1] {
                anyhow::bail!("row_ptr not monotone");
            }
        }
        let n = self.n() as u32;
        if let Some(&bad) = self.col_idx.iter().find(|&&c| c >= n) {
            anyhow::bail!("column {bad} out of range n={n}");
        }
        if let Some(v) = &self.vals {
            if v.len() != self.col_idx.len() {
                anyhow::bail!("vals length mismatch");
            }
        }
        Ok(())
    }

    /// Whether every adjacency list is sorted ascending (required by the
    /// TC set-intersection kernel).
    pub fn rows_sorted(&self) -> bool {
        (0..self.n()).all(|v| self.neighbors(v).windows(2).all(|w| w[0] <= w[1]))
    }

    /// Sort every adjacency list in place (values follow their columns).
    pub fn sort_rows(&mut self) {
        let n = self.n();
        match &mut self.vals {
            None => {
                for v in 0..n {
                    let (lo, hi) = (self.row_ptr[v] as usize, self.row_ptr[v + 1] as usize);
                    self.col_idx[lo..hi].sort_unstable();
                }
            }
            Some(vals) => {
                for v in 0..n {
                    let (lo, hi) = (self.row_ptr[v] as usize, self.row_ptr[v + 1] as usize);
                    let mut pairs: Vec<(u32, f32)> = self.col_idx[lo..hi]
                        .iter()
                        .copied()
                        .zip(vals[lo..hi].iter().copied())
                        .collect();
                    pairs.sort_unstable_by_key(|p| p.0);
                    for (k, (c, w)) in pairs.into_iter().enumerate() {
                        self.col_idx[lo + k] = c;
                        vals[lo + k] = w;
                    }
                }
            }
        }
    }

    /// The transpose (CSC view of the same matrix, materialized as CSR of
    /// the reverse graph). Pull-mode kernels over in-neighborhoods use
    /// this.
    pub fn transposed(&self) -> Csr {
        self.transpose_impl(true)
    }

    /// The transpose of the adjacency structure only — `vals` are never
    /// materialized. Pull-mode kernels that ignore edge weights (the
    /// deterministic parallel PageRank) use this instead of
    /// [`Csr::transposed`] to skip building an O(m) weight array they
    /// would immediately drop. The counting sort is stable, so row `u`
    /// lists in-neighbors in ascending source order, exactly like
    /// [`Csr::transposed`].
    pub fn transposed_structure(&self) -> Csr {
        self.transpose_impl(false)
    }

    /// The one stable-counting-sort transpose skeleton behind both
    /// public forms (the stability — row `u` lists in-neighbors in
    /// ascending `(source, edge)` order — is load-bearing: the
    /// deterministic parallel PageRank reproduces the sequential push
    /// kernel's f32 addition order through it).
    fn transpose_impl(&self, want_vals: bool) -> Csr {
        let n = self.n();
        let mut counts = vec![0u64; n + 1];
        for &c in &self.col_idx {
            counts[c as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let row_ptr = counts.clone();
        let mut cursor = counts;
        let mut col_idx = vec![0u32; self.m()];
        let mut vals = if want_vals {
            self.vals.as_ref().map(|_| vec![0f32; self.m()])
        } else {
            None
        };
        for v in 0..n {
            let (lo, hi) = (self.row_ptr[v] as usize, self.row_ptr[v + 1] as usize);
            for e in lo..hi {
                let c = self.col_idx[e] as usize;
                let pos = cursor[c] as usize;
                cursor[c] += 1;
                col_idx[pos] = v as u32;
                if let (Some(out), Some(inp)) = (vals.as_mut(), self.vals.as_ref()) {
                    out[pos] = inp[e];
                }
            }
        }
        Csr { row_ptr, col_idx, vals }
    }

    /// Bytes occupied (offsets + indices + values), for Table-2 style
    /// inventory rows.
    pub fn bytes_offsets(&self) -> u64 {
        (self.row_ptr.len() * 8) as u64
    }

    /// Bytes of the index array.
    pub fn bytes_indices(&self) -> u64 {
        (self.col_idx.len() * 4) as u64
    }

    /// Bytes of the value array (0 for unweighted graphs). Completes
    /// the inventory triple for the kernel-format byte accounting
    /// ([`crate::runtime::format`]).
    pub fn bytes_vals(&self) -> u64 {
        self.vals.as_ref().map_or(0, |v| (v.len() * 4) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Csr {
        // 0: [1,2]  1: [2]  2: [0]
        Csr { row_ptr: vec![0, 2, 3, 4], col_idx: vec![1, 2, 2, 0], vals: None }
    }

    #[test]
    fn accessors() {
        let g = tiny();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.degree(1), 1);
        assert_eq!(g.max_degree(), 2);
        g.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_colidx() {
        let g = Csr { row_ptr: vec![0, 1], col_idx: vec![5], vals: None };
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_nonmonotone() {
        let g = Csr { row_ptr: vec![0, 2, 1], col_idx: vec![0, 0], vals: None };
        assert!(g.validate().is_err());
    }

    #[test]
    fn transpose_involution() {
        let g = tiny();
        let t = g.transposed();
        // Transpose twice (with sorted rows) gives back the original
        // structure.
        let mut tt = t.transposed();
        tt.sort_rows();
        let mut gg = g.clone();
        gg.sort_rows();
        assert_eq!(tt, gg);
    }

    #[test]
    fn transpose_reverses_edges() {
        let g = tiny();
        let t = g.transposed();
        // Edge 0→1 in g implies 1→0 in t.
        assert!(t.neighbors(1).contains(&0));
        assert!(t.neighbors(2).contains(&0));
        assert!(t.neighbors(2).contains(&1));
        assert!(t.neighbors(0).contains(&2));
        assert_eq!(t.m(), g.m());
    }

    #[test]
    fn transposed_structure_matches_transposed_minus_vals() {
        let g = Csr {
            row_ptr: vec![0, 2, 3, 4],
            col_idx: vec![1, 2, 2, 0],
            vals: Some(vec![1.0, 2.0, 3.0, 4.0]),
        };
        let full = g.transposed();
        let structure = g.transposed_structure();
        assert_eq!(structure.row_ptr, full.row_ptr);
        assert_eq!(structure.col_idx, full.col_idx, "same stable in-neighbor order");
        assert!(structure.vals.is_none());
        assert!(full.vals.is_some());
    }

    #[test]
    fn sort_rows_with_vals_keeps_pairing() {
        let mut g = Csr {
            row_ptr: vec![0, 3],
            col_idx: vec![2, 0, 1],
            vals: Some(vec![2.0, 0.0, 1.0]),
        };
        g.sort_rows();
        assert_eq!(g.col_idx, vec![0, 1, 2]);
        assert_eq!(g.vals.unwrap(), vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn rows_sorted_detects() {
        let g = tiny();
        assert!(g.rows_sorted());
        let bad = Csr { row_ptr: vec![0, 2], col_idx: vec![1, 0], vals: None };
        assert!(!bad.rows_sorted());
    }
}
