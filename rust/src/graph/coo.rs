//! COO (coordinate / edge-list) graph representation — the paper's input
//! format and the representation BOBA operates on directly.

use crate::util::prng::Xoshiro256;

/// A directed graph as parallel source/destination arrays, `COO(G) = (I, J)`
/// in the paper's notation, with an optional edge-value array for SpMV.
///
/// Vertex IDs are `u32` (the paper's datasets top out at 23.9M vertices);
/// edge counts are `usize`.
#[derive(Clone, Debug, PartialEq)]
pub struct Coo {
    /// Number of vertices `n = |V(G)|`. IDs in `src`/`dst` are `< n`.
    pub n: usize,
    /// Edge sources, `I`.
    pub src: Vec<u32>,
    /// Edge destinations, `J`.
    pub dst: Vec<u32>,
    /// Optional edge weights (SpMV values); `None` ⇒ unweighted (1.0).
    pub vals: Option<Vec<f32>>,
}

impl Coo {
    /// Build an unweighted COO; panics in debug if an endpoint is out of
    /// range or the arrays disagree in length.
    pub fn new(n: usize, src: Vec<u32>, dst: Vec<u32>) -> Self {
        debug_assert_eq!(src.len(), dst.len());
        debug_assert!(src.iter().chain(dst.iter()).all(|&v| (v as usize) < n));
        Self { n, src, dst, vals: None }
    }

    /// Build a weighted COO.
    pub fn with_vals(n: usize, src: Vec<u32>, dst: Vec<u32>, vals: Vec<f32>) -> Self {
        debug_assert_eq!(src.len(), vals.len());
        let mut c = Self::new(n, src, dst);
        c.vals = Some(vals);
        c
    }

    /// Number of edges `m = |E(G)|`.
    #[inline]
    pub fn m(&self) -> usize {
        self.src.len()
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Validate structural invariants (every endpoint `< n`, lengths
    /// agree). Returns an error naming the first violation.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.src.len() != self.dst.len() {
            anyhow::bail!("src/dst length mismatch: {} vs {}", self.src.len(), self.dst.len());
        }
        if let Some(v) = &self.vals {
            if v.len() != self.src.len() {
                anyhow::bail!("vals length mismatch: {} vs {}", v.len(), self.src.len());
            }
        }
        for (i, (&s, &d)) in self.src.iter().zip(&self.dst).enumerate() {
            if s as usize >= self.n || d as usize >= self.n {
                anyhow::bail!("edge {i} = ({s},{d}) out of range n={}", self.n);
            }
        }
        Ok(())
    }

    /// Out-degrees of every vertex (one linear pass over `I`).
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.n];
        for &s in &self.src {
            deg[s as usize] += 1;
        }
        deg
    }

    /// Total degrees (in + out), the degree notion BOBA's preferential-
    /// attachment intuition uses (appearances in `I++J`).
    pub fn total_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.n];
        for &s in &self.src {
            deg[s as usize] += 1;
        }
        for &d in &self.dst {
            deg[d as usize] += 1;
        }
        deg
    }

    /// Apply a vertex relabeling: edge `(u, v)` becomes
    /// `(new_of_old[u], new_of_old[v])`. Edge order and values are
    /// preserved (reordering relabels vertices, it does not permute the
    /// edge list).
    pub fn relabeled(&self, new_of_old: &[u32]) -> Coo {
        assert_eq!(new_of_old.len(), self.n);
        let src = self.src.iter().map(|&s| new_of_old[s as usize]).collect();
        let dst = self.dst.iter().map(|&d| new_of_old[d as usize]).collect();
        Coo { n: self.n, src, dst, vals: self.vals.clone() }
    }

    /// Randomize vertex labels with a uniform permutation — the paper's
    /// input model (§5: "We assume that input labels are already
    /// randomized"); destroys any structure in the original IDs.
    pub fn randomized(&self, seed: u64) -> Coo {
        let mut rng = Xoshiro256::new(seed);
        let perm = rng.permutation(self.n);
        self.relabeled(&perm)
    }

    /// Append the reverse of every edge (used to view a directed dataset
    /// as undirected, e.g. for triangle counting).
    pub fn symmetrized(&self) -> Coo {
        let mut src = Vec::with_capacity(self.m() * 2);
        let mut dst = Vec::with_capacity(self.m() * 2);
        src.extend_from_slice(&self.src);
        dst.extend_from_slice(&self.dst);
        src.extend_from_slice(&self.dst);
        dst.extend_from_slice(&self.src);
        let vals = self.vals.as_ref().map(|v| {
            let mut vv = Vec::with_capacity(v.len() * 2);
            vv.extend_from_slice(v);
            vv.extend_from_slice(v);
            vv
        });
        Coo { n: self.n, src, dst, vals }
    }

    /// Remove self-loops and duplicate edges (stable; keeps the first
    /// occurrence). Needed before triangle counting.
    pub fn deduped(&self) -> Coo {
        let mut seen = std::collections::HashSet::with_capacity(self.m());
        let mut src = Vec::with_capacity(self.m());
        let mut dst = Vec::with_capacity(self.m());
        let mut vals = self.vals.as_ref().map(|_| Vec::with_capacity(self.m()));
        for i in 0..self.m() {
            let (s, d) = (self.src[i], self.dst[i]);
            if s == d {
                continue;
            }
            if seen.insert(((s as u64) << 32) | d as u64) {
                src.push(s);
                dst.push(d);
                if let (Some(v), Some(orig)) = (vals.as_mut(), self.vals.as_ref()) {
                    v.push(orig[i]);
                }
            }
        }
        Coo { n: self.n, src, dst, vals }
    }

    /// Iterator over `(src, dst)` pairs.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.src.iter().copied().zip(self.dst.iter().copied())
    }

    /// Sort edges by `(dst, src)` — the "sorted by destination" input
    /// Proposition 10 assumes, and §5.6's recommended pre-pass for
    /// randomly ordered edge lists.
    pub fn sorted_by_dst(&self) -> Coo {
        let mut idx = self.edge_ranks();
        idx.sort_by_key(|&i| ((self.dst[i as usize] as u64) << 32) | self.src[i as usize] as u64);
        self.gathered_u32(&idx)
    }

    /// Sort edges by `(src, dst)` — needed by TC's CSR build so adjacency
    /// lists come out sorted.
    pub fn sorted_by_src(&self) -> Coo {
        let mut idx = self.edge_ranks();
        idx.sort_by_key(|&i| ((self.src[i as usize] as u64) << 32) | self.dst[i as usize] as u64);
        self.gathered_u32(&idx)
    }

    /// `0..m` as `u32` edge ranks — the index width every edge permuter
    /// here uses. Edge counts fit u32 for the paper's datasets; the
    /// assert is unconditional because a silent `as u32` truncation
    /// would drop edges rather than fail.
    fn edge_ranks(&self) -> Vec<u32> {
        assert!(self.m() <= u32::MAX as usize, "edge count {} exceeds u32 ranks", self.m());
        (0..self.m() as u32).collect()
    }

    /// Permute the *edge list* (not vertex labels) by `idx`.
    pub fn gathered(&self, idx: &[usize]) -> Coo {
        let src = idx.iter().map(|&i| self.src[i]).collect();
        let dst = idx.iter().map(|&i| self.dst[i]).collect();
        let vals = self.vals.as_ref().map(|v| idx.iter().map(|&i| v[i]).collect());
        Coo { n: self.n, src, dst, vals }
    }

    /// [`Coo::gathered`] over `u32` edge ranks — what the radix sorts
    /// produce; avoids materializing a widened `Vec<usize>` copy
    /// (8 bytes/edge) of the index array just to gather.
    pub fn gathered_u32(&self, idx: &[u32]) -> Coo {
        let src = idx.iter().map(|&i| self.src[i as usize]).collect();
        let dst = idx.iter().map(|&i| self.dst[i as usize]).collect();
        let vals = self
            .vals
            .as_ref()
            .map(|v| idx.iter().map(|&i| v[i as usize]).collect());
        Coo { n: self.n, src, dst, vals }
    }

    /// Shuffle the edge list order (the adversarial §5.6 scenario).
    pub fn edge_shuffled(&self, seed: u64) -> Coo {
        let mut rng = Xoshiro256::new(seed);
        let mut idx = self.edge_ranks();
        rng.shuffle(&mut idx);
        self.gathered_u32(&idx)
    }

    /// Bytes this COO occupies in memory (for Table 2-style inventory).
    pub fn bytes(&self) -> u64 {
        (self.src.len() * 4 + self.dst.len() * 4
            + self.vals.as_ref().map_or(0, |v| v.len() * 4)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Coo {
        // 0→1, 1→2, 2→0, 0→2
        Coo::new(3, vec![0, 1, 2, 0], vec![1, 2, 0, 2])
    }

    #[test]
    fn construction_and_counts() {
        let g = tiny();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 4);
        g.validate().unwrap();
    }

    #[test]
    fn validate_catches_out_of_range() {
        let g = Coo { n: 2, src: vec![0, 3], dst: vec![1, 1], vals: None };
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_catches_length_mismatch() {
        let g = Coo { n: 2, src: vec![0], dst: vec![1, 0], vals: None };
        assert!(g.validate().is_err());
        let g2 = Coo { n: 2, src: vec![0], dst: vec![1], vals: Some(vec![1.0, 2.0]) };
        assert!(g2.validate().is_err());
    }

    #[test]
    fn degrees() {
        let g = tiny();
        assert_eq!(g.out_degrees(), vec![2, 1, 1]);
        assert_eq!(g.total_degrees(), vec![3, 2, 3]);
    }

    #[test]
    fn relabel_is_involutive_with_inverse() {
        let g = tiny();
        let perm = vec![2u32, 0, 1]; // old->new
        let h = g.relabeled(&perm);
        assert_eq!(h.src, vec![2, 0, 1, 2]);
        assert_eq!(h.dst, vec![0, 1, 2, 1]);
        // Inverse permutation restores the original.
        let mut inv = vec![0u32; 3];
        for (old, &new) in perm.iter().enumerate() {
            inv[new as usize] = old as u32;
        }
        assert_eq!(h.relabeled(&inv), g);
    }

    #[test]
    fn randomized_preserves_structure() {
        let g = tiny();
        let r = g.randomized(99);
        assert_eq!(r.m(), g.m());
        assert_eq!(r.n(), g.n());
        // Degree multiset is invariant under relabeling.
        let mut d0 = g.total_degrees();
        let mut d1 = r.total_degrees();
        d0.sort_unstable();
        d1.sort_unstable();
        assert_eq!(d0, d1);
    }

    #[test]
    fn symmetrize_doubles_edges() {
        let g = tiny();
        let s = g.symmetrized();
        assert_eq!(s.m(), 8);
        // Every reversed edge present.
        let set: std::collections::HashSet<_> = s.edges().collect();
        for (u, v) in g.edges() {
            assert!(set.contains(&(v, u)));
        }
    }

    #[test]
    fn dedup_removes_loops_and_dupes() {
        let g = Coo::new(3, vec![0, 0, 1, 1], vec![0, 1, 2, 2]);
        let d = g.deduped();
        assert_eq!(d.m(), 2);
        assert_eq!(d.src, vec![0, 1]);
        assert_eq!(d.dst, vec![1, 2]);
    }

    #[test]
    fn sort_by_dst_orders() {
        let g = tiny().sorted_by_dst();
        for i in 1..g.m() {
            let prev = ((g.dst[i - 1] as u64) << 32) | g.src[i - 1] as u64;
            let cur = ((g.dst[i] as u64) << 32) | g.src[i] as u64;
            assert!(prev <= cur);
        }
    }

    #[test]
    fn edge_shuffle_preserves_multiset() {
        let g = tiny();
        let s = g.edge_shuffled(4);
        let mut a: Vec<_> = g.edges().collect();
        let mut b: Vec<_> = s.edges().collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn gathered_u32_matches_gathered() {
        let g = Coo::with_vals(3, vec![0, 1, 2, 0], vec![1, 2, 0, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let idx = [3usize, 0, 2, 1];
        let idx32: Vec<u32> = idx.iter().map(|&i| i as u32).collect();
        assert_eq!(g.gathered(&idx), g.gathered_u32(&idx32));
    }

    #[test]
    fn weighted_roundtrip() {
        let g = Coo::with_vals(2, vec![0, 1], vec![1, 0], vec![0.5, 2.5]);
        let r = g.relabeled(&[1, 0]);
        assert_eq!(r.vals.unwrap(), vec![0.5, 2.5]);
    }
}
