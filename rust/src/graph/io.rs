//! Graph file I/O: Matrix Market (`.mtx`) and plain edge lists (`.el`) —
//! the formats the paper identifies as the dominant entry points to graph
//! pipelines (SuiteSparse, SNAP, networkrepository all ship them).
//!
//! Matching the paper's workflow observation, `read_*` functions return
//! **COO** — conversion to CSR is an explicit, measured pipeline stage
//! (`crate::convert`), never hidden inside the reader.

use super::coo::Coo;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Read a Matrix Market coordinate file into COO.
///
/// Supports `matrix coordinate (pattern|real|integer) (general|symmetric)`.
/// Symmetric files get their mirrored edges materialized (like SciPy's
/// `mmread` + `coo_matrix`). 1-based indices are converted to 0-based.
pub fn read_matrix_market(path: &Path) -> anyhow::Result<Coo> {
    let f = std::fs::File::open(path)?;
    let mut lines = std::io::BufReader::new(f).lines();

    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty file"))??;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 5 || !h[0].starts_with("%%MatrixMarket") {
        anyhow::bail!("not a MatrixMarket file: {header:?}");
    }
    if h[1] != "matrix" || h[2] != "coordinate" {
        anyhow::bail!("only 'matrix coordinate' supported, got {header:?}");
    }
    let field = h[3]; // pattern | real | integer
    let symmetry = h[4]; // general | symmetric
    if !matches!(field, "pattern" | "real" | "integer") {
        anyhow::bail!("unsupported field type {field}");
    }
    if !matches!(symmetry, "general" | "symmetric") {
        anyhow::bail!("unsupported symmetry {symmetry}");
    }

    // Skip comments; first data line is "rows cols nnz".
    let mut dims: Option<(usize, usize, usize)> = None;
    let mut src = Vec::new();
    let mut dst = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        if dims.is_none() {
            let r: usize = it.next().unwrap().parse()?;
            let c: usize = it.next().unwrap().parse()?;
            let nnz: usize = it.next().unwrap().parse()?;
            dims = Some((r, c, nnz));
            src.reserve(nnz);
            dst.reserve(nnz);
            continue;
        }
        let i: u64 = it.next().ok_or_else(|| anyhow::anyhow!("short line"))?.parse()?;
        let j: u64 = it.next().ok_or_else(|| anyhow::anyhow!("short line"))?.parse()?;
        if i == 0 || j == 0 {
            anyhow::bail!("MatrixMarket indices are 1-based; found 0");
        }
        src.push((i - 1) as u32);
        dst.push((j - 1) as u32);
        if field != "pattern" {
            let v: f32 = it.next().map(|s| s.parse()).transpose()?.unwrap_or(1.0);
            vals.push(v);
        }
        if symmetry == "symmetric" && i != j {
            src.push((j - 1) as u32);
            dst.push((i - 1) as u32);
            if field != "pattern" {
                vals.push(*vals.last().unwrap());
            }
        }
    }
    let (r, c, _) = dims.ok_or_else(|| anyhow::anyhow!("missing size line"))?;
    let n = r.max(c);
    let mut coo = Coo::new(n, src, dst);
    if field != "pattern" {
        coo.vals = Some(vals);
    }
    coo.validate()?;
    Ok(coo)
}

/// Write COO as MatrixMarket `matrix coordinate real general`.
pub fn write_matrix_market(coo: &Coo, path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let field = if coo.vals.is_some() { "real" } else { "pattern" };
    writeln!(w, "%%MatrixMarket matrix coordinate {field} general")?;
    writeln!(w, "% written by boba (BOBA reproduction)")?;
    writeln!(w, "{} {} {}", coo.n(), coo.n(), coo.m())?;
    match &coo.vals {
        Some(v) => {
            for i in 0..coo.m() {
                writeln!(w, "{} {} {}", coo.src[i] + 1, coo.dst[i] + 1, v[i])?;
            }
        }
        None => {
            for i in 0..coo.m() {
                writeln!(w, "{} {}", coo.src[i] + 1, coo.dst[i] + 1)?;
            }
        }
    }
    Ok(())
}

/// Read a whitespace-separated edge list (`u v` per line, `#` comments),
/// SNAP style. IDs need not be dense: they are *relabeled to a dense
/// 0..n range in first-appearance order* — which is exactly a sequential
/// BOBA pass (the paper's observation that pipelines that must renumber
/// anyway get BOBA for free). Set `preserve_ids = true` to instead keep
/// numeric IDs (n = max + 1, or the header's `n=` if larger — so a
/// [`write_edge_list`] round-trip preserves trailing isolated vertices).
pub fn read_edge_list(path: &Path, preserve_ids: bool) -> anyhow::Result<Coo> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut raw: Vec<(u64, u64)> = Vec::new();
    let mut header_n: Option<usize> = None;
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            // Our own writer records `n=` in a comment; honor it so
            // vertex count survives the round-trip. Only a token-
            // boundary match counts — `min=`/`mean=` in third-party
            // headers must not be misread as a vertex count.
            if header_n.is_none() {
                for (at, _) in t.match_indices("n=") {
                    let at_boundary = at == 0
                        || matches!(t.as_bytes()[at - 1], b' ' | b'\t' | b'#' | b':');
                    if !at_boundary {
                        continue;
                    }
                    let digits: String = t[at + 2..]
                        .chars()
                        .take_while(|c| c.is_ascii_digit())
                        .collect();
                    if let Ok(v) = digits.parse() {
                        header_n = Some(v);
                        break;
                    }
                }
            }
            continue;
        }
        let mut it = t.split_whitespace();
        let u: u64 = it.next().unwrap().parse()?;
        let v: u64 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("edge line with one endpoint: {t:?}"))?
            .parse()?;
        raw.push((u, v));
    }
    if preserve_ids {
        let n_ids = raw.iter().map(|&(u, v)| u.max(v)).max().map_or(0, |x| x + 1) as usize;
        let n = n_ids.max(header_n.unwrap_or(0));
        let src = raw.iter().map(|&(u, _)| u as u32).collect();
        let dst = raw.iter().map(|&(_, v)| v as u32).collect();
        return Ok(Coo::new(n, src, dst));
    }
    // Dense relabel in first-appearance order over I++J — BOBA order.
    let mut map = std::collections::HashMap::new();
    let mut next = 0u32;
    let mut id = |x: u64, map: &mut std::collections::HashMap<u64, u32>| {
        *map.entry(x).or_insert_with(|| {
            let v = next;
            next += 1;
            v
        })
    };
    let mut src = Vec::with_capacity(raw.len());
    let mut dst = Vec::with_capacity(raw.len());
    for &(u, _) in &raw {
        src.push(id(u, &mut map));
    }
    for &(_, v) in &raw {
        dst.push(id(v, &mut map));
    }
    Ok(Coo::new(next as usize, src, dst))
}

/// Write a plain `u v` edge list.
pub fn write_edge_list(coo: &Coo, path: &Path) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# boba edge list: n={} m={}", coo.n(), coo.m())?;
    for i in 0..coo.m() {
        writeln!(w, "{} {}", coo.src[i], coo.dst[i])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("boba_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn mtx_roundtrip_pattern() {
        let g = Coo::new(4, vec![0, 1, 2, 3], vec![1, 2, 3, 0]);
        let p = tmp("rt.mtx");
        write_matrix_market(&g, &p).unwrap();
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(g, h);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_roundtrip_real() {
        let g = Coo::with_vals(3, vec![0, 2], vec![1, 0], vec![1.5, -2.0]);
        let p = tmp("rtv.mtx");
        write_matrix_market(&g, &p).unwrap();
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(h.vals.as_ref().unwrap(), &vec![1.5, -2.0]);
        assert_eq!(h.src, g.src);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_symmetric_mirrors() {
        let p = tmp("sym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern symmetric\n3 3 2\n2 1\n3 3\n",
        )
        .unwrap();
        let g = read_matrix_market(&p).unwrap();
        // (2,1) mirrored to (1,2); diagonal (3,3) not mirrored.
        assert_eq!(g.m(), 3);
        let set: std::collections::HashSet<_> = g.edges().collect();
        assert!(set.contains(&(1, 0)) && set.contains(&(0, 1)) && set.contains(&(2, 2)));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_rejects_garbage() {
        let p = tmp("bad.mtx");
        std::fs::write(&p, "hello world\n1 1 1\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_dense_relabel_is_first_appearance() {
        let p = tmp("el.txt");
        std::fs::write(&p, "# comment\n100 7\n7 100\n500 100\n").unwrap();
        let g = read_edge_list(&p, false).unwrap();
        // First appearances scanning I then J: 100→0, 7→1, 500→2.
        assert_eq!(g.n(), 3);
        assert_eq!(g.src, vec![0, 1, 2]);
        assert_eq!(g.dst, vec![1, 0, 0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_preserved_ids() {
        let p = tmp("el2.txt");
        std::fs::write(&p, "0 5\n2 3\n").unwrap();
        let g = read_edge_list(&p, true).unwrap();
        assert_eq!(g.n(), 6);
        assert_eq!(g.src, vec![0, 2]);
        std::fs::remove_file(&p).ok();
    }

    /// Edge multiset (order-insensitive, multiplicity-sensitive).
    fn edge_multiset(g: &Coo) -> std::collections::HashMap<(u32, u32), u32> {
        let mut m = std::collections::HashMap::new();
        for e in g.edges() {
            *m.entry(e).or_insert(0u32) += 1;
        }
        m
    }

    #[test]
    fn mtx_roundtrip_preserves_n_m_and_multiset() {
        use crate::graph::gen;
        // Generated graph with duplicate edges kept and an isolated
        // trailing vertex (n > max id + 1).
        let mut g = gen::preferential_attachment(500, 4, 11).randomized(12);
        g.n += 3; // three isolated vertices
        let p = tmp("full_rt.mtx");
        write_matrix_market(&g, &p).unwrap();
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(h.n(), g.n(), "n survives (dims line)");
        assert_eq!(h.m(), g.m(), "m survives");
        assert_eq!(edge_multiset(&h), edge_multiset(&g), "edge multiset survives");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_on_disk_is_one_based() {
        let g = Coo::new(3, vec![0, 2], vec![1, 0]);
        let p = tmp("onebased.mtx");
        write_matrix_market(&g, &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let data: Vec<&str> = text
            .lines()
            .filter(|l| !l.starts_with('%'))
            .skip(1) // dims line
            .collect();
        // Edge (0,1) is stored as "1 2", (2,0) as "3 1" — 1-based.
        assert_eq!(data, vec!["1 2", "3 1"]);
        // And reading converts back to 0-based.
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(h.src, g.src);
        assert_eq!(h.dst, g.dst);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_roundtrip_weighted_multiset() {
        let g = Coo::with_vals(
            4,
            vec![0, 1, 1, 3],
            vec![1, 2, 2, 0],
            vec![0.5, -1.25, 2.0, 8.0],
        );
        let p = tmp("wrt.mtx");
        write_matrix_market(&g, &p).unwrap();
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(h.n(), g.n());
        assert_eq!(h.m(), g.m());
        assert_eq!(edge_multiset(&h), edge_multiset(&g));
        assert_eq!(h.vals, g.vals, "values follow their edges");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_roundtrip_preserves_n_via_header() {
        // n = 9 with max id 5: the trailing isolated vertices are only
        // recorded in the writer's `n=` header comment.
        let g = Coo::new(9, vec![0, 5, 2], vec![5, 2, 0]);
        let p = tmp("hdr.el");
        write_edge_list(&g, &p).unwrap();
        let h = read_edge_list(&p, true).unwrap();
        assert_eq!(h.n(), 9, "n survives via the header");
        assert_eq!(h.m(), g.m());
        assert_eq!(edge_multiset(&h), edge_multiset(&g));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_header_ignores_non_boundary_matches() {
        // `mean=` and `min=` contain "n=" but are not a vertex count.
        let p = tmp("fake_hdr.el");
        std::fs::write(&p, "# mean=3.5 min=900000\n0 1\n1 0\n").unwrap();
        let g = read_edge_list(&p, true).unwrap();
        assert_eq!(g.n(), 2, "no phantom vertices from mean=/min=");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = Coo::new(3, vec![0, 1, 2], vec![1, 2, 0]);
        let p = tmp("rt.el");
        write_edge_list(&g, &p).unwrap();
        let h = read_edge_list(&p, true).unwrap();
        assert_eq!(g, h);
        std::fs::remove_file(&p).ok();
    }
}
