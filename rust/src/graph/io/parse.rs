//! Byte-level numeric parsing for the ingest hot loop.
//!
//! The readers in [`super`] never materialize a per-line `String` and
//! never run UTF-8 validation over edge data: a file is one `&[u8]`,
//! lines are subslices, and numbers are decoded by the digit loops
//! here. Integers are a plain checked accumulate; floats take a fast
//! path that is *provably* correctly rounded (mantissa exact in `f32`,
//! divided by an exactly-representable power of ten — one rounding
//! total) and fall back to `str::parse` on the rare token outside that
//! envelope (exponents, > 7 significant digits, inf/nan), so every
//! accepted token decodes bit-identically to the old
//! `BufReader::lines()` + `str::parse` readers.

/// Horizontal whitespace inside a line (CR shows up when a CRLF file's
/// lines are split on `\n` alone; it is trimmed by the line iterator,
/// but tolerate it mid-scan too).
#[inline]
pub(crate) fn is_ws(b: u8) -> bool {
    matches!(b, b' ' | b'\t' | b'\r')
}

/// First non-whitespace position at or after `i`.
#[inline]
pub(crate) fn skip_ws(s: &[u8], mut i: usize) -> usize {
    while i < s.len() && is_ws(s[i]) {
        i += 1;
    }
    i
}

/// Parse an unsigned decimal integer at `s[i..]`. Returns the value and
/// the index one past the last digit; `None` on no digits or overflow.
/// The caller checks that the next byte is whitespace/EOL, so `12x3`
/// is a junk token, not the integer 12.
#[inline]
pub(crate) fn parse_u64_at(s: &[u8], mut i: usize) -> Option<(u64, usize)> {
    let start = i;
    let mut v: u64 = 0;
    while i < s.len() && s[i].is_ascii_digit() {
        v = v.checked_mul(10)?.checked_add((s[i] - b'0') as u64)?;
        i += 1;
    }
    (i > start).then_some((v, i))
}

/// [`parse_u64_at`] with an optional leading `+` — Rust's integer
/// `FromStr` accepts `+3`, so the data-line and size-line parsers must
/// too to stay input-compatible with the old `str::parse` readers.
/// (The `n=` header scan deliberately does NOT use this: the old code
/// collected bare digits only, so `n=+5` was never a match.)
#[inline]
pub(crate) fn parse_int_token(s: &[u8], i: usize) -> Option<(u64, usize)> {
    if i < s.len() && s[i] == b'+' {
        return parse_u64_at(s, i + 1);
    }
    parse_u64_at(s, i)
}

/// End of the token starting at `i` (first whitespace byte or EOL).
#[inline]
pub(crate) fn token_end(s: &[u8], mut i: usize) -> usize {
    while i < s.len() && !is_ws(s[i]) {
        i += 1;
    }
    i
}

/// Exact powers of ten representable in `f32` (10^10 = 5^10 · 2^10 and
/// 5^10 < 2^24, so every entry's significand fits in 24 bits).
const POW10_F32: [f32; 11] = [
    1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10,
];

/// Decode one float token, bit-identical to `tok.parse::<f32>()`.
///
/// Fast path: `sign? digits '.'? digits?` with the all-digits mantissa
/// `< 2^24` and ≤ 10 fraction digits. Then mantissa and divisor are
/// both exact in `f32` and the single division rounds once from the
/// exact rational value — which is precisely the correctly-rounded
/// result `str::parse` computes. Everything else (exponents, long
/// mantissas, `inf`/`nan`) falls back to `str::parse` on the token
/// slice, so the equivalence holds for every accepted input.
pub(crate) fn parse_f32_token(tok: &[u8]) -> Option<f32> {
    let (neg, body) = match tok.first()? {
        b'-' => (true, &tok[1..]),
        b'+' => (false, &tok[1..]),
        _ => (false, &tok[..]),
    };
    let mut mant: u32 = 0;
    let mut frac = 0usize;
    let mut any_digit = false;
    let mut seen_dot = false;
    for &b in body {
        match b {
            b'0'..=b'9' => {
                mant = mant * 10 + (b - b'0') as u32;
                if mant >= 1 << 24 {
                    return parse_f32_fallback(tok);
                }
                any_digit = true;
                if seen_dot {
                    frac += 1;
                }
            }
            b'.' if !seen_dot => seen_dot = true,
            _ => return parse_f32_fallback(tok),
        }
    }
    if !any_digit || frac >= POW10_F32.len() {
        return parse_f32_fallback(tok);
    }
    let v = mant as f32 / POW10_F32[frac];
    Some(if neg { -v } else { v })
}

#[cold]
fn parse_f32_fallback(tok: &[u8]) -> Option<f32> {
    std::str::from_utf8(tok).ok()?.parse::<f32>().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_basics() {
        assert_eq!(parse_u64_at(b"12345 7", 0), Some((12345, 5)));
        assert_eq!(parse_u64_at(b"  42", 2), Some((42, 4)));
        assert_eq!(parse_u64_at(b"x1", 0), None);
        assert_eq!(parse_u64_at(b"", 0), None);
        // Overflow is an error, not a wrap.
        assert_eq!(parse_u64_at(b"99999999999999999999999", 0), None);
        // The caller detects junk via the returned index.
        let (v, at) = parse_u64_at(b"12x3", 0).unwrap();
        assert_eq!((v, at), (12, 2));
    }

    #[test]
    fn int_token_accepts_plus_like_from_str() {
        assert_eq!(parse_int_token(b"+42", 0), Some((42, 3)));
        assert_eq!(parse_int_token(b"42", 0), Some((42, 2)));
        assert_eq!(parse_int_token(b"+", 0), None);
        assert_eq!(parse_int_token(b"-3", 0), None, "u64 stays unsigned");
        assert_eq!(parse_int_token(b"++1", 0), None);
    }

    #[test]
    fn f32_matches_str_parse_exactly() {
        // Fast-path shapes, fallback shapes, and signs — every one must
        // be bit-identical to str::parse.
        for s in [
            "0", "1", "-1", "+1", "1.5", "-2.25", "0.1", "-0.1", "-0",
            "123456.7", "0.0000000001", "16777215", "16777216", "1.",
            ".5", "3.14159265358979", "1e-3", "2.5E+7", "-1e10", "inf",
            "-inf", "1.17549435e-38", "3.4028235e38", "0.30000001",
            "123456789", "9.999999999",
        ] {
            let want: f32 = s.parse().unwrap();
            let got = parse_f32_token(s.as_bytes()).unwrap();
            assert_eq!(got.to_bits(), want.to_bits(), "token {s:?}");
        }
        // NaN compares by bits, not ==.
        let nan = parse_f32_token(b"NaN").unwrap();
        assert_eq!(nan.to_bits(), "NaN".parse::<f32>().unwrap().to_bits());
    }

    #[test]
    fn f32_rejects_junk() {
        for s in ["", ".", "-", "+.", "1.2.3", "12a", "--1"] {
            assert!(parse_f32_token(s.as_bytes()).is_none(), "token {s:?}");
        }
    }

    #[test]
    fn f32_exhaustive_fraction_sweep_vs_str_parse() {
        // A dense sweep over the fast-path envelope boundary: values
        // around 2^24 and many fraction widths.
        for mant in [0u64, 1, 9, 16777215, 16777216, 16777217, 999999999] {
            for frac in 0..12usize {
                let s = if frac == 0 {
                    format!("{mant}")
                } else {
                    let digits = format!("{mant:0>width$}", width = frac.max(1));
                    let split = digits.len() - frac.min(digits.len());
                    format!("{}.{}", &digits[..split], &digits[split..])
                };
                let want: f32 = s.parse().unwrap();
                let got = parse_f32_token(s.as_bytes()).unwrap();
                assert_eq!(got.to_bits(), want.to_bits(), "token {s:?}");
            }
        }
    }

    #[test]
    fn token_end_and_ws() {
        let s = b"abc  def";
        assert_eq!(token_end(s, 0), 3);
        assert_eq!(skip_ws(s, 3), 5);
        assert_eq!(token_end(s, 5), 8);
    }
}
