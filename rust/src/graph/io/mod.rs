//! Graph file ingest: Matrix Market (`.mtx`), plain edge lists
//! (`.el`), and the binary [`bcoo`] sidecar format — the pipeline's
//! front door, and a measured stage of it.
//!
//! The paper measures *end-to-end* graph-creation time, and for text
//! inputs the load stage dominates once reordering and conversion are
//! parallel. These readers therefore never touch `BufReader::lines()`:
//! a file is read into one `Vec<u8>`, split at newline boundaries into
//! per-worker ranges, and parsed straight from the bytes (no per-line
//! `String`, no UTF-8 validation, no `str::parse` in the hot loop —
//! see [`parse`](self) internals) on the [`crate::parallel`] worker
//! pool. Per-worker `(src, dst, vals)` buffers are stitched by
//! [`crate::parallel::par_concat`], so **output order equals file
//! order at every thread count** — the same determinism contract the
//! parallel COO→CSR converters honour. Symmetric-`.mtx` mirroring
//! happens inside each worker (mirror follows its original, exactly
//! like the sequential reader), and `.el` dense relabeling derives
//! first-appearance order from a rank-then-remap pass over per-worker
//! first-position maps.
//!
//! Matching the paper's workflow observation, `read_*` functions return
//! **COO** — conversion to CSR is an explicit, measured pipeline stage
//! (`crate::convert`), never hidden inside the reader.
//!
//! Repeated loads skip text entirely: [`load_graph_file`] consults the
//! write-once `.bcoo` sidecar cache ([`bcoo`] — raw little-endian
//! arrays, loaded at memcpy speed) and falls back to the parallel text
//! parse, writing the sidecar for next time.

pub mod bcoo;
mod parse;

use super::coo::Coo;
use crate::parallel;
use anyhow::{bail, Context};
use parse::{is_ws, parse_f32_token, parse_int_token, parse_u64_at, skip_ws, token_end};
use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};

// ───────────────────────── shared machinery ──────────────────────────

/// Iterator over the lines of `bytes[at..hi)`: yields
/// `(line_start_offset, line)` with the trailing `\n` (and a `\r`
/// before it, for CRLF files) stripped. The final line needs no
/// trailing newline.
struct LineIter<'a> {
    bytes: &'a [u8],
    at: usize,
    hi: usize,
}

impl<'a> Iterator for LineIter<'a> {
    type Item = (usize, &'a [u8]);

    fn next(&mut self) -> Option<(usize, &'a [u8])> {
        if self.at >= self.hi {
            return None;
        }
        let start = self.at;
        let mut end = start;
        while end < self.hi && self.bytes[end] != b'\n' {
            end += 1;
        }
        self.at = end + 1;
        let mut line_end = end;
        if line_end > start && self.bytes[line_end - 1] == b'\r' {
            line_end -= 1;
        }
        Some((start, &self.bytes[start..line_end]))
    }
}

/// Strip leading/trailing horizontal whitespace.
fn trim(line: &[u8]) -> &[u8] {
    let mut lo = 0;
    let mut hi = line.len();
    while lo < hi && is_ws(line[lo]) {
        lo += 1;
    }
    while hi > lo && is_ws(line[hi - 1]) {
        hi -= 1;
    }
    &line[lo..hi]
}

/// 1-based line number of byte `offset` (error paths only — errors are
/// reported with the line they occurred on, computed lazily so the hot
/// path never counts newlines).
fn line_no(bytes: &[u8], offset: usize) -> usize {
    bytes[..offset.min(bytes.len())].iter().filter(|&&b| b == b'\n').count() + 1
}

/// A parse failure inside a worker's range: byte offset of the line it
/// occurred on plus the message. Ranges race, so the caller reports the
/// failure with the *smallest* offset — the same error a sequential
/// scan would have hit first, at every thread count.
struct PErr {
    at: usize,
    msg: String,
}

impl PErr {
    fn new(at: usize, msg: impl Into<String>) -> Self {
        Self { at, msg: msg.into() }
    }
}

/// Parse one integer token at `t[i..]` (optional leading `+`, like
/// `str::parse`), requiring a whitespace/EOL boundary after it so
/// `12x3` is junk, not 12. `what` names the token in both diagnostics;
/// `off` is the line's byte offset for error reporting.
fn expect_int(t: &[u8], i: usize, off: usize, what: &str) -> Result<(u64, usize), PErr> {
    let Some((v, ni)) = parse_int_token(t, i) else {
        return Err(PErr::new(off, format!(
            "expected integer {what} in {:?}",
            String::from_utf8_lossy(t)
        )));
    };
    if ni < t.len() && !is_ws(t[ni]) {
        return Err(PErr::new(off, format!(
            "junk after {what} in {:?}",
            String::from_utf8_lossy(t)
        )));
    }
    Ok((v, ni))
}

/// Split `bytes[start..]` into up to `parts` contiguous ranges whose
/// boundaries sit just past a newline, so no line spans two ranges and
/// concatenating per-range output in range order reproduces file order.
fn newline_ranges(bytes: &[u8], start: usize, parts: usize) -> Vec<(usize, usize)> {
    let len = bytes.len();
    if start >= len {
        return Vec::new();
    }
    let parts = parts.max(1);
    let step = (len - start).div_ceil(parts);
    let mut ranges = Vec::with_capacity(parts);
    let mut lo = start;
    while lo < len {
        let mut hi = (lo + step).min(len);
        while hi < len && bytes[hi - 1] != b'\n' {
            hi += 1;
        }
        ranges.push((lo, hi));
        lo = hi;
    }
    ranges
}

/// Worker count for a data section: one range (sequential, no dispatch)
/// below 64 KiB — at that size dispatch overhead beats the win — else
/// one range per pool worker.
fn ingest_parts(data_len: usize) -> usize {
    if data_len < (1 << 16) {
        1
    } else {
        parallel::threads()
    }
}

/// Fold per-range results, keeping parsed chunks in range order and the
/// earliest (smallest-offset) error if any range failed.
fn collect_chunks<T>(results: Vec<Result<T, PErr>>, bytes: &[u8]) -> anyhow::Result<Vec<T>> {
    let mut chunks = Vec::with_capacity(results.len());
    let mut first_err: Option<PErr> = None;
    for r in results {
        match r {
            Ok(c) => chunks.push(c),
            Err(e) => {
                if first_err.as_ref().map_or(true, |f| e.at < f.at) {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = first_err {
        bail!("line {}: {}", line_no(bytes, e.at), e.msg);
    }
    Ok(chunks)
}

// ───────────────────────── Matrix Market ─────────────────────────────

/// Read a Matrix Market coordinate file into COO, parsing the data
/// section in parallel (see the module docs for the determinism
/// contract).
///
/// Supports `matrix coordinate (pattern|real|integer) (general|symmetric)`.
/// Symmetric files get their mirrored edges materialized (like SciPy's
/// `mmread` + `coo_matrix`). 1-based indices are converted to 0-based.
pub fn read_matrix_market(path: &Path) -> anyhow::Result<Coo> {
    let bytes = std::fs::read(path)?;
    parse_matrix_market(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Parse Matrix Market bytes (the file already in memory). Split out of
/// [`read_matrix_market`] so benches can time parsing without disk.
pub fn parse_matrix_market(bytes: &[u8]) -> anyhow::Result<Coo> {
    let mut lines = LineIter { bytes, at: 0, hi: bytes.len() };
    let (_, header) = lines.next().ok_or_else(|| anyhow::anyhow!("empty file"))?;
    let header_s = String::from_utf8_lossy(header);
    let h: Vec<&str> = header_s.split_whitespace().collect();
    if h.len() < 5 || !h[0].starts_with("%%MatrixMarket") {
        bail!("not a MatrixMarket file: {header_s:?}");
    }
    if h[1] != "matrix" || h[2] != "coordinate" {
        bail!("only 'matrix coordinate' supported, got {header_s:?}");
    }
    let field = h[3]; // pattern | real | integer
    let symmetry = h[4]; // general | symmetric
    if !matches!(field, "pattern" | "real" | "integer") {
        bail!("unsupported field type {field}");
    }
    if !matches!(symmetry, "general" | "symmetric") {
        bail!("unsupported symmetry {symmetry}");
    }
    let pattern = field == "pattern";
    let symmetric = symmetry == "symmetric";

    // Skip comments; first data line is "rows cols nnz". A malformed
    // size line is a proper error naming the line, never a panic.
    let (r, c, _nnz) = loop {
        let Some((off, line)) = lines.next() else {
            bail!("missing size line");
        };
        let t = trim(line);
        if t.is_empty() || t[0] == b'%' {
            continue;
        }
        let Some(dims) = parse_size_line(t) else {
            bail!(
                "line {}: malformed MatrixMarket size line {:?} (expected \"rows cols nnz\")",
                line_no(bytes, off),
                String::from_utf8_lossy(line)
            );
        };
        break dims;
    };
    let data_start = lines.at.min(bytes.len());

    let ranges = newline_ranges(bytes, data_start, ingest_parts(bytes.len() - data_start));
    let results: Vec<Result<MtxChunk, PErr>> = if ranges.len() <= 1 {
        ranges
            .iter()
            .map(|&(lo, hi)| parse_mtx_range(bytes, lo, hi, pattern, symmetric))
            .collect()
    } else {
        parallel::par_jobs(
            ranges
                .iter()
                .map(|&(lo, hi)| move || parse_mtx_range(bytes, lo, hi, pattern, symmetric))
                .collect(),
        )
    };
    let chunks = collect_chunks(results, bytes)?;

    // Move, don't clone: chunks is consumed field-by-field below. A
    // lone chunk (small file, or one worker) is moved out whole — no
    // point memcpying the arrays through the gather.
    let (mut srcs, mut dsts, mut valss) = (Vec::new(), Vec::new(), Vec::new());
    for c in chunks {
        srcs.push(c.src);
        dsts.push(c.dst);
        valss.push(c.vals);
    }
    let (src, dst, vals) = if srcs.len() == 1 {
        let vals = (!pattern).then(|| valss.pop().unwrap());
        (srcs.pop().unwrap(), dsts.pop().unwrap(), vals)
    } else {
        (
            parallel::par_concat(&srcs),
            parallel::par_concat(&dsts),
            (!pattern).then(|| parallel::par_concat(&valss)),
        )
    };

    let n = r.max(c);
    // Struct literal, not Coo::new: an out-of-range index in the file
    // must surface as validate()'s error, not a debug_assert panic.
    let coo = Coo { n, src, dst, vals };
    coo.validate()?;
    Ok(coo)
}

/// Parse `rows cols nnz` (extra trailing tokens tolerated, as before).
fn parse_size_line(t: &[u8]) -> Option<(usize, usize, usize)> {
    let mut i = skip_ws(t, 0);
    let mut out = [0u64; 3];
    for slot in &mut out {
        let (v, ni) = parse_int_token(t, i)?;
        if ni < t.len() && !is_ws(t[ni]) {
            return None; // junk glued to the number
        }
        *slot = v;
        i = skip_ws(t, ni);
    }
    Some((out[0] as usize, out[1] as usize, out[2] as usize))
}

/// One worker's share of a Matrix Market data section.
struct MtxChunk {
    src: Vec<u32>,
    dst: Vec<u32>,
    vals: Vec<f32>,
}

fn parse_mtx_range(
    bytes: &[u8],
    lo: usize,
    hi: usize,
    pattern: bool,
    symmetric: bool,
) -> Result<MtxChunk, PErr> {
    // ~"1 2\n" is 4 bytes; an eighth of the range is a conservative
    // line-count guess that avoids most regrows without overshooting.
    let est = (hi - lo) / 8 + 4;
    let cap = if symmetric { est * 2 } else { est };
    let mut src = Vec::with_capacity(cap);
    let mut dst = Vec::with_capacity(cap);
    let mut vals = Vec::with_capacity(if pattern { 0 } else { cap });
    for (off, line) in (LineIter { bytes, at: lo, hi }) {
        let t = trim(line);
        if t.is_empty() || t[0] == b'%' {
            continue;
        }
        let i0 = skip_ws(t, 0);
        let (iv, n1) = expect_int(t, i0, off, "row index")?;
        let i1 = skip_ws(t, n1);
        if i1 >= t.len() {
            return Err(PErr::new(off, "short line".to_string()));
        }
        let (jv, n2) = expect_int(t, i1, off, "column index")?;
        if iv == 0 || jv == 0 {
            return Err(PErr::new(off, "MatrixMarket indices are 1-based; found 0"));
        }
        if iv > u32::MAX as u64 + 1 || jv > u32::MAX as u64 + 1 {
            return Err(PErr::new(off, format!("vertex index {} exceeds the u32 range", iv.max(jv))));
        }
        src.push((iv - 1) as u32);
        dst.push((jv - 1) as u32);
        if !pattern {
            let i2 = skip_ws(t, n2);
            let v = if i2 >= t.len() {
                1.0 // value column omitted, as mmread tolerates
            } else {
                let end = token_end(t, i2);
                match parse_f32_token(&t[i2..end]) {
                    Some(v) => v,
                    None => {
                        return Err(PErr::new(off, format!(
                            "bad value token {:?}",
                            String::from_utf8_lossy(&t[i2..end])
                        )));
                    }
                }
            };
            vals.push(v);
        }
        if symmetric && iv != jv {
            src.push((jv - 1) as u32);
            dst.push((iv - 1) as u32);
            if !pattern {
                vals.push(*vals.last().unwrap());
            }
        }
    }
    Ok(MtxChunk { src, dst, vals })
}

/// Write COO as MatrixMarket `matrix coordinate real general`
/// (`pattern` when unweighted). Edges are formatted into a reusable
/// byte buffer and written in ~64 KiB batches — no per-edge formatter
/// + syscall round trip; output is byte-identical to the old
/// per-`writeln!` writer.
pub fn write_matrix_market(coo: &Coo, path: &Path) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)?;
    let field = if coo.vals.is_some() { "real" } else { "pattern" };
    let mut buf: Vec<u8> = Vec::with_capacity(FLUSH_AT + 64);
    writeln!(buf, "%%MatrixMarket matrix coordinate {field} general")?;
    writeln!(buf, "% written by boba (BOBA reproduction)")?;
    writeln!(buf, "{} {} {}", coo.n(), coo.n(), coo.m())?;
    match &coo.vals {
        Some(v) => {
            for i in 0..coo.m() {
                push_uint(&mut buf, coo.src[i] as u64 + 1);
                buf.push(b' ');
                push_uint(&mut buf, coo.dst[i] as u64 + 1);
                buf.push(b' ');
                write!(buf, "{}", v[i])?;
                buf.push(b'\n');
                flush_if_full(&mut f, &mut buf)?;
            }
        }
        None => {
            for i in 0..coo.m() {
                push_uint(&mut buf, coo.src[i] as u64 + 1);
                buf.push(b' ');
                push_uint(&mut buf, coo.dst[i] as u64 + 1);
                buf.push(b'\n');
                flush_if_full(&mut f, &mut buf)?;
            }
        }
    }
    f.write_all(&buf)?;
    Ok(())
}

const FLUSH_AT: usize = 1 << 16;

#[inline]
fn flush_if_full(f: &mut std::fs::File, buf: &mut Vec<u8>) -> std::io::Result<()> {
    if buf.len() >= FLUSH_AT {
        f.write_all(buf)?;
        buf.clear();
    }
    Ok(())
}

/// Append a decimal integer (same bytes `Display` would produce).
#[inline]
fn push_uint(buf: &mut Vec<u8>, mut v: u64) {
    let mut tmp = [0u8; 20];
    let mut i = tmp.len();
    loop {
        i -= 1;
        tmp[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    buf.extend_from_slice(&tmp[i..]);
}

// ───────────────────────── edge lists ────────────────────────────────

/// Read a whitespace-separated edge list (`u v` per line, `#` comments),
/// SNAP style, parsing in parallel. IDs need not be dense: they are
/// *relabeled to a dense 0..n range in first-appearance order* — which
/// is exactly a sequential BOBA pass (the paper's observation that
/// pipelines that must renumber anyway get BOBA for free); the parallel
/// reader reproduces that order exactly via a rank-then-remap pass
/// (per-worker first-position maps, min-merged, ranked by position).
/// Set `preserve_ids = true` to instead keep numeric IDs (n = max + 1,
/// or the header's `n=` if larger — so a [`write_edge_list`] round-trip
/// preserves trailing isolated vertices).
pub fn read_edge_list(path: &Path, preserve_ids: bool) -> anyhow::Result<Coo> {
    let bytes = std::fs::read(path)?;
    parse_edge_list(&bytes, preserve_ids)
        .with_context(|| format!("parsing {}", path.display()))
}

/// Parse edge-list bytes (the file already in memory). Split out of
/// [`read_edge_list`] so benches can time parsing without disk.
pub fn parse_edge_list(bytes: &[u8], preserve_ids: bool) -> anyhow::Result<Coo> {
    let ranges = newline_ranges(bytes, 0, ingest_parts(bytes.len()));
    let results: Vec<Result<ElChunk, PErr>> = if ranges.len() <= 1 {
        ranges.iter().map(|&(lo, hi)| parse_el_range(bytes, lo, hi)).collect()
    } else {
        parallel::par_jobs(
            ranges.iter().map(|&(lo, hi)| move || parse_el_range(bytes, lo, hi)).collect(),
        )
    };
    let chunks = collect_chunks(results, bytes)?;

    // Our own writer records `n=` in a comment; the first boundary match
    // in file order wins, exactly as the sequential scan found it.
    let header_n = chunks
        .iter()
        .filter_map(|c| c.header_n)
        .min_by_key(|&(off, _)| off)
        .map(|(_, n)| n);

    if preserve_ids {
        let max_id = chunks.iter().filter(|c| !c.src.is_empty()).map(|c| c.max_id).max();
        if let Some(mx) = max_id {
            if mx > u32::MAX as u64 {
                bail!("vertex id {mx} exceeds the u32 vertex-id range");
            }
        }
        let n_ids = max_id.map_or(0, |mx| mx as usize + 1);
        let n = n_ids.max(header_n.unwrap_or(0));
        // Gather + narrow in one pass (every id was range-checked above).
        let src_chunks: Vec<&[u64]> = chunks.iter().map(|c| c.src.as_slice()).collect();
        let dst_chunks: Vec<&[u64]> = chunks.iter().map(|c| c.dst.as_slice()).collect();
        let src = parallel::par_concat_map(&src_chunks, |&v| v as u32);
        let dst = parallel::par_concat_map(&dst_chunks, |&v| v as u32);
        return Ok(Coo { n, src, dst, vals: None });
    }

    // Dense relabel in first-appearance order over I++J — BOBA order.
    // A lone chunk is moved out whole instead of copied through the
    // gather (same fast path as the mtx stitch).
    let (mut srcs, mut dsts) = (Vec::new(), Vec::new());
    for c in chunks {
        srcs.push(c.src);
        dsts.push(c.dst);
    }
    let (src_raw, dst_raw) = if srcs.len() == 1 {
        (srcs.pop().unwrap(), dsts.pop().unwrap())
    } else {
        (parallel::par_concat(&srcs), parallel::par_concat(&dsts))
    };
    let (n, src, dst) = dense_relabel(&src_raw, &dst_raw)?;
    Ok(Coo { n, src, dst, vals: None })
}

/// One worker's share of an edge-list file.
struct ElChunk {
    src: Vec<u64>,
    dst: Vec<u64>,
    /// Max endpoint id in this chunk (meaningful only when non-empty).
    max_id: u64,
    /// First boundary-matched `n=N` header comment: (byte offset, N).
    header_n: Option<(usize, usize)>,
}

fn parse_el_range(bytes: &[u8], lo: usize, hi: usize) -> Result<ElChunk, PErr> {
    let est = (hi - lo) / 8 + 4;
    let mut src = Vec::with_capacity(est);
    let mut dst = Vec::with_capacity(est);
    let mut max_id = 0u64;
    let mut header_n: Option<(usize, usize)> = None;
    for (off, line) in (LineIter { bytes, at: lo, hi }) {
        let t = trim(line);
        if t.is_empty() || t[0] == b'#' || t[0] == b'%' {
            if header_n.is_none() {
                if let Some(n) = scan_header_n(t) {
                    header_n = Some((off, n));
                }
            }
            continue;
        }
        let i0 = skip_ws(t, 0);
        let (u, n1) = expect_int(t, i0, off, "endpoint")?;
        let i1 = skip_ws(t, n1);
        if i1 >= t.len() {
            return Err(PErr::new(off, format!(
                "edge line with one endpoint: {:?}",
                String::from_utf8_lossy(t)
            )));
        }
        let (v, n2) = expect_int(t, i1, off, "endpoint")?;
        max_id = max_id.max(u).max(v);
        src.push(u);
        dst.push(v);
    }
    Ok(ElChunk { src, dst, max_id, header_n })
}

/// Scan a comment line for a token-boundary `n=DIGITS` (our writer's
/// header). Only a boundary match counts — `min=`/`mean=` in
/// third-party headers must not be misread as a vertex count.
fn scan_header_n(t: &[u8]) -> Option<usize> {
    let mut at = 0usize;
    while at + 1 < t.len() {
        if t[at] == b'n' && t[at + 1] == b'=' {
            let at_boundary =
                at == 0 || matches!(t[at - 1], b' ' | b'\t' | b'#' | b':');
            if at_boundary {
                if let Some((v, _)) = parse_u64_at(t, at + 2) {
                    if v <= usize::MAX as u64 {
                        return Some(v as usize);
                    }
                }
            }
        }
        at += 1;
    }
    None
}

/// Rank-then-remap dense relabeling: compute each distinct id's first
/// position in the virtual `I ++ J` sequence (per-worker maps over
/// position ranges, min-merged), sort ids by that rank to assign dense
/// labels, then remap both arrays in parallel. Produces exactly the
/// labels a sequential first-appearance scan assigns.
fn dense_relabel(
    src_raw: &[u64],
    dst_raw: &[u64],
) -> anyhow::Result<(usize, Vec<u32>, Vec<u32>)> {
    let m = src_raw.len();
    let total = 2 * m;
    let parts = if total < (1 << 16) { 1 } else { parallel::threads() };
    let step = total.div_ceil(parts.max(1)).max(1);
    let maps: Vec<HashMap<u64, u64>> = parallel::par_jobs(
        (0..parts)
            .map(|k| {
                let (lo, hi) = ((k * step).min(total), ((k + 1) * step).min(total));
                move || {
                    let mut first = HashMap::new();
                    for p in lo..hi {
                        let id = if p < m { src_raw[p] } else { dst_raw[p - m] };
                        first.entry(id).or_insert(p as u64);
                    }
                    first
                }
            })
            .collect(),
    );
    let mut first: HashMap<u64, u64> = HashMap::new();
    for map in maps {
        for (id, pos) in map {
            first
                .entry(id)
                .and_modify(|p| *p = (*p).min(pos))
                .or_insert(pos);
        }
    }
    let mut order: Vec<(u64, u64)> = first.iter().map(|(&id, &pos)| (pos, id)).collect();
    order.sort_unstable();
    let n = order.len();
    if n > u32::MAX as usize + 1 {
        bail!("{n} distinct vertex ids exceed the u32 label range");
    }
    // Reuse the first-position map as the label map (overwrite values
    // with ranks) instead of building and re-hashing a second
    // HashMap of the same cardinality.
    for (rank, &(_, id)) in order.iter().enumerate() {
        *first.get_mut(&id).expect("id came from this map") = rank as u64;
    }
    let label = first;
    let chunk = parallel::default_chunk(m);
    let src = parallel::par_map_chunks(m, chunk, |lo, _hi, out| {
        for (k, slot) in out.iter_mut().enumerate() {
            *slot = label[&src_raw[lo + k]] as u32;
        }
    });
    let dst = parallel::par_map_chunks(m, chunk, |lo, _hi, out| {
        for (k, slot) in out.iter_mut().enumerate() {
            *slot = label[&dst_raw[lo + k]] as u32;
        }
    });
    Ok((n, src, dst))
}

/// Write a plain `u v` edge list, batched like [`write_matrix_market`]
/// (byte-identical output to the old per-`writeln!` writer).
pub fn write_edge_list(coo: &Coo, path: &Path) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)?;
    let mut buf: Vec<u8> = Vec::with_capacity(FLUSH_AT + 64);
    writeln!(buf, "# boba edge list: n={} m={}", coo.n(), coo.m())?;
    for i in 0..coo.m() {
        push_uint(&mut buf, coo.src[i] as u64);
        buf.push(b' ');
        push_uint(&mut buf, coo.dst[i] as u64);
        buf.push(b'\n');
        flush_if_full(&mut f, &mut buf)?;
    }
    f.write_all(&buf)?;
    Ok(())
}

// ───────────────────────── cached front door ─────────────────────────

/// Load a graph file of any supported on-disk format: `.mtx`,
/// `.el`/`.txt` (text, parsed in parallel), or `.bcoo` (binary,
/// memcpy-speed). Text loads consult the write-once `.bcoo` sidecar
/// cache — `graph.mtx` reads `graph.mtx.bcoo` when it is strictly
/// newer than the source, and writes it (best-effort) after a text
/// parse — unless `BOBA_NO_BCOO_CACHE=1` disables the cache.
/// `preserve_ids` has the [`read_edge_list`] meaning and is part of
/// the cache key (separate sidecar name per mode, plus a flag bit), so
/// the two relabeling modes never cross-serve or thrash each other's
/// cache.
pub fn load_graph_file(path: &Path, preserve_ids: bool) -> anyhow::Result<Coo> {
    if path.to_string_lossy().ends_with(".bcoo") {
        return bcoo::read_bcoo(path);
    }
    let dense = text_dense_mode(path, preserve_ids);
    if bcoo::cache_enabled() {
        if let Some(coo) = bcoo::try_sidecar(path, dense) {
            return Ok(coo);
        }
    }
    let coo = parse_text_file(path, preserve_ids)?;
    if bcoo::cache_enabled() {
        bcoo::write_sidecar(&coo, path, dense);
    }
    Ok(coo)
}

/// The single place the text format-selection policy lives: `.mtx`
/// goes to the Matrix Market reader, everything else is an edge list.
/// Both [`load_graph_file`] and [`convert_to_bcoo`] dispatch through
/// here so the policy cannot drift between them.
fn parse_text_file(path: &Path, preserve_ids: bool) -> anyhow::Result<Coo> {
    if path.to_string_lossy().ends_with(".mtx") {
        read_matrix_market(path)
    } else {
        read_edge_list(path, preserve_ids)
    }
}

/// Whether a text load of `path` produces a dense-relabeled graph —
/// the sidecar cache key companion of [`parse_text_file`]'s dispatch.
fn text_dense_mode(path: &Path, preserve_ids: bool) -> bool {
    !path.to_string_lossy().ends_with(".mtx") && !preserve_ids
}

/// Explicitly convert a text graph file to `.bcoo` (the `boba
/// convert-bcoo` subcommand). Writes to `out` when given, else to the
/// mode's sidecar path (`graph.mtx` → `graph.mtx.bcoo`; a
/// dense-relabeled `.el` → `g.el.dense.bcoo`), and returns the written
/// path plus the parsed graph. Unlike the implicit cache this always
/// writes, and write failures are errors.
pub fn convert_to_bcoo(
    path: &Path,
    out: Option<&Path>,
    preserve_ids: bool,
) -> anyhow::Result<(PathBuf, Coo)> {
    let name = path.to_string_lossy();
    if name.ends_with(".bcoo") {
        bail!("{name} already is a .bcoo file");
    }
    let dense = text_dense_mode(path, preserve_ids);
    let coo = parse_text_file(path, preserve_ids)?;
    let target =
        out.map(Path::to_path_buf).unwrap_or_else(|| bcoo::sidecar_path_for(path, dense));
    let flags = if dense { bcoo::FLAG_DENSE } else { 0 };
    bcoo::write_bcoo_flagged(&coo, &target, flags)
        .with_context(|| format!("writing {}", target.display()))?;
    Ok((target, coo))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("boba_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn mtx_roundtrip_pattern() {
        let g = Coo::new(4, vec![0, 1, 2, 3], vec![1, 2, 3, 0]);
        let p = tmp("rt.mtx");
        write_matrix_market(&g, &p).unwrap();
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(g, h);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_roundtrip_real() {
        let g = Coo::with_vals(3, vec![0, 2], vec![1, 0], vec![1.5, -2.0]);
        let p = tmp("rtv.mtx");
        write_matrix_market(&g, &p).unwrap();
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(h.vals.as_ref().unwrap(), &vec![1.5, -2.0]);
        assert_eq!(h.src, g.src);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_symmetric_mirrors() {
        let p = tmp("sym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern symmetric\n3 3 2\n2 1\n3 3\n",
        )
        .unwrap();
        let g = read_matrix_market(&p).unwrap();
        // (2,1) mirrored to (1,2); diagonal (3,3) not mirrored.
        assert_eq!(g.m(), 3);
        let set: std::collections::HashSet<_> = g.edges().collect();
        assert!(set.contains(&(1, 0)) && set.contains(&(0, 1)) && set.contains(&(2, 2)));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_rejects_garbage() {
        let p = tmp("bad.mtx");
        std::fs::write(&p, "hello world\n1 1 1\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_malformed_size_line_errors_with_line_number() {
        let p = tmp("badsize.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern general\n% c\n3 three 9\n1 1\n",
        )
        .unwrap();
        let err = format!("{:#}", read_matrix_market(&p).unwrap_err());
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("size line"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_dense_relabel_is_first_appearance() {
        let p = tmp("el.txt");
        std::fs::write(&p, "# comment\n100 7\n7 100\n500 100\n").unwrap();
        let g = read_edge_list(&p, false).unwrap();
        // First appearances scanning I then J: 100→0, 7→1, 500→2.
        assert_eq!(g.n(), 3);
        assert_eq!(g.src, vec![0, 1, 2]);
        assert_eq!(g.dst, vec![1, 0, 0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_preserved_ids() {
        let p = tmp("el2.txt");
        std::fs::write(&p, "0 5\n2 3\n").unwrap();
        let g = read_edge_list(&p, true).unwrap();
        assert_eq!(g.n(), 6);
        assert_eq!(g.src, vec![0, 2]);
        std::fs::remove_file(&p).ok();
    }

    /// Edge multiset (order-insensitive, multiplicity-sensitive).
    fn edge_multiset(g: &Coo) -> std::collections::HashMap<(u32, u32), u32> {
        let mut m = std::collections::HashMap::new();
        for e in g.edges() {
            *m.entry(e).or_insert(0u32) += 1;
        }
        m
    }

    #[test]
    fn mtx_roundtrip_preserves_n_m_and_multiset() {
        use crate::graph::gen;
        // Generated graph with duplicate edges kept and an isolated
        // trailing vertex (n > max id + 1).
        let mut g = gen::preferential_attachment(500, 4, 11).randomized(12);
        g.n += 3; // three isolated vertices
        let p = tmp("full_rt.mtx");
        write_matrix_market(&g, &p).unwrap();
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(h.n(), g.n(), "n survives (dims line)");
        assert_eq!(h.m(), g.m(), "m survives");
        assert_eq!(edge_multiset(&h), edge_multiset(&g), "edge multiset survives");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_on_disk_is_one_based() {
        let g = Coo::new(3, vec![0, 2], vec![1, 0]);
        let p = tmp("onebased.mtx");
        write_matrix_market(&g, &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let data: Vec<&str> = text
            .lines()
            .filter(|l| !l.starts_with('%'))
            .skip(1) // dims line
            .collect();
        // Edge (0,1) is stored as "1 2", (2,0) as "3 1" — 1-based.
        assert_eq!(data, vec!["1 2", "3 1"]);
        // And reading converts back to 0-based.
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(h.src, g.src);
        assert_eq!(h.dst, g.dst);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mtx_roundtrip_weighted_multiset() {
        let g = Coo::with_vals(
            4,
            vec![0, 1, 1, 3],
            vec![1, 2, 2, 0],
            vec![0.5, -1.25, 2.0, 8.0],
        );
        let p = tmp("wrt.mtx");
        write_matrix_market(&g, &p).unwrap();
        let h = read_matrix_market(&p).unwrap();
        assert_eq!(h.n(), g.n());
        assert_eq!(h.m(), g.m());
        assert_eq!(edge_multiset(&h), edge_multiset(&g));
        assert_eq!(h.vals, g.vals, "values follow their edges");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_roundtrip_preserves_n_via_header() {
        // n = 9 with max id 5: the trailing isolated vertices are only
        // recorded in the writer's `n=` header comment.
        let g = Coo::new(9, vec![0, 5, 2], vec![5, 2, 0]);
        let p = tmp("hdr.el");
        write_edge_list(&g, &p).unwrap();
        let h = read_edge_list(&p, true).unwrap();
        assert_eq!(h.n(), 9, "n survives via the header");
        assert_eq!(h.m(), g.m());
        assert_eq!(edge_multiset(&h), edge_multiset(&g));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_header_ignores_non_boundary_matches() {
        // `mean=` and `min=` contain "n=" but are not a vertex count.
        let p = tmp("fake_hdr.el");
        std::fs::write(&p, "# mean=3.5 min=900000\n0 1\n1 0\n").unwrap();
        let g = read_edge_list(&p, true).unwrap();
        assert_eq!(g.n(), 2, "no phantom vertices from mean=/min=");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = Coo::new(3, vec![0, 1, 2], vec![1, 2, 0]);
        let p = tmp("rt.el");
        write_edge_list(&g, &p).unwrap();
        let h = read_edge_list(&p, true).unwrap();
        assert_eq!(g, h);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn newline_ranges_tile_and_align() {
        let text = b"aa\nbbbb\nc\n\ndddd\nee";
        for parts in 1..8 {
            let ranges = newline_ranges(text, 0, parts);
            assert_eq!(ranges.first().map(|r| r.0), Some(0));
            assert_eq!(ranges.last().map(|r| r.1), Some(text.len()));
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
                assert_eq!(text[w[0].1 - 1], b'\n', "boundary after newline");
            }
        }
        assert!(newline_ranges(b"", 0, 4).is_empty());
    }

    #[test]
    fn convert_to_bcoo_roundtrips_and_names_sidecar() {
        let g = Coo::new(4, vec![0, 1, 3], vec![1, 2, 0]);
        let p = tmp("conv.mtx");
        write_matrix_market(&g, &p).unwrap();
        let (out, parsed) = convert_to_bcoo(&p, None, true).unwrap();
        assert_eq!(out, bcoo::sidecar_path(&p));
        assert_eq!(parsed, g);
        assert_eq!(bcoo::read_bcoo(&out).unwrap(), g);
        // Already-binary input is rejected, not double-converted.
        assert!(convert_to_bcoo(&out, None, true).is_err());
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&out).ok();
    }

    #[test]
    fn load_graph_file_reads_all_formats() {
        let g = Coo::new(4, vec![0, 1, 3], vec![1, 2, 0]);
        let mtx = tmp("lgf.mtx");
        write_matrix_market(&g, &mtx).unwrap();
        let sc = bcoo::sidecar_path(&mtx);
        std::fs::remove_file(&sc).ok();
        assert_eq!(load_graph_file(&mtx, true).unwrap(), g);
        // The text parse wrote the sidecar; the second load takes it.
        assert!(sc.exists(), "sidecar written after text parse");
        assert_eq!(load_graph_file(&mtx, true).unwrap(), g);
        assert_eq!(load_graph_file(&sc, true).unwrap(), g);
        std::fs::remove_file(&mtx).ok();
        std::fs::remove_file(&sc).ok();
    }
}
