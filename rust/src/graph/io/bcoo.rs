//! `.bcoo` — the versioned little-endian binary COO interchange format
//! and its write-once sidecar cache.
//!
//! Text formats pay tokenizing + decimal decoding per edge no matter
//! how fast the parser is; `.bcoo` stores the three `Coo` arrays as raw
//! little-endian words so a load is header validation + one `memcpy`
//! per array (plus a parallel bounds check — a corrupt cache must fail,
//! not crash a kernel later). Layout, all little-endian:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"BCOO"
//!      4     4  version (u32, currently 2; 1 still readable)
//!      8     4  flags   (u32: bit 0 = has vals, bit 1 = dense-relabeled)
//!     12     8  n       (u64 vertex count)
//!     20     8  m       (u64 edge count)
//!     28    4m  src     (m × u32)
//!   28+4m   4m  dst     (m × u32)
//!   28+8m   4m  vals    (m × f32, present iff flag bit 0)
//!    end     8  FNV-64 checksum of every preceding byte (version ≥ 2)
//! ```
//!
//! Version 2 appends an FNV-1a 64-bit checksum of the whole file body,
//! so a bit-flipped or truncated cache is detected at load instead of
//! silently changing answers (the length check alone cannot catch an
//! in-place flip). Version-1 files (no trailer) are still read — an
//! old cache keeps working until its source changes.
//!
//! The **sidecar cache**: the first text parse of `graph.mtx` writes
//! `graph.mtx.bcoo` next to it; later loads take the binary path when
//! the sidecar's mtime is strictly newer than the source's (strictness
//! keeps coarse-timestamp filesystems on the re-parse side, never the
//! stale side). The two `.el` relabeling modes cache under different
//! names (`g.el.bcoo` preserve-ids, `g.el.dense.bcoo` dense) so mixed
//! consumers keep both warm, and flag bit 1 additionally records the
//! mode so a renamed file is never served for the wrong one. Set
//! `BOBA_NO_BCOO_CACHE=1` to disable both sides of the cache; a stale
//! or wrong-mode sidecar is silently ignored (the text is re-parsed and
//! the sidecar rewritten), never an error. A sidecar that fails to
//! *parse* — bad checksum, truncation, zero length — is **quarantined**:
//! renamed to `<sidecar>.bad` (preserving the evidence for inspection)
//! before the text re-parse rewrites a fresh one, so a corrupt cache
//! can never be retried forever or silently deleted.

use crate::graph::Coo;
use crate::parallel;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Magic bytes every `.bcoo` file starts with.
pub const MAGIC: [u8; 4] = *b"BCOO";
/// Format version this build writes (trailing FNV-64 checksum); it
/// still reads version 1 (checksum-less) files.
pub const VERSION: u32 = 2;
/// Flag bit: the file carries an f32 values array.
pub const FLAG_VALS: u32 = 1;
/// Flag bit: the edge list was dense-relabeled (first-appearance order)
/// at parse time — sidecar cache keying, see the module docs.
pub const FLAG_DENSE: u32 = 1 << 1;

const HEADER_LEN: usize = 28;
/// Bytes of trailing checksum in a version ≥ 2 file.
const SUM_LEN: usize = 8;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64-bit hash — the `.bcoo` integrity checksum. Not
/// cryptographic: it detects bit flips and truncation, which is the
/// failure model for an on-disk cache, at one multiply per byte.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Read a `.bcoo` file.
pub fn read_bcoo(path: &Path) -> Result<Coo> {
    Ok(read_bcoo_flagged(path)?.0)
}

/// Read a `.bcoo` file, returning the graph and the raw flags word.
pub(crate) fn read_bcoo_flagged(path: &Path) -> Result<(Coo, u32)> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_bcoo(&bytes).with_context(|| format!("parsing {}", path.display()))
}

fn parse_bcoo(bytes: &[u8]) -> Result<(Coo, u32)> {
    if bytes.len() < HEADER_LEN {
        bail!("not a .bcoo file: {} bytes is shorter than the header", bytes.len());
    }
    if bytes[..4] != MAGIC {
        bail!("not a .bcoo file (bad magic {:?})", &bytes[..4]);
    }
    let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    let version = u32_at(4);
    if version != 1 && version != VERSION {
        bail!("unsupported .bcoo version {version} (this reader understands 1..={VERSION})");
    }
    let trailer = if version >= 2 { SUM_LEN as u64 } else { 0 };
    let flags = u32_at(8);
    let n = u64_at(12);
    let m = u64_at(20);
    let arrays = if flags & FLAG_VALS != 0 { 3u64 } else { 2 };
    let expected = m
        .checked_mul(4 * arrays)
        .and_then(|b| b.checked_add(HEADER_LEN as u64 + trailer))
        .filter(|&b| b == bytes.len() as u64);
    if expected.is_none() {
        bail!(
            "truncated .bcoo: m={m} with flags {flags:#x} needs {} bytes, file has {}",
            m.saturating_mul(4 * arrays).saturating_add(HEADER_LEN as u64 + trailer),
            bytes.len()
        );
    }
    if version >= 2 {
        let body = &bytes[..bytes.len() - SUM_LEN];
        let stored = u64::from_le_bytes(bytes[bytes.len() - SUM_LEN..].try_into().unwrap());
        let computed = fnv64(body);
        if stored != computed {
            crate::obs::corrupt::inc("bcoo-checksum");
            bail!(
                "corrupt .bcoo: FNV-64 checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
            );
        }
    }
    let (n, m) = (n as usize, m as usize);
    let src = u32s_from_le(&bytes[HEADER_LEN..HEADER_LEN + 4 * m]);
    let dst = u32s_from_le(&bytes[HEADER_LEN + 4 * m..HEADER_LEN + 8 * m]);
    let vals = (flags & FLAG_VALS != 0)
        .then(|| f32s_from_le(&bytes[HEADER_LEN + 8 * m..HEADER_LEN + 12 * m]));
    // Parallel bounds check: a corrupt or hand-edited cache must error
    // here, not index out of range inside a kernel.
    let max_id = parallel::par_reduce(
        m,
        parallel::default_chunk(m),
        0u32,
        |acc, lo, hi| {
            let mut acc = acc;
            for i in lo..hi {
                acc = acc.max(src[i]).max(dst[i]);
            }
            acc
        },
        u32::max,
    );
    if m > 0 && max_id as u64 >= n as u64 {
        bail!("corrupt .bcoo: vertex id {max_id} out of range for n={n}");
    }
    Ok((Coo { n, src, dst, vals }, flags))
}

/// Write `coo` as a `.bcoo` file (vals flag set iff the graph is
/// weighted; dense flag clear — use the sidecar API for cache-keyed
/// writes).
pub fn write_bcoo(coo: &Coo, path: &Path) -> Result<()> {
    write_bcoo_flagged(coo, path, 0)
}

pub(crate) fn write_bcoo_flagged(coo: &Coo, path: &Path, extra_flags: u32) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = HashingWriter {
        inner: std::io::BufWriter::with_capacity(1 << 20, f),
        hash: FNV_OFFSET,
    };
    let mut flags = extra_flags;
    if coo.vals.is_some() {
        flags |= FLAG_VALS;
    }
    w.write_all(&MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&flags.to_le_bytes())?;
    w.write_all(&(coo.n() as u64).to_le_bytes())?;
    w.write_all(&(coo.m() as u64).to_le_bytes())?;
    write_u32s(&mut w, &coo.src)?;
    write_u32s(&mut w, &coo.dst)?;
    if let Some(v) = &coo.vals {
        // f32 and u32 share size/alignment; serialize the bit patterns.
        write_f32s(&mut w, v)?;
    }
    // The trailer hashes everything before it and is not self-hashed.
    let sum = w.hash;
    w.inner.write_all(&sum.to_le_bytes())?;
    w.inner.flush()?;
    Ok(())
}

/// Folds every written byte into an FNV-1a state on the way to the
/// underlying writer, so the version-2 trailer is computed in the same
/// single pass that serializes the arrays.
struct HashingWriter<W: Write> {
    inner: W,
    hash: u64,
}

impl<W: Write> Write for HashingWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        for &b in &buf[..n] {
            self.hash = (self.hash ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        Ok(n)
    }
    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Sidecar path for a text source in the default (preserve-ids / mtx)
/// mode: the full file name plus `.bcoo` (`graph.mtx` →
/// `graph.mtx.bcoo`), so different extensions never collide.
pub fn sidecar_path(path: &Path) -> PathBuf {
    sidecar_path_for(path, false)
}

/// Sidecar path for a given relabeling mode. The two `.el` modes cache
/// under different names (`g.el.bcoo` vs `g.el.dense.bcoo`) so
/// consumers that disagree on `preserve_ids` (the CLI defaults to
/// dense, the registry/repro to preserve) each keep a warm cache
/// instead of invalidating the other's on every load.
pub fn sidecar_path_for(path: &Path, dense: bool) -> PathBuf {
    let mut name = path.as_os_str().to_os_string();
    name.push(if dense { ".dense.bcoo" } else { ".bcoo" });
    PathBuf::from(name)
}

/// True unless `BOBA_NO_BCOO_CACHE` disables the sidecar cache.
pub fn cache_enabled() -> bool {
    match std::env::var("BOBA_NO_BCOO_CACHE") {
        Ok(v) => v.is_empty() || v == "0",
        Err(_) => true,
    }
}

/// Load the sidecar for `path` if it exists, is **strictly newer**
/// than the source, parses cleanly, and was written for the same
/// relabeling mode. Strict ordering is the conservative side of coarse
/// filesystem timestamps: a source rewritten within the mtime
/// granularity of the sidecar write re-parses (wasted work) instead of
/// serving the old graph (wrong result). Any failure means "re-parse
/// the text" — never an error — but a sidecar that fails to *parse*
/// (checksum mismatch, truncation, zero length) is quarantined first:
/// renamed to `<sidecar>.bad` so the corrupt bytes survive for
/// inspection and the fresh rewrite cannot race a retry loop. A stale
/// or wrong-mode sidecar is left in place untouched — it is valid, just
/// not usable for this load. The `corrupt-sidecar` fault point
/// ([`crate::obs::chaos`]) makes an otherwise-healthy read take the
/// corrupt path, exercising quarantine + fallback end to end.
pub(crate) fn try_sidecar(path: &Path, dense: bool) -> Option<Coo> {
    let sc = sidecar_path_for(path, dense);
    let source_mtime = mtime(path)?;
    let sidecar_mtime = mtime(&sc)?;
    if sidecar_mtime <= source_mtime {
        return None; // stale (or indistinguishable from stale)
    }
    let parsed = if crate::obs::chaos::should("corrupt-sidecar") {
        Err(anyhow::anyhow!("injected fault: corrupt-sidecar"))
    } else {
        read_bcoo_flagged(&sc)
    };
    match parsed {
        Ok((coo, flags)) => ((flags & FLAG_DENSE != 0) == dense).then_some(coo),
        Err(e) => {
            quarantine(&sc, &e);
            None
        }
    }
}

/// Rename a corrupt sidecar to `<sidecar>.bad` (best-effort) and log
/// why — the text re-parse that follows rewrites a fresh cache.
fn quarantine(sc: &Path, why: &anyhow::Error) {
    let mut name = sc.as_os_str().to_os_string();
    name.push(".bad");
    let dest = PathBuf::from(name);
    if std::fs::rename(sc, &dest).is_ok() {
        crate::obs::corrupt::inc("bcoo-quarantine");
        eprintln!(
            "[boba] quarantined corrupt sidecar {} -> {} ({why:#}); re-parsing text",
            sc.display(),
            dest.display()
        );
    }
}

/// Per-write tmp-name discriminator: the pid alone is not unique
/// within a process, and the server registry's prepare path can race
/// two threads onto the same sidecar (`GraphRegistry::get_or_prepare`
/// runs prepares outside the lock).
static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Best-effort sidecar write: to a uniquely-named temp file, then an
/// atomic rename so concurrent readers and racing writers (the server
/// registry's worker threads) never see a half-written cache. Failures
/// (read-only dir, full disk) are swallowed — the cache is an
/// optimization, not a deliverable.
pub(crate) fn write_sidecar(coo: &Coo, path: &Path, dense: bool) {
    let sc = sidecar_path_for(path, dense);
    let tmp = {
        let mut name = sc.as_os_str().to_os_string();
        let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        name.push(format!(".tmp{}.{seq}", std::process::id()));
        PathBuf::from(name)
    };
    let flags = if dense { FLAG_DENSE } else { 0 };
    if write_bcoo_flagged(coo, &tmp, flags).is_ok() {
        if std::fs::rename(&tmp, &sc).is_err() {
            std::fs::remove_file(&tmp).ok();
        }
    } else {
        std::fs::remove_file(&tmp).ok();
    }
}

fn mtime(p: &Path) -> Option<std::time::SystemTime> {
    std::fs::metadata(p).ok()?.modified().ok()
}

fn u32s_from_le(bytes: &[u8]) -> Vec<u32> {
    debug_assert_eq!(bytes.len() % 4, 0);
    let n = bytes.len() / 4;
    if cfg!(target_endian = "little") {
        let mut v: Vec<u32> = Vec::with_capacity(n);
        // SAFETY: the reservation holds n u32s = bytes.len() bytes, the
        // ranges don't overlap, and on a little-endian target the byte
        // image of [u32] is the on-disk layout.
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), v.as_mut_ptr() as *mut u8, bytes.len());
            v.set_len(n);
        }
        v
    } else {
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

fn f32s_from_le(bytes: &[u8]) -> Vec<f32> {
    u32s_from_le(bytes).into_iter().map(f32::from_bits).collect()
}

fn write_u32s(w: &mut impl Write, v: &[u32]) -> std::io::Result<()> {
    if cfg!(target_endian = "little") {
        // SAFETY: reinterpreting [u32] as its byte image is always
        // valid (alignment only loosens), and on little-endian the
        // image is the on-disk layout.
        let bytes =
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
        w.write_all(bytes)
    } else {
        let mut buf = Vec::with_capacity(4 << 10);
        for chunk in v.chunks(1 << 10) {
            buf.clear();
            for x in chunk {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        Ok(())
    }
}

fn write_f32s(w: &mut impl Write, v: &[f32]) -> std::io::Result<()> {
    if cfg!(target_endian = "little") {
        // SAFETY: same as write_u32s — f32 has the same size/alignment.
        let bytes =
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
        w.write_all(bytes)
    } else {
        let mut buf = Vec::with_capacity(4 << 10);
        for chunk in v.chunks(1 << 10) {
            buf.clear();
            for x in chunk {
                buf.extend_from_slice(&x.to_bits().to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("boba_bcoo_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_unweighted_and_weighted() {
        let g = Coo::new(5, vec![0, 4, 2, 2], vec![1, 0, 3, 2]);
        let p = tmp("rt.bcoo");
        write_bcoo(&g, &p).unwrap();
        assert_eq!(read_bcoo(&p).unwrap(), g);
        let w = Coo::with_vals(3, vec![0, 2], vec![1, 0], vec![1.5, -0.25]);
        write_bcoo(&w, &p).unwrap();
        assert_eq!(read_bcoo(&p).unwrap(), w);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_empty_graph_keeps_n() {
        let g = Coo::new(7, vec![], vec![]);
        let p = tmp("empty.bcoo");
        write_bcoo(&g, &p).unwrap();
        assert_eq!(read_bcoo(&p).unwrap(), g);
        std::fs::remove_file(&p).ok();
    }

    /// Recompute the version-2 trailer after editing payload bytes, so
    /// a test can reach the checks that run *after* checksum
    /// verification.
    fn patch_sum(bytes: &mut [u8]) {
        let len = bytes.len();
        let sum = fnv64(&bytes[..len - SUM_LEN]);
        bytes[len - SUM_LEN..].copy_from_slice(&sum.to_le_bytes());
    }

    #[test]
    fn rejects_bad_magic_version_truncation_checksum_and_bounds() {
        let g = Coo::new(3, vec![0, 1], vec![1, 2]);
        let p = tmp("bad.bcoo");
        write_bcoo(&g, &p).unwrap();
        let good = std::fs::read(&p).unwrap();

        let chain = |p: &Path| format!("{:#}", read_bcoo(p).unwrap_err());

        let mut bad = good.clone();
        bad[0] = b'X';
        std::fs::write(&p, &bad).unwrap();
        assert!(chain(&p).contains("magic"));

        let mut bad = good.clone();
        bad[4] = 99; // version
        std::fs::write(&p, &bad).unwrap();
        assert!(chain(&p).contains("version"));

        std::fs::write(&p, &good[..good.len() - 3]).unwrap();
        assert!(chain(&p).contains("truncated"));

        let mut bad = good.clone();
        bad[HEADER_LEN] ^= 0x01; // payload bit flip, trailer untouched
        std::fs::write(&p, &bad).unwrap();
        assert!(chain(&p).contains("checksum"));

        let mut bad = good.clone();
        bad[HEADER_LEN] = 200; // src[0] = 200 ≥ n = 3
        patch_sum(&mut bad); // honest trailer so the bounds check runs
        std::fs::write(&p, &bad).unwrap();
        assert!(chain(&p).contains("out of range"));

        std::fs::write(&p, b"BC").unwrap();
        assert!(read_bcoo(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn version_1_files_without_checksum_still_read() {
        let g = Coo::new(4, vec![0, 3], vec![1, 2]);
        let p = tmp("v1.bcoo");
        write_bcoo(&g, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.truncate(bytes.len() - SUM_LEN); // strip the v2 trailer
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert_eq!(read_bcoo(&p).unwrap(), g, "checksum-less v1 caches stay readable");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn fnv64_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn sidecar_path_appends_full_extension_and_keys_by_mode() {
        assert_eq!(
            sidecar_path(Path::new("/x/graph.mtx")),
            PathBuf::from("/x/graph.mtx.bcoo")
        );
        assert_eq!(sidecar_path(Path::new("g.el")), PathBuf::from("g.el.bcoo"));
        assert_eq!(
            sidecar_path_for(Path::new("g.el"), true),
            PathBuf::from("g.el.dense.bcoo"),
            "dense mode caches under its own name"
        );
        assert_eq!(sidecar_path_for(Path::new("g.el"), false), sidecar_path(Path::new("g.el")));
    }
}
