//! `.bcoo` — the versioned little-endian binary COO interchange format
//! and its write-once sidecar cache.
//!
//! Text formats pay tokenizing + decimal decoding per edge no matter
//! how fast the parser is; `.bcoo` stores the three `Coo` arrays as raw
//! little-endian words so a load is header validation + one `memcpy`
//! per array (plus a parallel bounds check — a corrupt cache must fail,
//! not crash a kernel later). Layout, all little-endian:
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"BCOO"
//!      4     4  version (u32, currently 1)
//!      8     4  flags   (u32: bit 0 = has vals, bit 1 = dense-relabeled)
//!     12     8  n       (u64 vertex count)
//!     20     8  m       (u64 edge count)
//!     28    4m  src     (m × u32)
//!   28+4m   4m  dst     (m × u32)
//!   28+8m   4m  vals    (m × f32, present iff flag bit 0)
//! ```
//!
//! The **sidecar cache**: the first text parse of `graph.mtx` writes
//! `graph.mtx.bcoo` next to it; later loads take the binary path when
//! the sidecar's mtime is strictly newer than the source's (strictness
//! keeps coarse-timestamp filesystems on the re-parse side, never the
//! stale side). The two `.el` relabeling modes cache under different
//! names (`g.el.bcoo` preserve-ids, `g.el.dense.bcoo` dense) so mixed
//! consumers keep both warm, and flag bit 1 additionally records the
//! mode so a renamed file is never served for the wrong one. Set
//! `BOBA_NO_BCOO_CACHE=1` to disable both sides of the cache; a stale,
//! truncated, or foreign sidecar is ignored (the text is re-parsed and
//! the sidecar rewritten), never an error.

use crate::graph::Coo;
use crate::parallel;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Magic bytes every `.bcoo` file starts with.
pub const MAGIC: [u8; 4] = *b"BCOO";
/// Format version this build reads and writes.
pub const VERSION: u32 = 1;
/// Flag bit: the file carries an f32 values array.
pub const FLAG_VALS: u32 = 1;
/// Flag bit: the edge list was dense-relabeled (first-appearance order)
/// at parse time — sidecar cache keying, see the module docs.
pub const FLAG_DENSE: u32 = 1 << 1;

const HEADER_LEN: usize = 28;

/// Read a `.bcoo` file.
pub fn read_bcoo(path: &Path) -> Result<Coo> {
    Ok(read_bcoo_flagged(path)?.0)
}

/// Read a `.bcoo` file, returning the graph and the raw flags word.
pub(crate) fn read_bcoo_flagged(path: &Path) -> Result<(Coo, u32)> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_bcoo(&bytes).with_context(|| format!("parsing {}", path.display()))
}

fn parse_bcoo(bytes: &[u8]) -> Result<(Coo, u32)> {
    if bytes.len() < HEADER_LEN {
        bail!("not a .bcoo file: {} bytes is shorter than the header", bytes.len());
    }
    if bytes[..4] != MAGIC {
        bail!("not a .bcoo file (bad magic {:?})", &bytes[..4]);
    }
    let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    let version = u32_at(4);
    if version != VERSION {
        bail!("unsupported .bcoo version {version} (this reader understands {VERSION})");
    }
    let flags = u32_at(8);
    let n = u64_at(12);
    let m = u64_at(20);
    let arrays = if flags & FLAG_VALS != 0 { 3u64 } else { 2 };
    let expected = m
        .checked_mul(4 * arrays)
        .and_then(|b| b.checked_add(HEADER_LEN as u64))
        .filter(|&b| b == bytes.len() as u64);
    if expected.is_none() {
        bail!(
            "truncated .bcoo: m={m} with flags {flags:#x} needs {} bytes, file has {}",
            m.saturating_mul(4 * arrays).saturating_add(HEADER_LEN as u64),
            bytes.len()
        );
    }
    let (n, m) = (n as usize, m as usize);
    let src = u32s_from_le(&bytes[HEADER_LEN..HEADER_LEN + 4 * m]);
    let dst = u32s_from_le(&bytes[HEADER_LEN + 4 * m..HEADER_LEN + 8 * m]);
    let vals = (flags & FLAG_VALS != 0)
        .then(|| f32s_from_le(&bytes[HEADER_LEN + 8 * m..HEADER_LEN + 12 * m]));
    // Parallel bounds check: a corrupt or hand-edited cache must error
    // here, not index out of range inside a kernel.
    let max_id = parallel::par_reduce(
        m,
        parallel::default_chunk(m),
        0u32,
        |acc, lo, hi| {
            let mut acc = acc;
            for i in lo..hi {
                acc = acc.max(src[i]).max(dst[i]);
            }
            acc
        },
        u32::max,
    );
    if m > 0 && max_id as u64 >= n as u64 {
        bail!("corrupt .bcoo: vertex id {max_id} out of range for n={n}");
    }
    Ok((Coo { n, src, dst, vals }, flags))
}

/// Write `coo` as a `.bcoo` file (vals flag set iff the graph is
/// weighted; dense flag clear — use the sidecar API for cache-keyed
/// writes).
pub fn write_bcoo(coo: &Coo, path: &Path) -> Result<()> {
    write_bcoo_flagged(coo, path, 0)
}

pub(crate) fn write_bcoo_flagged(coo: &Coo, path: &Path, extra_flags: u32) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = std::io::BufWriter::with_capacity(1 << 20, f);
    let mut flags = extra_flags;
    if coo.vals.is_some() {
        flags |= FLAG_VALS;
    }
    w.write_all(&MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&flags.to_le_bytes())?;
    w.write_all(&(coo.n() as u64).to_le_bytes())?;
    w.write_all(&(coo.m() as u64).to_le_bytes())?;
    write_u32s(&mut w, &coo.src)?;
    write_u32s(&mut w, &coo.dst)?;
    if let Some(v) = &coo.vals {
        // f32 and u32 share size/alignment; serialize the bit patterns.
        write_f32s(&mut w, v)?;
    }
    w.flush()?;
    Ok(())
}

/// Sidecar path for a text source in the default (preserve-ids / mtx)
/// mode: the full file name plus `.bcoo` (`graph.mtx` →
/// `graph.mtx.bcoo`), so different extensions never collide.
pub fn sidecar_path(path: &Path) -> PathBuf {
    sidecar_path_for(path, false)
}

/// Sidecar path for a given relabeling mode. The two `.el` modes cache
/// under different names (`g.el.bcoo` vs `g.el.dense.bcoo`) so
/// consumers that disagree on `preserve_ids` (the CLI defaults to
/// dense, the registry/repro to preserve) each keep a warm cache
/// instead of invalidating the other's on every load.
pub fn sidecar_path_for(path: &Path, dense: bool) -> PathBuf {
    let mut name = path.as_os_str().to_os_string();
    name.push(if dense { ".dense.bcoo" } else { ".bcoo" });
    PathBuf::from(name)
}

/// True unless `BOBA_NO_BCOO_CACHE` disables the sidecar cache.
pub fn cache_enabled() -> bool {
    match std::env::var("BOBA_NO_BCOO_CACHE") {
        Ok(v) => v.is_empty() || v == "0",
        Err(_) => true,
    }
}

/// Load the sidecar for `path` if it exists, is **strictly newer**
/// than the source, parses cleanly, and was written for the same
/// relabeling mode. Strict ordering is the conservative side of coarse
/// filesystem timestamps: a source rewritten within the mtime
/// granularity of the sidecar write re-parses (wasted work) instead of
/// serving the old graph (wrong result). Any failure means "re-parse
/// the text" — never an error.
pub(crate) fn try_sidecar(path: &Path, dense: bool) -> Option<Coo> {
    let sc = sidecar_path_for(path, dense);
    let source_mtime = mtime(path)?;
    let sidecar_mtime = mtime(&sc)?;
    if sidecar_mtime <= source_mtime {
        return None; // stale (or indistinguishable from stale)
    }
    let (coo, flags) = read_bcoo_flagged(&sc).ok()?;
    ((flags & FLAG_DENSE != 0) == dense).then_some(coo)
}

/// Per-write tmp-name discriminator: the pid alone is not unique
/// within a process, and the server registry's prepare path can race
/// two threads onto the same sidecar (`GraphRegistry::get_or_prepare`
/// runs prepares outside the lock).
static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Best-effort sidecar write: to a uniquely-named temp file, then an
/// atomic rename so concurrent readers and racing writers (the server
/// registry's worker threads) never see a half-written cache. Failures
/// (read-only dir, full disk) are swallowed — the cache is an
/// optimization, not a deliverable.
pub(crate) fn write_sidecar(coo: &Coo, path: &Path, dense: bool) {
    let sc = sidecar_path_for(path, dense);
    let tmp = {
        let mut name = sc.as_os_str().to_os_string();
        let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        name.push(format!(".tmp{}.{seq}", std::process::id()));
        PathBuf::from(name)
    };
    let flags = if dense { FLAG_DENSE } else { 0 };
    if write_bcoo_flagged(coo, &tmp, flags).is_ok() {
        if std::fs::rename(&tmp, &sc).is_err() {
            std::fs::remove_file(&tmp).ok();
        }
    } else {
        std::fs::remove_file(&tmp).ok();
    }
}

fn mtime(p: &Path) -> Option<std::time::SystemTime> {
    std::fs::metadata(p).ok()?.modified().ok()
}

fn u32s_from_le(bytes: &[u8]) -> Vec<u32> {
    debug_assert_eq!(bytes.len() % 4, 0);
    let n = bytes.len() / 4;
    if cfg!(target_endian = "little") {
        let mut v: Vec<u32> = Vec::with_capacity(n);
        // SAFETY: the reservation holds n u32s = bytes.len() bytes, the
        // ranges don't overlap, and on a little-endian target the byte
        // image of [u32] is the on-disk layout.
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), v.as_mut_ptr() as *mut u8, bytes.len());
            v.set_len(n);
        }
        v
    } else {
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

fn f32s_from_le(bytes: &[u8]) -> Vec<f32> {
    u32s_from_le(bytes).into_iter().map(f32::from_bits).collect()
}

fn write_u32s(w: &mut impl Write, v: &[u32]) -> std::io::Result<()> {
    if cfg!(target_endian = "little") {
        // SAFETY: reinterpreting [u32] as its byte image is always
        // valid (alignment only loosens), and on little-endian the
        // image is the on-disk layout.
        let bytes =
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
        w.write_all(bytes)
    } else {
        let mut buf = Vec::with_capacity(4 << 10);
        for chunk in v.chunks(1 << 10) {
            buf.clear();
            for x in chunk {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        Ok(())
    }
}

fn write_f32s(w: &mut impl Write, v: &[f32]) -> std::io::Result<()> {
    if cfg!(target_endian = "little") {
        // SAFETY: same as write_u32s — f32 has the same size/alignment.
        let bytes =
            unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
        w.write_all(bytes)
    } else {
        let mut buf = Vec::with_capacity(4 << 10);
        for chunk in v.chunks(1 << 10) {
            buf.clear();
            for x in chunk {
                buf.extend_from_slice(&x.to_bits().to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("boba_bcoo_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_unweighted_and_weighted() {
        let g = Coo::new(5, vec![0, 4, 2, 2], vec![1, 0, 3, 2]);
        let p = tmp("rt.bcoo");
        write_bcoo(&g, &p).unwrap();
        assert_eq!(read_bcoo(&p).unwrap(), g);
        let w = Coo::with_vals(3, vec![0, 2], vec![1, 0], vec![1.5, -0.25]);
        write_bcoo(&w, &p).unwrap();
        assert_eq!(read_bcoo(&p).unwrap(), w);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_empty_graph_keeps_n() {
        let g = Coo::new(7, vec![], vec![]);
        let p = tmp("empty.bcoo");
        write_bcoo(&g, &p).unwrap();
        assert_eq!(read_bcoo(&p).unwrap(), g);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_magic_version_truncation_and_bounds() {
        let g = Coo::new(3, vec![0, 1], vec![1, 2]);
        let p = tmp("bad.bcoo");
        write_bcoo(&g, &p).unwrap();
        let good = std::fs::read(&p).unwrap();

        let chain = |p: &Path| format!("{:#}", read_bcoo(p).unwrap_err());

        let mut bad = good.clone();
        bad[0] = b'X';
        std::fs::write(&p, &bad).unwrap();
        assert!(chain(&p).contains("magic"));

        let mut bad = good.clone();
        bad[4] = 99; // version
        std::fs::write(&p, &bad).unwrap();
        assert!(chain(&p).contains("version"));

        std::fs::write(&p, &good[..good.len() - 3]).unwrap();
        assert!(chain(&p).contains("truncated"));

        let mut bad = good.clone();
        bad[HEADER_LEN] = 200; // src[0] = 200 ≥ n = 3
        std::fs::write(&p, &bad).unwrap();
        assert!(chain(&p).contains("out of range"));

        std::fs::write(&p, b"BC").unwrap();
        assert!(read_bcoo(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn sidecar_path_appends_full_extension_and_keys_by_mode() {
        assert_eq!(
            sidecar_path(Path::new("/x/graph.mtx")),
            PathBuf::from("/x/graph.mtx.bcoo")
        );
        assert_eq!(sidecar_path(Path::new("g.el")), PathBuf::from("g.el.bcoo"));
        assert_eq!(
            sidecar_path_for(Path::new("g.el"), true),
            PathBuf::from("g.el.dense.bcoo"),
            "dense mode caches under its own name"
        );
        assert_eq!(sidecar_path_for(Path::new("g.el"), false), sidecar_path(Path::new("g.el")));
    }
}
