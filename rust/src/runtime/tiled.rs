//! Cache-blocked tiled SpMV — column tiles sized to L2.
//!
//! Columns are split into tiles of [`TILE_COLS`] (32768 columns × 4
//! bytes = a 128 KiB slab of `x`, sized to stay L2-resident). A row
//! whose column sequence is **tile-monotone** (tile indices
//! non-decreasing left to right — which is what `Csr::sort_rows`
//! produces, and nearly free under a BOBA ordering) is split into one
//! segment per tile; the kernel then walks tiles outermost, so every
//! gather inside a tile hits the same hot 128 KiB of `x`. Local
//! columns within a tile fit in a `u16` (`TILE_COLS ≤ 65536`), so
//! tiling doubles as 2-byte compression. Rows that are not
//! tile-monotone, or whose segments would average fewer than 4 edges
//! (`segments·4 > edges` — segment bookkeeping would outweigh the
//! u16 savings), fall back to an **irregular** plain-CSR stream
//! processed row-at-a-time.
//!
//! Bit-identity with `spmv_pull` is structural: a tiled row's
//! segments are visited in ascending tile order, which *is* its
//! original edge order (that's what monotone means), each resuming
//! from the row's running `y` value; irregular rows replay their
//! edges verbatim. Within one tile a row owns at most one segment, so
//! the parallel path (segments of a tile split edge-balanced across
//! the pool, tiles barriered in sequence) writes disjoint rows.

use crate::algos::spmv::edge_balanced_bounds;
use crate::graph::Csr;
use crate::parallel::{self, SendPtr};

use super::format::{SpmvFormat, PAR_MIN_EDGES};

/// Columns per tile: 32768 × 4-byte `x` entries = 128 KiB, sized to
/// sit in a typical per-core L2; also the largest width whose local
/// offsets fit a `u16`.
pub const TILE_COLS: usize = 1 << 15;

/// A column-tiled operator with an irregular fallback stream. See the
/// module docs for the layout and the tiling acceptance rule.
pub struct TiledCsr {
    n: usize,
    m: usize,
    n_tiles: usize,
    /// Segment index range per tile: tile `t` owns segments
    /// `tile_ptr[t] .. tile_ptr[t+1]` (segments stored tile-major).
    tile_ptr: Vec<u64>,
    /// Destination row of each segment.
    seg_row: Vec<u32>,
    /// Edge count of each segment.
    seg_len: Vec<u32>,
    /// Offset of each segment's first edge in `tcols`.
    seg_off: Vec<u64>,
    /// Tile-local column offsets (`col − tile·TILE_COLS`).
    tcols: Vec<u16>,
    /// Values aligned with `tcols` (weighted graphs only).
    tvals: Option<Vec<f32>>,
    /// Rows routed to the irregular fallback, in ascending order.
    irr_rows: Vec<u32>,
    /// CSR-style offsets into `irr_cols` per irregular row.
    irr_ptr: Vec<u64>,
    /// Raw columns of the irregular rows, original edge order.
    irr_cols: Vec<u32>,
    /// Values aligned with `irr_cols` (weighted graphs only).
    irr_vals: Option<Vec<f32>>,
}

/// Split decision for one row: segments-per-tile if tiled, edge count
/// if irregular, nothing if empty.
enum RowPlan {
    Tiled,
    Irregular,
    Empty,
}

fn plan_row(cols: &[u32]) -> RowPlan {
    if cols.is_empty() {
        return RowPlan::Empty;
    }
    let mut segs = 1usize;
    let mut prev = cols[0] as usize / TILE_COLS;
    for &c in &cols[1..] {
        let t = c as usize / TILE_COLS;
        if t < prev {
            return RowPlan::Irregular;
        }
        if t > prev {
            segs += 1;
            prev = t;
        }
    }
    // Tiling must pay for its segment bookkeeping: require an average
    // of ≥ 4 edges per segment, else the row streams cheaper as raw CSR.
    if segs * 4 <= cols.len() {
        RowPlan::Tiled
    } else {
        RowPlan::Irregular
    }
}

impl TiledCsr {
    /// Encode `csr`. Two passes: classify rows and count segments per
    /// tile, then fill the tile-major segment streams.
    pub fn encode(csr: &Csr) -> TiledCsr {
        let n = csr.n();
        let m = csr.m();
        let n_tiles = n.div_ceil(TILE_COLS);
        // Pass 1: classify rows, count segments and edges per tile.
        let mut plans: Vec<RowPlan> = Vec::with_capacity(n);
        let mut segs_per_tile = vec![0u64; n_tiles];
        let mut edges_per_tile = vec![0u64; n_tiles];
        let mut irr_edges = 0usize;
        let mut irr_count = 0usize;
        for v in 0..n {
            let plan = plan_row(csr.neighbors(v));
            match plan {
                RowPlan::Tiled => {
                    let cols = csr.neighbors(v);
                    let mut prev = usize::MAX;
                    for &c in cols {
                        let t = c as usize / TILE_COLS;
                        if t != prev {
                            segs_per_tile[t] += 1;
                            prev = t;
                        }
                        edges_per_tile[t] += 1;
                    }
                }
                RowPlan::Irregular => {
                    irr_edges += csr.degree(v);
                    irr_count += 1;
                }
                RowPlan::Empty => {}
            }
            plans.push(plan);
        }
        let mut tile_ptr = Vec::with_capacity(n_tiles + 1);
        tile_ptr.push(0u64);
        let mut tile_edge_base = Vec::with_capacity(n_tiles);
        let mut seg_total = 0u64;
        let mut edge_total = 0u64;
        for t in 0..n_tiles {
            tile_edge_base.push(edge_total);
            seg_total += segs_per_tile[t];
            edge_total += edges_per_tile[t];
            tile_ptr.push(seg_total);
        }
        // Pass 2: fill, with running cursors per tile.
        let mut seg_row = vec![0u32; seg_total as usize];
        let mut seg_len = vec![0u32; seg_total as usize];
        let mut seg_off = vec![0u64; seg_total as usize];
        let mut tcols = vec![0u16; edge_total as usize];
        let mut tvals = csr.vals.as_ref().map(|_| vec![0f32; edge_total as usize]);
        let mut seg_cursor: Vec<u64> = tile_ptr[..n_tiles].to_vec();
        let mut edge_cursor = tile_edge_base;
        let mut irr_rows = Vec::with_capacity(irr_count);
        let mut irr_ptr = Vec::with_capacity(irr_count + 1);
        irr_ptr.push(0u64);
        let mut irr_cols = Vec::with_capacity(irr_edges);
        let mut irr_vals = csr.vals.as_ref().map(|_| Vec::with_capacity(irr_edges));
        for v in 0..n {
            match plans[v] {
                RowPlan::Tiled => {
                    let cols = csr.neighbors(v);
                    let rv = csr.row_vals(v);
                    let mut i = 0usize;
                    while i < cols.len() {
                        let t = cols[i] as usize / TILE_COLS;
                        let run_start = i;
                        while i < cols.len() && cols[i] as usize / TILE_COLS == t {
                            i += 1;
                        }
                        let s = seg_cursor[t] as usize;
                        seg_cursor[t] += 1;
                        let off = edge_cursor[t];
                        seg_row[s] = v as u32;
                        seg_len[s] = (i - run_start) as u32;
                        seg_off[s] = off;
                        for (k, &c) in cols[run_start..i].iter().enumerate() {
                            tcols[off as usize + k] = (c as usize - t * TILE_COLS) as u16;
                            if let (Some(tv), Some(rv)) = (tvals.as_mut(), rv) {
                                tv[off as usize + k] = rv[run_start + k];
                            }
                        }
                        edge_cursor[t] += (i - run_start) as u64;
                    }
                }
                RowPlan::Irregular => {
                    irr_rows.push(v as u32);
                    irr_cols.extend_from_slice(csr.neighbors(v));
                    if let (Some(iv), Some(rv)) = (irr_vals.as_mut(), csr.row_vals(v)) {
                        iv.extend_from_slice(rv);
                    }
                    irr_ptr.push(irr_cols.len() as u64);
                }
                RowPlan::Empty => {}
            }
        }
        TiledCsr {
            n,
            m,
            n_tiles,
            tile_ptr,
            seg_row,
            seg_len,
            seg_off,
            tcols,
            tvals,
            irr_rows,
            irr_ptr,
            irr_cols,
            irr_vals,
        }
    }

    /// Edges stored in the tiled (u16) stream.
    pub fn tiled_edges(&self) -> usize {
        self.tcols.len()
    }

    /// Edges that fell back to the irregular (u32) stream.
    pub fn irregular_edges(&self) -> usize {
        self.irr_cols.len()
    }

    /// Process segments `[s_lo, s_hi)` (global indices) of tile `t`.
    /// Reads and resumes each row's running `y`; callers guarantee no
    /// two concurrent calls share a row (one segment per row per tile).
    fn run_tile_segs(&self, t: usize, s_lo: usize, s_hi: usize, x: &[f32], y: SendPtr<f32>) {
        let x_base = t * TILE_COLS;
        for s in s_lo..s_hi {
            let row = self.seg_row[s] as usize;
            let off = self.seg_off[s] as usize;
            let len = self.seg_len[s] as usize;
            // SAFETY: rows are disjoint across concurrent callers;
            // prior tiles were barriered before this call.
            let mut acc = unsafe { *y.get().add(row) };
            match &self.tvals {
                Some(tv) => {
                    for k in 0..len {
                        acc += tv[off + k] * x[x_base + self.tcols[off + k] as usize];
                    }
                }
                None => {
                    for k in 0..len {
                        acc += x[x_base + self.tcols[off + k] as usize];
                    }
                }
            }
            // SAFETY: same exclusivity argument as the read above —
            // this caller owns `row` for the duration of the tile.
            unsafe { *y.get().add(row) = acc };
        }
    }

    /// Process irregular rows `[k_lo, k_hi)` (indices into `irr_rows`).
    fn run_irr(&self, k_lo: usize, k_hi: usize, x: &[f32], y: SendPtr<f32>) {
        for k in k_lo..k_hi {
            let row = self.irr_rows[k] as usize;
            let lo = self.irr_ptr[k] as usize;
            let hi = self.irr_ptr[k + 1] as usize;
            let mut acc = 0f32;
            match &self.irr_vals {
                Some(iv) => {
                    for e in lo..hi {
                        acc += iv[e] * x[self.irr_cols[e] as usize];
                    }
                }
                None => {
                    for e in lo..hi {
                        acc += x[self.irr_cols[e] as usize];
                    }
                }
            }
            // SAFETY: irregular rows are disjoint across callers and
            // never appear in the tiled streams.
            unsafe { *y.get().add(row) = acc };
        }
    }
}

impl SpmvFormat for TiledCsr {
    fn name(&self) -> &'static str {
        "tiled"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn m(&self) -> usize {
        self.m
    }

    fn index_bytes(&self) -> u64 {
        2 * self.tcols.len() as u64 + 4 * self.irr_cols.len() as u64
    }

    fn overhead_bytes(&self) -> u64 {
        8 * self.tile_ptr.len() as u64
            + (4 + 4 + 8) * self.seg_row.len() as u64
            + 4 * self.irr_rows.len() as u64
            + 8 * self.irr_ptr.len() as u64
    }

    fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0f32; self.n];
        let y_ptr = SendPtr(y.as_mut_ptr());
        for t in 0..self.n_tiles {
            self.run_tile_segs(t, self.tile_ptr[t] as usize, self.tile_ptr[t + 1] as usize, x, y_ptr);
        }
        self.run_irr(0, self.irr_rows.len(), x, y_ptr);
        y
    }

    fn spmv_parallel(&self, x: &[f32]) -> Vec<f32> {
        if self.m < PAR_MIN_EDGES {
            return self.spmv(x);
        }
        assert_eq!(x.len(), self.n);
        let mut y = vec![0f32; self.n];
        let tasks = (parallel::threads() * 8).max(1);
        let y_ptr = SendPtr(y.as_mut_ptr());
        // Tiles run in sequence (each par_for_chunks is a barrier, so
        // a row's running y is complete before the next tile resumes
        // it); segments within a tile split edge-balanced.
        for t in 0..self.n_tiles {
            let s0 = self.tile_ptr[t] as usize;
            let s1 = self.tile_ptr[t + 1] as usize;
            if s0 == s1 {
                continue;
            }
            let mut ptr = Vec::with_capacity(s1 - s0 + 1);
            ptr.push(0u64);
            let mut run = 0u64;
            for s in s0..s1 {
                run += self.seg_len[s] as u64;
                ptr.push(run);
            }
            let bounds = edge_balanced_bounds(&ptr, tasks);
            parallel::par_for_chunks(tasks, 1, |t_lo, t_hi| {
                for task in t_lo..t_hi {
                    self.run_tile_segs(t, s0 + bounds[task], s0 + bounds[task + 1], x, y_ptr);
                }
            });
        }
        if !self.irr_rows.is_empty() {
            let bounds = edge_balanced_bounds(&self.irr_ptr, tasks);
            parallel::par_for_chunks(tasks, 1, |t_lo, t_hi| {
                for task in t_lo..t_hi {
                    self.run_irr(bounds[task], bounds[task + 1], x, y_ptr);
                }
            });
        }
        y
    }

    fn decode(&self) -> Csr {
        let mut row_ptr = vec![0u64; self.n + 1];
        for (i, &r) in self.seg_row.iter().enumerate() {
            row_ptr[r as usize + 1] += self.seg_len[i] as u64;
        }
        for (k, &r) in self.irr_rows.iter().enumerate() {
            row_ptr[r as usize + 1] += self.irr_ptr[k + 1] - self.irr_ptr[k];
        }
        for v in 0..self.n {
            row_ptr[v + 1] += row_ptr[v];
        }
        let mut col_idx = vec![0u32; self.m];
        let mut vals = self.tvals.as_ref().or(self.irr_vals.as_ref()).map(|_| vec![0f32; self.m]);
        let mut cursor: Vec<u64> = row_ptr[..self.n].to_vec();
        // Tiled rows: ascending tiles replay original edge order.
        for t in 0..self.n_tiles {
            for s in self.tile_ptr[t] as usize..self.tile_ptr[t + 1] as usize {
                let row = self.seg_row[s] as usize;
                let off = self.seg_off[s] as usize;
                for k in 0..self.seg_len[s] as usize {
                    let at = cursor[row] as usize;
                    col_idx[at] = (t * TILE_COLS + self.tcols[off + k] as usize) as u32;
                    if let (Some(dv), Some(tv)) = (vals.as_mut(), self.tvals.as_ref()) {
                        dv[at] = tv[off + k];
                    }
                    cursor[row] += 1;
                }
            }
        }
        for (k, &r) in self.irr_rows.iter().enumerate() {
            let row = r as usize;
            for e in self.irr_ptr[k] as usize..self.irr_ptr[k + 1] as usize {
                let at = cursor[row] as usize;
                col_idx[at] = self.irr_cols[e];
                if let (Some(dv), Some(iv)) = (vals.as_mut(), self.irr_vals.as_ref()) {
                    dv[at] = iv[e];
                }
                cursor[row] += 1;
            }
        }
        Csr { row_ptr, col_idx, vals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::spmv::spmv_pull;
    use crate::convert;
    use crate::graph::gen::{self, GenParams};

    #[test]
    fn sorted_rows_engage_the_tiled_stream() {
        let g = gen::rmat(&GenParams::rmat(12, 8), 5).randomized(6);
        let mut csr = convert::coo_to_csr(&g);
        csr.sort_rows();
        let f = TiledCsr::encode(&csr);
        assert!(f.tiled_edges() > 0, "sorted rmat rows must tile");
        assert_eq!(f.decode(), csr);
        let x: Vec<f32> = (0..csr.n()).map(|i| (i % 31) as f32 * 0.25).collect();
        let want = spmv_pull(&csr, &x);
        let got = f.spmv(&x);
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn unsorted_rows_fall_back_irregular_and_stay_exact() {
        // Descending columns are tile-non-monotone on any multi-tile
        // graph — and on a single-tile graph they tile trivially;
        // either way the bits must match.
        let g = gen::rmat(&GenParams::rmat(10, 8), 5).randomized(8);
        let csr = convert::coo_to_csr(&g); // unsorted neighbor lists
        let f = TiledCsr::encode(&csr);
        assert_eq!(f.decode(), csr);
        let x: Vec<f32> = (0..csr.n()).map(|i| (i % 17) as f32 - 8.0).collect();
        let want = spmv_pull(&csr, &x);
        let got = f.spmv(&x);
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
