//! PJRT runtime — loads the AOT HLO artifacts (`make artifacts`) and
//! executes them from the Rust hot path. Python never runs here.
//!
//! Pipeline per artifact: `HloModuleProto::from_text_file` → wrap as
//! `XlaComputation` → `PjRtClient::compile` (once, cached) → `execute`
//! per request. HLO *text* is the interchange format because the crate's
//! xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id serialized protos.
//!
//! [`ell`] packs CSR matrices into the fixed `(N_TILE × K)` ELL tiles the
//! artifacts were compiled for; `Engine` (`pjrt`-gated) stitches tile
//! executions into
//! whole-graph SpMV and PageRank.

//! The executable engine is compiled only with the **`pjrt` feature**
//! (it needs the `xla` crate, which does not resolve offline — see
//! Cargo.toml); [`Meta`] parsing and the [`ell`] packing plan are pure
//! and always available.
//!
//! The module also hosts the **CPU kernel-format family** behind the
//! [`format::SpmvFormat`] trait — compressed/tiled CSR layouts
//! ([`delta`], [`sell`], [`tiled`], plus [`ell::EllFormat`]) whose
//! SpMV kernels are bit-identical to `spmv_pull` at every thread
//! count. These are pure std and always available; `serve --format`
//! and repro table T5 build on them.

pub mod delta;
pub mod ell;
pub mod format;
pub mod sell;
pub mod tiled;

#[cfg(feature = "pjrt")]
use crate::graph::Csr;
use anyhow::{Context, Result};
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

/// Artifact tile geometry, read from `artifacts/meta.json`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Meta {
    /// Rows per tile (static artifact shape).
    pub n_tile: usize,
    /// ELL slots per pass.
    pub k: usize,
}

impl Meta {
    /// Parse the (tiny, known-shape) meta.json without a JSON crate.
    pub fn parse(text: &str) -> Result<Meta> {
        let grab = |key: &str| -> Result<usize> {
            let pat = format!("\"{key}\":");
            let at = text
                .find(&pat)
                .with_context(|| format!("meta.json missing {key}"))?;
            let rest = &text[at + pat.len()..];
            let digits: String = rest
                .chars()
                .skip_while(|c| c.is_whitespace())
                .take_while(|c| c.is_ascii_digit())
                .collect();
            digits.parse().with_context(|| format!("bad {key} in meta.json"))
        };
        Ok(Meta { n_tile: grab("n_tile")?, k: grab("k")? })
    }

    /// Read from a directory's meta.json.
    pub fn load(dir: &Path) -> Result<Meta> {
        let text = std::fs::read_to_string(dir.join("meta.json")).with_context(|| {
            format!("reading {}/meta.json — run `make artifacts`", dir.display())
        })?;
        Self::parse(&text)
    }
}

/// Which SpMV artifact to execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpmvKind {
    /// `spmv_ell.hlo.txt` — plain-jnp L2 graph.
    Jnp,
    /// `spmv_ell_pallas.hlo.txt` — the L1 Pallas kernel's lowering.
    Pallas,
}

/// A compiled-and-loaded artifact set on the CPU PJRT client.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    spmv_jnp: xla::PjRtLoadedExecutable,
    spmv_pallas: xla::PjRtLoadedExecutable,
    pagerank_step: xla::PjRtLoadedExecutable,
    /// Tile geometry the artifacts were compiled for.
    pub meta: Meta,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Default artifact directory (`$BOBA_ARTIFACTS` or the nearest
    /// ancestor `artifacts/`, so tests and benches work from target
    /// directories).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("BOBA_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let mut d = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            if d.join("artifacts/meta.json").exists() {
                return d.join("artifacts");
            }
            if !d.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let meta = Meta::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto =
                xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                    .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))
        };
        Ok(Engine {
            spmv_jnp: compile("spmv_ell")?,
            spmv_pallas: compile("spmv_ell_pallas")?,
            pagerank_step: compile("pagerank_step")?,
            client,
            meta,
        })
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<Engine> {
        Self::load(&Self::default_dir())
    }

    /// Platform name of the underlying PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one ELL tile pass: returns this pass's partial
    /// `y[i] = Σ_j vals[i,j] · x_tilevec[cols[i,j]]` (accumulation across
    /// passes happens in the caller's buffer).
    ///
    /// NOTE: `cols` index into `x`, which is the *whole padded vector for
    /// this tile's column space* — the artifacts are compiled with
    /// `m == n_tile`, so the plan splits the column space into tile-sized
    /// segments (see [`ell::EllPlan`]).
    pub fn spmv_tile(
        &self,
        kind: SpmvKind,
        cols: &[i32],
        vals: &[f32],
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let (nt, k) = (self.meta.n_tile, self.meta.k);
        anyhow::ensure!(cols.len() == nt * k, "cols len {} != {}", cols.len(), nt * k);
        anyhow::ensure!(vals.len() == nt * k, "vals len mismatch");
        anyhow::ensure!(x.len() == nt, "x len {} != n_tile {}", x.len(), nt);
        let cols_l = xla::Literal::vec1(cols).reshape(&[nt as i64, k as i64])?;
        let vals_l = xla::Literal::vec1(vals).reshape(&[nt as i64, k as i64])?;
        let x_l = xla::Literal::vec1(x);
        let exe = match kind {
            SpmvKind::Jnp => &self.spmv_jnp,
            SpmvKind::Pallas => &self.spmv_pallas,
        };
        let result =
            exe.execute::<xla::Literal>(&[cols_l, vals_l, x_l])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute the PageRank update artifact on one padded tile:
    /// returns `(rank_new, l1_delta)`.
    pub fn pagerank_step_tile(
        &self,
        y: &[f32],
        rank_old: &[f32],
        damping: f32,
        base: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let nt = self.meta.n_tile;
        anyhow::ensure!(y.len() == nt && rank_old.len() == nt);
        let y_l = xla::Literal::vec1(y);
        let r_l = xla::Literal::vec1(rank_old);
        let d_l = xla::Literal::scalar(damping);
        let b_l = xla::Literal::scalar(base);
        let result = self
            .pagerank_step
            .execute::<xla::Literal>(&[y_l, r_l, d_l, b_l])?[0][0]
            .to_literal_sync()?;
        let (rank, delta) = result.to_tuple2()?;
        Ok((rank.to_vec::<f32>()?, delta.get_first_element::<f32>()?))
    }

    /// Whole-graph SpMV through the tiled artifacts.
    pub fn spmv_csr(&self, kind: SpmvKind, csr: &Csr, x: &[f32]) -> Result<Vec<f32>> {
        let plan = ell::EllPlan::pack(csr, self.meta)?;
        plan.execute(self, kind, x)
    }

    /// Full PageRank through the artifacts: SpMV over the weighted
    /// transpose plan + the pagerank_step artifact per tile per
    /// iteration. `plan` must be built from the *pull* matrix
    /// (`ell::EllPlan::pack_pagerank`).
    pub fn pagerank(
        &self,
        plan: &ell::EllPlan,
        n: usize,
        damping: f32,
        max_iters: usize,
        tol: f32,
    ) -> Result<(Vec<f32>, usize)> {
        let nt = self.meta.n_tile;
        let padded = n.div_ceil(nt) * nt;
        let mut rank = vec![1.0 / n as f32; n];
        rank.resize(padded, 0.0);
        let mut iters = 0;
        for _ in 0..max_iters {
            iters += 1;
            let mut y = plan.execute(self, SpmvKind::Jnp, &rank)?;
            y.resize(padded, 0.0); // execute() truncates to n rows
            // Dangling + teleport base (L3 owns graph-global scalars).
            let dangling_mass: f32 =
                plan.dangling.iter().map(|&v| rank[v as usize]).sum();
            let base = (1.0 - damping) / n as f32 + damping * dangling_mass / n as f32;
            let mut delta_total = 0f32;
            let mut next = vec![0f32; padded];
            for t in 0..padded / nt {
                let (tile_rank, delta) = self.pagerank_step_tile(
                    &y[t * nt..(t + 1) * nt],
                    &rank[t * nt..(t + 1) * nt],
                    damping,
                    base,
                )?;
                next[t * nt..(t + 1) * nt].copy_from_slice(&tile_rank);
                delta_total += delta;
            }
            // Zero the padding rows so they never accumulate teleport mass.
            for v in next[n..].iter_mut() {
                *v = 0.0;
            }
            rank = next;
            if delta_total < tol {
                break;
            }
        }
        rank.truncate(n);
        Ok((rank, iters))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let m = Meta::parse(r#"{"n_tile": 8192, "k": 16, "artifacts": []}"#).unwrap();
        assert_eq!(m, Meta { n_tile: 8192, k: 16 });
    }

    #[test]
    fn meta_rejects_missing_keys() {
        assert!(Meta::parse(r#"{"n_tile": 8192}"#).is_err());
        assert!(Meta::parse("{}").is_err());
    }

    #[test]
    fn meta_parses_unspaced() {
        let m = Meta::parse(r#"{"k":4,"n_tile":512}"#).unwrap();
        assert_eq!(m, Meta { n_tile: 512, k: 4 });
    }
}
