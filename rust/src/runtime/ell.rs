//! CSR → fixed-shape ELL tile packing for the AOT artifacts.
//!
//! The artifacts are compiled for a static `(N_TILE, K)` tile whose
//! column ids index a length-`N_TILE` vector segment. An arbitrary CSR is
//! therefore decomposed along both axes:
//!
//! * rows are split into **row tiles** of `N_TILE`;
//! * the column space is split into **column segments** of `N_TILE`
//!   (each segment sees its own slice of `x`);
//! * within a (row-tile, segment) block, rows holding more than `K`
//!   entries spill into additional **passes**.
//!
//! Execution accumulates `y[tile] += artifact(cols, vals, x[segment])`
//! over all passes. Padding slots carry `col = 0, val = 0.0`, which the
//! kernel's multiply annihilates.
//!
//! BOBA's effect is visible here too: clustered column labels concentrate
//! a row's entries into fewer segments, producing fewer passes (the
//! pass count is reported by [`EllPlan::passes`] and benchmarked in
//! docs/EXPERIMENTS.md).
//!
//! The module also hosts [`EllFormat`], the **CPU** ELL variant behind
//! the [`super::format::SpmvFormat`] trait. It deliberately differs
//! from [`EllPlan`] in two ways. First, it does not segment the column
//! space (the CPU can address all of `x`), because segment-grouping
//! reorders a row's edges and would break bit-identity with
//! `spmv_pull` on unsorted rows. Second — the fix the differential
//! harness demanded — padding slots are skipped by per-lane **length
//! guards** instead of the `col = 0, val = 0.0` annihilation trick:
//! `0.0 · x[0]` is only zero while `x[0]` is finite, so the old scheme
//! silently turns padding into NaN the moment a query carries ±∞ (and
//! burns gather bandwidth on x[0] even when it doesn't).
//! `tests/format_equiv.rs` pins both properties.

use super::Meta;
#[cfg(feature = "pjrt")]
use super::{Engine, SpmvKind};
use crate::algos::spmv::edge_balanced_bounds;
use crate::graph::Csr;
use anyhow::Result;

/// One executable tile pass.
#[derive(Clone, Debug)]
struct TilePass {
    row_tile: usize,
    col_seg: usize,
    cols: Vec<i32>,
    vals: Vec<f32>,
}

/// A packed execution plan for one CSR matrix.
#[derive(Clone, Debug)]
pub struct EllPlan {
    meta: Meta,
    n_rows: usize,
    n_cols: usize,
    passes: Vec<TilePass>,
    /// Vertices with zero out-degree in the *original* orientation —
    /// needed by PageRank's dangling-mass correction.
    pub dangling: Vec<u32>,
}

impl EllPlan {
    /// Pack a CSR into tile passes for `meta`'s geometry.
    pub fn pack(csr: &Csr, meta: Meta) -> Result<EllPlan> {
        let n = csr.n();
        let nt = meta.n_tile;
        let k = meta.k;
        let row_tiles = n.div_ceil(nt).max(1);
        let col_segs = n.div_ceil(nt).max(1);
        let mut passes: Vec<TilePass> = Vec::new();
        // Per (row_tile, col_seg): a vector of per-local-row entry lists.
        // Built tile-by-tile to bound peak memory.
        for rt in 0..row_tiles {
            let r0 = rt * nt;
            let r1 = ((rt + 1) * nt).min(n);
            // entries[seg][local_row] -> (local_col, val)
            let mut entries: Vec<Vec<Vec<(i32, f32)>>> = Vec::new();
            entries.resize_with(col_segs, || vec![Vec::new(); r1 - r0]);
            for r in r0..r1 {
                let (lo, hi) = (csr.row_ptr[r] as usize, csr.row_ptr[r + 1] as usize);
                for e in lo..hi {
                    let c = csr.col_idx[e] as usize;
                    let seg = c / nt;
                    let val = csr.vals.as_ref().map_or(1.0, |v| v[e]);
                    entries[seg][r - r0].push(((c - seg * nt) as i32, val));
                }
            }
            for (seg, rows) in entries.into_iter().enumerate() {
                let max_deg = rows.iter().map(|r| r.len()).max().unwrap_or(0);
                if max_deg == 0 {
                    continue;
                }
                let npass = max_deg.div_ceil(k);
                for p in 0..npass {
                    let mut cols = vec![0i32; nt * k];
                    let mut vals = vec![0f32; nt * k];
                    let mut used = false;
                    for (lr, row) in rows.iter().enumerate() {
                        let start = p * k;
                        if start >= row.len() {
                            continue;
                        }
                        for (slot, &(c, v)) in
                            row[start..row.len().min(start + k)].iter().enumerate()
                        {
                            cols[lr * k + slot] = c;
                            vals[lr * k + slot] = v;
                            used = true;
                        }
                    }
                    if used {
                        passes.push(TilePass { row_tile: rt, col_seg: seg, cols, vals });
                    }
                }
            }
        }
        let dangling =
            (0..n).filter(|&v| csr.degree(v) == 0).map(|v| v as u32).collect();
        Ok(EllPlan { meta, n_rows: n, n_cols: n, passes, dangling })
    }

    /// Pack the *pull* (transposed, 1/outdeg-weighted) matrix of a graph
    /// for PageRank: `y[v] = Σ_{u→v} rank[u] / outdeg(u)`.
    pub fn pack_pagerank(csr: &Csr, meta: Meta) -> Result<EllPlan> {
        let n = csr.n();
        let mut weighted = csr.clone();
        let mut vals = vec![0f32; csr.m()];
        for v in 0..n {
            let deg = csr.degree(v);
            if deg == 0 {
                continue;
            }
            let w = 1.0 / deg as f32;
            for e in csr.row_ptr[v] as usize..csr.row_ptr[v + 1] as usize {
                vals[e] = w;
            }
        }
        weighted.vals = Some(vals);
        let mut plan = Self::pack(&weighted.transposed(), meta)?;
        // Dangling = zero out-degree in the ORIGINAL orientation.
        plan.dangling = (0..n).filter(|&v| csr.degree(v) == 0).map(|v| v as u32).collect();
        Ok(plan)
    }

    /// Number of tile passes (the PJRT launch count for one SpMV).
    pub fn passes(&self) -> usize {
        self.passes.len()
    }

    /// Rows of the packed matrix.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Execute the plan: `y = A·x` with `x` of length ≥ n (padded
    /// internally). Only available with the `pjrt` feature (needs a
    /// compiled [`Engine`]); packing itself is feature-free.
    #[cfg(feature = "pjrt")]
    pub fn execute(&self, engine: &Engine, kind: SpmvKind, x: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            x.len() >= self.n_cols,
            "x has {} entries, matrix has {} columns",
            x.len(),
            self.n_cols
        );
        let nt = self.meta.n_tile;
        let padded_rows = self.n_rows.div_ceil(nt) * nt;
        let padded_cols = self.n_cols.div_ceil(nt) * nt;
        let mut xp = x[..self.n_cols].to_vec();
        xp.resize(padded_cols, 0.0);
        let mut y = vec![0f32; padded_rows];
        for pass in &self.passes {
            let seg = &xp[pass.col_seg * nt..(pass.col_seg + 1) * nt];
            let part = engine.spmv_tile(kind, &pass.cols, &pass.vals, seg)?;
            let y_slice = &mut y[pass.row_tile * nt..(pass.row_tile + 1) * nt];
            for (acc, p) in y_slice.iter_mut().zip(&part) {
                *acc += p;
            }
        }
        y.truncate(self.n_rows);
        Ok(y)
    }
}

/// Geometry of the CPU [`EllFormat`]: 128-row tiles bound the padding
/// blow-up a hub row inflicts on its tile-mates (the per-pass slot
/// count is `lanes·k`, paid until the longest row drains), and `k = 8`
/// edges per pass keeps short rows near one pass.
pub const CPU_ELL_META: Meta = Meta { n_tile: 128, k: 8 };

/// One pass of one row tile: each lane's next ≤ `k` edges, in original
/// CSR order, with a per-lane count guarding the padding slots.
struct RowPass {
    /// Column ids, lane-major: `cols[lane·k + slot]`; padding slots 0
    /// but never read (see `lens`).
    cols: Vec<u32>,
    /// Values aligned with `cols` (weighted graphs only).
    vals: Option<Vec<f32>>,
    /// Edges this pass actually holds per lane (≤ k).
    lens: Vec<u16>,
}

/// Row-tiled ELL behind the `SpmvFormat` trait — the CPU sibling of
/// [`EllPlan`] (see the module docs for why the two differ).
pub struct EllFormat {
    n: usize,
    m: usize,
    meta: Meta,
    /// Pass index range per row tile: tile `rt` owns
    /// `passes[tile_ptr[rt] .. tile_ptr[rt+1]]`.
    tile_ptr: Vec<usize>,
    /// Cumulative stored edges per row tile (for edge-balanced
    /// parallel partitioning).
    tile_edge_ptr: Vec<u64>,
    passes: Vec<RowPass>,
}

impl EllFormat {
    /// Pack `csr` with the [`CPU_ELL_META`] geometry.
    pub fn encode(csr: &Csr) -> EllFormat {
        Self::encode_with(csr, CPU_ELL_META)
    }

    /// Pack `csr` with an explicit tile geometry.
    pub fn encode_with(csr: &Csr, meta: Meta) -> EllFormat {
        let n = csr.n();
        let nt = meta.n_tile;
        let k = meta.k;
        let row_tiles = n.div_ceil(nt);
        let mut tile_ptr = Vec::with_capacity(row_tiles + 1);
        tile_ptr.push(0usize);
        let mut tile_edge_ptr = Vec::with_capacity(row_tiles + 1);
        tile_edge_ptr.push(0u64);
        let mut passes: Vec<RowPass> = Vec::new();
        for rt in 0..row_tiles {
            let r0 = rt * nt;
            let r1 = ((rt + 1) * nt).min(n);
            let lanes = r1 - r0;
            let max_deg = (r0..r1).map(|v| csr.degree(v)).max().unwrap_or(0);
            for p in 0..max_deg.div_ceil(k) {
                let mut cols = vec![0u32; lanes * k];
                let mut vals = csr.vals.as_ref().map(|_| vec![0f32; lanes * k]);
                let mut lens = vec![0u16; lanes];
                for (lr, v) in (r0..r1).enumerate() {
                    let nbrs = csr.neighbors(v);
                    let start = p * k;
                    if start >= nbrs.len() {
                        continue;
                    }
                    let cnt = (nbrs.len() - start).min(k);
                    lens[lr] = cnt as u16;
                    cols[lr * k..lr * k + cnt].copy_from_slice(&nbrs[start..start + cnt]);
                    if let (Some(pv), Some(rv)) = (vals.as_mut(), csr.row_vals(v)) {
                        pv[lr * k..lr * k + cnt].copy_from_slice(&rv[start..start + cnt]);
                    }
                }
                passes.push(RowPass { cols, vals, lens });
            }
            tile_ptr.push(passes.len());
            let edges: u64 = csr.row_ptr[r1] - csr.row_ptr[r0];
            tile_edge_ptr.push(tile_edge_ptr[rt] + edges);
        }
        EllFormat { n, m: csr.m(), meta, tile_ptr, tile_edge_ptr, passes }
    }

    /// Total tile passes (the CPU analogue of [`EllPlan::passes`]).
    pub fn pass_count(&self) -> usize {
        self.passes.len()
    }

    /// Process row tiles `[t0, t1)`. A row's passes all live in its
    /// tile, so tile ranges write disjoint rows.
    fn run_tiles(&self, t0: usize, t1: usize, x: &[f32], y: crate::parallel::SendPtr<f32>) {
        let nt = self.meta.n_tile;
        let k = self.meta.k;
        let mut acc = vec![0f32; nt];
        for rt in t0..t1 {
            let r0 = rt * nt;
            let lanes = ((rt + 1) * nt).min(self.n) - r0;
            acc[..lanes].fill(0.0);
            for pass in &self.passes[self.tile_ptr[rt]..self.tile_ptr[rt + 1]] {
                match &pass.vals {
                    Some(pv) => {
                        for lr in 0..lanes {
                            for slot in 0..pass.lens[lr] as usize {
                                acc[lr] +=
                                    pv[lr * k + slot] * x[pass.cols[lr * k + slot] as usize];
                            }
                        }
                    }
                    None => {
                        for lr in 0..lanes {
                            for slot in 0..pass.lens[lr] as usize {
                                acc[lr] += x[pass.cols[lr * k + slot] as usize];
                            }
                        }
                    }
                }
            }
            for lr in 0..lanes {
                // SAFETY: tile ranges are disjoint across callers.
                unsafe { *y.get().add(r0 + lr) = acc[lr] };
            }
        }
    }
}

impl super::format::SpmvFormat for EllFormat {
    fn name(&self) -> &'static str {
        "ell"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn m(&self) -> usize {
        self.m
    }

    fn index_bytes(&self) -> u64 {
        // Padded slots are streamed whether used or not: charge them.
        self.passes.iter().map(|p| 4 * p.cols.len() as u64).sum()
    }

    fn overhead_bytes(&self) -> u64 {
        let lens: u64 = self.passes.iter().map(|p| 2 * p.lens.len() as u64).sum();
        lens + 8 * (self.tile_ptr.len() + self.tile_edge_ptr.len()) as u64
    }

    fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0f32; self.n];
        let tiles = self.tile_ptr.len() - 1;
        self.run_tiles(0, tiles, x, crate::parallel::SendPtr(y.as_mut_ptr()));
        y
    }

    fn spmv_parallel(&self, x: &[f32]) -> Vec<f32> {
        if self.m < super::format::PAR_MIN_EDGES {
            return self.spmv(x);
        }
        assert_eq!(x.len(), self.n);
        let mut y = vec![0f32; self.n];
        let tasks = (crate::parallel::threads() * 8).max(1);
        let bounds = edge_balanced_bounds(&self.tile_edge_ptr, tasks);
        let y_ptr = crate::parallel::SendPtr(y.as_mut_ptr());
        crate::parallel::par_for_chunks(tasks, 1, |t_lo, t_hi| {
            for t in t_lo..t_hi {
                self.run_tiles(bounds[t], bounds[t + 1], x, y_ptr);
            }
        });
        y
    }

    fn decode(&self) -> Csr {
        let nt = self.meta.n_tile;
        let k = self.meta.k;
        let mut row_ptr = vec![0u64; self.n + 1];
        for rt in 0..self.tile_ptr.len() - 1 {
            let r0 = rt * nt;
            for pass in &self.passes[self.tile_ptr[rt]..self.tile_ptr[rt + 1]] {
                for (lr, &cnt) in pass.lens.iter().enumerate() {
                    row_ptr[r0 + lr + 1] += cnt as u64;
                }
            }
        }
        for v in 0..self.n {
            row_ptr[v + 1] += row_ptr[v];
        }
        let mut col_idx = vec![0u32; self.m];
        let mut vals =
            self.passes.iter().find_map(|p| p.vals.as_ref()).map(|_| vec![0f32; self.m]);
        let mut cursor: Vec<u64> = row_ptr[..self.n].to_vec();
        for rt in 0..self.tile_ptr.len() - 1 {
            let r0 = rt * nt;
            for pass in &self.passes[self.tile_ptr[rt]..self.tile_ptr[rt + 1]] {
                for (lr, &cnt) in pass.lens.iter().enumerate() {
                    for slot in 0..cnt as usize {
                        let at = cursor[r0 + lr] as usize;
                        col_idx[at] = pass.cols[lr * k + slot];
                        if let (Some(dv), Some(pv)) = (vals.as_mut(), pass.vals.as_ref()) {
                            dv[at] = pv[lr * k + slot];
                        }
                        cursor[r0 + lr] += 1;
                    }
                }
            }
        }
        Csr { row_ptr, col_idx, vals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert::coo_to_csr;
    use crate::graph::gen;

    fn meta() -> Meta {
        Meta { n_tile: 512, k: 4 }
    }

    #[test]
    fn pack_counts_passes() {
        // A single row with 10 entries in one segment: ceil(10/4)=3 passes.
        let mut src = Vec::new();
        let mut dst = Vec::new();
        for i in 0..10u32 {
            src.push(0);
            dst.push(i);
        }
        let csr = coo_to_csr(&crate::graph::Coo::new(20, src, dst));
        let plan = EllPlan::pack(&csr, meta()).unwrap();
        assert_eq!(plan.passes(), 3);
    }

    #[test]
    fn pack_splits_column_segments() {
        // n = 1000 > 512: edges crossing the segment boundary get their
        // own passes.
        let coo = crate::graph::Coo::new(1000, vec![0, 0], vec![10, 700]);
        let csr = coo_to_csr(&coo);
        let plan = EllPlan::pack(&csr, meta()).unwrap();
        assert_eq!(plan.passes(), 2); // one per segment
    }

    #[test]
    fn pack_dangling_detected() {
        let coo = crate::graph::Coo::new(5, vec![0], vec![1]);
        let csr = coo_to_csr(&coo);
        let plan = EllPlan::pack(&csr, meta()).unwrap();
        assert_eq!(plan.dangling, vec![1, 2, 3, 4]);
    }

    #[test]
    fn boba_reduces_pass_count_vs_random() {
        // Pass count is a pure function of packing, testable without PJRT:
        // clustered labels → fewer (row-tile, segment) crossings.
        use crate::reorder::{boba::Boba, Reorderer};
        let g = gen::preferential_attachment(3000, 4, 5);
        let rand = g.randomized(7);
        let p = Boba::parallel().reorder(&rand);
        let reord = rand.relabeled(p.new_of_old());
        let plan_rand = EllPlan::pack(&coo_to_csr(&rand), meta()).unwrap();
        let plan_boba = EllPlan::pack(&coo_to_csr(&reord), meta()).unwrap();
        assert!(
            plan_boba.passes() <= plan_rand.passes(),
            "boba {} vs rand {}",
            plan_boba.passes(),
            plan_rand.passes()
        );
    }

    #[test]
    fn cpu_ell_matches_spmv_pull_bitwise_and_roundtrips() {
        use super::super::format::SpmvFormat;
        use crate::algos::spmv::spmv_pull;
        let g = gen::rmat(&gen::GenParams::rmat(10, 8), 3).randomized(4);
        let csr = coo_to_csr(&g);
        let f = EllFormat::encode(&csr);
        assert_eq!(f.decode(), csr);
        let x: Vec<f32> = (0..csr.n()).map(|i| (i % 23) as f32 * 0.5 - 5.0).collect();
        let want = spmv_pull(&csr, &x);
        let got = f.spmv(&x);
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn cpu_ell_padding_survives_infinite_inputs() {
        // The historical failure mode: padding slots as col=0/val=0.0
        // give 0.0·x[0] = NaN when x[0] = ∞. The length-guarded kernel
        // must stay bit-identical to spmv_pull regardless of x[0].
        use super::super::format::SpmvFormat;
        use crate::algos::spmv::spmv_pull;
        let n = 300usize;
        let mut src: Vec<u32> = Vec::new();
        let mut dst: Vec<u32> = Vec::new();
        for v in 1..n as u32 {
            // Hub row 0 forces multiple passes; short rows 1.. leave
            // padding slots in every pass after their first.
            src.push(0);
            dst.push(v);
            src.push(v);
            dst.push(v - 1);
        }
        let csr = coo_to_csr(&crate::graph::Coo::new(n, src, dst));
        let f = EllFormat::encode(&csr);
        let mut x: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        x[0] = f32::INFINITY;
        let want = spmv_pull(&csr, &x);
        let got = f.spmv(&x);
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "length guards must keep padding out of the accumulators"
        );
    }
}
