//! CSR → fixed-shape ELL tile packing for the AOT artifacts.
//!
//! The artifacts are compiled for a static `(N_TILE, K)` tile whose
//! column ids index a length-`N_TILE` vector segment. An arbitrary CSR is
//! therefore decomposed along both axes:
//!
//! * rows are split into **row tiles** of `N_TILE`;
//! * the column space is split into **column segments** of `N_TILE`
//!   (each segment sees its own slice of `x`);
//! * within a (row-tile, segment) block, rows holding more than `K`
//!   entries spill into additional **passes**.
//!
//! Execution accumulates `y[tile] += artifact(cols, vals, x[segment])`
//! over all passes. Padding slots carry `col = 0, val = 0.0`, which the
//! kernel's multiply annihilates.
//!
//! BOBA's effect is visible here too: clustered column labels concentrate
//! a row's entries into fewer segments, producing fewer passes (the
//! pass count is reported by [`EllPlan::passes`] and benchmarked in
//! docs/EXPERIMENTS.md).

use super::Meta;
#[cfg(feature = "pjrt")]
use super::{Engine, SpmvKind};
use crate::graph::Csr;
use anyhow::Result;

/// One executable tile pass.
#[derive(Clone, Debug)]
struct TilePass {
    row_tile: usize,
    col_seg: usize,
    cols: Vec<i32>,
    vals: Vec<f32>,
}

/// A packed execution plan for one CSR matrix.
#[derive(Clone, Debug)]
pub struct EllPlan {
    meta: Meta,
    n_rows: usize,
    n_cols: usize,
    passes: Vec<TilePass>,
    /// Vertices with zero out-degree in the *original* orientation —
    /// needed by PageRank's dangling-mass correction.
    pub dangling: Vec<u32>,
}

impl EllPlan {
    /// Pack a CSR into tile passes for `meta`'s geometry.
    pub fn pack(csr: &Csr, meta: Meta) -> Result<EllPlan> {
        let n = csr.n();
        let nt = meta.n_tile;
        let k = meta.k;
        let row_tiles = n.div_ceil(nt).max(1);
        let col_segs = n.div_ceil(nt).max(1);
        let mut passes: Vec<TilePass> = Vec::new();
        // Per (row_tile, col_seg): a vector of per-local-row entry lists.
        // Built tile-by-tile to bound peak memory.
        for rt in 0..row_tiles {
            let r0 = rt * nt;
            let r1 = ((rt + 1) * nt).min(n);
            // entries[seg][local_row] -> (local_col, val)
            let mut entries: Vec<Vec<Vec<(i32, f32)>>> = Vec::new();
            entries.resize_with(col_segs, || vec![Vec::new(); r1 - r0]);
            for r in r0..r1 {
                let (lo, hi) = (csr.row_ptr[r] as usize, csr.row_ptr[r + 1] as usize);
                for e in lo..hi {
                    let c = csr.col_idx[e] as usize;
                    let seg = c / nt;
                    let val = csr.vals.as_ref().map_or(1.0, |v| v[e]);
                    entries[seg][r - r0].push(((c - seg * nt) as i32, val));
                }
            }
            for (seg, rows) in entries.into_iter().enumerate() {
                let max_deg = rows.iter().map(|r| r.len()).max().unwrap_or(0);
                if max_deg == 0 {
                    continue;
                }
                let npass = max_deg.div_ceil(k);
                for p in 0..npass {
                    let mut cols = vec![0i32; nt * k];
                    let mut vals = vec![0f32; nt * k];
                    let mut used = false;
                    for (lr, row) in rows.iter().enumerate() {
                        let start = p * k;
                        if start >= row.len() {
                            continue;
                        }
                        for (slot, &(c, v)) in
                            row[start..row.len().min(start + k)].iter().enumerate()
                        {
                            cols[lr * k + slot] = c;
                            vals[lr * k + slot] = v;
                            used = true;
                        }
                    }
                    if used {
                        passes.push(TilePass { row_tile: rt, col_seg: seg, cols, vals });
                    }
                }
            }
        }
        let dangling =
            (0..n).filter(|&v| csr.degree(v) == 0).map(|v| v as u32).collect();
        Ok(EllPlan { meta, n_rows: n, n_cols: n, passes, dangling })
    }

    /// Pack the *pull* (transposed, 1/outdeg-weighted) matrix of a graph
    /// for PageRank: `y[v] = Σ_{u→v} rank[u] / outdeg(u)`.
    pub fn pack_pagerank(csr: &Csr, meta: Meta) -> Result<EllPlan> {
        let n = csr.n();
        let mut weighted = csr.clone();
        let mut vals = vec![0f32; csr.m()];
        for v in 0..n {
            let deg = csr.degree(v);
            if deg == 0 {
                continue;
            }
            let w = 1.0 / deg as f32;
            for e in csr.row_ptr[v] as usize..csr.row_ptr[v + 1] as usize {
                vals[e] = w;
            }
        }
        weighted.vals = Some(vals);
        let mut plan = Self::pack(&weighted.transposed(), meta)?;
        // Dangling = zero out-degree in the ORIGINAL orientation.
        plan.dangling = (0..n).filter(|&v| csr.degree(v) == 0).map(|v| v as u32).collect();
        Ok(plan)
    }

    /// Number of tile passes (the PJRT launch count for one SpMV).
    pub fn passes(&self) -> usize {
        self.passes.len()
    }

    /// Rows of the packed matrix.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Execute the plan: `y = A·x` with `x` of length ≥ n (padded
    /// internally). Only available with the `pjrt` feature (needs a
    /// compiled [`Engine`]); packing itself is feature-free.
    #[cfg(feature = "pjrt")]
    pub fn execute(&self, engine: &Engine, kind: SpmvKind, x: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            x.len() >= self.n_cols,
            "x has {} entries, matrix has {} columns",
            x.len(),
            self.n_cols
        );
        let nt = self.meta.n_tile;
        let padded_rows = self.n_rows.div_ceil(nt) * nt;
        let padded_cols = self.n_cols.div_ceil(nt) * nt;
        let mut xp = x[..self.n_cols].to_vec();
        xp.resize(padded_cols, 0.0);
        let mut y = vec![0f32; padded_rows];
        for pass in &self.passes {
            let seg = &xp[pass.col_seg * nt..(pass.col_seg + 1) * nt];
            let part = engine.spmv_tile(kind, &pass.cols, &pass.vals, seg)?;
            let y_slice = &mut y[pass.row_tile * nt..(pass.row_tile + 1) * nt];
            for (acc, p) in y_slice.iter_mut().zip(&part) {
                *acc += p;
            }
        }
        y.truncate(self.n_rows);
        Ok(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert::coo_to_csr;
    use crate::graph::gen;

    fn meta() -> Meta {
        Meta { n_tile: 512, k: 4 }
    }

    #[test]
    fn pack_counts_passes() {
        // A single row with 10 entries in one segment: ceil(10/4)=3 passes.
        let mut src = Vec::new();
        let mut dst = Vec::new();
        for i in 0..10u32 {
            src.push(0);
            dst.push(i);
        }
        let csr = coo_to_csr(&crate::graph::Coo::new(20, src, dst));
        let plan = EllPlan::pack(&csr, meta()).unwrap();
        assert_eq!(plan.passes(), 3);
    }

    #[test]
    fn pack_splits_column_segments() {
        // n = 1000 > 512: edges crossing the segment boundary get their
        // own passes.
        let coo = crate::graph::Coo::new(1000, vec![0, 0], vec![10, 700]);
        let csr = coo_to_csr(&coo);
        let plan = EllPlan::pack(&csr, meta()).unwrap();
        assert_eq!(plan.passes(), 2); // one per segment
    }

    #[test]
    fn pack_dangling_detected() {
        let coo = crate::graph::Coo::new(5, vec![0], vec![1]);
        let csr = coo_to_csr(&coo);
        let plan = EllPlan::pack(&csr, meta()).unwrap();
        assert_eq!(plan.dangling, vec![1, 2, 3, 4]);
    }

    #[test]
    fn boba_reduces_pass_count_vs_random() {
        // Pass count is a pure function of packing, testable without PJRT:
        // clustered labels → fewer (row-tile, segment) crossings.
        use crate::reorder::{boba::Boba, Reorderer};
        let g = gen::preferential_attachment(3000, 4, 5);
        let rand = g.randomized(7);
        let p = Boba::parallel().reorder(&rand);
        let reord = rand.relabeled(p.new_of_old());
        let plan_rand = EllPlan::pack(&coo_to_csr(&rand), meta()).unwrap();
        let plan_boba = EllPlan::pack(&coo_to_csr(&reord), meta()).unwrap();
        assert!(
            plan_boba.passes() <= plan_rand.passes(),
            "boba {} vs rand {}",
            plan_boba.passes(),
            plan_rand.passes()
        );
    }
}
