//! SELL-C-σ — sliced ELL with σ-window length sorting.
//!
//! Kreutzer et al.'s format, tuned here for the CPU pool: rows are
//! sorted by degree (descending, stable) within windows of
//! [`SELL_SIGMA`] consecutive rows of the *current* ordering — so a
//! BOBA-reordered CSR keeps its locality, the sort only shuffles
//! within small windows — then packed into slices of [`SELL_C`] rows.
//! Each slice is padded to its longest member and stored slot-major
//! (`cols[slice_base + slot·C + lane]`), which is the
//! vectorization-friendly layout; per-lane row ids and lengths are
//! kept alongside for the scatter and the padding guards.
//!
//! Two properties give bit-identity with `spmv_pull` structurally:
//! a row's slots hold its edges in original CSR order (slot `i` =
//! edge `i`), and padding slots are skipped by a **length guard**
//! (`slot < lens[lane]`) rather than annihilated by a `0.0` value —
//! so padding can never contribute to an accumulator, not even a
//! `0.0·∞ = NaN`. Each row lives in exactly one lane of one slice,
//! so parallel slice ranges write disjoint rows.

use crate::algos::spmv::edge_balanced_bounds;
use crate::graph::Csr;
use crate::parallel::{self, SendPtr};

use super::format::{SpmvFormat, PAR_MIN_EDGES};

/// Slice height (rows per slice) — 8 lanes matches a 256-bit f32
/// vector and keeps the per-slice accumulator block in registers.
pub const SELL_C: usize = 8;

/// Length-sort window. Sorting only within 256-row windows bounds how
/// far the packing strays from the input (BOBA) order while still
/// grouping similar-length rows into slices (less padding).
pub const SELL_SIGMA: usize = 256;

/// Lane marker for padding lanes of the final partial slice.
const PAD_ROW: u32 = u32::MAX;

/// A SELL-C-σ encoded operator. See the module docs for the layout.
pub struct SellCs {
    n: usize,
    m: usize,
    /// Source row of each lane, slice-major: `rows[s·C + lane]`
    /// (`PAD_ROW` for padding lanes of the last slice).
    rows: Vec<u32>,
    /// Stored edge count of each lane (same indexing as `rows`).
    lens: Vec<u32>,
    /// Padded-slot offsets per slice: slice `s` owns
    /// `cols[slice_ptr[s] .. slice_ptr[s+1]]`.
    slice_ptr: Vec<u64>,
    /// Column indices, slot-major within each slice; padding slots 0.
    cols: Vec<u32>,
    /// Edge values aligned with `cols` (weighted graphs only).
    vals: Option<Vec<f32>>,
}

impl SellCs {
    /// Encode `csr`: σ-window stable length sort, then C-row slices
    /// padded to their longest member.
    pub fn encode(csr: &Csr) -> SellCs {
        let n = csr.n();
        let m = csr.m();
        let mut order: Vec<u32> = (0..n as u32).collect();
        for w0 in (0..n).step_by(SELL_SIGMA) {
            let w1 = (w0 + SELL_SIGMA).min(n);
            // Stable: equal-length rows keep their (BOBA) order.
            order[w0..w1].sort_by_key(|&r| std::cmp::Reverse(csr.degree(r as usize)));
        }
        let n_slices = n.div_ceil(SELL_C);
        let mut rows = vec![PAD_ROW; n_slices * SELL_C];
        let mut lens = vec![0u32; n_slices * SELL_C];
        let mut slice_ptr = Vec::with_capacity(n_slices + 1);
        slice_ptr.push(0u64);
        let mut slots = 0u64;
        for s in 0..n_slices {
            let mut width = 0usize;
            for lane in 0..SELL_C {
                let g = s * SELL_C + lane;
                if g < n {
                    let r = order[g];
                    rows[g] = r;
                    let d = csr.degree(r as usize);
                    lens[g] = d as u32;
                    width = width.max(d);
                }
            }
            slots += (width * SELL_C) as u64;
            slice_ptr.push(slots);
        }
        let mut cols = vec![0u32; slots as usize];
        let mut vals = csr.vals.as_ref().map(|_| vec![0f32; slots as usize]);
        for s in 0..n_slices {
            let base = slice_ptr[s] as usize;
            for lane in 0..SELL_C {
                let g = s * SELL_C + lane;
                let r = rows[g];
                if r == PAD_ROW {
                    continue;
                }
                let nbrs = csr.neighbors(r as usize);
                let rv = csr.row_vals(r as usize);
                for (slot, &c) in nbrs.iter().enumerate() {
                    cols[base + slot * SELL_C + lane] = c;
                    if let (Some(v), Some(rv)) = (vals.as_mut(), rv) {
                        v[base + slot * SELL_C + lane] = rv[slot];
                    }
                }
            }
        }
        SellCs { n, m, rows, lens, slice_ptr, cols, vals }
    }

    /// Process slices `[s0, s1)`, writing each lane's accumulator to
    /// its source row. Caller guarantees the slice ranges are
    /// disjoint (each row lives in exactly one slice).
    fn run_slices(&self, s0: usize, s1: usize, x: &[f32], y: SendPtr<f32>) {
        for s in s0..s1 {
            let base = self.slice_ptr[s] as usize;
            let width = (self.slice_ptr[s + 1] - self.slice_ptr[s]) as usize / SELL_C;
            let lane0 = s * SELL_C;
            let mut acc = [0f32; SELL_C];
            match &self.vals {
                Some(vals) => {
                    for slot in 0..width {
                        let off = base + slot * SELL_C;
                        for l in 0..SELL_C {
                            if (slot as u32) < self.lens[lane0 + l] {
                                acc[l] += vals[off + l] * x[self.cols[off + l] as usize];
                            }
                        }
                    }
                }
                None => {
                    for slot in 0..width {
                        let off = base + slot * SELL_C;
                        for l in 0..SELL_C {
                            if (slot as u32) < self.lens[lane0 + l] {
                                acc[l] += x[self.cols[off + l] as usize];
                            }
                        }
                    }
                }
            }
            for l in 0..SELL_C {
                let r = self.rows[lane0 + l];
                if r != PAD_ROW {
                    // SAFETY: each row lives in exactly one lane, and
                    // slice ranges are disjoint across callers.
                    unsafe { *y.get().add(r as usize) = acc[l] };
                }
            }
        }
    }
}

impl SpmvFormat for SellCs {
    fn name(&self) -> &'static str {
        "sell"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn m(&self) -> usize {
        self.m
    }

    fn index_bytes(&self) -> u64 {
        // Padding slots are real bytes the kernel streams: charge them.
        4 * self.cols.len() as u64
    }

    fn overhead_bytes(&self) -> u64 {
        4 * self.rows.len() as u64 + 4 * self.lens.len() as u64 + 8 * self.slice_ptr.len() as u64
    }

    fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0f32; self.n];
        let n_slices = self.slice_ptr.len() - 1;
        self.run_slices(0, n_slices, x, SendPtr(y.as_mut_ptr()));
        y
    }

    fn spmv_parallel(&self, x: &[f32]) -> Vec<f32> {
        if self.m < PAR_MIN_EDGES {
            return self.spmv(x);
        }
        assert_eq!(x.len(), self.n);
        let mut y = vec![0f32; self.n];
        let tasks = (parallel::threads() * 8).max(1);
        // Balance tasks by padded slots — the slice-granular analogue
        // of edge-balanced row bounds.
        let bounds = edge_balanced_bounds(&self.slice_ptr, tasks);
        let y_ptr = SendPtr(y.as_mut_ptr());
        parallel::par_for_chunks(tasks, 1, |t_lo, t_hi| {
            for t in t_lo..t_hi {
                self.run_slices(bounds[t], bounds[t + 1], x, y_ptr);
            }
        });
        y
    }

    fn decode(&self) -> Csr {
        let mut row_ptr = vec![0u64; self.n + 1];
        for (g, &r) in self.rows.iter().enumerate() {
            if r != PAD_ROW {
                row_ptr[r as usize + 1] = self.lens[g] as u64;
            }
        }
        for v in 0..self.n {
            row_ptr[v + 1] += row_ptr[v];
        }
        let mut col_idx = vec![0u32; self.m];
        let mut vals = self.vals.as_ref().map(|_| vec![0f32; self.m]);
        let n_slices = self.slice_ptr.len() - 1;
        for s in 0..n_slices {
            let base = self.slice_ptr[s] as usize;
            for lane in 0..SELL_C {
                let g = s * SELL_C + lane;
                let r = self.rows[g];
                if r == PAD_ROW {
                    continue;
                }
                let lo = row_ptr[r as usize] as usize;
                for slot in 0..self.lens[g] as usize {
                    col_idx[lo + slot] = self.cols[base + slot * SELL_C + lane];
                    if let (Some(dv), Some(sv)) = (vals.as_mut(), self.vals.as_ref()) {
                        dv[lo + slot] = sv[base + slot * SELL_C + lane];
                    }
                }
            }
        }
        Csr { row_ptr, col_idx, vals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::spmv::spmv_pull;
    use crate::convert;
    use crate::graph::gen::{self, GenParams};

    #[test]
    fn skewed_graph_roundtrips_and_matches_bitwise() {
        let g = gen::rmat(&GenParams::rmat(10, 8), 11).randomized(13);
        let csr = convert::coo_to_csr(&g);
        let f = SellCs::encode(&csr);
        assert_eq!(f.decode(), csr);
        let x: Vec<f32> = (0..csr.n()).map(|i| (i % 13) as f32 * 0.5 - 3.0).collect();
        let want = spmv_pull(&csr, &x);
        let got = f.spmv(&x);
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn padding_is_guarded_not_annihilated() {
        // Row 0 is a hub; its slice-mates are short rows whose padding
        // slots would read x[0] if unguarded. x[0] = ∞ turns any
        // 0.0·x[0] annihilation into NaN — the guard must keep every
        // short row finite and bit-identical.
        let n = 64usize;
        let mut src: Vec<u32> = Vec::new();
        let mut dst: Vec<u32> = Vec::new();
        for v in 0..n as u32 {
            src.push(0);
            dst.push(v);
            src.push(v);
            dst.push((v + 1) % n as u32);
        }
        let csr = convert::coo_to_csr(&crate::graph::Coo::new(n, src, dst));
        let f = SellCs::encode(&csr);
        let mut x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        x[0] = f32::INFINITY;
        let want = spmv_pull(&csr, &x);
        let got = f.spmv(&x);
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "guarded padding must match spmv_pull bit-for-bit under ±∞ inputs"
        );
    }
}
