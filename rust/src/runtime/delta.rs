//! Delta/narrow CSR — per-row-block column compression.
//!
//! Rows are grouped into blocks of [`DELTA_BLOCK_ROWS`] consecutive
//! rows (their edges are contiguous in CSR, so a block is one edge
//! range). A block whose column **span** (`max_col − min_col`) fits in
//! a `u16` stores its columns as 2-byte deltas from the block's minimum
//! column (the 4-byte base); a block that doesn't, or that holds fewer
//! than two edges, falls back to raw 4-byte columns. Under a BOBA
//! ordering most blocks are narrow — neighbor IDs cluster — so the
//! column stream approaches 2 bytes/edge; under random labels every
//! block of a large graph spans the full ID range and the format
//! degrades gracefully to plain-CSR width.
//!
//! The narrow rule `span ≤ 65535 && edges ≥ 2` makes
//! `bytes_per_edge ≤ 4.0` an *invariant*, not a tendency: a narrow
//! block pays `2·edges + 4` (deltas + base) against plain CSR's
//! `4·edges`, which wins exactly when `edges ≥ 2`; wide and empty
//! blocks pay plain-CSR cost or nothing. `tests/format_fuzz.rs`
//! hammers the boundary (spans of exactly 65535/65536, empty rows
//! inside blocks, hub rows) with seeded random graphs.
//!
//! SpMV decodes on the fly — `col = base + delta` per edge, in
//! original edge order — so bit-identity with `spmv_pull` is
//! structural, not incidental.

use crate::algos::spmv::edge_balanced_bounds;
use crate::graph::Csr;
use crate::parallel::{self, SendPtr};

use super::format::{SpmvFormat, PAR_MIN_EDGES};

/// Rows per compression block. 64 rows keeps block descriptors cheap
/// (one per cache line of `row_ptr`) while giving the span check
/// enough edges to amortize the 4-byte base.
pub const DELTA_BLOCK_ROWS: usize = 64;

/// Per-block descriptor: where the block's column stream starts
/// (in `cols16` if narrow, `cols32` otherwise) and the narrow base.
#[derive(Clone, Copy, Debug)]
struct Block {
    /// Offset into `cols16` (narrow) or `cols32` (wide) of this
    /// block's first edge.
    start: u32,
    /// Minimum column of the block — the value deltas are relative to.
    base: u32,
    /// Whether this block's columns live in the u16 delta stream.
    narrow: bool,
}

/// A CSR with per-block delta-compressed column indices. See the
/// module docs for the layout and the narrow/wide fallback rule.
pub struct DeltaCsr {
    n: usize,
    row_ptr: Vec<u64>,
    blocks: Vec<Block>,
    cols16: Vec<u16>,
    cols32: Vec<u32>,
    vals: Option<Vec<f32>>,
    narrow_blocks: usize,
    wide_blocks: usize,
}

impl DeltaCsr {
    /// Encode `csr`. One pass over the edges per block: min/max scan,
    /// then delta or raw emission. Edge order is preserved exactly.
    pub fn encode(csr: &Csr) -> DeltaCsr {
        let n = csr.n();
        let m = csr.m();
        assert!(m <= u32::MAX as usize, "delta format indexes edge streams with u32");
        let n_blocks = n.div_ceil(DELTA_BLOCK_ROWS);
        let mut blocks = Vec::with_capacity(n_blocks);
        let mut cols16: Vec<u16> = Vec::new();
        let mut cols32: Vec<u32> = Vec::new();
        let mut narrow_blocks = 0usize;
        let mut wide_blocks = 0usize;
        for b in 0..n_blocks {
            let r0 = b * DELTA_BLOCK_ROWS;
            let r1 = ((b + 1) * DELTA_BLOCK_ROWS).min(n);
            let e0 = csr.row_ptr[r0] as usize;
            let e1 = csr.row_ptr[r1] as usize;
            if e0 == e1 {
                // Empty block: zero column-stream bytes, counted as
                // neither narrow nor wide.
                blocks.push(Block { start: cols32.len() as u32, base: 0, narrow: false });
                continue;
            }
            let block_cols = &csr.col_idx[e0..e1];
            let mut lo = u32::MAX;
            let mut hi = 0u32;
            for &c in block_cols {
                lo = lo.min(c);
                hi = hi.max(c);
            }
            let edges = e1 - e0;
            // Narrow iff the span fits u16 AND 2·edges + 4 ≤ 4·edges,
            // i.e. edges ≥ 2 — the bytes_per_edge ≤ 4.0 invariant.
            if hi - lo <= u16::MAX as u32 && edges >= 2 {
                blocks.push(Block { start: cols16.len() as u32, base: lo, narrow: true });
                cols16.extend(block_cols.iter().map(|&c| (c - lo) as u16));
                narrow_blocks += 1;
            } else {
                blocks.push(Block { start: cols32.len() as u32, base: 0, narrow: false });
                cols32.extend_from_slice(block_cols);
                wide_blocks += 1;
            }
        }
        DeltaCsr {
            n,
            row_ptr: csr.row_ptr.clone(),
            blocks,
            cols16,
            cols32,
            vals: csr.vals.clone(),
            narrow_blocks,
            wide_blocks,
        }
    }

    /// Blocks encoded in the u16 delta stream.
    pub fn narrow_blocks(&self) -> usize {
        self.narrow_blocks
    }

    /// Non-empty blocks that fell back to raw u32 columns.
    pub fn wide_blocks(&self) -> usize {
        self.wide_blocks
    }

    /// Accumulate rows `[r0, r1)` into the output behind `y`. Caller
    /// guarantees exclusive access to those rows.
    fn run_rows(&self, r0: usize, r1: usize, x: &[f32], y: SendPtr<f32>) {
        for v in r0..r1 {
            let blk = self.blocks[v / DELTA_BLOCK_ROWS];
            let block_e0 = self.row_ptr[(v / DELTA_BLOCK_ROWS) * DELTA_BLOCK_ROWS] as usize;
            let lo = self.row_ptr[v] as usize;
            let hi = self.row_ptr[v + 1] as usize;
            let start = blk.start as usize;
            let mut acc = 0f32;
            match &self.vals {
                Some(vals) => {
                    if blk.narrow {
                        for e in lo..hi {
                            let c = blk.base + self.cols16[start + (e - block_e0)] as u32;
                            acc += vals[e] * x[c as usize];
                        }
                    } else {
                        for e in lo..hi {
                            let c = self.cols32[start + (e - block_e0)];
                            acc += vals[e] * x[c as usize];
                        }
                    }
                }
                None => {
                    if blk.narrow {
                        for e in lo..hi {
                            let c = blk.base + self.cols16[start + (e - block_e0)] as u32;
                            acc += x[c as usize];
                        }
                    } else {
                        for e in lo..hi {
                            let c = self.cols32[start + (e - block_e0)];
                            acc += x[c as usize];
                        }
                    }
                }
            }
            // SAFETY: row ranges are disjoint across callers.
            unsafe { *y.get().add(v) = acc };
        }
    }
}

impl SpmvFormat for DeltaCsr {
    fn name(&self) -> &'static str {
        "delta"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn m(&self) -> usize {
        self.row_ptr.last().copied().unwrap_or(0) as usize
    }

    fn index_bytes(&self) -> u64 {
        2 * self.cols16.len() as u64
            + 4 * self.cols32.len() as u64
            + 4 * self.narrow_blocks as u64
    }

    fn overhead_bytes(&self) -> u64 {
        // row_ptr plus the non-base part of the block descriptors
        // (stream offset + narrow flag).
        8 * self.row_ptr.len() as u64 + 5 * self.blocks.len() as u64
    }

    fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0f32; self.n];
        self.run_rows(0, self.n, x, SendPtr(y.as_mut_ptr()));
        y
    }

    fn spmv_parallel(&self, x: &[f32]) -> Vec<f32> {
        if self.m() < PAR_MIN_EDGES {
            return self.spmv(x);
        }
        assert_eq!(x.len(), self.n);
        let mut y = vec![0f32; self.n];
        let tasks = (parallel::threads() * 8).max(1);
        let bounds = edge_balanced_bounds(&self.row_ptr, tasks);
        let y_ptr = SendPtr(y.as_mut_ptr());
        parallel::par_for_chunks(tasks, 1, |t_lo, t_hi| {
            for t in t_lo..t_hi {
                self.run_rows(bounds[t], bounds[t + 1], x, y_ptr);
            }
        });
        y
    }

    fn decode(&self) -> Csr {
        let mut col_idx = Vec::with_capacity(self.m());
        for b in 0..self.blocks.len() {
            let blk = self.blocks[b];
            let e0 = self.row_ptr[b * DELTA_BLOCK_ROWS] as usize;
            let e1 = self.row_ptr[((b + 1) * DELTA_BLOCK_ROWS).min(self.n)] as usize;
            let start = blk.start as usize;
            if blk.narrow {
                col_idx
                    .extend(self.cols16[start..start + (e1 - e0)].iter().map(|&d| blk.base + d as u32));
            } else {
                col_idx.extend_from_slice(&self.cols32[start..start + (e1 - e0)]);
            }
        }
        Csr { row_ptr: self.row_ptr.clone(), col_idx, vals: self.vals.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert;
    use crate::graph::gen::{self, GenParams};

    #[test]
    fn boba_clustered_columns_compress_below_plain_csr() {
        // Local neighborhoods: every row's columns within ±100.
        let n = 4096u32;
        let mut src = Vec::new();
        let mut dst = Vec::new();
        for v in 0..n {
            for k in 1..=4u32 {
                src.push(v);
                dst.push((v + k * 25) % n);
            }
        }
        let csr = convert::coo_to_csr(&crate::graph::Coo::new(n as usize, src, dst));
        let d = DeltaCsr::encode(&csr);
        assert_eq!(d.wide_blocks(), 0, "local graph must be all-narrow");
        assert!(d.bytes_per_edge() < 2.5, "got {}", d.bytes_per_edge());
        assert_eq!(d.decode(), csr);
    }

    #[test]
    fn bytes_per_edge_never_exceeds_plain_csr() {
        let g = gen::rmat(&GenParams::rmat(10, 8), 7).randomized(9);
        let csr = convert::coo_to_csr(&g);
        let d = DeltaCsr::encode(&csr);
        assert!(d.bytes_per_edge() <= 4.0 + 1e-12, "got {}", d.bytes_per_edge());
        assert_eq!(d.decode(), csr);
    }
}
