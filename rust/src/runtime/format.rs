//! The `SpmvFormat` trait — a family of interchangeable CSR kernel
//! layouts, every one of them held to the repo's bit-identical
//! determinism bar.
//!
//! BOBA's reordering makes a row's neighbor IDs nearly monotone-local,
//! and plain 4-byte CSR leaves that structure on the table. The formats
//! behind this trait exploit it in the *layout*:
//!
//! | name    | module            | idea                                   |
//! |---------|-------------------|----------------------------------------|
//! | `csr`   | this module       | plain CSR (`spmv_pull`), the reference |
//! | `delta` | [`super::delta`]  | u16 column deltas per 64-row block     |
//! | `sell`  | [`super::sell`]   | SELL-C-σ sliced ELL (C=8, σ=256)       |
//! | `tiled` | [`super::tiled`]  | L2-sized column tiles, u16 local cols  |
//! | `ell`   | [`super::ell`]    | row-tiled ELL with length guards       |
//!
//! **The contract** (the same bar `spmm_pull` and the deterministic
//! parallel converter meet): for every format, `spmv` and
//! `spmv_parallel` return a `y` vector whose every `f32` is
//! **bit-identical** to [`crate::algos::spmv::spmv_pull`] on the source
//! CSR, at every thread count. That pins the accumulation order: each
//! destination row starts from `0.0f32` and adds its edge contributions
//! in original CSR edge order. `tests/format_equiv.rs` enforces the
//! contract differentially; encoders must also round-trip exactly
//! (`decode()` reproduces the source CSR, `==` on all arrays).
//!
//! Byte accounting: [`SpmvFormat::index_bytes`] is the encoded
//! column-index stream (the per-edge gather addresses, including any
//! per-block bases needed to reconstruct them) — `bytes_per_edge` is
//! that over `m`, so plain CSR scores exactly 4.0 and `delta`'s win
//! under a BOBA ordering is directly comparable. Row-structure and
//! control arrays (row pointers, slice tables, pass headers) are
//! reported separately via [`SpmvFormat::overhead_bytes`].

use crate::algos::spmv;
use crate::graph::Csr;

/// Below this edge count every `spmv_parallel` falls back to the
/// sequential kernel — the same cutoff `spmv_pull_parallel` uses, so
/// the formats inherit its small-graph behavior.
pub(crate) const PAR_MIN_EDGES: usize = 1 << 14;

/// Registry of encodable format names, in the order the evidence layer
/// (repro T5, `micro_format`) sweeps them. Every name is accepted by
/// [`encode`] and by `serve --format`.
pub const FORMAT_NAMES: [&str; 5] = ["csr", "delta", "sell", "tiled", "ell"];

/// A CSR kernel layout: an encoded sparse operator that can run SpMV
/// bit-identically to `spmv_pull` on the CSR it was encoded from.
pub trait SpmvFormat: Send + Sync {
    /// Format name as listed in [`FORMAT_NAMES`].
    fn name(&self) -> &'static str;

    /// Number of rows/vertices of the encoded operator.
    fn n(&self) -> usize;

    /// Number of stored edges (padding slots excluded).
    fn m(&self) -> usize;

    /// Bytes of the encoded column-index stream: everything needed to
    /// reconstruct the per-edge gather addresses (delta streams,
    /// per-block bases, padded ELL slots), excluding row structure.
    /// Plain CSR: `4·m`.
    fn index_bytes(&self) -> u64;

    /// Bytes of row-structure and control arrays beyond the index
    /// stream (row pointers, slice/segment tables, lane lengths).
    fn overhead_bytes(&self) -> u64;

    /// Column-stream bytes per edge — the compression headline
    /// (plain CSR = 4.0; 0.0 for an edgeless graph).
    fn bytes_per_edge(&self) -> f64 {
        if self.m() == 0 {
            0.0
        } else {
            self.index_bytes() as f64 / self.m() as f64
        }
    }

    /// Sequential SpMV (`y = A·x` pull-style). Bit-identical to
    /// `spmv_pull` on the source CSR.
    fn spmv(&self, x: &[f32]) -> Vec<f32>;

    /// Pool-parallel SpMV. Bit-identical to the sequential kernel (and
    /// therefore to `spmv_pull`) at every thread count: rows are
    /// partitioned, never split, so each accumulation chain is intact.
    fn spmv_parallel(&self, x: &[f32]) -> Vec<f32>;

    /// Reconstruct the source CSR exactly (same `row_ptr`, `col_idx`
    /// in original edge order, same `vals`).
    fn decode(&self) -> Csr;
}

/// Encode `csr` into the named format. Accepts any name in
/// [`FORMAT_NAMES`]; errors (listing the vocabulary) otherwise.
pub fn encode(name: &str, csr: &Csr) -> anyhow::Result<Box<dyn SpmvFormat>> {
    Ok(match name {
        "csr" => Box::new(CsrFormat::encode(csr)),
        "delta" => Box::new(super::delta::DeltaCsr::encode(csr)),
        "sell" => Box::new(super::sell::SellCs::encode(csr)),
        "tiled" => Box::new(super::tiled::TiledCsr::encode(csr)),
        "ell" => Box::new(super::ell::EllFormat::encode(csr)),
        other => anyhow::bail!(
            "unknown kernel format {other:?} (expected one of {})",
            FORMAT_NAMES.join("|")
        ),
    })
}

/// Plain CSR behind the trait: the identity encoding and the reference
/// point every other format is measured against (4.0 bytes/edge,
/// kernels delegate to `spmv_pull` / `spmv_pull_parallel`).
pub struct CsrFormat {
    csr: Csr,
}

impl CsrFormat {
    /// Wrap a clone of `csr` (the identity encoding).
    pub fn encode(csr: &Csr) -> CsrFormat {
        CsrFormat { csr: csr.clone() }
    }
}

impl SpmvFormat for CsrFormat {
    fn name(&self) -> &'static str {
        "csr"
    }

    fn n(&self) -> usize {
        self.csr.n()
    }

    fn m(&self) -> usize {
        self.csr.m()
    }

    fn index_bytes(&self) -> u64 {
        self.csr.bytes_indices()
    }

    fn overhead_bytes(&self) -> u64 {
        self.csr.bytes_offsets()
    }

    fn spmv(&self, x: &[f32]) -> Vec<f32> {
        spmv::spmv_pull(&self.csr, x)
    }

    fn spmv_parallel(&self, x: &[f32]) -> Vec<f32> {
        spmv::spmv_pull_parallel(&self.csr, x)
    }

    fn decode(&self) -> Csr {
        self.csr.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert;
    use crate::graph::gen::{self, GenParams};

    #[test]
    fn every_registered_name_encodes() {
        let g = gen::rmat(&GenParams::rmat(8, 4), 3).randomized(5);
        let csr = convert::coo_to_csr(&g);
        for name in FORMAT_NAMES {
            let f = encode(name, &csr).expect("registered name must encode");
            assert_eq!(f.name(), name);
            assert_eq!(f.n(), csr.n());
            assert_eq!(f.m(), csr.m());
            assert_eq!(f.decode(), csr, "{name}: decode must round-trip");
        }
    }

    #[test]
    fn unknown_name_is_rejected_with_vocabulary() {
        let csr = convert::coo_to_csr(&crate::graph::Coo::new(2, vec![0], vec![1]));
        let err = encode("bitmap", &csr).unwrap_err().to_string();
        assert!(err.contains("csr|delta|sell|tiled|ell"), "got: {err}");
    }

    #[test]
    fn plain_csr_scores_four_bytes_per_edge() {
        let g = gen::rmat(&GenParams::rmat(8, 4), 3);
        let csr = convert::coo_to_csr(&g);
        let f = CsrFormat::encode(&csr);
        assert!((f.bytes_per_edge() - 4.0).abs() < 1e-12);
        assert_eq!(f.index_bytes(), 4 * csr.m() as u64);
    }
}
