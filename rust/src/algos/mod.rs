//! The paper's four evaluation workloads (§5.1): SpMV, PageRank, triangle
//! counting, and SSSP — "each featuring a different type of graph
//! traversal".
//!
//! Every kernel comes in two flavours:
//! * a plain, fast version used by the timing experiments (Fig. 4/5/6,
//!   Table 3);
//! * a `*_traced` version that reports every data-dependent memory read
//!   to a [`trace::Tracer`] — the cache simulator implements `Tracer`, and
//!   that pairing reproduces the paper's Fig. 7 profiler numbers (we trace
//!   reads only, matching the paper: "We only measure the hit rates for
//!   the read operations").

pub mod trace;
pub mod spmv;
pub mod spmm;
pub mod pagerank;
pub mod tc;
pub mod sssp;

pub use trace::{NoTrace, Tracer};
