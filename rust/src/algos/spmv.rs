//! SpMV — the paper's representative kernel ("single-hop graph traversal
//! from all graph vertices", §1.1).
//!
//! Pull form, Algorithm 1 in the paper: for every row `v`, accumulate
//! `Σ A[v,u] · x[u]` over the stored columns `u ∈ N(v)`. The
//! cache-critical access is the gather `x[u]` (the paper's Line 4):
//! coalesced iff the labels of `N(v)` cluster — precisely what BOBA's
//! spatial locality buys.
//!
//! Variants: sequential, edge-balanced parallel (the CPU analogue of the
//! paper's merge-path GPU load balancing — workers own equal *edge*
//! shares, not equal row counts, so hub rows cannot skew the schedule),
//! and traced (for the Fig. 7 cache analysis).

use super::trace::{Region, Tracer};
use crate::graph::Csr;
use crate::parallel::{self, SendPtr};

/// Software-prefetch lookahead (edges) for the `x[col]` gather. Tuned on
/// the 1-core testbed: 610 → 464 ms (-24%) on a randomized 64M-edge PA
/// graph; neutral on already-local (BOBA-ordered) inputs. See
/// docs/EXPERIMENTS.md §Perf.
pub(crate) const PF_DIST: usize = 32;

/// Partition rows into `tasks` contiguous ranges owning ~equal numbers of
/// *edges* (binary search over `row_ptr`, the merge-path diagonal idea of
/// Merrill & Garland simplified to row granularity: a task never splits a
/// row, but task boundaries are chosen on the edge axis). Returns
/// `tasks + 1` row bounds; shared by [`spmv_pull_parallel`] and the
/// multi-RHS [`super::spmm`] kernel so both balance hub rows identically.
pub(crate) fn edge_balanced_row_bounds(csr: &Csr, tasks: usize) -> Vec<usize> {
    edge_balanced_bounds(&csr.row_ptr, tasks)
}

/// The same edge-balanced partition over any CSR-style prefix array
/// (`ptr[i]` = cumulative work before item `i`, `ptr.len() = items+1`).
/// The compressed kernel formats ([`crate::runtime::format`]) reuse it
/// to balance rows, SELL slices, tile segments, and ELL row tiles with
/// the exact same boundary choices as `spmv_pull_parallel`.
pub(crate) fn edge_balanced_bounds(ptr: &[u64], tasks: usize) -> Vec<usize> {
    let n = ptr.len().saturating_sub(1);
    let m = ptr.last().copied().unwrap_or(0) as usize;
    let edges_per_task = m.div_ceil(tasks.max(1));
    let mut bounds = Vec::with_capacity(tasks + 1);
    for t in 0..=tasks {
        let target = (t * edges_per_task).min(m) as u64;
        let row = ptr.partition_point(|&p| p < target);
        bounds.push(row.min(n));
    }
    bounds[0] = 0;
    *bounds.last_mut().unwrap() = n;
    bounds
}

#[inline(always)]
fn prefetch_x(x: &[f32], cols: &[u32], e: usize) {
    #[cfg(target_arch = "x86_64")]
    {
        let pf = e + PF_DIST;
        if pf < cols.len() {
            // SAFETY: _mm_prefetch is a non-faulting hint — the address
            // is never dereferenced; `add` stays in bounds of `x`
            // because CSR construction validates every column id < n.
            unsafe {
                core::arch::x86_64::_mm_prefetch(
                    x.as_ptr().add(cols[pf] as usize) as *const i8,
                    core::arch::x86_64::_MM_HINT_T0,
                );
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (x, cols, e);
    }
}

/// Sequential pull SpMV: `y = A·x` with `A` given by `csr` (missing
/// `vals` ⇒ all ones, i.e. plain neighbor sum).
pub fn spmv_pull(csr: &Csr, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), csr.n());
    let mut y = vec![0f32; csr.n()];
    let cols = &csr.col_idx;
    match &csr.vals {
        Some(vals) => {
            for v in 0..csr.n() {
                let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
                let mut acc = 0f32;
                for e in lo..hi {
                    prefetch_x(x, cols, e);
                    acc += vals[e] * x[cols[e] as usize];
                }
                y[v] = acc;
            }
        }
        None => {
            for v in 0..csr.n() {
                let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
                let mut acc = 0f32;
                for e in lo..hi {
                    prefetch_x(x, cols, e);
                    acc += x[cols[e] as usize];
                }
                y[v] = acc;
            }
        }
    }
    y
}

/// Edge-balanced parallel SpMV.
///
/// Rows are partitioned so each task owns ~equal numbers of *edges*
/// (binary search over `row_ptr`, the merge-path diagonal idea of Merrill
/// & Garland simplified to row granularity: a task never splits a row, but
/// task boundaries are chosen on the edge axis).
pub fn spmv_pull_parallel(csr: &Csr, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), csr.n());
    let n = csr.n();
    let m = csr.m();
    if m < 1 << 14 {
        return spmv_pull(csr, x);
    }
    let tasks = (parallel::threads() * 8).max(1);
    let bounds = edge_balanced_row_bounds(csr, tasks);

    let mut y = vec![0f32; n];
    let y_ptr = SendPtr(y.as_mut_ptr());
    let bounds_ref = &bounds;
    parallel::par_for_chunks(tasks, 1, |t_lo, t_hi| {
        for t in t_lo..t_hi {
            let (r0, r1) = (bounds_ref[t], bounds_ref[t + 1]);
            for v in r0..r1 {
                let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
                let mut acc = 0f32;
                match &csr.vals {
                    Some(vals) => {
                        for e in lo..hi {
                            acc += vals[e] * x[csr.col_idx[e] as usize];
                        }
                    }
                    None => {
                        for e in lo..hi {
                            acc += x[csr.col_idx[e] as usize];
                        }
                    }
                }
                // SAFETY: row ranges are disjoint across tasks.
                unsafe { *y_ptr.get().add(v) = acc };
            }
        }
    });
    y
}

/// Traced pull SpMV for the cache analysis: reports reads of `row_ptr`
/// (streaming), `col_idx` (streaming), `vals` (streaming) and the gather
/// `x[col]` (the random access Fig. 7 is about).
pub fn spmv_pull_traced<T: Tracer>(csr: &Csr, x: &[f32], tracer: &mut T) -> Vec<f32> {
    assert_eq!(x.len(), csr.n());
    let mut y = vec![0f32; csr.n()];
    for v in 0..csr.n() {
        tracer.read8(Region::RowPtr, v);
        tracer.read8(Region::RowPtr, v + 1);
        let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
        let mut acc = 0f32;
        for e in lo..hi {
            tracer.read4(Region::ColIdx, e);
            let u = csr.col_idx[e] as usize;
            tracer.read4(Region::VectorX, u);
            let w = match &csr.vals {
                Some(vals) => {
                    tracer.read4(Region::Vals, e);
                    vals[e]
                }
                None => 1.0,
            };
            acc += w * x[u];
        }
        y[v] = acc;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::trace::VecTrace;
    use crate::convert::coo_to_csr;
    use crate::graph::gen::{self, GenParams};
    use crate::graph::Coo;

    fn dense_ref(csr: &Csr, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0f32; csr.n()];
        for v in 0..csr.n() {
            for (k, &c) in csr.neighbors(v).iter().enumerate() {
                let w = csr.row_vals(v).map_or(1.0, |vv| vv[k]);
                y[v] += w * x[c as usize];
            }
        }
        y
    }

    #[test]
    fn unweighted_counts_neighbors() {
        let coo = Coo::new(3, vec![0, 0, 1], vec![1, 2, 2]);
        let csr = coo_to_csr(&coo);
        let y = spmv_pull(&csr, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, 1.0, 0.0]);
    }

    #[test]
    fn weighted_matches_dense() {
        let coo = Coo::with_vals(3, vec![0, 1, 2], vec![1, 2, 0], vec![2.0, 3.0, 4.0]);
        let csr = coo_to_csr(&coo);
        let x = vec![1.0, 10.0, 100.0];
        assert_eq!(spmv_pull(&csr, &x), vec![20.0, 300.0, 4.0]);
        assert_eq!(spmv_pull(&csr, &x), dense_ref(&csr, &x));
    }

    #[test]
    fn parallel_matches_sequential() {
        let g = gen::rmat(&GenParams::rmat(13, 16), 3);
        let csr = coo_to_csr(&g);
        let x: Vec<f32> = (0..csr.n()).map(|i| (i % 17) as f32 * 0.25).collect();
        let a = spmv_pull(&csr, &x);
        let b = spmv_pull_parallel(&csr, &x);
        // Unweighted sums of the same f32s in the same row order:
        // bitwise identical.
        assert_eq!(a, b);
    }

    #[test]
    fn traced_matches_plain_and_counts_reads() {
        let g = gen::uniform_random(100, 700, 2);
        let csr = coo_to_csr(&g);
        let x = vec![1.5f32; 100];
        let mut t = VecTrace::default();
        let y1 = spmv_pull_traced(&csr, &x, &mut t);
        let y0 = spmv_pull(&csr, &x);
        assert_eq!(y0, y1);
        // Reads: 2 row_ptr per row + (col_idx + x) per edge (no vals).
        assert_eq!(t.addrs.len(), 2 * csr.n() + 2 * csr.m());
    }

    #[test]
    fn empty_rows_yield_zero() {
        let coo = Coo::new(4, vec![0], vec![3]);
        let csr = coo_to_csr(&coo);
        let y = spmv_pull(&csr, &[1.0; 4]);
        assert_eq!(y, vec![1.0, 0.0, 0.0, 0.0]);
    }
}
