//! Single-source shortest path — the paper's sparse-frontier workload
//! (§5.1): "sparse frontiers of vertices, atomic updates to destination
//! vertices' distances, and traversal of neighbor vertices".
//!
//! Two implementations:
//! * [`dijkstra`] — binary-heap Dijkstra, the correctness oracle;
//! * [`sssp_frontier`] — frontier-relaxation (Bellman-Ford with an active
//!   queue), the GPU-style algorithm the paper's benchmarks run, with a
//!   traced variant for Fig. 7.
//!
//! Weights come from `csr.vals` (all-ones when absent, making SSSP = BFS
//! hop counts).

use super::trace::{Region, Tracer};
use crate::graph::Csr;
use crate::util::deadline;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Distance array result (f32::INFINITY ⇒ unreachable).
pub type Distances = Vec<f32>;

/// Binary-heap Dijkstra from `source`. Requires non-negative weights.
pub fn dijkstra(csr: &Csr, source: u32) -> Distances {
    let n = csr.n();
    let mut dist = vec![f32::INFINITY; n];
    dist[source as usize] = 0.0;
    // (ordered-bits distance, vertex) — f32 bits of non-negative floats
    // compare like the floats themselves.
    let mut heap: BinaryHeap<(Reverse<u32>, u32)> = BinaryHeap::new();
    heap.push((Reverse(0f32.to_bits()), source));
    while let Some((Reverse(dbits), v)) = heap.pop() {
        let d = f32::from_bits(dbits);
        if d > dist[v as usize] {
            continue;
        }
        let (lo, hi) = (csr.row_ptr[v as usize] as usize, csr.row_ptr[v as usize + 1] as usize);
        for e in lo..hi {
            let u = csr.col_idx[e] as usize;
            let w = csr.vals.as_ref().map_or(1.0, |vv| vv[e]);
            let nd = d + w;
            if nd < dist[u] {
                dist[u] = nd;
                heap.push((Reverse(nd.to_bits()), u as u32));
            }
        }
    }
    dist
}

/// Frontier-based relaxation (the GPU pattern): repeatedly relax all
/// edges out of the active frontier until no distance changes.
///
/// Checks the ambient request deadline ([`crate::util::deadline`])
/// between rounds: an expired budget abandons the remaining frontier
/// and returns the (partial) distances relaxed so far — the serve
/// path's post-kernel deadline check turns that into a 504 instead of
/// serving them. Unscoped callers see a thread-local load per round and
/// an unchanged fixpoint.
pub fn sssp_frontier(csr: &Csr, source: u32) -> Distances {
    let n = csr.n();
    let mut dist = vec![f32::INFINITY; n];
    dist[source as usize] = 0.0;
    let mut frontier = vec![source];
    let mut in_next = vec![false; n];
    while !frontier.is_empty() {
        if deadline::expired() {
            break;
        }
        let mut next = Vec::new();
        for &v in &frontier {
            let dv = dist[v as usize];
            let (lo, hi) =
                (csr.row_ptr[v as usize] as usize, csr.row_ptr[v as usize + 1] as usize);
            for e in lo..hi {
                let u = csr.col_idx[e] as usize;
                let w = csr.vals.as_ref().map_or(1.0, |vv| vv[e]);
                let nd = dv + w;
                if nd < dist[u] {
                    dist[u] = nd;
                    if !in_next[u] {
                        in_next[u] = true;
                        next.push(u as u32);
                    }
                }
            }
        }
        for &u in &next {
            in_next[u as usize] = false;
        }
        frontier = next;
    }
    dist
}

/// Traced frontier SSSP. Reads: frontier vertex distances (`VectorX`),
/// `row_ptr`, `col_idx` stream, weights, and the relaxation target
/// `dist[u]` (`VectorY`) — the label-sensitive random access.
pub fn sssp_frontier_traced<T: Tracer>(csr: &Csr, source: u32, tracer: &mut T) -> Distances {
    let n = csr.n();
    let mut dist = vec![f32::INFINITY; n];
    dist[source as usize] = 0.0;
    let mut frontier = vec![source];
    let mut in_next = vec![false; n];
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &v in &frontier {
            tracer.read4(Region::VectorX, v as usize);
            tracer.read8(Region::RowPtr, v as usize);
            tracer.read8(Region::RowPtr, v as usize + 1);
            let dv = dist[v as usize];
            let (lo, hi) =
                (csr.row_ptr[v as usize] as usize, csr.row_ptr[v as usize + 1] as usize);
            for e in lo..hi {
                tracer.read4(Region::ColIdx, e);
                let u = csr.col_idx[e] as usize;
                let w = match csr.vals.as_ref() {
                    Some(vv) => {
                        tracer.read4(Region::Vals, e);
                        vv[e]
                    }
                    None => 1.0,
                };
                tracer.read4(Region::VectorY, u);
                let nd = dv + w;
                if nd < dist[u] {
                    dist[u] = nd;
                    if !in_next[u] {
                        in_next[u] = true;
                        next.push(u as u32);
                    }
                }
            }
        }
        for &u in &next {
            in_next[u as usize] = false;
        }
        frontier = next;
    }
    dist
}

/// Maximum sources per [`sssp_frontier_multi`] batch (active-source
/// masks are `u16` bit sets; wider batches are chunked by callers, see
/// [`crate::server::coalesce`]).
pub const MAX_SOURCES: usize = 16;

/// Multi-source frontier SSSP: relax `s ∈ 1..=`[`MAX_SOURCES`] sources
/// per edge scan. Returns column-major distances — `out[i*n..(i+1)*n]`
/// is source `i`'s distance array.
///
/// The union frontier is scanned once per round: each frontier vertex's
/// adjacency (`row_ptr` lookup + `col_idx`/`vals` stream — the part of
/// the traversal reordering cannot compress) is loaded **once** and
/// relaxed for every source whose bit is set in the vertex's active
/// mask, instead of once per source.
///
/// Output is **bit-identical to per-source [`sssp_frontier`]**: with
/// non-negative weights, frontier relaxation run to fixpoint computes
/// `dist[u] = min over paths P(source→u) of the f32 left-fold sum of P`
/// regardless of relaxation order — `fl(a+w)` is monotone in `a`, so at
/// fixpoint `dist[u]` is both ≤ every path's float sum (induction along
/// the path) and equal to some path's float sum (every update extends
/// one). Scheduling changes which relaxations run, never the fixpoint.
/// `tests/batch_equiv.rs` pins the equality on every fixture.
pub fn sssp_frontier_multi(csr: &Csr, sources: &[u32]) -> Vec<f32> {
    let s = sources.len();
    assert!(
        (1..=MAX_SOURCES).contains(&s),
        "sssp batch width {s} out of range 1..={MAX_SOURCES}"
    );
    let n = csr.n();
    let mut dist = vec![f32::INFINITY; s * n];
    // Per-vertex bit sets: `active` = sources for which the vertex is in
    // the current frontier, `pending` = next frontier under construction.
    let mut active = vec![0u16; n];
    let mut pending = vec![0u16; n];
    let mut frontier: Vec<u32> = Vec::new();
    let mut next: Vec<u32> = Vec::new();
    for (i, &src) in sources.iter().enumerate() {
        let src = src as usize;
        assert!(src < n, "source {src} out of range n={n}");
        dist[i * n + src] = 0.0;
        if active[src] == 0 {
            frontier.push(src as u32);
        }
        active[src] |= 1 << i;
    }
    while !frontier.is_empty() {
        // Per-round deadline checkpoint, as in [`sssp_frontier`]: the
        // whole batch aborts together (partial distances are discarded
        // by the caller's post-kernel deadline check).
        if deadline::expired() {
            break;
        }
        for &v in &frontier {
            let v = v as usize;
            let mask = active[v];
            let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
            for e in lo..hi {
                let u = csr.col_idx[e] as usize;
                let w = csr.vals.as_ref().map_or(1.0, |vv| vv[e]);
                let mut bits = mask;
                while bits != 0 {
                    let i = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let nd = dist[i * n + v] + w;
                    if nd < dist[i * n + u] {
                        dist[i * n + u] = nd;
                        if pending[u] == 0 {
                            next.push(u as u32);
                        }
                        pending[u] |= 1 << i;
                    }
                }
            }
        }
        for &v in &frontier {
            active[v as usize] = 0;
        }
        std::mem::swap(&mut frontier, &mut next);
        std::mem::swap(&mut active, &mut pending);
        next.clear();
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert::coo_to_csr;
    use crate::graph::gen;
    use crate::graph::Coo;
    use crate::util::prng::Xoshiro256;

    fn weighted_csr(n: usize, m: usize, seed: u64) -> Csr {
        let mut g = gen::uniform_random(n, m, seed);
        let mut rng = Xoshiro256::new(seed + 1);
        g.vals = Some((0..m).map(|_| rng.next_f32() + 0.01).collect());
        coo_to_csr(&g)
    }

    #[test]
    fn line_graph_distances() {
        let g = Coo::new(4, vec![0, 1, 2], vec![1, 2, 3]);
        let csr = coo_to_csr(&g);
        assert_eq!(dijkstra(&csr, 0), vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(sssp_frontier(&csr, 0), vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn unreachable_is_infinite() {
        let g = Coo::new(3, vec![0], vec![1]);
        let csr = coo_to_csr(&g);
        let d = dijkstra(&csr, 0);
        assert!(d[2].is_infinite());
    }

    #[test]
    fn frontier_matches_dijkstra_weighted() {
        for seed in 0..4 {
            let csr = weighted_csr(200, 1500, seed);
            let a = dijkstra(&csr, 0);
            let b = sssp_frontier(&csr, 0);
            for (x, y) in a.iter().zip(&b) {
                if x.is_finite() {
                    assert!((x - y).abs() < 1e-4, "{x} vs {y}");
                } else {
                    assert!(y.is_infinite());
                }
            }
        }
    }

    #[test]
    fn traced_matches_untraced() {
        let csr = weighted_csr(150, 800, 9);
        let mut t = super::super::trace::VecTrace::default();
        let a = sssp_frontier(&csr, 3);
        let b = sssp_frontier_traced(&csr, 3, &mut t);
        assert_eq!(a, b);
        assert!(!t.addrs.is_empty());
    }

    #[test]
    fn multi_source_matches_per_source() {
        for (s, seed) in [(1usize, 3u64), (2, 4), (7, 5), (16, 6)] {
            let csr = weighted_csr(150, 900, seed);
            let sources: Vec<u32> = (0..s).map(|i| ((i * 31 + 2) % 150) as u32).collect();
            let d = sssp_frontier_multi(&csr, &sources);
            for (i, &src) in sources.iter().enumerate() {
                let want = sssp_frontier(&csr, src);
                assert_eq!(&d[i * 150..(i + 1) * 150], want.as_slice(), "s={s} i={i}");
            }
        }
    }

    #[test]
    fn multi_source_handles_duplicate_sources_and_no_edges() {
        let csr = coo_to_csr(&Coo::new(3, vec![], vec![]));
        let d = sssp_frontier_multi(&csr, &[1, 1, 2]);
        assert_eq!(d[3 + 1], 0.0);
        assert_eq!(d[2 * 3 + 2], 0.0);
        assert!(d[0].is_infinite() && d[3].is_infinite());
    }

    #[test]
    fn expired_deadline_abandons_remaining_rounds() {
        let g = Coo::new(4, vec![0, 1, 2], vec![1, 2, 3]);
        let csr = coo_to_csr(&g);
        let d = crate::util::deadline::scope(Some(std::time::Instant::now()));
        // Source distance is set before the first round, every other
        // vertex stays unreached — the kernel never relaxed an edge.
        let partial = sssp_frontier(&csr, 0);
        assert_eq!(partial[0], 0.0);
        assert!(partial[1..].iter().all(|v| v.is_infinite()));
        let multi = sssp_frontier_multi(&csr, &[0, 1]);
        assert!(multi[1].is_infinite() && multi[4 + 2].is_infinite());
        drop(d);
        assert_eq!(sssp_frontier(&csr, 0), vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn distances_invariant_under_relabeling() {
        let g = gen::grid_road(20, 20, 2);
        let csr = coo_to_csr(&g);
        let d0 = sssp_frontier(&csr, 0);
        let perm = {
            let mut rng = Xoshiro256::new(5);
            rng.permutation(g.n())
        };
        let h = g.relabeled(&perm);
        let csr2 = coo_to_csr(&h);
        let d1 = sssp_frontier(&csr2, perm[0]);
        for v in 0..g.n() {
            let x = d0[v];
            let y = d1[perm[v] as usize];
            if x.is_finite() {
                assert!((x - y).abs() < 1e-4);
            } else {
                assert!(y.is_infinite());
            }
        }
    }
}
