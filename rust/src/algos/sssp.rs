//! Single-source shortest path — the paper's sparse-frontier workload
//! (§5.1): "sparse frontiers of vertices, atomic updates to destination
//! vertices' distances, and traversal of neighbor vertices".
//!
//! Two implementations:
//! * [`dijkstra`] — binary-heap Dijkstra, the correctness oracle;
//! * [`sssp_frontier`] — frontier-relaxation (Bellman-Ford with an active
//!   queue), the GPU-style algorithm the paper's benchmarks run, with a
//!   traced variant for Fig. 7.
//!
//! Weights come from `csr.vals` (all-ones when absent, making SSSP = BFS
//! hop counts).

use super::trace::{Region, Tracer};
use crate::graph::Csr;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Distance array result (f32::INFINITY ⇒ unreachable).
pub type Distances = Vec<f32>;

/// Binary-heap Dijkstra from `source`. Requires non-negative weights.
pub fn dijkstra(csr: &Csr, source: u32) -> Distances {
    let n = csr.n();
    let mut dist = vec![f32::INFINITY; n];
    dist[source as usize] = 0.0;
    // (ordered-bits distance, vertex) — f32 bits of non-negative floats
    // compare like the floats themselves.
    let mut heap: BinaryHeap<(Reverse<u32>, u32)> = BinaryHeap::new();
    heap.push((Reverse(0f32.to_bits()), source));
    while let Some((Reverse(dbits), v)) = heap.pop() {
        let d = f32::from_bits(dbits);
        if d > dist[v as usize] {
            continue;
        }
        let (lo, hi) = (csr.row_ptr[v as usize] as usize, csr.row_ptr[v as usize + 1] as usize);
        for e in lo..hi {
            let u = csr.col_idx[e] as usize;
            let w = csr.vals.as_ref().map_or(1.0, |vv| vv[e]);
            let nd = d + w;
            if nd < dist[u] {
                dist[u] = nd;
                heap.push((Reverse(nd.to_bits()), u as u32));
            }
        }
    }
    dist
}

/// Frontier-based relaxation (the GPU pattern): repeatedly relax all
/// edges out of the active frontier until no distance changes.
pub fn sssp_frontier(csr: &Csr, source: u32) -> Distances {
    let n = csr.n();
    let mut dist = vec![f32::INFINITY; n];
    dist[source as usize] = 0.0;
    let mut frontier = vec![source];
    let mut in_next = vec![false; n];
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &v in &frontier {
            let dv = dist[v as usize];
            let (lo, hi) =
                (csr.row_ptr[v as usize] as usize, csr.row_ptr[v as usize + 1] as usize);
            for e in lo..hi {
                let u = csr.col_idx[e] as usize;
                let w = csr.vals.as_ref().map_or(1.0, |vv| vv[e]);
                let nd = dv + w;
                if nd < dist[u] {
                    dist[u] = nd;
                    if !in_next[u] {
                        in_next[u] = true;
                        next.push(u as u32);
                    }
                }
            }
        }
        for &u in &next {
            in_next[u as usize] = false;
        }
        frontier = next;
    }
    dist
}

/// Traced frontier SSSP. Reads: frontier vertex distances (`VectorX`),
/// `row_ptr`, `col_idx` stream, weights, and the relaxation target
/// `dist[u]` (`VectorY`) — the label-sensitive random access.
pub fn sssp_frontier_traced<T: Tracer>(csr: &Csr, source: u32, tracer: &mut T) -> Distances {
    let n = csr.n();
    let mut dist = vec![f32::INFINITY; n];
    dist[source as usize] = 0.0;
    let mut frontier = vec![source];
    let mut in_next = vec![false; n];
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &v in &frontier {
            tracer.read4(Region::VectorX, v as usize);
            tracer.read8(Region::RowPtr, v as usize);
            tracer.read8(Region::RowPtr, v as usize + 1);
            let dv = dist[v as usize];
            let (lo, hi) =
                (csr.row_ptr[v as usize] as usize, csr.row_ptr[v as usize + 1] as usize);
            for e in lo..hi {
                tracer.read4(Region::ColIdx, e);
                let u = csr.col_idx[e] as usize;
                let w = match csr.vals.as_ref() {
                    Some(vv) => {
                        tracer.read4(Region::Vals, e);
                        vv[e]
                    }
                    None => 1.0,
                };
                tracer.read4(Region::VectorY, u);
                let nd = dv + w;
                if nd < dist[u] {
                    dist[u] = nd;
                    if !in_next[u] {
                        in_next[u] = true;
                        next.push(u as u32);
                    }
                }
            }
        }
        for &u in &next {
            in_next[u as usize] = false;
        }
        frontier = next;
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert::coo_to_csr;
    use crate::graph::gen;
    use crate::graph::Coo;
    use crate::util::prng::Xoshiro256;

    fn weighted_csr(n: usize, m: usize, seed: u64) -> Csr {
        let mut g = gen::uniform_random(n, m, seed);
        let mut rng = Xoshiro256::new(seed + 1);
        g.vals = Some((0..m).map(|_| rng.next_f32() + 0.01).collect());
        coo_to_csr(&g)
    }

    #[test]
    fn line_graph_distances() {
        let g = Coo::new(4, vec![0, 1, 2], vec![1, 2, 3]);
        let csr = coo_to_csr(&g);
        assert_eq!(dijkstra(&csr, 0), vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(sssp_frontier(&csr, 0), vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn unreachable_is_infinite() {
        let g = Coo::new(3, vec![0], vec![1]);
        let csr = coo_to_csr(&g);
        let d = dijkstra(&csr, 0);
        assert!(d[2].is_infinite());
    }

    #[test]
    fn frontier_matches_dijkstra_weighted() {
        for seed in 0..4 {
            let csr = weighted_csr(200, 1500, seed);
            let a = dijkstra(&csr, 0);
            let b = sssp_frontier(&csr, 0);
            for (x, y) in a.iter().zip(&b) {
                if x.is_finite() {
                    assert!((x - y).abs() < 1e-4, "{x} vs {y}");
                } else {
                    assert!(y.is_infinite());
                }
            }
        }
    }

    #[test]
    fn traced_matches_untraced() {
        let csr = weighted_csr(150, 800, 9);
        let mut t = super::super::trace::VecTrace::default();
        let a = sssp_frontier(&csr, 3);
        let b = sssp_frontier_traced(&csr, 3, &mut t);
        assert_eq!(a, b);
        assert!(!t.addrs.is_empty());
    }

    #[test]
    fn distances_invariant_under_relabeling() {
        let g = gen::grid_road(20, 20, 2);
        let csr = coo_to_csr(&g);
        let d0 = sssp_frontier(&csr, 0);
        let perm = {
            let mut rng = Xoshiro256::new(5);
            rng.permutation(g.n())
        };
        let h = g.relabeled(&perm);
        let csr2 = coo_to_csr(&h);
        let d1 = sssp_frontier(&csr2, perm[0]);
        for v in 0..g.n() {
            let x = d0[v];
            let y = d1[perm[v] as usize];
            if x.is_finite() {
                assert!((x - y).abs() < 1e-4);
            } else {
                assert!(y.is_infinite());
            }
        }
    }
}
