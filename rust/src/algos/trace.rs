//! Memory-trace plumbing for the cache-hit-rate experiments (Fig. 7).
//!
//! Kernels call [`Tracer::read`] with a synthetic byte address for every
//! data-dependent load. Arrays live in disjoint address regions (see
//! [`Region`]) so the simulator observes the same inter-array conflict
//! behaviour a real heap layout would produce. The no-op tracer
//! monomorphizes away, so untraced kernels pay nothing.

/// Synthetic base addresses for the arrays graph kernels touch.
///
/// Regions are 1 GiB apart — far beyond any dataset in the benches — so
/// arrays never alias.
#[derive(Clone, Copy, Debug)]
pub enum Region {
    /// Dense input vector `x` (SpMV) / rank vector (PR) / dist (SSSP).
    VectorX = 0,
    /// Dense output vector `y` / next-rank / updated dist.
    VectorY = 1,
    /// CSR `col_idx`.
    ColIdx = 2,
    /// CSR `row_ptr`.
    RowPtr = 3,
    /// Edge values.
    Vals = 4,
    /// Second adjacency structure (TC destination lists).
    Adj2 = 5,
}

impl Region {
    /// Byte address of `index`-th element of `elem_size` bytes in this
    /// region.
    #[inline(always)]
    pub fn addr(self, index: usize, elem_size: usize) -> u64 {
        (self as u64) << 30 | (index * elem_size) as u64
    }
}

/// Receives the kernel's data-dependent reads.
pub trait Tracer {
    /// A read of the cache-line-relevant byte address `addr`.
    fn read(&mut self, addr: u64);

    /// Convenience: read of a 4-byte element.
    #[inline(always)]
    fn read4(&mut self, region: Region, index: usize) {
        self.read(region.addr(index, 4));
    }

    /// Convenience: read of an 8-byte element.
    #[inline(always)]
    fn read8(&mut self, region: Region, index: usize) {
        self.read(region.addr(index, 8));
    }
}

/// The zero-cost tracer for production runs.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoTrace;

impl Tracer for NoTrace {
    #[inline(always)]
    fn read(&mut self, _addr: u64) {}
}

/// Records addresses into a vector (tests, debugging).
#[derive(Clone, Debug, Default)]
pub struct VecTrace {
    /// The accumulated addresses.
    pub addrs: Vec<u64>,
}

impl Tracer for VecTrace {
    #[inline]
    fn read(&mut self, addr: u64) {
        self.addrs.push(addr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_disjoint() {
        let a = Region::VectorX.addr(1 << 27, 4); // 512 MiB offset
        let b = Region::VectorY.addr(0, 4);
        assert!(a < b);
    }

    #[test]
    fn vec_trace_records() {
        let mut t = VecTrace::default();
        t.read4(Region::ColIdx, 3);
        t.read8(Region::RowPtr, 2);
        assert_eq!(t.addrs.len(), 2);
        assert_eq!(t.addrs[0], (Region::ColIdx as u64) << 30 | 12);
        assert_eq!(t.addrs[1], (Region::RowPtr as u64) << 30 | 16);
    }
}
