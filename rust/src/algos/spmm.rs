//! Multi-RHS SpMV (block SpMV / SpMM): `Y = A·X` for `k` right-hand
//! sides in one pass over the graph.
//!
//! The paper's entire win is the locality of the `x[col]` gather
//! (Alg. 1 line 4, Fig. 7). A block kernel multiplies that payoff by
//! `k`: the `row_ptr`/`col_idx` index streams — pure bandwidth, the part
//! reordering cannot help — are read **once** for `k` vectors instead of
//! `k` times, so the per-query edge-stream cost drops as `1/k` while the
//! BOBA-clustered gathers stay cache-resident. This is the serving
//! layer's batching primitive: the request coalescer
//! ([`crate::server::coalesce`]) parks concurrent SpMV queries and
//! answers them with one [`spmm_pull_parallel`] call.
//!
//! Layout: `X` and `Y` are **column-major** — column `j` (one query's
//! vector) is the contiguous slice `[j*n .. (j+1)*n]`, so column `j` of
//! the output is byte-identical to what `spmv_pull` would have produced
//! for that column alone. The inner loop is row-tiled over a
//! const-generic `K`: the `k` accumulators live in registers and the
//! column loop fully unrolls.
//!
//! Determinism contract: for every column `j`, the accumulation order
//! over a row's edges is exactly [`super::spmv::spmv_pull`]'s, so the
//! output is **bit-identical to `k` independent `spmv_pull` calls** at
//! every thread count and batch width (`tests/batch_equiv.rs` pins
//! this).

use super::spmv::{edge_balanced_row_bounds, PF_DIST};
use crate::graph::Csr;
use crate::parallel::{self, SendPtr};

/// Maximum right-hand sides per kernel call. 16 accumulators is the
/// largest tile that plausibly stays in registers on x86-64 (16 XMM/YMM
/// names); wider batches are chunked by the callers (the coalescer's
/// `max_batch` is clamped to this, `/query/batch` splits into tiles).
pub const MAX_RHS: usize = 16;

/// Prefetch the `k` gather targets of the edge `PF_DIST` ahead — the
/// [`super::spmv`] prefetch scheme applied per column. The per-edge
/// prefetch count scales with `K`, but so does the per-edge work (K
/// FMAs), so the prefetch-per-FMA ratio matches the single-RHS kernel.
#[inline(always)]
fn prefetch_cols<const K: usize>(x: &[f32], n: usize, cols: &[u32], e: usize) {
    #[cfg(target_arch = "x86_64")]
    {
        let pf = e + PF_DIST;
        if pf < cols.len() {
            let c = cols[pf] as usize;
            for j in 0..K {
                // SAFETY: _mm_prefetch is a non-faulting hint — the
                // address is never dereferenced; `add` stays in bounds
                // of the K×n matrix `x` because CSR construction
                // validates every column id < n and j < K.
                unsafe {
                    core::arch::x86_64::_mm_prefetch(
                        x.as_ptr().add(j * n + c) as *const i8,
                        core::arch::x86_64::_MM_HINT_T0,
                    );
                }
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (x, n, cols, e);
    }
}

/// Row-tiled kernel body over rows `[r0, r1)` for a compile-time tile
/// width `K`.
///
/// # Safety
/// `y` must be valid for writes of `K * csr.n()` f32s, and the caller
/// must guarantee exclusive access to rows `[r0, r1)` of every column
/// (writes land at `y[j*n + v]` for `v ∈ [r0, r1)`, `j ∈ [0, K)`).
unsafe fn spmm_rows<const K: usize>(csr: &Csr, x: &[f32], y: *mut f32, r0: usize, r1: usize) {
    let n = csr.n();
    let cols = &csr.col_idx;
    match &csr.vals {
        Some(vals) => {
            for v in r0..r1 {
                let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
                let mut acc = [0f32; K];
                for e in lo..hi {
                    prefetch_cols::<K>(x, n, cols, e);
                    let c = cols[e] as usize;
                    let w = vals[e];
                    for j in 0..K {
                        acc[j] += w * x[j * n + c];
                    }
                }
                for j in 0..K {
                    *y.add(j * n + v) = acc[j];
                }
            }
        }
        None => {
            for v in r0..r1 {
                let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
                let mut acc = [0f32; K];
                for e in lo..hi {
                    prefetch_cols::<K>(x, n, cols, e);
                    let c = cols[e] as usize;
                    for j in 0..K {
                        acc[j] += x[j * n + c];
                    }
                }
                for j in 0..K {
                    *y.add(j * n + v) = acc[j];
                }
            }
        }
    }
}

/// Monomorphization dispatch: route the runtime `k` onto the
/// const-generic row kernel.
///
/// # Safety
/// Same contract as [`spmm_rows`] with `K = k`; `k` must be in
/// `1..=MAX_RHS` (validated by the public entry points).
unsafe fn run_rows(csr: &Csr, x: &[f32], k: usize, y: *mut f32, r0: usize, r1: usize) {
    match k {
        1 => spmm_rows::<1>(csr, x, y, r0, r1),
        2 => spmm_rows::<2>(csr, x, y, r0, r1),
        3 => spmm_rows::<3>(csr, x, y, r0, r1),
        4 => spmm_rows::<4>(csr, x, y, r0, r1),
        5 => spmm_rows::<5>(csr, x, y, r0, r1),
        6 => spmm_rows::<6>(csr, x, y, r0, r1),
        7 => spmm_rows::<7>(csr, x, y, r0, r1),
        8 => spmm_rows::<8>(csr, x, y, r0, r1),
        9 => spmm_rows::<9>(csr, x, y, r0, r1),
        10 => spmm_rows::<10>(csr, x, y, r0, r1),
        11 => spmm_rows::<11>(csr, x, y, r0, r1),
        12 => spmm_rows::<12>(csr, x, y, r0, r1),
        13 => spmm_rows::<13>(csr, x, y, r0, r1),
        14 => spmm_rows::<14>(csr, x, y, r0, r1),
        15 => spmm_rows::<15>(csr, x, y, r0, r1),
        16 => spmm_rows::<16>(csr, x, y, r0, r1),
        _ => unreachable!("k validated to 1..=MAX_RHS"),
    }
}

fn validate(csr: &Csr, x: &[f32], k: usize) {
    assert!(
        (1..=MAX_RHS).contains(&k),
        "spmm batch width k={k} out of range 1..={MAX_RHS}"
    );
    assert_eq!(
        x.len(),
        k * csr.n(),
        "X must be column-major k*n (k={k}, n={})",
        csr.n()
    );
}

/// Sequential multi-RHS pull SpMV: `Y = A·X` for `k ∈ 1..=`[`MAX_RHS`]
/// right-hand sides, `X`/`Y` column-major (`x[j*n..(j+1)*n]` is column
/// `j`). Bit-identical to `k` independent
/// [`super::spmv::spmv_pull`] calls on the columns.
pub fn spmm_pull(csr: &Csr, x: &[f32], k: usize) -> Vec<f32> {
    validate(csr, x, k);
    let mut y = vec![0f32; k * csr.n()];
    // SAFETY: `y` has k*n elements and this single call owns all rows.
    unsafe { run_rows(csr, x, k, y.as_mut_ptr(), 0, csr.n()) };
    y
}

/// Edge-balanced parallel multi-RHS pull SpMV on the persistent worker
/// pool — same row partitioning as
/// [`super::spmv::spmv_pull_parallel`], same determinism contract:
/// bit-identical to [`spmm_pull`] (and hence to `k` independent
/// `spmv_pull` calls) at every thread count.
pub fn spmm_pull_parallel(csr: &Csr, x: &[f32], k: usize) -> Vec<f32> {
    validate(csr, x, k);
    let n = csr.n();
    if csr.m() < 1 << 14 {
        return spmm_pull(csr, x, k);
    }
    let tasks = (parallel::threads() * 8).max(1);
    let bounds = edge_balanced_row_bounds(csr, tasks);
    let mut y = vec![0f32; k * n];
    let y_ptr = SendPtr(y.as_mut_ptr());
    let bounds_ref = &bounds;
    parallel::par_for_chunks(tasks, 1, |t_lo, t_hi| {
        for t in t_lo..t_hi {
            let (r0, r1) = (bounds_ref[t], bounds_ref[t + 1]);
            // SAFETY: task row ranges are disjoint, so writes to
            // y[j*n + v] for v in [r0, r1) are exclusive per task; the
            // allocation is k*n as required.
            unsafe { run_rows(csr, x, k, y_ptr.get(), r0, r1) };
        }
    });
    y
}

/// Column `j` of a column-major multi-RHS vector block (a view helper
/// for callers unpacking [`spmm_pull`] output).
pub fn column(y: &[f32], n: usize, j: usize) -> &[f32] {
    &y[j * n..(j + 1) * n]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::spmv;
    use crate::convert::coo_to_csr;
    use crate::graph::gen::{self, GenParams};
    use crate::graph::Coo;
    use crate::parallel::ThreadGuard;

    fn rhs(n: usize, k: usize) -> Vec<f32> {
        (0..k * n)
            .map(|i| ((i as u32).wrapping_mul(2654435761) % 997) as f32 * 0.013 + 0.25)
            .collect()
    }

    fn k_spmv_ref(csr: &crate::graph::Csr, x: &[f32], k: usize) -> Vec<f32> {
        let n = csr.n();
        let mut want = Vec::with_capacity(k * n);
        for j in 0..k {
            want.extend(spmv::spmv_pull(csr, column(x, n, j)));
        }
        want
    }

    #[test]
    fn matches_k_independent_spmv_calls_unweighted() {
        let g = gen::uniform_random(300, 2500, 7);
        let csr = coo_to_csr(&g);
        for k in [1, 2, 3, 5, 16] {
            let x = rhs(csr.n(), k);
            assert_eq!(spmm_pull(&csr, &x, k), k_spmv_ref(&csr, &x, k), "k={k}");
        }
    }

    #[test]
    fn matches_k_independent_spmv_calls_weighted() {
        let mut g = gen::uniform_random(200, 1500, 9);
        g.vals = Some((0..g.m()).map(|i| (i % 13) as f32 * 0.5 - 2.0).collect());
        let csr = coo_to_csr(&g);
        for k in [1, 4, 7] {
            let x = rhs(csr.n(), k);
            assert_eq!(spmm_pull(&csr, &x, k), k_spmv_ref(&csr, &x, k), "k={k}");
        }
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        let g = gen::rmat(&GenParams::rmat(13, 16), 3);
        let csr = coo_to_csr(&g);
        for k in [1, 4, 8] {
            let x = rhs(csr.n(), k);
            let want = spmm_pull(&csr, &x, k);
            for t in [1, 2, 4, 8] {
                let _g = ThreadGuard::pin(t);
                assert_eq!(spmm_pull_parallel(&csr, &x, k), want, "k={k} t={t}");
            }
        }
    }

    #[test]
    fn empty_and_single_vertex_graphs() {
        let empty = coo_to_csr(&Coo::new(4, vec![], vec![]));
        assert_eq!(spmm_pull(&empty, &[1.0; 8], 2), vec![0.0; 8]);
        let single = coo_to_csr(&Coo::new(1, vec![0], vec![0]));
        assert_eq!(spmm_pull(&single, &[3.0, 5.0], 2), vec![3.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_oversized_batch() {
        let csr = coo_to_csr(&Coo::new(2, vec![0], vec![1]));
        let x = vec![0.0; 34];
        spmm_pull(&csr, &x, 17);
    }
}
