//! PageRank — the paper's iterate-until-convergence workload. Matches the
//! paper's GPU formulation (§5.1): push-based, "each edge's source
//! propagates its weight to its neighbor vertices" — the cache-critical
//! access is the scatter into `rank_next[dst]`, which clusters iff
//! destination labels cluster.

use super::spmv;
use super::trace::{Region, Tracer};
use crate::graph::Csr;
use crate::parallel::{self, SendPtr};
use crate::util::deadline;
use std::sync::atomic::{AtomicU32, Ordering};

/// PageRank parameters.
#[derive(Clone, Copy, Debug)]
pub struct PrParams {
    /// Damping factor (0.85 standard).
    pub damping: f32,
    /// Maximum iterations.
    pub max_iters: usize,
    /// L1 convergence tolerance.
    pub tol: f32,
}

impl Default for PrParams {
    fn default() -> Self {
        Self { damping: 0.85, max_iters: 100, tol: 1e-6 }
    }
}

/// Result: ranks and the iteration count actually run.
#[derive(Clone, Debug)]
pub struct PrResult {
    /// Final rank vector (sums to ~1).
    pub ranks: Vec<f32>,
    /// Iterations executed.
    pub iters: usize,
}

/// Sequential push-based PageRank.
///
/// Cooperatively checks the ambient request deadline
/// ([`crate::util::deadline`]) before each power iteration: an expired
/// budget stops the iterate-until-convergence loop early and returns
/// the ranks computed so far (the serve path discards them and answers
/// 504 — its post-kernel deadline check fires). With no deadline in
/// scope the check is a thread-local load and the iteration count is
/// unchanged, so results stay bit-identical.
pub fn pagerank(csr: &Csr, p: PrParams) -> PrResult {
    let n = csr.n();
    let mut rank = vec![1.0f32 / n as f32; n];
    let mut next = vec![0f32; n];
    let mut iters = 0;
    for _ in 0..p.max_iters {
        if deadline::expired() {
            break;
        }
        iters += 1;
        next.fill(0.0);
        let mut dangling = 0f32;
        for v in 0..n {
            let deg = csr.degree(v);
            if deg == 0 {
                dangling += rank[v];
                continue;
            }
            let share = rank[v] / deg as f32;
            for &u in csr.neighbors(v) {
                next[u as usize] += share;
            }
        }
        let base = (1.0 - p.damping) / n as f32 + p.damping * dangling / n as f32;
        let mut delta = 0f32;
        for v in 0..n {
            let nv = base + p.damping * next[v];
            delta += (nv - rank[v]).abs();
            rank[v] = nv;
        }
        if delta < p.tol {
            break;
        }
    }
    PrResult { ranks: rank, iters }
}

/// Deterministic parallel PageRank — **bit-identical to [`pagerank`] at
/// every thread count**.
///
/// The old kernel (kept as [`pagerank_parallel_atomic`]) scattered
/// `share` into `next[dst]` through a relaxed CAS loop: f32 addition is
/// not associative, so the ranks — and every serve response/digest
/// built on them — depended on thread interleaving, breaking the
/// bit-determinism discipline the deterministic converter and the
/// parallel ingest established. This rebuild follows the same PR-3
/// pattern (turn racing scatters into race-free per-destination
/// accumulation):
///
/// * the push scatter becomes a **pull over the transposed CSR**: row
///   `u` of `Aᵀ` lists `u`'s in-neighbors in ascending source order
///   ([`Csr::transposed_structure`] is a stable counting sort), which is exactly
///   the order the sequential push loop (`for v in 0..n`) adds into
///   `next[u]` — so each destination's f32 sum is reproduced term by
///   term, and rows parallelize with disjoint writes
///   ([`super::spmv::spmv_pull_parallel`] does the pull);
/// * the dangling-mass and delta/update reductions stay **sequential in
///   vertex order** (O(n) f32 adds per iteration, noise next to the
///   O(m) pull) because the sequential kernel folds them as f32 in
///   exactly that order — a tree reduction would converge to a
///   different tolerance decision near the threshold.
///
/// Cost: one transpose (O(m), amortized over all iterations) plus
/// `share`/`next` vectors.
pub fn pagerank_parallel(csr: &Csr, p: PrParams) -> PrResult {
    if csr.n() < 1 << 14 {
        return pagerank(csr, p);
    }
    // Pull operand: the reverse graph, structure only (PageRank
    // propagates shares along edges regardless of vals, like the push
    // kernel, so the transposed weight array is never built).
    let tr = csr.transposed_structure();
    pagerank_parallel_pull(csr, &tr, p)
}

/// [`pagerank_parallel`] with a caller-supplied transpose — the serving
/// path caches `Aᵀ` per prepared artifact ([`crate::server::registry`]
/// builds it as a first-class prepare stage), so repeated PageRank
/// queries skip the per-call O(m) transpose this function's wrapper
/// pays. `tr` must be the stable-counting-sort transpose of `csr`
/// ([`Csr::transposed_structure`]); any other in-neighbor order changes
/// the f32 summation order and breaks digest equality with the
/// sequential kernel. Small graphs still take the sequential kernel
/// (same threshold as the wrapper), keeping results identical across
/// both entry points.
pub fn pagerank_parallel_pull(csr: &Csr, tr: &Csr, p: PrParams) -> PrResult {
    let n = csr.n();
    if n < 1 << 14 {
        return pagerank(csr, p);
    }
    debug_assert_eq!(tr.n(), n);
    debug_assert_eq!(tr.m(), csr.m());
    let mut rank = vec![1.0f32 / n as f32; n];
    let mut share = vec![0f32; n];
    let chunk = parallel::default_chunk(n);
    let mut iters = 0;
    for _ in 0..p.max_iters {
        // Same per-iteration deadline checkpoint as [`pagerank`]: bail
        // between power iterations, never mid-pull.
        if deadline::expired() {
            break;
        }
        iters += 1;
        // share[v] = rank[v]/deg(v) — element-wise, deterministic.
        {
            let rank_ref = &rank;
            let share_ptr = SendPtr(share.as_mut_ptr());
            parallel::par_for_chunks(n, chunk, |lo, hi| {
                for v in lo..hi {
                    let deg = csr.degree(v);
                    let s = if deg == 0 { 0.0 } else { rank_ref[v] / deg as f32 };
                    // SAFETY: disjoint chunks.
                    unsafe { *share_ptr.get().add(v) = s };
                }
            });
        }
        // Dangling mass: sequential f32 fold in vertex order — the
        // sequential kernel's exact summation order.
        let mut dangling = 0f32;
        for v in 0..n {
            if csr.degree(v) == 0 {
                dangling += rank[v];
            }
        }
        // next[u] = Σ share[v] over in-neighbors v ascending — the pull
        // form of the push scatter, row-parallel and race-free.
        let next = spmv::spmv_pull_parallel(tr, &share);
        let base = (1.0 - p.damping) / n as f32 + p.damping * dangling / n as f32;
        let mut delta = 0f32;
        for v in 0..n {
            let nv = base + p.damping * next[v];
            delta += (nv - rank[v]).abs();
            rank[v] = nv;
        }
        if delta < p.tol {
            break;
        }
    }
    PrResult { ranks: rank, iters }
}

/// The pre-rebuild parallel kernel: push-based with atomic f32
/// accumulation (CAS loop on `AtomicU32` bits — the CPU analogue of the
/// paper's GPU `atomicAdd`). **Nondeterministic** across thread
/// interleavings (f32 addition order varies); retained strictly as the
/// ablation baseline the deterministic [`pagerank_parallel`] is priced
/// against (the same role `convert::coo_to_csr_parallel_atomic` plays
/// for the converter).
pub fn pagerank_parallel_atomic(csr: &Csr, p: PrParams) -> PrResult {
    let n = csr.n();
    if n < 1 << 14 {
        return pagerank(csr, p);
    }
    let mut rank = vec![1.0f32 / n as f32; n];
    let next: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let mut iters = 0;
    let chunk = parallel::default_chunk(n);
    for _ in 0..p.max_iters {
        iters += 1;
        for a in &next {
            a.store(0, Ordering::Relaxed);
        }
        let rank_ref = &rank;
        let dangling = parallel::par_reduce(
            n,
            chunk,
            0f64,
            |acc, lo, hi| {
                let mut d = acc;
                for v in lo..hi {
                    let deg = csr.degree(v);
                    if deg == 0 {
                        d += rank_ref[v] as f64;
                        continue;
                    }
                    let share = rank_ref[v] / deg as f32;
                    for &u in csr.neighbors(v) {
                        atomic_add_f32(&next[u as usize], share);
                    }
                }
                d
            },
            |a, b| a + b,
        ) as f32;
        let base = (1.0 - p.damping) / n as f32 + p.damping * dangling / n as f32;
        // Update + delta reduction.
        let rank_ptr = SendPtr(rank.as_mut_ptr());
        let delta = parallel::par_reduce(
            n,
            chunk,
            0f64,
            |acc, lo, hi| {
                let mut d = acc;
                for v in lo..hi {
                    let nv = base + p.damping * f32::from_bits(next[v].load(Ordering::Relaxed));
                    // SAFETY: disjoint chunks.
                    unsafe {
                        let slot = rank_ptr.get().add(v);
                        d += (nv - *slot).abs() as f64;
                        *slot = nv;
                    }
                }
                d
            },
            |a, b| a + b,
        );
        if (delta as f32) < p.tol {
            break;
        }
    }
    PrResult { ranks: rank, iters }
}

#[inline]
fn atomic_add_f32(cell: &AtomicU32, v: f32) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let newv = (f32::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, newv, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// Traced sequential PageRank (one traced power iteration is
/// representative; Fig. 7 traces `iters` of them). Reads: `rank[v]`
/// (stream), `row_ptr`, `col_idx` (stream), and the scatter target
/// `next[dst]` — counted as a read because the += is a read-modify-write.
pub fn pagerank_traced<T: Tracer>(csr: &Csr, p: PrParams, iters: usize, tracer: &mut T) -> PrResult {
    let n = csr.n();
    let mut rank = vec![1.0f32 / n as f32; n];
    let mut next = vec![0f32; n];
    let mut done = 0;
    for _ in 0..iters.min(p.max_iters) {
        done += 1;
        next.fill(0.0);
        let mut dangling = 0f32;
        for v in 0..n {
            tracer.read4(Region::VectorX, v);
            tracer.read8(Region::RowPtr, v);
            tracer.read8(Region::RowPtr, v + 1);
            let deg = csr.degree(v);
            if deg == 0 {
                dangling += rank[v];
                continue;
            }
            let share = rank[v] / deg as f32;
            let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
            for e in lo..hi {
                tracer.read4(Region::ColIdx, e);
                let u = csr.col_idx[e] as usize;
                tracer.read4(Region::VectorY, u);
                next[u] += share;
            }
        }
        let base = (1.0 - p.damping) / n as f32 + p.damping * dangling / n as f32;
        for v in 0..n {
            rank[v] = base + p.damping * next[v];
        }
    }
    PrResult { ranks: rank, iters: done }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert::coo_to_csr;
    use crate::graph::gen::{self, GenParams};
    use crate::graph::Coo;

    #[test]
    fn ranks_sum_to_one() {
        let g = gen::preferential_attachment(500, 3, 1);
        let csr = coo_to_csr(&g);
        let r = pagerank(&csr, PrParams::default());
        let s: f32 = r.ranks.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "sum {s}");
    }

    #[test]
    fn cycle_is_uniform() {
        let n = 10u32;
        let src: Vec<u32> = (0..n).collect();
        let dst: Vec<u32> = (0..n).map(|i| (i + 1) % n).collect();
        let csr = coo_to_csr(&Coo::new(n as usize, src, dst));
        let r = pagerank(&csr, PrParams::default());
        for &v in &r.ranks {
            assert!((v - 0.1).abs() < 1e-4, "rank {v}");
        }
    }

    #[test]
    fn hub_outranks_leaves() {
        // Star pointing at center: leaves -> 0.
        let src = vec![1, 2, 3, 4];
        let dst = vec![0, 0, 0, 0];
        let csr = coo_to_csr(&Coo::new(5, src, dst));
        let r = pagerank(&csr, PrParams::default());
        assert!(r.ranks[0] > 4.0 * r.ranks[1]);
    }

    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        // n = 2^15 ≥ the 2^14 threshold, so the parallel path really
        // executes; the rebuilt kernel must reproduce the sequential
        // ranks bit for bit (tests/batch_equiv.rs additionally sweeps
        // pinned thread counts).
        let g = gen::rmat(&GenParams::rmat(15, 8), 9);
        let csr = coo_to_csr(&g);
        let p = PrParams { max_iters: 30, ..Default::default() };
        let s = pagerank(&csr, p);
        let q = pagerank_parallel(&csr, p);
        assert_eq!(s.iters, q.iters);
        assert_eq!(s.ranks, q.ranks, "deterministic parallel pagerank must match bitwise");
    }

    #[test]
    fn precomputed_transpose_matches_wrapper() {
        // The serving path hands pagerank_parallel_pull the transpose it
        // cached at prepare time; the result must be bit-identical to
        // the transpose-per-call wrapper (and hence to sequential).
        let g = gen::rmat(&GenParams::rmat(15, 8), 11);
        let csr = coo_to_csr(&g);
        let tr = csr.transposed_structure();
        let p = PrParams { max_iters: 20, ..Default::default() };
        let a = pagerank_parallel(&csr, p);
        let b = pagerank_parallel_pull(&csr, &tr, p);
        assert_eq!(a.iters, b.iters);
        assert_eq!(a.ranks, b.ranks);
        // Below the threshold both entry points fall back to sequential.
        let small = coo_to_csr(&gen::preferential_attachment(500, 3, 1));
        let str_ = small.transposed_structure();
        assert_eq!(
            pagerank_parallel_pull(&small, &str_, p).ranks,
            pagerank(&small, p).ranks
        );
    }

    #[test]
    fn atomic_baseline_stays_close_to_sequential() {
        // The retained CAS-scatter baseline is nondeterministic by
        // design; it must still converge to the same ranks numerically.
        let g = gen::rmat(&GenParams::rmat(15, 8), 9);
        let csr = coo_to_csr(&g);
        let p = PrParams { max_iters: 30, ..Default::default() };
        let s = pagerank(&csr, p);
        let q = pagerank_parallel_atomic(&csr, p);
        let dmax = s
            .ranks
            .iter()
            .zip(&q.ranks)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(dmax < 1e-5, "max diff {dmax}");
    }

    #[test]
    fn traced_one_iter_matches_untraced_one_iter() {
        let g = gen::uniform_random(300, 2000, 4);
        let csr = coo_to_csr(&g);
        let p = PrParams { max_iters: 1, tol: 0.0, ..Default::default() };
        let a = pagerank(&csr, p);
        let mut t = super::super::trace::VecTrace::default();
        let b = pagerank_traced(&csr, PrParams::default(), 1, &mut t);
        assert_eq!(a.ranks, b.ranks);
        assert!(!t.addrs.is_empty());
    }

    #[test]
    fn expired_deadline_stops_iterating_between_iterations() {
        let g = gen::preferential_attachment(500, 3, 1);
        let csr = coo_to_csr(&g);
        let d = crate::util::deadline::scope(Some(std::time::Instant::now()));
        let r = pagerank(&csr, PrParams::default());
        assert_eq!(r.iters, 0, "spent budget must stop before the first iteration");
        drop(d);
        // With the scope gone the kernel iterates normally again.
        assert!(pagerank(&csr, PrParams::default()).iters > 0);
    }

    #[test]
    fn dangling_mass_redistributed() {
        // 0 -> 1, 1 dangling.
        let csr = coo_to_csr(&Coo::new(2, vec![0], vec![1]));
        let r = pagerank(&csr, PrParams::default());
        let s: f32 = r.ranks.iter().sum();
        assert!((s - 1.0).abs() < 1e-3);
        assert!(r.ranks[1] > r.ranks[0]);
    }
}
