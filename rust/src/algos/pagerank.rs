//! PageRank — the paper's iterate-until-convergence workload. Matches the
//! paper's GPU formulation (§5.1): push-based, "each edge's source
//! propagates its weight to its neighbor vertices" — the cache-critical
//! access is the scatter into `rank_next[dst]`, which clusters iff
//! destination labels cluster.

use super::trace::{Region, Tracer};
use crate::graph::Csr;
use crate::parallel::{self, SendPtr};
use std::sync::atomic::{AtomicU32, Ordering};

/// PageRank parameters.
#[derive(Clone, Copy, Debug)]
pub struct PrParams {
    /// Damping factor (0.85 standard).
    pub damping: f32,
    /// Maximum iterations.
    pub max_iters: usize,
    /// L1 convergence tolerance.
    pub tol: f32,
}

impl Default for PrParams {
    fn default() -> Self {
        Self { damping: 0.85, max_iters: 100, tol: 1e-6 }
    }
}

/// Result: ranks and the iteration count actually run.
#[derive(Clone, Debug)]
pub struct PrResult {
    /// Final rank vector (sums to ~1).
    pub ranks: Vec<f32>,
    /// Iterations executed.
    pub iters: usize,
}

/// Sequential push-based PageRank.
pub fn pagerank(csr: &Csr, p: PrParams) -> PrResult {
    let n = csr.n();
    let mut rank = vec![1.0f32 / n as f32; n];
    let mut next = vec![0f32; n];
    let mut iters = 0;
    for _ in 0..p.max_iters {
        iters += 1;
        next.fill(0.0);
        let mut dangling = 0f32;
        for v in 0..n {
            let deg = csr.degree(v);
            if deg == 0 {
                dangling += rank[v];
                continue;
            }
            let share = rank[v] / deg as f32;
            for &u in csr.neighbors(v) {
                next[u as usize] += share;
            }
        }
        let base = (1.0 - p.damping) / n as f32 + p.damping * dangling / n as f32;
        let mut delta = 0f32;
        for v in 0..n {
            let nv = base + p.damping * next[v];
            delta += (nv - rank[v]).abs();
            rank[v] = nv;
        }
        if delta < p.tol {
            break;
        }
    }
    PrResult { ranks: rank, iters }
}

/// Parallel push-based PageRank with atomic f32 accumulation (CAS loop on
/// `AtomicU32` bits — the CPU analogue of the paper's GPU `atomicAdd`).
pub fn pagerank_parallel(csr: &Csr, p: PrParams) -> PrResult {
    let n = csr.n();
    if n < 1 << 14 {
        return pagerank(csr, p);
    }
    let mut rank = vec![1.0f32 / n as f32; n];
    let next: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let mut iters = 0;
    let chunk = parallel::default_chunk(n);
    for _ in 0..p.max_iters {
        iters += 1;
        for a in &next {
            a.store(0, Ordering::Relaxed);
        }
        let rank_ref = &rank;
        let dangling = parallel::par_reduce(
            n,
            chunk,
            0f64,
            |acc, lo, hi| {
                let mut d = acc;
                for v in lo..hi {
                    let deg = csr.degree(v);
                    if deg == 0 {
                        d += rank_ref[v] as f64;
                        continue;
                    }
                    let share = rank_ref[v] / deg as f32;
                    for &u in csr.neighbors(v) {
                        atomic_add_f32(&next[u as usize], share);
                    }
                }
                d
            },
            |a, b| a + b,
        ) as f32;
        let base = (1.0 - p.damping) / n as f32 + p.damping * dangling / n as f32;
        // Update + delta reduction.
        let rank_ptr = SendPtr(rank.as_mut_ptr());
        let delta = parallel::par_reduce(
            n,
            chunk,
            0f64,
            |acc, lo, hi| {
                let mut d = acc;
                for v in lo..hi {
                    let nv = base + p.damping * f32::from_bits(next[v].load(Ordering::Relaxed));
                    // SAFETY: disjoint chunks.
                    unsafe {
                        let slot = rank_ptr.get().add(v);
                        d += (nv - *slot).abs() as f64;
                        *slot = nv;
                    }
                }
                d
            },
            |a, b| a + b,
        );
        if (delta as f32) < p.tol {
            break;
        }
    }
    PrResult { ranks: rank, iters }
}

#[inline]
fn atomic_add_f32(cell: &AtomicU32, v: f32) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let newv = (f32::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, newv, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// Traced sequential PageRank (one traced power iteration is
/// representative; Fig. 7 traces `iters` of them). Reads: `rank[v]`
/// (stream), `row_ptr`, `col_idx` (stream), and the scatter target
/// `next[dst]` — counted as a read because the += is a read-modify-write.
pub fn pagerank_traced<T: Tracer>(csr: &Csr, p: PrParams, iters: usize, tracer: &mut T) -> PrResult {
    let n = csr.n();
    let mut rank = vec![1.0f32 / n as f32; n];
    let mut next = vec![0f32; n];
    let mut done = 0;
    for _ in 0..iters.min(p.max_iters) {
        done += 1;
        next.fill(0.0);
        let mut dangling = 0f32;
        for v in 0..n {
            tracer.read4(Region::VectorX, v);
            tracer.read8(Region::RowPtr, v);
            tracer.read8(Region::RowPtr, v + 1);
            let deg = csr.degree(v);
            if deg == 0 {
                dangling += rank[v];
                continue;
            }
            let share = rank[v] / deg as f32;
            let (lo, hi) = (csr.row_ptr[v] as usize, csr.row_ptr[v + 1] as usize);
            for e in lo..hi {
                tracer.read4(Region::ColIdx, e);
                let u = csr.col_idx[e] as usize;
                tracer.read4(Region::VectorY, u);
                next[u] += share;
            }
        }
        let base = (1.0 - p.damping) / n as f32 + p.damping * dangling / n as f32;
        for v in 0..n {
            rank[v] = base + p.damping * next[v];
        }
    }
    PrResult { ranks: rank, iters: done }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert::coo_to_csr;
    use crate::graph::gen::{self, GenParams};
    use crate::graph::Coo;

    #[test]
    fn ranks_sum_to_one() {
        let g = gen::preferential_attachment(500, 3, 1);
        let csr = coo_to_csr(&g);
        let r = pagerank(&csr, PrParams::default());
        let s: f32 = r.ranks.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "sum {s}");
    }

    #[test]
    fn cycle_is_uniform() {
        let n = 10u32;
        let src: Vec<u32> = (0..n).collect();
        let dst: Vec<u32> = (0..n).map(|i| (i + 1) % n).collect();
        let csr = coo_to_csr(&Coo::new(n as usize, src, dst));
        let r = pagerank(&csr, PrParams::default());
        for &v in &r.ranks {
            assert!((v - 0.1).abs() < 1e-4, "rank {v}");
        }
    }

    #[test]
    fn hub_outranks_leaves() {
        // Star pointing at center: leaves -> 0.
        let src = vec![1, 2, 3, 4];
        let dst = vec![0, 0, 0, 0];
        let csr = coo_to_csr(&Coo::new(5, src, dst));
        let r = pagerank(&csr, PrParams::default());
        assert!(r.ranks[0] > 4.0 * r.ranks[1]);
    }

    #[test]
    fn parallel_matches_sequential_approximately() {
        let g = gen::rmat(&GenParams::rmat(11, 8), 9);
        let csr = coo_to_csr(&g);
        let p = PrParams { max_iters: 30, ..Default::default() };
        let a = pagerank(&csr, p);
        // Force the parallel path despite small n by inlining its body —
        // easier: just check it agrees through the public API on a big
        // enough graph.
        let g2 = gen::rmat(&GenParams::rmat(15, 8), 9);
        let csr2 = coo_to_csr(&g2);
        let s = pagerank(&csr2, p);
        let q = pagerank_parallel(&csr2, p);
        let dmax = s
            .ranks
            .iter()
            .zip(&q.ranks)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(dmax < 1e-5, "max diff {dmax}");
        assert!(a.iters > 0);
    }

    #[test]
    fn traced_one_iter_matches_untraced_one_iter() {
        let g = gen::uniform_random(300, 2000, 4);
        let csr = coo_to_csr(&g);
        let p = PrParams { max_iters: 1, tol: 0.0, ..Default::default() };
        let a = pagerank(&csr, p);
        let mut t = super::super::trace::VecTrace::default();
        let b = pagerank_traced(&csr, PrParams::default(), 1, &mut t);
        assert_eq!(a.ranks, b.ranks);
        assert!(!t.addrs.is_empty());
    }

    #[test]
    fn dangling_mass_redistributed() {
        // 0 -> 1, 1 dangling.
        let csr = coo_to_csr(&Coo::new(2, vec![0], vec![1]));
        let r = pagerank(&csr, PrParams::default());
        let s: f32 = r.ranks.iter().sum();
        assert!((s - 1.0).abs() < 1e-3);
        assert!(r.ranks[1] > r.ranks[0]);
    }
}
