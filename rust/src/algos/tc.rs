//! Triangle counting — the paper's set-intersection workload (§5.1):
//! "for each edge in the graph, we perform a set-intersection operation
//! between the adjacency lists of the edge source and destination".
//!
//! The standard forward/degree-ordered algorithm: orient each undirected
//! edge from lower to higher ID, then for every directed edge `(u, v)`
//! intersect `N⁺(u)` and `N⁺(v)`. Requires sorted adjacency lists — which
//! is why the paper's TC pipeline (Fig. 4) charges a COO sort before
//! conversion.

use super::trace::{Region, Tracer};
use crate::graph::Csr;
use crate::parallel;

/// Build the DAG orientation (lower ID → higher ID) of an undirected
/// graph given as a (possibly directed, possibly duplicated) CSR. Rows
/// must be sorted ascending.
pub fn orient_for_tc(csr: &Csr) -> Csr {
    assert!(csr.rows_sorted(), "TC requires sorted adjacency lists");
    let n = csr.n();
    let mut row_ptr = vec![0u64; n + 1];
    for v in 0..n {
        let mut cnt = 0u64;
        let mut prev = u32::MAX;
        for &u in csr.neighbors(v) {
            if u as usize > v && u != prev {
                cnt += 1;
            }
            prev = u;
        }
        row_ptr[v + 1] = cnt;
    }
    for i in 0..n {
        row_ptr[i + 1] += row_ptr[i];
    }
    let mut col_idx = vec![0u32; *row_ptr.last().unwrap() as usize];
    for v in 0..n {
        let mut pos = row_ptr[v] as usize;
        let mut prev = u32::MAX;
        for &u in csr.neighbors(v) {
            if u as usize > v && u != prev {
                col_idx[pos] = u;
                pos += 1;
            }
            prev = u;
        }
    }
    Csr { row_ptr, col_idx, vals: None }
}

/// Count triangles in the oriented DAG (output of [`orient_for_tc`]).
pub fn triangle_count(dag: &Csr) -> u64 {
    let n = dag.n();
    let mut total = 0u64;
    for u in 0..n {
        for &v in dag.neighbors(u) {
            total += intersect_count(dag.neighbors(u), dag.neighbors(v as usize));
        }
    }
    total
}

/// Parallel triangle count (row-parallel over the DAG).
pub fn triangle_count_parallel(dag: &Csr) -> u64 {
    let n = dag.n();
    parallel::par_reduce(
        n,
        parallel::default_chunk(n).max(64),
        0u64,
        |acc, lo, hi| {
            let mut t = acc;
            for u in lo..hi {
                for &v in dag.neighbors(u) {
                    t += intersect_count(dag.neighbors(u), dag.neighbors(v as usize));
                }
            }
            t
        },
        |a, b| a + b,
    )
}

/// Traced triangle count: the source adjacency list is "already in the
/// cache" (paper §5.1), so we trace reads of the *destination* vertex's
/// list (region `Adj2`) plus the edge stream (`ColIdx`) — the accesses
/// whose locality reordering changes.
pub fn triangle_count_traced<T: Tracer>(dag: &Csr, tracer: &mut T) -> u64 {
    let n = dag.n();
    let mut total = 0u64;
    for u in 0..n {
        tracer.read8(Region::RowPtr, u);
        tracer.read8(Region::RowPtr, u + 1);
        let (lo_u, hi_u) = (dag.row_ptr[u] as usize, dag.row_ptr[u + 1] as usize);
        for e in lo_u..hi_u {
            tracer.read4(Region::ColIdx, e);
            let v = dag.col_idx[e] as usize;
            tracer.read8(Region::RowPtr, v);
            let (lo_v, hi_v) = (dag.row_ptr[v] as usize, dag.row_ptr[v + 1] as usize);
            for ev in lo_v..hi_v {
                tracer.read4(Region::Adj2, ev);
            }
            total += intersect_count(dag.neighbors(u), dag.neighbors(v));
        }
    }
    total
}

/// Degree rank: position of each vertex in the (total-degree, id)
/// ascending order. Orienting every edge from lower to higher rank bounds
/// out-degrees by O(√m) on any graph (the standard arboricity argument),
/// which keeps TC tractable on skew graphs where ID orientation explodes
/// at the hubs.
pub fn degree_rank(csr: &Csr) -> Vec<u32> {
    let n = csr.n();
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by_key(|&v| (csr.degree(v as usize), v));
    let mut rank = vec![0u32; n];
    for (r, &v) in order.iter().enumerate() {
        rank[v as usize] = r as u32;
    }
    rank
}

/// Orient each (deduped) edge from lower to higher `rank`, emitting each
/// row's survivors sorted by rank (so ranked merge-intersection works).
pub fn orient_by_rank(csr: &Csr, rank: &[u32]) -> Csr {
    let n = csr.n();
    let mut row_ptr = vec![0u64; n + 1];
    let mut rows: Vec<Vec<u32>> = vec![Vec::new(); n];
    for v in 0..n {
        let rv = rank[v];
        let row = &mut rows[v];
        for &u in csr.neighbors(v) {
            if rank[u as usize] > rv {
                row.push(u);
            }
        }
        row.sort_unstable_by_key(|&u| rank[u as usize]);
        row.dedup();
        row_ptr[v + 1] = row_ptr[v] + row.len() as u64;
    }
    let mut col_idx = Vec::with_capacity(*row_ptr.last().unwrap() as usize);
    for row in rows {
        col_idx.extend(row);
    }
    Csr { row_ptr, col_idx, vals: None }
}

/// Triangle count over a rank-oriented DAG (rows sorted by rank).
pub fn triangle_count_ranked(dag: &Csr, rank: &[u32]) -> u64 {
    let n = dag.n();
    let mut total = 0u64;
    for u in 0..n {
        for &v in dag.neighbors(u) {
            total += intersect_count_ranked(dag.neighbors(u), dag.neighbors(v as usize), rank);
        }
    }
    total
}

/// Traced ranked triangle count (same trace regions as
/// [`triangle_count_traced`]).
pub fn triangle_count_ranked_traced<T: Tracer>(dag: &Csr, rank: &[u32], tracer: &mut T) -> u64 {
    let n = dag.n();
    let mut total = 0u64;
    for u in 0..n {
        tracer.read8(Region::RowPtr, u);
        tracer.read8(Region::RowPtr, u + 1);
        let (lo_u, hi_u) = (dag.row_ptr[u] as usize, dag.row_ptr[u + 1] as usize);
        for e in lo_u..hi_u {
            tracer.read4(Region::ColIdx, e);
            let v = dag.col_idx[e] as usize;
            tracer.read8(Region::RowPtr, v);
            let (lo_v, hi_v) = (dag.row_ptr[v] as usize, dag.row_ptr[v + 1] as usize);
            for ev in lo_v..hi_v {
                tracer.read4(Region::Adj2, ev);
            }
            total += intersect_count_ranked(dag.neighbors(u), dag.neighbors(v), rank);
        }
    }
    total
}

/// Ranked merge |A ∩ B| (slices sorted by `rank`).
#[inline]
fn intersect_count_ranked(a: &[u32], b: &[u32], rank: &[u32]) -> u64 {
    let (mut i, mut j, mut c) = (0usize, 0usize, 0u64);
    while i < a.len() && j < b.len() {
        match rank[a[i] as usize].cmp(&rank[b[j] as usize]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

/// Merge-style |A ∩ B| for sorted slices.
#[inline]
fn intersect_count(a: &[u32], b: &[u32]) -> u64 {
    let (mut i, mut j, mut c) = (0usize, 0usize, 0u64);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert::{coo_to_csr, sort_coo_by_src};
    use crate::graph::gen;
    use crate::graph::Coo;

    fn count(coo: &Coo) -> u64 {
        let und = coo.symmetrized().deduped();
        let csr = coo_to_csr(&sort_coo_by_src(&und));
        triangle_count(&orient_for_tc(&csr))
    }

    #[test]
    fn triangle_graph_has_one() {
        let g = Coo::new(3, vec![0, 1, 2], vec![1, 2, 0]);
        assert_eq!(count(&g), 1);
    }

    #[test]
    fn square_has_none_k4_has_four() {
        let square = Coo::new(4, vec![0, 1, 2, 3], vec![1, 2, 3, 0]);
        assert_eq!(count(&square), 0);
        let mut src = Vec::new();
        let mut dst = Vec::new();
        for u in 0..4u32 {
            for v in (u + 1)..4 {
                src.push(u);
                dst.push(v);
            }
        }
        let k4 = Coo::new(4, src, dst);
        assert_eq!(count(&k4), 4);
    }

    #[test]
    fn duplicate_edges_do_not_inflate() {
        let g = Coo::new(3, vec![0, 0, 1, 2], vec![1, 1, 2, 0]);
        assert_eq!(count(&g), 1);
    }

    #[test]
    fn relabeling_is_invariant() {
        let g = gen::preferential_attachment(300, 4, 6);
        let c0 = count(&g);
        let c1 = count(&g.randomized(17));
        assert_eq!(c0, c1);
        assert!(c0 > 0, "PA graph should close triangles");
    }

    #[test]
    fn parallel_matches_sequential() {
        let g = gen::rmat(&gen::GenParams::rmat(12, 8), 2);
        let und = g.symmetrized().deduped();
        let dag = orient_for_tc(&coo_to_csr(&sort_coo_by_src(&und)));
        assert_eq!(triangle_count(&dag), triangle_count_parallel(&dag));
    }

    #[test]
    fn traced_matches_plain() {
        let g = gen::uniform_random(120, 900, 8);
        let und = g.symmetrized().deduped();
        let dag = orient_for_tc(&coo_to_csr(&sort_coo_by_src(&und)));
        let mut t = super::super::trace::VecTrace::default();
        assert_eq!(triangle_count_traced(&dag, &mut t), triangle_count(&dag));
        assert!(!t.addrs.is_empty());
    }

    #[test]
    fn mesh_triangles_positive() {
        let g = gen::delaunay_mesh(10, 10, 1);
        assert!(count(&g) > 50); // every diagonal closes 2 triangles
    }

    #[test]
    fn ranked_matches_id_orientation() {
        for seed in 0..3 {
            let g = gen::rmat(&gen::GenParams::rmat(10, 8), seed);
            let und = g.symmetrized().deduped();
            let csr = coo_to_csr(&sort_coo_by_src(&und));
            let id_count = triangle_count(&orient_for_tc(&csr));
            let rank = degree_rank(&csr);
            let dag = orient_by_rank(&csr, &rank);
            assert_eq!(triangle_count_ranked(&dag, &rank), id_count, "seed {seed}");
        }
    }

    #[test]
    fn ranked_dag_outdegree_bounded() {
        // Degree orientation must shrink hub out-degrees dramatically.
        let g = gen::preferential_attachment(2000, 8, 3);
        let und = g.symmetrized().deduped();
        let csr = coo_to_csr(&sort_coo_by_src(&und));
        let rank = degree_rank(&csr);
        let dag = orient_by_rank(&csr, &rank);
        assert!(dag.max_degree() * 4 < csr.max_degree(),
            "dag {} vs graph {}", dag.max_degree(), csr.max_degree());
    }

    #[test]
    fn ranked_traced_matches() {
        let g = gen::uniform_random(150, 1000, 5);
        let und = g.symmetrized().deduped();
        let csr = coo_to_csr(&sort_coo_by_src(&und));
        let rank = degree_rank(&csr);
        let dag = orient_by_rank(&csr, &rank);
        let mut t = super::super::trace::VecTrace::default();
        assert_eq!(
            triangle_count_ranked_traced(&dag, &rank, &mut t),
            triangle_count_ranked(&dag, &rank)
        );
        assert!(!t.addrs.is_empty());
    }
}
