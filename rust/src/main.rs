//! `boba` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   datasets                         print the Table-2 style inventory
//!   generate  --dataset N --out F    build a dataset and write .mtx/.el
//!   reorder   --algo S [--in F | --dataset N] [--out F]
//!   convert   [--in F | --dataset N]             time COO→CSR
//!   run       --app A [--algo S] [--in F | --dataset N]
//!   pipeline  --app A --algo S [--dataset N]     full Problem-3 pipeline
//!   table1 | table3 | fig4 | fig5 | fig6 | fig7  regenerate a paper table/figure
//!   spmv-pjrt [--dataset N] [--pallas]           SpMV through the AOT artifacts
//!
//! Common options: --seed (default 42), --scale quick|full (or BOBA_SCALE),
//! --heavy false (or BOBA_HEAVY=0) to skip Gorder/RCM in figure drivers.

use boba::algos::spmv;
use boba::convert;
use boba::coordinator::{datasets, experiments, pipeline};
use boba::graph::{gen, io, Coo};
use boba::reorder::{
    boba::Boba, degree::DegreeSort, gorder::Gorder, hub::HubSort, random::RandomOrder, rcm::Rcm,
    Reorderer,
};
use boba::runtime::{Engine, SpmvKind};
use boba::util::args::Args;
use boba::util::timer::Stopwatch;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    if let Some(scale) = args.get("scale") {
        std::env::set_var("BOBA_SCALE", scale);
    }
    if let Some(h) = args.get("heavy") {
        std::env::set_var("BOBA_HEAVY", if h == "false" || h == "0" { "0" } else { "1" });
    }
    let seed: u64 = args.get_parse("seed", 42);
    match args.command.as_deref() {
        Some("datasets") => {
            println!("{}", datasets::inventory(seed));
        }
        Some("generate") => {
            let g = load_graph(args, seed)?;
            let out = args.get_or("out", "graph.mtx");
            if out.ends_with(".mtx") {
                io::write_matrix_market(&g, Path::new(&out))?;
            } else {
                io::write_edge_list(&g, Path::new(&out))?;
            }
            println!("wrote {} (n={} m={})", out, g.n(), g.m());
        }
        Some("reorder") => {
            let g = load_graph(args, seed)?.randomized(seed + 1);
            let scheme = scheme_by_name(&args.get_or("algo", "boba"), seed)?;
            let sw = Stopwatch::start();
            let perm = scheme.reorder(&g);
            let ms = sw.ms();
            let h = g.relabeled(perm.new_of_old());
            println!(
                "{}: reordered n={} m={} in {:.2} ms (NBR {:.3} -> {:.3})",
                scheme.name(),
                g.n(),
                g.m(),
                ms,
                boba::metrics::nbr_coo(&g),
                boba::metrics::nbr_coo(&h),
            );
            if let Some(out) = args.get("out") {
                io::write_matrix_market(&h, Path::new(out))?;
                println!("wrote {out}");
            }
        }
        Some("convert") => {
            let g = load_graph(args, seed)?.randomized(seed + 1);
            let sw = Stopwatch::start();
            let csr = convert::coo_to_csr(&g);
            println!("COO→CSR: n={} m={} in {:.2} ms", csr.n(), csr.m(), sw.ms());
        }
        Some("run") => {
            let g = load_graph(args, seed)?.randomized(seed + 1);
            let app = app_by_name(&args.get_or("app", "spmv"))?;
            let stage = match args.get("algo") {
                None => pipeline::ReorderStage::None,
                Some(name) => pipeline::ReorderStage::Scheme(scheme_by_name(name, seed)?),
            };
            let report = pipeline::Pipeline::new(app).run(&g, &stage);
            println!(
                "{} via {}: total {:.2} ms [{}] digest={:.6e}",
                report.app,
                report.scheme,
                report.total_ms(),
                report.stages.summary(),
                report.digest,
            );
        }
        Some("pipeline") => {
            // The full online scenario: streaming ingest + reorder +
            // convert + app, with stage timings.
            let g = load_graph(args, seed)?.randomized(seed + 1);
            let app = app_by_name(&args.get_or("app", "spmv"))?;
            let batch: usize = args.get_parse("batch", 1 << 16);
            let sw = Stopwatch::start();
            let (producer, stream) = pipeline::StreamingIngest::from_coo(g.clone(), batch, 4);
            let (assembled, batches) = stream.collect();
            producer.join().ok();
            let ingest_ms = sw.ms();
            let stage = match args.get("algo") {
                None => pipeline::ReorderStage::Scheme(Box::new(Boba::parallel())),
                Some(name) => pipeline::ReorderStage::Scheme(scheme_by_name(name, seed)?),
            };
            let report = pipeline::Pipeline::new(app).run(&assembled, &stage);
            println!(
                "pipeline: ingest {batches} batches in {:.2} ms; {} via {}: {:.2} ms [{}]",
                ingest_ms,
                report.app,
                report.scheme,
                report.total_ms(),
                report.stages.summary(),
            );
        }
        Some("table1") => println!("{}", experiments::table1(seed).render()),
        Some("table3") => println!("{}", experiments::table3(seed).render()),
        Some("fig4") => println!("{}", experiments::fig4(seed).render()),
        Some("fig5") => println!("{}", experiments::fig5(seed).render()),
        Some("fig6") => println!("{}", experiments::fig6(seed).render()),
        Some("fig7") => println!("{}", experiments::fig7(seed).render()),
        Some("spmv-pjrt") => {
            let g = load_graph(args, seed)?.randomized(seed + 1);
            let csr = convert::coo_to_csr(&g);
            let engine = Engine::load_default()?;
            let kind = if args.flag("pallas") { SpmvKind::Pallas } else { SpmvKind::Jnp };
            let x = vec![1.0f32; csr.n()];
            let sw = Stopwatch::start();
            let y = engine.spmv_csr(kind, &csr, &x)?;
            let pjrt_ms = sw.ms();
            let y_native = spmv::spmv_pull(&csr, &x);
            let max_diff = y
                .iter()
                .zip(&y_native)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            println!(
                "PJRT SpMV ({kind:?}) on {}: n={} m={} in {:.2} ms; max |Δ| vs native = {max_diff:e}",
                engine.platform(),
                csr.n(),
                csr.m(),
                pjrt_ms,
            );
        }
        _ => {
            eprintln!(
                "usage: boba <datasets|generate|reorder|convert|run|pipeline|\
                 table1|table3|fig4|fig5|fig6|fig7|spmv-pjrt> [options]\n\
                 (see rust/src/main.rs header for options)"
            );
        }
    }
    Ok(())
}

/// Load a graph from `--in FILE` or build `--dataset NAME` (default
/// pa_c8).
fn load_graph(args: &Args, seed: u64) -> anyhow::Result<Coo> {
    if let Some(path) = args.get("in") {
        let p = Path::new(path);
        return if path.ends_with(".mtx") {
            io::read_matrix_market(p)
        } else {
            io::read_edge_list(p, args.flag("preserve-ids"))
        };
    }
    if let Some(name) = args.get("dataset") {
        if let Some(d) = datasets::by_name(name) {
            return Ok(d.build(seed));
        }
        // Ad-hoc recipes: rmat:scale:ef, pa:n:c, grid:w:h
        let parts: Vec<&str> = name.split(':').collect();
        match parts.as_slice() {
            ["rmat", s, ef] => {
                return Ok(gen::rmat(&gen::GenParams::rmat(s.parse()?, ef.parse()?), seed))
            }
            ["pa", n, c] => return Ok(gen::preferential_attachment(n.parse()?, c.parse()?, seed)),
            ["grid", w, h] => return Ok(gen::grid_road(w.parse()?, h.parse()?, seed)),
            _ => anyhow::bail!("unknown dataset {name}"),
        }
    }
    Ok(datasets::by_name("pa_c8").unwrap().build(seed))
}

fn scheme_by_name(name: &str, seed: u64) -> anyhow::Result<Box<dyn Reorderer + Send + Sync>> {
    Ok(match name.to_lowercase().as_str() {
        "boba" => Box::new(Boba::parallel()),
        "boba-seq" => Box::new(Boba::sequential()),
        "boba-atomic" => Box::new(Boba::parallel_atomic()),
        "degree" => Box::new(DegreeSort::new()),
        "hub" => Box::new(HubSort::new()),
        "rcm" => Box::new(Rcm::new()),
        "gorder" => Box::new(Gorder::new(5)),
        "random" => Box::new(RandomOrder::new(seed)),
        other => anyhow::bail!("unknown scheme {other}"),
    })
}

fn app_by_name(name: &str) -> anyhow::Result<pipeline::App> {
    Ok(match name.to_lowercase().as_str() {
        "spmv" => pipeline::App::Spmv,
        "pr" | "pagerank" => pipeline::App::PageRank,
        "tc" => pipeline::App::Tc,
        "sssp" => pipeline::App::Sssp,
        other => anyhow::bail!("unknown app {other}"),
    })
}
