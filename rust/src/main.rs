//! `boba` — the L3 coordinator CLI and the L4 service entry points.
//!
//! Subcommands:
//!   datasets                         print the Table-2 style inventory
//!   generate  --dataset N --out F    build a dataset and write .mtx/.el/.bcoo
//!   convert-bcoo --in F [--out F]    convert a text graph to binary .bcoo
//!   reorder   --algo S [--in F | --dataset N] [--out F]
//!   convert   [--in F | --dataset N]             time COO→CSR
//!   run       --app A [--algo S] [--in F | --dataset N]
//!   pipeline  --app A --algo S [--dataset N] [--batch B] [--in-flight K]
//!   serve     [--addr H:P] [--workers W] [--cache C] [--batch B]
//!             [--in-flight K] [--batch-window-us U] [--max-batch K]
//!             [--no-trace] [--slow-trace-ms T] [--format F]
//!             [--rate R] [--burst B] [--max-inflight K]
//!             [--default-deadline-ms D]
//!             [--wal-dir DIR] [--compact-threshold N]
//!                                      run the graph-analytics service;
//!             --no-trace disables stage-span tracing (BOBA_NO_TRACE=1
//!             does the same), --slow-trace-ms logs slower traces to
//!             stderr as one-line JSON, --format encodes a compressed
//!             kernel variant (csr|delta|sell|tiled|ell) per artifact,
//!             gated bit-identical at prepare and exposed on /metrics;
//!             --rate/--burst set the per-tenant token bucket (429 +
//!             Retry-After when drained), --max-inflight caps
//!             concurrent queries (expensive kinds shed first, 503),
//!             --default-deadline-ms bounds requests that send no
//!             x-deadline-ms header (504 past the budget); BOBA_FAULTS
//!             arms deterministic fault injection (see /debug/faults);
//!             --wal-dir enables durable POST /mutate (fsynced
//!             write-ahead log + crash recovery on restart),
//!             --compact-threshold sets the overlay size that triggers
//!             a background BOBA re-run folding the delta into a fresh
//!             epoch (0 = manual POST /graphs/{id}/compact only)
//!   loadgen   [--addr H:P] [--conns C] [--requests R] [--dataset N]
//!             [--scheme S] [--mix spmv:7,pagerank:3] [--pr-iters I]
//!             [--compare] [--coalesce] [--batch-queries K]
//!             [--compare-coalesced] [--scrape-metrics] [--json F]
//!             [--spawn] [--target-qps Q] [--retries N] [--backoff-ms B]
//!             [--overload] [--mutate-frac F] [--churn]
//!             drive a server; --coalesce sends K-query batches through
//!             POST /query/batch (with --compare it appends a
//!             single-vs-coalesced pricing row; --compare-coalesced
//!             prices just that contrast); --scrape-metrics diffs
//!             GET /metrics around each run and embeds the server-side
//!             percentiles/stage breakdown into the report;
//!             --target-qps switches to an open-loop arrival schedule,
//!             --retries/--backoff-ms retry 429/503 rejections with
//!             jittered exponential backoff honoring Retry-After,
//!             --overload appends an admission-on vs unprotected
//!             overload sweep at 2x measured capacity (spawns its own
//!             servers; composable with --compare);
//!             --mutate-frac mixes that fraction of POST /mutate
//!             batches (zipfian vertex popularity) into the load,
//!             --churn appends a frozen-vs-mutating pricing of query
//!             p50/p99 and goodput (spawns its own WAL-enabled server)
//!   table1 | table3 | fig4 | fig5 | fig6 | fig7  regenerate a paper table/figure
//!   repro     [--quick|--full] [--tables t1,t2,t3,t4,t5] [--threads N]
//!             [--datasets A,B] [--reps K] [--json F] [--md F]
//!             run the paper-reproduction harness: T1 reorder time,
//!             T2 COO→CSR conversion, T3 end-to-end, T4 cache rates,
//!             T5 kernel formats (bytes/edge, encode/SpMV time,
//!             effective GB/s vs a measured stream roofline);
//!             writes BENCH_repro.json + docs/RESULTS.md
//!   spmv-pjrt [--dataset N] [--pallas]           SpMV through the AOT artifacts
//!                                                (needs the `pjrt` build feature)
//!   lint      [--root DIR] [--json]  run the repo-invariant static
//!             analyzer over rust/src + ci.sh + docs/ARCHITECTURE.md
//!             (unsafe-safety, raw-spawn, panic-path, atomic-ordering,
//!             metrics-drift, chaos-drift, ablation-reach); prints an
//!             aligned table (or a JSON document with --json) and exits
//!             nonzero when violations remain
//!
//! Common options: --seed (default 42), --scale quick|full (or BOBA_SCALE),
//! --heavy false (or BOBA_HEAVY=0) to skip Gorder/RCM in figure drivers.
//! Worker threads: --threads N (repro) or the BOBA_THREADS env var.

use anyhow::Context;
use boba::convert;
use boba::coordinator::{datasets, experiments, pipeline};
use boba::graph::{io, Coo};
use boba::reorder::{self, boba::Boba};
use boba::server::{self, loadgen, ServerConfig};
use boba::util::args::Args;
use boba::util::timer::Stopwatch;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    if let Some(scale) = args.get("scale") {
        std::env::set_var("BOBA_SCALE", scale);
    }
    if let Some(h) = args.get("heavy") {
        std::env::set_var("BOBA_HEAVY", if h == "false" || h == "0" { "0" } else { "1" });
    }
    let seed: u64 = args.get_parse("seed", 42);
    match args.command.as_deref() {
        Some("datasets") => {
            println!("{}", datasets::inventory(seed));
        }
        Some("generate") => {
            let g = load_graph(args, seed)?;
            let out = args.get_or("out", "graph.mtx");
            if out.ends_with(".mtx") {
                io::write_matrix_market(&g, Path::new(&out))?;
            } else if out.ends_with(".bcoo") {
                io::bcoo::write_bcoo(&g, Path::new(&out))?;
            } else {
                io::write_edge_list(&g, Path::new(&out))?;
            }
            println!("wrote {} (n={} m={})", out, g.n(), g.m());
        }
        Some("convert-bcoo") => {
            // Explicit text → .bcoo conversion (the same binary format
            // the sidecar cache writes implicitly); later loads of the
            // output (or of the text next to it) skip parsing entirely.
            let inp = args
                .get("in")
                .context("convert-bcoo needs --in FILE (.mtx, .el, or .txt)")?;
            let out = args.get("out").map(Path::new);
            let (written, g) =
                io::convert_to_bcoo(Path::new(inp), out, args.flag("preserve-ids"))?;
            println!(
                "wrote {} (n={} m={}, {} bytes vs {} text)",
                written.display(),
                g.n(),
                g.m(),
                std::fs::metadata(&written).map(|m| m.len()).unwrap_or(0),
                std::fs::metadata(inp).map(|m| m.len()).unwrap_or(0),
            );
        }
        Some("reorder") => {
            let g = load_graph(args, seed)?.randomized(seed + 1);
            let scheme = reorder::by_name(&args.get_or("algo", "boba"), seed)?;
            let sw = Stopwatch::start();
            let perm = scheme.reorder(&g);
            let ms = sw.ms();
            let h = g.relabeled(perm.new_of_old());
            println!(
                "{}: reordered n={} m={} in {:.2} ms (NBR {:.3} -> {:.3})",
                scheme.name(),
                g.n(),
                g.m(),
                ms,
                boba::metrics::nbr_coo(&g),
                boba::metrics::nbr_coo(&h),
            );
            if let Some(out) = args.get("out") {
                io::write_matrix_market(&h, Path::new(out))?;
                println!("wrote {out}");
            }
        }
        Some("convert") => {
            let g = load_graph(args, seed)?.randomized(seed + 1);
            let sw = Stopwatch::start();
            let csr = convert::coo_to_csr(&g);
            println!("COO→CSR: n={} m={} in {:.2} ms", csr.n(), csr.m(), sw.ms());
        }
        Some("run") => {
            let g = load_graph(args, seed)?.randomized(seed + 1);
            let app = app_by_name(&args.get_or("app", "spmv"))?;
            let stage = match args.get("algo") {
                None => pipeline::ReorderStage::None,
                Some(name) => pipeline::ReorderStage::Scheme(reorder::by_name(name, seed)?),
            };
            let report = pipeline::Pipeline::new(app).run(&g, &stage);
            println!(
                "{} via {}: total {:.2} ms [{}] digest={:.6e}",
                report.app,
                report.scheme,
                report.total_ms(),
                report.stages.summary(),
                report.digest,
            );
        }
        Some("pipeline") => {
            // The full online scenario: streaming ingest + reorder +
            // convert + app, with stage timings.
            let g = load_graph(args, seed)?.randomized(seed + 1);
            let app = app_by_name(&args.get_or("app", "spmv"))?;
            let batch: usize = args.get_parse("batch", 1 << 16);
            let in_flight: usize = args.get_parse("in-flight", 4);
            let sw = Stopwatch::start();
            let (producer, stream) = pipeline::StreamingIngest::from_coo(g.clone(), batch, in_flight);
            let (assembled, batches) = stream.collect();
            producer.join().ok();
            let ingest_ms = sw.ms();
            let stage = match args.get("algo") {
                None => pipeline::ReorderStage::Scheme(Box::new(Boba::parallel())),
                Some(name) => pipeline::ReorderStage::Scheme(reorder::by_name(name, seed)?),
            };
            let report = pipeline::Pipeline::new(app).run(&assembled, &stage);
            println!(
                "pipeline: ingest {batches} batches in {:.2} ms; {} via {}: {:.2} ms [{}]",
                ingest_ms,
                report.app,
                report.scheme,
                report.total_ms(),
                report.stages.summary(),
            );
        }
        Some("serve") => {
            let cfg = server_config(args, seed);
            let srv = server::spawn(cfg.clone())?;
            println!(
                "boba serve: listening on {} ({} workers, cache {} graphs, \
                 batch {}, in-flight {})",
                srv.addr(),
                cfg.workers,
                cfg.capacity,
                cfg.batch,
                cfg.in_flight,
            );
            println!("try: curl -X POST {}/graphs -d '{{\"dataset\": \"rmat:16:16\", \"scheme\": \"boba\"}}'", srv.addr());
            srv.join();
        }
        Some("loadgen") => {
            let mut cfg = loadgen::LoadgenConfig {
                addr: args.get_or("addr", "127.0.0.1:7171"),
                conns: args.get_parse("conns", 4),
                requests: args.get_parse("requests", 400),
                dataset: args.get_or("dataset", "rmat:16:16"),
                scheme: args.get_or("scheme", "boba"),
                mix: loadgen::parse_mix(&args.get_or("mix", "spmv:7,pagerank:3"))?,
                pr_iters: args.get_parse("pr-iters", 5),
                seed,
                coalesce: args.flag("coalesce"),
                batch: args.get_parse("batch-queries", 4),
                scrape_metrics: args.flag("scrape-metrics"),
                target_qps: args.get_parse("target-qps", 0.0),
                retries: args.get_parse("retries", 0),
                backoff_ms: args.get_parse("backoff-ms", 50),
                mutate_frac: args.get_parse("mutate-frac", 0.0),
            };
            // --spawn: self-host an ephemeral server for the run (CI's
            // one-command benchmark mode).
            let spawned = if args.flag("spawn") {
                let mut scfg = server_config(args, seed);
                scfg.addr = "127.0.0.1:0".to_string();
                let srv = server::spawn(scfg)?;
                cfg.addr = srv.addr().to_string();
                Some(srv)
            } else {
                None
            };
            let doc = if args.flag("compare") {
                // Scheme comparison runs in single mode; --coalesce then
                // appends a single-vs-coalesced pricing on the reordered
                // scheme, so BENCH_serve.json carries both axes.
                let mut single_cfg = cfg.clone();
                single_cfg.coalesce = false;
                let (reordered, baseline, speedup) = loadgen::compare(&single_cfg)?;
                println!("baseline  {}", baseline.render());
                println!("reordered {}", reordered.render());
                println!(
                    "BOBA-prepared serving speedup: {speedup:.2}x queries/second \
                     ({:.0} vs {:.0} q/s)",
                    reordered.qps, baseline.qps,
                );
                let coalesced = if cfg.coalesce {
                    let co = loadgen::run(&cfg)?;
                    println!("coalesced {}", co.render());
                    let co_speedup =
                        if reordered.qps > 0.0 { co.qps / reordered.qps } else { 0.0 };
                    println!(
                        "request-coalescing speedup: {co_speedup:.2}x queries/second \
                         ({:.0} vs {:.0} q/s, batches of {})",
                        co.qps, reordered.qps, co.batch,
                    );
                    Some((co, co_speedup))
                } else {
                    None
                };
                loadgen::comparison_json(
                    &reordered,
                    &baseline,
                    speedup,
                    coalesced.as_ref().map(|(r, s)| (r, *s)),
                )
            } else if args.flag("compare-coalesced") {
                let (single, coalesced, speedup) = loadgen::compare_coalesced(&cfg)?;
                println!("single    {}", single.render());
                println!("coalesced {}", coalesced.render());
                println!("request-coalescing speedup: {speedup:.2}x queries/second");
                loadgen::batch_comparison_json(&single, &coalesced, speedup)
            } else {
                let report = loadgen::run(&cfg)?;
                println!("{}", report.render());
                report.to_json()
            };
            // --overload: append the admission-on vs unprotected sweep
            // (it provisions its own pair of ephemeral servers, so it
            // composes with any of the modes above).
            let doc = if args.flag("overload") {
                let sweep = loadgen_overload(args, &cfg, seed)?;
                match doc {
                    boba::util::Json::Obj(mut pairs) => {
                        pairs.push(("overload".to_string(), sweep));
                        boba::util::Json::Obj(pairs)
                    }
                    other => other,
                }
            } else {
                doc
            };
            // --churn: append the frozen-vs-mutating pricing (it spawns
            // its own WAL-enabled server, so it composes with any mode
            // above and never mutates the --addr target).
            let doc = if args.flag("churn") {
                let section = loadgen_churn(args, &cfg, seed)?;
                match doc {
                    boba::util::Json::Obj(mut pairs) => {
                        pairs.push(("churn".to_string(), section));
                        boba::util::Json::Obj(pairs)
                    }
                    other => other,
                }
            } else {
                doc
            };
            if let Some(path) = args.get("json") {
                std::fs::write(path, doc.render() + "\n")?;
                println!("wrote {path}");
            }
            if let Some(srv) = spawned {
                srv.shutdown();
            }
        }
        Some("repro") => repro_cmd(args, seed)?,
        Some("table1") => println!("{}", experiments::table1(seed).render()),
        Some("table3") => println!("{}", experiments::table3(seed).render()),
        Some("fig4") => println!("{}", experiments::fig4(seed).render()),
        Some("fig5") => println!("{}", experiments::fig5(seed).render()),
        Some("fig6") => println!("{}", experiments::fig6(seed).render()),
        Some("fig7") => println!("{}", experiments::fig7(seed).render()),
        Some("spmv-pjrt") => spmv_pjrt(args, seed)?,
        Some("lint") => lint_cmd(args)?,
        _ => {
            eprintln!(
                "usage: boba <datasets|generate|convert-bcoo|reorder|convert|run|pipeline|\
                 serve|loadgen|repro|table1|table3|fig4|fig5|fig6|fig7|spmv-pjrt|lint> [options]\n\
                 (see rust/src/main.rs header for options)"
            );
        }
    }
    Ok(())
}

/// The `repro` subcommand: run the paper-reproduction harness and write
/// `BENCH_repro.json` + `docs/RESULTS.md`.
fn repro_cmd(args: &Args, seed: u64) -> anyhow::Result<()> {
    use boba::coordinator::repro;
    let quick = if args.flag("full") {
        false
    } else if args.flag("quick") {
        true
    } else {
        datasets::Scale::from_env() == datasets::Scale::Quick
    };
    let mut opts =
        if quick { repro::ReproOptions::quick(seed) } else { repro::ReproOptions::full(seed) };
    if let Some(t) = args.get("tables") {
        opts.tables = repro::parse_tables(t)?;
    }
    // --heavy true/false overrides the scale default (BOBA_HEAVY was
    // already folded into the env by dispatch()); a bare `--heavy` flag
    // opts in.
    if args.get("heavy").is_some() {
        opts.heavy = experiments::include_heavy();
    } else if args.flag("heavy") {
        opts.heavy = true;
    }
    if let Some(t) = args.get("threads") {
        let n: usize = t.parse().context("--threads must be a positive integer")?;
        // 0 would clear the override (ThreadGuard semantics) and
        // silently fall back to the machine default — reject it.
        anyhow::ensure!(n > 0, "--threads must be a positive integer, got 0");
        opts.threads = Some(n);
    }
    if let Some(specs) = args.get("datasets") {
        opts.dataset_specs =
            specs.split(',').filter(|s| !s.is_empty()).map(|s| s.trim().to_string()).collect();
    }
    opts.reps = args.get_parse("reps", opts.reps);
    opts.pr_iters = args.get_parse("pr-iters", opts.pr_iters);

    let run = repro::run(&opts)?;
    println!("{}", run.console);

    let json_path = args.get_or("json", &default_output("BENCH_repro.json"));
    std::fs::write(&json_path, run.doc.to_json().render() + "\n")
        .with_context(|| format!("writing {json_path}"))?;
    let md_path = args.get_or("md", &default_output("docs/RESULTS.md"));
    if let Some(parent) = Path::new(&md_path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(&md_path, run.doc.render_markdown())
        .with_context(|| format!("writing {md_path}"))?;
    println!(
        "repro: {} records across {:?} (schemes: {:?}, threads {}) -> {json_path}, {md_path}",
        run.doc.records.len(),
        run.doc.tables(),
        run.doc.schemes(),
        run.doc.threads,
    );
    Ok(())
}

/// Default output path for repro artifacts: repo-root-relative when the
/// CLI is invoked from `rust/` (the `cargo run` working directory), else
/// CWD-relative.
fn default_output(name: &str) -> String {
    if !Path::new("ROADMAP.md").exists() && Path::new("../ROADMAP.md").exists() {
        format!("../{name}")
    } else {
        name.to_string()
    }
}

/// Shared `serve`/`loadgen --spawn` server configuration from flags.
fn server_config(args: &Args, seed: u64) -> ServerConfig {
    let default = ServerConfig::default();
    ServerConfig {
        addr: args.get_or("addr", &default.addr),
        workers: args.get_parse("workers", default.workers),
        capacity: args.get_parse("cache", default.capacity),
        batch: args.get_parse("batch", default.batch),
        in_flight: args.get_parse("in-flight", default.in_flight),
        seed,
        read_timeout: default.read_timeout,
        batch_window_us: args.get_parse("batch-window-us", default.batch_window_us),
        max_batch: args.get_parse("max-batch", default.max_batch),
        trace: !args.flag("no-trace"),
        slow_trace_ms: args.get("slow-trace-ms").and_then(|v| v.parse().ok()),
        format: args.get("format").map(|v| v.to_string()),
        rate: args.get_parse("rate", default.rate),
        burst: args.get_parse("burst", default.burst),
        max_inflight: args.get_parse("max-inflight", default.max_inflight),
        default_deadline_ms: args.get("default-deadline-ms").and_then(|v| v.parse().ok()),
        wal_dir: args.get("wal-dir").map(std::path::PathBuf::from),
        compact_threshold: args.get_parse("compact-threshold", default.compact_threshold),
    }
}

/// The `loadgen --overload` sweep: measure unloaded latency and
/// closed-loop capacity against an admission-enabled server, then drive
/// the same mix open-loop at 2× capacity against that server and
/// against an unprotected twin. Both servers are ephemeral — the sweep
/// never touches the `--addr` target.
fn loadgen_overload(
    args: &Args,
    cfg: &loadgen::LoadgenConfig,
    seed: u64,
) -> anyhow::Result<boba::util::Json> {
    // Admission-enabled server from the serve flags, defaulting the
    // protections ON where the flags left them unconfigured (a sweep
    // against an unprotected "protected" server prices nothing).
    let mut scfg = server_config(args, seed);
    scfg.addr = "127.0.0.1:0".to_string();
    if scfg.max_inflight == 0 {
        scfg.max_inflight = scfg.workers.max(2);
    }
    if scfg.default_deadline_ms.is_none() {
        scfg.default_deadline_ms = Some(2_000);
    }
    let protected = server::spawn(scfg.clone())?;

    // Unloaded reference: one closed-loop connection, small sample.
    let mut unloaded_cfg = cfg.clone();
    unloaded_cfg.addr = protected.addr().to_string();
    unloaded_cfg.target_qps = 0.0;
    unloaded_cfg.conns = 1;
    unloaded_cfg.requests = cfg.requests.clamp(20, 100);
    let unloaded = loadgen::run(&unloaded_cfg)?;

    // Closed-loop capacity with the full connection count (the cached
    // artifact, so this measures query service, not preparation).
    let mut cap_cfg = cfg.clone();
    cap_cfg.addr = protected.addr().to_string();
    cap_cfg.target_qps = 0.0;
    let capacity = loadgen::run(&cap_cfg)?;
    let target =
        if cfg.target_qps > 0.0 { cfg.target_qps } else { (capacity.qps * 2.0).max(1.0) };

    // 2× overload against the protected server…
    let mut over_cfg = cap_cfg.clone();
    over_cfg.target_qps = target;
    if over_cfg.retries == 0 {
        over_cfg.retries = 2; // exercise the Retry-After-honoring backoff
    }
    let admission = loadgen::run(&over_cfg)?;
    protected.shutdown();

    // …and the same overload against an unprotected twin.
    let mut base_scfg = scfg;
    base_scfg.rate = 0.0;
    base_scfg.burst = 0.0;
    base_scfg.max_inflight = 0;
    base_scfg.default_deadline_ms = None;
    let unprotected = server::spawn(base_scfg)?;
    let mut base_cfg = over_cfg.clone();
    base_cfg.addr = unprotected.addr().to_string();
    let no_admission = loadgen::run(&base_cfg)?;
    unprotected.shutdown();

    println!("unloaded     {}", unloaded.render());
    println!("capacity     {}", capacity.render());
    println!("admission    {}", admission.render());
    println!("no-admission {}", no_admission.render());
    let vs = |p99: f64| if unloaded.p99_ms > 0.0 { p99 / unloaded.p99_ms } else { 0.0 };
    println!(
        "overload @ {target:.0} q/s offered: admission p99 {:.3} ms ({:.2}x unloaded) vs \
         unprotected p99 {:.3} ms ({:.2}x); goodput {:.0} vs {:.0} q/s",
        admission.p99_ms,
        vs(admission.p99_ms),
        no_admission.p99_ms,
        vs(no_admission.p99_ms),
        admission.qps,
        no_admission.qps,
    );
    Ok(loadgen::overload_comparison_json(&unloaded, &capacity, &admission, &no_admission, target))
}

/// The `loadgen --churn` sweep: run the same workload read-only and
/// with `--mutate-frac` (default 0.2) of request slots sent as durable
/// mutations, against an ephemeral WAL-enabled server, and price what
/// churn costs the co-resident queries (p50/p99/goodput ratios plus
/// the server's mutation/compaction counters).
fn loadgen_churn(
    args: &Args,
    cfg: &loadgen::LoadgenConfig,
    seed: u64,
) -> anyhow::Result<boba::util::Json> {
    let mut scfg = server_config(args, seed);
    scfg.addr = "127.0.0.1:0".to_string();
    let scratch = scfg.wal_dir.is_none();
    let wal_dir = scfg.wal_dir.clone().unwrap_or_else(|| {
        std::env::temp_dir().join(format!("boba-churn-wal-{}", std::process::id()))
    });
    if scratch {
        let _ = std::fs::remove_dir_all(&wal_dir);
    }
    std::fs::create_dir_all(&wal_dir)
        .with_context(|| format!("creating {}", wal_dir.display()))?;
    scfg.wal_dir = Some(wal_dir.clone());
    if args.get("compact-threshold").is_none() {
        // Low enough that a modest run triggers at least one background
        // BOBA re-run — the amortization claim needs compactions to
        // actually happen while queries flow.
        scfg.compact_threshold = 512;
    }
    let srv = server::spawn(scfg)?;
    let mut ccfg = cfg.clone();
    ccfg.addr = srv.addr().to_string();
    let (frozen, mutating, section) = loadgen::churn(&ccfg)?;
    println!("frozen   {}", frozen.render());
    println!("mutating {}", mutating.render());
    println!(
        "churn @ mutate-frac {:.2}: goodput {:.0} vs {:.0} q/s ({:.2}x), \
         p99 {:.3} vs {:.3} ms",
        mutating.mutate_frac,
        mutating.qps,
        frozen.qps,
        if frozen.qps > 0.0 { mutating.qps / frozen.qps } else { 0.0 },
        mutating.p99_ms,
        frozen.p99_ms,
    );
    srv.shutdown();
    if scratch {
        std::fs::remove_dir_all(&wal_dir).ok();
    }
    Ok(section)
}

/// The `lint` subcommand: load the tree (from `--root`, or by walking
/// up to the repo root), run every rule, and report. Violations exit
/// nonzero so CI can require the stage.
fn lint_cmd(args: &Args) -> anyhow::Result<()> {
    use boba::analysis;
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => {
            let cwd = std::env::current_dir().context("reading the working directory")?;
            analysis::find_root(&cwd)
                .context("not inside the repo (no ancestor with ROADMAP.md + rust/src) — pass --root DIR")?
        }
    };
    let input = analysis::load_tree(&root)
        .with_context(|| format!("loading the tree under {}", root.display()))?;
    let violations = analysis::lint(&input);
    if args.flag("json") {
        println!("{}", analysis::render_json(&violations));
    } else if violations.is_empty() {
        println!(
            "boba lint: clean ({} files, {} rules)",
            input.sources.len(),
            analysis::RULES.len(),
        );
    } else {
        print!("{}", analysis::render_table(&violations));
    }
    anyhow::ensure!(
        violations.is_empty(),
        "{} lint violation(s) — annotate with `// lint: allow(<rule>): <reason>` \
         only where the invariant genuinely does not apply",
        violations.len(),
    );
    Ok(())
}

/// Load a graph from `--in FILE` or build `--dataset NAME` (default
/// pa_c8). Dataset specs share their vocabulary with the server's
/// registry (`datasets::resolve`). Files go through the parallel
/// byte-level readers with the `.bcoo` sidecar cache
/// (`io::load_graph_file`); pass `--preserve-ids` to keep sparse
/// edge-list IDs instead of dense first-appearance relabeling.
fn load_graph(args: &Args, seed: u64) -> anyhow::Result<Coo> {
    if let Some(path) = args.get("in") {
        return io::load_graph_file(Path::new(path), args.flag("preserve-ids"));
    }
    match args.get("dataset") {
        Some(name) => datasets::resolve(name, seed),
        None => Ok(datasets::by_name("pa_c8").unwrap().build(seed)),
    }
}

fn app_by_name(name: &str) -> anyhow::Result<pipeline::App> {
    Ok(match name.to_lowercase().as_str() {
        "spmv" => pipeline::App::Spmv,
        "pr" | "pagerank" => pipeline::App::PageRank,
        "tc" => pipeline::App::Tc,
        "sssp" => pipeline::App::Sssp,
        other => anyhow::bail!("unknown app {other}"),
    })
}

/// SpMV through the AOT PJRT artifacts (build with `--features pjrt`).
#[cfg(feature = "pjrt")]
fn spmv_pjrt(args: &Args, seed: u64) -> anyhow::Result<()> {
    use boba::algos::spmv;
    use boba::runtime::{Engine, SpmvKind};
    let g = load_graph(args, seed)?.randomized(seed + 1);
    let csr = convert::coo_to_csr(&g);
    let engine = Engine::load_default()?;
    let kind = if args.flag("pallas") { SpmvKind::Pallas } else { SpmvKind::Jnp };
    let x = vec![1.0f32; csr.n()];
    let sw = Stopwatch::start();
    let y = engine.spmv_csr(kind, &csr, &x)?;
    let pjrt_ms = sw.ms();
    let y_native = spmv::spmv_pull(&csr, &x);
    let max_diff = y
        .iter()
        .zip(&y_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!(
        "PJRT SpMV ({kind:?}) on {}: n={} m={} in {:.2} ms; max |Δ| vs native = {max_diff:e}",
        engine.platform(),
        csr.n(),
        csr.m(),
        pjrt_ms,
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn spmv_pjrt(_args: &Args, _seed: u64) -> anyhow::Result<()> {
    anyhow::bail!(
        "this binary was built without the `pjrt` feature; rebuild with \
         `cargo build --features pjrt` (requires the xla crate, see Cargo.toml)"
    )
}
