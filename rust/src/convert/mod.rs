//! COO → CSR conversion — the pipeline stage the paper's Problem 3 is
//! built around.
//!
//! Conversion is a counting sort: (1) histogram source IDs, (2) prefix-sum
//! into row offsets, (3) scatter columns. Passes (1) and (3) index the
//! count/cursor arrays by *source vertex ID*; with randomized labels those
//! accesses are uniformly random over an `n`-sized array (cache-hostile),
//! while after BOBA the labels of edge-adjacent sources cluster, so
//! consecutive edges hit nearby counters — this is the paper's §5.3
//! explanation for the conversion-time speedup (1.3–5.1×), and the effect
//! reproduces directly on CPU caches.
//!
//! The parallel converters ([`coo_to_csr_parallel`],
//! [`coo_to_csr_relabeled_parallel`]) are **deterministic**: private
//! per-worker histograms + a two-level prefix sum + exact starting
//! cursors make their output bit-identical to the sequential kernels at
//! every thread count, so sorted inputs stay sorted and digests compare
//! across `--threads` settings ([`coo_to_csr_parallel_atomic`] is the
//! old atomic-scatter baseline, kept for the microbenches).
//!
//! ```
//! use boba::convert::coo_to_csr;
//! use boba::graph::Coo;
//!
//! let coo = Coo::new(3, vec![0, 1, 2, 0], vec![1, 2, 0, 2]);
//! let csr = coo_to_csr(&coo);
//! assert_eq!(csr.neighbors(0), &[1, 2]); // stable: COO edge order kept
//! assert_eq!(csr.neighbors(2), &[0]);
//! assert_eq!(csr.m(), 4);
//! ```

use crate::graph::{Coo, Csr};
use crate::parallel;
use std::sync::atomic::{AtomicU64, Ordering};

/// Software-prefetch lookahead (edges) for the counter/cursor accesses.
/// Tuned on the 1-core testbed: 1251 → 912 ms (-27%) converting a
/// randomized 64M-edge PA graph; neutral on BOBA-ordered inputs whose
/// counter accesses already cluster. See docs/EXPERIMENTS.md §Perf.
const PF_DIST: usize = 32;

#[inline(always)]
fn prefetch<T>(arr: &[T], idx: usize) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: _mm_prefetch is a non-faulting hint — the address is
    // never dereferenced, so even an out-of-range `idx` (callers pass
    // in-bounds ids) could not fault; `add` on a one-past-the-end
    // pointer is the worst case and is only computed, never read.
    unsafe {
        core::arch::x86_64::_mm_prefetch(
            arr.as_ptr().add(idx) as *const i8,
            core::arch::x86_64::_MM_HINT_T0,
        );
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (arr, idx);
    }
}

/// Sequential COO→CSR (counting sort). Preserves the relative order of
/// each vertex's edges (stable scatter).
pub fn coo_to_csr(coo: &Coo) -> Csr {
    let n = coo.n();
    let m = coo.m();
    let src = &coo.src;
    // (1) histogram
    let mut row_ptr = vec![0u64; n + 1];
    for e in 0..m {
        if e + PF_DIST < m {
            prefetch(&row_ptr, src[e + PF_DIST] as usize + 1);
        }
        row_ptr[src[e] as usize + 1] += 1;
    }
    // (2) prefix sum
    for i in 0..n {
        row_ptr[i + 1] += row_ptr[i];
    }
    // (3) stable scatter
    let mut cursor = row_ptr.clone();
    let mut col_idx = vec![0u32; m];
    let mut vals = coo.vals.as_ref().map(|_| vec![0f32; m]);
    for e in 0..m {
        if e + PF_DIST < m {
            prefetch(&cursor, src[e + PF_DIST] as usize);
        }
        let s = src[e] as usize;
        let pos = cursor[s] as usize;
        cursor[s] += 1;
        col_idx[pos] = coo.dst[e];
        if let (Some(out), Some(v)) = (vals.as_mut(), coo.vals.as_ref()) {
            out[pos] = v[e];
        }
    }
    Csr { row_ptr, col_idx, vals }
}

/// Parallel COO→CSR, **bit-identical to [`coo_to_csr`] at every thread
/// count**: the classic deterministic counting sort with per-worker
/// private histograms and exact per-worker starting cursors (Koohi
/// Esfahani & Vandierendonck's recipe for graph transposition).
///
/// Edges are split into one contiguous range per partition; each
/// partition histograms privately, a two-level prefix sum (per-partition
/// × per-vertex-block, both levels parallel) turns the histograms into
/// exact starting cursors, and a race-free stable scatter follows. A
/// vertex's row is filled partition-by-partition in edge order, so the
/// output equals the sequential stable scatter exactly — no atomics, no
/// [`Csr::sort_rows`] compensation downstream. The number of partitions
/// does not affect the output, only the schedule.
pub fn coo_to_csr_parallel(coo: &Coo) -> Csr {
    if coo.m() < (1 << 15) || parallel::threads() == 1 {
        return coo_to_csr(coo); // not worth the extra passes
    }
    if coo.m() >= u32::MAX as usize {
        // Beyond the parallel skeleton's u32 counters; the sequential
        // kernel handles any m and needs no mapped copy here.
        return coo_to_csr(coo);
    }
    parallel_counting_sort(coo, |v| v)
}

/// The pre-pool parallel converter: atomic histogram + sequential prefix
/// sum + atomic fetch-add scatter. Row contents come out in a
/// nondeterministic order *within* each row (like the GPU implementations
/// the paper measures), so callers need [`Csr::sort_rows`] or a sorted
/// COO to compare outputs. Retained as the microbenchmark baseline for
/// the deterministic kernel ([`coo_to_csr_parallel`]) — see
/// docs/EXPERIMENTS.md §Conversion and `benches/micro_convert.rs`; new
/// code should not call this.
pub fn coo_to_csr_parallel_atomic(coo: &Coo) -> Csr {
    let n = coo.n();
    let m = coo.m();
    if m < 1 << 15 {
        return coo_to_csr(coo); // not worth the atomics
    }
    // (1) atomic histogram over edge chunks.
    let counts: Vec<AtomicU64> = (0..n + 1).map(|_| AtomicU64::new(0)).collect();
    let chunk = parallel::default_chunk(m);
    parallel::par_for_chunks(m, chunk, |lo, hi| {
        for e in lo..hi {
            counts[coo.src[e] as usize + 1].fetch_add(1, Ordering::Relaxed);
        }
    });
    // (2) prefix sum (sequential; n ≪ m). The histogram counted vertex v
    // at slot v+1, so the inclusive running sum over counts[0..=i] is
    // already the *exclusive* start of row i (edges with src < i) —
    // row_ptr[0] = counts[0] = 0, no shift needed.
    let mut row_ptr = vec![0u64; n + 1];
    let mut acc = 0u64;
    for i in 0..=n {
        acc += counts[i].load(Ordering::Relaxed);
        row_ptr[i] = acc;
    }
    // (3) scatter with atomic cursors.
    let cursor: Vec<AtomicU64> =
        row_ptr[..n].iter().map(|&v| AtomicU64::new(v)).collect();
    let mut col_idx = vec![0u32; m];
    let mut vals = coo.vals.as_ref().map(|_| vec![0f32; m]);
    {
        let col_ptr = parallel::SendPtr(col_idx.as_mut_ptr());
        let val_ptr = vals.as_mut().map(|v| parallel::SendPtr(v.as_mut_ptr()));
        parallel::par_for_chunks(m, chunk, |lo, hi| {
            for e in lo..hi {
                let s = coo.src[e] as usize;
                let pos = cursor[s].fetch_add(1, Ordering::Relaxed) as usize;
                // SAFETY: fetch_add hands out each position exactly once.
                unsafe {
                    *col_ptr.get().add(pos) = coo.dst[e];
                    if let (Some(vp), Some(v)) = (val_ptr, coo.vals.as_ref()) {
                        *vp.get().add(pos) = v[e];
                    }
                }
            }
        });
    }
    Csr { row_ptr, col_idx, vals }
}

/// Shared skeleton of the deterministic parallel converters: counting
/// sort of `map(src[e])` with a stable scatter of `map(dst[e])`, where
/// `map` is the identity ([`coo_to_csr_parallel`]) or an old→new label
/// table ([`coo_to_csr_relabeled_parallel`]).
///
/// Layout: `counts` is `p` private per-vertex histograms (u32, flat
/// `p × n`); the two-level prefix sum rewrites them in place into
/// *vertex-block-local* exclusive offsets, with one `u64` base per
/// vertex block carrying the global part — that keeps the table at
/// 4 bytes/counter while staying correct past 4 G total edges.
fn parallel_counting_sort<Map>(coo: &Coo, map: Map) -> Csr
where
    Map: Fn(u32) -> u32 + Sync,
{
    let n = coo.n();
    let m = coo.m();
    debug_assert!(n > 0 && m > 0);
    // Per-partition counters are u32: a single partition never holds
    // ≥ 4G edges. Only the relabeled entry point can still get here at
    // that scale (the identity path pre-filters); materialize the
    // relabeling — real work there — and convert sequentially.
    if m >= u32::MAX as usize {
        let relabeled = Coo {
            n,
            src: coo.src.iter().map(|&v| map(v)).collect(),
            dst: coo.dst.iter().map(|&v| map(v)).collect(),
            vals: coo.vals.clone(),
        };
        return coo_to_csr(&relabeled);
    }
    // Fixed contiguous edge range per partition. The partition count is
    // free to differ between runs (it never changes the output), so it
    // tracks the current worker pin, then shrinks until the private
    // counter table (p × n × 4 bytes) stays within ~2× the edge arrays
    // — high-degree graphs keep full parallelism, hypersparse ones trade
    // workers for memory.
    let mut p = parallel::threads().clamp(1, 64).min(m);
    while p > 1 && p * n > 4 * m {
        p /= 2;
    }
    let per = m.div_ceil(p);
    let map = &map;

    // ── (1) private histograms, one partition per worker ─────────────
    let mut counts = vec![0u32; p * n];
    {
        let counts_ptr = parallel::SendPtr(counts.as_mut_ptr());
        parallel::par_for_chunks(p, 1, |plo, phi| {
            for r in plo..phi {
                let (elo, ehi) = ((r * per).min(m), ((r + 1) * per).min(m));
                // SAFETY: partition r exclusively owns counts[r*n..(r+1)*n].
                let hist = unsafe {
                    std::slice::from_raw_parts_mut(counts_ptr.get().add(r * n), n)
                };
                for e in elo..ehi {
                    if e + PF_DIST < ehi {
                        prefetch(hist, map(coo.src[e + PF_DIST]) as usize);
                    }
                    hist[map(coo.src[e]) as usize] += 1;
                }
            }
        });
    }

    // ── (2) two-level prefix sum ─────────────────────────────────────
    // Level 1 (parallel over vertex blocks): within each block, walk
    // vertices × partitions in (vertex, partition) order, replacing each
    // count with the running block-local offset; record the row start in
    // row_ptr and the block total.
    let block = n.div_ceil(p * 4).next_power_of_two().max(1024);
    let shift = block.trailing_zeros();
    let nblocks = n.div_ceil(block);
    let mut row_ptr = vec![0u64; n + 1];
    let mut block_sums = vec![0u64; nblocks];
    {
        let counts_ptr = parallel::SendPtr(counts.as_mut_ptr());
        let row_ptr_ptr = parallel::SendPtr(row_ptr.as_mut_ptr());
        let sums_ptr = parallel::SendPtr(block_sums.as_mut_ptr());
        parallel::par_for_chunks(nblocks, 1, |blo, bhi| {
            for b in blo..bhi {
                let (vlo, vhi) = (b * block, ((b + 1) * block).min(n));
                let mut acc = 0u64;
                for v in vlo..vhi {
                    // SAFETY: vertex v belongs to exactly one block, and
                    // blocks are disjoint across chunk iterations.
                    unsafe { *row_ptr_ptr.get().add(v) = acc };
                    for r in 0..p {
                        // SAFETY: slot (r, v) is visited once — v is
                        // owned by this block and r iterates each
                        // partition's private counter row exactly once.
                        let slot = unsafe { &mut *counts_ptr.get().add(r * n + v) };
                        let c = *slot;
                        // Block totals are < m < 4G, so the offset fits.
                        *slot = acc as u32;
                        acc += c as u64;
                    }
                }
                // SAFETY: block b is owned by exactly one chunk iteration.
                unsafe { *sums_ptr.get().add(b) = acc };
            }
        });
    }
    // Level 2 (sequential; nblocks is small): exclusive prefix over the
    // block totals gives each block's global base.
    let mut base = vec![0u64; nblocks];
    let mut acc = 0u64;
    for (slot, total) in base.iter_mut().zip(&block_sums) {
        *slot = acc;
        acc += *total;
    }
    debug_assert_eq!(acc, m as u64);
    // Fold the bases into the row starts (parallel over blocks).
    {
        let row_ptr_ptr = parallel::SendPtr(row_ptr.as_mut_ptr());
        let base_ref = &base;
        parallel::par_for_chunks(nblocks, 1, |blo, bhi| {
            for b in blo..bhi {
                let (vlo, vhi) = (b * block, ((b + 1) * block).min(n));
                for v in vlo..vhi {
                    // SAFETY: blocks are disjoint.
                    unsafe { *row_ptr_ptr.get().add(v) += base_ref[b] };
                }
            }
        });
    }
    row_ptr[n] = m as u64;

    // ── (3) race-free stable scatter, same partition ranges ──────────
    // Partition r's cursor for vertex v starts at exactly the slot after
    // every earlier partition's v-edges, so writes are disjoint and each
    // row comes out in global edge order — the sequential output.
    let mut col_idx = vec![0u32; m];
    let mut vals = coo.vals.as_ref().map(|_| vec![0f32; m]);
    {
        let counts_ptr = parallel::SendPtr(counts.as_mut_ptr());
        let col_ptr = parallel::SendPtr(col_idx.as_mut_ptr());
        let val_ptr = vals.as_mut().map(|v| parallel::SendPtr(v.as_mut_ptr()));
        let base_ref = &base;
        parallel::par_for_chunks(p, 1, |plo, phi| {
            for r in plo..phi {
                let (elo, ehi) = ((r * per).min(m), ((r + 1) * per).min(m));
                // SAFETY: partition r exclusively owns its cursor row.
                let cursors = unsafe {
                    std::slice::from_raw_parts_mut(counts_ptr.get().add(r * n), n)
                };
                for e in elo..ehi {
                    if e + PF_DIST < ehi {
                        prefetch(cursors, map(coo.src[e + PF_DIST]) as usize);
                    }
                    let s = map(coo.src[e]) as usize;
                    let pos = (base_ref[s >> shift] + cursors[s] as u64) as usize;
                    cursors[s] += 1;
                    // SAFETY: exact starting cursors make every pos unique
                    // across partitions and edges.
                    unsafe {
                        *col_ptr.get().add(pos) = map(coo.dst[e]);
                        if let (Some(vp), Some(v)) = (val_ptr, coo.vals.as_ref()) {
                            *vp.get().add(pos) = v[e];
                        }
                    }
                }
            }
        });
    }
    Csr { row_ptr, col_idx, vals }
}

/// Fused relabel + COO→CSR (sequential): builds the CSR of
/// `coo.relabeled(new_of_old)` without materializing the intermediate
/// COO. [`coo_to_csr_relabeled_parallel`] is the multi-worker variant
/// with bit-identical output.
///
/// §Perf: the reordered pipeline's two stages (relabel: 2m gathers + 2m
/// writes; convert: 2m reads + m writes) share most of their memory
/// traffic — fusing them skips one full write+read of the edge list
/// (~2×8m bytes), a ~35% end-to-end reduction for the BOBA→CSR path on
/// the 1-core testbed (docs/EXPERIMENTS.md §Perf). Output is identical
/// to `coo_to_csr(&coo.relabeled(new_of_old))`.
pub fn coo_to_csr_relabeled(coo: &Coo, new_of_old: &[u32]) -> Csr {
    assert_eq!(new_of_old.len(), coo.n());
    let n = coo.n();
    let m = coo.m();
    let mut row_ptr = vec![0u64; n + 1];
    for &s in &coo.src {
        row_ptr[new_of_old[s as usize] as usize + 1] += 1;
    }
    for i in 0..n {
        row_ptr[i + 1] += row_ptr[i];
    }
    let mut cursor = row_ptr.clone();
    let mut col_idx = vec![0u32; m];
    let mut vals = coo.vals.as_ref().map(|_| vec![0f32; m]);
    for e in 0..m {
        let s = new_of_old[coo.src[e] as usize] as usize;
        let pos = cursor[s] as usize;
        cursor[s] += 1;
        col_idx[pos] = new_of_old[coo.dst[e] as usize];
        if let (Some(out), Some(v)) = (vals.as_mut(), coo.vals.as_ref()) {
            out[pos] = v[e];
        }
    }
    Csr { row_ptr, col_idx, vals }
}

/// Parallel fused relabel + COO→CSR on the same deterministic
/// counting-sort skeleton as [`coo_to_csr_parallel`] (the label table
/// becomes the vertex map): bit-identical to [`coo_to_csr_relabeled`] —
/// and therefore to `coo_to_csr(&coo.relabeled(new_of_old))` — at every
/// thread count.
pub fn coo_to_csr_relabeled_parallel(coo: &Coo, new_of_old: &[u32]) -> Csr {
    assert_eq!(new_of_old.len(), coo.n());
    if coo.m() < (1 << 15) || parallel::threads() == 1 {
        return coo_to_csr_relabeled(coo, new_of_old);
    }
    parallel_counting_sort(coo, |v| new_of_old[v as usize])
}

/// CSR → COO (row-major edge order).
pub fn csr_to_coo(csr: &Csr) -> Coo {
    let n = csr.n();
    let mut src = Vec::with_capacity(csr.m());
    let mut dst = Vec::with_capacity(csr.m());
    for v in 0..n {
        for &c in csr.neighbors(v) {
            src.push(v as u32);
            dst.push(c);
        }
    }
    let mut coo = Coo::new(n, src, dst);
    coo.vals = csr.vals.clone();
    coo
}

/// Sort a COO by `(src, dst)` with a two-pass radix over the key — the
/// expensive pre-pass Table 4 ("sorting delaunay_24 is 10.5–13× slower
/// than converting") charges to the TC pipeline. Cache behaviour is
/// label-dependent, so BOBA speeds this up slightly too (§5.3: 1.045–1.54×).
pub fn sort_coo_by_src(coo: &Coo) -> Coo {
    // LSD radix sort on dst then src (stable), u32 keys, 2×16-bit digits
    // per key — 4 passes total, all linear.
    let m = coo.m();
    let mut idx: Vec<u32> = (0..m as u32).collect();
    let mut tmp = vec![0u32; m];
    let radix_pass = |idx: &mut Vec<u32>, tmp: &mut Vec<u32>, key: &dyn Fn(u32) -> u32| {
        let mut hist = vec![0u32; 1 << 16];
        for &i in idx.iter() {
            hist[key(i) as usize] += 1;
        }
        let mut acc = 0u32;
        for h in hist.iter_mut() {
            let c = *h;
            *h = acc;
            acc += c;
        }
        for &i in idx.iter() {
            let k = key(i) as usize;
            tmp[hist[k] as usize] = i;
            hist[k] += 1;
        }
        std::mem::swap(idx, tmp);
    };
    let dst = &coo.dst;
    let src = &coo.src;
    radix_pass(&mut idx, &mut tmp, &|i| dst[i as usize] & 0xFFFF);
    radix_pass(&mut idx, &mut tmp, &|i| dst[i as usize] >> 16);
    radix_pass(&mut idx, &mut tmp, &|i| src[i as usize] & 0xFFFF);
    radix_pass(&mut idx, &mut tmp, &|i| src[i as usize] >> 16);
    // Gather directly through the u32 ranks — no widened Vec<usize> copy
    // (8 bytes/edge) just to fit the gather's index type.
    coo.gathered_u32(&idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{self, GenParams};

    #[test]
    fn seq_conversion_tiny() {
        let coo = Coo::new(3, vec![0, 1, 2, 0], vec![1, 2, 0, 2]);
        let csr = coo_to_csr(&coo);
        csr.validate().unwrap();
        assert_eq!(csr.neighbors(0), &[1, 2]);
        assert_eq!(csr.neighbors(1), &[2]);
        assert_eq!(csr.neighbors(2), &[0]);
    }

    #[test]
    fn seq_conversion_stable() {
        // Vertex 0's edges must keep COO order.
        let coo = Coo::new(4, vec![0, 0, 0], vec![3, 1, 2]);
        let csr = coo_to_csr(&coo);
        assert_eq!(csr.neighbors(0), &[3, 1, 2]);
    }

    #[test]
    fn weighted_conversion_pairs_vals() {
        let coo = Coo::with_vals(2, vec![1, 0, 1], vec![0, 1, 1], vec![3.0, 1.0, 2.0]);
        let csr = coo_to_csr(&coo);
        assert_eq!(csr.neighbors(1), &[0, 1]);
        assert_eq!(csr.row_vals(1).unwrap(), &[3.0, 2.0]);
        assert_eq!(csr.row_vals(0).unwrap(), &[1.0]);
    }

    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        let g = gen::rmat(&GenParams::rmat(12, 16), 77);
        let a = coo_to_csr(&g);
        let b = coo_to_csr_parallel(&g);
        // The determinism contract: no sort_rows compensation, plain
        // equality of every array.
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_atomic_matches_up_to_row_order() {
        let g = gen::rmat(&GenParams::rmat(12, 16), 77);
        let a = coo_to_csr(&g);
        let mut b = coo_to_csr_parallel_atomic(&g);
        assert_eq!(a.row_ptr, b.row_ptr);
        // The retained baseline is only multiset-equal per row.
        let mut a_sorted = a.clone();
        a_sorted.sort_rows();
        b.sort_rows();
        assert_eq!(a_sorted.col_idx, b.col_idx);
    }

    #[test]
    fn relabeled_parallel_is_bit_identical_to_fused() {
        use crate::reorder::{boba::Boba, Reorderer};
        let g = gen::rmat(&GenParams::rmat(12, 16), 13).randomized(5);
        let p = Boba::sequential().reorder(&g);
        let seq = coo_to_csr_relabeled(&g, p.new_of_old());
        let par = coo_to_csr_relabeled_parallel(&g, p.new_of_old());
        assert_eq!(seq, par);
    }

    #[test]
    fn parallel_small_falls_back() {
        let coo = Coo::new(3, vec![0, 1], vec![1, 2]);
        let csr = coo_to_csr_parallel(&coo);
        assert_eq!(csr.neighbors(0), &[1]);
    }

    #[test]
    fn fused_relabel_convert_matches_two_stage() {
        use crate::reorder::{boba::Boba, Reorderer};
        let g = gen::rmat(&GenParams::rmat(11, 8), 9).randomized(4);
        let p = Boba::sequential().reorder(&g);
        let two_stage = coo_to_csr(&g.relabeled(p.new_of_old()));
        let fused = coo_to_csr_relabeled(&g, p.new_of_old());
        assert_eq!(two_stage, fused);
    }

    #[test]
    fn fused_relabel_convert_weighted() {
        let g = Coo::with_vals(3, vec![0, 1, 2], vec![1, 2, 0], vec![1.0, 2.0, 3.0]);
        let perm = vec![2u32, 0, 1];
        let two_stage = coo_to_csr(&g.relabeled(&perm));
        let fused = coo_to_csr_relabeled(&g, &perm);
        assert_eq!(two_stage, fused);
    }

    #[test]
    fn csr_coo_roundtrip() {
        let g = gen::uniform_random(100, 500, 3);
        let csr = coo_to_csr(&g);
        let back = csr_to_coo(&csr);
        let csr2 = coo_to_csr(&back);
        assert_eq!(csr, csr2);
    }

    #[test]
    fn radix_sort_sorts() {
        let g = gen::uniform_random(1000, 10_000, 4);
        let s = sort_coo_by_src(&g);
        for i in 1..s.m() {
            let prev = ((s.src[i - 1] as u64) << 32) | s.dst[i - 1] as u64;
            let cur = ((s.src[i] as u64) << 32) | s.dst[i] as u64;
            assert!(prev <= cur);
        }
        // Same edge multiset.
        let mut a: Vec<_> = g.edges().collect();
        let mut b: Vec<_> = s.edges().collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn sorted_coo_gives_sorted_rows() {
        let g = gen::rmat(&GenParams::rmat(10, 8), 5);
        let csr = coo_to_csr(&sort_coo_by_src(&g));
        assert!(csr.rows_sorted());
    }
}
