//! Testbed capture — the "machine" block of every committed benchmark
//! JSON (`BENCH_repro.json`, `BENCH_serve.json`).
//!
//! Benchmark numbers without the machine they ran on are noise: the
//! paper's Table 5 fixes a V100 + dual Xeon testbed, and cross-run
//! comparisons of this repo's perf trajectory are only valid within one
//! machine class. [`MachineInfo::capture`] records what std can see
//! (OS, architecture, CPU count, worker-thread count, crate version,
//! hostname) and [`rss_peak_bytes`] adds the peak resident set from
//! `/proc/self/status` on Linux — the repro harness stores it next to
//! the timings so memory blowups show up in the trajectory too.

use crate::parallel;
use crate::util::json::Json;

/// A snapshot of the machine and process configuration a benchmark ran
/// under.
#[derive(Clone, Debug)]
pub struct MachineInfo {
    /// Host name (best-effort; "unknown" when undiscoverable).
    pub hostname: String,
    /// Operating system (`std::env::consts::OS`).
    pub os: String,
    /// CPU architecture (`std::env::consts::ARCH`).
    pub arch: String,
    /// Available hardware parallelism.
    pub cpus: usize,
    /// Worker threads the [`crate::parallel`] runtime will use (honours
    /// `BOBA_THREADS` / [`crate::parallel::set_threads`]).
    pub threads: usize,
    /// Crate version (the code the numbers belong to).
    pub version: String,
}

impl MachineInfo {
    /// Capture the current machine/process configuration.
    pub fn capture() -> Self {
        Self {
            hostname: hostname(),
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
            cpus: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0),
            threads: parallel::threads(),
            version: env!("CARGO_PKG_VERSION").to_string(),
        }
    }

    /// Render as the `machine` JSON object of a benchmark document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hostname", Json::Str(self.hostname.clone())),
            ("os", Json::Str(self.os.clone())),
            ("arch", Json::Str(self.arch.clone())),
            ("cpus", Json::Num(self.cpus as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("version", Json::Str(self.version.clone())),
        ])
    }
}

/// Best-effort host name: `HOSTNAME` env var, then
/// `/proc/sys/kernel/hostname`, then "unknown".
fn hostname() -> String {
    if let Ok(h) = std::env::var("HOSTNAME") {
        if !h.is_empty() {
            return h;
        }
    }
    if let Ok(h) = std::fs::read_to_string("/proc/sys/kernel/hostname") {
        let h = h.trim();
        if !h.is_empty() {
            return h.to_string();
        }
    }
    "unknown".to_string()
}

/// Peak resident set size (`VmHWM`) of this process in bytes, from
/// `/proc/self/status`. `None` on platforms without procfs.
pub fn rss_peak_bytes() -> Option<u64> {
    proc_status_kb("VmHWM:").map(|kb| kb * 1024)
}

/// Current resident set size (`VmRSS`) in bytes. `None` without procfs.
pub fn rss_bytes() -> Option<u64> {
    proc_status_kb("VmRSS:").map(|kb| kb * 1024)
}

/// Measured single-thread streaming-copy bandwidth in GB/s — the
/// roofline denominator of repro T5's effective-GB/s column. Copies a
/// 32 MiB `f32` buffer (far beyond any LLC) three times after a warmup
/// pass and counts read + write traffic. A plain copy, not a triad:
/// it bounds what a single core's demand stream can move, which is the
/// honest ceiling for the single-artifact SpMV it is compared against.
pub fn stream_bandwidth_gbs() -> f64 {
    const WORDS: usize = 8 << 20; // 32 MiB source + 32 MiB destination
    const REPS: u32 = 3;
    let src = vec![1.0f32; WORDS];
    let mut dst = vec![0.0f32; WORDS];
    dst.copy_from_slice(&src); // warmup: faults both buffers in
    let start = std::time::Instant::now();
    for _ in 0..REPS {
        dst.copy_from_slice(&src);
        crate::bench::black_box(&dst);
    }
    let secs = start.elapsed().as_secs_f64().max(1e-9);
    let bytes = 2.0 * (WORDS * 4) as f64 * REPS as f64;
    bytes / secs / 1e9
}

fn proc_status_kb(key: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            // Format: "VmHWM:	   12345 kB"
            let num: String =
                rest.chars().filter(|c| c.is_ascii_digit()).collect();
            return num.parse().ok();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_has_sane_fields() {
        let m = MachineInfo::capture();
        assert!(!m.os.is_empty());
        assert!(!m.arch.is_empty());
        assert!(m.threads >= 1);
        assert!(!m.version.is_empty());
    }

    #[test]
    fn to_json_roundtrips() {
        let m = MachineInfo::capture();
        let j = m.to_json();
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.get("os").unwrap().as_str(), Some(m.os.as_str()));
        assert_eq!(back.get("threads").unwrap().as_u64(), Some(m.threads as u64));
    }

    #[test]
    fn stream_bandwidth_is_positive_and_finite() {
        let gbs = stream_bandwidth_gbs();
        assert!(gbs.is_finite() && gbs > 0.0, "got {gbs}");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn rss_reads_on_linux() {
        // Both gauges exist and peak >= current (same scan, monotone).
        let peak = rss_peak_bytes().expect("VmHWM on linux");
        let cur = rss_bytes().expect("VmRSS on linux");
        assert!(peak > 0 && cur > 0);
        assert!(peak >= cur / 2, "peak {peak} vs current {cur}");
    }
}
