//! The `BENCH_repro.json` result schema and its renderers.
//!
//! The repro harness ([`crate::coordinator::repro`]) emits one
//! [`ResultsDoc`] per run: machine info, run configuration, and a flat
//! list of [`Record`]s keyed `(table, dataset, scheme, app, metric)`.
//! The schema is **stable and versioned** ([`SCHEMA`]) because the
//! committed JSON is the repo's perf trajectory — later optimization PRs
//! are judged against it, so both the emitter and a strict parser/
//! validator ([`ResultsDoc::parse`]) live here under test.
//!
//! [`ResultsDoc::render_markdown`] renders the same records as the
//! human-readable `docs/RESULTS.md`, so the committed table and the
//! committed JSON can never drift apart.

use super::machine::MachineInfo;
use super::stats::Summary;
use crate::util::human;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Schema identifier written to every document. Version 2 added the
/// T3 `ingest_ms` stage rows (the pipeline's front door is now a
/// priced stage); version 3 adds the T5 kernel-format table
/// (`bytes_per_edge`, `encode_ms`, `spmv_ms`, `effective_gbs` per
/// scheme × format, plus one `stream_gbs` roofline row).
/// [`ResultsDoc::parse`] still reads older documents — they simply
/// carry fewer tables.
pub const SCHEMA: &str = "boba-repro/3";

/// Older schema identifiers [`ResultsDoc::parse`] accepts (committed
/// trajectory points from earlier PRs stay readable).
pub const LEGACY_SCHEMAS: [&str; 2] = ["boba-repro/1", "boba-repro/2"];

/// The repro table identifiers, in report order.
pub const TABLE_IDS: [&str; 5] = ["T1", "T2", "T3", "T4", "T5"];

/// Human title for a repro table id (used by both renderers).
pub fn table_title(id: &str) -> &'static str {
    match id {
        "T1" => "T1 — reordering time per scheme",
        "T2" => "T2 — COO→CSR conversion time, pre/post reorder",
        "T3" => "T3 — end-to-end pipeline time (ingest + reorder + [sort] + convert + app) and batched SpMV (spmm k-rows)",
        "T4" => "T4 — simulated cache hit rates (V100-scaled hierarchy)",
        "T5" => "T5 — kernel formats: bytes/edge, encode + SpMV time, effective GB/s vs the measured stream roofline",
        _ => "unknown table",
    }
}

/// One measured quantity of the repro run.
#[derive(Clone, Debug)]
pub struct Record {
    /// Repro table this row belongs to ("T1".."T4").
    pub table: String,
    /// Dataset name (suite name or ad-hoc spec).
    pub dataset: String,
    /// Reordering scheme name (CLI vocabulary, plus "random" baseline).
    pub scheme: String,
    /// Application, for tables keyed by workload (T3/T4); empty
    /// otherwise.
    pub app: String,
    /// Metric name ("reorder_ms", "convert_ms", "total_ms", "l1_hit_pct",
    /// "speedup_x", ...).
    pub metric: String,
    /// Unit of the summary values ("ms", "%", "x").
    pub unit: String,
    /// Robust summary over the measured iterations.
    pub summary: Summary,
    /// Throughput (items/second — edges for reorder/convert), when the
    /// metric has a natural item count.
    pub items_per_sec: Option<f64>,
    /// Order-sensitive digest of the produced artifact — the permutation
    /// on T1 rows, the full CSR (row_ptr, col_idx, vals) on T2
    /// conversion rows; the determinism tests and the CI par-det gate
    /// compare these.
    pub digest: Option<String>,
}

impl Record {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("table", Json::Str(self.table.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("scheme", Json::Str(self.scheme.clone())),
            ("app", Json::Str(self.app.clone())),
            ("metric", Json::Str(self.metric.clone())),
            ("unit", Json::Str(self.unit.clone())),
            ("median", Json::Num(self.summary.median_ms)),
            ("mad", Json::Num(self.summary.mad_ms)),
            ("min", Json::Num(self.summary.min_ms)),
            ("max", Json::Num(self.summary.max_ms)),
            ("mean", Json::Num(self.summary.mean_ms)),
            ("iters", Json::Num(self.summary.n as f64)),
        ];
        if let Some(t) = self.items_per_sec {
            pairs.push(("items_per_sec", Json::Num(t)));
        }
        if let Some(d) = &self.digest {
            pairs.push(("digest", Json::Str(d.clone())));
        }
        Json::obj(pairs)
    }

    fn parse(j: &Json) -> Result<Record> {
        let s = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(|v| v.as_str())
                .with_context(|| format!("record missing string field {k:?}"))?
                .to_string())
        };
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("record missing numeric field {k:?}"))
        };
        Ok(Record {
            table: s("table")?,
            dataset: s("dataset")?,
            scheme: s("scheme")?,
            app: s("app")?,
            metric: s("metric")?,
            unit: s("unit")?,
            summary: Summary {
                median_ms: f("median")?,
                mad_ms: f("mad")?,
                min_ms: f("min")?,
                max_ms: f("max")?,
                mean_ms: f("mean")?,
                n: f("iters")? as usize,
            },
            items_per_sec: j.get("items_per_sec").and_then(|v| v.as_f64()),
            digest: j.get("digest").and_then(|v| v.as_str()).map(|s| s.to_string()),
        })
    }

    /// Format one summary value in this record's unit.
    pub fn fmt(&self, v: f64) -> String {
        match self.unit.as_str() {
            "ms" => human::ms(v),
            "%" => format!("{v:.1}%"),
            "x" => format!("{v:.2}x"),
            other => format!("{v:.4} {other}"),
        }
    }
}

/// A complete repro run: configuration + machine + records.
#[derive(Clone, Debug)]
pub struct ResultsDoc {
    /// Seed the run used.
    pub seed: u64,
    /// Dataset scale ("quick" or "full").
    pub scale: String,
    /// Worker threads the run was pinned to.
    pub threads: usize,
    /// Captured machine snapshot.
    pub machine: MachineInfo,
    /// Peak RSS at the end of the run (Linux; `None` elsewhere).
    pub rss_peak_bytes: Option<u64>,
    /// Unix timestamp (seconds) the document was created.
    pub created_unix: u64,
    /// All measurements, in emission order.
    pub records: Vec<Record>,
}

impl ResultsDoc {
    /// Fresh document capturing the current machine and time.
    pub fn new(seed: u64, scale: &str) -> Self {
        let machine = MachineInfo::capture();
        let threads = machine.threads;
        Self {
            seed,
            scale: scale.to_string(),
            threads,
            machine,
            rss_peak_bytes: None,
            created_unix: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            records: Vec::new(),
        }
    }

    /// Append a record.
    pub fn push(&mut self, r: Record) {
        self.records.push(r);
    }

    /// Unique table ids present, in [`TABLE_IDS`] order (unknown ids
    /// last, in first-seen order).
    pub fn tables(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for id in TABLE_IDS {
            if self.records.iter().any(|r| r.table == id) {
                out.push(id.to_string());
            }
        }
        for r in &self.records {
            if !out.contains(&r.table) {
                out.push(r.table.clone());
            }
        }
        out
    }

    /// Unique scheme names present (sorted). Scheme-less rows (the T3
    /// ingest stage) are not a scheme and are excluded.
    pub fn schemes(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .records
            .iter()
            .filter(|r| !r.scheme.is_empty())
            .map(|r| r.scheme.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Look up a record.
    pub fn get(&self, table: &str, dataset: &str, scheme: &str, metric: &str) -> Option<&Record> {
        self.records.iter().find(|r| {
            r.table == table && r.dataset == dataset && r.scheme == scheme && r.metric == metric
        })
    }

    /// Render as the versioned JSON document.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("created_unix", Json::Num(self.created_unix as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("scale", Json::Str(self.scale.clone())),
            ("threads", Json::Num(self.threads as f64)),
            ("machine", self.machine.to_json()),
            (
                "rss_peak_bytes",
                self.rss_peak_bytes.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null),
            ),
            ("records", Json::Arr(self.records.iter().map(|r| r.to_json()).collect())),
        ])
    }

    /// Strict parse + schema validation of a rendered document. Rejects
    /// unknown schema versions and structurally incomplete records, so a
    /// drifting emitter fails its own tests rather than committing an
    /// unreadable trajectory point.
    pub fn parse(text: &str) -> Result<ResultsDoc> {
        let j = Json::parse(text).context("BENCH_repro.json is not valid JSON")?;
        let schema = j
            .get("schema")
            .and_then(|v| v.as_str())
            .context("missing \"schema\" field")?;
        if schema != SCHEMA && !LEGACY_SCHEMAS.contains(&schema) {
            bail!(
                "unknown schema {schema:?} (this reader understands {SCHEMA:?} \
                 and legacy {LEGACY_SCHEMAS:?})"
            );
        }
        let num = |k: &str| -> Result<u64> {
            j.get(k).and_then(|v| v.as_u64()).with_context(|| format!("missing numeric {k:?}"))
        };
        let mj = j.get("machine").context("missing \"machine\" object")?;
        let ms = |k: &str| -> Result<String> {
            Ok(mj.get(k)
                .and_then(|v| v.as_str())
                .with_context(|| format!("machine missing {k:?}"))?
                .to_string())
        };
        let machine = MachineInfo {
            hostname: ms("hostname")?,
            os: ms("os")?,
            arch: ms("arch")?,
            cpus: mj.get("cpus").and_then(|v| v.as_u64()).context("machine missing cpus")?
                as usize,
            threads: mj
                .get("threads")
                .and_then(|v| v.as_u64())
                .context("machine missing threads")? as usize,
            version: ms("version")?,
        };
        let records = match j.get("records").context("missing \"records\" array")? {
            Json::Arr(items) => items
                .iter()
                .enumerate()
                .map(|(i, r)| Record::parse(r).with_context(|| format!("record {i}")))
                .collect::<Result<Vec<_>>>()?,
            _ => bail!("\"records\" is not an array"),
        };
        Ok(ResultsDoc {
            seed: num("seed")?,
            scale: j
                .get("scale")
                .and_then(|v| v.as_str())
                .context("missing \"scale\"")?
                .to_string(),
            threads: num("threads")? as usize,
            machine,
            rss_peak_bytes: j.get("rss_peak_bytes").and_then(|v| v.as_u64()),
            created_unix: num("created_unix")?,
            records,
        })
    }

    /// Render the records as the `docs/RESULTS.md` page: one GitHub-
    /// flavoured markdown table per repro table, preceded by the run
    /// configuration, so the committed page is regenerable from (and
    /// always consistent with) the committed JSON.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("# Reproduction results\n\n");
        out.push_str(
            "Generated by `boba repro` — do not edit by hand. Regenerate with:\n\n\
             ```sh\ncd rust && cargo run --release -- repro --quick \\\n    \
             --json ../BENCH_repro.json --md ../docs/RESULTS.md\n```\n\n",
        );
        out.push_str(&format!(
            "- **machine**: {} ({} {}, {} CPUs), crate v{}\n- **threads**: {}\n\
             - **seed**: {}\n- **scale**: {}\n",
            self.machine.hostname,
            self.machine.os,
            self.machine.arch,
            self.machine.cpus,
            self.machine.version,
            self.threads,
            self.seed,
            self.scale,
        ));
        if let Some(b) = self.rss_peak_bytes {
            out.push_str(&format!("- **peak RSS**: {}\n", human::bytes_binary(b)));
        }
        out.push('\n');
        for table in self.tables() {
            out.push_str(&format!("## {}\n\n", table_title(&table)));
            out.push_str("| dataset | scheme | app | metric | median | min | max | n |\n");
            out.push_str("|---|---|---|---|---:|---:|---:|---:|\n");
            for r in self.records.iter().filter(|r| r.table == table) {
                out.push_str(&format!(
                    "| {} | {} | {} | {} | {} | {} | {} | {} |\n",
                    r.dataset,
                    // Scheme-less rows (the T3 ingest stage) render like
                    // app-less ones.
                    if r.scheme.is_empty() { "—" } else { r.scheme.as_str() },
                    if r.app.is_empty() { "—" } else { r.app.as_str() },
                    r.metric,
                    r.fmt(r.summary.median_ms),
                    r.fmt(r.summary.min_ms),
                    r.fmt(r.summary.max_ms),
                    r.summary.n,
                ));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_doc() -> ResultsDoc {
        let mut doc = ResultsDoc::new(42, "quick");
        doc.push(Record {
            table: "T1".into(),
            dataset: "rmat_q".into(),
            scheme: "boba".into(),
            app: String::new(),
            metric: "reorder_ms".into(),
            unit: "ms".into(),
            summary: Summary::of(&mut [1.0, 1.2, 1.1]),
            items_per_sec: Some(1.0e8),
            digest: Some("deadbeef".into()),
        });
        doc.push(Record {
            table: "T4".into(),
            dataset: "rmat_q".into(),
            scheme: "boba".into(),
            app: "SpMV".into(),
            metric: "l1_hit_pct".into(),
            unit: "%".into(),
            summary: Summary::single(61.5),
            items_per_sec: None,
            digest: None,
        });
        doc.rss_peak_bytes = Some(1 << 20);
        doc
    }

    #[test]
    fn json_roundtrip_preserves_records() {
        let doc = sample_doc();
        let text = doc.to_json().render();
        let back = ResultsDoc::parse(&text).unwrap();
        assert_eq!(back.seed, 42);
        assert_eq!(back.records.len(), 2);
        let r = back.get("T1", "rmat_q", "boba", "reorder_ms").unwrap();
        assert_eq!(r.digest.as_deref(), Some("deadbeef"));
        assert_eq!(r.summary.n, 3);
        assert!((r.summary.median_ms - 1.1).abs() < 1e-9);
        assert_eq!(back.rss_peak_bytes, Some(1 << 20));
    }

    #[test]
    fn parse_rejects_wrong_schema() {
        let doc = sample_doc();
        let text = doc.to_json().render().replace(SCHEMA, "boba-repro/999");
        assert!(ResultsDoc::parse(&text).is_err());
    }

    #[test]
    fn parse_accepts_legacy_schema() {
        // Committed v1/v2 trajectory points (pre-ingest-stage,
        // pre-format-table) stay readable.
        let doc = sample_doc();
        for legacy in LEGACY_SCHEMAS {
            let text = doc.to_json().render().replace(SCHEMA, legacy);
            let back = ResultsDoc::parse(&text).unwrap();
            assert_eq!(back.records.len(), doc.records.len());
        }
    }

    #[test]
    fn markdown_renders_scheme_less_rows_with_dash() {
        let mut doc = sample_doc();
        doc.push(Record {
            table: "T3".into(),
            dataset: "rmat_q".into(),
            scheme: String::new(),
            app: String::new(),
            metric: "ingest_ms".into(),
            unit: "ms".into(),
            summary: Summary::single(4.2),
            items_per_sec: Some(1.0e8),
            digest: None,
        });
        let md = doc.render_markdown();
        assert!(md.contains("| rmat_q | — | — | ingest_ms |"), "{md}");
        assert!(
            !doc.schemes().contains(&String::new()),
            "scheme-less rows are not a scheme"
        );
    }

    #[test]
    fn parse_rejects_incomplete_record() {
        let text = format!(
            "{{\"schema\":\"{SCHEMA}\",\"created_unix\":0,\"seed\":1,\
             \"scale\":\"quick\",\"threads\":1,\
             \"machine\":{{\"hostname\":\"h\",\"os\":\"linux\",\"arch\":\"x\",\
             \"cpus\":1,\"threads\":1,\"version\":\"0\"}},\
             \"rss_peak_bytes\":null,\
             \"records\":[{{\"table\":\"T1\"}}]}}"
        );
        let err = ResultsDoc::parse(&text).unwrap_err();
        assert!(format!("{err:#}").contains("record 0"), "{err:#}");
    }

    #[test]
    fn markdown_lists_every_table_present() {
        let doc = sample_doc();
        let md = doc.render_markdown();
        assert!(md.contains("## T1 —"));
        assert!(md.contains("## T4 —"));
        assert!(!md.contains("## T2 —"), "absent tables are not rendered");
        assert!(md.contains("| rmat_q | boba |"));
        assert!(md.contains("61.5%"));
        assert!(md.contains("boba repro"));
    }

    #[test]
    fn tables_ordered_canonically() {
        let doc = sample_doc();
        assert_eq!(doc.tables(), vec!["T1".to_string(), "T4".to_string()]);
        assert_eq!(doc.schemes(), vec!["boba".to_string()]);
    }
}
