//! Robust summary statistics over timing samples.
//!
//! Every repro-harness measurement is reported as a [`Summary`] — median
//! (the headline number, robust to scheduler noise), median absolute
//! deviation (spread), and min/max/mean (the envelope) — following the
//! methodology critique of Faldu et al. ("A Closer Look at Lightweight
//! Graph Reordering"): single-shot timings of reordering pipelines are
//! dominated by cache and scheduler state, so the harness always runs
//! warmup + repeated iterations and summarizes.

/// Summary statistics of a set of timing samples (milliseconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    /// Median sample.
    pub median_ms: f64,
    /// Median absolute deviation around the median.
    pub mad_ms: f64,
    /// Smallest sample.
    pub min_ms: f64,
    /// Largest sample.
    pub max_ms: f64,
    /// Arithmetic mean.
    pub mean_ms: f64,
    /// Number of samples summarized.
    pub n: usize,
}

impl Summary {
    /// An all-zero summary (no samples).
    pub fn zero() -> Self {
        Self { median_ms: 0.0, mad_ms: 0.0, min_ms: 0.0, max_ms: 0.0, mean_ms: 0.0, n: 0 }
    }

    /// Summarize `samples` (sorts in place; empty input yields
    /// [`Summary::zero`]).
    pub fn of(samples: &mut [f64]) -> Self {
        if samples.is_empty() {
            return Self::zero();
        }
        let (median, mad) = median_mad(samples);
        let min = samples[0];
        let max = samples[samples.len() - 1];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        Self {
            median_ms: median,
            mad_ms: mad,
            min_ms: min,
            max_ms: max,
            mean_ms: mean,
            n: samples.len(),
        }
    }

    /// A single-sample summary (deterministic quantities, e.g. simulated
    /// hit rates, where repetition adds nothing).
    pub fn single(v: f64) -> Self {
        Self { median_ms: v, mad_ms: 0.0, min_ms: v, max_ms: v, mean_ms: v, n: 1 }
    }
}

/// Median and median-absolute-deviation of samples (sorts in place).
pub fn median_mad(samples: &mut [f64]) -> (f64, f64) {
    if samples.is_empty() {
        return (0.0, 0.0);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (median, dev[dev.len() / 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_samples() {
        let mut s = vec![3.0, 1.0, 2.0, 100.0, 2.5];
        let sum = Summary::of(&mut s);
        assert_eq!(sum.median_ms, 2.5);
        assert_eq!(sum.min_ms, 1.0);
        assert_eq!(sum.max_ms, 100.0);
        assert_eq!(sum.n, 5);
        assert!((sum.mean_ms - 21.7).abs() < 1e-9);
        assert!(sum.mad_ms <= 1.5, "mad robust to the outlier: {}", sum.mad_ms);
    }

    #[test]
    fn summary_empty_is_zero() {
        assert_eq!(Summary::of(&mut []), Summary::zero());
    }

    #[test]
    fn summary_single() {
        let s = Summary::single(7.5);
        assert_eq!(s.median_ms, 7.5);
        assert_eq!(s.min_ms, 7.5);
        assert_eq!(s.max_ms, 7.5);
        assert_eq!(s.n, 1);
    }

    #[test]
    fn median_mad_basic() {
        let mut s = vec![1.0, 100.0, 2.0, 3.0, 2.5];
        let (med, mad) = median_mad(&mut s);
        assert_eq!(med, 2.5);
        assert!(mad <= 1.5);
    }
}
