//! The benchmark harness family (criterion does not resolve offline).
//!
//! * this module — [`Bench`] (warmup + repeated timed iterations with a
//!   wall-clock cap), [`Measurement`] and [`Report`] for aligned table
//!   output: everything the paper-table benches in `rust/benches/` need;
//! * [`stats`] — robust summaries (median + MAD + min/max/mean) shared
//!   by every timing consumer;
//! * [`machine`] — testbed capture (OS/arch/CPUs/threads, peak RSS) so
//!   committed numbers carry the machine they ran on;
//! * [`results`] — the versioned `BENCH_repro.json` schema with a strict
//!   parser and the `docs/RESULTS.md` markdown renderer.
//!
//! Benches are ordinary binaries with `harness = false`; each builds a
//! [`Bench`] per measurement and prints rows via [`Report`]. The repro
//! harness ([`crate::coordinator::repro`]) layers [`results`] on top.
//!
//! ```
//! let m = boba::bench::Bench::quick().run("add", || 1 + 1);
//! assert!(m.iters() >= 1);
//! assert!(m.summary.min_ms <= m.summary.median_ms);
//! ```

pub mod machine;
pub mod results;
pub mod stats;

pub use stats::{median_mad, Summary};

use crate::util::human;
use std::time::{Duration, Instant};

/// One measured quantity.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Label (e.g. "BOBA/kron18/reorder").
    pub name: String,
    /// Full summary (median/MAD/min/max/mean) of the samples — the
    /// single source of truth for the numbers.
    pub summary: Summary,
    /// Optional throughput item count (edges, rows...) per iteration.
    pub items: Option<u64>,
}

impl Measurement {
    /// Median time per iteration, milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.summary.median_ms
    }

    /// Median absolute deviation, milliseconds.
    pub fn mad_ms(&self) -> f64 {
        self.summary.mad_ms
    }

    /// Iterations measured.
    pub fn iters(&self) -> usize {
        self.summary.n
    }

    /// Items per second, if an item count was attached.
    pub fn throughput(&self) -> Option<f64> {
        self.items.map(|it| it as f64 / (self.summary.median_ms / 1e3))
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded iterations.
    pub iters: usize,
    /// Hard cap on total measurement time; stops early if exceeded.
    pub max_total: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 1, iters: 5, max_total: Duration::from_secs(60) }
    }
}

impl Bench {
    /// Quick preset for cheap micro-measurements.
    pub fn quick() -> Self {
        Self { warmup: 2, iters: 9, max_total: Duration::from_secs(20) }
    }

    /// One-shot preset for expensive end-to-end runs.
    pub fn once() -> Self {
        Self { warmup: 0, iters: 1, max_total: Duration::from_secs(600) }
    }

    /// Run `f` under this configuration and summarize. The closure's
    /// return value is black-boxed to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        let started = Instant::now();
        for _ in 0..self.iters.max(1) {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64() * 1e3);
            if started.elapsed() > self.max_total {
                break;
            }
        }
        let summary = Summary::of(&mut samples);
        Measurement { name: name.to_string(), summary, items: None }
    }

    /// Like [`Bench::run`] with a throughput item count.
    pub fn run_with_items<T>(
        &self,
        name: &str,
        items: u64,
        f: impl FnMut() -> T,
    ) -> Measurement {
        let mut m = self.run(name, f);
        m.items = Some(items);
        m
    }
}

/// Identity function the optimizer must assume has side effects.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects measurements and renders the final table.
#[derive(Debug, Default)]
pub struct Report {
    rows: Vec<Measurement>,
    title: String,
}

impl Report {
    /// New report with a title banner.
    pub fn new(title: &str) -> Self {
        Self { rows: Vec::new(), title: title.to_string() }
    }

    /// Add a measurement.
    pub fn push(&mut self, m: Measurement) {
        self.rows.push(m);
    }

    /// Access rows (drivers post-process them, e.g. speedup columns).
    pub fn rows(&self) -> &[Measurement] {
        &self.rows
    }

    /// Render as an aligned table.
    pub fn render(&self) -> String {
        let mut rows = Vec::new();
        for m in &self.rows {
            let thr = m
                .throughput()
                .map(|t| format!("{}/s", human::count_compact(t as u64)))
                .unwrap_or_default();
            rows.push(vec![
                m.name.clone(),
                human::ms(m.median_ms()),
                format!("±{}", human::ms(m.mad_ms())),
                format!("n={}", m.iters()),
                thr,
            ]);
        }
        format!(
            "\n== {} ==\n{}",
            self.title,
            human::table(&["benchmark", "median", "mad", "iters", "throughput"], &rows)
        )
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_measures_and_counts() {
        let b = Bench { warmup: 1, iters: 3, max_total: Duration::from_secs(5) };
        let m = b.run("spin", || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert_eq!(m.iters(), 3);
        assert!(m.median_ms() >= 1.5, "median {}", m.median_ms());
        assert!(m.summary.min_ms <= m.median_ms() && m.median_ms() <= m.summary.max_ms);
    }

    #[test]
    fn median_mad_basic() {
        let mut s = vec![1.0, 100.0, 2.0, 3.0, 2.5];
        let (med, mad) = median_mad(&mut s);
        assert_eq!(med, 2.5);
        assert!(mad <= 1.5); // robust to the 100.0 outlier
    }

    #[test]
    fn throughput_computed() {
        let b = Bench { warmup: 0, iters: 1, max_total: Duration::from_secs(5) };
        let m =
            b.run_with_items("x", 1_000_000, || std::thread::sleep(Duration::from_millis(10)));
        let thr = m.throughput().unwrap();
        assert!(thr < 2e8 && thr > 1e6, "thr {thr}");
    }

    #[test]
    fn report_renders_rows() {
        let mut r = Report::new("T");
        r.push(Measurement {
            name: "a".into(),
            summary: Summary::single(1.0),
            items: Some(100),
        });
        let s = r.render();
        assert!(s.contains("== T ==") && s.contains('a'));
    }

    #[test]
    fn bench_respects_time_cap() {
        let b = Bench { warmup: 0, iters: 1000, max_total: Duration::from_millis(30) };
        let m = b.run("slow", || std::thread::sleep(Duration::from_millis(10)));
        assert!(m.iters() < 10, "iters {}", m.iters());
    }
}
