//! Vertex reordering algorithms: BOBA (the paper's contribution) and
//! every baseline its evaluation compares against.
//!
//! | Scheme | Class | Paper section | Module |
//! |---|---|---|---|
//! | BOBA (seq Alg. 2, par Alg. 3) | lightweight | §4 | [`boba`] |
//! | Random relabeling | baseline | §5.1 | [`random`] |
//! | Full sort by degree | lightweight | §3.2 | [`degree`] |
//! | Hub sort (frequency sort) | lightweight | §3.2 [Zhang et al. 2017] | [`hub`] |
//! | Reverse Cuthill–McKee | heavyweight | §3.1.1 [Cuthill & McKee 1969] | [`rcm`] |
//! | Gorder (window-w greedy) | heavyweight | §3.1.2 [Wei et al. 2016] | [`gorder`] |
//!
//! All reorderers consume a COO (the paper's pragmatic pipeline input) and
//! produce a [`Permutation`] mapping old vertex IDs to new ones; apply it
//! with [`crate::graph::Coo::relabeled`].
//!
//! ```
//! use boba::graph::Coo;
//! use boba::reorder::{by_name, Reorderer};
//!
//! // BOBA orders by first appearance in I++J = [2, 0] ++ [0, 1].
//! let coo = Coo::new(3, vec![2, 0], vec![0, 1]);
//! let perm = by_name("boba", 42).unwrap().reorder(&coo);
//! perm.validate(3).unwrap();
//! assert_eq!(perm.order(), vec![2, 0, 1]);
//! ```

pub mod perm;
pub mod boba;
pub mod random;
pub mod degree;
pub mod hub;
pub mod rcm;
pub mod gorder;

pub use perm::Permutation;

use crate::graph::Coo;

/// A vertex-reordering algorithm.
pub trait Reorderer {
    /// Short name used in tables ("BOBA", "Gorder", ...).
    fn name(&self) -> &'static str;

    /// Compute the permutation for `coo` (old ID → new ID).
    fn reorder(&self, coo: &Coo) -> Permutation;

    /// Compute the permutation AND the relabeled COO.
    ///
    /// The default is reorder-then-relabel (two passes). BOBA overrides
    /// it with a single fused pass: assigning labels *is* scanning the
    /// edge list, so the relabeled arrays can be emitted for free — this
    /// matches the paper's GPU kernel, whose output is the reordered
    /// edge list, and is the §Perf accounting used by the pipeline
    /// ("reorder" = produce the relabeled COO).
    fn reorder_relabel(&self, coo: &Coo) -> (Permutation, Coo) {
        let p = self.reorder(coo);
        let relabeled = coo.relabeled(p.new_of_old());
        (p, relabeled)
    }

    /// Whether the method is lightweight in the paper's taxonomy
    /// (affects which experiments include it).
    fn lightweight(&self) -> bool {
        true
    }
}

/// Look up a scheme by its CLI/service name. Accepted names: `boba`,
/// `boba-seq`, `boba-atomic`, `degree`, `hub`, `rcm`, `gorder`,
/// `random` (seeded relabeling). Shared by the CLI dispatcher and the
/// server's [`crate::server::registry::GraphRegistry`].
pub fn by_name(name: &str, seed: u64) -> anyhow::Result<Box<dyn Reorderer + Send + Sync>> {
    Ok(match name.to_lowercase().as_str() {
        "boba" => Box::new(boba::Boba::parallel()),
        "boba-seq" => Box::new(boba::Boba::sequential()),
        // lint: allow(ablation-reach): the name table must be able to
        // construct the ablation scheme; only repro/bench invocations
        // ever pass "boba-atomic".
        "boba-atomic" => Box::new(boba::Boba::parallel_atomic()),
        "degree" => Box::new(degree::DegreeSort::new()),
        "hub" => Box::new(hub::HubSort::new()),
        "rcm" => Box::new(rcm::Rcm::new()),
        "gorder" => Box::new(gorder::Gorder::new(5)),
        "random" => Box::new(random::RandomOrder::new(seed)),
        other => anyhow::bail!(
            "unknown scheme {other} (expected boba|boba-seq|boba-atomic|degree|hub|rcm|gorder|random)"
        ),
    })
}

/// Every scheme of the paper's §5 benches, in table order:
/// Random is implicit (the input is pre-randomized), so this returns
/// Gorder, RCM, BOBA, Hub, Degree.
pub fn all_schemes(seed: u64) -> Vec<Box<dyn Reorderer + Send + Sync>> {
    vec![
        Box::new(gorder::Gorder::new(5)),
        Box::new(rcm::Rcm::new()),
        Box::new(boba::Boba::parallel()),
        Box::new(hub::HubSort::new()),
        Box::new(degree::DegreeSort::new()),
        Box::new(random::RandomOrder::new(seed)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn every_scheme_produces_valid_permutation() {
        let g = gen::preferential_attachment(500, 4, 3).randomized(9);
        for scheme in all_schemes(1) {
            let p = scheme.reorder(&g);
            p.validate(g.n()).unwrap_or_else(|e| {
                panic!("{} produced invalid permutation: {e}", scheme.name())
            });
        }
    }

    #[test]
    fn relabeled_graph_preserves_degree_multiset() {
        let g = gen::grid_road(30, 30, 2).randomized(5);
        for scheme in all_schemes(2) {
            let p = scheme.reorder(&g);
            let h = g.relabeled(p.new_of_old());
            let mut d0 = g.total_degrees();
            let mut d1 = h.total_degrees();
            d0.sort_unstable();
            d1.sort_unstable();
            assert_eq!(d0, d1, "{}", scheme.name());
        }
    }
}
