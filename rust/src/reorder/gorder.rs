//! Gorder (Wei, Yu, Lu, Lin — SIGMOD 2016) — the paper's second
//! heavyweight baseline (§3.1.2): a greedy 1/(2w)-approximation of the
//! windowed-TSP objective GScore (Model 6). Vertices are emitted one at a
//! time; the next vertex is the one with the largest total score
//! `s(u, v) = |N_in(u) ∩ N_in(v)| + |{uv, vu} ∩ E|` against the last `w`
//! emitted vertices.
//!
//! Implementation follows the Gorder paper's incremental scheme: placing
//! `ve` bumps the priority of its out/in-neighbors (edge term) and of all
//! co-children of its in-neighbors (sibling term); when `vb` slides out
//! of the window the same deltas are subtracted. The priority queue is a
//! lazy max-heap (stale entries re-validated on pop) standing in for the
//! paper's unit heap. Runtime `O(w · deg_max · m)` worst case — Gorder is
//! *the* heavyweight method, and its cost showing up as 2–3 orders above
//! BOBA's in Fig. 5/6 is part of the reproduction.

use super::perm::Permutation;
use super::Reorderer;
use crate::convert::coo_to_csr;
use crate::graph::{Coo, Csr};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Gorder reorderer with window `w` (the paper of record uses w=5).
#[derive(Clone, Debug)]
pub struct Gorder {
    w: usize,
    hub_cap: usize,
}

impl Gorder {
    /// Create with window size `w` and the default hub relaxation.
    pub fn new(w: usize) -> Self {
        assert!(w >= 1);
        Self { w, hub_cap: 2048 }
    }

    /// Sibling enumeration skips common in-neighbors with out-degree
    /// above `cap` — the hub relaxation Gorder's reference implementation
    /// applies (a mega-hub is an in-neighbor of ~everything, so its
    /// sibling contribution is near-uniform noise at quadratic cost).
    /// `usize::MAX` disables the relaxation.
    pub fn with_hub_cap(w: usize, cap: usize) -> Self {
        assert!(w >= 1);
        Self { w, hub_cap: cap }
    }
}

impl Reorderer for Gorder {
    fn name(&self) -> &'static str {
        "Gorder"
    }

    fn lightweight(&self) -> bool {
        false
    }

    fn reorder(&self, coo: &Coo) -> Permutation {
        let g = coo.deduped();
        let out = coo_to_csr(&g);
        let inn = out.transposed();
        gorder_greedy(&out, &inn, self.w, self.hub_cap)
    }
}

/// The greedy window scan.
fn gorder_greedy(out: &Csr, inn: &Csr, w: usize, hub_cap: usize) -> Permutation {
    let n = out.n();
    if n == 0 {
        return Permutation::identity(0);
    }
    let mut key = vec![0i64; n]; // current window score per candidate
    let mut placed = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    // Lazy max-heap of (key, vertex); entries go stale when key changes.
    let mut heap: BinaryHeap<(i64, Reverse<u32>)> = BinaryHeap::new();

    // Start from the max-total-degree vertex (Gorder's choice: max
    // in-degree; total degree is equivalent for the symmetric datasets and
    // more robust on directed ones).
    let seed = (0..n)
        .max_by_key(|&v| out.degree(v) + inn.degree(v))
        .unwrap() as u32;

    // Apply the score delta of vertex `ve` entering (+1) / leaving (-1)
    // the window, updating candidate keys and pushing fresh heap entries.
    let apply = |ve: u32,
                     sign: i64,
                     key: &mut Vec<i64>,
                     heap: &mut BinaryHeap<(i64, Reverse<u32>)>,
                     placed: &Vec<bool>| {
        let bump = |u: u32, key: &mut Vec<i64>, heap: &mut BinaryHeap<(i64, Reverse<u32>)>| {
            if !placed[u as usize] {
                key[u as usize] += sign;
                if sign > 0 {
                    heap.push((key[u as usize], Reverse(u)));
                }
            }
        };
        // Edge term: uv or vu in E.
        for &u in out.neighbors(ve as usize) {
            bump(u, key, heap);
        }
        for &u in inn.neighbors(ve as usize) {
            bump(u, key, heap);
        }
        // Sibling term: common in-neighbor x (x -> ve and x -> u).
        for &x in inn.neighbors(ve as usize) {
            if out.degree(x as usize) > hub_cap {
                continue; // hub relaxation (see Gorder::with_hub_cap)
            }
            for &u in out.neighbors(x as usize) {
                if u != ve {
                    bump(u, key, heap);
                }
            }
        }
    };

    // Place the seed.
    placed[seed as usize] = true;
    order.push(seed);
    apply(seed, 1, &mut key, &mut heap, &placed);

    let mut next_fallback = 0u32; // ID scan for empty-heap (new component)
    while order.len() < n {
        // Window slide-out.
        if order.len() > w {
            let vb = order[order.len() - 1 - w];
            apply(vb, -1, &mut key, &mut heap, &placed);
        }
        // Pop until a fresh entry surfaces.
        let ve = loop {
            match heap.pop() {
                Some((k, Reverse(v))) => {
                    if placed[v as usize] {
                        continue;
                    }
                    if k > key[v as usize] {
                        // Stale (a decrement happened); re-insert at the
                        // true priority and keep looking.
                        heap.push((key[v as usize], Reverse(v)));
                        continue;
                    }
                    break v;
                }
                None => {
                    // Disconnected leftover: take the next unplaced ID.
                    while placed[next_fallback as usize] {
                        next_fallback += 1;
                    }
                    break next_fallback;
                }
            }
        };
        placed[ve as usize] = true;
        order.push(ve);
        apply(ve, 1, &mut key, &mut heap, &placed);
    }
    Permutation::from_order(&order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::metrics::{gscore, nscore};

    #[test]
    fn valid_permutation() {
        let g = gen::preferential_attachment(300, 3, 2).randomized(4);
        let p = Gorder::new(5).reorder(&g);
        p.validate(g.n()).unwrap();
    }

    #[test]
    fn valid_on_disconnected() {
        let g = Coo::new(7, vec![0, 1, 4, 5], vec![1, 2, 5, 6]); // vertex 3 isolated
        let p = Gorder::new(3).reorder(&g);
        p.validate(7).unwrap();
    }

    #[test]
    fn improves_gscore_over_random() {
        let g = gen::preferential_attachment(600, 4, 5).randomized(11);
        let p = Gorder::new(5).reorder(&g);
        let h = g.relabeled(p.new_of_old());
        let sc_rand = gscore(&g, 5);
        let sc_gord = gscore(&h, 5);
        assert!(
            sc_gord as f64 > 1.5 * sc_rand as f64,
            "gorder {sc_gord} vs rand {sc_rand}"
        );
    }

    #[test]
    fn improves_nscore_on_mesh() {
        let g = gen::delaunay_mesh(16, 16, 3).randomized(6);
        let p = Gorder::new(5).reorder(&g);
        let h = g.relabeled(p.new_of_old());
        assert!(nscore(&h) > nscore(&g), "{} vs {}", nscore(&h), nscore(&g));
    }

    #[test]
    fn window_one_still_works() {
        let g = gen::grid_road(10, 10, 1).randomized(2);
        let p = Gorder::new(1).reorder(&g);
        p.validate(g.n()).unwrap();
    }
}
