//! Vertex permutations: the output type of every reorderer.
//!
//! Two equivalent encodings appear in the paper: the *order* form
//! `p = p_1 p_2 ... p_n` (Algorithm 2's output — `p[k]` is the old ID of
//! the vertex placed at new position `k`) and the *mapping* form
//! (`new_of_old[v]` = new ID of old vertex `v`), which is what
//! [`crate::graph::Coo::relabeled`] consumes. [`Permutation`] stores the
//! mapping form and converts from either.

/// A bijection on `0..n` vertex IDs, stored as `old → new`.
#[derive(Clone, Debug, PartialEq)]
pub struct Permutation {
    new_of_old: Vec<u32>,
}

impl Permutation {
    /// Identity permutation.
    pub fn identity(n: usize) -> Self {
        Self { new_of_old: (0..n as u32).collect() }
    }

    /// From the mapping form (`new_of_old[old] = new`).
    pub fn from_new_of_old(new_of_old: Vec<u32>) -> Self {
        Self { new_of_old }
    }

    /// From the order form (`order[k] = old ID at new position k`, the
    /// paper's `p`).
    pub fn from_order(order: &[u32]) -> Self {
        let mut new_of_old = vec![u32::MAX; order.len()];
        for (new, &old) in order.iter().enumerate() {
            new_of_old[old as usize] = new as u32;
        }
        Self { new_of_old }
    }

    /// The mapping slice (`old → new`).
    pub fn new_of_old(&self) -> &[u32] {
        &self.new_of_old
    }

    /// Number of vertices.
    pub fn len(&self) -> usize {
        self.new_of_old.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.new_of_old.is_empty()
    }

    /// The order form (`new → old`), i.e. the inverse mapping.
    pub fn order(&self) -> Vec<u32> {
        let mut order = vec![u32::MAX; self.len()];
        for (old, &new) in self.new_of_old.iter().enumerate() {
            order[new as usize] = old as u32;
        }
        order
    }

    /// Inverse permutation.
    pub fn inverse(&self) -> Permutation {
        Permutation { new_of_old: self.order() }
    }

    /// Compose: apply `self` first, then `after` (`(after ∘ self)(v)`).
    pub fn then(&self, after: &Permutation) -> Permutation {
        assert_eq!(self.len(), after.len());
        let new_of_old = self
            .new_of_old
            .iter()
            .map(|&mid| after.new_of_old[mid as usize])
            .collect();
        Permutation { new_of_old }
    }

    /// Check bijectivity over `0..n`.
    pub fn validate(&self, n: usize) -> anyhow::Result<()> {
        if self.len() != n {
            anyhow::bail!("permutation has {} entries, expected {n}", self.len());
        }
        let mut seen = vec![false; n];
        for (old, &new) in self.new_of_old.iter().enumerate() {
            let idx = new as usize;
            if idx >= n {
                anyhow::bail!("vertex {old} maps to {new} ≥ n={n}");
            }
            if seen[idx] {
                anyhow::bail!("new ID {new} assigned twice");
            }
            seen[idx] = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_maps_to_self() {
        let p = Permutation::identity(5);
        assert_eq!(p.new_of_old(), &[0, 1, 2, 3, 4]);
        p.validate(5).unwrap();
    }

    #[test]
    fn order_mapping_roundtrip() {
        // order: position 0 holds old vertex 2, etc.
        let order = vec![2u32, 0, 1];
        let p = Permutation::from_order(&order);
        assert_eq!(p.new_of_old(), &[1, 2, 0]);
        assert_eq!(p.order(), order);
    }

    #[test]
    fn inverse_composes_to_identity() {
        let p = Permutation::from_new_of_old(vec![3, 1, 0, 2]);
        let composed = p.then(&p.inverse());
        assert_eq!(composed, Permutation::identity(4));
    }

    #[test]
    fn validate_catches_duplicates_and_range() {
        assert!(Permutation::from_new_of_old(vec![0, 0]).validate(2).is_err());
        assert!(Permutation::from_new_of_old(vec![0, 5]).validate(2).is_err());
        assert!(Permutation::from_new_of_old(vec![0]).validate(2).is_err());
        assert!(Permutation::from_new_of_old(vec![1, 0]).validate(2).is_ok());
    }

    #[test]
    fn then_applies_in_sequence() {
        let a = Permutation::from_new_of_old(vec![1, 2, 0]); // v -> v+1 mod 3
        let b = Permutation::from_new_of_old(vec![2, 0, 1]); // v -> v-1 mod 3
        assert_eq!(a.then(&b), Permutation::identity(3));
    }
}
