//! Hub sort (frequency-based sorting, Zhang et al. 2017) — the partial
//! variant of degree sorting the paper benchmarks: only *hub* vertices
//! (degree above the average) are sorted to the front; all other vertices
//! keep their relative order. Cheaper than a full sort and preserves
//! whatever structure the non-hub labels already carry.

use super::perm::Permutation;
use super::Reorderer;
use crate::graph::Coo;

/// Hub-sort reorderer.
#[derive(Clone, Debug, Default)]
pub struct HubSort;

impl HubSort {
    /// Create with the standard avg-degree hub threshold.
    pub fn new() -> Self {
        Self
    }
}

impl Reorderer for HubSort {
    fn name(&self) -> &'static str {
        "Hub"
    }

    fn reorder(&self, coo: &Coo) -> Permutation {
        let deg = coo.total_degrees();
        let n = coo.n();
        if n == 0 {
            return Permutation::identity(0);
        }
        let avg = (2 * coo.m()) as f64 / n as f64;
        // Hubs sorted by degree descending (ID tiebreak); non-hubs follow
        // in original ID order.
        let mut hubs: Vec<u32> = (0..n as u32)
            .filter(|&v| deg[v as usize] as f64 > avg)
            .collect();
        hubs.sort_by_key(|&v| (u32::MAX - deg[v as usize], v));
        let mut order = hubs;
        for v in 0..n as u32 {
            if !(deg[v as usize] as f64 > avg) {
                order.push(v);
            }
        }
        Permutation::from_order(&order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn hubs_precede_nonhubs() {
        let g = gen::preferential_attachment(500, 4, 1).randomized(7);
        let p = HubSort::new().reorder(&g);
        let deg = g.total_degrees();
        let avg = (2 * g.m()) as f64 / g.n() as f64;
        let order = p.order();
        let boundary = order
            .iter()
            .position(|&v| !(deg[v as usize] as f64 > avg))
            .unwrap();
        assert!(order[boundary..].iter().all(|&v| deg[v as usize] as f64 <= avg));
        // Hubs sorted descending by degree.
        for w in order[..boundary].windows(2) {
            assert!(deg[w[0] as usize] >= deg[w[1] as usize]);
        }
    }

    #[test]
    fn nonhubs_keep_relative_order() {
        let g = gen::grid_road(20, 20, 3);
        let p = HubSort::new().reorder(&g);
        let deg = g.total_degrees();
        let avg = (2 * g.m()) as f64 / g.n() as f64;
        let order = p.order();
        let nonhubs: Vec<u32> = order
            .iter()
            .copied()
            .filter(|&v| deg[v as usize] as f64 <= avg)
            .collect();
        // Original ID order preserved.
        for w in nonhubs.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn valid_on_uniform_graph() {
        let g = gen::uniform_random(200, 800, 2);
        let p = HubSort::new().reorder(&g);
        p.validate(200).unwrap();
    }
}
