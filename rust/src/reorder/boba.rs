//! BOBA — Batched Order By Attachment (the paper's Algorithms 2 and 3).
//!
//! Order vertices by their (first) appearance in the flattened edge list
//! `I++J`. The intuition (paper §1.2, Figure 1): scanning `I++J` is a
//! deterministic analogue of sampling cells of the flattened edge list,
//! which is how preferential attachment picks targets — so appearance
//! order approximates attachment order, which Corollary 9 shows is a
//! near-optimal ordering for PA-generated graphs.
//!
//! Three variants:
//! * [`Boba::sequential`] — Algorithm 2 verbatim: one stable scan, exact
//!   first-appearance order.
//! * [`Boba::parallel`] — Algorithm 3 as published: chunked parallel scan
//!   with **racy** (non-atomic) min records; any appearance index may win.
//!   This mirrors the paper's GPU kernel, which deliberately skips
//!   `AtomicMin` ("the resulting permutation did not yield reorderings
//!   that delivered significantly better performance").
//! * [`Boba::parallel_atomic`] — Algorithm 3 with `AtomicMin` at lines
//!   4/6, recovering the sequential order exactly (used as a correctness
//!   oracle for the racy variant and benchmarked for the paper's claim
//!   that it is not worth the cost).
//!
//! Cost: reads are linear in `m`; writes through to the records table are
//! linear in `n` (each vertex's slot converges after a bounded number of
//! improvements); the final rank compaction is a sort over `n` keys.

use super::perm::Permutation;
use super::Reorderer;
use crate::graph::Coo;
use crate::parallel::{self, atomic::AtomicU32Array};

/// Which Algorithm-3 record update is used.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Algorithm 2: sequential stable scan.
    Sequential,
    /// Algorithm 3 as published: racy min records.
    ParallelRacy,
    /// Algorithm 3 + AtomicMin: parallel, exact first-appearance order.
    ParallelAtomic,
}

/// The BOBA reorderer.
#[derive(Clone, Debug)]
pub struct Boba {
    variant: Variant,
}

impl Boba {
    /// Algorithm 2 (sequential).
    pub fn sequential() -> Self {
        Self { variant: Variant::Sequential }
    }

    /// Algorithm 3 (parallel, racy records — the paper's GPU default).
    pub fn parallel() -> Self {
        Self { variant: Variant::ParallelRacy }
    }

    /// Algorithm 3 with AtomicMin (exact first-appearance order).
    pub fn parallel_atomic() -> Self {
        Self { variant: Variant::ParallelAtomic }
    }

    /// The variant in use.
    pub fn variant(&self) -> Variant {
        self.variant
    }
}

impl Reorderer for Boba {
    fn name(&self) -> &'static str {
        match self.variant {
            Variant::Sequential => "BOBA-seq",
            Variant::ParallelRacy => "BOBA",
            Variant::ParallelAtomic => "BOBA-atomic",
        }
    }

    fn reorder(&self, coo: &Coo) -> Permutation {
        match self.variant {
            Variant::Sequential => sequential(coo),
            Variant::ParallelRacy => parallel_records(coo, false),
            Variant::ParallelAtomic => parallel_records(coo, true),
        }
    }

    /// Fused reorder + relabel (single pass; §Perf): label assignment IS
    /// the scan of `I++J`, so the relabeled arrays are emitted in the
    /// same pass — matching the paper's GPU kernel, whose output is the
    /// reordered edge list. On the 1-core testbed this cuts
    /// reorder+relabel from 1.68 s to 1.29 s on a 64M-edge PA graph.
    fn reorder_relabel(&self, coo: &Coo) -> (Permutation, Coo) {
        match self.variant {
            // The racy variant degenerates to the stable scan on this
            // path too — exact first-appearance labels, emitted inline.
            Variant::Sequential | Variant::ParallelRacy => sequential_relabel(coo),
            Variant::ParallelAtomic => {
                let p = parallel_records(coo, true);
                let relabeled = coo.relabeled(p.new_of_old());
                (p, relabeled)
            }
        }
    }
}

/// Software-prefetch lookahead for the label-table gather (the same
/// tuning as convert's counter prefetch; see docs/EXPERIMENTS.md §Perf).
const PF_DIST: usize = 32;

#[inline(always)]
fn prefetch_u32(arr: &[u32], idx: usize) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: _mm_prefetch is a non-faulting hint — the address is
    // never dereferenced; callers pass vertex ids < n = arr.len().
    unsafe {
        core::arch::x86_64::_mm_prefetch(
            arr.as_ptr().add(idx) as *const i8,
            core::arch::x86_64::_MM_HINT_T0,
        );
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (arr, idx);
    }
}

/// Single-pass Algorithm 2 + relabel: scan `I` then `J`, assigning the
/// next label at each first appearance and writing the relabeled
/// endpoint immediately.
pub fn sequential_relabel(coo: &Coo) -> (Permutation, Coo) {
    let n = coo.n();
    let m = coo.m();
    let mut label = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut new_src = Vec::with_capacity(m);
    let mut new_dst = Vec::with_capacity(m);
    let src = &coo.src;
    let dst = &coo.dst;
    for e in 0..m {
        if e + PF_DIST < m {
            prefetch_u32(&label, src[e + PF_DIST] as usize);
        }
        let slot = &mut label[src[e] as usize];
        if *slot == u32::MAX {
            *slot = next;
            next += 1;
        }
        new_src.push(*slot);
    }
    for e in 0..m {
        if e + PF_DIST < m {
            prefetch_u32(&label, dst[e + PF_DIST] as usize);
        }
        let slot = &mut label[dst[e] as usize];
        if *slot == u32::MAX {
            *slot = next;
            next += 1;
        }
        new_dst.push(*slot);
    }
    // Isolated vertices: labels appended in ID order.
    for slot in label.iter_mut() {
        if *slot == u32::MAX {
            *slot = next;
            next += 1;
        }
    }
    let mut out = Coo::new(n, new_src, new_dst);
    out.vals = coo.vals.clone();
    (Permutation::from_new_of_old(label), out)
}

/// Algorithm 2: scan `I` then `J`, emit each vertex the first time it is
/// seen. Vertices in no edge (the paper precondition excludes them; we
/// tolerate them) are appended at the end in ID order.
pub fn sequential(coo: &Coo) -> Permutation {
    let n = coo.n();
    let mut order = Vec::with_capacity(n);
    let mut seen = vec![false; n];
    for &v in coo.src.iter().chain(coo.dst.iter()) {
        let vi = v as usize;
        if !seen[vi] {
            seen[vi] = true;
            order.push(v);
            if order.len() == n {
                return Permutation::from_order(&order);
            }
        }
    }
    // Isolated vertices (not covered by the paper's precondition).
    for v in 0..n as u32 {
        if !seen[v as usize] {
            order.push(v);
        }
    }
    Permutation::from_order(&order)
}

/// Algorithm 3: for every position `i` of the flattened edge list `I++J`
/// in parallel, record `i` into the owning vertex's slot if smaller
/// (racy or atomic); then rank-compact the records into a permutation
/// ("ParMapKeys" in the paper).
fn parallel_records(coo: &Coo, use_atomic: bool) -> Permutation {
    let n = coo.n();
    let m = coo.m();
    // One worker ⇒ the chunked scan degenerates to Algorithm 2's stable
    // scan anyway; take the cheaper direct path (§Perf: 25 → 14.5 ms on
    // rmat18 on the 1-core testbed).
    if parallel::threads() == 1 || m < (1 << 14) {
        return sequential(coo);
    }
    let records = AtomicU32Array::new(n, u32::MAX);
    let chunk = parallel::default_chunk(2 * m);
    // One logical loop over [0, 2m): first half reads I, second half J —
    // matching Algorithm 3's flattened indexing so recorded indices are
    // comparable across the two arrays.
    let src = &coo.src;
    let dst = &coo.dst;
    parallel::par_for_chunks(2 * m, chunk, |lo, hi| {
        // Split the chunk at the I/J boundary to keep the inner loops
        // branch-free (hot path; see docs/EXPERIMENTS.md §Perf).
        let (i_lo, i_hi) = (lo.min(m), hi.min(m));
        if use_atomic {
            for i in i_lo..i_hi {
                records.atomic_min(src[i] as usize, i as u32);
            }
            for i in lo.max(m)..hi.max(m) {
                records.atomic_min(dst[i - m] as usize, i as u32);
            }
        } else {
            for i in i_lo..i_hi {
                records.racy_min(src[i] as usize, i as u32);
            }
            for i in lo.max(m)..hi.max(m) {
                records.racy_min(dst[i - m] as usize, i as u32);
            }
        }
    });
    rank_compact(records.into_vec())
}

/// Turn the records table `r` (vertex → appearance index, `u32::MAX` for
/// isolated vertices) into a dense permutation: vertices sorted by
/// record value; isolated vertices last, by ID. Records are unique by
/// construction (each flattened cell owns one vertex), so the sort key is
/// unambiguous. The paper's `ParMapKeys(p, r)`.
///
/// Implemented as a 2-pass LSD radix sort on the 32-bit record (16-bit
/// digits, carrying the vertex payload) — ~2.5× faster than the u64
/// comparison sort it replaced (§Perf). Stability of LSD radix keeps
/// equal-record (i.e. only the u32::MAX isolated bucket) vertices in ID
/// order, preserving the documented tie-break.
fn rank_compact(records: Vec<u32>) -> Permutation {
    let n = records.len();
    let mut idx: Vec<u32> = (0..n as u32).collect();
    let mut tmp = vec![0u32; n];
    for shift in [0u32, 16u32] {
        let mut hist = vec![0u32; 1 << 16];
        for &v in idx.iter() {
            hist[((records[v as usize] >> shift) & 0xFFFF) as usize] += 1;
        }
        let mut acc = 0u32;
        for h in hist.iter_mut() {
            let c = *h;
            *h = acc;
            acc += c;
        }
        for &v in idx.iter() {
            let d = ((records[v as usize] >> shift) & 0xFFFF) as usize;
            tmp[hist[d] as usize] = v;
            hist[d] += 1;
        }
        std::mem::swap(&mut idx, &mut tmp);
    }
    Permutation::from_order(&idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{self, GenParams};
    use crate::parallel::ThreadGuard;

    #[test]
    fn sequential_first_appearance_order() {
        // I = [3,1,3], J = [1,2,0] -> first appearances: 3,1,2,0
        let coo = Coo::new(4, vec![3, 1, 3], vec![1, 2, 0]);
        let p = sequential(&coo);
        assert_eq!(p.order(), vec![3, 1, 2, 0]);
    }

    #[test]
    fn sequential_early_exit_when_i_covers_all() {
        // All vertices appear in I.
        let coo = Coo::new(3, vec![2, 0, 1], vec![0, 1, 2]);
        let p = sequential(&coo);
        assert_eq!(p.order(), vec![2, 0, 1]);
    }

    #[test]
    fn isolated_vertices_appended() {
        let coo = Coo::new(5, vec![3], vec![1]);
        let p = sequential(&coo);
        assert_eq!(p.order(), vec![3, 1, 0, 2, 4]);
        p.validate(5).unwrap();
    }

    #[test]
    fn atomic_parallel_equals_sequential() {
        let g = gen::rmat(&GenParams::rmat(12, 8), 42).randomized(3);
        let p_seq = Boba::sequential().reorder(&g);
        let p_par = Boba::parallel_atomic().reorder(&g);
        assert_eq!(p_seq, p_par);
    }

    #[test]
    fn atomic_parallel_equals_sequential_many_seeds() {
        for seed in 0..5 {
            let g = gen::preferential_attachment(2000, 3, seed).randomized(seed + 1);
            assert_eq!(
                Boba::sequential().reorder(&g),
                Boba::parallel_atomic().reorder(&g),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn racy_parallel_is_valid_permutation() {
        let g = gen::rmat(&GenParams::rmat(13, 8), 7).randomized(1);
        let p = Boba::parallel().reorder(&g);
        p.validate(g.n()).unwrap();
    }

    #[test]
    fn racy_single_thread_equals_sequential() {
        // With one worker the racy scan degenerates to the stable scan.
        let _g = ThreadGuard::pin(1);
        let g = gen::grid_road(40, 40, 5).randomized(2);
        assert_eq!(Boba::sequential().reorder(&g), Boba::parallel().reorder(&g));
    }

    #[test]
    fn racy_records_are_appearance_positions() {
        // Property: for every vertex, its new rank orders by SOME position
        // where it appears in I++J. Verify via round-trip: relabel, then
        // the vertex at new ID 0 must appear at the earliest recorded cell
        // of some thread's view — weaker check: every vertex's rank is
        // consistent with at least one appearance (it appears at all).
        let g = gen::uniform_random(300, 2000, 9);
        let p = Boba::parallel().reorder(&g);
        let order = p.order();
        let deg = g.total_degrees();
        // Non-isolated vertices must all precede isolated ones.
        let first_isolated = order.iter().position(|&v| deg[v as usize] == 0);
        if let Some(k) = first_isolated {
            assert!(order[k..].iter().all(|&v| deg[v as usize] == 0));
        }
    }

    #[test]
    fn figure1_star_centers_land_early() {
        // Paper Figure 1: two adjacent star centers a=0, b=1 with 5 leaves
        // each. In the edge list (a,b),(a,leaves...),(b,leaves...), BOBA
        // places a and b in the first two positions.
        let g = gen::double_star(5);
        let p = Boba::sequential().reorder(&g);
        let order = p.order();
        assert_eq!(order[0], 0);
        assert_eq!(order[1], 1);
    }

    #[test]
    fn figure3_road_example() {
        // Paper Figure 3's moral: on a road-like path graph sorted by
        // destination, BOBA keeps edge-adjacent vertices nearby. Build a
        // path 0-1-2-...-9 with randomized labels, reorder, and check the
        // max label distance across edges ("bandwidth") shrinks vs random.
        let n = 200;
        let src: Vec<u32> = (0..n as u32 - 1).collect();
        let dst: Vec<u32> = (1..n as u32).collect();
        let path = Coo::new(n, src, dst).randomized(11);
        let p = Boba::sequential().reorder(&path);
        let relab = path.relabeled(p.new_of_old());
        let bw_boba = relab
            .edges()
            .map(|(u, v)| (u as i64 - v as i64).unsigned_abs())
            .max()
            .unwrap();
        let bw_rand = path
            .edges()
            .map(|(u, v)| (u as i64 - v as i64).unsigned_abs())
            .max()
            .unwrap();
        assert!(bw_boba < bw_rand, "boba {bw_boba} rand {bw_rand}");
        // On a path listed in src order, BOBA is near-perfect: the scan of
        // I yields path order exactly.
        assert!(bw_boba <= 2, "bw {bw_boba}");
    }

    #[test]
    fn fused_relabel_matches_two_stage() {
        for seed in 0..5 {
            let g = gen::rmat(&GenParams::rmat(11, 8), seed).randomized(seed + 1);
            let (p, relab) = Boba::parallel().reorder_relabel(&g);
            let p2 = sequential(&g);
            assert_eq!(p, p2, "seed {seed}");
            assert_eq!(relab, g.relabeled(p2.new_of_old()), "seed {seed}");
        }
    }

    #[test]
    fn fused_relabel_handles_isolated_and_vals() {
        let g = Coo::with_vals(5, vec![3], vec![1], vec![2.5]);
        let (p, relab) = Boba::sequential().reorder_relabel(&g);
        p.validate(5).unwrap();
        assert_eq!(relab.src, vec![0]);
        assert_eq!(relab.dst, vec![1]);
        assert_eq!(relab.vals, Some(vec![2.5]));
    }

    #[test]
    fn reorder_time_scales_linearly_ish() {
        // Smoke check that parallel BOBA handles a million-edge graph.
        let g = gen::rmat(&GenParams::rmat(16, 16), 1).randomized(2);
        let t = std::time::Instant::now();
        let p = Boba::parallel().reorder(&g);
        let dt = t.elapsed();
        p.validate(g.n()).unwrap();
        assert!(dt.as_secs() < 30, "took {dt:?}");
    }
}
