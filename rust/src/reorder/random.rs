//! Random relabeling — the paper's baseline input model (§5: datasets are
//! randomized before every experiment, so "Rand" columns are the
//! unreordered reference).

use super::perm::Permutation;
use super::Reorderer;
use crate::graph::Coo;
use crate::util::prng::Xoshiro256;

/// Uniformly random permutation of vertex IDs.
#[derive(Clone, Debug)]
pub struct RandomOrder {
    seed: u64,
}

impl RandomOrder {
    /// Create with a seed (deterministic per seed).
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }
}

impl Reorderer for RandomOrder {
    fn name(&self) -> &'static str {
        "Random"
    }

    fn reorder(&self, coo: &Coo) -> Permutation {
        let mut rng = Xoshiro256::new(self.seed);
        Permutation::from_new_of_old(rng.permutation(coo.n()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn produces_valid_permutation() {
        let g = gen::uniform_random(100, 300, 1);
        let p = RandomOrder::new(5).reorder(&g);
        p.validate(100).unwrap();
    }

    #[test]
    fn deterministic_per_seed() {
        let g = gen::uniform_random(50, 100, 1);
        assert_eq!(RandomOrder::new(3).reorder(&g), RandomOrder::new(3).reorder(&g));
        assert_ne!(RandomOrder::new(3).reorder(&g), RandomOrder::new(4).reorder(&g));
    }
}
