//! Reverse Cuthill–McKee (Cuthill & McKee 1969) — the paper's first
//! heavyweight baseline (§3.1.1), a bandwidth-reduction heuristic:
//! BFS from a peripheral low-degree vertex, visiting each level's
//! neighbors in ascending-degree order, then reverse the visit order.
//! Runtime `O(deg_max · |E|)` dominated by the per-vertex neighbor sorts.
//!
//! RCM is defined on undirected graphs; directed inputs are symmetrized
//! first (as MATLAB's `symrcm`, the tool the paper used, does).

use super::perm::Permutation;
use super::Reorderer;
use crate::convert::coo_to_csr;
use crate::graph::{Coo, Csr};

/// Reverse Cuthill–McKee reorderer.
#[derive(Clone, Debug, Default)]
pub struct Rcm;

impl Rcm {
    /// Create.
    pub fn new() -> Self {
        Self
    }
}

impl Reorderer for Rcm {
    fn name(&self) -> &'static str {
        "RCM"
    }

    fn lightweight(&self) -> bool {
        false
    }

    fn reorder(&self, coo: &Coo) -> Permutation {
        let adj = coo_to_csr(&coo.symmetrized().deduped());
        rcm_order(&adj)
    }
}

/// Pseudo-peripheral vertex: repeated BFS, hopping to a min-degree vertex
/// of the last level until eccentricity stops growing (George–Liu).
fn pseudo_peripheral(adj: &Csr, start: u32, visited_scratch: &mut Vec<u32>) -> u32 {
    let mut root = start;
    let mut last_ecc = 0usize;
    // `visited_scratch` holds a BFS epoch stamp per vertex to avoid
    // reallocating a bitmap per call.
    loop {
        let (levels, ecc) = bfs_levels(adj, root, visited_scratch);
        if ecc <= last_ecc && last_ecc > 0 {
            return root;
        }
        last_ecc = ecc;
        // Min-degree vertex of the last level.
        let next = levels
            .iter()
            .copied()
            .min_by_key(|&v| adj.degree(v as usize))
            .unwrap_or(root);
        if next == root {
            return root;
        }
        root = next;
    }
}

/// BFS from `root`; returns the final level's vertices and eccentricity.
fn bfs_levels(adj: &Csr, root: u32, stamp: &mut Vec<u32>) -> (Vec<u32>, usize) {
    // Fresh epoch: bump all stamps lazily by using root as epoch marker is
    // fragile; simplest correct approach: clear via fill (O(n), called a
    // bounded number of times per component).
    stamp.fill(0);
    stamp[root as usize] = 1;
    let mut frontier = vec![root];
    let mut ecc = 0;
    let mut last = frontier.clone();
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &v in &frontier {
            for &u in adj.neighbors(v as usize) {
                if stamp[u as usize] == 0 {
                    stamp[u as usize] = 1;
                    next.push(u);
                }
            }
        }
        if next.is_empty() {
            last = frontier;
            break;
        }
        ecc += 1;
        last = next.clone();
        frontier = next;
    }
    (last, ecc)
}

/// Full RCM over all components.
pub fn rcm_order(adj: &Csr) -> Permutation {
    let n = adj.n();
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut scratch = vec![0u32; n];

    // Process components in order of their min-ID vertex.
    for seed in 0..n as u32 {
        if visited[seed as usize] {
            continue;
        }
        // Isolated vertices are their own component; skip the (O(n) per
        // call) peripheral search for them.
        if adj.degree(seed as usize) == 0 {
            visited[seed as usize] = true;
            order.push(seed);
            continue;
        }
        let root = pseudo_peripheral(adj, seed, &mut scratch);
        // Cuthill–McKee BFS: queue ordered, neighbors appended by
        // ascending degree.
        let mut queue = std::collections::VecDeque::new();
        visited[root as usize] = true;
        queue.push_back(root);
        let mut nbrs: Vec<u32> = Vec::new();
        while let Some(v) = queue.pop_front() {
            order.push(v);
            nbrs.clear();
            nbrs.extend(
                adj.neighbors(v as usize)
                    .iter()
                    .copied()
                    .filter(|&u| !visited[u as usize]),
            );
            nbrs.sort_unstable_by_key(|&u| adj.degree(u as usize));
            for &u in &nbrs {
                if !visited[u as usize] {
                    visited[u as usize] = true;
                    queue.push_back(u);
                }
            }
        }
    }
    order.reverse(); // the "R" in RCM
    Permutation::from_order(&order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::metrics::bandwidth;

    #[test]
    fn valid_permutation_multi_component() {
        // Two disjoint triangles.
        let g = Coo::new(6, vec![0, 1, 2, 3, 4, 5], vec![1, 2, 0, 4, 5, 3]);
        let p = Rcm::new().reorder(&g);
        p.validate(6).unwrap();
    }

    #[test]
    fn reduces_bandwidth_on_randomized_path() {
        let n = 500u32;
        let src: Vec<u32> = (0..n - 1).collect();
        let dst: Vec<u32> = (1..n).collect();
        let g = Coo::new(n as usize, src, dst).randomized(13);
        let p = Rcm::new().reorder(&g);
        let h = g.relabeled(p.new_of_old());
        // RCM on a path must recover bandwidth 1 (optimal).
        assert_eq!(bandwidth(&h), 1, "rand bw {}", bandwidth(&g));
    }

    #[test]
    fn reduces_bandwidth_on_mesh() {
        let g = gen::delaunay_mesh(20, 20, 1).randomized(4);
        let p = Rcm::new().reorder(&g);
        let h = g.relabeled(p.new_of_old());
        assert!(bandwidth(&h) * 3 < bandwidth(&g), "bw {} vs {}", bandwidth(&h), bandwidth(&g));
    }

    #[test]
    fn handles_isolated_vertices() {
        let g = Coo::new(4, vec![0], vec![1]); // 2, 3 isolated
        let p = Rcm::new().reorder(&g);
        p.validate(4).unwrap();
    }
}
