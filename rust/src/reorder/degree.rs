//! Full sort by (descending) degree — the classic lightweight scheme the
//! paper's §3.2 describes: place hub vertices first, hoping they form a
//! densely connected, cache-resident subgraph. On uniform-degree graphs
//! this degenerates to (roughly) a random permutation (Figure 3), which
//! is exactly the failure mode the Fig. 6 experiments exhibit.

use super::perm::Permutation;
use super::Reorderer;
use crate::graph::Coo;

/// Sort vertices by total degree, descending; ties broken by original ID
/// (stable), matching the reference reordering tool's behaviour.
#[derive(Clone, Debug, Default)]
pub struct DegreeSort;

impl DegreeSort {
    /// Create.
    pub fn new() -> Self {
        Self
    }
}

impl Reorderer for DegreeSort {
    fn name(&self) -> &'static str {
        "Degree"
    }

    fn reorder(&self, coo: &Coo) -> Permutation {
        let deg = coo.total_degrees();
        let n = coo.n();
        let mut order: Vec<u32> = (0..n as u32).collect();
        // Descending by degree, ascending by ID on ties.
        order.sort_by_key(|&v| (u32::MAX - deg[v as usize], v));
        Permutation::from_order(&order)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn hubs_first() {
        let g = gen::double_star(4); // vertices 0,1 have degree 5
        let p = DegreeSort::new().reorder(&g);
        let order = p.order();
        assert_eq!(&order[..2], &[0, 1]);
    }

    #[test]
    fn ties_stable_by_id() {
        // 3 vertices all degree 1 (a triangle has degree 2 each).
        let g = Coo::new(4, vec![0, 1, 2, 3], vec![1, 0, 3, 2]);
        let p = DegreeSort::new().reorder(&g);
        assert_eq!(p.order(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn descending_degree_invariant() {
        let g = gen::preferential_attachment(400, 3, 8).randomized(2);
        let p = DegreeSort::new().reorder(&g);
        let deg = g.total_degrees();
        let order = p.order();
        for w in order.windows(2) {
            assert!(deg[w[0] as usize] >= deg[w[1] as usize]);
        }
    }
}
