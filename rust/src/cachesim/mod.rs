//! Trace-driven cache simulator — the stand-in for the paper's GPU
//! profiler counters (Fig. 7 measures L1/L2 hit rates and the share of
//! transactions served by DRAM with nvprof on a V100).
//!
//! [`Cache`] is a set-associative LRU cache; [`Hierarchy`] stacks an
//! L1 + L2 and counts hits per level. The default geometry mirrors the
//! paper's V100: 128 KiB L1 (one SM's unified cache), 6 MiB L2, 128-byte
//! lines. The simulator consumes the synthetic address streams emitted by
//! the `*_traced` kernels in [`crate::algos`]; what it preserves from the
//! real hardware is exactly what Fig. 7 compares — the *relative* hit
//! rates of reordering schemes on the same kernel, which are a function
//! of the access pattern, not of GPU microarchitecture details.
//!
//! ```
//! use boba::cachesim::Hierarchy;
//!
//! let mut h = Hierarchy::cpu_like(); // 64 B lines
//! h.access(0); // cold miss
//! h.access(4); // same line: L1 hit
//! let r = h.rates();
//! assert_eq!(r.reads, 2);
//! assert!((r.l1 - 0.5).abs() < 1e-9);
//! ```

use crate::algos::trace::Tracer;

/// One set-associative LRU cache level.
#[derive(Clone, Debug)]
pub struct Cache {
    sets: Vec<Vec<(u64, u64)>>, // per set: (tag, last-use stamp)
    assoc: usize,
    line_bits: u32,
    set_mask: u64,
    clock: u64,
    /// Number of accesses that hit this level.
    pub hits: u64,
    /// Number of accesses that missed this level.
    pub misses: u64,
}

impl Cache {
    /// Build a cache of `size_bytes` with `assoc` ways and `line_bytes`
    /// lines (both powers of two).
    pub fn new(size_bytes: usize, assoc: usize, line_bytes: usize) -> Self {
        assert!(line_bytes.is_power_of_two() && assoc >= 1);
        let lines = size_bytes / line_bytes;
        // Sets need not be a power of two (the V100's 6 MiB L2 yields
        // 3072); indexing uses modulo, tags keep the full line address.
        let nsets = (lines / assoc).max(1);
        Self {
            sets: vec![Vec::with_capacity(assoc); nsets],
            assoc,
            line_bits: line_bytes.trailing_zeros(),
            set_mask: nsets as u64,
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Access `addr`; returns true on hit. Misses fill (allocate-on-read).
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        let line = addr >> self.line_bits;
        let set = (line % self.set_mask) as usize;
        let tag = line;
        let ways = &mut self.sets[set];
        if let Some(slot) = ways.iter_mut().find(|(t, _)| *t == tag) {
            slot.1 = self.clock;
            self.hits += 1;
            return true;
        }
        self.misses += 1;
        if ways.len() < self.assoc {
            ways.push((tag, self.clock));
        } else {
            // Evict LRU.
            let lru = ways
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, ts))| *ts)
                .map(|(i, _)| i)
                .unwrap();
            ways[lru] = (tag, self.clock);
        }
        false
    }

    /// Hit rate in [0, 1]; 0 if no accesses.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Reset counters (keeps contents).
    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

/// L1 + L2 hierarchy with DRAM fraction, V100-flavoured defaults.
#[derive(Clone, Debug)]
pub struct Hierarchy {
    /// Level-1 cache.
    pub l1: Cache,
    /// Level-2 cache.
    pub l2: Cache,
}

/// Hit-rate summary for one traced run (one Fig. 7 bar group).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HitRates {
    /// L1 read hit rate.
    pub l1: f64,
    /// L2 read hit rate (of L1 misses).
    pub l2: f64,
    /// Fraction of all reads served by DRAM.
    pub dram_fraction: f64,
    /// Total reads traced.
    pub reads: u64,
}

impl Default for Hierarchy {
    fn default() -> Self {
        Self::v100_like()
    }
}

impl Hierarchy {
    /// The paper's GPU: per-SM 128 KiB L1 (4-way here), 6 MiB L2
    /// (16-way), 128 B lines.
    pub fn v100_like() -> Self {
        Self { l1: Cache::new(128 << 10, 4, 128), l2: Cache::new(6 << 20, 16, 128) }
    }

    /// A CPU-ish hierarchy (32 KiB L1/8-way, 1 MiB L2/16-way, 64 B
    /// lines) used to show the effect reproduces across cache shapes
    /// (the paper: "improves cache locality on both CPUs and GPUs").
    pub fn cpu_like() -> Self {
        Self { l1: Cache::new(32 << 10, 8, 64), l2: Cache::new(1 << 20, 16, 64) }
    }

    /// The V100 geometry scaled 8× down (16 KiB L1, 768 KiB L2 — the
    /// same 48:1 L2:L1 ratio and 128 B lines). Fig. 7 runs use this
    /// because our datasets are 16–64× smaller than the paper's; keeping
    /// the cache:working-set ratio comparable keeps the hit-rate contrast
    /// comparable (docs/EXPERIMENTS.md documents the scaling).
    pub fn v100_scaled() -> Self {
        Self { l1: Cache::new(16 << 10, 4, 128), l2: Cache::new(768 << 10, 16, 128) }
    }

    /// Access an address through L1 → L2.
    #[inline]
    pub fn access(&mut self, addr: u64) {
        if !self.l1.access(addr) {
            self.l2.access(addr);
        }
    }

    /// Summarize hit rates.
    pub fn rates(&self) -> HitRates {
        let reads = self.l1.hits + self.l1.misses;
        let dram = self.l2.misses;
        HitRates {
            l1: self.l1.hit_rate(),
            l2: self.l2.hit_rate(),
            dram_fraction: if reads == 0 { 0.0 } else { dram as f64 / reads as f64 },
            reads,
        }
    }
}

impl Tracer for Hierarchy {
    #[inline]
    fn read(&mut self, addr: u64) {
        self.access(addr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_hits_within_lines() {
        // 32 4-byte elements per 128B line: 31/32 of a linear scan hits.
        let mut c = Cache::new(128 << 10, 4, 128);
        for i in 0..32 * 1024u64 {
            c.access(i * 4);
        }
        let hr = c.hit_rate();
        assert!((hr - 31.0 / 32.0).abs() < 0.01, "hr {hr}");
    }

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1 << 10, 2, 64);
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63));
        assert!(!c.access(64));
    }

    #[test]
    fn lru_eviction_order() {
        // 2-way set: A, B fill; touching A then inserting C must evict B.
        let mut c = Cache::new(128, 2, 64); // 1 set, 2 ways
        let a = 0u64;
        let b = 1 << 20;
        let cc = 2 << 20;
        c.access(a);
        c.access(b);
        c.access(a); // A is MRU
        c.access(cc); // evicts B
        assert!(c.access(a), "A should remain");
        assert!(!c.access(b), "B should have been evicted");
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = Cache::new(4 << 10, 4, 64);
        // Cyclic scan of 64 KiB >> 4 KiB cache with LRU = ~0% hits.
        for _ in 0..4 {
            for i in 0..(64 << 10) / 64u64 {
                c.access(i * 64);
            }
        }
        assert!(c.hit_rate() < 0.05, "hr {}", c.hit_rate());
    }

    #[test]
    fn hierarchy_l2_catches_l1_evictions() {
        let mut h = Hierarchy::v100_like();
        // Working set of 1 MiB: misses L1 (128 KiB) on wrap, fits L2.
        let lines = (1 << 20) / 128u64;
        for _ in 0..3 {
            for i in 0..lines {
                h.access(i * 128);
            }
        }
        let r = h.rates();
        assert!(r.l2 > 0.5, "l2 {r:?}");
        assert!(r.dram_fraction < 0.4, "{r:?}");
    }

    #[test]
    fn random_vs_local_access_ordering() {
        // The core phenomenon behind the whole paper: clustered gathers
        // beat scattered gathers.
        use crate::util::prng::Xoshiro256;
        let mut rng = Xoshiro256::new(1);
        let n = 1 << 20;
        let mut local = Hierarchy::v100_like();
        let mut scattered = Hierarchy::v100_like();
        for k in 0..200_000u64 {
            // local: addresses drift slowly
            local.access(((k / 8) * 128 % (n * 4)) | 0);
            scattered.access(rng.below(n) * 4);
        }
        assert!(local.rates().l1 > scattered.rates().l1 + 0.3);
    }

    #[test]
    fn rates_zero_when_untouched() {
        let h = Hierarchy::v100_like();
        let r = h.rates();
        assert_eq!(r.reads, 0);
        assert_eq!(r.l1, 0.0);
    }
}
