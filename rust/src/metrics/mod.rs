//! Locality metrics from the paper: NBR (§5.2, Table 1), NScore (Model 7),
//! GScore (Model 6, Wei et al.), and matrix bandwidth (§3.1.1).
//!
//! All metrics are functions of the *labeled* graph — apply a reordering
//! first ([`crate::graph::Coo::relabeled`]) and compare metric values
//! across schemes, as Table 1 does.
//!
//! ```
//! use boba::graph::Coo;
//! use boba::metrics::{bandwidth, nscore};
//!
//! // A path graph labeled in path order has optimal bandwidth 1.
//! let path = Coo::new(4, vec![0, 1, 2], vec![1, 2, 3]);
//! assert_eq!(bandwidth(&path), 1);
//! // Vertices 0 and 1 share out-neighbors {2, 3}: NScore counts both.
//! let g = Coo::new(4, vec![0, 0, 1, 1], vec![2, 3, 2, 3]);
//! assert_eq!(nscore(&g), 2);
//! ```

use crate::convert::coo_to_csr;
use crate::graph::{Coo, Csr};
use std::collections::HashSet;

/// Cache line size (in vertex IDs) used by NBR: 128-byte GPU cache lines
/// over 4-byte IDs, the paper's setting.
pub const IDS_PER_LINE: u64 = 32;

/// NBR(G) — the paper's spatial-locality metric (§5.2): the expected
/// ratio of cache lines spanned by a vertex's neighborhood to its size,
/// averaged over vertices with at least one neighbor. Lower is better.
///
/// "Lines spanned" counts *distinct* cache lines touched by the
/// neighborhood's IDs with a 128-byte line (32 × u32 IDs).
pub fn nbr(csr: &Csr) -> f64 {
    nbr_lines(csr, IDS_PER_LINE)
}

/// NBR with an explicit line size (in IDs per line).
pub fn nbr_lines(csr: &Csr, ids_per_line: u64) -> f64 {
    let n = csr.n();
    let mut total = 0.0;
    let mut counted = 0usize;
    let mut lines: HashSet<u64> = HashSet::new();
    for v in 0..n {
        let nb = csr.neighbors(v);
        if nb.is_empty() {
            continue;
        }
        lines.clear();
        for &u in nb {
            lines.insert(u as u64 / ids_per_line);
        }
        total += lines.len() as f64 / nb.len() as f64;
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        total / counted as f64
    }
}

/// NBR straight from a COO (converts internally; Table 1 reports "NBR
/// over CSR").
pub fn nbr_coo(coo: &Coo) -> f64 {
    nbr(&coo_to_csr(coo))
}

/// NScore(G, p) for the *current* labeling (Model 7): sum over
/// consecutive vertex IDs of shared out-neighbor counts,
/// `Σ_{i=1}^{n-1} |N(i) ∩ N(i+1)|`.
pub fn nscore(coo: &Coo) -> u64 {
    nscore_csr(&coo_to_csr(coo))
}

/// NScore over a prebuilt CSR (rows need not be sorted; sorting is done
/// on local copies).
pub fn nscore_csr(csr: &Csr) -> u64 {
    let n = csr.n();
    if n < 2 {
        return 0;
    }
    let mut total = 0u64;
    let mut a: Vec<u32> = Vec::new();
    let mut b: Vec<u32> = Vec::new();
    for i in 0..n - 1 {
        a.clear();
        a.extend_from_slice(csr.neighbors(i));
        a.sort_unstable();
        a.dedup();
        b.clear();
        b.extend_from_slice(csr.neighbors(i + 1));
        b.sort_unstable();
        b.dedup();
        total += sorted_intersection_count(&a, &b);
    }
    total
}

/// GScore(G, w) (Model 6): windowed generalization —
/// `Σ_i Σ_{j=max(1,i-w)}^{i-1} s(v_i, v_j)` with
/// `s(u,v) = |N(u) ∩ N(v)| + |{uv,vu} ∩ E|`.
pub fn gscore(coo: &Coo, w: usize) -> u64 {
    let csr = {
        let mut c = coo_to_csr(&coo.deduped());
        c.sort_rows();
        c
    };
    let n = csr.n();
    let mut total = 0u64;
    for i in 0..n {
        for j in i.saturating_sub(w)..i {
            let shared =
                sorted_intersection_count(csr.neighbors(i), csr.neighbors(j));
            let uv = csr.neighbors(i).binary_search(&(j as u32)).is_ok() as u64;
            let vu = csr.neighbors(j).binary_search(&(i as u32)).is_ok() as u64;
            total += shared + uv + vu;
        }
    }
    total
}

/// Matrix bandwidth (§3.1.1): `max_{uv ∈ E} |p(u) - p(v)|` under the
/// current labeling.
pub fn bandwidth(coo: &Coo) -> u64 {
    coo.edges()
        .map(|(u, v)| (u as i64 - v as i64).unsigned_abs())
        .max()
        .unwrap_or(0)
}

/// Average per-edge label distance — a smoother locality signal than the
/// max; used by the spy-plot example's captions.
pub fn avg_edge_distance(coo: &Coo) -> f64 {
    if coo.m() == 0 {
        return 0.0;
    }
    let s: u64 = coo
        .edges()
        .map(|(u, v)| (u as i64 - v as i64).unsigned_abs())
        .sum();
    s as f64 / coo.m() as f64
}

/// |A ∩ B| for sorted, deduped slices.
fn sorted_intersection_count(a: &[u32], b: &[u32]) -> u64 {
    let (mut i, mut j, mut c) = (0usize, 0usize, 0u64);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

/// Lemma 8's upper bound: NScore(G, p*) ≤ m. Exposed so property tests
/// and the theory benches can assert it.
pub fn nscore_upper_bound(coo: &Coo) -> u64 {
    coo.m() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn intersection_counts() {
        assert_eq!(sorted_intersection_count(&[1, 3, 5], &[2, 3, 5, 9]), 2);
        assert_eq!(sorted_intersection_count(&[], &[1]), 0);
        assert_eq!(sorted_intersection_count(&[7], &[7]), 1);
    }

    #[test]
    fn bandwidth_path_identity() {
        let n = 10u32;
        let g = Coo::new(10, (0..n - 1).collect(), (1..n).collect());
        assert_eq!(bandwidth(&g), 1);
        let r = g.randomized(3);
        assert!(bandwidth(&r) > 1);
    }

    #[test]
    fn nscore_of_shared_neighbor_pair() {
        // 0 and 1 both point to 2 and 3; consecutive labels 0,1 share 2.
        let g = Coo::new(4, vec![0, 0, 1, 1], vec![2, 3, 2, 3]);
        assert_eq!(nscore(&g), 2);
    }

    #[test]
    fn nscore_respects_lemma8() {
        for seed in 0..5 {
            let g = gen::uniform_random(100, 600, seed);
            assert!(nscore(&g) <= nscore_upper_bound(&g));
        }
    }

    #[test]
    fn gscore_window_contains_nscore_pairs() {
        // GScore(w=1) >= NScore because s() adds the edge indicator.
        let g = gen::preferential_attachment(200, 3, 1).randomized(2);
        assert!(gscore(&g, 1) >= nscore(&g.deduped()));
    }

    #[test]
    fn nbr_identity_mesh_beats_random() {
        // Row-major mesh labels are spatially local: NBR must beat the
        // randomized labeling clearly (this is Table 1's core contrast).
        let g = gen::delaunay_mesh(40, 40, 2);
        let nat = nbr_coo(&g);
        let rnd = nbr_coo(&g.randomized(5));
        assert!(nat < 0.8 * rnd, "natural {nat} vs random {rnd}");
    }

    #[test]
    fn nbr_perfect_locality_low() {
        // Every vertex's neighbors in one line -> NBR = 1/deg ... with
        // deg 4 inside one line: lines=1, |N|=4 -> 0.25.
        let g = Coo::new(
            8,
            vec![0, 0, 0, 0],
            vec![1, 2, 3, 4],
        );
        let csr = coo_to_csr(&g);
        assert!((nbr(&csr) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn nbr_range() {
        let g = gen::rmat(&gen::GenParams::rmat(10, 8), 3).randomized(1);
        let v = nbr_coo(&g);
        assert!(v > 0.0 && v <= 1.0, "nbr {v}");
    }

    #[test]
    fn avg_edge_distance_path() {
        let g = Coo::new(5, vec![0, 1, 2, 3], vec![1, 2, 3, 4]);
        assert!((avg_edge_distance(&g) - 1.0).abs() < 1e-12);
    }
}
