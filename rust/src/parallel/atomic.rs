//! Atomic arrays used by the parallel reordering kernels.
//!
//! Algorithm 3 in the paper records, for every vertex, an index into the
//! flattened edge list `I++J`. The GPU implementation lets these records
//! race (any appearance index is acceptable); an `AtomicMin` variant
//! recovers the sequential first-appearance semantics at some cost. Both
//! variants exist here, and [`AtomicU32Array`] is the shared record table.

use std::sync::atomic::{AtomicU32, Ordering};

/// A fixed-size array of `AtomicU32` with min/CAS helpers.
pub struct AtomicU32Array {
    data: Vec<AtomicU32>,
}

impl AtomicU32Array {
    /// Create with every slot set to `init`.
    pub fn new(len: usize, init: u32) -> Self {
        let mut data = Vec::with_capacity(len);
        for _ in 0..len {
            data.push(AtomicU32::new(init));
        }
        Self { data }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Relaxed load.
    #[inline]
    pub fn load(&self, i: usize) -> u32 {
        self.data[i].load(Ordering::Relaxed)
    }

    /// Relaxed store.
    #[inline]
    pub fn store(&self, i: usize, v: u32) {
        self.data[i].store(v, Ordering::Relaxed)
    }

    /// Racy conditional store: `if v < slot { slot = v }` WITHOUT
    /// atomicity of the read-modify-write (two relaxed ops). This is the
    /// paper's non-atomic Algorithm 3 line 4/6: last writer wins, but any
    /// recorded value is a valid appearance index.
    #[inline]
    pub fn racy_min(&self, i: usize, v: u32) {
        if v < self.data[i].load(Ordering::Relaxed) {
            self.data[i].store(v, Ordering::Relaxed);
        }
    }

    /// True atomic fetch-min (`fetch_min` is stable on AtomicU32).
    /// Recovers the sequential first-appearance order; the paper found
    /// the quality gain not worth the cost — we benchmark both.
    #[inline]
    pub fn atomic_min(&self, i: usize, v: u32) {
        self.data[i].fetch_min(v, Ordering::Relaxed);
    }

    /// Consume into a plain vector.
    pub fn into_vec(self) -> Vec<u32> {
        self.data.into_iter().map(|a| a.into_inner()).collect()
    }

    /// Snapshot to a plain vector.
    pub fn to_vec(&self) -> Vec<u32> {
        self.data.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::par_for_chunks;

    #[test]
    fn atomic_min_finds_global_min() {
        let n = 64;
        let arr = AtomicU32Array::new(n, u32::MAX);
        par_for_chunks(100_000, 512, |lo, hi| {
            for i in lo..hi {
                arr.atomic_min(i % n, i as u32);
            }
        });
        for i in 0..n {
            assert_eq!(arr.load(i), i as u32, "slot {i}");
        }
    }

    #[test]
    fn racy_min_records_some_appearance() {
        // The racy variant may not find the min, but every recorded value
        // must be one that was actually offered.
        let n = 16;
        let arr = AtomicU32Array::new(n, u32::MAX);
        par_for_chunks(10_000, 64, |lo, hi| {
            for i in lo..hi {
                arr.racy_min(i % n, (i * 2) as u32);
            }
        });
        for i in 0..n {
            let v = arr.load(i) as usize;
            // Values offered to slot i are exactly {2(i + k*n)}, so any
            // recorded value is ≡ 2i (mod 2n) and below 20_000.
            assert!(v < 20_000, "slot {i} = {v}");
            assert_eq!(v % (2 * n), 2 * i, "slot {i} = {v}");
        }
    }

    #[test]
    fn into_vec_roundtrip() {
        let arr = AtomicU32Array::new(4, 9);
        arr.store(2, 5);
        assert_eq!(arr.to_vec(), vec![9, 9, 5, 9]);
        assert_eq!(arr.into_vec(), vec![9, 9, 5, 9]);
    }
}
