//! The persistent worker pool behind every `par_*` entry point.
//!
//! Before this module existed, each `par_for_chunks`/`par_reduce`/
//! `par_jobs` call spawned fresh OS threads through `std::thread::scope`
//! and joined them on exit. That is correct but pays thread
//! spawn/teardown (tens of microseconds each) on *every* hot-region
//! entry — BOBA's record scan, the conversion passes, and per-request
//! SpMV rows are all short enough that dispatch dominated memory
//! traffic (docs/EXPERIMENTS.md §Pool quantifies the gap via
//! `benches/micro_pool.rs`).
//!
//! Design (std-only; rayon does not resolve offline):
//!
//! * Workers are spawned lazily on first dispatch and then **persist**
//!   for the life of the process, parked on a `Condvar` wait against a
//!   shared `Mutex`-protected job queue when idle.
//! * A dispatch publishes one task — a lifetime-erased pointer to
//!   the caller's worker closure plus a generation latch — and asks for
//!   `helpers` pool workers. The **caller always participates**: it runs
//!   the same closure itself, so a dispatch never waits for a worker to
//!   become free before making progress, and nested dispatches from pool
//!   workers (e.g. `par_jobs` jobs that call `par_for_chunks`, or server
//!   worker threads entering the substrate) cannot deadlock — in the
//!   worst case the nested caller simply does all the work alone.
//! * [`set_threads`](super::set_threads) / `ThreadGuard` / `BOBA_THREADS`
//!   mask *active* workers per dispatch: the pool may hold more parked
//!   threads than the current pin, but each dispatch asks for at most
//!   `threads() - 1` helpers, so a pin of `n` means at most `n` threads
//!   ever touch one task.
//! * Completion is a generation-counted barrier in miniature: every
//!   dispatch is its own generation (a fresh `Task` carrying the pool's
//!   generation number), and the caller closes the task and blocks on
//!   its latch until the last helper of that generation leaves. Helpers
//!   that pop a closed (stale-generation) task drop it without touching
//!   the closure — which is what makes the lifetime erasure sound.
//!
//! Safety argument for the lifetime erasure: the closure reference is
//! valid for the whole `dispatch` call. A helper only dereferences it
//! after registering itself in the task latch *under the latch lock*
//! while the task is not closed; the caller cannot observe "closed with
//! zero running" (and therefore cannot return and invalidate the
//! closure) until that helper deregisters. Helpers that arrive after
//! close never touch the pointer.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Hard cap on pool threads, a backstop against pathological
/// `BOBA_THREADS` values; dispatches masked above this simply run with
/// fewer helpers.
const MAX_WORKERS: usize = 256;

/// Lifetime-erased shared worker closure (`&dyn Fn(slot)` transmuted to
/// `'static`; see the module-level safety argument).
struct FnPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared across workers by design) and the
// latch protocol guarantees it outlives every dereference.
unsafe impl Send for FnPtr {}
// SAFETY: same argument as Send — the pointee is `Sync`, so shared
// references to it may be dereferenced from any worker concurrently.
unsafe impl Sync for FnPtr {}

/// Latch state of one dispatch generation.
struct TaskState {
    /// Set by the caller once its own share of the work is done; helpers
    /// arriving later drop the task unexecuted.
    closed: bool,
    /// Helpers currently inside the closure.
    running: usize,
    /// First helper panic payload (re-raised in the caller, so the
    /// original message survives the pool crossing like it survives
    /// `std::thread::scope`).
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
}

/// One dispatch generation: the erased closure plus its completion latch.
struct Task {
    func: FnPtr,
    /// Next participant slot (0 = the caller); slots index per-worker
    /// output arrays in `par_reduce`-style consumers.
    next_slot: AtomicUsize,
    state: Mutex<TaskState>,
    done: Condvar,
}

impl Task {
    fn new(func: FnPtr) -> Self {
        Task {
            func,
            next_slot: AtomicUsize::new(0),
            state: Mutex::new(TaskState { closed: false, running: 0, panic_payload: None }),
            done: Condvar::new(),
        }
    }

    fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Helper-side entry: register in the latch, run one share of the
    /// task, deregister. Returns immediately on a closed task.
    fn participate(&self) {
        {
            let mut st = self.state.lock().unwrap();
            if st.closed {
                return;
            }
            st.running += 1;
        }
        let slot = self.next_slot.fetch_add(1, Ordering::Relaxed);
        // SAFETY: `closed` was false while we held the latch lock, so the
        // dispatching caller is still inside `dispatch` and cannot return
        // (invalidating the closure) until `running` returns to zero.
        let func = unsafe { &*self.func.0 };
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| func(slot)));
        let mut st = self.state.lock().unwrap();
        st.running -= 1;
        if let Err(payload) = outcome {
            st.panic_payload.get_or_insert(payload);
        }
        if st.running == 0 {
            self.done.notify_all();
        }
    }

    /// Caller-side barrier: close this generation (pending helpers will
    /// skip it) and wait until every registered helper has left the
    /// closure. Returns the first helper panic payload, if any.
    fn close_and_wait(&self) -> Option<Box<dyn std::any::Any + Send>> {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        while st.running > 0 {
            st = self.done.wait(st).unwrap();
        }
        st.panic_payload.take()
    }
}

/// A queued request for helpers: `remaining` workers may still join
/// `task`'s generation.
struct Entry {
    task: Arc<Task>,
    remaining: usize,
}

/// The process-wide pool.
struct Pool {
    queue: Mutex<VecDeque<Entry>>,
    work: Condvar,
    /// Worker threads spawned so far (monotone; workers never exit).
    spawned: AtomicUsize,
    /// Workers currently inside a task closure (the rest are parked on
    /// the queue condvar).
    busy: AtomicUsize,
    /// Dispatch generations published so far.
    generations: AtomicU64,
}

static POOL: OnceLock<Pool> = OnceLock::new();

impl Pool {
    fn global() -> &'static Pool {
        POOL.get_or_init(|| Pool {
            queue: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            spawned: AtomicUsize::new(0),
            busy: AtomicUsize::new(0),
            generations: AtomicU64::new(0),
        })
    }

    /// Grow the pool to at least `want` workers (capped). Lazy: nothing
    /// is spawned until the first multi-threaded dispatch needs help.
    fn ensure_workers(&'static self, want: usize) {
        let want = want.min(MAX_WORKERS);
        loop {
            let have = self.spawned.load(Ordering::Relaxed);
            if have >= want {
                return;
            }
            if self
                .spawned
                .compare_exchange(have, have + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_err()
            {
                continue; // raced with another dispatcher; re-check
            }
            let spawned = std::thread::Builder::new()
                .name(format!("boba-pool-{have}"))
                .spawn(move || self.worker_loop());
            if spawned.is_err() {
                // Thread exhaustion: give the slot back and stop growing;
                // dispatches stay correct (the caller always works).
                self.spawned.fetch_sub(1, Ordering::Relaxed);
                return;
            }
        }
    }

    /// Worker main: park on the queue, join one task generation, repeat.
    fn worker_loop(&'static self) {
        loop {
            let task = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if let Some(task) = Self::pop(&mut q) {
                        break task;
                    }
                    q = self.work.wait(q).unwrap();
                }
            };
            self.busy.fetch_add(1, Ordering::Relaxed);
            task.participate();
            self.busy.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Pop one helper ticket, discarding closed (stale) generations.
    fn pop(q: &mut VecDeque<Entry>) -> Option<Arc<Task>> {
        while let Some(front) = q.front_mut() {
            if front.task.is_closed() {
                q.pop_front();
                continue;
            }
            front.remaining -= 1;
            let task = front.task.clone();
            if front.remaining == 0 {
                q.pop_front();
            }
            return Some(task);
        }
        None
    }

    fn submit(&self, task: Arc<Task>, helpers: usize) {
        self.generations.fetch_add(1, Ordering::Relaxed);
        let mut q = self.queue.lock().unwrap();
        // Drop tickets of finished generations so the queue cannot
        // accumulate stale entries faster than workers discard them.
        q.retain(|e| !e.task.is_closed());
        q.push_back(Entry { task, remaining: helpers });
        drop(q);
        // Wake only as many workers as there are tickets — notify_all
        // here would thundering-herd every parked worker on each short
        // dispatch. A worker that loses the race to a busy one re-parks;
        // spurious extra wakeups are benign, missing ones impossible
        // (one notify per ticket).
        for _ in 0..helpers {
            self.work.notify_one();
        }
    }
}

/// Run `f(slot)` on the calling thread plus up to `helpers` pool workers
/// and return once every participant has finished. Slots are unique and
/// dense-ish in `0..=helpers`; the closure must treat any subset of
/// slots actually showing up as valid (a busy pool may contribute fewer
/// helpers — the caller then claims the whole work list itself).
///
/// Panics in any participant are propagated to the caller after the
/// barrier, like `std::thread::scope`.
pub(crate) fn dispatch(helpers: usize, f: &(dyn Fn(usize) + Sync)) {
    if helpers == 0 {
        f(0);
        return;
    }
    let pool = Pool::global();
    pool.ensure_workers(helpers);
    // A ticket nobody can serve is pointless: clamp to the workers that
    // actually exist (spawning can fail under resource exhaustion).
    let helpers = helpers.min(pool.spawned.load(Ordering::Relaxed));
    if helpers == 0 {
        f(0);
        return;
    }
    // SAFETY: lifetime erasure only — `close_and_wait` below blocks
    // until every helper has left the closure, so the borrow of `f`
    // outlives all dereferences (the latch protocol in the module docs).
    let func: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
    let task = Arc::new(Task::new(FnPtr(func as *const _)));
    pool.submit(task.clone(), helpers);
    let slot = task.next_slot.fetch_add(1, Ordering::Relaxed);
    let own = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(slot)));
    // The barrier must run even if our own share panicked — helpers may
    // still be inside the (stack-allocated) closure environment.
    let helper_payload = task.close_and_wait();
    if let Err(payload) = own {
        std::panic::resume_unwind(payload);
    }
    if let Some(payload) = helper_payload {
        std::panic::resume_unwind(payload);
    }
}

/// Pool observability: `(workers_spawned, dispatch_generations)`. Worker
/// count is monotone (threads persist once spawned; `set_threads` masks
/// them per dispatch instead of tearing them down), so a bounded value
/// across many dispatches is the pool-reuse signal the stress tests and
/// `benches/micro_pool.rs` assert on.
pub fn stats() -> (usize, u64) {
    let pool = Pool::global();
    (pool.spawned.load(Ordering::Relaxed), pool.generations.load(Ordering::Relaxed))
}

/// Point-in-time pool gauges for `/stats` and `/metrics`.
#[derive(Clone, Copy, Debug)]
pub struct PoolStats {
    /// Worker threads spawned so far (monotone).
    pub spawned: usize,
    /// Workers currently executing a task closure.
    pub active: usize,
    /// Workers parked on the queue condvar (`spawned - active`).
    pub parked: usize,
    /// Dispatch generations published so far (monotone).
    pub dispatches: u64,
}

/// Snapshot the pool gauges. `active`/`parked` are instantaneous reads
/// of a moving target — consistent with each other only approximately,
/// which is all a scrape needs.
pub fn snapshot() -> PoolStats {
    let pool = Pool::global();
    let spawned = pool.spawned.load(Ordering::Relaxed);
    let active = pool.busy.load(Ordering::Relaxed).min(spawned);
    PoolStats {
        spawned,
        active,
        parked: spawned - active,
        dispatches: pool.generations.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::{self, ThreadGuard};

    #[test]
    fn dispatch_runs_caller_inline_when_no_helpers() {
        let hits = AtomicUsize::new(0);
        dispatch(0, &|slot| {
            assert_eq!(slot, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dispatch_slots_are_unique_and_bounded() {
        for _ in 0..50 {
            let helpers = 3;
            let seen: Vec<AtomicUsize> = (0..helpers + 1).map(|_| AtomicUsize::new(0)).collect();
            dispatch(helpers, &|slot| {
                seen[slot].fetch_add(1, Ordering::Relaxed);
            });
            for s in &seen {
                assert!(s.load(Ordering::Relaxed) <= 1, "slot used twice");
            }
            // The caller always participates, so at least one slot ran.
            assert!(seen.iter().any(|s| s.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn workers_persist_across_dispatches() {
        let _g = ThreadGuard::pin(4);
        // Warm the pool, then hammer it: the spawned count must not grow
        // per dispatch (that was the spawn-per-call behaviour).
        parallel::par_for_chunks(1 << 16, 1 << 10, |_lo, _hi| {});
        let (after_warm, _) = stats();
        for _ in 0..64 {
            parallel::par_for_chunks(1 << 16, 1 << 10, |_lo, _hi| {});
        }
        let (after_burst, _) = stats();
        // Stats are process-global and other tests dispatch concurrently,
        // so bound growth by the largest legitimate pool size (machine
        // parallelism / the largest ThreadGuard pin in the suite), far
        // below the 64 × 3 helpers spawn-per-call would have created.
        let ceiling = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).max(8);
        assert!(
            after_burst <= ceiling,
            "pool grew per dispatch: {after_warm} -> {after_burst} (ceiling {ceiling})"
        );
    }

    #[test]
    fn snapshot_gauges_are_consistent() {
        let _g = ThreadGuard::pin(4);
        parallel::par_for_chunks(1 << 16, 1 << 10, |_lo, _hi| {});
        let s = super::snapshot();
        assert_eq!(s.spawned, s.active + s.parked);
        assert!(s.dispatches >= 1);
        // Both counters are monotone; tests run concurrently, so the
        // later read can only be >=.
        let (spawned, generations) = stats();
        assert!(spawned >= s.spawned);
        assert!(generations >= s.dispatches);
    }

    #[test]
    fn helper_panic_propagates_to_caller() {
        let _g = ThreadGuard::pin(4);
        let result = std::panic::catch_unwind(|| {
            parallel::par_for_chunks(1 << 16, 1 << 10, |lo, _hi| {
                if lo == 0 {
                    panic!("boom");
                }
            });
        });
        assert!(result.is_err(), "panic must cross the dispatch barrier");
        // The pool must still be usable afterwards.
        let total = AtomicUsize::new(0);
        parallel::par_for_chunks(1000, 100, |lo, hi| {
            total.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }
}
