//! The parallel-execution substrate — this crate's stand-in for the
//! paper's GPU.
//!
//! The paper runs BOBA (Algorithm 3) and the graph kernels on a V100 with
//! tens of thousands of hardware threads; offline, neither `rayon` nor
//! `tokio` resolve, so the crate carries a small deterministic data-parallel
//! runtime built on a persistent worker [`pool`]:
//!
//! * [`par_for_chunks`] / [`par_map_chunks`] — static+dynamic chunked
//!   parallel-for over an index range (the moral equivalent of a CUDA grid
//!   launch: each chunk is a "thread block").
//! * [`par_reduce`] — tree reduction of per-worker partials.
//! * [`par_concat`] / [`par_concat_map`] — order-preserving parallel
//!   gather of per-worker output buffers into one contiguous `Vec`,
//!   optionally converting per element (the stitch step of the
//!   parallel file ingest).
//! * [`par_jobs`] — heterogeneous independent jobs, work-conserving (a
//!   slow job never blocks the next from starting).
//! * [`atomic`] — atomic u32/usize min-arrays used by the atomic-min
//!   variant of Algorithm 3.
//!
//! All four dispatch through [`pool`]: workers are spawned once, parked
//! when idle, and reused by every hot region — BOBA's record scan, the
//! COO→CSR conversion passes, per-request SpMV rows — instead of paying
//! `std::thread::scope` spawn/teardown per call (docs/EXPERIMENTS.md
//! §Pool has the dispatch-overhead numbers, `benches/micro_pool.rs` the
//! harness). The dispatching thread always participates in the work, so
//! nested parallelism (server worker threads entering these primitives,
//! `par_jobs` jobs that fan out internally) degrades to less parallelism,
//! never to deadlock.
//!
//! Worker count defaults to the machine's available parallelism and can be
//! pinned through [`set_threads`] / [`ThreadGuard`] (used by benches and
//! `boba repro --threads` to sweep scaling) or the `BOBA_THREADS`
//! environment variable. Pinning masks how many pool workers a dispatch
//! may use; parked workers persist. Pinning changes scheduling only: every
//! consumer except the deliberately racy parallel BOBA variant produces
//! thread-count-independent results.
//!
//! ```
//! let sum = boba::parallel::par_reduce(
//!     1_000, 64, 0u64,
//!     |acc, lo, hi| acc + (lo..hi).map(|i| i as u64).sum::<u64>(),
//!     |a, b| a + b,
//! );
//! assert_eq!(sum, 499_500);
//! ```

pub mod atomic;
pub mod pool;

use std::sync::atomic::{AtomicUsize, Ordering};

static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads the runtime will use.
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    if let Ok(v) = std::env::var("BOBA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Pin the worker count (0 restores the default). Returns the previous
/// override.
pub fn set_threads(n: usize) -> usize {
    THREAD_OVERRIDE.swap(n, Ordering::Relaxed)
}

/// Scope guard that pins the worker count for its lifetime.
pub struct ThreadGuard(usize);

impl ThreadGuard {
    /// Pin to `n` threads until the guard drops.
    pub fn pin(n: usize) -> Self {
        Self(set_threads(n))
    }
}

impl Drop for ThreadGuard {
    fn drop(&mut self) {
        set_threads(self.0);
    }
}

/// Pick a chunk size for `len` items: large enough to amortize dispatch,
/// small enough that dynamic scheduling load-balances (~8 chunks/worker).
pub fn default_chunk(len: usize) -> usize {
    let t = threads();
    (len / (t * 8)).max(1024).min(len.max(1))
}

/// Dynamic chunked parallel-for: `body(lo, hi)` is invoked on disjoint
/// subranges of `0..len` from multiple threads (the caller plus pool
/// workers). `body` must be fine with any interleaving (the CUDA-kernel
/// contract).
pub fn par_for_chunks<F>(len: usize, chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if len == 0 {
        return;
    }
    let chunk = chunk.max(1);
    let t = threads().min(len.div_ceil(chunk)).max(1);
    if t == 1 {
        body(0, len);
        return;
    }
    let cursor = AtomicUsize::new(0);
    let worker = |_slot: usize| loop {
        let lo = cursor.fetch_add(chunk, Ordering::Relaxed);
        if lo >= len {
            break;
        }
        let hi = (lo + chunk).min(len);
        body(lo, hi);
    };
    pool::dispatch(t - 1, &worker);
}

/// Parallel map over chunks writing into a fresh `Vec<T>`: `fill(lo, hi,
/// out_slice)` must fully initialize `out_slice` (length `hi - lo`).
pub fn par_map_chunks<T, F>(len: usize, chunk: usize, fill: F) -> Vec<T>
where
    T: Copy + Default + Send + Sync,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let mut out = vec![T::default(); len];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        par_for_chunks(len, chunk, |lo, hi| {
            // SAFETY: chunks are disjoint, so each &mut slice is exclusive.
            let slice = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(lo), hi - lo) };
            fill(lo, hi, slice);
        });
    }
    out
}

/// Parallel reduction: each participating worker folds chunks into its
/// own accumulator with `fold`, partials are combined with `merge` in
/// slot order. As before the pool rewrite, *which* chunks land in which
/// accumulator is scheduling-dependent, so `merge`/`fold` should be
/// associative-and-commutative for thread-count-independent results.
pub fn par_reduce<A, F, M>(len: usize, chunk: usize, identity: A, fold: F, merge: M) -> A
where
    A: Send + Clone,
    F: Fn(A, usize, usize) -> A + Sync,
    M: Fn(A, A) -> A,
{
    if len == 0 {
        return identity;
    }
    let chunk = chunk.max(1);
    let t = threads().min(len.div_ceil(chunk)).max(1);
    if t == 1 {
        return fold(identity, 0, len);
    }
    // Accumulators are cloned up front and handed out by participant
    // slot, so `A` needs `Send` but not `Sync`; a slot that never shows
    // up (busy pool) just contributes its untouched identity.
    let mut partials: Vec<Option<A>> = (0..t).map(|_| Some(identity.clone())).collect();
    let cursor = AtomicUsize::new(0);
    {
        let parts = SendPtr(partials.as_mut_ptr());
        let worker = |slot: usize| {
            // SAFETY: dispatch hands out each slot in 0..t to at most one
            // participant, so this &mut is exclusive.
            let acc_slot = unsafe { &mut *parts.get().add(slot) };
            let mut acc = acc_slot.take().expect("slot visited once");
            loop {
                let lo = cursor.fetch_add(chunk, Ordering::Relaxed);
                if lo >= len {
                    break;
                }
                let hi = (lo + chunk).min(len);
                acc = fold(acc, lo, hi);
            }
            *acc_slot = Some(acc);
        };
        pool::dispatch(t - 1, &worker);
    }
    partials.into_iter().flatten().fold(identity, merge)
}

/// Concatenate per-worker output buffers into one `Vec` with a parallel
/// gather: offsets are prefix-summed sequentially (cheap — one add per
/// chunk), then every chunk is memcpy'd into its slot concurrently.
/// Output order equals chunk order, so producers that emit in input
/// order stitch back to exactly the sequential result — the determinism
/// contract the parallel ingest readers (`graph::io`) are built on.
pub fn par_concat<T: Copy + Send + Sync>(chunks: &[Vec<T>]) -> Vec<T> {
    gathered(
        &chunks.iter().map(|c| c.as_slice()).collect::<Vec<_>>(),
        // SAFETY: the write is delegated to `gathered`, which hands
        // each chunk an exclusive destination region. memcpy
        // specialization: one copy_nonoverlapping per chunk instead of
        // per-element stores.
        |chunk, dst| unsafe {
            std::ptr::copy_nonoverlapping(chunk.as_ptr(), dst, chunk.len());
        },
    )
}

/// [`par_concat`] with a per-element conversion: chunk order is
/// preserved and `f` is applied during the gather (the ingest readers
/// use this to narrow raw `u64` ids to `u32` without an intermediate
/// copy).
pub fn par_concat_map<T, U, F>(chunks: &[&[T]], f: F) -> Vec<U>
where
    T: Sync,
    U: Copy + Send + Sync,
    F: Fn(&T) -> U + Sync,
{
    gathered(chunks, |chunk, dst| {
        for (k, v) in chunk.iter().enumerate() {
            // SAFETY: `gathered` guarantees dst..dst+chunk.len() is an
            // exclusive region of the output allocation.
            unsafe { *dst.add(k) = f(v) };
        }
    })
}

/// The one gather skeleton behind [`par_concat`] / [`par_concat_map`]:
/// `write(chunk, dst)` must fully initialize `dst..dst + chunk.len()`.
fn gathered<T, U, W>(chunks: &[&[T]], write: W) -> Vec<U>
where
    T: Sync,
    U: Send + Sync,
    W: Fn(&[T], *mut U) + Sync,
{
    let total: usize = chunks.iter().map(|c| c.len()).sum();
    let mut out: Vec<U> = Vec::with_capacity(total);
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        let write = &write;
        let mut off = 0usize;
        let jobs: Vec<_> = chunks
            .iter()
            .map(|&c| {
                let my_off = off;
                off += c.len();
                move || {
                    // SAFETY: [my_off, my_off + c.len()) ranges tile
                    // [0, total) disjointly (offsets are the exclusive
                    // prefix sum of chunk lengths), so each writer gets
                    // an exclusive region of the reserved allocation.
                    write(c, unsafe { out_ptr.get().add(my_off) });
                }
            })
            .collect();
        par_jobs(jobs);
    }
    // SAFETY: every element of [0, total) was initialized by exactly one
    // job above (par_jobs runs all jobs to completion or propagates the
    // panic, in which case this line is never reached).
    unsafe { out.set_len(total) };
    out
}

/// Run `k` independent jobs on the pool, returning their results in
/// submission order. The coordinator uses this for multi-request
/// dispatch. Scheduling is work-conserving: each participant pulls the
/// next unclaimed job as soon as it finishes its current one, so one
/// slow job delays only itself (the old implementation ran jobs in
/// waves of `threads()`, where the slowest job in a wave gated the
/// entire next wave).
pub fn par_jobs<T: Send, F>(jobs: Vec<F>) -> Vec<T>
where
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    let t = threads().min(n).max(1);
    if t == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let mut jobs: Vec<Option<F>> = jobs.into_iter().map(Some).collect();
    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let cursor = AtomicUsize::new(0);
    {
        let jobs_ptr = SendPtr(jobs.as_mut_ptr());
        let out_ptr = SendPtr(results.as_mut_ptr());
        let worker = |_slot: usize| loop {
            let i = cursor.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            // SAFETY: the cursor hands out each index exactly once, so
            // the take() and the result write are exclusive.
            let job = unsafe { (*jobs_ptr.get().add(i)).take().expect("job claimed once") };
            let out = job();
            // SAFETY: index i was claimed exclusively by the cursor
            // above — no other worker writes results[i].
            unsafe {
                *out_ptr.get().add(i) = Some(out);
            }
        };
        pool::dispatch(t - 1, &worker);
    }
    results.into_iter().map(|r| r.expect("all jobs completed")).collect()
}

/// A Send+Sync raw-pointer wrapper for disjoint-chunk writes.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub *mut T);
// SAFETY: SendPtr carries no aliasing claim of its own — every user
// must (and does) guarantee disjoint writes; the wrapper only moves the
// raw address across threads, which is sound for any `*mut T`.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: sharing the wrapper only shares the address value; see Send.
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    #[inline]
    pub fn get(self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_covers_every_index_once() {
        let n = 100_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_for_chunks(n, 1000, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_fills_exactly() {
        let v = par_map_chunks(10_000, 128, |lo, _hi, out| {
            for (k, slot) in out.iter_mut().enumerate() {
                *slot = (lo + k) as u64 * 2;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64 * 2);
        }
    }

    #[test]
    fn par_reduce_sums() {
        let n = 1_000_000usize;
        let s = par_reduce(n, 4096, 0u64, |acc, lo, hi| {
            acc + (lo..hi).map(|i| i as u64).sum::<u64>()
        }, |a, b| a + b);
        assert_eq!(s, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn par_reduce_empty_is_identity() {
        let s = par_reduce(0, 16, 7u64, |a, _, _| a + 1, |a, b| a + b);
        assert_eq!(s, 7);
    }

    #[test]
    fn thread_guard_restores() {
        let before = threads();
        {
            let _g = ThreadGuard::pin(2);
            assert_eq!(threads(), 2);
        }
        assert_eq!(threads(), before);
    }

    #[test]
    fn par_jobs_ordered_results() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..17usize).map(|i| Box::new(move || i * i) as _).collect();
        let out = par_jobs(jobs);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn single_thread_path() {
        let _g = ThreadGuard::pin(1);
        let total = AtomicU64::new(0);
        par_for_chunks(1000, 10, |lo, hi| {
            total.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn par_concat_preserves_chunk_order() {
        // Uneven chunk sizes, including empties, at several pins.
        let chunks: Vec<Vec<u32>> = (0..13u32)
            .map(|k| (0..(k * 37) % 501).map(|x| k * 100_000 + x).collect())
            .collect();
        let expected: Vec<u32> = chunks.iter().flatten().copied().collect();
        for t in [1, 2, 4, 8] {
            let _g = ThreadGuard::pin(t);
            assert_eq!(par_concat(&chunks), expected, "t={t}");
        }
        assert!(par_concat::<u64>(&[]).is_empty());
    }

    #[test]
    fn par_concat_map_narrows_in_chunk_order() {
        let a: Vec<u64> = (0..1000).collect();
        let b: Vec<u64> = (1000..1003).collect();
        let c: Vec<u64> = Vec::new();
        let chunks: Vec<&[u64]> = vec![&a, &b, &c];
        for t in [1, 4] {
            let _g = ThreadGuard::pin(t);
            let got = par_concat_map(&chunks, |&v| v as u32);
            let want: Vec<u32> = (0..1003).collect();
            assert_eq!(got, want, "t={t}");
        }
    }

    #[test]
    fn default_chunk_reasonable() {
        assert!(default_chunk(10) >= 1);
        assert!(default_chunk(100_000_000) >= 1024);
    }
}
