//! The parallel-execution substrate — this crate's stand-in for the
//! paper's GPU.
//!
//! The paper runs BOBA (Algorithm 3) and the graph kernels on a V100 with
//! tens of thousands of hardware threads; offline, neither `rayon` nor
//! `tokio` resolve, so the crate carries a small deterministic data-parallel
//! runtime built on `std::thread::scope`:
//!
//! * [`par_for_chunks`] / [`par_map_chunks`] — static+dynamic chunked
//!   parallel-for over an index range (the moral equivalent of a CUDA grid
//!   launch: each chunk is a "thread block").
//! * [`par_reduce`] — tree reduction of per-worker partials.
//! * [`atomic`] — atomic u32/usize min-arrays used by the atomic-min
//!   variant of Algorithm 3.
//!
//! Worker count defaults to the machine's available parallelism and can be
//! pinned through [`set_threads`] / [`ThreadGuard`] (used by benches and
//! `boba repro --threads` to sweep scaling) or the `BOBA_THREADS`
//! environment variable. Pinning changes scheduling only: every consumer
//! except the deliberately racy parallel BOBA variant produces
//! thread-count-independent results.
//!
//! ```
//! let sum = boba::parallel::par_reduce(
//!     1_000, 64, 0u64,
//!     |acc, lo, hi| acc + (lo..hi).map(|i| i as u64).sum::<u64>(),
//!     |a, b| a + b,
//! );
//! assert_eq!(sum, 499_500);
//! ```

pub mod atomic;

use std::sync::atomic::{AtomicUsize, Ordering};

static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads the runtime will use.
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    if let Ok(v) = std::env::var("BOBA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Pin the worker count (0 restores the default). Returns the previous
/// override.
pub fn set_threads(n: usize) -> usize {
    THREAD_OVERRIDE.swap(n, Ordering::Relaxed)
}

/// Scope guard that pins the worker count for its lifetime.
pub struct ThreadGuard(usize);

impl ThreadGuard {
    /// Pin to `n` threads until the guard drops.
    pub fn pin(n: usize) -> Self {
        Self(set_threads(n))
    }
}

impl Drop for ThreadGuard {
    fn drop(&mut self) {
        set_threads(self.0);
    }
}

/// Pick a chunk size for `len` items: large enough to amortize dispatch,
/// small enough that dynamic scheduling load-balances (~8 chunks/worker).
pub fn default_chunk(len: usize) -> usize {
    let t = threads();
    (len / (t * 8)).max(1024).min(len.max(1))
}

/// Dynamic chunked parallel-for: `body(lo, hi)` is invoked on disjoint
/// subranges of `0..len` from multiple threads. `body` must be fine with
/// any interleaving (the CUDA-kernel contract).
pub fn par_for_chunks<F>(len: usize, chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    if len == 0 {
        return;
    }
    let t = threads().min(len.div_ceil(chunk)).max(1);
    if t == 1 {
        body(0, len);
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..t {
            s.spawn(|| loop {
                let lo = cursor.fetch_add(chunk, Ordering::Relaxed);
                if lo >= len {
                    break;
                }
                let hi = (lo + chunk).min(len);
                body(lo, hi);
            });
        }
    });
}

/// Parallel map over chunks writing into a fresh `Vec<T>`: `fill(lo, hi,
/// out_slice)` must fully initialize `out_slice` (length `hi - lo`).
pub fn par_map_chunks<T, F>(len: usize, chunk: usize, fill: F) -> Vec<T>
where
    T: Copy + Default + Send + Sync,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let mut out = vec![T::default(); len];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        par_for_chunks(len, chunk, |lo, hi| {
            // SAFETY: chunks are disjoint, so each &mut slice is exclusive.
            let slice = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(lo), hi - lo) };
            fill(lo, hi, slice);
        });
    }
    out
}

/// Parallel reduction: each worker folds chunks into an accumulator with
/// `fold`, partials are combined with `merge`.
pub fn par_reduce<A, F, M>(len: usize, chunk: usize, identity: A, fold: F, merge: M) -> A
where
    A: Send + Clone,
    F: Fn(A, usize, usize) -> A + Sync,
    M: Fn(A, A) -> A,
{
    if len == 0 {
        return identity;
    }
    let t = threads().min(len.div_ceil(chunk)).max(1);
    if t == 1 {
        return fold(identity, 0, len);
    }
    let cursor = AtomicUsize::new(0);
    let fold_ref = &fold;
    let partials: Vec<A> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..t)
            .map(|_| {
                let id = identity.clone();
                let cursor = &cursor;
                s.spawn(move || {
                    let mut acc = id;
                    loop {
                        let lo = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if lo >= len {
                            break;
                        }
                        let hi = (lo + chunk).min(len);
                        acc = fold_ref(acc, lo, hi);
                    }
                    acc
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    partials.into_iter().fold(identity, merge)
}

/// Run `k` independent jobs (one thread each, capped at the worker count),
/// returning their results in order. The coordinator uses this for
/// multi-request dispatch.
pub fn par_jobs<T: Send, F>(jobs: Vec<F>) -> Vec<T>
where
    F: FnOnce() -> T + Send,
{
    let t = threads();
    if t == 1 || jobs.len() <= 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    // Simple wave scheduling: spawn up to `t` at a time.
    let mut results: Vec<Option<T>> = Vec::new();
    for _ in 0..jobs.len() {
        results.push(None);
    }
    let mut jobs: Vec<Option<F>> = jobs.into_iter().map(Some).collect();
    let n = jobs.len();
    let mut start = 0;
    while start < n {
        let end = (start + t).min(n);
        let wave: Vec<(usize, F)> =
            (start..end).map(|i| (i, jobs[i].take().unwrap())).collect();
        let wave_results: Vec<(usize, T)> = std::thread::scope(|s| {
            let handles: Vec<_> = wave
                .into_iter()
                .map(|(i, job)| s.spawn(move || (i, job())))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (i, r) in wave_results {
            results[i] = Some(r);
        }
        start = end;
    }
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// A Send+Sync raw-pointer wrapper for disjoint-chunk writes.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    #[inline]
    pub fn get(self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_covers_every_index_once() {
        let n = 100_000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_for_chunks(n, 1000, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_fills_exactly() {
        let v = par_map_chunks(10_000, 128, |lo, _hi, out| {
            for (k, slot) in out.iter_mut().enumerate() {
                *slot = (lo + k) as u64 * 2;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u64 * 2);
        }
    }

    #[test]
    fn par_reduce_sums() {
        let n = 1_000_000usize;
        let s = par_reduce(n, 4096, 0u64, |acc, lo, hi| {
            acc + (lo..hi).map(|i| i as u64).sum::<u64>()
        }, |a, b| a + b);
        assert_eq!(s, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn par_reduce_empty_is_identity() {
        let s = par_reduce(0, 16, 7u64, |a, _, _| a + 1, |a, b| a + b);
        assert_eq!(s, 7);
    }

    #[test]
    fn thread_guard_restores() {
        let before = threads();
        {
            let _g = ThreadGuard::pin(2);
            assert_eq!(threads(), 2);
        }
        assert_eq!(threads(), before);
    }

    #[test]
    fn par_jobs_ordered_results() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..17usize).map(|i| Box::new(move || i * i) as _).collect();
        let out = par_jobs(jobs);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn single_thread_path() {
        let _g = ThreadGuard::pin(1);
        let total = AtomicU64::new(0);
        par_for_chunks(1000, 10, |lo, hi| {
            total.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn default_chunk_reasonable() {
        assert!(default_chunk(10) >= 1);
        assert!(default_chunk(100_000_000) >= 1024);
    }
}
