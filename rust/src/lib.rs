//! # BOBA — Batched Order By Attachment
//!
//! A production-quality reproduction of *"BOBA: A Parallel Lightweight
//! Graph Reordering Algorithm with Heavyweight Implications"* (Drescher,
//! Porumbescu, Awad, Owens — UC Davis, 2023).
//!
//! The library implements the paper's lightweight reordering algorithm
//! (sequential Algorithm 2 and parallel Algorithm 3), every baseline the
//! paper compares against (random relabeling, full degree sort, hub sort,
//! Reverse Cuthill–McKee, Gorder), the pragmatic graph-creation pipeline
//! of the paper's Problem 3 (COO ingest → reorder → CSR conversion →
//! graph algorithm), the four evaluation workloads (SpMV, PageRank,
//! triangle counting, SSSP), the paper's locality metrics (NBR, NScore,
//! GScore, bandwidth), and a trace-driven cache simulator standing in for
//! the paper's GPU profiler counters.
//!
//! ## Architecture: three compute layers plus a service layer
//!
//! * **L4 ([`server`])** — the online service: `boba serve` exposes the
//!   prepared artifacts over HTTP (std-only, multi-threaded), with a
//!   [`server::registry::GraphRegistry`] LRU that runs the Problem-3
//!   pipeline once per `(dataset, scheme)` and serves every subsequent
//!   SpMV/PageRank/SSSP/TC query from the cached reordered CSR;
//!   `boba loadgen` measures the result as queries/second.
//! * **L3 (this crate)** — the coordinator: reordering, conversion,
//!   algorithms, metrics, experiment drivers, CLI.
//! * **L2 (python/compile/model.py)** — JAX compute graphs (SpMV over a
//!   padded ELL layout; a PageRank iteration) AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — the Pallas gather-reduce kernel
//!   that L2 calls; verified against a pure-jnp oracle at build time.
//!
//! Python never runs at request time: [`runtime`] loads the AOT HLO
//! artifacts through PJRT (the `xla` crate, behind the off-by-default
//! `pjrt` feature) and executes them natively.
//!
//! ## Quickstart
//!
//! ```no_run
//! use boba::graph::gen::{self, GenParams};
//! use boba::reorder::{Reorderer, boba::Boba};
//! use boba::convert;
//! use boba::algos::spmv;
//!
//! // Generate an R-MAT graph with randomized labels (the paper's input
//! // model: a COO edge list whose vertex IDs carry no structure).
//! let coo = gen::rmat(&GenParams::rmat(16, 16), 42).randomized(7);
//! // Reorder with parallel BOBA (Algorithm 3).
//! let perm = Boba::parallel().reorder(&coo);
//! let coo2 = coo.relabeled(perm.new_of_old());
//! // Convert and run SpMV.
//! let csr = convert::coo_to_csr(&coo2);
//! let x = vec![1.0f32; csr.n()];
//! let y = spmv::spmv_pull(&csr, &x);
//! assert_eq!(y.len(), csr.n());
//! ```

pub mod util;
pub mod parallel;
pub mod graph;
pub mod convert;
pub mod reorder;
pub mod algos;
pub mod cachesim;
pub mod metrics;
pub mod coordinator;
pub mod server;
pub mod runtime;
pub mod bench;
pub mod testing;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
