//! The pragmatic graph-creation pipeline (the paper's Problem 3):
//!
//! ```text
//! edge chunks ──ingest──► COO ──reorder──► COO' ──convert──► CSR ──► f(G)
//!                (batched)     (BOBA/...)      (counting)       (SpMV/PR/TC/SSSP)
//! ```
//!
//! Reordering is an *online* stage: its cost is charged to the run, and
//! the paper's thesis is that BOBA's cost is repaid by faster conversion
//! and faster `f(G)`. [`Pipeline::run`] measures every stage and returns
//! the stacked timings Fig. 4 plots.
//!
//! [`StreamingIngest`] demonstrates the online scenario end-to-end:
//! a producer thread emits bounded edge batches (RAPIDS-style dynamic
//! graph production) through a backpressured channel while the
//! coordinator assembles the COO incrementally.

use crate::algos::{pagerank, spmv, sssp, tc};
use crate::convert;
use crate::graph::{Coo, Csr};
use crate::reorder::Reorderer;
use crate::util::timer::{StageTimer, Stopwatch};
use std::sync::mpsc;

/// Which graph application terminates the pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum App {
    /// One SpMV over the CSR.
    Spmv,
    /// PageRank to convergence (bounded iterations).
    PageRank,
    /// Triangle counting (adds the COO sort stage, as in the paper).
    Tc,
    /// Single-source shortest path from vertex 0.
    Sssp,
}

impl App {
    /// Name used in reports.
    pub fn name(self) -> &'static str {
        match self {
            App::Spmv => "SpMV",
            App::PageRank => "PR",
            App::Tc => "TC",
            App::Sssp => "SSSP",
        }
    }

    /// All four, in the paper's figure order.
    pub fn all() -> [App; 4] {
        [App::Spmv, App::PageRank, App::Tc, App::Sssp]
    }
}

/// Which reordering stage to run.
pub enum ReorderStage {
    /// Leave labels as they are (the "Random" baseline — inputs are
    /// pre-randomized).
    None,
    /// Apply a reorderer.
    Scheme(Box<dyn Reorderer + Send + Sync>),
}

/// Per-run report: stage timings + an application-result digest (so
/// correctness can be asserted across schemes).
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// Scheme name ("Random" when no reordering ran).
    pub scheme: String,
    /// Application executed.
    pub app: &'static str,
    /// Stage timings: `reorder`, `sort` (TC only), `convert`, `app`.
    pub stages: StageTimer,
    /// Order-insensitive digest of the application output.
    pub digest: f64,
    /// Edges processed.
    pub m: usize,
}

impl PipelineReport {
    /// Total end-to-end milliseconds (the Fig. 4 bar height).
    pub fn total_ms(&self) -> f64 {
        self.stages.total_ms()
    }
}

/// The pipeline runner.
pub struct Pipeline {
    /// Application stage.
    pub app: App,
    /// PageRank iteration cap (the paper uses converged PR; quick
    /// experiments cap it).
    pub pr_iters: usize,
}

impl Default for Pipeline {
    fn default() -> Self {
        Self { app: App::Spmv, pr_iters: 20 }
    }
}

impl Pipeline {
    /// New pipeline for `app`.
    pub fn new(app: App) -> Self {
        Self { app, ..Default::default() }
    }

    /// Run the full pipeline on `coo` with the given reorder stage.
    /// The input is treated as already randomized (the paper's model).
    pub fn run(&self, coo: &Coo, stage: &ReorderStage) -> PipelineReport {
        let mut stages = StageTimer::new();
        // ── reorder ────────────────────────────────────────────────
        // "Reorder" produces the relabeled COO (the paper's GPU kernel
        // outputs the reordered edge list). BOBA overrides
        // `reorder_relabel` with a fused single pass (§Perf); other
        // schemes pay reorder + relabel here.
        let (scheme_name, working): (String, std::borrow::Cow<Coo>) = match stage {
            ReorderStage::None => ("Random".to_string(), std::borrow::Cow::Borrowed(coo)),
            ReorderStage::Scheme(s) => {
                let sw = Stopwatch::start();
                let (_perm, relabeled) =
                    crate::obs::span("pipeline.reorder", || s.reorder_relabel(coo));
                stages.record("reorder", sw.elapsed());
                (s.name().to_string(), std::borrow::Cow::Owned(relabeled))
            }
        };
        // ── sort (TC only, paper §5.3) ────────────────────────────
        let working: std::borrow::Cow<Coo> = if self.app == App::Tc {
            let sw = Stopwatch::start();
            let und = working.symmetrized().deduped();
            let sorted = convert::sort_coo_by_src(&und);
            stages.record("sort", sw.elapsed());
            std::borrow::Cow::Owned(sorted)
        } else {
            working
        };
        // ── convert ───────────────────────────────────────────────
        // Deterministic parallel conversion: bit-identical to the
        // sequential kernel, so TC's sorted COO still yields sorted
        // rows and digests compare across schemes and thread counts.
        let sw = Stopwatch::start();
        let csr = crate::obs::span("pipeline.convert", || convert::coo_to_csr_parallel(&working));
        stages.record("convert", sw.elapsed());
        // ── app ───────────────────────────────────────────────────
        let sw = Stopwatch::start();
        let digest = crate::obs::span("pipeline.app", || self.run_app(&csr));
        stages.record("app", sw.elapsed());
        PipelineReport {
            scheme: scheme_name,
            app: self.app.name(),
            stages,
            digest,
            m: coo.m(),
        }
    }

    /// Execute the application stage, returning a label-invariant digest.
    fn run_app(&self, csr: &Csr) -> f64 {
        match self.app {
            App::Spmv => {
                let x = vec![1.0f32; csr.n()];
                let y = spmv::spmv_pull(csr, &x);
                y.iter().map(|&v| v as f64).sum()
            }
            App::PageRank => {
                let p = pagerank::PrParams {
                    max_iters: self.pr_iters,
                    ..Default::default()
                };
                let r = pagerank::pagerank(csr, p);
                r.ranks.iter().map(|&v| v as f64).sum()
            }
            App::Tc => {
                // Degree-rank orientation (arboricity-bounded out-degrees)
                // — the practical choice on skew graphs; see algos::tc.
                let rank = tc::degree_rank(csr);
                let dag = tc::orient_by_rank(csr, &rank);
                tc::triangle_count_ranked(&dag, &rank) as f64
            }
            App::Sssp => {
                // Source = max-total-degree vertex: a label-invariant
                // choice (out-degree alone ties on PA graphs, where every
                // vertex sources exactly c edges), so digests compare
                // across schemes.
                let mut total_deg: Vec<u64> =
                    (0..csr.n()).map(|v| csr.degree(v) as u64).collect();
                for &c in &csr.col_idx {
                    total_deg[c as usize] += 1;
                }
                let src =
                    (0..csr.n()).max_by_key(|&v| total_deg[v]).unwrap_or(0) as u32;
                let d = sssp::sssp_frontier(csr, src);
                d.iter().filter(|v| v.is_finite()).map(|&v| v as f64).sum()
            }
        }
    }
}

/// One streamed edge batch: sources, destinations, optional weights.
type EdgeBatch = (Vec<u32>, Vec<u32>, Option<Vec<f32>>);

/// Streaming/batched edge ingestion with backpressure (bounded channel).
pub struct StreamingIngest {
    rx: mpsc::Receiver<EdgeBatch>,
    n: usize,
}

impl StreamingIngest {
    /// Spawn a producer that chops `coo` into `batch`-edge chunks and
    /// streams them with a channel capacity of `in_flight` batches.
    /// Both knobs are exposed on the CLI (`--batch`, `--in-flight`) and
    /// in the server's registry config. The final chunk is usually
    /// partial (`m % batch` edges) and is emitted like any other;
    /// degenerate knob values are clamped (`batch == 0` would otherwise
    /// spin forever emitting empty chunks).
    pub fn from_coo(coo: Coo, batch: usize, in_flight: usize) -> (std::thread::JoinHandle<()>, Self) {
        let batch = batch.max(1);
        let (tx, rx) = mpsc::sync_channel(in_flight.max(1));
        let n = coo.n();
        // lint: allow(raw-spawn): the ingest producer is an I/O-bound
        // streamer that must not occupy a compute-pool worker for the
        // whole ingest; it blocks on the bounded channel, which would
        // deadlock the pool's helper-barrier dispatch model.
        let handle = std::thread::spawn(move || {
            let m = coo.m();
            let mut at = 0;
            while at < m {
                // min() caps the last batch at the tail length, so a
                // partial final batch is sent, never dropped.
                let hi = (at + batch).min(m);
                let chunk = (
                    coo.src[at..hi].to_vec(),
                    coo.dst[at..hi].to_vec(),
                    // Weights ride along so weighted datasets (SpMV
                    // values) survive batched ingestion.
                    coo.vals.as_ref().map(|v| v[at..hi].to_vec()),
                );
                if tx.send(chunk).is_err() {
                    return; // consumer dropped
                }
                at = hi;
            }
        });
        (handle, Self { rx, n })
    }

    /// Drain the stream into a COO (the coordinator's assembly loop).
    /// Returns the graph and the number of batches consumed.
    pub fn collect(self) -> (Coo, usize) {
        let mut src = Vec::new();
        let mut dst = Vec::new();
        let mut vals: Option<Vec<f32>> = None;
        let mut batches = 0;
        while let Ok((s, d, v)) = self.rx.recv() {
            src.extend_from_slice(&s);
            dst.extend_from_slice(&d);
            if let Some(vv) = v {
                vals.get_or_insert_with(Vec::new).extend_from_slice(&vv);
            }
            batches += 1;
        }
        let mut coo = Coo::new(self.n, src, dst);
        coo.vals = vals;
        (coo, batches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::reorder::boba::Boba;

    fn sample() -> Coo {
        gen::preferential_attachment(2000, 4, 3).randomized(9)
    }

    #[test]
    fn spmv_digest_invariant_across_schemes() {
        let g = sample();
        let pipe = Pipeline::new(App::Spmv);
        let a = pipe.run(&g, &ReorderStage::None);
        let b = pipe.run(&g, &ReorderStage::Scheme(Box::new(Boba::parallel())));
        // Column sums of A·1 are label-invariant.
        assert!((a.digest - b.digest).abs() < 1e-6 * a.digest.abs().max(1.0));
        assert_eq!(a.scheme, "Random");
        assert_eq!(b.scheme, "BOBA");
    }

    #[test]
    fn tc_digest_is_triangle_count_invariant() {
        let g = sample();
        let pipe = Pipeline::new(App::Tc);
        let a = pipe.run(&g, &ReorderStage::None);
        let b = pipe.run(&g, &ReorderStage::Scheme(Box::new(Boba::sequential())));
        assert_eq!(a.digest, b.digest);
        assert!(a.stages.ms("sort").is_some(), "TC must include the sort stage");
    }

    #[test]
    fn stages_recorded_in_order() {
        let g = sample();
        let pipe = Pipeline::new(App::Spmv);
        let r = pipe.run(&g, &ReorderStage::Scheme(Box::new(Boba::parallel())));
        let names: Vec<_> = r.stages.stages().iter().map(|(n, _)| n.clone()).collect();
        assert_eq!(names, vec!["reorder", "convert", "app"]);
        assert!(r.total_ms() > 0.0);
    }

    #[test]
    fn sssp_runs() {
        let g = sample();
        let pipe = Pipeline::new(App::Sssp);
        let r = pipe.run(&g, &ReorderStage::None);
        assert!(r.digest >= 0.0);
    }

    #[test]
    fn pagerank_digest_close_to_one() {
        let g = sample();
        let pipe = Pipeline { app: App::PageRank, pr_iters: 50 };
        let r = pipe.run(&g, &ReorderStage::None);
        assert!((r.digest - 1.0).abs() < 0.01, "digest {}", r.digest);
    }

    #[test]
    fn streaming_ingest_reassembles() {
        let g = sample();
        let (h, stream) = StreamingIngest::from_coo(g.clone(), 333, 2);
        let (got, batches) = stream.collect();
        h.join().unwrap();
        assert_eq!(got, g);
        assert_eq!(batches, g.m().div_ceil(333));
    }

    #[test]
    fn streaming_ingest_final_partial_batch_not_dropped() {
        // 10 edges in batches of 4: two full batches + a 2-edge tail
        // that must be emitted, not dropped.
        let g = Coo::new(
            11,
            vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9],
            vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10],
        );
        let (h, stream) = StreamingIngest::from_coo(g.clone(), 4, 2);
        let (got, batches) = stream.collect();
        h.join().unwrap();
        assert_eq!(got.m(), g.m(), "no edges may be dropped");
        assert_eq!(got, g);
        assert_eq!(batches, 3);
    }

    #[test]
    fn streaming_ingest_preserves_weights() {
        let g = Coo::with_vals(
            4,
            vec![0, 1, 2, 3, 0],
            vec![1, 2, 3, 0, 2],
            vec![0.5, -1.0, 2.25, 8.0, 3.5],
        );
        let (h, stream) = StreamingIngest::from_coo(g.clone(), 2, 1);
        let (got, batches) = stream.collect();
        h.join().unwrap();
        assert_eq!(got, g, "weights must survive batched ingestion");
        assert_eq!(batches, 3);
    }

    #[test]
    fn streaming_ingest_batch_larger_than_graph() {
        let g = sample();
        let (h, stream) = StreamingIngest::from_coo(g.clone(), g.m() * 10, 1);
        let (got, batches) = stream.collect();
        h.join().unwrap();
        assert_eq!(got, g);
        assert_eq!(batches, 1);
    }

    #[test]
    fn streaming_ingest_zero_batch_clamped() {
        let g = sample();
        let (h, stream) = StreamingIngest::from_coo(g.clone(), 0, 1);
        let (got, batches) = stream.collect();
        h.join().unwrap();
        assert_eq!(got, g, "batch=0 is clamped to 1, not an infinite loop");
        assert_eq!(batches, g.m());
    }

    #[test]
    fn streaming_ingest_empty_graph() {
        let g = Coo::new(5, vec![], vec![]);
        let (h, stream) = StreamingIngest::from_coo(g.clone(), 64, 2);
        let (got, batches) = stream.collect();
        h.join().unwrap();
        assert_eq!(got, g);
        assert_eq!(got.n(), 5, "vertex count survives an edgeless stream");
        assert_eq!(batches, 0);
    }

    #[test]
    fn streaming_ingest_backpressure_small_capacity() {
        let g = sample();
        let (h, stream) = StreamingIngest::from_coo(g.clone(), 100, 1);
        std::thread::sleep(std::time::Duration::from_millis(10)); // producer blocks
        let (got, _) = stream.collect();
        h.join().unwrap();
        assert_eq!(got.m(), g.m());
    }
}
