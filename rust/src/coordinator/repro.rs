//! `boba repro` — the paper-reproduction benchmark harness.
//!
//! Drives the full *scheme × dataset × kernel* matrix end-to-end and
//! emits machine-readable results ([`crate::bench::results`]): four
//! repro tables mirroring the paper's quantitative claims,
//!
//! * **T1** — reordering time per scheme (BOBA seq/parallel/atomic vs
//!   random/degree/hub and, with `--heavy`, RCM/Gorder): the paper's
//!   "~1 order of magnitude faster than lightweight techniques" claim;
//! * **T2** — COO→CSR conversion time on pre-randomized vs
//!   BOBA-reordered inputs, across the sequential kernel, the
//!   deterministic parallel kernel (`par-det` rows — bit-identical
//!   output, digest-gated against the sequential digest), the retained
//!   atomic-scatter baseline (`par-atomic`), and the fused
//!   relabel+convert paths (sequential + parallel): the paper's §5.3
//!   conversion speedups, treating conversion as a first-class workload
//!   (Koohi Esfahani & Vandierendonck);
//! * **T3** — end-to-end pipeline time (ingest + reorder + \[sort\] +
//!   convert + app) for SpMV/PageRank/TC/SSSP: the paper's headline
//!   up-to-3.45× end-to-end speedups. Since schema `boba-repro/2` the
//!   run prices the pipeline's front door too: one `ingest_ms` row per
//!   dataset — a disk re-load for file specs (the `.bcoo` sidecar hit
//!   after the first parse wrote it — the served steady state) or the
//!   batched `StreamingIngest` assembly for generated specs (what the
//!   server registry pays) — and, since the batched query engine, the
//!   `spmm_k{1,4,8}_ms` rows pricing the multi-RHS SpMV the serving
//!   coalescer amortizes concurrent queries with;
//! * **T4** — simulated L1/L2 hit rates and DRAM fraction per workload:
//!   the paper's Fig. 7 profiler numbers (7–52% L1 / 11–67% L2 gains);
//! * **T5** — compressed kernel formats ([`crate::runtime::format`]):
//!   bytes/edge of the column stream, encode time, SpMV time, and
//!   effective GB/s against a measured single-thread stream roofline
//!   ([`machine::stream_bandwidth_gbs`]), per scheme × format. Every
//!   format is bit-compared against `spmv_pull` before it is timed —
//!   a divergence fails the run, the same contract the serving
//!   registry enforces at prepare time.
//!
//! Methodology (after Faldu et al.'s critique of ad-hoc reordering
//! evaluations): inputs are pre-randomized (the paper's §5 model), every
//! timing is warmup + median-of-k with min/max envelope
//! ([`crate::bench::Bench`]), thread count is pinned and recorded, and
//! the run writes both `BENCH_repro.json` (stable schema, committed as
//! the perf trajectory) and `docs/RESULTS.md` (rendered from the same
//! records).

use super::datasets;
use super::pipeline::{App, Pipeline, ReorderStage, StreamingIngest};
use crate::algos::{pagerank, sssp, tc};
use crate::bench::machine;
use crate::bench::results::{Record, ResultsDoc};
use crate::bench::{black_box, Bench, Summary};
use crate::cachesim::Hierarchy;
use crate::convert;
use crate::graph::{gen, Coo};
use crate::parallel;
use crate::reorder::{self, boba::Boba, Permutation, Reorderer};
use crate::util::human;
use anyhow::{bail, Context, Result};

/// Configuration of one repro run (CLI flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct ReproOptions {
    /// Base seed for dataset generation and randomization.
    pub seed: u64,
    /// Quick (CI-sized) or full (benchmark-sized) generated datasets.
    pub quick: bool,
    /// Which tables to run ("T1".."T4").
    pub tables: Vec<String>,
    /// Include the heavyweight schemes (RCM, Gorder).
    pub heavy: bool,
    /// Pin the worker-thread count for the whole run (recorded in the
    /// output; `None` keeps the `BOBA_THREADS`/machine default).
    pub threads: Option<usize>,
    /// Dataset specs (suite names, generator recipes, or `.mtx`/`.el`
    /// paths); empty selects the generated default trio.
    pub dataset_specs: Vec<String>,
    /// Timed iterations per measurement (median-of-k).
    pub reps: usize,
    /// Warmup iterations per measurement.
    pub warmup: usize,
    /// PageRank iteration cap for T3.
    pub pr_iters: usize,
}

impl ReproOptions {
    /// CI-sized defaults: the generated trio, all four tables,
    /// median-of-3 with one warmup.
    pub fn quick(seed: u64) -> Self {
        Self {
            seed,
            quick: true,
            tables: all_tables(),
            heavy: false,
            threads: None,
            dataset_specs: Vec::new(),
            reps: 3,
            warmup: 1,
            pr_iters: 10,
        }
    }

    /// Benchmark-sized defaults: larger generated datasets, median-of-5,
    /// heavyweight schemes included.
    pub fn full(seed: u64) -> Self {
        Self {
            seed,
            quick: false,
            tables: all_tables(),
            heavy: true,
            threads: None,
            dataset_specs: Vec::new(),
            reps: 5,
            warmup: 1,
            pr_iters: 20,
        }
    }
}

/// All table ids, in run order.
pub fn all_tables() -> Vec<String> {
    crate::bench::results::TABLE_IDS.iter().map(|s| s.to_string()).collect()
}

/// Parse a `--tables t1,t3` style list (case-insensitive, `all` for the
/// full set).
pub fn parse_tables(spec: &str) -> Result<Vec<String>> {
    if spec.eq_ignore_ascii_case("all") {
        return Ok(all_tables());
    }
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.is_empty()) {
        let id = part.trim().to_uppercase();
        if !crate::bench::results::TABLE_IDS.contains(&id.as_str()) {
            bail!("unknown repro table {part:?} (expected t1|t2|t3|t4|t5|all)");
        }
        if !out.contains(&id) {
            out.push(id);
        }
    }
    if out.is_empty() {
        bail!("--tables selected nothing (expected t1|t2|t3|t4|t5|all)");
    }
    Ok(out)
}

/// FNV-1a 64 digest of a permutation's mapping array, as fixed-width
/// hex. Two runs that produce byte-identical permutations produce equal
/// digests — the determinism handle the thread-count tests compare.
pub fn perm_digest(p: &Permutation) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in p.new_of_old() {
        fnv_eat(&mut h, &v.to_le_bytes());
    }
    format!("{h:016x}")
}

/// FNV-1a 64 digest of a CSR's full contents (row_ptr, col_idx, vals) as
/// fixed-width hex — the *bit-identical output* handle T2's determinism
/// gate compares between the sequential and `par-det` converters (and
/// the CI step asserts on).
pub fn csr_digest(csr: &crate::graph::Csr) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in &csr.row_ptr {
        fnv_eat(&mut h, &v.to_le_bytes());
    }
    for &c in &csr.col_idx {
        fnv_eat(&mut h, &c.to_le_bytes());
    }
    if let Some(vals) = &csr.vals {
        for &v in vals {
            fnv_eat(&mut h, &v.to_bits().to_le_bytes());
        }
    }
    format!("{h:016x}")
}

#[inline]
fn fnv_eat(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x1000_0000_01b3);
    }
}

/// The T1 scheme lineup: every BOBA variant plus every lightweight
/// baseline, with the heavyweight pair appended when `heavy` is set.
/// Names are [`crate::reorder::by_name`] vocabulary.
pub fn t1_schemes(heavy: bool) -> Vec<&'static str> {
    let mut v = vec!["boba-seq", "boba", "boba-atomic", "degree", "hub", "random"];
    if heavy {
        v.extend(["rcm", "gorder"]);
    }
    v
}

/// The T3/T4 lineup: the served-pipeline schemes ("random" = the
/// pre-randomized labels, the paper's baseline).
fn pipeline_schemes(heavy: bool) -> Vec<&'static str> {
    let mut v = vec!["random", "boba", "hub", "degree"];
    if heavy {
        v.extend(["rcm", "gorder"]);
    }
    v
}

/// Build the run's dataset list (generated graphs pre-randomized — the
/// paper's input model; on-disk files keep their labels, matching the
/// server's registry, see [`datasets::resolve_source`]). Defaults to a
/// generated RMAT / uniform / road-like trio from [`crate::graph::gen`],
/// sized by `quick`.
fn build_datasets(opts: &ReproOptions) -> Result<Vec<(String, Coo)>> {
    let seed = opts.seed;
    if opts.dataset_specs.is_empty() {
        let trio: Vec<(String, Coo)> = if opts.quick {
            vec![
                ("rmat_q".into(), gen::rmat(&gen::GenParams::rmat(13, 8), seed)),
                ("uniform_q".into(), gen::uniform_random(20_000, 120_000, seed + 1)),
                ("road_q".into(), gen::grid_road(160, 120, seed + 2).symmetrized()),
            ]
        } else {
            vec![
                ("rmat_f".into(), gen::rmat(&gen::GenParams::rmat(17, 16), seed)),
                ("uniform_f".into(), gen::uniform_random(400_000, 3_200_000, seed + 1)),
                ("road_f".into(), gen::grid_road(1_200, 900, seed + 2).symmetrized()),
            ]
        };
        return Ok(trio
            .into_iter()
            .enumerate()
            .map(|(i, (name, g))| {
                let r = g.randomized(seed + 101 + i as u64);
                (name, r)
            })
            .collect());
    }
    let mut out = Vec::new();
    for (i, spec) in opts.dataset_specs.iter().enumerate() {
        let g = datasets::resolve_source(spec, seed)
            .with_context(|| format!("resolving dataset {spec}"))?;
        let g = if datasets::is_file_spec(spec) {
            g // file labels served as-is (the registry's policy)
        } else {
            g.randomized(seed + 101 + i as u64)
        };
        out.push((spec.clone(), g));
    }
    Ok(out)
}

/// A finished repro run: the structured document plus the console
/// rendering the CLI prints.
pub struct ReproRun {
    /// Structured results (serialize with
    /// [`ResultsDoc::to_json`] / [`ResultsDoc::render_markdown`]).
    pub doc: ResultsDoc,
    /// Human-readable per-table text (aligned tables).
    pub console: String,
}

/// Execute the configured tables and collect every record.
pub fn run(opts: &ReproOptions) -> Result<ReproRun> {
    let _guard = opts.threads.map(parallel::ThreadGuard::pin);
    let scale = if opts.quick { "quick" } else { "full" };
    let mut doc = ResultsDoc::new(opts.seed, scale);
    doc.threads = parallel::threads();
    let data = build_datasets(opts)?;
    let mut console = String::new();
    for table in &opts.tables {
        match table.as_str() {
            "T1" => t1_reorder_time(opts, &data, &mut doc, &mut console),
            "T2" => t2_conversion(opts, &data, &mut doc, &mut console)?,
            "T3" => t3_end_to_end(opts, &data, &mut doc, &mut console)?,
            "T4" => t4_cache_rates(opts, &data, &mut doc, &mut console)?,
            "T5" => t5_formats(opts, &data, &mut doc, &mut console)?,
            other => bail!("unknown repro table {other:?}"),
        }
    }
    doc.rss_peak_bytes = machine::rss_peak_bytes();
    Ok(ReproRun { doc, console })
}

/// Bench preset for a scheme: heavyweight methods get fewer iterations
/// (they dominate wall-clock; their cost being orders above BOBA's *is*
/// the result, not something repetition sharpens).
fn bench_for(opts: &ReproOptions, heavy_scheme: bool) -> Bench {
    if heavy_scheme {
        Bench {
            warmup: 0,
            iters: opts.reps.clamp(1, 2),
            max_total: std::time::Duration::from_secs(300),
        }
    } else {
        Bench {
            warmup: opts.warmup,
            iters: opts.reps.max(1),
            max_total: std::time::Duration::from_secs(120),
        }
    }
}

/// A millisecond-unit [`Record`] skeleton; callers attach throughput /
/// digest before pushing.
fn timing_record(
    table: &str,
    dataset: &str,
    scheme: &str,
    app: &str,
    metric: &str,
    summary: Summary,
) -> Record {
    Record {
        table: table.into(),
        dataset: dataset.into(),
        scheme: scheme.into(),
        app: app.into(),
        metric: metric.into(),
        unit: "ms".into(),
        summary,
        items_per_sec: None,
        digest: None,
    }
}

// ───────────────────────── T1: reorder time ──────────────────────────

fn t1_reorder_time(
    opts: &ReproOptions,
    data: &[(String, Coo)],
    doc: &mut ResultsDoc,
    console: &mut String,
) {
    let mut rows = Vec::new();
    for (dname, g) in data {
        for name in t1_schemes(opts.heavy) {
            let scheme = reorder::by_name(name, opts.seed).expect("lineup names are valid");
            let heavy_scheme = !scheme.lightweight();
            // Digest first — this untimed run doubles as one warmup
            // iteration, so the bench runs one fewer (heavy schemes get
            // no extra run at all).
            let digest = perm_digest(&scheme.reorder(g));
            let mut bench = bench_for(opts, heavy_scheme);
            bench.warmup = bench.warmup.saturating_sub(1);
            let m = bench.run_with_items(
                &format!("{dname}/{name}"),
                g.m() as u64,
                || scheme.reorder(g),
            );
            rows.push(vec![
                dname.clone(),
                name.to_string(),
                human::ms(m.summary.median_ms),
                format!("±{}", human::ms(m.summary.mad_ms)),
                human::ms(m.summary.min_ms),
                human::ms(m.summary.max_ms),
                format!("n={}", m.summary.n),
                m.throughput()
                    .map(|t| format!("{} edges/s", human::count_compact(t as u64)))
                    .unwrap_or_default(),
            ]);
            let mut rec = timing_record("T1", dname, name, "", "reorder_ms", m.summary);
            rec.items_per_sec = m.throughput();
            rec.digest = Some(digest);
            doc.push(rec);
        }
    }
    console.push_str(&format!(
        "\n== {} ==\n{}",
        crate::bench::results::table_title("T1"),
        human::table(
            &["dataset", "scheme", "median", "mad", "min", "max", "iters", "throughput"],
            &rows
        )
    ));
}

// ───────────────────────── T2: conversion ────────────────────────────

fn t2_conversion(
    opts: &ReproOptions,
    data: &[(String, Coo)],
    doc: &mut ResultsDoc,
    console: &mut String,
) -> Result<()> {
    let mut rows = Vec::new();
    for (dname, g) in data {
        let bench = bench_for(opts, false);
        // BOBA-reordered copy (reorder cost is T1's business; T2 isolates
        // conversion on the two labelings, the paper's §5.3 contrast).
        let (perm, h) = Boba::parallel().reorder_relabel(g);
        let mut add =
            |scheme: &str, metric: &str, m: crate::bench::Measurement, digest: Option<String>| {
                rows.push(vec![
                    dname.clone(),
                    scheme.to_string(),
                    metric.to_string(),
                    human::ms(m.summary.median_ms),
                    human::ms(m.summary.min_ms),
                    human::ms(m.summary.max_ms),
                    format!("n={}", m.summary.n),
                ]);
                let mut rec = timing_record("T2", dname, scheme, "", metric, m.summary);
                rec.items_per_sec = m.throughput();
                rec.digest = digest;
                doc.push(rec);
            };
        let edges = g.m() as u64;
        // Output digests: the determinism gate. The deterministic
        // parallel kernels ("par-det") must reproduce the sequential
        // output bit-for-bit; a mismatch fails the run (and CI).
        let seq_rand = csr_digest(&convert::coo_to_csr(g));
        let det_rand = csr_digest(&convert::coo_to_csr_parallel(g));
        let seq_boba = csr_digest(&convert::coo_to_csr(&h));
        let det_boba = csr_digest(&convert::coo_to_csr_parallel(&h));
        let fused_seq = csr_digest(&convert::coo_to_csr_relabeled(g, perm.new_of_old()));
        let fused_par =
            csr_digest(&convert::coo_to_csr_relabeled_parallel(g, perm.new_of_old()));
        for (what, a, b) in [
            ("coo_to_csr_parallel(random)", &seq_rand, &det_rand),
            ("coo_to_csr_parallel(boba)", &seq_boba, &det_boba),
            ("coo_to_csr_relabeled(fused)", &seq_boba, &fused_seq),
            ("coo_to_csr_relabeled_parallel(fused)", &seq_boba, &fused_par),
        ] {
            if a != b {
                bail!(
                    "{dname}: {what} output digest {b} differs from the \
                     sequential digest {a} — the par-det determinism \
                     contract is broken"
                );
            }
        }
        add(
            "random",
            "convert_seq_ms",
            bench.run_with_items("seq/rand", edges, || convert::coo_to_csr(g)),
            Some(seq_rand),
        );
        add(
            "random",
            "convert_par_det_ms",
            bench.run_with_items("par-det/rand", edges, || convert::coo_to_csr_parallel(g)),
            Some(det_rand),
        );
        add(
            "random",
            "convert_par_atomic_ms",
            bench.run_with_items("par-atomic/rand", edges, || {
                convert::coo_to_csr_parallel_atomic(g)
            }),
            None, // nondeterministic within rows by design
        );
        add(
            "boba",
            "convert_seq_ms",
            bench.run_with_items("seq/boba", edges, || convert::coo_to_csr(&h)),
            Some(seq_boba.clone()),
        );
        add(
            "boba",
            "convert_par_det_ms",
            bench.run_with_items("par-det/boba", edges, || convert::coo_to_csr_parallel(&h)),
            Some(det_boba),
        );
        add(
            "boba",
            "convert_par_atomic_ms",
            bench.run_with_items("par-atomic/boba", edges, || {
                convert::coo_to_csr_parallel_atomic(&h)
            }),
            None,
        );
        add(
            "boba",
            "convert_fused_ms",
            bench.run_with_items("fused/boba", edges, || {
                convert::coo_to_csr_relabeled(g, perm.new_of_old())
            }),
            Some(fused_seq),
        );
        add(
            "boba",
            "convert_fused_par_ms",
            bench.run_with_items("fused-par/boba", edges, || {
                convert::coo_to_csr_relabeled_parallel(g, perm.new_of_old())
            }),
            Some(fused_par),
        );
        // Derived: sequential-conversion speedup post-reorder.
        let pre = doc
            .get("T2", dname, "random", "convert_seq_ms")
            .map(|r| r.summary.median_ms)
            .unwrap_or(0.0);
        let post = doc
            .get("T2", dname, "boba", "convert_seq_ms")
            .map(|r| r.summary.median_ms)
            .unwrap_or(0.0);
        if pre > 0.0 && post > 0.0 {
            doc.push(Record {
                table: "T2".into(),
                dataset: dname.clone(),
                scheme: "boba".into(),
                app: String::new(),
                metric: "convert_speedup_x".into(),
                unit: "x".into(),
                summary: Summary::single(pre / post),
                items_per_sec: None,
                digest: None,
            });
            rows.push(vec![
                dname.clone(),
                "boba".into(),
                "convert_speedup_x".into(),
                format!("{:.2}x", pre / post),
                String::new(),
                String::new(),
                String::new(),
            ]);
        }
    }
    console.push_str(&format!(
        "\n== {} ==\n{}",
        crate::bench::results::table_title("T2"),
        human::table(&["dataset", "scheme", "metric", "median", "min", "max", "iters"], &rows)
    ));
    Ok(())
}

// ───────────────────────── T3: end-to-end ────────────────────────────

fn t3_end_to_end(
    opts: &ReproOptions,
    data: &[(String, Coo)],
    doc: &mut ResultsDoc,
    console: &mut String,
) -> Result<()> {
    let mut rows = Vec::new();
    for (dname, g) in data {
        // ── ingest stage (schema boba-repro/2) ────────────────────
        // One row per dataset: ingest is scheme-independent, so it is
        // measured once instead of re-read per scheme × app. File
        // specs re-load from disk — build_datasets' first text parse
        // wrote the `.bcoo` sidecar, so this prices the binary-cache
        // hit, the steady state every later run pays. Generated specs
        // price the batched StreamingIngest assembly the server
        // registry runs (the per-iteration clone stands in for the
        // producer materializing its batches).
        let bench = bench_for(opts, false);
        let m_ingest = if datasets::is_file_spec(dname) {
            // Fallible probe first: a file deleted since build_datasets
            // surfaces as an error that keeps the T1/T2 records already
            // measured, not a panic. The timed closure then only races
            // a deletion inside the measurement window itself.
            datasets::resolve_source(dname, opts.seed)
                .with_context(|| format!("re-ingesting dataset {dname} for T3"))?;
            bench.run_with_items(&format!("{dname}/ingest"), g.m() as u64, || {
                datasets::resolve_source(dname, opts.seed)
                    .expect("dataset loadable a moment ago")
            })
        } else {
            bench.run_with_items(&format!("{dname}/ingest"), g.m() as u64, || {
                let (producer, stream) = StreamingIngest::from_coo(g.clone(), 1 << 16, 4);
                let out = stream.collect();
                producer.join().ok();
                out
            })
        };
        let mut rec = timing_record("T3", dname, "", "", "ingest_ms", m_ingest.summary);
        rec.items_per_sec = m_ingest.throughput();
        doc.push(rec);
        rows.push(vec![
            dname.clone(),
            "—".into(),
            "(ingest)".into(),
            human::ms(m_ingest.summary.median_ms),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ]);
        // ── batched SpMV (spmm) rows ──────────────────────────────
        // The serving layer's coalescer answers k concurrent SpMV
        // queries with one multi-RHS pass; these rows price that
        // amortization offline: total time for a k-wide spmm on the
        // prepared CSR (k = 1 is the single-query baseline, so
        // median/k falling as k grows is the per-query edge-stream
        // saving `benches/micro_batch.rs` sweeps in detail).
        for scheme in ["random", "boba"] {
            let csr = if scheme == "random" {
                convert::coo_to_csr_parallel(g)
            } else {
                let (_p, h) = Boba::parallel().reorder_relabel(g);
                convert::coo_to_csr_parallel(&h)
            };
            for k in [1usize, 4, 8] {
                let x = vec![1.0f32; k * csr.n()];
                let m = bench.run_with_items(
                    &format!("{dname}/{scheme}/spmm_k{k}"),
                    (g.m() * k) as u64,
                    || crate::algos::spmm::spmm_pull_parallel(&csr, &x, k),
                );
                let mut rec = timing_record(
                    "T3",
                    dname,
                    scheme,
                    "SpMV",
                    &format!("spmm_k{k}_ms"),
                    m.summary,
                );
                rec.items_per_sec = m.throughput();
                doc.push(rec);
                rows.push(vec![
                    dname.clone(),
                    "SpMV".into(),
                    format!("{scheme}/spmm_k{k}"),
                    human::ms(m.summary.median_ms),
                    format!("{:.3} ms/query", m.summary.median_ms / k as f64),
                    String::new(),
                    String::new(),
                    String::new(),
                ]);
            }
        }
        for app in App::all() {
            let mut random_median = None;
            for name in pipeline_schemes(opts.heavy) {
                let stage = stage_for(name, opts.seed)?;
                let heavy_scheme = matches!(name, "rcm" | "gorder");
                // Heavy schemes run the pipeline once (the reorder stage
                // alone dominates); light schemes honour --reps.
                let runs = if heavy_scheme { 1 } else { opts.reps.max(1) };
                let pipe = Pipeline { app, pr_iters: opts.pr_iters };
                // Median-of-k over *whole pipeline* runs; stage breakdown
                // comes from the run with the median total.
                let mut reports: Vec<_> = (0..runs).map(|_| pipe.run(g, &stage)).collect();
                reports.sort_by(|a, b| a.total_ms().partial_cmp(&b.total_ms()).unwrap());
                let mut totals: Vec<f64> = reports.iter().map(|r| r.total_ms()).collect();
                let summary = Summary::of(&mut totals);
                let median_report = &reports[reports.len() / 2];
                let mut rec =
                    timing_record("T3", dname, name, app.name(), "total_ms", summary);
                rec.items_per_sec = Some(g.m() as f64 / (summary.median_ms / 1e3).max(1e-12));
                doc.push(rec);
                for stage_name in ["reorder", "sort", "convert", "app"] {
                    if let Some(ms) = median_report.stages.ms(stage_name) {
                        doc.push(timing_record(
                            "T3",
                            dname,
                            name,
                            app.name(),
                            &format!("{stage_name}_ms"),
                            Summary::single(ms),
                        ));
                    }
                }
                let speedup = match random_median {
                    None => {
                        random_median = Some(summary.median_ms);
                        1.0
                    }
                    Some(base) => base / summary.median_ms.max(1e-9),
                };
                doc.push(Record {
                    table: "T3".into(),
                    dataset: dname.clone(),
                    scheme: name.into(),
                    app: app.name().into(),
                    metric: "speedup_x".into(),
                    unit: "x".into(),
                    summary: Summary::single(speedup),
                    items_per_sec: None,
                    digest: None,
                });
                rows.push(vec![
                    dname.clone(),
                    app.name().to_string(),
                    name.to_string(),
                    human::ms(summary.median_ms),
                    format!("{speedup:.2}x"),
                    human::ms(median_report.stages.ms("reorder").unwrap_or(0.0)),
                    human::ms(median_report.stages.ms("convert").unwrap_or(0.0)),
                    human::ms(median_report.stages.ms("app").unwrap_or(0.0)),
                ]);
            }
        }
    }
    console.push_str(&format!(
        "\n== {} ==\n{}",
        crate::bench::results::table_title("T3"),
        human::table(
            &["dataset", "app", "scheme", "total", "speedup", "reorder", "convert", "app"],
            &rows
        )
    ));
    Ok(())
}

/// Map a pipeline scheme name to its [`ReorderStage`]; "random" is the
/// no-op stage (inputs are pre-randomized).
fn stage_for(name: &str, seed: u64) -> Result<ReorderStage> {
    Ok(match name {
        "random" => ReorderStage::None,
        other => ReorderStage::Scheme(reorder::by_name(other, seed)?),
    })
}

// ───────────────────────── T4: cache rates ───────────────────────────

fn t4_cache_rates(
    opts: &ReproOptions,
    data: &[(String, Coo)],
    doc: &mut ResultsDoc,
    console: &mut String,
) -> Result<()> {
    let mut rows = Vec::new();
    for (dname, g) in data {
        for name in pipeline_schemes(opts.heavy) {
            let graph: Coo = match name {
                "random" => g.clone(),
                other => {
                    let scheme = reorder::by_name(other, opts.seed)?;
                    let (_p, h) = scheme.reorder_relabel(g);
                    h
                }
            };
            let csr = convert::coo_to_csr(&graph);
            for app in App::all() {
                let mut hier = Hierarchy::v100_scaled();
                match app {
                    App::Spmv => {
                        let x = vec![1.0f32; csr.n()];
                        black_box(crate::algos::spmv::spmv_pull_traced(&csr, &x, &mut hier));
                    }
                    App::PageRank => {
                        black_box(pagerank::pagerank_traced(
                            &csr,
                            pagerank::PrParams::default(),
                            2,
                            &mut hier,
                        ));
                    }
                    App::Tc => {
                        let und = graph.symmetrized().deduped();
                        let csr_u = convert::coo_to_csr(&und);
                        let rank = tc::degree_rank(&csr_u);
                        let dag = tc::orient_by_rank(&csr_u, &rank);
                        black_box(tc::triangle_count_ranked_traced(&dag, &rank, &mut hier));
                    }
                    App::Sssp => {
                        let src = (0..csr.n()).max_by_key(|&v| csr.degree(v)).unwrap_or(0);
                        black_box(sssp::sssp_frontier_traced(&csr, src as u32, &mut hier));
                    }
                }
                let r = hier.rates();
                for (metric, v) in [
                    ("l1_hit_pct", r.l1 * 100.0),
                    ("l2_hit_pct", r.l2 * 100.0),
                    ("dram_pct", r.dram_fraction * 100.0),
                ] {
                    doc.push(Record {
                        table: "T4".into(),
                        dataset: dname.clone(),
                        scheme: name.into(),
                        app: app.name().into(),
                        metric: metric.into(),
                        unit: "%".into(),
                        summary: Summary::single(v),
                        items_per_sec: None,
                        digest: None,
                    });
                }
                rows.push(vec![
                    dname.clone(),
                    app.name().to_string(),
                    name.to_string(),
                    format!("{:.1}", r.l1 * 100.0),
                    format!("{:.1}", r.l2 * 100.0),
                    format!("{:.1}", r.dram_fraction * 100.0),
                ]);
            }
        }
    }
    console.push_str(&format!(
        "\n== {} ==\n{}",
        crate::bench::results::table_title("T4"),
        human::table(&["dataset", "app", "scheme", "L1 %", "L2 %", "DRAM %"], &rows)
    ));
    Ok(())
}

// ───────────────────────── T5: kernel formats ────────────────────────

fn t5_formats(
    opts: &ReproOptions,
    data: &[(String, Coo)],
    doc: &mut ResultsDoc,
    console: &mut String,
) -> Result<()> {
    use crate::runtime::format::{self, SpmvFormat, FORMAT_NAMES};
    let mut rows = Vec::new();
    // One roofline row per run: the measured single-thread streaming
    // copy every effective-GB/s cell below is read against.
    let stream = machine::stream_bandwidth_gbs();
    doc.push(Record {
        table: "T5".into(),
        dataset: String::new(),
        scheme: String::new(),
        app: String::new(),
        metric: "stream_gbs".into(),
        unit: "GB/s".into(),
        summary: Summary::single(stream),
        items_per_sec: None,
        digest: None,
    });
    rows.push(vec![
        "(machine)".into(),
        String::new(),
        "stream_gbs".into(),
        format!("{stream:.2} GB/s"),
        String::new(),
        String::new(),
        String::new(),
    ]);
    let bench = bench_for(opts, false);
    for (dname, g) in data {
        for scheme in ["random", "boba"] {
            // Same labeling contrast as T3's spmm rows; rows are
            // additionally sorted so the tiled format's column tiles
            // engage (sort order is labeling-independent per row, so
            // the scheme contrast is untouched).
            let mut csr = if scheme == "random" {
                convert::coo_to_csr_parallel(g)
            } else {
                let (_p, h) = Boba::parallel().reorder_relabel(g);
                convert::coo_to_csr_parallel(&h)
            };
            csr.sort_rows();
            let x: Vec<f32> =
                (0..csr.n()).map(|i| ((i % 17) as f32) * 0.25).collect();
            let want = crate::algos::spmv::spmv_pull(&csr, &x);
            for name in FORMAT_NAMES {
                let enc = format::encode(name, &csr)
                    .with_context(|| format!("encoding {name} for {dname}@{scheme}"))?;
                // The bit-identity gate the registry enforces at
                // prepare time — a format that diverges from spmv_pull
                // must never produce a timing row.
                for (kernel, got) in
                    [("sequential", enc.spmv(&x)), ("parallel", enc.spmv_parallel(&x))]
                {
                    let same = want.len() == got.len()
                        && want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits());
                    if !same {
                        bail!(
                            "{dname}@{scheme}: {name} {kernel} SpMV diverges bitwise \
                             from spmv_pull — the format-equivalence contract is broken"
                        );
                    }
                }
                let bpe = enc.bytes_per_edge();
                let m_enc = bench.run_with_items(
                    &format!("{dname}/{scheme}/{name}/encode"),
                    csr.m() as u64,
                    || format::encode(name, &csr).expect("encoded a moment ago"),
                );
                let m_spmv = bench.run_with_items(
                    &format!("{dname}/{scheme}/{name}/spmv"),
                    csr.m() as u64,
                    || enc.spmv_parallel(&x),
                );
                // Effective bandwidth: bytes the kernel must stream
                // (column + control structure + the f32 value stream
                // and y writes, 8·n) over the median SpMV time.
                let traffic = (enc.index_bytes()
                    + enc.overhead_bytes()
                    + csr.bytes_vals()
                    + 8 * csr.n() as u64) as f64;
                let eff = traffic / (m_spmv.summary.median_ms / 1e3).max(1e-12) / 1e9;
                for (metric, unit, v) in [
                    ("bytes_per_edge", "B/edge", bpe),
                    ("encode_ms", "ms", m_enc.summary.median_ms),
                    ("spmv_ms", "ms", m_spmv.summary.median_ms),
                    ("effective_gbs", "GB/s", eff),
                ] {
                    doc.push(Record {
                        table: "T5".into(),
                        dataset: dname.clone(),
                        scheme: scheme.into(),
                        app: name.to_string(),
                        metric: metric.into(),
                        unit: unit.into(),
                        summary: Summary::single(v),
                        items_per_sec: None,
                        digest: None,
                    });
                }
                rows.push(vec![
                    dname.clone(),
                    scheme.to_string(),
                    name.to_string(),
                    format!("{bpe:.2} B/e"),
                    human::ms(m_enc.summary.median_ms),
                    human::ms(m_spmv.summary.median_ms),
                    format!("{eff:.2} ({:.0}% of stream)", 100.0 * eff / stream.max(1e-9)),
                ]);
            }
        }
    }
    console.push_str(&format!(
        "\n== {} ==\n{}",
        crate::bench::results::table_title("T5"),
        human::table(
            &["dataset", "scheme", "format", "bytes/edge", "encode", "spmv", "eff GB/s"],
            &rows
        )
    ));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runs (tiny datasets, all four tables) are exercised in
    // rust/tests/integration_repro.rs; here we cover the cheap pure
    // machinery.

    #[test]
    fn parse_tables_accepts_subsets_and_all() {
        assert_eq!(parse_tables("all").unwrap(), all_tables());
        assert_eq!(parse_tables("t1,t3").unwrap(), vec!["T1", "T3"]);
        assert_eq!(parse_tables("T4,t4").unwrap(), vec!["T4"]);
        assert!(parse_tables("t9").is_err());
        assert!(parse_tables("").is_err());
    }

    #[test]
    fn t1_lineup_has_all_boba_variants_and_baselines() {
        let light = t1_schemes(false);
        for s in ["boba-seq", "boba", "boba-atomic", "degree", "hub", "random"] {
            assert!(light.contains(&s), "{s} missing");
        }
        assert!(!light.contains(&"gorder"));
        let heavy = t1_schemes(true);
        assert!(heavy.contains(&"rcm") && heavy.contains(&"gorder"));
        // Every name resolves in the shared CLI vocabulary.
        for s in heavy {
            reorder::by_name(s, 1).unwrap();
        }
    }

    #[test]
    fn perm_digest_distinguishes_and_repeats() {
        let a = Permutation::from_new_of_old(vec![0, 1, 2]);
        let b = Permutation::from_new_of_old(vec![2, 1, 0]);
        assert_eq!(perm_digest(&a), perm_digest(&a));
        assert_ne!(perm_digest(&a), perm_digest(&b));
        assert_eq!(perm_digest(&a).len(), 16);
    }

    #[test]
    fn quick_datasets_are_ci_sized() {
        let opts = ReproOptions::quick(7);
        let data = build_datasets(&opts).unwrap();
        assert_eq!(data.len(), 3);
        for (name, g) in &data {
            assert!(g.m() <= 200_000, "{name} too big for quick: {}", g.m());
            assert!(g.m() >= 50_000, "{name} too small: {}", g.m());
            g.validate().unwrap();
        }
    }

    #[test]
    fn dataset_specs_resolve_via_shared_vocabulary() {
        let mut opts = ReproOptions::quick(3);
        opts.dataset_specs = vec!["rmat:10:4".into()];
        let data = build_datasets(&opts).unwrap();
        assert_eq!(data.len(), 1);
        assert_eq!(data[0].1.n(), 1 << 10);
        opts.dataset_specs = vec!["no-such-dataset".into()];
        assert!(build_datasets(&opts).is_err());
    }
}
