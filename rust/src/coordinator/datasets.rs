//! The dataset suite — synthetic stand-ins for the paper's Table 2
//! corpus (SuiteSparse/SNAP are unreachable offline; DESIGN.md §2 defends
//! each substitution). Scales are reduced so the full experiment sweep
//! finishes on CPU; set `BOBA_SCALE=full` for larger instances, or
//! `quick` (default for tests) for CI-sized ones.

use crate::graph::gen::{self, GenParams};
use crate::graph::Coo;

/// Degree-structure family, the axis the paper's evaluation splits on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Skew / power-law (kron, soc-*, hollywood, arabic, PA).
    ScaleFree,
    /// Uniform / road-like (road_usa, osm, delaunay, rgg).
    Uniform,
}

/// Suite scale knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// CI-sized (≈0.1–0.5M edges): every experiment in seconds.
    Quick,
    /// Benchmark-sized (≈2–8M edges): minutes per figure.
    Full,
}

impl Scale {
    /// Read from `BOBA_SCALE` (default Quick).
    pub fn from_env() -> Scale {
        match std::env::var("BOBA_SCALE").as_deref() {
            Ok("full") | Ok("FULL") => Scale::Full,
            _ => Scale::Quick,
        }
    }
}

/// A dataset recipe (name + generator + family).
#[derive(Clone)]
pub struct Dataset {
    /// Table-row name, styled after the paper's corpus.
    pub name: &'static str,
    /// Paper dataset this one stands in for.
    pub stands_in_for: &'static str,
    /// Degree family.
    pub family: Family,
    build: fn(Scale, u64) -> Coo,
}

impl Dataset {
    /// Build the graph (deterministic per seed).
    pub fn build(&self, seed: u64) -> Coo {
        (self.build)(Scale::from_env(), seed)
    }

    /// Build at an explicit scale.
    pub fn build_at(&self, scale: Scale, seed: u64) -> Coo {
        (self.build)(scale, seed)
    }
}

fn kron(scale: Scale, seed: u64) -> Coo {
    let s = match scale {
        Scale::Quick => 14,
        Scale::Full => 18,
    };
    gen::rmat(&GenParams::rmat(s, 16), seed)
}

fn soc(scale: Scale, seed: u64) -> Coo {
    let s = match scale {
        Scale::Quick => 14,
        Scale::Full => 18,
    };
    gen::rmat(&GenParams::rmat_social(s, 12), seed)
}

fn pa(scale: Scale, seed: u64) -> Coo {
    let n = match scale {
        Scale::Quick => 20_000,
        Scale::Full => 400_000,
    };
    gen::preferential_attachment(n, 8, seed)
}

fn hollywood(scale: Scale, seed: u64) -> Coo {
    // hollywood-2009: small n, very high average degree (~100),
    // symmetric (co-starring is undirected).
    let n = match scale {
        Scale::Quick => 4_000,
        Scale::Full => 60_000,
    };
    gen::preferential_attachment(n, 48, seed).symmetrized()
}

// The paper's road/delaunay/rgg matrices are SYMMETRIC (SuiteSparse
// stores them as undirected graphs); the builders symmetrize so
// out-neighborhoods match the paper's — this matters to NBR, which can
// only drop below 1 when a vertex has multiple neighbors per cache line.

fn road(scale: Scale, seed: u64) -> Coo {
    let (w, h) = match scale {
        Scale::Quick => (400, 300),
        Scale::Full => (2_000, 1_500),
    };
    gen::grid_road(w, h, seed).symmetrized()
}

fn delaunay(scale: Scale, seed: u64) -> Coo {
    let (w, h) = match scale {
        Scale::Quick => (360, 360),
        Scale::Full => (1_600, 1_600),
    };
    gen::delaunay_mesh(w, h, seed).symmetrized()
}

fn rgg(scale: Scale, seed: u64) -> Coo {
    let s = match scale {
        Scale::Quick => 17,
        Scale::Full => 21,
    };
    gen::rgg(s, 12, seed).symmetrized()
}

/// The scale-free suite (paper Fig. 5's row of datasets).
pub fn scale_free_suite() -> Vec<Dataset> {
    vec![
        Dataset {
            name: "kron_s",
            stands_in_for: "kron_g500-logn20/21",
            family: Family::ScaleFree,
            build: kron,
        },
        Dataset {
            name: "soc_s",
            stands_in_for: "soc-LiveJournal/soc-orkut",
            family: Family::ScaleFree,
            build: soc,
        },
        Dataset {
            name: "pa_c8",
            stands_in_for: "ljournal-2008 / arabic-2005 (PA-like web)",
            family: Family::ScaleFree,
            build: pa,
        },
        Dataset {
            name: "hollywood_s",
            stands_in_for: "hollywood-2009",
            family: Family::ScaleFree,
            build: hollywood,
        },
    ]
}

/// The uniform/road suite (paper Fig. 6's datasets).
pub fn uniform_suite() -> Vec<Dataset> {
    vec![
        Dataset {
            name: "road_grid",
            stands_in_for: "road_usa / great-britain_osm",
            family: Family::Uniform,
            build: road,
        },
        Dataset {
            name: "delaunay_s",
            stands_in_for: "delaunay_n22/23/24",
            family: Family::Uniform,
            build: delaunay,
        },
        Dataset {
            name: "rgg_s",
            stands_in_for: "rgg_n_2_22/23/24_s0",
            family: Family::Uniform,
            build: rgg,
        },
    ]
}

/// All datasets (Table 1 / Table 2 order: uniform first, like the paper).
pub fn full_suite() -> Vec<Dataset> {
    let mut v = uniform_suite();
    v.extend(scale_free_suite());
    v
}

/// Look a dataset up by name.
pub fn by_name(name: &str) -> Option<Dataset> {
    full_suite().into_iter().find(|d| d.name == name)
}

/// Resolve a dataset *spec* to a COO: a suite name from [`full_suite`]
/// or an ad-hoc generator recipe — `rmat:SCALE:EDGEFACTOR`, `pa:N:C`,
/// `grid:W:H`. Shared by the CLI dispatcher and the server's graph
/// registry, so `boba run --dataset X` and `POST /graphs {"dataset":
/// "X"}` accept exactly the same vocabulary.
pub fn resolve(spec: &str, seed: u64) -> anyhow::Result<Coo> {
    if let Some(d) = by_name(spec) {
        return Ok(d.build(seed));
    }
    let parts: Vec<&str> = spec.split(':').collect();
    match parts.as_slice() {
        ["rmat", s, ef] => Ok(gen::rmat(&GenParams::rmat(s.parse()?, ef.parse()?), seed)),
        ["pa", n, c] => Ok(gen::preferential_attachment(n.parse()?, c.parse()?, seed)),
        ["grid", w, h] => Ok(gen::grid_road(w.parse()?, h.parse()?, seed)),
        _ => anyhow::bail!(
            "unknown dataset {spec} (see `boba datasets`, or use rmat:S:EF | pa:N:C | grid:W:H)"
        ),
    }
}

/// True if `spec` names an on-disk graph file rather than a suite name
/// or generator recipe.
pub fn is_file_spec(spec: &str) -> bool {
    spec.ends_with(".mtx")
        || spec.ends_with(".el")
        || spec.ends_with(".txt")
        || spec.ends_with(".bcoo")
}

/// Resolve a dataset *source*: an on-disk `.mtx`/`.el`/`.txt`/`.bcoo`
/// file or a [`resolve`] spec. Edge-list files keep their vertex IDs
/// (`preserve_ids` — a dense first-appearance relabel would itself be a
/// sequential BOBA pass, silently pre-reordering the baseline). Text
/// files go through [`crate::graph::io::load_graph_file`], so the
/// parallel byte-level parser and the write-once `.bcoo` sidecar cache
/// apply to every consumer — the CLI, the server's registry, and the
/// repro harness — and a repeated load (server restarts, repro sweeps)
/// is a memcpy, not a re-parse. No randomization is applied here: file
/// labels are served as-is, and callers apply
/// [`crate::graph::Coo::randomized`] to generated graphs per the
/// paper's input model.
pub fn resolve_source(spec: &str, seed: u64) -> anyhow::Result<Coo> {
    use crate::graph::io;
    use std::path::Path;
    if is_file_spec(spec) {
        return io::load_graph_file(Path::new(spec), true);
    }
    resolve(spec, seed)
}

/// Table 2 analogue: the dataset inventory with |V|, |E| and CSR sizes.
pub fn inventory(seed: u64) -> String {
    use crate::convert::coo_to_csr;
    use crate::util::human;
    let mut rows = Vec::new();
    for d in full_suite() {
        let g = d.build(seed);
        let csr = coo_to_csr(&g);
        rows.push(vec![
            d.name.to_string(),
            human::count_compact(g.n() as u64),
            human::count_compact(g.m() as u64),
            human::mb_decimal(csr.bytes_offsets()),
            human::mb_decimal(csr.bytes_indices()),
            d.stands_in_for.to_string(),
        ]);
    }
    human::table(
        &["dataset", "|V|", "|E|", "offsets MB", "indices MB", "stands in for"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_nonempty_and_distinct() {
        let names: Vec<_> = full_suite().iter().map(|d| d.name).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
        assert!(names.len() >= 7);
    }

    #[test]
    fn builds_are_deterministic() {
        let d = by_name("road_grid").unwrap();
        assert_eq!(d.build_at(Scale::Quick, 1), d.build_at(Scale::Quick, 1));
    }

    #[test]
    fn families_assigned() {
        for d in scale_free_suite() {
            assert_eq!(d.family, Family::ScaleFree);
        }
        for d in uniform_suite() {
            assert_eq!(d.family, Family::Uniform);
        }
    }

    #[test]
    fn quick_scale_bounded() {
        for d in full_suite() {
            let g = d.build_at(Scale::Quick, 3);
            assert!(g.m() < 2_000_000, "{} too big for quick: {}", d.name, g.m());
            assert!(g.m() > 50_000, "{} too small: {}", d.name, g.m());
            g.validate().unwrap();
        }
    }

    #[test]
    fn file_specs_detected_and_resolved() {
        assert!(is_file_spec("g.mtx") && is_file_spec("g.el") && is_file_spec("g.txt"));
        assert!(is_file_spec("g.bcoo"), ".bcoo is a file spec");
        assert!(!is_file_spec("rmat:10:4") && !is_file_spec("road_grid"));
        // Recipes fall through to resolve(); missing files / bogus specs
        // error instead of panicking.
        assert_eq!(resolve_source("rmat:10:4", 1).unwrap().n(), 1 << 10);
        assert!(resolve_source("/no/such/file.mtx", 1).is_err());
        assert!(resolve_source("/no/such/file.bcoo", 1).is_err());
        assert!(resolve_source("bogus-spec", 1).is_err());
    }

    #[test]
    fn inventory_renders() {
        let s = inventory(1);
        assert!(s.contains("kron_s") && s.contains("delaunay_s"));
    }
}
