//! L3 coordinator — the paper's pragmatic graph-creation pipeline
//! (Problem 3) and the experiment drivers that regenerate every table and
//! figure of the evaluation section.
//!
//! * [`datasets`] — the synthetic dataset suite standing in for the
//!   paper's Table 2 corpus (recipes + deterministic builds).
//! * [`pipeline`] — the ingest → reorder → convert → compute pipeline
//!   with streaming/batched ingestion and per-stage timing (Fig. 4's
//!   stacked bars come from these records).
//! * [`experiments`] — one driver per paper table/figure (Table 1,
//!   Table 3, Fig. 4–7), shared by the CLI and the benches.
//! * [`repro`] — the `boba repro` harness: the scheme × dataset × kernel
//!   matrix as four repro tables (T1–T4), emitted as `BENCH_repro.json`
//!   and `docs/RESULTS.md`.

pub mod datasets;
pub mod pipeline;
pub mod experiments;
pub mod repro;
