//! Experiment drivers — one per table/figure of the paper's evaluation.
//! Shared by the `boba` CLI and the `rust/benches/*` bench targets so the
//! numbers in docs/EXPERIMENTS.md are regenerable from either entry point.
//! (The machine-readable counterpart of these drivers is
//! [`crate::coordinator::repro`], which runs the same scheme × dataset ×
//! kernel matrix under the repro methodology and emits
//! `BENCH_repro.json`.)
//!
//! Every driver consumes pre-randomized inputs (the paper's §5 model) and
//! returns an [`ExpTable`] of structured rows plus helpers to render the
//! same layout the paper prints.

use super::datasets::{self, Dataset};
use super::pipeline::{App, Pipeline, ReorderStage};
use crate::algos::{pagerank, spmv, sssp, tc};
use crate::cachesim::Hierarchy;
use crate::convert;
use crate::graph::Coo;
use crate::metrics;
use crate::reorder::{
    boba::Boba, degree::DegreeSort, gorder::Gorder, hub::HubSort, rcm::Rcm, Reorderer,
};
use crate::util::human;
use crate::util::timer::Stopwatch;

/// A rendered experiment: header + data rows (all strings, pre-formatted)
/// plus the raw numbers keyed `(row_label, column)` for tests.
pub struct ExpTable {
    /// Table title (e.g. "Table 1: NBR").
    pub title: String,
    /// Column headers.
    pub header: Vec<String>,
    /// Formatted rows.
    pub rows: Vec<Vec<String>>,
    /// Raw values for assertions: (row, col) -> value.
    pub raw: Vec<(String, String, f64)>,
}

impl ExpTable {
    fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            raw: Vec::new(),
        }
    }

    fn record(&mut self, row: &str, col: &str, v: f64) {
        self.raw.push((row.to_string(), col.to_string(), v));
    }

    /// Raw value lookup.
    pub fn get(&self, row: &str, col: &str) -> Option<f64> {
        self.raw
            .iter()
            .find(|(r, c, _)| r == row && c == col)
            .map(|(_, _, v)| *v)
    }

    /// Render to an aligned text table.
    pub fn render(&self) -> String {
        let h: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        format!("\n== {} ==\n{}", self.title, human::table(&h, &self.rows))
    }
}

/// Whether to include the heavyweight schemes (Gorder/RCM). They dominate
/// wall-clock; `BOBA_HEAVY=0` skips them.
pub fn include_heavy() -> bool {
    !matches!(std::env::var("BOBA_HEAVY").as_deref(), Ok("0") | Ok("false"))
}

/// The scheme lineup of Table 1 / Fig. 5 / Fig. 6, in paper column order.
fn schemes(heavy: bool) -> Vec<Box<dyn Reorderer + Send + Sync>> {
    let mut v: Vec<Box<dyn Reorderer + Send + Sync>> = Vec::new();
    if heavy {
        v.push(Box::new(Gorder::new(5)));
        v.push(Box::new(Rcm::new()));
    }
    v.push(Box::new(Boba::parallel()));
    v.push(Box::new(HubSort::new()));
    v.push(Box::new(DegreeSort::new()));
    v
}

// ───────────────────────── Table 1: NBR ──────────────────────────────

/// Table 1 — the NBR spatial-locality metric over CSR for every dataset
/// × {Rand, Gorder, RCM, BOBA, Hub}. Lower is better.
pub fn table1(seed: u64) -> ExpTable {
    let heavy = include_heavy();
    let mut header = vec!["dataset", "Rand"];
    if heavy {
        header.extend(["Gorder", "RCM"]);
    }
    header.extend(["BOBA", "Hub", "Degree"]);
    let mut t = ExpTable::new("Table 1: NBR metric over CSR (lower = better locality)", &header);
    for d in datasets::full_suite() {
        let g = d.build(seed).randomized(seed + 1);
        let mut row = vec![d.name.to_string()];
        let rand_nbr = metrics::nbr_coo(&g);
        t.record(d.name, "Rand", rand_nbr);
        row.push(format!("{rand_nbr:.2}"));
        for s in schemes(heavy) {
            let perm = s.reorder(&g);
            let h = g.relabeled(perm.new_of_old());
            let v = metrics::nbr_coo(&h);
            t.record(d.name, s.name(), v);
            row.push(format!("{v:.2}"));
        }
        t.rows.push(row);
    }
    t
}

// ───────────────────────── Table 3: randomized inputs ────────────────

/// Table 3 — SpMV and COO→CSR runtimes on *pre-randomized* datasets,
/// Rand vs BOBA (the "is BOBA safe to apply indiscriminately?" check;
/// delaunay is the designed negative result).
///
/// Table 3's whole point is memory behaviour, so its graphs are built at
/// a fixed vertex scale that exceeds the testbed's L2 regardless of
/// `BOBA_SCALE` (dense working sets 4–16 MiB; the paper's were 4–90 MB).
pub fn table3(seed: u64) -> ExpTable {
    use crate::graph::gen;
    let mut t = ExpTable::new(
        "Table 3: randomized datasets — SpMV / COO→CSR ms (Rand vs BOBA)",
        &["dataset", "Rand SpMV", "Rand conv", "BOBA SpMV", "BOBA conv"],
    );
    // The paper's Table-3 lineup: arabic (PA web), soc, delaunay, coPapers
    // (dense PA) — mapped to matched-structure builds.
    let lineup: Vec<(&str, Coo)> = vec![
        ("arabic_like", gen::preferential_attachment(4_000_000, 8, seed)),
        ("soc_like", gen::rmat(&gen::GenParams::rmat_social(20, 8), seed)),
        ("delaunay_like", gen::delaunay_mesh(1000, 1000, seed).symmetrized()),
        ("copapers_like", gen::preferential_attachment(150_000, 48, seed).symmetrized()),
    ];
    for (name, raw) in lineup {
        let g = raw.randomized(seed + 7);
        let (rand_spmv, rand_conv) = time_conv_spmv(&g);
        let (_, h) = Boba::parallel().reorder_relabel(&g);
        let (boba_spmv, boba_conv) = time_conv_spmv(&h);
        t.record(name, "rand_spmv", rand_spmv);
        t.record(name, "rand_conv", rand_conv);
        t.record(name, "boba_spmv", boba_spmv);
        t.record(name, "boba_conv", boba_conv);
        t.rows.push(vec![
            name.to_string(),
            human::ms(rand_spmv),
            human::ms(rand_conv),
            human::ms(boba_spmv),
            human::ms(boba_conv),
        ]);
    }
    t
}

fn time_conv_spmv(g: &Coo) -> (f64, f64) {
    let sw = Stopwatch::start();
    let csr = convert::coo_to_csr(g);
    let conv = sw.ms();
    let x = vec![1.0f32; csr.n()];
    // Median of 3 SpMV runs.
    let mut samples: Vec<f64> = (0..3)
        .map(|_| {
            let sw = Stopwatch::start();
            crate::bench::black_box(spmv::spmv_pull(&csr, &x));
            sw.ms()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (samples[1], conv)
}

// ───────────────────────── Fig. 4: end-to-end ─────────────────────────

/// Fig. 4 — end-to-end stacked stage times (reorder + \[sort\] + convert +
/// app), BOBA vs Random, per application × dataset. The headline
/// end-to-end speedup numbers come from here.
///
/// Like Table 3, Fig. 4 is a memory-behaviour experiment: it uses
/// dedicated builds whose dense working sets exceed L2 (the `quick`
/// suite fits this testbed's 105 MB LLC entirely, where reordering has
/// nothing to win — DESIGN.md §2).
pub fn fig4(seed: u64) -> ExpTable {
    use crate::graph::gen;
    let mut t = ExpTable::new(
        "Fig 4: end-to-end time (ms) — Random vs BOBA (reorder+sort+convert+app)",
        &["dataset", "app", "rand total", "boba total", "speedup", "boba reorder", "boba convert", "boba app"],
    );
    let lineup: Vec<(&str, Coo)> = vec![
        ("pa4M", gen::preferential_attachment(4_000_000, 8, seed)),
        ("road1.5M", gen::grid_road(1500, 1000, seed).symmetrized()),
    ];
    for (d_name, raw) in lineup {
        let g = raw.randomized(seed + 3);
        for app in App::all() {
            let pipe = Pipeline::new(app);
            let rand = pipe.run(&g, &ReorderStage::None);
            let boba = pipe.run(&g, &ReorderStage::Scheme(Box::new(Boba::parallel())));
            let key = format!("{}/{}", d_name, app.name());
            let speedup = rand.total_ms() / boba.total_ms();
            t.record(&key, "rand_total", rand.total_ms());
            t.record(&key, "boba_total", boba.total_ms());
            t.record(&key, "speedup", speedup);
            t.record(&key, "boba_reorder", boba.stages.ms("reorder").unwrap_or(0.0));
            t.rows.push(vec![
                d_name.to_string(),
                app.name().to_string(),
                human::ms(rand.total_ms()),
                human::ms(boba.total_ms()),
                format!("{speedup:.2}x"),
                human::ms(boba.stages.ms("reorder").unwrap_or(0.0)),
                human::ms(boba.stages.ms("convert").unwrap_or(0.0)),
                human::ms(boba.stages.ms("app").unwrap_or(0.0)),
            ]);
        }
    }
    t
}

// ───────────────── Fig. 5 / Fig. 6: runtime vs reorder time ───────────

/// Shared driver for Fig. 5 (scale-free) and Fig. 6 (uniform): for every
/// dataset × scheme, the reorder time plus each application's runtime
/// normalized to the Random baseline.
fn fig56(datasets_: Vec<Dataset>, title: &str, seed: u64) -> ExpTable {
    let heavy = include_heavy();
    let mut t = ExpTable::new(
        title,
        &["dataset", "scheme", "reorder ms", "SpMV rel", "PR rel", "TC rel", "SSSP rel"],
    );
    for d in datasets_ {
        let g = d.build(seed).randomized(seed + 5);
        // SSSP source: fixed by *identity*, then mapped through each
        // scheme's permutation so every run explores the same subgraph.
        let source = {
            let deg = g.total_degrees();
            (0..g.n()).max_by_key(|&v| deg[v]).unwrap_or(0) as u32
        };
        // Random baseline runtimes.
        let base = app_runtimes(&g, None, source);
        for s in schemes(heavy) {
            let sw = Stopwatch::start();
            let perm = s.reorder(&g);
            let reorder_ms = sw.ms();
            let h = g.relabeled(perm.new_of_old());
            let times = app_runtimes(&h, Some(&base), perm.new_of_old()[source as usize]);
            let key = format!("{}/{}", d.name, s.name());
            t.record(&key, "reorder_ms", reorder_ms);
            let mut row = vec![
                d.name.to_string(),
                s.name().to_string(),
                human::ms(reorder_ms),
            ];
            for (app, rel) in ["SpMV", "PR", "TC", "SSSP"].iter().zip(times.rel) {
                t.record(&key, app, rel);
                row.push(format!("{rel:.2}"));
            }
            t.rows.push(row);
        }
    }
    t
}

struct AppTimes {
    abs: [f64; 4],
    rel: [f64; 4],
}

/// Run the four applications on a (possibly reordered) graph; `base`
/// normalizes to a prior run's absolute times; `source` is the SSSP
/// source in the graph's *current* labeling.
fn app_runtimes(g: &Coo, base: Option<&AppTimes>, source: u32) -> AppTimes {
    let csr = convert::coo_to_csr(g);
    let x = vec![1.0f32; csr.n()];
    // SpMV: median of 3.
    let spmv_ms = median3(|| {
        crate::bench::black_box(spmv::spmv_pull(&csr, &x));
    });
    let pr_ms = {
        let sw = Stopwatch::start();
        crate::bench::black_box(pagerank::pagerank(
            &csr,
            pagerank::PrParams { max_iters: 10, tol: 0.0, ..Default::default() },
        ));
        sw.ms()
    };
    let tc_ms = {
        let und = g.symmetrized().deduped();
        let sorted = convert::sort_coo_by_src(&und);
        let csr_s = convert::coo_to_csr(&sorted);
        let rank = tc::degree_rank(&csr_s);
        let dag = tc::orient_by_rank(&csr_s, &rank);
        let sw = Stopwatch::start();
        crate::bench::black_box(tc::triangle_count_ranked(&dag, &rank));
        sw.ms()
    };
    let sssp_ms = median3(|| {
        crate::bench::black_box(sssp::sssp_frontier(&csr, source));
    });
    let abs = [spmv_ms, pr_ms, tc_ms, sssp_ms];
    let rel = match base {
        Some(b) => {
            let mut r = [0.0; 4];
            for i in 0..4 {
                r[i] = abs[i] / b.abs[i].max(1e-9);
            }
            r
        }
        None => [1.0; 4],
    };
    AppTimes { abs, rel }
}

fn median3(mut f: impl FnMut()) -> f64 {
    let mut s: Vec<f64> = (0..3)
        .map(|_| {
            let sw = Stopwatch::start();
            f();
            sw.ms()
        })
        .collect();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[1]
}

/// Fig. 5 — scale-free graphs.
pub fn fig5(seed: u64) -> ExpTable {
    fig56(
        datasets::scale_free_suite(),
        "Fig 5: runtime (normalized to Random) vs reorder time — scale-free",
        seed,
    )
}

/// Fig. 6 — uniform/road graphs.
pub fn fig6(seed: u64) -> ExpTable {
    fig56(
        datasets::uniform_suite(),
        "Fig 6: runtime (normalized to Random) vs reorder time — uniform/road",
        seed,
    )
}

// ───────────────────────── Fig. 7: cache hit rates ────────────────────

/// Fig. 7 — simulated L1/L2 hit rates (and DRAM fraction) per application
/// × scheme on one scale-free and one uniform dataset.
///
/// Uses purpose-built graphs whose dense-vector working set exceeds the
/// simulated L2 (as the paper's million-vertex datasets exceed the
/// V100's), with [`Hierarchy::v100_scaled`] keeping the
/// cache : working-set ratio comparable.
pub fn fig7(seed: u64) -> ExpTable {
    let heavy = include_heavy();
    let mut t = ExpTable::new(
        "Fig 7: simulated cache hit rates (V100-scaled hierarchy, reads only)",
        &["dataset", "app", "scheme", "L1 %", "L2 %", "DRAM %"],
    );
    let picks: [(&str, Coo); 2] = [
        ("kron18", crate::graph::gen::rmat(&crate::graph::gen::GenParams::rmat(18, 8), seed)),
        ("road800", crate::graph::gen::grid_road(800, 400, seed)),
    ];
    for (name, raw) in picks {
        let d_name = name;
        let g = raw.randomized(seed + 9);
        // Schemes incl. the Random identity. Gorder runs with a tighter
        // hub cap here: at Fig. 7's graph sizes the uncapped sibling
        // enumeration costs tens of minutes for an ordering whose hit
        // rates the cap barely moves (docs/EXPERIMENTS.md notes the ablation).
        let mut lineup: Vec<(String, Coo)> = vec![("Random".into(), g.clone())];
        let mut fig7_schemes: Vec<Box<dyn Reorderer + Send + Sync>> = Vec::new();
        if heavy {
            fig7_schemes.push(Box::new(Gorder::with_hub_cap(5, 256)));
            fig7_schemes.push(Box::new(Rcm::new()));
        }
        fig7_schemes.push(Box::new(Boba::parallel()));
        fig7_schemes.push(Box::new(HubSort::new()));
        fig7_schemes.push(Box::new(DegreeSort::new()));
        for s in fig7_schemes {
            let perm = s.reorder(&g);
            lineup.push((s.name().to_string(), g.relabeled(perm.new_of_old())));
        }
        for (scheme, graph) in &lineup {
            let csr = convert::coo_to_csr(graph);
            for app in App::all() {
                let mut hier = Hierarchy::v100_scaled();
                match app {
                    App::Spmv => {
                        let x = vec![1.0f32; csr.n()];
                        crate::bench::black_box(spmv::spmv_pull_traced(&csr, &x, &mut hier));
                    }
                    App::PageRank => {
                        crate::bench::black_box(pagerank::pagerank_traced(
                            &csr,
                            pagerank::PrParams::default(),
                            2,
                            &mut hier,
                        ));
                    }
                    App::Tc => {
                        let und = graph.symmetrized().deduped();
                        let csr_u = convert::coo_to_csr(&und);
                        let rank = tc::degree_rank(&csr_u);
                        let dag = tc::orient_by_rank(&csr_u, &rank);
                        crate::bench::black_box(tc::triangle_count_ranked_traced(
                            &dag, &rank, &mut hier,
                        ));
                    }
                    App::Sssp => {
                        // Max-out-degree source: source 0 can be a fringe
                        // vertex under some relabelings, yielding a
                        // near-empty (unrepresentative) trace.
                        let src = (0..csr.n()).max_by_key(|&v| csr.degree(v)).unwrap_or(0);
                        crate::bench::black_box(sssp::sssp_frontier_traced(
                            &csr, src as u32, &mut hier,
                        ));
                    }
                }
                let r = hier.rates();
                let key = format!("{}/{}/{}", d_name, app.name(), scheme);
                t.record(&key, "l1", r.l1);
                t.record(&key, "l2", r.l2);
                t.record(&key, "dram", r.dram_fraction);
                t.rows.push(vec![
                    d_name.to_string(),
                    app.name().to_string(),
                    scheme.clone(),
                    format!("{:.1}", r.l1 * 100.0),
                    format!("{:.1}", r.l2 * 100.0),
                    format!("{:.1}", r.dram_fraction * 100.0),
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    // Experiment drivers are exercised end-to-end in
    // rust/tests/integration_experiments.rs (they are minutes-long at
    // default scale); here we only check the cheap table machinery.

    #[test]
    fn exptable_records_and_gets() {
        let mut t = ExpTable::new("t", &["a", "b"]);
        t.record("r1", "a", 1.5);
        assert_eq!(t.get("r1", "a"), Some(1.5));
        assert_eq!(t.get("r1", "b"), None);
        t.rows.push(vec!["r1".into(), "1.5".into()]);
        assert!(t.render().contains("== t =="));
    }

    #[test]
    fn scheme_lineup_order() {
        let names: Vec<_> = schemes(true).iter().map(|s| s.name()).collect();
        assert_eq!(names, vec!["Gorder", "RCM", "BOBA", "Hub", "Degree"]);
        let light: Vec<_> = schemes(false).iter().map(|s| s.name()).collect();
        assert_eq!(light, vec!["BOBA", "Hub", "Degree"]);
    }
}
