//! The repo-invariant rules `boba lint` enforces, each grounded in an
//! invariant docs/ARCHITECTURE.md or a module doc already states:
//!
//! | rule | invariant |
//! |---|---|
//! | `unsafe-safety` | every `unsafe` carries a `// SAFETY:` (or `# Safety` doc section) and lives in a whitelisted module |
//! | `raw-spawn` | kernel parallelism goes through `parallel::pool`; raw `thread::spawn`/`scope`/`Builder` only where whitelisted or in tests |
//! | `panic-path` | the serve path answers with status codes — no `unwrap`/`expect`/`panic!`/`unreachable!` outside tests |
//! | `atomic-ordering` | every non-counter `Ordering::` use names its pairing in an `// ordering:` comment |
//! | `metrics-drift` | `boba_*` families emitted in code == ci.sh exposition gate == ARCHITECTURE.md table |
//! | `chaos-drift` | `obs::chaos` fault points == the ARCHITECTURE.md fault table |
//! | `ablation-reach` | `*_atomic` nondeterministic kernels referenced only from their module, repro, and tests |
//!
//! Escape hatch: `// lint: allow(<rule>): <reason>` suppresses the
//! named rule on the comment's line, the rest of its comment block,
//! and the first code line below. The reason is mandatory — a bare
//! allow is itself a violation (`allow-syntax`).

use super::lex::{find_token, ident_byte, line_of, memfind, Scanned};
use super::{LintInput, Violation};
use std::collections::BTreeSet;

/// Every rule name `lint: allow(...)` may reference.
pub const RULES: &[&str] = &[
    "unsafe-safety",
    "raw-spawn",
    "panic-path",
    "atomic-ordering",
    "metrics-drift",
    "chaos-drift",
    "ablation-reach",
];

/// Files (relative to rust/src) allowed to contain `unsafe` code.
pub const UNSAFE_OK: &[&str] = &[
    "algos/pagerank.rs",
    "algos/spmm.rs",
    "algos/spmv.rs",
    "convert/mod.rs",
    "graph/delta.rs",
    "graph/io/bcoo.rs",
    "obs/ring.rs",
    "parallel/mod.rs",
    "parallel/pool.rs",
    "reorder/boba.rs",
    "runtime/delta.rs",
    "runtime/ell.rs",
    "runtime/sell.rs",
    "runtime/tiled.rs",
];

/// Files allowed to spawn raw OS threads (the pool itself and the
/// server's accept/worker threads); everything else annotates or uses
/// the pool.
pub const SPAWN_OK: &[&str] = &["parallel/pool.rs", "server/mod.rs"];

/// The serve request path: no unwrap/expect/panic! outside tests.
pub const PANIC_PATH_FILES: &[&str] = &[
    "server/admission.rs",
    "server/coalesce.rs",
    "server/http.rs",
    "server/live.rs",
    "server/router.rs",
    "server/wal.rs",
];

/// Files whose `Ordering::Relaxed` uses are pure counters/gauges (no
/// synchronization piggybacks on them) — Relaxed needs no annotation
/// there. Acquire/Release/AcqRel/SeqCst always need one.
pub const RELAXED_COUNTER_OK: &[&str] = &[
    "algos/pagerank.rs",
    "algos/tc.rs",
    "convert/mod.rs",
    "graph/io/bcoo.rs",
    "obs/chaos.rs",
    "obs/corrupt.rs",
    "obs/hist.rs",
    "obs/ring.rs",
    "obs/span.rs",
    "parallel/atomic.rs",
    "parallel/mod.rs",
    "parallel/pool.rs",
    "server/admission.rs",
    "server/coalesce.rs",
    "server/live.rs",
    "server/loadgen.rs",
    "server/mod.rs",
    "server/registry.rs",
    "server/router.rs",
    "server/stats.rs",
    "server/wal.rs",
];

const ATOMIC_VARIANTS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Line ranges (1-based, inclusive) covered by `#[cfg(test)]` /
/// `#[test]` items — brace-matched on the masked text.
pub fn test_ranges(s: &Scanned) -> Vec<(usize, usize)> {
    let mask = &s.mask;
    let n = mask.len();
    let mut ranges = Vec::new();
    for marker in ["#[cfg(test)]", "#[test]"] {
        let mut start = 0;
        while let Some(p) = memfind(mask, marker.as_bytes(), start) {
            start = p + 1;
            // skip to the item's opening brace; a `;` first means no body
            let mut j = p + marker.len();
            while j < n && mask[j] != b'{' && mask[j] != b';' {
                j += 1;
            }
            if j >= n || mask[j] == b';' {
                continue;
            }
            let mut depth = 0i64;
            let mut k = j;
            while k < n {
                if mask[k] == b'{' {
                    depth += 1;
                } else if mask[k] == b'}' {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k += 1;
            }
            ranges.push((line_of(mask, p), line_of(mask, k.min(n.saturating_sub(1)))));
        }
    }
    ranges
}

fn in_ranges(ranges: &[(usize, usize)], line: usize) -> bool {
    ranges.iter().any(|&(lo, hi)| lo <= line && line <= hi)
}

/// True when any of `markers` appears in a comment on `line` or in the
/// contiguous comment/attribute/statement-continuation block above it.
pub fn marker_near(s: &Scanned, line: usize, markers: &[&str]) -> bool {
    let hit = |l: usize| s.comments_on_line(l).iter().any(|part| markers.iter().any(|m| part.contains(m)));
    if hit(line) {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        let raw = s.raw_line(l).trim().to_string();
        let masked = s.mask_line(l).trim().to_string();
        let is_comment_line = !raw.is_empty() && masked.is_empty();
        let is_attr_line = masked.starts_with("#[") || masked.starts_with("#![");
        // A statement continued onto the flagged line (`let x =` /
        // open paren / trailing comma ...) — keep walking up to the
        // comment above the statement's first line.
        let is_continuation = !masked.is_empty()
            && "=(,{+|&].".contains(masked.chars().last().unwrap_or(' '))
            && !is_attr_line;
        if !(is_comment_line || is_attr_line || is_continuation) {
            return false;
        }
        if hit(l) {
            return true;
        }
        l -= 1;
    }
    false
}

/// Parse every `lint: allow(<rule>): <reason>` annotation into the
/// `(line, rule)` suppression set. Malformed allows (unknown rule,
/// missing reason) are reported as `allow-syntax` violations.
pub fn parse_allows(s: &Scanned, path: &str, out: &mut Vec<Violation>) -> BTreeSet<(usize, String)> {
    let mut allows = BTreeSet::new();
    for (start, ctext) in &s.comments {
        // Allows live in working `//` comments only; doc comments
        // (`///x` -> "/x", `//!x` -> "!x", `/**x*/` -> "*x") merely
        // *describe* the grammar and stay inert.
        if matches!(ctext.as_bytes().first(), Some(b'/' | b'!' | b'*')) {
            continue;
        }
        for (k, part) in ctext.split('\n').enumerate() {
            let line = start + k;
            let Some(p) = part.find("lint: allow(") else { continue };
            let rest = &part[p + "lint: allow(".len()..];
            let Some(close) = rest.find(')') else {
                out.push(Violation::new(
                    "allow-syntax",
                    path,
                    line,
                    "malformed lint: allow annotation (missing ')')",
                ));
                continue;
            };
            let rule = rest[..close].trim();
            let tail = rest[close + 1..].trim();
            if !RULES.contains(&rule) {
                out.push(Violation::new(
                    "allow-syntax",
                    path,
                    line,
                    &format!("lint: allow names unknown rule '{rule}'"),
                ));
                continue;
            }
            if !tail.starts_with(':') || tail[1..].trim().is_empty() {
                out.push(Violation::new(
                    "allow-syntax",
                    path,
                    line,
                    &format!("lint: allow({rule}) carries no reason — write 'lint: allow({rule}): <why>'"),
                ));
                continue;
            }
            allows.insert((line, rule.to_string()));
            // Suppression extends through the rest of the comment
            // block to the first code line below it.
            let mut l = line + 1;
            loop {
                let raw_empty = s.raw_line(l).trim().is_empty();
                let mask_empty = s.mask_line(l).trim().is_empty();
                allows.insert((l, rule.to_string()));
                if !raw_empty && mask_empty {
                    l += 1; // still inside the comment block
                    continue;
                }
                break;
            }
        }
    }
    allows
}

/// True when the `.unwrap()` whose `.` sits at `dot_pos` follows a
/// `lock()`/`read()`/`write()`/`wait*()` call — unwrapping lock
/// poisoning propagates a *prior* panic rather than creating one, so
/// the panic-path rule exempts it.
pub fn receiver_is_lock(mask: &[u8], dot_pos: usize) -> bool {
    let mut k = dot_pos as i64 - 1;
    while k >= 0 && (mask[k as usize] as char).is_whitespace() {
        k -= 1;
    }
    if k < 0 || mask[k as usize] != b')' {
        return false;
    }
    let mut depth = 0i64;
    while k >= 0 {
        if mask[k as usize] == b')' {
            depth += 1;
        } else if mask[k as usize] == b'(' {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        k -= 1;
    }
    if k <= 0 {
        return false;
    }
    let mut e = k - 1;
    while e >= 0 && (mask[e as usize] as char).is_whitespace() {
        e -= 1;
    }
    let mut b = e;
    while b >= 0 && ident_byte(mask[b as usize]) {
        b -= 1;
    }
    let name = String::from_utf8_lossy(&mask[(b + 1) as usize..(e + 1) as usize]).into_owned();
    matches!(
        name.as_str(),
        "lock" | "read" | "write" | "wait" | "wait_timeout" | "wait_while" | "wait_timeout_while"
    )
}

/// `(line, token)` for every `boba_<word>` token in a text file.
pub fn boba_tokens(text: &str) -> Vec<(usize, String)> {
    let t = text.as_bytes();
    let n = t.len();
    let mut out = Vec::new();
    let mut i = 0;
    let mut line = 1;
    while i < n {
        if t[i] == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if t[i..].starts_with(b"boba_") && (i == 0 || !ident_byte(t[i - 1])) {
            let mut j = i;
            while j < n && ident_byte(t[j]) {
                j += 1;
            }
            out.push((line, String::from_utf8_lossy(&t[i..j]).into_owned()));
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// The `for fam in ... do` family list in ci.sh → `(names, gate_line)`.
pub fn parse_ci_family_gate(text: &str) -> Option<(Vec<String>, usize)> {
    let p = text.find("for fam in")?;
    let gate_line = line_of(text.as_bytes(), p);
    let q = text[p..].find("do").map(|r| r + p)?;
    let seg = &text[p + "for fam in".len()..q];
    Some((boba_tokens(seg).into_iter().map(|(_, t)| t).collect(), gate_line))
}

/// Names in a `<!-- marker:begin -->` … `<!-- marker:end -->` fenced
/// markdown table — rows shaped `| \`name\` | … |`, with any `:PARAM` /
/// `{labels}` suffix stripped. Returns `(name, line)` pairs.
pub fn parse_marked_table(text: &str, marker: &str) -> Option<Vec<(String, usize)>> {
    let begin = text.find(&format!("<!-- {marker}:begin -->"))?;
    let end = text.find(&format!("<!-- {marker}:end -->"))?;
    if end < begin {
        return None;
    }
    let mut out = Vec::new();
    let base_line = line_of(text.as_bytes(), begin);
    for (i, line) in text[begin..end].split('\n').enumerate() {
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("| `") {
            let Some(close) = rest.find('`') else { continue };
            let mut name = &rest[..close];
            for sep in [':', '{'] {
                if let Some(cut) = name.find(sep) {
                    name = &name[..cut];
                }
            }
            out.push((name.to_string(), base_line + i));
        }
    }
    Some(out)
}

/// Names in obs/chaos.rs's `KNOWN_POINTS: &[&str]` const, minus the
/// `test-*` points the unit tests arm to exercise table mechanics
/// (they are hooked by nothing and don't belong in the fault table).
pub fn parse_points_const(s: &Scanned) -> Option<Vec<String>> {
    let mask = &s.mask;
    let p = memfind(mask, b"KNOWN_POINTS: &[&str]", 0)?;
    let b = memfind(mask, b"[", p + "KNOWN_POINTS: &[&str]".len())?;
    let e = memfind(mask, b"]", b)?;
    let raw = s.text.as_bytes();
    let mut out = Vec::new();
    // string contents are masked; read them from the raw text via quote positions
    let mut k = b;
    while k < e {
        if raw[k] == b'"' {
            let mut j = k + 1;
            while j < e && raw[j] != b'"' {
                j += 1;
            }
            let name = String::from_utf8_lossy(&raw[k + 1..j]).into_owned();
            if !name.starts_with("test-") {
                out.push(name);
            }
            k = j + 1;
        } else {
            k += 1;
        }
    }
    Some(out)
}

/// Run every rule over `input`, returning all violations (sorted by
/// file, then line).
pub fn lint(input: &LintInput) -> Vec<Violation> {
    let mut v: Vec<Violation> = Vec::new();
    let scanned: Vec<(&str, Scanned)> =
        input.sources.iter().map(|f| (f.path.as_str(), Scanned::new(&f.text))).collect();
    let tranges: Vec<Vec<(usize, usize)>> = scanned.iter().map(|(_, s)| test_ranges(s)).collect();
    let allows: Vec<BTreeSet<(usize, String)>> =
        scanned.iter().map(|(p, s)| parse_allows(s, p, &mut v)).collect();

    let mut emitted_families: Vec<(String, String, usize)> = Vec::new();
    let mut atomic_defs: Vec<(String, String)> = Vec::new();

    for (idx, (path, s)) in scanned.iter().enumerate() {
        let mask = &s.mask;
        let tr = &tranges[idx];
        let emit = |rule: &str, line: usize, msg: &str, v: &mut Vec<Violation>| {
            if allows[idx].contains(&(line, rule.to_string())) {
                return;
            }
            v.push(Violation::new(rule, path, line, msg));
        };

        // ---- unsafe-safety ----
        for p in find_token(mask, "unsafe") {
            let line = line_of(mask, p);
            if !UNSAFE_OK.contains(path) {
                emit("unsafe-safety", line, "`unsafe` outside the modules whitelisted to own it", &mut v);
            }
            if !marker_near(s, line, &["SAFETY:", "# Safety"]) {
                emit(
                    "unsafe-safety",
                    line,
                    "`unsafe` without an immediately preceding `// SAFETY:` comment",
                    &mut v,
                );
            }
        }

        // ---- raw-spawn ----
        if !SPAWN_OK.contains(path) {
            for tok in ["thread::spawn", "thread::scope", "thread::Builder"] {
                for p in find_sub(mask, tok) {
                    let line = line_of(mask, p);
                    if in_ranges(tr, line) {
                        continue;
                    }
                    emit(
                        "raw-spawn",
                        line,
                        &format!("raw `{tok}` outside the pool — kernel parallelism goes through parallel::pool"),
                        &mut v,
                    );
                }
            }
        }

        // ---- panic-path ----
        if PANIC_PATH_FILES.contains(path) {
            for tok in [".unwrap()", ".expect(", "panic!", "unreachable!"] {
                let mut start = 0;
                while let Some(p) = memfind(mask, tok.as_bytes(), start) {
                    start = p + 1;
                    let line = line_of(mask, p);
                    if in_ranges(tr, line) {
                        continue;
                    }
                    if tok == ".unwrap()" && receiver_is_lock(mask, p) {
                        continue; // lock-poisoning unwrap: propagates a prior panic
                    }
                    emit(
                        "panic-path",
                        line,
                        &format!(
                            "`{}` on the request path — answer with a status code, not an abort",
                            tok.trim_matches('.')
                        ),
                        &mut v,
                    );
                }
            }
        }

        // ---- atomic-ordering ----
        for variant in ATOMIC_VARIANTS {
            let tok = format!("Ordering::{variant}");
            for p in find_token(mask, &tok) {
                let line = line_of(mask, p);
                if in_ranges(tr, line) {
                    continue;
                }
                if *variant == "Relaxed" && RELAXED_COUNTER_OK.contains(path) {
                    continue;
                }
                if !marker_near(s, line, &["ordering:"]) {
                    emit(
                        "atomic-ordering",
                        line,
                        &format!("`{tok}` without an `// ordering:` comment naming its pairing"),
                        &mut v,
                    );
                }
            }
        }

        // ---- metrics-drift: collect emitted families ----
        let mut start = 0;
        while let Some(p) = memfind(mask, b"family(", start) {
            start = p + 1;
            if p > 0 && ident_byte(mask[p - 1]) {
                continue;
            }
            let line = line_of(mask, p);
            if in_ranges(tr, line) {
                continue;
            }
            let mut j = p + "family(".len();
            while j < mask.len() && (mask[j] as char).is_whitespace() {
                j += 1;
            }
            if j < mask.len() && mask[j] == b'"' {
                // read from the RAW text (string contents are masked out)
                let raw = s.text.as_bytes();
                let mut k = j + 1;
                let mut name = Vec::new();
                while k < raw.len() && raw[k] != b'"' {
                    name.push(raw[k]);
                    k += 1;
                }
                let name = String::from_utf8_lossy(&name).into_owned();
                if name.starts_with("boba_") {
                    emitted_families.push((name, path.to_string(), line));
                }
            }
        }

        // ---- ablation-reach: collect *_atomic fn defs ----
        for p in find_token(mask, "fn") {
            let mut j = p + 2;
            while j < mask.len() && (mask[j] as char).is_whitespace() {
                j += 1;
            }
            let b = j;
            while j < mask.len() && ident_byte(mask[j]) {
                j += 1;
            }
            let name = String::from_utf8_lossy(&mask[b..j]).into_owned();
            if name.ends_with("_atomic") {
                atomic_defs.push((name, path.to_string()));
            }
        }
    }

    // ---- ablation-reach: references ----
    for (name, def_path) in &atomic_defs {
        for (idx, (path, s)) in scanned.iter().enumerate() {
            if *path == def_path.as_str() || *path == "coordinator/repro.rs" {
                continue;
            }
            for p in find_token(&s.mask, name) {
                let line = line_of(&s.mask, p);
                if in_ranges(&tranges[idx], line) {
                    continue;
                }
                if allows[idx].contains(&(line, "ablation-reach".to_string())) {
                    continue;
                }
                v.push(Violation::new(
                    "ablation-reach",
                    path,
                    line,
                    &format!("nondeterministic ablation kernel `{name}` referenced outside benches/repro"),
                ));
            }
        }
    }

    // ---- metrics-drift ----
    let mut emitted: Vec<String> = Vec::new();
    for (name, _, _) in &emitted_families {
        if !emitted.contains(name) {
            emitted.push(name.clone());
        }
    }
    emitted.sort();
    if let Some(ci) = &input.ci_sh {
        match parse_ci_family_gate(ci) {
            None => v.push(Violation::new(
                "metrics-drift",
                "ci.sh",
                0,
                "ci.sh has no `for fam in ... do` metrics gate list",
            )),
            Some((fams, gate_line)) => {
                for name in &emitted {
                    if !fams.contains(name) {
                        v.push(Violation::new(
                            "metrics-drift",
                            "ci.sh",
                            gate_line,
                            &format!("emitted family `{name}` missing from the ci.sh exposition gate"),
                        ));
                    }
                }
                let mut seen = BTreeSet::new();
                for name in &fams {
                    if seen.insert(name.clone()) && !emitted.contains(name) {
                        v.push(Violation::new(
                            "metrics-drift",
                            "ci.sh",
                            gate_line,
                            &format!("ci.sh exposition gate greps `{name}`, which no code emits"),
                        ));
                    }
                }
            }
        }
        // stray boba_ tokens anywhere in ci.sh must be emitted families
        for (ln, tok) in boba_tokens(ci) {
            if !emitted.contains(&tok) {
                v.push(Violation::new(
                    "metrics-drift",
                    "ci.sh",
                    ln,
                    &format!("ci.sh references `{tok}`, which no code emits"),
                ));
            }
        }
    }
    if let Some(arch) = &input.architecture_md {
        match parse_marked_table(arch, "lint:metrics-families") {
            None => v.push(Violation::new(
                "metrics-drift",
                "docs/ARCHITECTURE.md",
                0,
                "ARCHITECTURE.md lacks the `lint:metrics-families` marked table",
            )),
            Some(doc_fams) => {
                let names: Vec<&String> = doc_fams.iter().map(|(n, _)| n).collect();
                for name in &emitted {
                    if !names.contains(&name) {
                        v.push(Violation::new(
                            "metrics-drift",
                            "docs/ARCHITECTURE.md",
                            0,
                            &format!("emitted family `{name}` missing from the ARCHITECTURE.md families table"),
                        ));
                    }
                }
                for (name, ln) in &doc_fams {
                    if !emitted.contains(name) {
                        v.push(Violation::new(
                            "metrics-drift",
                            "docs/ARCHITECTURE.md",
                            *ln,
                            &format!("ARCHITECTURE.md documents family `{name}`, which no code emits"),
                        ));
                    }
                }
            }
        }
    }

    // ---- chaos-drift ----
    let chaos = scanned.iter().find(|(p, _)| *p == "obs/chaos.rs");
    if let (Some((_, chaos)), Some(arch)) = (chaos, &input.architecture_md) {
        match parse_points_const(chaos) {
            None => v.push(Violation::new(
                "chaos-drift",
                "obs/chaos.rs",
                0,
                "obs/chaos.rs has no `KNOWN_POINTS: &[&str]` const to check",
            )),
            Some(points) => match parse_marked_table(arch, "lint:chaos-points") {
                None => v.push(Violation::new(
                    "chaos-drift",
                    "docs/ARCHITECTURE.md",
                    0,
                    "ARCHITECTURE.md lacks the `lint:chaos-points` marked fault table",
                )),
                Some(doc_pts) => {
                    let names: Vec<&String> = doc_pts.iter().map(|(n, _)| n).collect();
                    for pt in &points {
                        if !names.contains(&pt) {
                            v.push(Violation::new(
                                "chaos-drift",
                                "docs/ARCHITECTURE.md",
                                0,
                                &format!("chaos point `{pt}` missing from the ARCHITECTURE.md fault table"),
                            ));
                        }
                    }
                    for (name, ln) in &doc_pts {
                        if !points.contains(name) {
                            v.push(Violation::new(
                                "chaos-drift",
                                "docs/ARCHITECTURE.md",
                                *ln,
                                &format!("ARCHITECTURE.md fault table lists `{name}`, which obs/chaos.rs does not define"),
                            ));
                        }
                    }
                }
            },
        }
    }

    v.sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    v
}

fn find_sub(mask: &[u8], tok: &str) -> Vec<usize> {
    // identical to find_token; kept separate for tokens containing `::`
    // (word-boundary check applies to both edges of the whole token).
    find_token(mask, tok)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lex::Scanned;

    #[test]
    fn lock_receiver_detection() {
        let s = Scanned::new("fn f() { m.lock().unwrap(); x.unwrap(); cv.wait(g).unwrap(); }");
        let mut dots = Vec::new();
        let mut start = 0;
        while let Some(p) = memfind(&s.mask, b".unwrap()", start) {
            dots.push(p);
            start = p + 1;
        }
        assert_eq!(dots.len(), 3);
        assert!(receiver_is_lock(&s.mask, dots[0]));
        assert!(!receiver_is_lock(&s.mask, dots[1]));
        assert!(receiver_is_lock(&s.mask, dots[2]));
    }

    #[test]
    fn marked_table_strips_label_suffixes() {
        let md = "x\n<!-- lint:metrics-families:begin -->\n\
                  | `boba_a_total` | counter |\n\
                  | `boba_b_seconds{stage}` | histogram |\n\
                  <!-- lint:metrics-families:end -->\n";
        let rows = parse_marked_table(md, "lint:metrics-families").expect("markers found");
        let names: Vec<&str> = rows.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["boba_a_total", "boba_b_seconds"]);
    }

    #[test]
    fn ci_gate_extracts_families() {
        let sh = "#!/bin/sh\nfor fam in boba_a_total boba_b_seconds; do\n  grep $fam m\ndone\n";
        let (fams, line) = parse_ci_family_gate(sh).expect("gate found");
        assert_eq!(fams, ["boba_a_total", "boba_b_seconds"]);
        assert_eq!(line, 2);
    }

    #[test]
    fn points_const_skips_test_points() {
        let s = Scanned::new("const KNOWN_POINTS: &[&str] = &[\"conn-drop\", \"test-point\"];\n");
        assert_eq!(parse_points_const(&s).expect("const found"), ["conn-drop"]);
    }

    #[test]
    fn cfg_test_ranges_brace_match() {
        let s = Scanned::new("fn a() {}\n#[cfg(test)]\nmod t {\n    fn b() {}\n}\nfn c() {}\n");
        let r = test_ranges(&s);
        assert_eq!(r.len(), 1);
        assert!(in_ranges(&r, 3) && in_ranges(&r, 5) && !in_ranges(&r, 1) && !in_ranges(&r, 6));
    }
}
