//! Comment/string-aware lexical masking for the lint rules.
//!
//! [`Scanned`] walks a Rust source file once and produces a *mask*: a
//! byte string of the same length in which the contents of every
//! comment, string literal (including raw strings), and char literal
//! are blanked to spaces (newlines preserved, so line numbers line up).
//! Rules match tokens against the mask — `unsafe` inside a doc comment
//! or `"panic!"` inside a string can never false-positive — while the
//! comment *text* is kept on the side for the marker rules
//! (`// SAFETY:`, `// ordering:`, `lint: allow`).
//!
//! The scanner handles nested block comments, raw strings
//! (`r"…"`/`r#"…"#`), escaped chars (`'\n'`), and the char-literal vs
//! lifetime ambiguity (`'a'` is a char, `'a` in `&'a T` is not).

/// One scanned source file: the raw text, its blanked mask, and every
/// comment's text keyed by starting line.
pub struct Scanned {
    /// The raw source text.
    pub text: String,
    /// `text` with comment/string/char-literal contents blanked to
    /// spaces (newlines kept). Same byte length as `text`.
    pub mask: Vec<u8>,
    /// `(start_line, comment_text)` per comment, 1-based lines. A
    /// multi-line block comment appears once with its full text.
    pub comments: Vec<(usize, String)>,
}

/// True for bytes that extend an identifier (`[A-Za-z0-9_]`).
pub fn ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// 1-based line number of byte offset `pos` in `bytes`.
pub fn line_of(bytes: &[u8], pos: usize) -> usize {
    bytes[..pos.min(bytes.len())].iter().filter(|&&b| b == b'\n').count() + 1
}

impl Scanned {
    /// Scan `text`, building the mask and the comment table.
    pub fn new(text: &str) -> Scanned {
        let t = text.as_bytes();
        let n = t.len();
        let mut mask = t.to_vec();
        let mut comments: Vec<(usize, String)> = Vec::new();
        let blank = |mask: &mut Vec<u8>, a: usize, b: usize| {
            for m in mask.iter_mut().take(b.min(n)).skip(a) {
                if *m != b'\n' {
                    *m = b' ';
                }
            }
        };
        let mut i = 0;
        while i < n {
            let c = t[i];
            if c == b'/' && i + 1 < n && t[i + 1] == b'/' {
                let j = memfind(t, b"\n", i).unwrap_or(n);
                comments.push((line_of(t, i), lossy(&t[i + 2..j])));
                blank(&mut mask, i, j);
                i = j;
            } else if c == b'/' && i + 1 < n && t[i + 1] == b'*' {
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if t[j] == b'/' && j + 1 < n && t[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if t[j] == b'*' && j + 1 < n && t[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                comments.push((line_of(t, i), lossy(&t[i + 2..j.saturating_sub(2).max(i + 2)])));
                blank(&mut mask, i, j);
                i = j;
            } else if c == b'"' {
                let mut j = i + 1;
                while j < n {
                    if t[j] == b'\\' {
                        j += 2;
                    } else if t[j] == b'"' {
                        j += 1;
                        break;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut mask, i + 1, j.saturating_sub(1).max(i + 1));
                i = j;
            } else if c == b'r'
                && i + 1 < n
                && (t[i + 1] == b'#' || t[i + 1] == b'"')
                && (i == 0 || !ident_byte(t[i - 1]))
            {
                // raw string r"…" / r#"…"#
                let mut j = i + 1;
                let mut hashes = 0usize;
                while j < n && t[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && t[j] == b'"' {
                    let mut close = vec![b'"'];
                    close.extend(std::iter::repeat(b'#').take(hashes));
                    let k = match memfind(t, &close, j + 1) {
                        Some(p) => p + close.len(),
                        None => n,
                    };
                    blank(&mut mask, i + 1, k);
                    i = k;
                } else {
                    i += 1;
                }
            } else if c == b'\'' {
                // char literal vs lifetime
                if i + 1 < n && t[i + 1] == b'\\' {
                    let j = match memfind(t, b"'", i + 2) {
                        Some(p) => p + 1,
                        None => n,
                    };
                    blank(&mut mask, i + 1, j.saturating_sub(1).max(i + 1));
                    i = j;
                } else if i + 2 < n && t[i + 2] == b'\'' && t[i + 1] != b'\'' {
                    blank(&mut mask, i + 1, i + 2);
                    i += 3;
                } else {
                    i += 1; // lifetime
                }
            } else {
                i += 1;
            }
        }
        Scanned { text: text.to_string(), mask, comments }
    }

    /// Comment text fragments present on 1-based `line` (a multi-line
    /// block comment contributes its spanning fragment to each line).
    pub fn comments_on_line(&self, line: usize) -> Vec<&str> {
        let mut out = Vec::new();
        for (start, ctext) in &self.comments {
            for (k, part) in ctext.split('\n').enumerate() {
                if start + k == line {
                    out.push(part);
                }
            }
        }
        out
    }

    /// Raw source line `line` (1-based), or "" out of range.
    pub fn raw_line(&self, line: usize) -> &str {
        self.text.split('\n').nth(line.saturating_sub(1)).unwrap_or("")
    }

    /// Masked line `line` (1-based) as lossy UTF-8, or "" out of range.
    pub fn mask_line(&self, line: usize) -> String {
        match self.mask.split(|&b| b == b'\n').nth(line.saturating_sub(1)) {
            Some(seg) => lossy(seg),
            None => String::new(),
        }
    }
}

/// Byte-wise substring search from `start`; `None` when absent.
pub fn memfind(haystack: &[u8], needle: &[u8], start: usize) -> Option<usize> {
    if needle.is_empty() || start >= haystack.len() {
        return None;
    }
    haystack[start..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + start)
}

/// Word-boundary occurrences of `tok` in `mask` — byte positions.
pub fn find_token(mask: &[u8], tok: &str) -> Vec<usize> {
    let tok = tok.as_bytes();
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(p) = memfind(mask, tok, start) {
        let before = if p > 0 { mask[p - 1] } else { b' ' };
        let after = if p + tok.len() < mask.len() { mask[p + tok.len()] } else { b' ' };
        if !ident_byte(before) && !ident_byte(after) {
            out.push(p);
        }
        start = p + 1;
    }
    out
}

fn lossy(bytes: &[u8]) -> String {
    String::from_utf8_lossy(bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_line_and_block_comments() {
        let s = Scanned::new("let a = 1; // unsafe here\n/* unsafe\nblock */ let b;\n");
        assert!(find_token(&s.mask, "unsafe").is_empty());
        assert_eq!(s.comments.len(), 2);
        assert_eq!(s.comments[0].1, " unsafe here");
    }

    #[test]
    fn masks_strings_and_raw_strings() {
        let s = Scanned::new(r##"let x = "unsafe"; let y = r#"panic!("no")"#;"##);
        assert!(find_token(&s.mask, "unsafe").is_empty());
        assert_eq!(memfind(&s.mask, b"panic!", 0), None);
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let s = Scanned::new("fn f<'a>(x: &'a str) { let q = 'x'; let esc = '\\n'; }");
        // the lifetime 'a survives in the mask; char contents are blanked
        assert!(memfind(&s.mask, b"'a>", 0).is_some());
        assert_eq!(memfind(&s.mask, b"'x'", 0), None);
    }

    #[test]
    fn nested_block_comments() {
        let s = Scanned::new("/* outer /* inner */ still comment */ let z = 1;");
        assert!(memfind(&s.mask, b"let z", 0).is_some());
        assert_eq!(memfind(&s.mask, b"inner", 0), None);
    }

    #[test]
    fn newlines_preserved_for_line_numbers() {
        let s = Scanned::new("// one\n// two\nunsafe {}\n");
        let pos = find_token(&s.mask, "unsafe");
        assert_eq!(pos.len(), 1);
        assert_eq!(line_of(&s.mask, pos[0]), 3);
    }
}
