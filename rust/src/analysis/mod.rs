//! `boba lint` — a repo-invariant static analyzer for the concurrency
//! core (L6 in the module map).
//!
//! The repo documents a set of cross-cutting invariants — every
//! `unsafe` justifies itself, kernel parallelism goes through the pool,
//! the serve path never aborts, atomic orderings name their pairings,
//! the metrics/chaos vocabularies stay in sync across code, ci.sh, and
//! docs — but until now nothing *checked* them; they rotted or held by
//! review luck. This module is the checker: a std-only,
//! comment/string-aware token scanner ([`lex`]) plus the rule engine
//! ([`rules`]), wired as the `boba lint` subcommand and a required CI
//! stage.
//!
//! Deliberately not a rustc plugin or syn-based AST pass: the rules
//! are lexical (comments are *part of* what they check — a `// SAFETY:`
//! annotation is invisible to an AST) and the zero-dependency scanner
//! keeps the analyzer inside the repo's no-new-crates budget. The
//! trade-off is precision at token granularity, which the mask (see
//! [`lex::Scanned`]) makes sound against strings and comments.
//!
//! ```text
//! $ boba lint [--root DIR] [--json]
//! ```
//!
//! Exit is nonzero when any violation remains. Suppress a finding with
//! `// lint: allow(<rule>): <reason>` — the reason is mandatory.

pub mod lex;
pub mod rules;

pub use rules::{lint, RULES};

use crate::util::Json;
use std::path::{Path, PathBuf};

/// One source file handed to the linter: its repo-relative path (used
/// in whitelists and reports) and full text.
pub struct SourceFile {
    /// Path relative to `rust/src` (e.g. `server/router.rs`).
    pub path: String,
    /// The file's full text.
    pub text: String,
}

/// Everything [`lint`] looks at: the Rust tree plus the two non-Rust
/// artifacts the drift rules reconcile against (absent in fixture
/// tests, which then skip those rules).
pub struct LintInput {
    /// Rust sources keyed by `rust/src`-relative path.
    pub sources: Vec<SourceFile>,
    /// `ci.sh` text, when present (metrics-drift gate).
    pub ci_sh: Option<String>,
    /// `docs/ARCHITECTURE.md` text, when present (metrics/chaos tables).
    pub architecture_md: Option<String>,
}

/// One finding: which rule fired, where, and why.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Rule name (one of [`RULES`], or `allow-syntax` for bad allows).
    pub rule: String,
    /// Repo-relative file (`rust/src`-relative for sources; `ci.sh` /
    /// `docs/ARCHITECTURE.md` for the drift rules).
    pub file: String,
    /// 1-based line, or 0 for whole-file findings.
    pub line: usize,
    /// Human-readable explanation.
    pub msg: String,
}

impl Violation {
    /// Build a violation (convenience used throughout the rules).
    pub fn new(rule: &str, file: &str, line: usize, msg: &str) -> Violation {
        Violation {
            rule: rule.to_string(),
            file: file.to_string(),
            line,
            msg: msg.to_string(),
        }
    }
}

/// Walk up from `start` to the repo root — the first ancestor holding
/// both `ROADMAP.md` and `rust/src`. `None` when invoked outside the
/// repo (callers then require an explicit `--root`).
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut dir = Some(start.to_path_buf());
    while let Some(d) = dir {
        if d.join("ROADMAP.md").is_file() && d.join("rust").join("src").is_dir() {
            return Some(d);
        }
        dir = d.parent().map(Path::to_path_buf);
    }
    None
}

/// Load the real tree under `root`: every `.rs` file below `rust/src`
/// (sorted by path, so reports and fixtures are deterministic), plus
/// `ci.sh` and `docs/ARCHITECTURE.md` when present.
pub fn load_tree(root: &Path) -> std::io::Result<LintInput> {
    let src = root.join("rust").join("src");
    let mut paths: Vec<PathBuf> = Vec::new();
    collect_rs(&src, &mut paths)?;
    paths.sort();
    let mut sources = Vec::with_capacity(paths.len());
    for p in paths {
        let rel = p
            .strip_prefix(&src)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        sources.push(SourceFile { path: rel, text: std::fs::read_to_string(&p)? });
    }
    let read_opt = |p: PathBuf| match std::fs::read_to_string(&p) {
        Ok(t) => Some(t),
        Err(_) => None,
    };
    Ok(LintInput {
        sources,
        ci_sh: read_opt(root.join("ci.sh")),
        architecture_md: read_opt(root.join("docs").join("ARCHITECTURE.md")),
    })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Render violations as the human-facing aligned table, with a
/// per-rule count trailer (empty string for a clean tree).
pub fn render_table(violations: &[Violation]) -> String {
    if violations.is_empty() {
        return String::new();
    }
    let mut out = String::new();
    let loc_w = violations
        .iter()
        .map(|v| format!("{}:{}", v.file, v.line).len())
        .max()
        .unwrap_or(0);
    let rule_w = violations.iter().map(|v| v.rule.len()).max().unwrap_or(0);
    for v in violations {
        let loc = format!("{}:{}", v.file, v.line);
        out.push_str(&format!("{loc:<loc_w$}  [{:<rule_w$}]  {}\n", v.rule, v.msg));
    }
    let mut counts: Vec<(String, usize)> = Vec::new();
    for v in violations {
        match counts.iter_mut().find(|(r, _)| *r == v.rule) {
            Some((_, n)) => *n += 1,
            None => counts.push((v.rule.clone(), 1)),
        }
    }
    counts.sort();
    let summary: Vec<String> = counts.iter().map(|(r, n)| format!("{r}={n}")).collect();
    out.push_str(&format!("\n{} violation(s): {}\n", violations.len(), summary.join(", ")));
    out
}

/// Render violations as the machine-facing JSON document
/// (`{"version":"boba-lint/1","violations":[…],"count":N}`).
pub fn render_json(violations: &[Violation]) -> String {
    let rows: Vec<Json> = violations
        .iter()
        .map(|v| {
            Json::Obj(vec![
                ("rule".to_string(), Json::Str(v.rule.clone())),
                ("file".to_string(), Json::Str(v.file.clone())),
                ("line".to_string(), Json::Num(v.line as f64)),
                ("msg".to_string(), Json::Str(v.msg.clone())),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("version".to_string(), Json::Str("boba-lint/1".to_string())),
        ("violations".to_string(), Json::Arr(rows)),
        ("count".to_string(), Json::Num(violations.len() as f64)),
    ])
    .render()
}
