//! Concurrent log2 latency histograms — the shared measurement
//! primitive of the observability subsystem.
//!
//! This type started life in `server::stats` as the `/stats` endpoint
//! histogram; it moved here when the `/metrics` exposition and the
//! stage-span tracer needed the same primitive without dragging in the
//! server layer. `server::stats` re-exports it, so existing paths keep
//! working.
//!
//! Buckets are powers of two over microseconds: bucket `i` counts
//! samples in `[2^(i-1), 2^i)` µs (bucket 0 is `< 1 µs`). Factor-of-two
//! resolution is plenty for p50/p99 dashboards, and the fixed layout is
//! what lets the Prometheus exposition emit *cumulative* `le` buckets
//! without any locking — every cell is an independent relaxed atomic.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log2 buckets: the top bucket covers latencies up to
/// ~2^42 µs ≈ 50 days — effectively unbounded.
pub const BUCKETS: usize = 43;

/// A concurrent log2 latency histogram (microsecond domain).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// New empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        ((64 - us.leading_zeros()) as usize).min(BUCKETS - 1)
    }

    /// Upper bound (µs) of bucket `i` — the `le` boundary the exposition
    /// publishes and the value quantiles report for samples that landed
    /// there.
    pub fn bucket_upper_us(i: usize) -> u64 {
        1u64 << i
    }

    /// Record one sample.
    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    /// Record one sample given in microseconds.
    pub fn record_us(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples in microseconds (the exposition's `_sum`).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Snapshot of the raw (non-cumulative) bucket counts, index =
    /// bucket number, upper bound = [`Self::bucket_upper_us`].
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Mean latency in milliseconds (0 when empty).
    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e3
        }
    }

    /// Maximum latency in milliseconds.
    pub fn max_ms(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Latency quantile in milliseconds, as the upper bound of the
    /// bucket where the cumulative count crosses `q` (0 when empty),
    /// clamped to the observed maximum — the top occupied bucket's upper
    /// bound can overshoot the true max by up to 2×, and an unclamped
    /// p99 > max reads as nonsense in `/stats`. Resolution is a factor
    /// of two — plenty for p50/p99 dashboards.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                let upper = Self::bucket_upper_us(i) as f64 / 1e3;
                return upper.min(self.max_ms());
            }
        }
        self.max_ms()
    }

    /// JSON snapshot (count/mean/p50/p95/p99/p999/max) — the full
    /// percentile ladder served by `/stats`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("mean_ms", Json::Num(self.mean_ms())),
            ("p50_ms", Json::Num(self.quantile_ms(0.50))),
            ("p95_ms", Json::Num(self.quantile_ms(0.95))),
            ("p99_ms", Json::Num(self.quantile_ms(0.99))),
            ("p999_ms", Json::Num(self.quantile_ms(0.999))),
            ("max_ms", Json::Num(self.max_ms())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_is_clamped_to_observed_max() {
        // Regression: the top occupied bucket's upper bound used to be
        // returned verbatim, reporting p99 up to 2× the true max
        // (100 ms lands in the (65.536, 131.072] ms bucket).
        let h = Histogram::new();
        for _ in 0..100 {
            h.record_us(100_000);
        }
        assert_eq!(h.max_ms(), 100.0);
        assert_eq!(h.quantile_ms(0.99), 100.0, "p99 must never exceed max");
        assert_eq!(h.quantile_ms(0.999), 100.0);
        assert_eq!(h.quantile_ms(1.0), 100.0);
        // A quantile resolved below the top bucket still reports the
        // (un-clamped) bucket bound.
        h.record_us(10);
        assert!(h.quantile_ms(0.001) <= 0.016);
    }

    #[test]
    fn percentile_ladder_is_monotone() {
        let h = Histogram::new();
        for i in 0..1000u64 {
            h.record_us(i * 37 % 5000);
        }
        let p50 = h.quantile_ms(0.50);
        let p95 = h.quantile_ms(0.95);
        let p99 = h.quantile_ms(0.99);
        let p999 = h.quantile_ms(0.999);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= p999, "{p50} {p95} {p99} {p999}");
        assert!(p999 <= h.max_ms());
        let j = h.to_json();
        assert!(j.get("p95_ms").is_some() && j.get("p999_ms").is_some());
    }

    #[test]
    fn bucket_counts_match_total() {
        let h = Histogram::new();
        for us in [0u64, 1, 2, 100, 100_000, u64::MAX / 2] {
            h.record_us(us);
        }
        let counts = h.bucket_counts();
        assert_eq!(counts.iter().sum::<u64>(), h.count());
        assert_eq!(h.sum_us() > 0, true);
    }
}
