//! Process-wide I/O corruption counters.
//!
//! Silent corruption handling (`.bcoo` checksum rejects, quarantined
//! sidecars, WAL torn-tail truncations) used to be visible only as
//! `eprintln!` lines; these counters surface every such event to
//! `/metrics` as `boba_io_corruption_total{kind="…"}` so scrapes and
//! alerts see disk rot the moment recovery papers over it.

use std::sync::atomic::{AtomicU64, Ordering};

/// The fixed corruption-kind label set. Every kind is exported on every
/// scrape (zero-valued families are how dashboards learn a counter
/// exists before the first incident).
pub const KINDS: [&str; 3] = ["bcoo-checksum", "bcoo-quarantine", "wal-torn-tail"];

static COUNTS: [AtomicU64; 3] = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

fn slot(kind: &str) -> usize {
    KINDS.iter().position(|&k| k == kind).unwrap_or_else(|| {
        panic!("unknown corruption kind {kind:?} (add it to obs::corrupt::KINDS)")
    })
}

/// Record one corruption event of `kind` (must be one of [`KINDS`]).
pub fn inc(kind: &str) {
    COUNTS[slot(kind)].fetch_add(1, Ordering::Relaxed);
}

/// Current count for `kind`.
pub fn get(kind: &str) -> u64 {
    COUNTS[slot(kind)].load(Ordering::Relaxed)
}

/// `(kind, count)` snapshot across all kinds, in [`KINDS`] order.
pub fn snapshot() -> Vec<(&'static str, u64)> {
    KINDS.iter().map(|&k| (k, get(k))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate_per_kind() {
        let before = get("bcoo-checksum");
        inc("bcoo-checksum");
        inc("bcoo-checksum");
        assert_eq!(get("bcoo-checksum"), before + 2);
        let snap = snapshot();
        assert_eq!(snap.len(), KINDS.len());
        assert_eq!(snap[0].0, "bcoo-checksum");
    }

    #[test]
    #[should_panic(expected = "unknown corruption kind")]
    fn unknown_kind_panics() {
        inc("not-a-kind");
    }
}
