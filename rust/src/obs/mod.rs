//! Observability: stage-span tracing, the `/metrics` exposition, and
//! the scrape parser — std-only, shared by the serve path and loadgen.
//!
//! Three layers, bottom up:
//!
//! - [`hist`] — the concurrent log2 [`Histogram`], the one measurement
//!   primitive everything else aggregates into (moved here from
//!   `server::stats`, which re-exports it).
//! - [`span`] — `obs::span("prepare.reorder", || ...)` wall-times named
//!   stages into per-stage histograms and, when a request trace is open
//!   ([`begin`]), into that request's span tree. Completed traces are
//!   published to the lock-free [`ring`], served by
//!   `GET /debug/traces?n=K`; slow ones are logged to stderr as
//!   single-line JSON. `--no-trace` / `BOBA_NO_TRACE=1` reduce every
//!   hook to one relaxed atomic load.
//! - [`metrics`] + [`text`] — the hand-rolled Prometheus text builder
//!   behind `GET /metrics` and the matching strict parser used by
//!   `loadgen --scrape-metrics` and the conformance tests.
//!
//! The layering rule: `obs` depends only on `util` (and the vendored
//! `anyhow`), never on `server` — the server threads `obs` through its
//! handlers, not the other way around.

//! A fourth, test-only layer rides along: [`chaos`], the deterministic
//! fault-injection registry behind `BOBA_FAULTS` — armed only by the
//! resilience tests and overload drills, a single relaxed atomic load
//! otherwise.

pub mod chaos;
pub mod corrupt;
pub mod hist;
pub mod metrics;
pub mod ring;
pub mod span;
pub mod text;

pub use hist::Histogram;
pub use metrics::PromText;
pub use ring::TraceRing;
pub use span::{begin, enabled, init_from_env, set_enabled, span, stage_histograms, stage_record,
               Trace, TraceGuard};
