//! Stage-span tracing: a thread-local span stack that turns every
//! served request into a trace tree.
//!
//! `obs::span("prepare.reorder", || ...)` records the wall time of the
//! closure under a stable stage name. Two sinks consume the record:
//!
//! 1. **Stage histograms** — a process-wide `stage name → Histogram`
//!    registry ([`stage_histograms`]). Every span feeds it whether or
//!    not a trace is active, so the offline pipeline and the serve path
//!    share one per-stage latency surface, exposed as the
//!    `boba_stage_duration_seconds` family on `/metrics`.
//! 2. **The active trace** — if the current thread has a trace open
//!    ([`begin`]), the span becomes a node in its tree (nested spans
//!    nest in the tree). Completed traces are published to the ring
//!    buffer ([`super::ring`]) by the server and served by
//!    `GET /debug/traces`.
//!
//! The kill switch ([`set_enabled`], `--no-trace`, `BOBA_NO_TRACE`)
//! reduces `span` to a plain call: one relaxed atomic load, no clocks,
//! no allocation. With tracing on, the cost is two `Instant` reads, a
//! thread-local borrow, and one histogram record — `benches/micro_obs.rs`
//! holds this under 5 µs per span (in practice well under 1 µs).
//!
//! Spans are thread-local by design: work a leader executes on behalf
//! of parked followers (the coalescer) lands in the *leader's* trace;
//! the followers' traces show the wait (`coalesce.submit`). That is the
//! honest attribution — the kernel ran once.

use super::hist::Histogram;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Global tracing switch (default on; `BOBA_NO_TRACE=1` or `--no-trace`
/// turn it off at server start).
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Monotone per-process request/trace id source.
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Whether span recording is active.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip the global tracing switch; returns the previous value.
pub fn set_enabled(on: bool) -> bool {
    ENABLED.swap(on, Ordering::Relaxed)
}

/// Honour the `BOBA_NO_TRACE` environment kill switch (any non-empty
/// value other than `0` disables tracing). Called by the server at
/// spawn; idempotent.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("BOBA_NO_TRACE") {
        if !v.is_empty() && v != "0" {
            set_enabled(false);
        }
    }
}

/// One finished span in a trace tree.
#[derive(Clone, Debug)]
pub struct SpanNode {
    /// Stage name (`prepare.reorder`, `kernel.spmv`, ...).
    pub name: &'static str,
    /// Start offset from the trace begin, microseconds.
    pub start_us: u64,
    /// Wall time spent in the span, microseconds.
    pub us: u64,
    /// Nested spans, in execution order.
    pub children: Vec<SpanNode>,
}

impl SpanNode {
    /// JSON rendering (`{"name", "start_us", "us", "children"}`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("start_us", Json::Num(self.start_us as f64)),
            ("us", Json::Num(self.us as f64)),
            (
                "children",
                Json::Arr(self.children.iter().map(SpanNode::to_json).collect()),
            ),
        ])
    }
}

/// A completed request trace: the span tree plus request identity.
#[derive(Clone, Debug)]
pub struct Trace {
    /// Process-unique request id (echoed as `x-request-id`).
    pub id: u64,
    /// Endpoint name the request resolved to (`ingest`, `spmv`, ...).
    pub endpoint: &'static str,
    /// HTTP status the request answered with.
    pub status: u16,
    /// End-to-end request wall time, microseconds.
    pub total_us: u64,
    /// Top-level spans (each may nest).
    pub spans: Vec<SpanNode>,
}

impl Trace {
    /// Sum of top-level span durations — the traced share of
    /// [`Self::total_us`] (the acceptance gate: for a cold prepare these
    /// stages account for ≥90% of the request).
    pub fn spans_total_us(&self) -> u64 {
        self.spans.iter().map(|s| s.us).sum()
    }

    /// JSON rendering for `GET /debug/traces`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Str(format!("r-{}", self.id))),
            ("endpoint", Json::Str(self.endpoint.to_string())),
            ("status", Json::Num(self.status as f64)),
            ("total_us", Json::Num(self.total_us as f64)),
            ("spans_us", Json::Num(self.spans_total_us() as f64)),
            ("spans", Json::Arr(self.spans.iter().map(SpanNode::to_json).collect())),
        ])
    }

    /// Single-line JSON for the slow-trace stderr log (no interior
    /// newlines; one trace = one log line, grep-able by request id).
    pub fn render_line(&self) -> String {
        self.to_json().render()
    }
}

/// An open (still running) span frame on the thread-local stack.
struct OpenSpan {
    name: &'static str,
    start_us: u64,
    children: Vec<SpanNode>,
}

/// The trace being built on this thread.
struct Builder {
    id: u64,
    begun: Instant,
    stack: Vec<OpenSpan>,
    roots: Vec<SpanNode>,
}

thread_local! {
    static CURRENT: RefCell<Option<Builder>> = const { RefCell::new(None) };
}

/// Guard for one request trace. Created by [`begin`]; call
/// [`TraceGuard::finish`] to close it and collect the [`Trace`]. If the
/// guard is dropped unfinished (handler panic), the thread-local state
/// is cleared so the next request on this thread starts clean.
pub struct TraceGuard {
    /// This guard owns the thread-local builder (false when tracing is
    /// off or a trace was already active on this thread).
    active: bool,
    id: u64,
}

impl TraceGuard {
    /// The request id this guard allocated (0 when inactive).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Whether a trace is actually being recorded.
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Close the trace and return it (None when inactive). Spans still
    /// open on the stack (a panicking stage that was caught upstream)
    /// are folded into the tree with the time observed so far.
    pub fn finish(mut self, endpoint: &'static str, status: u16) -> Option<Trace> {
        if !self.active {
            return None;
        }
        self.active = false;
        CURRENT.with(|c| {
            let mut b = c.borrow_mut().take()?;
            let total_us = b.begun.elapsed().as_micros() as u64;
            // Fold any frames left open by an unwound stage.
            while let Some(open) = b.stack.pop() {
                let node = SpanNode {
                    name: open.name,
                    start_us: open.start_us,
                    us: total_us.saturating_sub(open.start_us),
                    children: open.children,
                };
                match b.stack.last_mut() {
                    Some(parent) => parent.children.push(node),
                    None => b.roots.push(node),
                }
            }
            Some(Trace { id: b.id, endpoint, status, total_us, spans: b.roots })
        })
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        if self.active {
            CURRENT.with(|c| c.borrow_mut().take());
        }
    }
}

/// Open a trace on this thread for one request. Returns an inactive
/// guard when tracing is disabled or a trace is already open (nested
/// begins never steal the outer trace).
pub fn begin() -> TraceGuard {
    if !enabled() {
        return TraceGuard { active: false, id: 0 };
    }
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    let fresh = CURRENT.with(|c| {
        let mut cur = c.borrow_mut();
        if cur.is_some() {
            return false;
        }
        *cur = Some(Builder { id, begun: Instant::now(), stack: Vec::new(), roots: Vec::new() });
        true
    });
    TraceGuard { active: fresh, id: if fresh { id } else { 0 } }
}

/// Run `f`, recording its wall time under `name` — into the stage
/// histogram always, and into the current thread's trace tree when one
/// is open. With tracing disabled this is a plain call (one relaxed
/// load).
pub fn span<T>(name: &'static str, f: impl FnOnce() -> T) -> T {
    // Fault point: an armed `slow-stage` chaos spec delays named stages
    // (simulating a seized disk or a cold cache) — when unarmed this is
    // one relaxed atomic load inside `chaos::fire`.
    if let Some(ms) = super::chaos::fire("slow-stage") {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
    if !enabled() {
        return f();
    }
    // Push an open frame if a trace is active (records the start offset).
    let traced = CURRENT.with(|c| {
        let mut cur = c.borrow_mut();
        match cur.as_mut() {
            Some(b) => {
                let start_us = b.begun.elapsed().as_micros() as u64;
                b.stack.push(OpenSpan { name, start_us, children: Vec::new() });
                true
            }
            None => false,
        }
    });
    let sw = Instant::now();
    let out = f();
    let us = sw.elapsed().as_micros() as u64;
    stage_record(name, us);
    if traced {
        CURRENT.with(|c| {
            let mut cur = c.borrow_mut();
            if let Some(b) = cur.as_mut() {
                if let Some(open) = b.stack.pop() {
                    let node = SpanNode {
                        name: open.name,
                        start_us: open.start_us,
                        us,
                        children: open.children,
                    };
                    match b.stack.last_mut() {
                        Some(parent) => parent.children.push(node),
                        None => b.roots.push(node),
                    }
                }
            }
        });
    }
    out
}

/// The process-wide stage-name → histogram registry. Names are
/// `&'static str` (stage vocabularies are compile-time), so lookup is a
/// pointer-or-bytes comparison over a short vector.
static STAGES: OnceLock<Mutex<Vec<(&'static str, Arc<Histogram>)>>> = OnceLock::new();

fn stages() -> &'static Mutex<Vec<(&'static str, Arc<Histogram>)>> {
    STAGES.get_or_init(|| Mutex::new(Vec::new()))
}

/// Record one duration under a stage name (what [`span`] does on exit;
/// public for externally-measured stages).
pub fn stage_record(name: &'static str, us: u64) {
    if !enabled() {
        return;
    }
    let hist = {
        let mut v = stages().lock().unwrap();
        match v.iter().find(|(n, _)| *n == name) {
            Some((_, h)) => h.clone(),
            None => {
                let h = Arc::new(Histogram::new());
                v.push((name, h.clone()));
                h
            }
        }
    };
    hist.record_us(us);
}

/// Snapshot of all stage histograms, in first-seen order (the
/// `/metrics` `boba_stage_duration_seconds` family iterates this).
pub fn stage_histograms() -> Vec<(&'static str, Arc<Histogram>)> {
    stages().lock().unwrap().clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_into_a_tree() {
        let guard = begin();
        assert!(guard.is_active());
        let out = span("test.outer", || {
            span("test.inner", || 7) + span("test.inner", || 35)
        });
        assert_eq!(out, 42);
        span("test.sibling", || ());
        let t = guard.finish("spmv", 200).expect("trace");
        assert_eq!(t.endpoint, "spmv");
        assert_eq!(t.spans.len(), 2);
        assert_eq!(t.spans[0].name, "test.outer");
        assert_eq!(t.spans[0].children.len(), 2);
        assert_eq!(t.spans[1].name, "test.sibling");
        assert!(t.total_us >= t.spans_total_us() || t.spans_total_us() - t.total_us < 1000);
        let j = t.to_json().render();
        assert!(j.contains("\"endpoint\":\"spmv\"") && j.contains("test.inner"), "{j}");
        assert!(!j.contains('\n'), "slow-trace log lines must be single-line");
    }

    #[test]
    fn nested_begin_does_not_steal_the_outer_trace() {
        let outer = begin();
        assert!(outer.is_active());
        let inner = begin();
        assert!(!inner.is_active());
        drop(inner);
        span("test.nested-begin", || ());
        let t = outer.finish("stats", 200).expect("outer trace survives");
        assert_eq!(t.spans.len(), 1);
    }

    #[test]
    fn spans_without_a_trace_feed_stage_histograms() {
        span("test.orphan-stage", || std::thread::sleep(std::time::Duration::from_micros(50)));
        let all = stage_histograms();
        let (_, h) = all
            .iter()
            .find(|(n, _)| *n == "test.orphan-stage")
            .expect("stage registered");
        assert!(h.count() >= 1);
    }

    #[test]
    fn kill_switch_disables_recording() {
        // Serialized via the env-independent global; restore on exit.
        let was = set_enabled(false);
        let g = begin();
        assert!(!g.is_active());
        let out = span("test.disabled", || 5);
        assert_eq!(out, 5);
        assert!(g.finish("spmv", 200).is_none());
        set_enabled(true);
        let before = stage_histograms()
            .iter()
            .find(|(n, _)| *n == "test.disabled")
            .map_or(0, |(_, h)| h.count());
        assert_eq!(before, 0, "disabled spans must not record");
        set_enabled(was);
    }

    #[test]
    fn dropped_guard_clears_thread_state() {
        let g = begin();
        assert!(g.is_active());
        drop(g); // simulated handler unwind
        let g2 = begin();
        assert!(g2.is_active(), "next request on the thread must trace");
        g2.finish("healthz", 200).unwrap();
    }

    #[test]
    fn request_ids_are_unique_and_monotone() {
        let a = begin();
        let ia = a.id();
        a.finish("healthz", 200).unwrap();
        let b = begin();
        assert!(b.id() > ia);
        b.finish("healthz", 200).unwrap();
    }
}
