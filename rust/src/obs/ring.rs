//! A fixed-size lock-free ring buffer of completed traces — the store
//! behind `GET /debug/traces?n=K`.
//!
//! Writers (request worker threads) claim a slot with one
//! `fetch_add` on the head and publish via an atomic pointer `swap`;
//! readers borrow a slot's trace by swapping the pointer out, cloning
//! the `Arc`, and CAS-ing the pointer back. Ownership of the heap trace
//! always transfers atomically through the slot, so a reader can never
//! observe a half-written trace and a concurrent writer can never free
//! a trace a reader still holds. If a writer lapped the slot while the
//! reader had it out (the CAS fails), the reader keeps its clone and
//! drops its raw pointer — the newer trace simply wins the slot.
//!
//! The cost per completed request is one allocation (the `Arc<Trace>`,
//! already built by the tracer) and two atomic ops; there is no lock to
//! convoy on when all workers publish at once.

use super::span::Trace;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// Capacity of the process-wide ring served by `/debug/traces`.
pub const GLOBAL_CAPACITY: usize = 256;

/// Fixed-capacity multi-writer trace ring. Holds the `capacity` most
/// recently published traces (approximately — concurrent writers may
/// interleave slot order, never content).
pub struct TraceRing {
    slots: Vec<AtomicPtr<Trace>>,
    head: AtomicUsize,
    pushed: AtomicU64,
}

impl TraceRing {
    /// New empty ring with `capacity` slots (minimum 1).
    pub fn new(capacity: usize) -> TraceRing {
        let capacity = capacity.max(1);
        TraceRing {
            slots: (0..capacity).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
            head: AtomicUsize::new(0),
            pushed: AtomicU64::new(0),
        }
    }

    /// Number of slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total traces ever published (the `boba_traces_total` counter).
    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }

    /// Publish a completed trace, displacing the oldest when full.
    pub fn push(&self, trace: Arc<Trace>) {
        // ordering: Relaxed — slot claim is a pure counter; publication
        // safety comes from the slot swap below, not from head.
        let at = self.head.fetch_add(1, Ordering::Relaxed) % self.slots.len();
        let fresh = Arc::into_raw(trace) as *mut Trace;
        // ordering: AcqRel — Release publishes the fully-built trace to
        // the reader's Acquire swap in `recent`; Acquire pairs with the
        // reader's CAS put-back so the displaced pointer's refcount
        // history is visible before we drop it.
        let old = self.slots[at].swap(fresh, Ordering::AcqRel);
        if !old.is_null() {
            // SAFETY: the swap transferred sole ownership of the
            // displaced slot's refcount to us; nobody else can reclaim
            // this pointer (a reader that still holds the trace holds
            // its own clone).
            unsafe { drop(Arc::from_raw(old)) };
        }
        self.pushed.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot up to `n` most recent traces, newest first. Slots a
    /// writer is mid-publish on (or that a concurrent reader has
    /// borrowed) are skipped — the reader only ever sees complete
    /// traces.
    pub fn recent(&self, n: usize) -> Vec<Arc<Trace>> {
        let cap = self.slots.len();
        // ordering: Acquire — pairs with writers' AcqRel slot swaps so
        // the head position we start walking from is no newer than the
        // slot contents we will observe.
        let head = self.head.load(Ordering::Acquire);
        let mut out = Vec::with_capacity(n.min(cap));
        for back in 1..=cap {
            if out.len() >= n {
                break;
            }
            let at = (head + cap - (back % cap)) % cap;
            // ordering: AcqRel — Acquire pairs with the writer's Release
            // swap in `push` so the trace body is fully visible; Release
            // publishes our null takeover to concurrent readers/writers.
            let raw = self.slots[at].swap(std::ptr::null_mut(), Ordering::AcqRel);
            if raw.is_null() {
                continue;
            }
            // Borrow: clone the Arc, then try to put the original back.
            // SAFETY: the swap transferred the slot's refcount to us —
            // `raw` came from `Arc::into_raw` in `push` (or our own
            // put-back below) and no other thread holds this reference.
            let owned = unsafe { Arc::from_raw(raw) };
            out.push(owned.clone());
            let back_in = Arc::into_raw(owned) as *mut Trace;
            // ordering: AcqRel on success — Release hands the refcount
            // back through the slot (pairs with `push`'s Acquire);
            // Relaxed on failure — we learned nothing we act on beyond
            // "a writer lapped us", and `back_in` stays thread-local.
            if self.slots[at]
                .compare_exchange(
                    std::ptr::null_mut(),
                    back_in,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                )
                .is_err()
            {
                // A writer lapped us; the newer trace keeps the slot.
                // SAFETY: the CAS failed, so the slot never took
                // `back_in` — the refcount we meant to hand back is
                // still ours to release.
                unsafe { drop(Arc::from_raw(back_in)) };
            }
        }
        out
    }
}

impl Drop for TraceRing {
    fn drop(&mut self) {
        for slot in &self.slots {
            // ordering: AcqRel — Acquire any in-flight publication
            // before reclaiming; &mut self means no new writers, but a
            // trace published just before drop must be fully visible.
            let raw = slot.swap(std::ptr::null_mut(), Ordering::AcqRel);
            if !raw.is_null() {
                // SAFETY: exclusive access (&mut self) — the slot's
                // refcount is the last reference routed through the
                // ring; readers that cloned keep their own Arcs.
                unsafe { drop(Arc::from_raw(raw)) };
            }
        }
    }
}

/// The process-wide ring `/debug/traces` serves.
pub fn global() -> &'static TraceRing {
    static RING: OnceLock<TraceRing> = OnceLock::new();
    RING.get_or_init(|| TraceRing::new(GLOBAL_CAPACITY))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(id: u64) -> Arc<Trace> {
        Arc::new(Trace { id, endpoint: "spmv", status: 200, total_us: id * 10, spans: Vec::new() })
    }

    #[test]
    fn recent_returns_newest_first_and_caps_at_capacity() {
        let ring = TraceRing::new(4);
        for id in 1..=6 {
            ring.push(trace(id));
        }
        assert_eq!(ring.pushed(), 6);
        let got = ring.recent(10);
        let ids: Vec<u64> = got.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![6, 5, 4, 3], "4 slots keep the last 4, newest first");
        // A second read sees the same traces (reader puts slots back).
        let again: Vec<u64> = ring.recent(2).iter().map(|t| t.id).collect();
        assert_eq!(again, vec![6, 5]);
    }

    #[test]
    fn empty_and_partial_rings() {
        let ring = TraceRing::new(8);
        assert!(ring.recent(5).is_empty());
        ring.push(trace(1));
        let got = ring.recent(5);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 1);
    }

    #[test]
    fn concurrent_writers_and_reader_see_only_complete_traces() {
        // The satellite stress test: many writers hammering a small
        // ring while a reader snapshots continuously. Every trace a
        // reader observes must be internally consistent (id encodes the
        // expected total_us), and nothing deadlocks or leaks.
        let ring = Arc::new(TraceRing::new(16));
        let writers = 8;
        let per = 500u64;
        std::thread::scope(|s| {
            for w in 0..writers {
                let ring = ring.clone();
                s.spawn(move || {
                    for i in 0..per {
                        let id = w * per + i + 1;
                        ring.push(trace(id));
                    }
                });
            }
            let ring2 = ring.clone();
            s.spawn(move || {
                for _ in 0..200 {
                    for t in ring2.recent(16) {
                        assert_eq!(t.total_us, t.id * 10, "torn trace observed");
                        assert_eq!(t.endpoint, "spmv");
                    }
                }
            });
        });
        assert_eq!(ring.pushed(), writers as u64 * per);
        let finals = ring.recent(16);
        assert_eq!(finals.len(), 16, "full ring after the storm");
        for t in &finals {
            assert_eq!(t.total_us, t.id * 10);
        }
    }
}
