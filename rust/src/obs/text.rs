//! Parser for the Prometheus text exposition — the consuming half of
//! [`super::metrics`].
//!
//! Two callers share it: `loadgen --scrape-metrics` (snapshot `/metrics`
//! before and after a run, diff the counters, embed server-side
//! percentiles in BENCH_serve.json) and the conformance suite in
//! `tests/obs_conformance.rs` (every family has HELP/TYPE, buckets are
//! cumulative and end in `+Inf`, counters are monotone). The parser is
//! deliberately strict — a sample without a preceding `# TYPE` for its
//! family is an error, which is exactly the conformance property the
//! tests want enforced.

use anyhow::{bail, Context, Result};

/// One parsed sample line (`name{labels} value`).
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Full sample name (may carry a `_bucket`/`_sum`/`_count` suffix).
    pub name: String,
    /// Label pairs in source order (values unescaped).
    pub labels: Vec<(String, String)>,
    /// Sample value (`+Inf` parses to `f64::INFINITY`).
    pub value: f64,
}

impl Sample {
    /// Label value lookup.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Whether this sample carries every `(key, value)` pair in `want`.
    pub fn matches(&self, want: &[(&str, &str)]) -> bool {
        want.iter().all(|(k, v)| self.label(k) == Some(*v))
    }
}

/// One metric family: the HELP/TYPE header plus its samples.
#[derive(Debug, Clone)]
pub struct Family {
    /// Family name (without sample suffixes).
    pub name: String,
    /// `counter` / `gauge` / `histogram`.
    pub typ: String,
    /// HELP text.
    pub help: String,
    /// Samples, in document order.
    pub samples: Vec<Sample>,
}

/// A fully parsed exposition document.
#[derive(Debug, Clone, Default)]
pub struct Scrape {
    /// Families in document order.
    pub families: Vec<Family>,
}

impl Scrape {
    /// Parse an exposition document. Strict: every sample must belong
    /// to a family announced by `# HELP` + `# TYPE` (exact name or a
    /// `_bucket`/`_sum`/`_count` suffix of it).
    pub fn parse(text: &str) -> Result<Scrape> {
        let mut scrape = Scrape::default();
        let mut pending_help: Option<(String, String)> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim_end();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let (name, help) = rest.split_once(' ').unwrap_or((rest, ""));
                pending_help = Some((name.to_string(), help.to_string()));
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let (name, typ) = rest
                    .split_once(' ')
                    .with_context(|| format!("line {}: TYPE without a type", lineno + 1))?;
                let help = match pending_help.take() {
                    Some((hname, help)) if hname == name => help,
                    _ => bail!("line {}: TYPE for {name} without matching HELP", lineno + 1),
                };
                if scrape.families.iter().any(|f| f.name == name) {
                    bail!("line {}: duplicate family {name}", lineno + 1);
                }
                scrape.families.push(Family {
                    name: name.to_string(),
                    typ: typ.trim().to_string(),
                    help,
                    samples: Vec::new(),
                });
                continue;
            }
            if line.starts_with('#') {
                continue; // other comments are legal and ignored
            }
            let sample = parse_sample(line)
                .with_context(|| format!("line {}: bad sample {line:?}", lineno + 1))?;
            let fam = scrape
                .families
                .iter_mut()
                .find(|f| {
                    sample.name == f.name
                        || ["_bucket", "_sum", "_count"].iter().any(|suf| {
                            sample.name.strip_suffix(suf).is_some_and(|base| base == f.name)
                        })
                })
                .with_context(|| {
                    format!("line {}: sample {} has no HELP/TYPE family", lineno + 1, sample.name)
                })?;
            fam.samples.push(sample);
        }
        Ok(scrape)
    }

    /// Family lookup by name.
    pub fn family(&self, name: &str) -> Option<&Family> {
        self.families.iter().find(|f| f.name == name)
    }

    /// First sample with this exact name whose labels include `labels`.
    pub fn sample(&self, name: &str, labels: &[(&str, &str)]) -> Option<&Sample> {
        self.families
            .iter()
            .flat_map(|f| f.samples.iter())
            .find(|s| s.name == name && s.matches(labels))
    }

    /// Scalar value lookup (counter/gauge).
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.sample(name, labels).map(|s| s.value)
    }

    /// Cumulative `(le, count)` buckets of a histogram family, in
    /// ascending bound order, `+Inf` (as `f64::INFINITY`) last. Empty
    /// when the family or label set is absent.
    pub fn histogram(&self, family: &str, labels: &[(&str, &str)]) -> Vec<(f64, f64)> {
        let name = format!("{family}_bucket");
        let mut out: Vec<(f64, f64)> = self
            .families
            .iter()
            .flat_map(|f| f.samples.iter())
            .filter(|s| s.name == name && s.matches(labels))
            .filter_map(|s| {
                let le = parse_value(s.label("le")?).ok()?;
                Some((le, s.value))
            })
            .collect();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        out
    }
}

/// Estimate quantile `q` from cumulative `(le, count)` buckets (what
/// `promql histogram_quantile` does, minus interpolation: the serving
/// histograms are log2-bucketed, so the bound itself is the honest
/// answer). Returns 0 when empty; a quantile landing in the `+Inf`
/// bucket reports the largest finite bound.
pub fn histogram_quantile(cum: &[(f64, f64)], q: f64) -> f64 {
    let total = cum.last().map_or(0.0, |&(_, c)| c);
    if total <= 0.0 {
        return 0.0;
    }
    let target = (q.clamp(0.0, 1.0) * total).ceil().max(1.0);
    let mut last_finite = 0.0;
    for &(le, c) in cum {
        if le.is_finite() {
            last_finite = le;
        }
        if c >= target {
            return if le.is_finite() { le } else { last_finite };
        }
    }
    last_finite
}

/// Subtract two cumulative bucket snapshots of the same family
/// (`post - pre`), yielding the cumulative distribution of just the
/// interval between the scrapes. Bounds present only in `post` (the
/// exposition trims trailing empty buckets, so `pre` may be shorter)
/// take `pre`'s total count as their baseline.
pub fn histogram_delta(pre: &[(f64, f64)], post: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let pre_total = pre.last().map_or(0.0, |&(_, c)| c);
    post.iter()
        .map(|&(le, c)| {
            let base = pre
                .iter()
                .find(|&&(ple, _)| ple == le)
                .map(|&(_, pc)| pc)
                .unwrap_or(if le.is_finite() { pre_total } else { 0.0 });
            (le, (c - base).max(0.0))
        })
        .collect()
}

fn parse_value(text: &str) -> Result<f64> {
    match text {
        "+Inf" | "Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        other => other.parse::<f64>().with_context(|| format!("bad value {other:?}")),
    }
}

fn parse_sample(line: &str) -> Result<Sample> {
    let bytes = line.as_bytes();
    let mut at = 0;
    while at < bytes.len()
        && (bytes[at].is_ascii_alphanumeric() || bytes[at] == b'_' || bytes[at] == b':')
    {
        at += 1;
    }
    if at == 0 {
        bail!("missing metric name");
    }
    let name = line[..at].to_string();
    let mut labels = Vec::new();
    let rest = &line[at..];
    let rest = if let Some(inner) = rest.strip_prefix('{') {
        let close = inner.rfind('}').context("unterminated label set")?;
        let mut l = &inner[..close];
        while !l.is_empty() {
            let eq = l.find('=').context("label without '='")?;
            let key = l[..eq].trim().to_string();
            let after = &l[eq + 1..];
            if !after.starts_with('"') {
                bail!("unquoted label value");
            }
            // Scan to the closing quote, honouring escapes; `i` indexes
            // into `after`, so `i + 1` is the byte just past the quote.
            let mut val = String::new();
            let mut chars = after.char_indices().skip(1);
            let mut past_quote = None;
            while let Some((i, c)) = chars.next() {
                match c {
                    '\\' => match chars.next() {
                        Some((_, 'n')) => val.push('\n'),
                        Some((_, e)) => val.push(e),
                        None => bail!("dangling escape in label value"),
                    },
                    '"' => {
                        past_quote = Some(i + 1);
                        break;
                    }
                    c => val.push(c),
                }
            }
            let past_quote = past_quote.context("unterminated label value")?;
            labels.push((key, val));
            l = after[past_quote..].trim_start_matches(',').trim_start();
        }
        &inner[close + 1..]
    } else {
        rest
    };
    let value_text = rest.trim();
    // A trailing timestamp (rare, we never emit one) would be a second
    // token; take the first.
    let value_text = value_text.split_whitespace().next().context("missing value")?;
    Ok(Sample { name, labels, value: parse_value(value_text)? })
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = "\
# HELP boba_requests_total Requests served.
# TYPE boba_requests_total counter
boba_requests_total{endpoint=\"spmv\"} 42
boba_requests_total{endpoint=\"pagerank\"} 7
# HELP boba_request_duration_seconds Request latency.
# TYPE boba_request_duration_seconds histogram
boba_request_duration_seconds_bucket{endpoint=\"spmv\",le=\"0.001\"} 30
boba_request_duration_seconds_bucket{endpoint=\"spmv\",le=\"0.004\"} 40
boba_request_duration_seconds_bucket{endpoint=\"spmv\",le=\"+Inf\"} 42
boba_request_duration_seconds_sum{endpoint=\"spmv\"} 0.05
boba_request_duration_seconds_count{endpoint=\"spmv\"} 42
# HELP boba_uptime_seconds Uptime.
# TYPE boba_uptime_seconds gauge
boba_uptime_seconds 12.5
";

    #[test]
    fn parses_families_samples_and_histograms() {
        let s = Scrape::parse(DOC).unwrap();
        assert_eq!(s.families.len(), 3);
        assert_eq!(s.family("boba_requests_total").unwrap().typ, "counter");
        assert_eq!(s.value("boba_requests_total", &[("endpoint", "spmv")]), Some(42.0));
        assert_eq!(s.value("boba_uptime_seconds", &[]), Some(12.5));
        let h = s.histogram("boba_request_duration_seconds", &[("endpoint", "spmv")]);
        assert_eq!(h.len(), 3);
        assert_eq!(h[0], (0.001, 30.0));
        assert!(h[2].0.is_infinite());
        assert_eq!(
            s.value("boba_request_duration_seconds_count", &[("endpoint", "spmv")]),
            Some(42.0)
        );
    }

    #[test]
    fn rejects_headerless_samples_and_orphan_type() {
        assert!(Scrape::parse("boba_x_total 1\n").is_err(), "sample without family");
        assert!(Scrape::parse("# TYPE boba_x_total counter\n").is_err(), "TYPE without HELP");
        let dup = "# HELP a_total x\n# TYPE a_total counter\n# HELP a_total x\n# TYPE a_total counter\n";
        assert!(Scrape::parse(dup).is_err(), "duplicate family");
    }

    #[test]
    fn label_escapes_round_trip_with_the_builder() {
        let mut p = super::super::metrics::PromText::new();
        p.family("m_total", "counter", "x");
        p.value("m_total", &[("k", "a\"b\\c\nd")], 3.0);
        let s = Scrape::parse(&p.render()).unwrap();
        assert_eq!(s.value("m_total", &[("k", "a\"b\\c\nd")]), Some(3.0));
    }

    #[test]
    fn quantiles_from_cumulative_buckets() {
        let cum = [(0.001, 30.0), (0.004, 40.0), (f64::INFINITY, 42.0)];
        assert_eq!(histogram_quantile(&cum, 0.5), 0.001);
        assert_eq!(histogram_quantile(&cum, 0.9), 0.004);
        // p99 lands in +Inf; report the largest finite bound.
        assert_eq!(histogram_quantile(&cum, 0.99), 0.004);
        assert_eq!(histogram_quantile(&[], 0.5), 0.0);
    }

    #[test]
    fn delta_handles_trimmed_pre_snapshots() {
        // pre was trimmed at 0.001 (nothing slower had happened yet).
        let pre = [(0.001, 10.0), (f64::INFINITY, 10.0)];
        let post = [(0.001, 12.0), (0.004, 15.0), (f64::INFINITY, 16.0)];
        let d = histogram_delta(&pre, &post);
        assert_eq!(d, vec![(0.001, 2.0), (0.004, 5.0), (f64::INFINITY, 6.0)]);
        let p50 = histogram_quantile(&d, 0.5);
        assert_eq!(p50, 0.004);
    }
}
